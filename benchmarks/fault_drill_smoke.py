"""Fault-drill benchmark gate -> BENCH_PR8.json (robustness point).

Two gated sections, CI-sized and deterministic:

* `drill_parity` — a fixed 3-fault plan (crash at window 3, corrupt-
  newest-checkpoint at 7, NaN-poisoned pool at 10) driven through the
  RunSupervisor at window_block=4 with a sketch attached. GATE: the
  drilled run's records, trajectories, and sketch histograms are
  BITWISE identical to the uninterrupted run, and the supervisor
  reports exactly 3 restarts.
* `supervisor_overhead` — the same config fault-free, supervised
  (cadenced atomic checkpoints + retention + guards) vs the bare
  `engine.run_block` loop. GATE: supervised wall <= 1.05x bare wall
  (the ISSUE's <= 5% overhead bar) — cadence spreads the checkpoint
  cost across blocks and guards read stats the collector already
  pulled, so the steady path stays device-bound. Both walls are
  medians over repeated runs in one process (same compile cache).

  PYTHONPATH=src python benchmarks/fault_drill_smoke.py [out.json]
"""
from __future__ import annotations

import json
import os
import shutil
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

import numpy as np  # noqa: E402

from repro.api import (  # noqa: E402
    Ensemble,
    Experiment,
    FailurePlan,
    Recovery,
    Reduction,
    Schedule,
    SketchSpec,
    simulate,
)
from repro.api.run import build_engine  # noqa: E402
from repro.core.cwc.models import lotka_volterra  # noqa: E402

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
N_INSTANCES, N_LANES, N_WINDOWS = 128, 16, 12
WINDOW_BLOCK = 4
CADENCE = 4
PLAN = {3: "crash", 7: "ckpt_corrupt", 10: "nan_pool"}
OVERHEAD_GATE = 1.05
REPEATS = 5


def make_exp(**kw):
    kw.setdefault("record_trajectories", True)
    return Experiment(
        model=lotka_volterra(2),
        ensemble=Ensemble.make(replicas=N_INSTANCES),
        schedule=Schedule(t_end=1.0, n_windows=N_WINDOWS, schema="iii"),
        reduction=Reduction.ENSEMBLE,
        n_lanes=N_LANES, seed=7, window_block=WINDOW_BLOCK, **kw)


def drill_parity_section():
    sk = SketchSpec(n_bins=8, lo=0.0, hi=600.0)
    base = simulate(make_exp(sketch=sk))
    tmp = tempfile.mkdtemp(prefix="fault_drill_")
    try:
        got = simulate(make_exp(sketch=sk, recovery=Recovery(
            ckpt_dir=os.path.join(tmp, "rec"), cadence=CADENCE,
            keep_last=2, inject=FailurePlan(schedule=PLAN))))
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    assert len(base.records) == len(got.records)
    for ra, rb in zip(base.records, got.records):
        assert (ra.mean == rb.mean).all() and (ra.var == rb.var).all()
        assert (ra.ci90 == rb.ci90).all()
    assert (base.trajectories() == got.trajectories()).all()
    for sa, sb in zip(base.sketches(), got.sketches()):
        assert (sa.hist == sb.hist).all()
    rep = got.recovery_report()
    assert rep["restarts"] == len(PLAN), rep
    row = {
        "plan": {str(w): k for w, k in sorted(PLAN.items())},
        "restarts": rep["restarts"],
        "faults_by_kind": rep["faults_by_kind"],
        "records_bitwise": True,
        "sketches_bitwise": True,
        "trajectories_bitwise": True,
    }
    print(f"drill_parity: {row}")
    return row


def _bare_wall() -> float:
    eng = build_engine(make_exp())
    t0 = time.perf_counter()
    while eng._window < len(eng.grid):
        eng.run_block(pipeline=True)
    return time.perf_counter() - t0


def _supervised_wall(tmp: str) -> float:
    exp = make_exp(recovery=Recovery(
        ckpt_dir=os.path.join(tmp, "rec"), cadence=CADENCE, keep_last=2))
    t0 = time.perf_counter()
    simulate(exp)
    return time.perf_counter() - t0


def overhead_section():
    bares, sups = [], []
    for i in range(REPEATS):
        bares.append(_bare_wall())
        tmp = tempfile.mkdtemp(prefix="fault_overhead_")
        try:
            sups.append(_supervised_wall(tmp))
        finally:
            shutil.rmtree(tmp, ignore_errors=True)
    # medians: first iterations pay compile, the gate is steady-state
    bare = float(np.median(bares))
    sup = float(np.median(sups))
    ratio = sup / bare
    row = {
        "bare_wall_ms": round(bare * 1e3, 2),
        "supervised_wall_ms": round(sup * 1e3, 2),
        "overhead_ratio": round(ratio, 4),
        "gate": OVERHEAD_GATE,
        "repeats": REPEATS,
    }
    print(f"supervisor_overhead: {row}")
    assert ratio <= OVERHEAD_GATE, (
        f"fault-free supervisor overhead {ratio:.3f}x exceeds the "
        f"{OVERHEAD_GATE}x gate")
    return row


def main() -> None:
    out_path = sys.argv[1] if len(sys.argv) > 1 else os.path.join(
        REPO, "BENCH_PR8.json")
    report = {
        "bench": "fault_drill_smoke",
        "config": {
            "n_instances": N_INSTANCES, "n_lanes": N_LANES,
            "n_windows": N_WINDOWS, "window_block": WINDOW_BLOCK,
            "cadence": CADENCE,
        },
        "drill_parity": drill_parity_section(),
        "supervisor_overhead": overhead_section(),
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(f"wrote {out_path}")


if __name__ == "__main__":
    main()
