"""Benchmark harness — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (see benchmarks/common.py).

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run fig4 fig7  # subset
"""
from __future__ import annotations

import sys


def main() -> None:
    which = set(sys.argv[1:])

    def want(name: str) -> bool:
        return not which or name in which

    print("name,us_per_call,derived")
    if want("fig4"):
        from benchmarks import fig4_simd

        fig4_simd.main()
    if want("fig7"):
        from benchmarks import fig7_scalability

        fig7_scalability.main()
    if want("fig1"):
        from benchmarks import fig1_trajectories

        fig1_trajectories.main()


if __name__ == "__main__":
    main()
