"""Paper Fig. 1 reproduction: E. coli gene-regulation ensemble.

100 independent instances, mean + variance (90% confidence) at fixed
simulation time steps, reduced ON-LINE (schema iii). Emits the summary
CSV row and writes the full trajectory statistics next to the bench.
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import emit
from repro.core.cwc.models import ecoli_gene_regulation
from repro.core.engine import SimConfig, SimulationEngine
from repro.core.stream import csv_sink

OUT = os.environ.get("FIG1_OUT", "artifacts/fig1_ecoli_stats.csv")


def main() -> None:
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    cfg = SimConfig(n_instances=100, t_end=100.0, n_windows=100,
                    n_lanes=100, schema="iii", seed=0)
    eng = SimulationEngine(ecoli_gene_regulation(), cfg)
    eng.stream.attach(csv_sink(OUT, eng.obs_names))
    t0 = time.perf_counter()
    recs = eng.run()
    wall = time.perf_counter() - t0
    last = recs[-1]
    protein = last.mean[eng.obs_names.index("ecoli/protein")]
    ci = last.ci90[eng.obs_names.index("ecoli/protein")]
    emit("fig1/ecoli_100x100windows", wall * 1e6 / len(recs),
         f"protein_mean={protein:.1f} ci90={ci:.2f} csv={OUT}")


if __name__ == "__main__":
    main()
