"""Roofline table from the dry-run artifacts (deliverable (g)).

Reads artifacts/dryrun/*.json (written by repro.launch.dryrun) and
prints the per-cell three-term roofline, dominant bottleneck, and
useful-FLOPs ratio. No devices are touched — safe inside benchmarks.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

ART = os.environ.get("DRYRUN_ART", "artifacts/dryrun")


def load_cells(tag: str = "") -> list[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(ART, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("tag", "") == tag and "roofline" in r:
            out.append(r)
    return out


def main() -> None:
    cells = load_cells()
    if not cells:
        print("roofline/no_artifacts,0,run repro.launch.dryrun first")
        return
    for r in sorted(cells, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        rf = r["roofline"]
        name = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
        emit(name, rf["bound_s"] * 1e6,
             f"dom={rf['dominant'].replace('_s','')} "
             f"comp={rf['compute_s']*1e3:.1f}ms "
             f"mem={rf['memory_s']*1e3:.1f}ms "
             f"coll={rf['collective_s']*1e3:.1f}ms "
             f"compute_frac={rf['compute_fraction']:.2f} "
             f"useful_flops={r['useful_flops_ratio']:.2f} "
             f"peak_GiB={r['memory']['peak_bytes']/2**30:.1f} "
             f"fits={r['fits_hbm']}")


if __name__ == "__main__":
    main()
