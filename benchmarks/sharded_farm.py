"""Sharded ensemble farm scalability (the paper's Fig. 7 sweep, taken
distributed): the same experiment farmed over 1/2/4/8 shards, with and
without the Pallas fused kernel inside each shard (the paper's two
families — single-simulation speedup × simulation farm — composed).

XLA's forced host-device count must be set before jax imports, so each
shard count runs in a subprocess (same pattern as
tests/test_distributed.py). Per point we report:

  * steady-state window wall time (median, post-warmup),
  * device dispatches — one per window on the sharded path, O(1) in
    shard count (vs one per group x window on the host-loop baseline),
  * blocking host syncs,
  * a digest of the records, asserting every shard count — AND the
    kernel vs jnp window body — reproduces the single-device fused
    baseline BIT-IDENTICALLY (counter-based per-lane RNG, stat_blocks
    pinned).

Forced host devices share the machine's cores, so wall time on one CPU
is about flat (the win is the dispatch/sync profile and the per-device
memory slice); on a real multi-host mesh the same program scales the
paper's farm across nodes.

  PYTHONPATH=src python benchmarks/sharded_farm.py
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SHARD_COUNTS = (1, 2, 4, 8)
STAT_BLOCKS = 8

CHILD = """
import hashlib
import numpy as np
from repro.api import (Ensemble, Experiment, Partitioning, Schedule,
                       simulate)
from repro.core.cwc.models import lotka_volterra

K = {k}
exp = Experiment(
    model=lotka_volterra(2),
    ensemble=Ensemble.make(replicas={instances}),
    schedule=Schedule(t_end=2.0, n_windows={windows}, schema="iii"),
    n_lanes={lanes}, seed=7, use_kernel={kernel},
    window_block={window_block},
    partitioning=Partitioning(n_shards=K, stat_blocks={blocks}))
res = simulate(exp)
tele = res.telemetry
steady = sorted(tele.window_wall_times[1:])
digest = hashlib.sha256(
    np.stack([np.concatenate([r.mean, r.var, r.ci90]) for r in
              res.records]).tobytes()).hexdigest()[:16]
print(f"{{K}},{{tele.dispatches}},{{tele.host_syncs}},"
      f"{{1e3 * steady[len(steady) // 2]:.2f}},"
      f"{{tele.wall_time_s:.2f}},{{digest}}")
"""


def run_point(k: int, instances: int, lanes: int, windows: int,
              kernel: bool = False, window_block: int = 1) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={k}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    snippet = textwrap.dedent(CHILD.format(
        k=k, instances=instances, lanes=lanes, windows=windows,
        blocks=STAT_BLOCKS, kernel=kernel, window_block=window_block))
    out = subprocess.run([sys.executable, "-c", snippet],
                         capture_output=True, text=True, env=env,
                         timeout=1200)
    if out.returncode != 0:
        raise SystemExit(out.stderr[-4000:])
    return out.stdout.strip()


def main() -> None:
    instances, lanes, windows = 512, 64, 8
    print(f"# sharded_farm: {instances} instances, {lanes} lanes, "
          f"{windows} windows, stat_blocks={STAT_BLOCKS}")
    print("shards,kernel,dispatches,host_syncs,wall_per_window_ms,"
          "wall_total_s,records_sha")
    digests = {}
    for kernel in (False, True):
        for k in SHARD_COUNTS:
            row = run_point(k, instances, lanes, windows, kernel=kernel)
            digests[(k, kernel)] = row.rsplit(",", 1)[1]
            shards, rest = row.split(",", 1)
            print(f"{shards},{int(kernel)},{rest}")
    assert len(set(digests.values())) == 1, (
        f"records diverged across shard counts / window bodies: "
        f"{digests}")
    print(f"#  records bit-identical across shards {SHARD_COUNTS} AND "
          "across kernel/jnp window bodies; dispatches stay one per "
          "window (O(1) in shard count)")


if __name__ == "__main__":
    main()
