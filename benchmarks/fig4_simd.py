"""Paper Fig. 4 reproduction + the TPU flip.

The paper vectorised *within* one instance (SSE, 4 lanes) and measured
speedup 0.99–1.02 on n-species Lotka-Volterra — Amdahl kills it because
only Match_Populations/Update vectorise. We reproduce the claim's
structure and then show the adaptation that changes the answer:
vectorise *across* instances (DESIGN.md §2).

Columns:
  pure_python   — paper's "sequential C++" stand-in (reference.py per-step
                  machinery driven on a flat term)
  jnp_1lane     — tensorised step, batch=1 (intra-instance vectorisation
                  only; the paper's SIMD analogue)
  jnp_256lane   — the same step, 256 instances per call (cross-instance)
  pallas_fused  — fused multi-step VMEM-resident kernel (interpret mode
                  on CPU — per-step cost is NOT hardware-representative,
                  reported for completeness; see EXPERIMENTS.md)
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core.cwc import reference
from repro.core.cwc.compile import compile_model
from repro.core.cwc.models import lotka_volterra
from repro.core.gillespie import advance_to, init_lanes, system_tensors
from repro.kernels.ops import fused_window

HORIZON = 0.05
N_SPECIES = (2, 4, 8, 16, 32)


def bench_pure_python(model, horizon: float) -> tuple[float, int]:
    term = model.initial_term()
    rng = np.random.default_rng(0)
    t, steps = 0.0, 0
    t0 = time.perf_counter()
    while t < horizon:
        t, alive = reference.simulation_step(term, model.rules, t, rng)
        steps += 1
        if not alive:
            break
    wall = time.perf_counter() - t0
    return wall / max(steps, 1), steps


def run(n_species: int):
    model = lotka_volterra(n_species)
    system, _ = compile_model(model)
    tensors = system_tensors(system)

    py_per_step, _ = bench_pure_python(model, HORIZON)

    def run_lanes(n_lanes):
        pool = init_lanes(system, n_lanes, seed=1)
        adv = jax.jit(lambda p: advance_to(p, tensors, HORIZON))
        wall = time_fn(adv, pool)
        steps = float(np.asarray(adv(pool).steps).sum())
        return wall / max(steps, 1)  # seconds per simulated event

    one = run_lanes(1)
    many = run_lanes(256)

    pool = init_lanes(system, 256, seed=1)
    t0 = time.perf_counter()
    out = fused_window(pool, tensors, HORIZON, chunk_steps=64)
    jax.block_until_ready(out.state.x)
    wall = time.perf_counter() - t0
    assert not bool(out.truncated), (
        f"fig4/lv{n_species}: fused window hit its chunk budget — the "
        "per-event number would cover a partial window; raise "
        "chunk_steps/max_chunks")
    fused = wall / max(float(np.asarray(out.state.steps).sum()), 1)

    emit(f"fig4/lv{n_species}/pure_python_per_event", py_per_step * 1e6)
    emit(f"fig4/lv{n_species}/jnp_1lane_per_event", one * 1e6,
         f"intra_speedup={py_per_step/one:.2f}")
    emit(f"fig4/lv{n_species}/jnp_256lane_per_event", many * 1e6,
         f"cross_speedup={py_per_step/many:.2f}")
    emit(f"fig4/lv{n_species}/pallas_fused_per_event(interp)", fused * 1e6)


def main() -> None:
    for n in N_SPECIES:
        run(n)


if __name__ == "__main__":
    main()
