"""Large-network benchmark smoke run -> BENCH_PR7.json.

Events/sec vs n_reactions for the DENSE engine against the SPARSE
dependency-graph engine (`Experiment(sparse=True)`) on generated
structured CWC models (`cell_ring_model` / `cell_lattice_model` —
compartment rings and lattices, hundreds to thousands of species and
reactions). Per row:

* dense and sparse runs are interleaved and repeated; the reported
  throughput is the BEST of the trials for each path (machine noise
  only ever deflates events/sec, so max-of-N is the low-variance
  estimator, applied identically to both paths),
* events/sec = exact-SSA events fired per second of steady
  (post-compile) wall: one warmup window is simulated first, the
  remaining windows are timed end to end through `result.resume()`,
* the records of every sparse run are asserted BITWISE equal to the
  dense run's (mean/var/ci90) — the speedup must not buy a different
  simulation.

THE GATE (CI): on the largest generated model (the 16x16 lattice,
R = 2048 >= 512) the sparse engine must deliver >= 2x the dense
events/sec. The smaller rows chart the events/sec-vs-R curve and are
reported ungated: the dependency-graph update wins asymptotically (the
dense per-event Match/Update is O(R*S) where sparse pays O(out-degree)
past the shared O(R) Resolve reduction), so the margin grows with R
and the gate sits where the win is structural, not noise.

  PYTHONPATH=src python benchmarks/large_network_smoke.py [out.json]
"""
from __future__ import annotations

import json
import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))

import numpy as np  # noqa: E402

from repro.api import Ensemble, Experiment, Schedule, simulate  # noqa: E402
from repro.core.cwc.compile import (  # noqa: E402
    cell_lattice_model,
    cell_ring_model,
    compile_model,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SEED = 11
N_LANES = 64
T_END = 0.5
N_WINDOWS = 4
TRIALS = 3
GATE_MIN_R = 512
GATE_SPEEDUP = 2.0
# (name, model builder, gated): ordered by n_reactions so the JSON rows
# read as the events/sec-vs-R curve; the largest model carries the gate
ROWS = (
    ("ring16", lambda: cell_ring_model(16), False),
    ("ring80", lambda: cell_ring_model(80), False),
    ("lattice16x16", lambda: cell_lattice_model(16, 16), True),
)


def run_path(model, sparse: bool):
    """One measured run: warmup window (compile + first dispatch), then
    the remaining windows timed end to end. Returns (result, events/s,
    steady wall seconds)."""
    exp = Experiment(
        model=model,
        ensemble=Ensemble.make(replicas=N_LANES),
        schedule=Schedule(t_end=T_END, n_windows=N_WINDOWS),
        n_lanes=N_LANES, seed=SEED, sparse=sparse)
    res = simulate(exp, max_windows=1)
    t0 = time.perf_counter()
    res = res.resume()
    wall = time.perf_counter() - t0
    events = int(np.sum(res.telemetry.steps_per_window[1:]))
    return res, events / wall, wall


def assert_records_bitwise(dense, sparse, name: str):
    for a, b in zip(dense.records, sparse.records):
        assert a.t == b.t and a.n == b.n, name
        assert (a.mean == b.mean).all(), (
            f"{name}: sparse records diverged from dense (mean)")
        assert (a.var == b.var).all(), (
            f"{name}: sparse records diverged from dense (var)")
        assert (a.ci90 == b.ci90).all(), (
            f"{name}: sparse records diverged from dense (ci90)")


def bench_row(name: str, build, gated: bool) -> dict:
    model = build()
    system = compile_model(model)[0]
    r, s = system.n_reactions, system.n_species
    dense_best = sparse_best = 0.0
    dense_res = None
    for _ in range(TRIALS):  # interleaved so load drift hits both paths
        d_res, d_evps, _ = run_path(model, sparse=False)
        s_res, s_evps, _ = run_path(model, sparse=True)
        dense_res = dense_res or d_res
        assert_records_bitwise(d_res, s_res, name)
        dense_best = max(dense_best, d_evps)
        sparse_best = max(sparse_best, s_evps)
    speedup = sparse_best / dense_best
    row = {
        "n_reactions": r,
        "n_species": s,
        "dense_events_per_s": round(dense_best, 1),
        "sparse_events_per_s": round(sparse_best, 1),
        "speedup_sparse_vs_dense": round(speedup, 3),
        "gated": gated,
        "records_bitwise_equal": True,
    }
    print(f"large_network/{name}: R={r} S={s} dense {dense_best:,.0f} "
          f"ev/s sparse {sparse_best:,.0f} ev/s -> {speedup:.2f}x"
          f"{' [gated]' if gated else ''}")
    if gated:
        assert r >= GATE_MIN_R, (
            f"{name}: gate row must be a large network (R={r} < "
            f"{GATE_MIN_R})")
        assert speedup >= GATE_SPEEDUP, (
            f"{name}: sparse {sparse_best:,.0f} ev/s is only "
            f"{speedup:.2f}x dense {dense_best:,.0f} ev/s "
            f"(gate: >= {GATE_SPEEDUP}x at R >= {GATE_MIN_R})")
    return row


def main() -> None:
    out_path = sys.argv[1] if len(sys.argv) > 1 else os.path.join(
        REPO, "BENCH_PR7.json")
    rows = {name: bench_row(name, build, gated)
            for name, build, gated in ROWS}
    doc = {
        "pr": 7,
        "generated_by": "benchmarks/large_network_smoke.py",
        "config": {
            "lanes": N_LANES, "t_end": T_END, "windows": N_WINDOWS,
            "seed": SEED, "trials": TRIALS,
            "throughput_measure": (
                "events/sec = exact-SSA events over the steady "
                "(post-warmup-window) end-to-end wall of resume(); "
                "best of the interleaved trials per path"),
            "gate": {
                "min_n_reactions": GATE_MIN_R,
                "min_speedup": GATE_SPEEDUP,
                "row": "lattice16x16"},
        },
        "events_per_s_vs_n_reactions": rows,
        "invariants": {
            "sparse_records_bitwise_equal_dense": True,
            "gated_row_speedup_ge_2x_at_r_ge_512": True,
        },
    }
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {out_path}")


if __name__ == "__main__":
    main()
