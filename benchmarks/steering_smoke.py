"""Early-stop steering benchmark -> the BENCH_PR6 savings row.

A mixed-variance immigration-death sweep (X(t) ~ Poisson(m(t)),
m(t) = (lam/mu)(1 - e^{-mu t})): the relative CI half-width at
saturation is 1.645 / sqrt(replicas * lam / mu), so high-lam points
converge (in relative terms) well before low-lam ones. With
`Steering(ci_rel_tol=...)` the converged points early-stop at the
first decision point past `min_windows`, freezing their lanes while
the noisy point runs the full grid.

Gates (CI asserts both):
* point-windows simulated with convergence stopping must be >= 1.2x
  fewer than without (the unsteered run always simulates
  n_points x n_windows);
* moment accuracy is unchanged: every point's final mean stays within
  3 sigma of the analytic Poisson value, and the never-stopped point's
  final record is BITWISE the unsteered run's (steering never touches
  a live lane when reallocation is off).

  PYTHONPATH=src python benchmarks/steering_smoke.py
"""
from __future__ import annotations

import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "src"))

from repro.api import (  # noqa: E402
    Ensemble,
    Experiment,
    Reduction,
    Schedule,
    SketchSpec,
    Steering,
    simulate,
)
from repro.core.reactions import make_system  # noqa: E402

BD_MU = 1.0
BD_LAMS = (50.0, 200.0, 800.0)  # mixed variance: rel CI ~ 1/sqrt(lam)
REPLICAS, N_LANES = 64, 16
N_WINDOWS, T_END = 12, 12.0
WINDOW_BLOCK = 2
CI_REL_TOL = 0.02  # stops lam=200/800; lam=50 stays noisy (~0.029)
MIN_WINDOWS = 6    # m(6)/m(inf) = 99.75% — freeze bias << sigma
SEED = 11


def _model():
    return make_system(
        ["A"],
        [({}, {"A": 1}, BD_LAMS[0]), ({"A": 1}, {}, BD_MU)],
        {"A": 0}, names=("birth", "death"))


def _experiment(steering):
    return Experiment(
        model=_model(),
        ensemble=Ensemble.make(replicas=REPLICAS,
                               sweep={"birth": list(BD_LAMS)}),
        schedule=Schedule(t_end=T_END, n_windows=N_WINDOWS),
        reduction=Reduction.PER_POINT,
        n_lanes=N_LANES, seed=SEED, window_block=WINDOW_BLOCK,
        sketch=SketchSpec(n_bins=32),
        steering=steering)


def early_stop_section() -> dict:
    base = simulate(_experiment(None))
    steered = simulate(_experiment(
        Steering(ci_rel_tol=CI_REL_TOL, min_windows=MIN_WINDOWS)))
    rep = steered.steering_report()
    total = rep["point_windows_total"]
    simulated = rep["point_windows_simulated"]
    ratio = rep["windows_saved_ratio"]
    print(f"early_stop: {len(rep['stopped_points'])}/{rep['n_points']} "
          f"points stopped at {rep['stop_windows']}; point-windows "
          f"{simulated}/{total} simulated ({ratio:.2f}x fewer)")
    assert ratio >= 1.2, (
        f"early-stop saved only {ratio:.2f}x point-windows "
        f"({simulated}/{total}); the >= 1.2x gate failed")

    # moment gate: every point's final mean within 3 sigma of the
    # analytic value at its freeze time (a stopped point's record is
    # frozen at its stop window, so that is the time it estimates)
    pp = steered.per_point()
    dt = T_END / N_WINDOWS
    zs = {}
    for p, lam in enumerate(BD_LAMS):
        t_freeze = rep["stop_windows"].get(p, N_WINDOWS) * dt
        m_true = lam / BD_MU * (1 - np.exp(-BD_MU * t_freeze))
        sd_mean = np.sqrt(m_true / REPLICAS)  # Poisson var = mean
        zs[f"birth={lam:g}"] = round(float(
            abs(pp["mean"][-1, p, 0] - m_true) / sd_mean), 3)
    print(f"early_stop: final-mean z-scores vs analytic: {zs}")
    assert max(zs.values()) <= 3.0, (
        f"steered final means drifted beyond 3 sigma: {zs}")

    # never-stopped points are untouched: bitwise vs the unsteered run
    base_pp = base.per_point()
    live = [p for p in range(len(BD_LAMS))
            if p not in rep["stopped_points"]]
    assert live, "expected at least one point to stay live"
    for p in live:
        assert (pp["mean"][-1, p] == base_pp["mean"][-1, p]).all(), (
            f"live point {p} diverged from the unsteered run")
    return {
        "point_windows_total": total,
        "point_windows_simulated": simulated,
        "windows_saved_ratio": round(ratio, 3),
        "stopped_points": rep["stopped_points"],
        "stop_windows": {str(k): v
                         for k, v in rep["stop_windows"].items()},
        "final_mean_z_vs_analytic": zs,
        "live_points_bitwise_vs_unsteered": True,
    }


if __name__ == "__main__":
    import json

    print(json.dumps(early_stop_section(), indent=2))
