"""Statistical benchmark smoke -> BENCH_PR4.json (tau-leaping's
entry in the perf trajectory).

Two sections, CI-sized, all seeded/deterministic:

* fig4 model (the 2-species Lotka-Volterra of the paper's Fig. 4,
  windows at the fig4 horizon scale): exact SSA vs Method.TAU_LEAP —
  solver steps per window, wall per window, the steps-per-unit-sim-time
  ratio (asserted >= 5x), leap share, and the tau-vs-exact ensemble
  moment agreement in z-units (asserted <= 3);
* birth-death with ANALYTIC ground truth (X(t) ~ Poisson(m(t))): both
  methods' mean/variance errors in sigma units of the analytic value
  (asserted <= 3).

  PYTHONPATH=src python benchmarks/stat_smoke.py [out.json]
"""
from __future__ import annotations

import json
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "src"))

from repro.api import Ensemble, Experiment, Method, Schedule, simulate  # noqa: E402
from repro.core.cwc.models import lotka_volterra  # noqa: E402
from repro.core.reactions import make_system  # noqa: E402

REPLICAS, N_LANES, N_WINDOWS = 128, 32, 4
FIG4_T_END = 0.2  # 4 windows at the fig4 per-event benchmark horizon
TAU_EPS = 0.05
BD_LAM, BD_MU, BD_T_END = 400.0, 1.0, 2.0


def _run(model, method, t_end, **kw):
    res = simulate(Experiment(
        model=model, ensemble=Ensemble.make(replicas=REPLICAS),
        schedule=Schedule(t_end=t_end, n_windows=N_WINDOWS),
        n_lanes=N_LANES, seed=7, method=method, **kw))
    tele = res.telemetry
    steady = sorted(tele.window_wall_times[1:])
    return res, {
        "steps_per_window": list(tele.steps_per_window),
        "leaps_per_window": list(tele.leaps_per_window),
        "wall_per_window_ms": round(
            1e3 * steady[len(steady) // 2], 3),
        "dispatches_per_window": tele.dispatches / N_WINDOWS,
        "host_syncs_per_window": tele.host_syncs / N_WINDOWS,
    }


def fig4_section():
    model = lotka_volterra(2)
    ex, m_ex = _run(model, Method.EXACT, FIG4_T_END)
    tl, m_tl = _run(model, Method.TAU_LEAP, FIG4_T_END, tau_eps=TAU_EPS)
    s_ex = sum(m_ex["steps_per_window"])
    s_tl = sum(m_tl["steps_per_window"])
    ratio = s_ex / max(s_tl, 1)
    # moment agreement at the final grid point, in z-units of the
    # two-sample standard error
    me, mt = ex.means()[-1], tl.means()[-1]
    se = np.sqrt(ex.records[-1].var / REPLICAS
                 + tl.records[-1].var / REPLICAS)
    z = np.abs(mt - me) / se
    out = {
        "exact": m_ex,
        "tau_leap": m_tl,
        "steps_ratio_exact_over_tau": round(ratio, 2),
        # informational at smoke scale: the small oscillatory lv2 is
        # dispatch-bound here, so the step saving does not translate to
        # wall clock (the high-propensity birth-death section below is
        # where the wall-clock speedup is real and gated)
        "wall_speedup_tau_vs_exact": round(
            m_ex["wall_per_window_ms"] / m_tl["wall_per_window_ms"], 3),
        "moment_z_tau_vs_exact": [round(float(v), 3) for v in z],
    }
    print(f"fig4/lv2: steps {s_ex} (exact) vs {s_tl} (tau) = "
          f"{ratio:.1f}x fewer; moment z {z}; wall speedup "
          f"{out['wall_speedup_tau_vs_exact']}x")
    assert ratio >= 5.0, (
        f"tau-leap step reduction {ratio:.2f}x < 5x on the fig4 model")
    assert (z <= 3.0).all(), f"tau-vs-exact moment error beyond 3 sigma: {z}"
    assert sum(m_tl["leaps_per_window"]) > 0
    return out


def birth_death_section():
    model = make_system(
        ["A"], [({}, {"A": 1}, BD_LAM), ({"A": 1}, {}, BD_MU)], {"A": 0})
    out = {}
    for method in (Method.EXACT, Method.TAU_LEAP):
        res, m = _run(model, method, BD_T_END)
        errs = []
        for rec in res.records:
            an = BD_LAM / BD_MU * (1 - np.exp(-BD_MU * rec.t))
            z_mean = float((rec.mean[0] - an) / np.sqrt(an / REPLICAS))
            z_var = float((rec.var[0] - an)
                          / (an * np.sqrt(2.0 / (REPLICAS - 1))))
            errs.append({"t": round(rec.t, 4),
                         "analytic_mean": round(an, 3),
                         "mean_z": round(z_mean, 3),
                         "var_z": round(z_var, 3)})
        worst = max(max(abs(e["mean_z"]), abs(e["var_z"])) for e in errs)
        print(f"birth_death/{method.value}: worst |z| = {worst:.2f}")
        assert worst <= 3.0, (
            f"{method.value} moment error beyond 3 sigma of the "
            f"analytic value: {errs}")
        out[method.value] = {**m, "moment_errors": errs}
    # the tau-leap WALL-CLOCK speedup (BENCH_PR4 recorded only the
    # step-count ratio): on this high-propensity model the Poisson
    # bundling pays for its per-iteration cost — ~2.7x at smoke scale.
    # Gate at >= 1.2 (tolerance for CI wall noise; the observed margin
    # is > 2x)
    speedup = (out["exact"]["wall_per_window_ms"]
               / out["tau_leap"]["wall_per_window_ms"])
    out["wall_speedup_tau_vs_exact"] = round(speedup, 3)
    print(f"birth_death: tau-leap wall-clock speedup {speedup:.2f}x")
    assert speedup >= 1.2, (
        f"tau-leap wall-clock speedup {speedup:.2f}x < 1.2x on the "
        "birth-death model (expected ~2.7x)")
    return out


def main() -> None:
    out_path = sys.argv[1] if len(sys.argv) > 1 else os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_PR4.json")
    fig4 = fig4_section()
    bd = birth_death_section()
    doc = {
        "pr": 4,
        "generated_by": "benchmarks/stat_smoke.py",
        "config": {
            "replicas": REPLICAS, "lanes": N_LANES,
            "windows": N_WINDOWS, "fig4_t_end": FIG4_T_END,
            "tau_eps": TAU_EPS,
            "birth_death": {"lam": BD_LAM, "mu": BD_MU,
                            "t_end": BD_T_END},
        },
        "fig4_lv2": fig4,
        "birth_death": bd,
        "invariants": {
            "tau_leap_steps_ratio_ge_5x": True,
            "moment_errors_within_3_sigma": True,
            "tau_leap_wall_speedup_birth_death_ge_1p2x": True,
            "tau_leap_records_bitwise_across_paths":
                "asserted in tests/test_tau_leap.py + tests/test_sharded.py",
        },
    }
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {out_path}")


if __name__ == "__main__":
    main()
