"""Window-dispatch paths head to head: the per-group host gather/
scatter loop, the single jitted `window_step` (device-side permutation
+ lax.scan over lane slices), and the Pallas fused kernel (device-side
chunk while_loop, in-VREG counter-based RNG).

Measures, for identical experiments:
  * device dispatches (jit launches) per run — the host<->device round
    trips the refactors remove (kernel: ONE per window, no per-chunk
    uniform-stream upload or continuation pull);
  * blocking device->host pulls;
  * wall time per window (post-warmup);
and asserts all paths produce bit-identical records (counter-based
per-lane RNG — kernel parity is bitwise for any chunk size, not just
the first window).

  PYTHONPATH=src python benchmarks/window_step_path.py
"""
from __future__ import annotations

import time

from repro.api import Ensemble, Experiment, Schedule, simulate
from repro.core.cwc.models import lotka_volterra

PATHS = ("host_loop", "window_step", "kernel")


def run_path(path: str, n_instances: int, n_lanes: int,
             n_windows: int = 8, window_block: int = 1):
    exp = Experiment(
        model=lotka_volterra(2),
        ensemble=Ensemble.make(replicas=n_instances),
        schedule=Schedule(t_end=2.0, n_windows=n_windows, schema="iii"),
        n_lanes=n_lanes,
        seed=7,
        host_loop=(path == "host_loop"),
        use_kernel=(path == "kernel"),
        window_block=window_block)
    # steady-state wall: warm up one block (jit compile + first
    # dispatch), then time the remaining windows END TO END — dispatch,
    # device compute, AND every blocking pull. The engine's per-window
    # wall shares deliberately exclude the pull (they are an
    # async-dispatch measure), so they cannot compare a per-window run
    # against a superstep run whose collect hides the pull behind the
    # next block's compute; this end-to-end measure can.
    warmup = max(window_block, 1)
    assert n_windows > warmup, (
        f"n_windows ({n_windows}) must exceed window_block "
        f"({window_block}): warmup consumes one full block and the "
        "steady measure needs at least one window after it")
    result = simulate(exp, max_windows=warmup)
    t0 = time.perf_counter()
    result.resume()
    steady_wall = time.perf_counter() - t0
    tele = result.telemetry
    return result, dict(
        dispatches=tele.dispatches,
        host_syncs=tele.host_syncs,
        dispatches_per_window=tele.dispatches / n_windows,
        host_syncs_per_window=tele.host_syncs / n_windows,
        wall_total_s=tele.wall_time_s,
        wall_per_window_ms=1e3 * steady_wall / (n_windows - warmup))


def main() -> None:
    print("instances,lanes,path,dispatches,host_syncs,"
          "wall_per_window_ms,wall_total_s")
    for n_instances, n_lanes in ((256, 32), (512, 64), (1024, 128)):
        rows = {}
        for path in PATHS:
            result, m = run_path(path, n_instances, n_lanes)
            rows[path] = (result, m)
            print(f"{n_instances},{n_lanes},{path},{m['dispatches']},"
                  f"{m['host_syncs']},{m['wall_per_window_ms']:.2f},"
                  f"{m['wall_total_s']:.2f}")
        base = rows["window_step"][0]
        for path in ("host_loop", "kernel"):
            assert (rows[path][0].means() == base.means()).all(), (
                f"{path} diverged from window_step!")
        d_old = rows["host_loop"][1]["dispatches"]
        d_new = rows["window_step"][1]["dispatches"]
        d_k = rows["kernel"][1]["dispatches"]
        w_old = rows["host_loop"][1]["wall_per_window_ms"]
        w_new = rows["window_step"][1]["wall_per_window_ms"]
        print(f"#  all paths bit-identical; dispatches {d_old} -> "
              f"{d_new} (window_step, {d_old / d_new:.0f}x fewer) / "
              f"{d_k} (kernel, one per window); steady window "
              f"{w_old:.2f}ms -> {w_new:.2f}ms "
              f"({w_old / max(w_new, 1e-9):.2f}x)")


if __name__ == "__main__":
    main()
