"""Old-vs-new window dispatch: per-group host gather/scatter loop vs
the single jitted, donated `window_step` (device-side permutation +
lax.scan over lane slices).

Measures, for identical experiments:
  * device dispatches (jit launches) per run — the host<->device round
    trips the refactor removes;
  * blocking device->host pulls;
  * wall time per window (post-warmup);
and asserts the two paths produce bit-identical records.

  PYTHONPATH=src python benchmarks/window_step_path.py
"""
from __future__ import annotations

import numpy as np

from repro.api import Ensemble, Experiment, Schedule, simulate
from repro.core.cwc.models import lotka_volterra


def run_path(host_loop: bool, n_instances: int, n_lanes: int,
             n_windows: int = 8):
    exp = Experiment(
        model=lotka_volterra(2),
        ensemble=Ensemble.make(replicas=n_instances),
        schedule=Schedule(t_end=2.0, n_windows=n_windows, schema="iii"),
        n_lanes=n_lanes,
        seed=7,
        host_loop=host_loop)
    result = simulate(exp)
    tele = result.telemetry
    # first window includes jit compile — report steady-state median
    steady = sorted(tele.window_wall_times[1:])
    return result, dict(
        dispatches=tele.dispatches,
        host_syncs=tele.host_syncs,
        wall_total_s=tele.wall_time_s,
        wall_per_window_ms=1e3 * steady[len(steady) // 2])


def main() -> None:
    print("instances,lanes,path,dispatches,host_syncs,"
          "wall_per_window_ms,wall_total_s")
    for n_instances, n_lanes in ((256, 32), (512, 64), (1024, 128)):
        rows = {}
        for host_loop in (True, False):
            result, m = run_path(host_loop, n_instances, n_lanes)
            rows[host_loop] = (result, m)
            path = "host_loop" if host_loop else "window_step"
            print(f"{n_instances},{n_lanes},{path},{m['dispatches']},"
                  f"{m['host_syncs']},{m['wall_per_window_ms']:.2f},"
                  f"{m['wall_total_s']:.2f}")
        old, new = rows[True][0], rows[False][0]
        assert (old.means() == new.means()).all(), "paths diverged!"
        d_old = rows[True][1]["dispatches"]
        d_new = rows[False][1]["dispatches"]
        w_old = rows[True][1]["wall_per_window_ms"]
        w_new = rows[False][1]["wall_per_window_ms"]
        print(f"#  bit-identical; dispatches {d_old} -> {d_new} "
              f"({d_old / d_new:.0f}x fewer), steady window "
              f"{w_old:.2f}ms -> {w_new:.2f}ms "
              f"({w_old / max(w_new, 1e-9):.2f}x)")


if __name__ == "__main__":
    main()
