"""Pipeline-depth probe + sweep (PR9: the depth-K superstep collector).

Two measurements on one collect-heavy workload (trajectory recording +
a cadenced checkpoint after every collected block — the host work the
pipeline exists to hide):

* PROBE — a `pipeline_depth="auto"` run times the first block's
  dispatch-wall (enqueue only) against its collect-wall (blocking ring
  pull + host reduce/emit/save) and resolves the depth the engine will
  use: `1 + ceil(collect / dispatch)`, clamped to [2, 8]. The ratio is
  the quantity that decides whether depth > 1 can pay at all: depth K
  hides up to (K-1) block-collects behind device compute, so with
  collect/dispatch <= K-1 the collect cost vanishes from the critical
  path.
* SWEEP + GATE — end-to-end walls (min of 3, compile included equally
  in every row) at depth 1, 2, and the probe's chosen depth. The gate
  is intentionally one-sided: the CHOSEN depth must not LOSE to the
  depth-1 collector (wall[chosen] <= wall[1] * 1.05; the 5% absorbs
  runner wall noise, same slack precedent as the tau-leap gate). On
  hosts where collect work is small relative to device compute the win
  is small — the gate proves depth-K is safe to leave on, the probe
  ratio documents the headroom.

Structural asserts ride the sweep: every depth's records AND
trajectories are bitwise the depth-1 run's, every cadence save was
served from a ring snapshot (zero pipeline flushes), and the telemetry
reports the resolved depth and a peak in-flight count that actually
reached it.

  PYTHONPATH=src python benchmarks/profile_pipeline.py
"""
from __future__ import annotations

import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))
sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.api import Ensemble, Experiment, Schedule, simulate  # noqa: E402
from repro.core.cwc.models import lotka_volterra  # noqa: E402

REPLICAS, N_LANES, N_WINDOWS = 128, 16, 12
WINDOW_BLOCK = 2  # 6 blocks: enough collects for depth 4 to matter
N_REPS = 3
GATE_TOL = 1.05  # runner wall noise allowance (tau-gate precedent)


def make_exp(depth):
    return Experiment(
        model=lotka_volterra(2),
        ensemble=Ensemble.make(replicas=REPLICAS),
        schedule=Schedule(t_end=1.0, n_windows=N_WINDOWS, schema="iii"),
        record_trajectories=True,
        n_lanes=N_LANES, seed=7, window_block=WINDOW_BLOCK,
        pipeline_depth=depth)


def _run(depth, ckpt_path):
    t0 = time.perf_counter()
    res = simulate(make_exp(depth), checkpoint_path=ckpt_path)
    return res, time.perf_counter() - t0


def pipeline_section() -> dict:
    tmp = tempfile.mkdtemp(prefix="bench_pr9_")
    # ---- probe: what does the first block's dispatch/collect split say?
    probe_res = simulate(make_exp("auto"))
    probe = dict(probe_res._engine.depth_probe)
    chosen = probe["depth"]
    print(f"profile_pipeline/probe: dispatch {probe['dispatch_s']*1e3:.2f}ms"
          f" collect {(probe['pull_s'] + probe['host_s'])*1e3:.2f}ms"
          f" ratio {probe['collect_dispatch_ratio']:.2f}"
          f" -> auto depth {chosen}")

    # ---- sweep: min-of-N end-to-end walls per depth, bitwise-checked
    depths = sorted({1, 2, chosen})
    walls, rows, results = {}, {}, {}
    for d in depths:
        best = float("inf")
        for rep in range(N_REPS):
            ck = os.path.join(tmp, f"ck_d{d}_r{rep}")
            res, wall = _run(d, ck)
            best = min(best, wall)
            results[d] = res
        t = results[d].telemetry
        n_blocks = N_WINDOWS // WINDOW_BLOCK
        assert t.pipeline_depth == d, (d, t.pipeline_depth)
        assert t.ckpt_flushes == 0, (
            f"depth {d}: {t.ckpt_flushes} cadence saves flushed the "
            "pipeline — snapshot serving regressed")
        assert t.snapshot_saves > 0, f"depth {d}: no snapshot saves"
        assert t.peak_inflight_blocks >= min(d, n_blocks), (d, t)
        assert t.peak_inflight_blocks <= min(d + 1, n_blocks), (d, t)
        walls[d] = best
        rows[f"depth={d}"] = {
            "wall_s_min_of_3": round(best, 4),
            "snapshot_saves": t.snapshot_saves,
            "ckpt_flushes": t.ckpt_flushes,
            "peak_inflight_blocks": t.peak_inflight_blocks,
        }
        print(f"profile_pipeline/depth={d}: {rows[f'depth={d}']}")

    base = results[1]
    for d in depths[1:]:
        got = results[d]
        assert (base.means() == got.means()).all(), (
            f"depth {d} records diverged from depth 1")
        assert (base.trajectories() == got.trajectories()).all(), (
            f"depth {d} trajectories diverged from depth 1")

    # ---- the gate: the auto-chosen depth must not lose to depth 1
    ratio = walls[chosen] / walls[1]
    print(f"#  pipeline wall depth1 {walls[1]*1e3:.1f}ms -> "
          f"depth{chosen} {walls[chosen]*1e3:.1f}ms "
          f"({walls[1] / max(walls[chosen], 1e-9):.2f}x)")
    assert walls[chosen] <= walls[1] * GATE_TOL, (
        f"auto-chosen depth {chosen} wall {walls[chosen]:.3f}s exceeds "
        f"depth-1 wall {walls[1]:.3f}s x {GATE_TOL} — deeper pipelining "
        "must never cost wall time on a collect-heavy workload")
    return {
        "probe": {k: (round(v, 6) if isinstance(v, float) else v)
                  for k, v in probe.items()},
        "sweep": rows,
        "chosen_depth": chosen,
        "chosen_over_depth1_wall_ratio": round(ratio, 4),
        "gate_tolerance": GATE_TOL,
    }


def main() -> None:
    section = pipeline_section()
    import json

    print(json.dumps(section, indent=2, sort_keys=True))


if __name__ == "__main__":
    main()
