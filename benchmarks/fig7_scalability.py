"""Paper Fig. 7 reproduction: speedup & scalability of the farm schemas.

The paper scales worker threads on an 8-core Nehalem; the TPU analogue
scales SIMD lanes (and shards — exercised in the dry-run). We measure:

* throughput (simulated events/s) of schema iii vs lane count — the
  "scalability" curve (parallel vs 1-lane parallel);
* schema i vs ii/iii on a HETEROGENEOUS ensemble (parameter sweep with
  10x rate spread): the paper's load-imbalance argument — static
  partitioning leaves lanes idle, time-slicing + predictive grouping
  recovers them;
* reduction included in the parallel timing, as the paper does
  ("the measures for the parallel version include the time spent for
  computing reductions").
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core.cwc.compile import compile_model
from repro.core.cwc.models import lotka_volterra
from repro.core.engine import SimConfig, SimulationEngine
from repro.core.sweep import SweepSpec, sweep_rates

T_END = 1.0
WINDOWS = 10


def _throughput(eng) -> float:
    t0 = time.perf_counter()
    eng.run()
    wall = time.perf_counter() - t0
    events = float(np.asarray(eng._pool.steps).sum())
    return events / wall


def scalability():
    base = None
    for lanes in (1, 4, 16, 64, 256):
        cfg = SimConfig(n_instances=lanes, t_end=T_END, n_windows=WINDOWS,
                        n_lanes=lanes, schema="iii", seed=0)
        eng = SimulationEngine(lotka_volterra(2), cfg)
        thr = _throughput(eng)
        if base is None:
            base = thr
        emit(f"fig7/scalability/lanes{lanes}", 1e6 / thr,
             f"events_per_s={thr:,.0f} speedup={thr/base:.1f} ideal={lanes}")


def load_balance():
    model = lotka_volterra(2)
    system, _ = compile_model(model)
    # heterogeneous ensemble: 4 sweep points spanning 10x event rates
    spec = SweepSpec.make({"reproduce": [0.3, 1.0, 2.0, 3.0]}, replicas=16)
    rates = sweep_rates(system, spec)
    for schema, policy in (("i", "static_rr"), ("iii", "on_demand"),
                           ("iii", "predictive")):
        cfg = SimConfig(n_instances=64, t_end=T_END, n_windows=WINDOWS,
                        n_lanes=16, schema=schema, policy=policy, seed=0)
        eng = SimulationEngine(model, cfg, rates=rates)
        thr = _throughput(eng)
        emit(f"fig7/imbalanced/schema_{schema}_{policy}", 1e6 / thr,
             f"events_per_s={thr:,.0f} "
             f"peak_buffered_B={eng.peak_buffered_bytes}")


def main() -> None:
    scalability()
    load_balance()


if __name__ == "__main__":
    main()
