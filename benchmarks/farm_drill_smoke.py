"""Multi-process farm benchmark gate -> BENCH_PR10.json (§3i).

Two gated sections, CI-sized and deterministic in OUTCOME (the fault's
landing point is timing-dependent; the merged result is not):

* `farm_drill` — a 3-worker farm with one scheduled `host_lost`
  (SIGKILL of whichever worker's heartbeat frontier first crosses the
  scheduled window). GATE: the merged records and final state are
  BITWISE identical to the uninterrupted single-process run with the
  same pinned statistics partition, with exactly one restart and
  exactly one host_lost fault in the recovery report.
* `farm_overhead` — the same config fault-free: 3-worker farm wall vs
  the SUPERVISED single-process wall (Recovery(workers=1), same
  checkpoint cadence — both sides pay the same durability tax).
  Worker STARTUP (interpreter + jax import + first-window jit +
  bundle I/O, measured per worker as process lifetime minus its
  engine-only wall) is the part a farm necessarily duplicates per
  process; on a box with >= `workers` cores it overlaps shard compute,
  on the 1-2 core CI runner it serializes in front of it. The gate is
  therefore core-aware and always pins ORCHESTRATION (coordinator
  polling, heartbeats, launch staggering, the bitwise merge) to
  <= 1.10x:
    - cores >= workers ("multicore"): farm_wall <= 1.10 x single_wall
    - otherwise ("serialized"):
      farm_wall - startup_total <= 1.10 x single_wall

  PYTHONPATH=src python benchmarks/farm_drill_smoke.py [out.json]
"""
from __future__ import annotations

import json
import os
import shutil
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

import numpy as np  # noqa: E402

from repro.api import (  # noqa: E402
    Ensemble,
    Experiment,
    FailurePlan,
    Recovery,
    Reduction,
    Schedule,
    simulate,
)
from repro.api.spec import Partitioning  # noqa: E402
from repro.core.cwc.models import cell_ring_model  # noqa: E402

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
N_INSTANCES, N_LANES, N_WINDOWS = 27648, 16, 12
WINDOW_BLOCK, CADENCE, WORKERS = 4, 4, 3
HEARTBEAT_S = 5.0
KILL_WINDOW = 4
OVERHEAD_GATE = 1.10


def make_exp(**kw):
    return Experiment(
        model=cell_ring_model(8),
        ensemble=Ensemble.make(replicas=N_INSTANCES),
        schedule=Schedule(t_end=6.0, n_windows=N_WINDOWS, schema="iii"),
        reduction=Reduction.ENSEMBLE,
        n_lanes=N_LANES, seed=7, window_block=WINDOW_BLOCK, **kw)


def run_single() -> tuple:
    """Supervised single-process baseline: same pinned stats partition
    and the same checkpoint cadence as each farm worker."""
    tmp = tempfile.mkdtemp(prefix="farm_single_")
    exp = make_exp(
        partitioning=Partitioning(n_shards=1, stat_blocks=WORKERS),
        recovery=Recovery(ckpt_dir=os.path.join(tmp, "rec"),
                          cadence=CADENCE, keep_last=2))
    try:
        t0 = time.perf_counter()
        res = simulate(exp)
        wall = time.perf_counter() - t0
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    return res, wall


def run_farm(schedule=None) -> tuple:
    tmp = tempfile.mkdtemp(prefix="farm_bench_")
    inject = (FailurePlan(schedule=schedule)
              if schedule is not None else None)
    exp = make_exp(recovery=Recovery(
        ckpt_dir=os.path.join(tmp, "farm"), cadence=CADENCE,
        keep_last=2, workers=WORKERS, heartbeat_s=HEARTBEAT_S,
        backoff_base_s=0.0, inject=inject))
    try:
        t0 = time.perf_counter()
        res = simulate(exp)
        wall = time.perf_counter() - t0
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    return res, wall


def assert_bitwise(base, got) -> None:
    assert len(base.records) == len(got.records)
    for ra, rb in zip(base.records, got.records):
        assert ra.t == rb.t and ra.n == rb.n
        assert (ra.mean == rb.mean).all() and (ra.var == rb.var).all()
        assert (ra.ci90 == rb.ci90).all()
    assert (base.final_state() == got.final_state()).all()


def drill_section(base) -> dict:
    got, wall = run_farm(schedule={KILL_WINDOW: "host_lost"})
    assert_bitwise(base, got)
    rep = got.recovery_report()
    assert rep["restarts"] == 1, rep["events"]
    assert rep["faults_by_kind"] == {"host_lost": 1}, rep["events"]
    killed = [e for e in rep["events"] if e["event"] == "fault"]
    row = {
        "schedule": {str(KILL_WINDOW): "host_lost"},
        "restarts": rep["restarts"],
        "faults_by_kind": rep["faults_by_kind"],
        "killed_worker": killed[0]["worker"],
        "records_bitwise": True,
        "final_state_bitwise": True,
        "wall_s": round(wall, 2),
    }
    print(f"farm_drill: {row}")
    return row


def overhead_section(base_wall: float) -> dict:
    got, farm_wall = run_farm()
    rep = got.recovery_report()
    assert rep["restarts"] == 0, rep["events"]
    # per-worker startup: process lifetime (launch -> done, from the
    # coordinator's timestamped event log) minus the engine-only wall
    launch = {e["worker"]: e["t"] for e in rep["events"]
              if e["event"] == "worker_launched"}
    done = {e["worker"]: e["t"] for e in rep["events"]
            if e["event"] == "worker_done"}
    startups = {
        w: max(0.0, (done[w] - launch[w]) - rep["worker_walls"][w])
        for w in done}
    startup_total = sum(startups.values())
    cores = os.cpu_count() or 1
    if cores >= WORKERS:
        mode, adjusted = "multicore", farm_wall
    else:
        mode, adjusted = "serialized", farm_wall - startup_total
    ratio = adjusted / base_wall
    row = {
        "mode": mode,
        "cores": cores,
        "single_wall_s": round(base_wall, 2),
        "farm_wall_s": round(farm_wall, 2),
        "worker_startup_s": {w: round(s, 2)
                             for w, s in sorted(startups.items())},
        "startup_total_s": round(startup_total, 2),
        "orchestration_ratio": round(ratio, 4),
        "gate": OVERHEAD_GATE,
    }
    print(f"farm_overhead: {row}")
    assert ratio <= OVERHEAD_GATE, (
        f"farm orchestration overhead {ratio:.3f}x exceeds the "
        f"{OVERHEAD_GATE}x gate ({mode} mode)")
    return row


def main() -> None:
    out_path = sys.argv[1] if len(sys.argv) > 1 else os.path.join(
        REPO, "BENCH_PR10.json")
    base, base_wall = run_single()
    report = {
        "bench": "farm_drill_smoke",
        "config": {
            "n_instances": N_INSTANCES, "n_lanes": N_LANES,
            "n_windows": N_WINDOWS, "window_block": WINDOW_BLOCK,
            "cadence": CADENCE, "workers": WORKERS,
            "heartbeat_s": HEARTBEAT_S,
        },
        "farm_drill": drill_section(base),
        "farm_overhead": overhead_section(base_wall),
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(f"wrote {out_path}")


if __name__ == "__main__":
    main()
