"""Small-scale benchmark smoke run -> BENCH_PR9.json (the perf
trajectory's superstep + steering + pipeline-depth point).

Five sections, all CI-sized and deterministic:

* `window_step_path` — host_loop vs window_step vs Pallas kernel, now
  each non-baseline path also at `window_block=4` (supersteps: 4
  windows per dispatch, record ring pulled per block by the async
  collector). Asserts the bitwise-parity invariants, the dispatch/sync
  amortisation (<= 0.25 per window at window_block=4), and the
  WALL-CLOCK GATE: the fused superstep's steady per-window wall must
  beat the per-window (window_block=1) fused baseline run in the same
  process — the same code path BENCH_PR3 profiled at this config.
  Tolerance: none (ratio <= 1.0); the win is structural (3 of every 4
  host round-trips removed), ~1.4x speedup observed (superstep/
  baseline wall ratio ~0.7), so a flake here is a real regression.
  Both gated rows are measured min-of-3 (GATE_REPS): the steady
  region is only ~8 windows, and a single-shot wall under runner load
  swings enough to trip the gate on noise alone.
* `sharded_farm` — 1/2-shard subprocesses x kernel x window_block,
  asserting ONE records digest across every combination AND that it
  equals the digest BENCH_PR3.json recorded for this exact config —
  supersteps (and everything since PR3) leave records bit-identical.
* `tau_wall_clock` — the birth-death wall-clock speedup of tau-leaping
  over exact SSA (stat_smoke's gated section; BENCH_PR4 recorded only
  the step-count ratio).
* `pipeline_depth` — the PR9 depth-K collector sweep
  (profile_pipeline): a dispatch-vs-collect probe resolves the "auto"
  depth, then end-to-end walls (min of 3) at depth 1 / 2 / chosen on a
  collect-heavy workload (trajectories + a checkpoint per collected
  block). GATES: every depth bitwise the depth-1 run; every cadence
  save served from a ring snapshot (zero pipeline flushes); the chosen
  depth's wall <= 1.05x the depth-1 wall.
* `early_stop` — the steering savings row (steering_smoke): on a
  mixed-variance immigration-death sweep, convergence early-stopping
  must simulate >= 1.2x fewer point-windows than the unsteered run
  while every point's final mean stays within 3 sigma of the analytic
  value at its freeze time and the never-stopped point stays BITWISE
  the unsteered run's.

  PYTHONPATH=src python benchmarks/bench_smoke.py [out.json]
"""
from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))

from benchmarks import (  # noqa: E402
    profile_pipeline,
    sharded_farm,
    stat_smoke,
    steering_smoke,
    window_step_path,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
# 12 windows: warmup eats one block (4 windows at window_block=4, 1 at
# window_block=1) and the steady measure covers the rest end to end
N_INSTANCES, N_LANES, N_WINDOWS = 128, 16, 12
WINDOW_BLOCK = 4
SHARD_INSTANCES, SHARD_LANES, SHARD_WINDOWS = 64, 8, 4
SHARD_COUNTS = (1, 2)
# (path, window_block) rows; host_loop stays the per-window baseline
ROWS = (("host_loop", 1), ("window_step", 1), ("kernel", 1),
        ("window_step", WINDOW_BLOCK), ("kernel", WINDOW_BLOCK))
# the two rows the wall-clock gate compares get min-of-3 steady walls:
# the steady region is only ~8 windows of wall, so a single-shot
# measure under runner load can swing 2-3x and trip the gate on noise
# while the structural comparison (host round trips removed) is about
# best-case walls, which min-of-N recovers
GATE_ROWS = {("window_step", 1), ("window_step", WINDOW_BLOCK)}
GATE_REPS = 3


def window_section():
    paths, results = {}, {}
    for path, wb in ROWS:
        best = None
        for _ in range(GATE_REPS if (path, wb) in GATE_ROWS else 1):
            result, m = window_step_path.run_path(
                path, N_INSTANCES, N_LANES, n_windows=N_WINDOWS,
                window_block=wb)
            if best is None \
                    or m["wall_per_window_ms"] < best["wall_per_window_ms"]:
                best = m
        m = best
        key = path if wb == 1 else f"{path},window_block={wb}"
        results[key] = result
        paths[key] = {
            "dispatches_per_window": m["dispatches_per_window"],
            "host_syncs_per_window": m["host_syncs_per_window"],
            "wall_per_window_ms": round(m["wall_per_window_ms"], 3),
        }
        print(f"window_step_path/{key}: {paths[key]}")
    base = results["window_step"].means()
    for key, res in results.items():
        assert (res.means() == base).all(), (
            f"{key} diverged from window_step")
    assert paths["kernel"]["dispatches_per_window"] == 1.0, (
        "kernel path must be one dispatch per window")
    # per-window paths: the truncation flag rides the combined record
    # pull, so EVERY per-window path is exactly one blocking sync per
    # window (PR4's invariant)
    for key, row in paths.items():
        if "window_block" in key:
            # supersteps amortise BOTH to 1/window_block per window
            assert row["dispatches_per_window"] <= 1 / WINDOW_BLOCK, (
                f"{key}: {row['dispatches_per_window']} dispatches/"
                f"window (expected <= {1 / WINDOW_BLOCK})")
            assert row["host_syncs_per_window"] < 1.0, (
                f"{key}: {row['host_syncs_per_window']} host syncs/"
                "window (expected amortised < 1.0)")
        else:
            assert row["host_syncs_per_window"] == 1.0, (
                f"{key}: {row['host_syncs_per_window']} host syncs/"
                "window (expected exactly 1.0)")
    # the wall-clock gate (tolerance 1.0 — see module docstring)
    wb_key = f"window_step,window_block={WINDOW_BLOCK}"
    w_base = paths["window_step"]["wall_per_window_ms"]
    w_block = paths[wb_key]["wall_per_window_ms"]
    print(f"#  fused superstep wall {w_base:.2f}ms -> {w_block:.2f}ms "
          f"per window ({w_base / max(w_block, 1e-9):.2f}x)")
    assert w_block <= w_base, (
        f"superstep fused path ({w_block:.3f}ms/window at window_block="
        f"{WINDOW_BLOCK}) must beat the per-window fused baseline "
        f"({w_base:.3f}ms/window) — the PR3-era profile at this config")
    return paths


def farm_section():
    farm = {}
    digests = set()
    for kernel in (False, True):
        for k in SHARD_COUNTS:
            for wb in (1, WINDOW_BLOCK):
                row = sharded_farm.run_point(
                    k, SHARD_INSTANCES, SHARD_LANES, SHARD_WINDOWS,
                    kernel=kernel, window_block=wb)
                shards, disp, syncs, wall_ms, wall_s, sha = row.split(",")
                digests.add(sha)
                key = f"shards={k},kernel={int(kernel)},window_block={wb}"
                farm[key] = {
                    "dispatches_per_window": int(disp) / SHARD_WINDOWS,
                    "host_syncs_per_window": int(syncs) / SHARD_WINDOWS,
                    "wall_per_window_ms": float(wall_ms),
                    "records_sha": sha,
                }
                print(f"sharded_farm/{key}: {farm[key]}")
    assert len(digests) == 1, (
        f"records diverged across shards/window bodies/blocks: {farm}")
    # cross-PR anchor: BENCH_PR3.json recorded this config's digest
    # when the per-window path was the only one — equality proves the
    # superstep refactor changed no record bit
    pr3_path = os.path.join(REPO, "BENCH_PR3.json")
    if os.path.exists(pr3_path):
        with open(pr3_path) as f:
            pr3 = json.load(f)
        pr3_sha = pr3["sharded_farm"]["shards=1,kernel=0"]["records_sha"]
        assert digests == {pr3_sha}, (
            f"records digest {digests} != BENCH_PR3 baseline {pr3_sha} "
            "— the engine no longer reproduces the PR3-era records")
    for key, row in farm.items():
        expect = 1.0 if "window_block=1" in key else 1 / WINDOW_BLOCK
        assert row["host_syncs_per_window"] == expect, (
            f"sharded_farm/{key}: {row['host_syncs_per_window']} host "
            f"syncs/window (expected {expect})")
    return farm


def main() -> None:
    out_path = sys.argv[1] if len(sys.argv) > 1 else os.path.join(
        REPO, "BENCH_PR9.json")
    paths = window_section()
    farm = farm_section()
    pipeline = profile_pipeline.pipeline_section()
    early_stop = steering_smoke.early_stop_section()
    bd = stat_smoke.birth_death_section()
    tau_wall = {
        "exact_wall_per_window_ms": bd["exact"]["wall_per_window_ms"],
        "tau_leap_wall_per_window_ms":
            bd["tau_leap"]["wall_per_window_ms"],
        "wall_speedup_tau_vs_exact": bd["wall_speedup_tau_vs_exact"],
    }
    doc = {
        "pr": 9,
        "generated_by": "benchmarks/bench_smoke.py",
        "config": {
            "wall_measure": (
                "wall_per_window_ms is the post-warmup END-TO-END wall "
                "per window (dispatch + device compute + every blocking "
                "pull) — unlike BENCH_PR3's async-dispatch median, "
                "which excluded the pull and so could not price the "
                "per-window host round-trip the superstep removes"),
            "window_step_path": {
                "instances": N_INSTANCES, "lanes": N_LANES,
                "windows": N_WINDOWS, "window_block": WINDOW_BLOCK},
            "sharded_farm": {
                "instances": SHARD_INSTANCES, "lanes": SHARD_LANES,
                "windows": SHARD_WINDOWS,
                "stat_blocks": sharded_farm.STAT_BLOCKS,
                "wall_note": (
                    "window_block=4 rows run the whole 4-window grid "
                    "as ONE block, so their wall medians include jit "
                    "compile; this section's point is the records "
                    "digest (pinned to the BENCH_PR3 baseline) and "
                    "the dispatch/sync profile — the gated wall "
                    "comparison lives in window_step_path")},
            "pipeline_depth": {
                "instances": profile_pipeline.REPLICAS,
                "lanes": profile_pipeline.N_LANES,
                "windows": profile_pipeline.N_WINDOWS,
                "window_block": profile_pipeline.WINDOW_BLOCK,
                "gate_tolerance": profile_pipeline.GATE_TOL,
                "wall_note": (
                    "min-of-3 END-TO-END walls including engine build "
                    "and jit compile (identical per row); the probe's "
                    "first-block dispatch wall also includes compile, "
                    "so its collect/dispatch ratio UNDERSTATES the "
                    "steady-state collect share and the auto depth "
                    "resolves conservatively (clamped to >= 2)")},
            "tau_wall_clock": {
                "model": "birth_death", "replicas": stat_smoke.REPLICAS,
                "lanes": stat_smoke.N_LANES,
                "windows": stat_smoke.N_WINDOWS,
                "t_end": stat_smoke.BD_T_END},
            "early_stop": {
                "model": "immigration_death",
                "sweep_birth": list(steering_smoke.BD_LAMS),
                "replicas": steering_smoke.REPLICAS,
                "lanes": steering_smoke.N_LANES,
                "windows": steering_smoke.N_WINDOWS,
                "t_end": steering_smoke.T_END,
                "window_block": steering_smoke.WINDOW_BLOCK,
                "ci_rel_tol": steering_smoke.CI_REL_TOL,
                "min_windows": steering_smoke.MIN_WINDOWS},
        },
        "window_step_path": paths,
        "sharded_farm": farm,
        "pipeline_depth": pipeline,
        "tau_wall_clock": tau_wall,
        "early_stop": early_stop,
        "invariants": {
            "all_paths_bitwise_identical": True,
            "records_match_bench_pr3_digest": True,
            "superstep_dispatches_per_window_le_0p25": True,
            "superstep_host_syncs_per_window_lt_1": True,
            "superstep_wall_beats_per_window_baseline": True,
            "depth_k_records_and_trajectories_bitwise": True,
            "cadence_saves_zero_pipeline_flushes": True,
            "chosen_depth_wall_le_depth1_x1p05": True,
            "tau_leap_wall_speedup_birth_death_ge_1p2x": True,
            "early_stop_point_windows_saved_ge_1p2x": True,
            "early_stop_final_means_within_3_sigma": True,
            "early_stop_live_points_bitwise_vs_unsteered": True,
        },
    }
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {out_path}")


if __name__ == "__main__":
    main()
