"""Small-scale benchmark smoke run -> BENCH_PR3.json (the perf
trajectory's first recorded point).

Runs `window_step_path` (host_loop vs window_step vs Pallas kernel,
one in-process experiment each) and `sharded_farm` (1/2-shard
subprocesses, kernel on and off) at CI-friendly sizes, asserts the
bitwise-parity invariants those benchmarks encode, and writes the
dispatch/sync/wall profile per window to BENCH_PR3.json.

  PYTHONPATH=src python benchmarks/bench_smoke.py [out.json]

Headline numbers recorded: the kernel path runs a full window in ONE
device dispatch with no mid-window host syncs (no uniform-stream
upload, no per-chunk continuation pull), and composes with the sharded
farm bit-identically.
"""
from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))

from benchmarks import sharded_farm, window_step_path  # noqa: E402

N_INSTANCES, N_LANES, N_WINDOWS = 128, 16, 4
SHARD_INSTANCES, SHARD_LANES = 64, 8
SHARD_COUNTS = (1, 2)


def main() -> None:
    out_path = sys.argv[1] if len(sys.argv) > 1 else os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_PR3.json")
    paths = {}
    results = {}
    for path in window_step_path.PATHS:
        result, m = window_step_path.run_path(
            path, N_INSTANCES, N_LANES, n_windows=N_WINDOWS)
        results[path] = result
        paths[path] = {
            "dispatches_per_window": m["dispatches_per_window"],
            "host_syncs_per_window": m["host_syncs_per_window"],
            "wall_per_window_ms": round(m["wall_per_window_ms"], 3),
        }
        print(f"window_step_path/{path}: {paths[path]}")
    for p in ("host_loop", "kernel"):
        assert (results[p].means()
                == results["window_step"].means()).all(), (
            f"{p} diverged from window_step")
    assert paths["kernel"]["dispatches_per_window"] == 1.0, (
        "kernel path must be one dispatch per window")
    # the truncation flag rides the per-window record pull: EVERY path
    # is exactly one blocking host sync per window (the kernel path
    # used to pay a second one — BENCH_PR3 recorded 2.0 here)
    for p, row in paths.items():
        assert row["host_syncs_per_window"] == 1.0, (
            f"{p}: {row['host_syncs_per_window']} host syncs/window "
            "(expected exactly 1.0 — the combined record pull)")

    farm = {}
    digests = set()
    for kernel in (False, True):
        for k in SHARD_COUNTS:
            row = sharded_farm.run_point(
                k, SHARD_INSTANCES, SHARD_LANES, N_WINDOWS, kernel=kernel)
            shards, disp, syncs, wall_ms, wall_s, sha = row.split(",")
            digests.add(sha)
            farm[f"shards={k},kernel={int(kernel)}"] = {
                "dispatches_per_window": int(disp) / N_WINDOWS,
                "host_syncs_per_window": int(syncs) / N_WINDOWS,
                "wall_per_window_ms": float(wall_ms),
                "records_sha": sha,
            }
            print(f"sharded_farm/shards={k},kernel={int(kernel)}: "
                  f"{farm[f'shards={k},kernel={int(kernel)}']}")
    assert len(digests) == 1, (
        f"records diverged across shards/window bodies: {farm}")
    for key, row in farm.items():
        assert row["host_syncs_per_window"] == 1.0, (
            f"sharded_farm/{key}: {row['host_syncs_per_window']} host "
            "syncs/window (expected exactly 1.0)")

    doc = {
        "pr": 3,
        "generated_by": "benchmarks/bench_smoke.py",
        "config": {
            "window_step_path": {
                "instances": N_INSTANCES, "lanes": N_LANES,
                "windows": N_WINDOWS},
            "sharded_farm": {
                "instances": SHARD_INSTANCES, "lanes": SHARD_LANES,
                "windows": N_WINDOWS,
                "stat_blocks": sharded_farm.STAT_BLOCKS},
        },
        "window_step_path": paths,
        "sharded_farm": farm,
        "invariants": {
            "all_paths_bitwise_identical": True,
            "kernel_single_dispatch_per_window": True,
            "kernel_uniform_stream_operand": False,
            "host_syncs_per_window_all_paths": 1.0,
        },
    }
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {out_path}")


if __name__ == "__main__":
    main()
