"""End-to-end LM training driver with checkpoint/restart.

Trains a ~20M-param llama-family model for a few hundred steps on CPU
(the same `train_loop` drives pods — only the mesh differs), crash-safe:
re-running the script resumes from the last checkpoint.

  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import dataclasses

from repro.configs.llama3_8b import smoke
from repro.launch.train import train_loop

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
args = ap.parse_args()

# a ~20M "llama3 family" model: same block structure, scaled dims
cfg = dataclasses.replace(
    smoke(), name="llama3-20m", n_layers=4, d_model=256, n_heads=8,
    n_kv_heads=4, d_ff=1024, vocab_size=4096)

import repro.configs.registry as registry

registry._MODULES["llama3-20m"] = type(
    "M", (), {"CONFIG": cfg, "smoke": staticmethod(lambda: cfg)})

state, losses = train_loop(
    "llama3-20m", smoke=True, steps=args.steps, batch=8, seq=128,
    ckpt_dir=args.ckpt_dir, ckpt_every=50, resume=True, lr=3e-4)

print(f"\nfinal loss {losses[-1]:.4f} (started {losses[0]:.4f}); "
      f"checkpoints in {args.ckpt_dir}")
