"""Paper Fig. 1: gene regulation in E. coli — 100 instances, mean +
90% CI at fixed simulation time steps, computed with the on-line
pipelined reduction (schema iii).

  PYTHONPATH=src python examples/ecoli_gene_regulation.py
Writes artifacts/ecoli_fig1.csv (t, mean, var, ci90 per observable).
"""
import os

from repro.api import CsvSink, Ensemble, Experiment, Schedule, simulate
from repro.api.run import observable_names
from repro.core.cwc.models import ecoli_gene_regulation

OUT = "artifacts/ecoli_fig1.csv"
os.makedirs("artifacts", exist_ok=True)

model = ecoli_gene_regulation()
result = simulate(Experiment(
    model=model,
    ensemble=Ensemble.make(replicas=100),
    schedule=Schedule(t_end=100.0, n_windows=100, schema="iii"),
    sinks=(CsvSink(OUT, observable_names(model)),),  # closed by simulate()
    n_lanes=100,
    seed=0,
))

# a terminal sparkline of the protein trajectory with its CI band
records = result.records
prot = result.obs_names.index("ecoli/protein")
peak = max(r.mean[prot] for r in records) or 1.0
print("t      protein (mean ± ci90)")
for r in records[::5]:
    bar = "#" * int(40 * r.mean[prot] / peak)
    print(f"{r.t:6.1f} {r.mean[prot]:8.1f} ±{r.ci90[prot]:6.2f}  {bar}")
print(f"\nfull statistics streamed to {OUT}")
