"""Parameter sweep (paper §3.1.2: "replicas or parameter sweeping"):
Lotka-Volterra predator death-rate sweep, 4 points × 32 replicas,
scheduled as ONE self-balancing farm with per-point on-line reduction.

  PYTHONPATH=src python examples/lotka_volterra_sweep.py
"""
import numpy as np

from repro.core.cwc.compile import compile_model
from repro.core.cwc.models import lotka_volterra
from repro.core.engine import SimConfig, SimulationEngine
from repro.core.sweep import SweepSpec, point_slices, sweep_rates

model = lotka_volterra(2)
system, _ = compile_model(model)

spec = SweepSpec.make({"die": [0.3, 0.6, 1.2, 2.4]}, replicas=32)
rates = sweep_rates(system, spec)

engine = SimulationEngine(
    model,
    SimConfig(n_instances=spec.n_instances(), t_end=5.0, n_windows=10,
              n_lanes=64, schema="iii", policy="predictive", seed=0),
    rates=rates,
)
engine.run()

x = np.asarray(engine._pool.x)  # (I, S) final states
print("predator death rate | final prey (mean) | final predators (mean)")
for pt, sl in zip(spec.points(), point_slices(spec)):
    prey, pred = x[sl, 0].mean(), x[sl, 1].mean()
    print(f"  k_die = {pt['die']:4.1f}       | {prey:12.1f}      | "
          f"{pred:12.1f}")
print(f"\nscheduler imbalance (cv of per-instance cost): "
      f"{engine.scheduler.imbalance():.2f}")
