"""Parameter sweep (paper §3.1.2: "replicas or parameter sweeping"):
Lotka-Volterra predator death-rate sweep, 4 points × 32 replicas,
declared as ONE experiment with per-point on-line reduction.

  PYTHONPATH=src python examples/lotka_volterra_sweep.py
"""
from repro.api import (
    Ensemble,
    Experiment,
    Policy,
    Reduction,
    Schedule,
    Schema,
    simulate,
)
from repro.core.cwc.models import lotka_volterra

result = simulate(Experiment(
    model=lotka_volterra(2),
    ensemble=Ensemble.make(replicas=32,
                           sweep={"die": [0.3, 0.6, 1.2, 2.4]}),
    schedule=Schedule(t_end=5.0, n_windows=10, schema=Schema.ONLINE,
                      policy=Policy.PREDICTIVE),
    reduction=Reduction.PER_POINT,
    n_lanes=64,
    seed=0,
))

pp = result.per_point()  # {"mean": (W, P, n_obs), ..., "points": [...]}
print("predator death rate | final prey (mean) | final predators (mean)")
for p, point in enumerate(pp["points"]):
    prey, pred = pp["mean"][-1, p]
    print(f"  k_die = {point['die']:4.1f}       | {prey:12.1f}      | "
          f"{pred:12.1f}")
print(f"\nwall={result.telemetry.wall_time_s:.2f}s "
      f"dispatches={result.telemetry.dispatches} "
      f"(one fused window_step per window)")
