"""Continuous-batching LM serving (the paper's schema ii/iii as an
inference engine — see DESIGN.md §5): requests with staggered lengths
share decode slices; finished slots are refilled on-demand; tokens
stream out per tick.

  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import numpy as np

from repro.configs.registry import get_smoke_config
from repro.models import build_model
from repro.serve.engine import Request, ServeEngine
from repro.sharding.rules import smoke_topology

cfg = get_smoke_config("llama3-8b")
model = build_model(cfg, smoke_topology(cfg))
params = model.init(jax.random.PRNGKey(0))

rng = np.random.default_rng(0)
engine = ServeEngine(model, params, n_slots=4, cache_len=64)

streamed = []
reqs = []
for i in range(10):
    prompt = rng.integers(0, cfg.vocab_size, size=rng.integers(3, 12))
    reqs.append(Request(
        uid=i, prompt=prompt.astype(np.int32),
        max_new_tokens=int(rng.integers(4, 16)),
        on_token=lambda uid, tok: streamed.append((uid, tok))))
    engine.submit(reqs[-1])

t0 = time.time()
engine.run()
wall = time.time() - t0

total_tokens = sum(len(r.out_tokens) for r in reqs)
print(f"{len(reqs)} requests, {total_tokens} tokens in {wall:.2f}s "
      f"({total_tokens/wall:.1f} tok/s) over {engine.ticks} ticks; "
      f"slot utilisation {engine.utilisation:.0%}")
for r in reqs[:3]:
    print(f"  req {r.uid}: prompt[{len(r.prompt)}] -> {r.out_tokens}")
print(f"streamed callbacks: {len(streamed)} (== total tokens: "
      f"{len(streamed) == total_tokens})")
