"""Quickstart: define a CWC model, declare an experiment, stream stats.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.api import Ensemble, Experiment, Schedule, Schema, simulate
from repro.core.cwc.rules import CWCModel, Rule
from repro.core.cwc.terms import TOP, term

# A CWC model straight from the paper's §2.1 example style:
#   ⊤ : a b X  -k->  c X
model = CWCModel(
    rules=(
        Rule.make(TOP, {"a": 1, "b": 1}, {"c": 1}, k=0.001, name="combine"),
        Rule.make(TOP, {"c": 1}, {"a": 1, "b": 1}, k=0.05, name="split"),
    ),
    init_fn=lambda: term({"a": 300, "b": 300}),
    observables=((TOP, "a"), (TOP, "b"), (TOP, "c")),
    name="quickstart",
)

# 64 stochastic instances, 20 sim-time windows, on-line reduction
result = simulate(Experiment(
    model=model,
    ensemble=Ensemble.make(replicas=64),
    schedule=Schedule(t_end=50.0, n_windows=20, schema=Schema.ONLINE),
    n_lanes=64,
    seed=0,
))
for rec in result.records:
    a, b, c = rec.mean
    print(f"t={rec.t:6.1f}  a={a:7.1f}  b={b:7.1f}  c={c:7.1f} "
          f"(ci90 ±{rec.ci90[2]:.2f}, n={rec.n:.0f})")

tele = result.telemetry
print(f"\npeak buffered bytes (schema iii is memory-bounded): "
      f"{tele.peak_buffered_bytes}")
print(f"one device dispatch per window: {tele.dispatches} dispatches "
      f"for {len(result.records)} windows in {tele.wall_time_s:.2f}s")
