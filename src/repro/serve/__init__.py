"""Serving: KV-cached decode + continuous batching engine."""
