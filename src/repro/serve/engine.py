"""Continuous-batching serve engine — paper schema ii/iii for LM decoding.

The mapping (DESIGN.md §5): a decode request IS a paper "simulation
instance" — irregular lifetime, stop/restartable, advancing on its own
clock. The engine realises the paper's mechanisms:

* fixed decode slices (schema ii time slicing): every engine tick is
  one batched `decode_step` over the slot array;
* slot compaction + on-demand admission (guideline G4): finished slots
  are freed and refilled from the pending queue without draining the
  batch (iteration-level scheduling);
* streaming outputs (G1/schema iii): tokens are pushed to per-request
  sinks as they are produced; nothing is buffered beyond the running
  window.
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.sampling import sample_token


@dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (P,) int32
    max_new_tokens: int = 32
    eos_id: int = -1  # -1: never
    temperature: float = 0.0
    out_tokens: list = field(default_factory=list)
    on_token: Optional[Callable[[int, int], None]] = None  # (uid, token)

    @property
    def done(self) -> bool:
        return (len(self.out_tokens) >= self.max_new_tokens
                or (self.out_tokens and self.out_tokens[-1] == self.eos_id))


class ServeEngine:
    def __init__(self, model, params, n_slots: int, cache_len: int,
                 seed: int = 0):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.cache_len = cache_len
        cfg = model.cfg
        if cfg.is_encoder_decoder:
            raise NotImplementedError("serve engine targets decoder-only")
        self.cache = model.init_cache(n_slots, cache_len)
        self.pos = np.zeros(n_slots, np.int32)
        self.tokens = np.zeros((n_slots, 1), np.int32)
        self.active: list[Optional[Request]] = [None] * n_slots
        self.pending: collections.deque = collections.deque()
        self.key = jax.random.PRNGKey(seed)
        self.ticks = 0
        self.busy_slot_ticks = 0
        self.total_slot_ticks = 0

        self._decode = jax.jit(model.decode_step, donate_argnums=(1,))
        self._prefill_one = jax.jit(self._prefill_impl)

    # ------------------------------------------------------------------
    def _prefill_impl(self, params, tokens):
        batch = {"tokens": tokens}
        cache, last_logits = self.model.prefill(params, batch,
                                                cache_len=self.cache_len)
        return cache, last_logits

    def submit(self, req: Request) -> None:
        self.pending.append(req)

    def _admit(self) -> None:
        """Fill free slots from the pending queue (paper: on-demand
        dispatch; the prefill writes the request's KV into the slot)."""
        for slot in range(self.n_slots):
            if self.active[slot] is not None or not self.pending:
                continue
            req = self.pending.popleft()
            p = len(req.prompt)
            assert p < self.cache_len
            cache_r, last_logits = self._prefill_one(
                self.params, jnp.asarray(req.prompt[None, :]))
            self.cache = _insert_slot(self.cache, cache_r, slot,
                                      self.cache_len)
            self.key, sub = jax.random.split(self.key)
            tok = sample_token(last_logits[0, -1], req.temperature, sub)
            self._record(req, int(tok))
            self.tokens[slot, 0] = int(tok)
            self.pos[slot] = p
            self.active[slot] = req if not req.done else None

    def _record(self, req: Request, tok: int) -> None:
        req.out_tokens.append(tok)
        if req.on_token:
            req.on_token(req.uid, tok)

    def tick(self) -> int:
        """One decode slice over all slots. Returns #active slots."""
        self._admit()
        live = [s for s in range(self.n_slots) if self.active[s] is not None]
        if not live:
            return 0
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(self.tokens),
            jnp.asarray(self.pos))
        self.ticks += 1
        self.busy_slot_ticks += len(live)
        self.total_slot_ticks += self.n_slots
        logits_np = logits[:, 0]
        for slot in live:
            req = self.active[slot]
            self.key, sub = jax.random.split(self.key)
            tok = int(sample_token(logits_np[slot], req.temperature, sub))
            self._record(req, tok)
            self.pos[slot] = min(self.pos[slot] + 1, self.cache_len - 1)
            self.tokens[slot, 0] = tok
            if req.done or self.pos[slot] >= self.cache_len - 1:
                self.active[slot] = None  # free slot -> refilled next tick
        return len(live)

    def run(self, max_ticks: int = 10_000) -> list[Request]:
        finished: list[Request] = []
        seen: set[int] = set()
        for _ in range(max_ticks):
            n = self.tick()
            if n == 0 and not self.pending:
                break
        return finished

    @property
    def utilisation(self) -> float:
        return (self.busy_slot_ticks / self.total_slot_ticks
                if self.total_slot_ticks else 0.0)


def _insert_slot(cache, cache_r, slot: int, cache_len: int):
    """Scatter a single-request prefill cache into batch slot `slot`,
    padding the sequence axis to cache_len."""

    def ins(dst, src):
        if dst.ndim >= 2 and src.shape[0] == 1:
            # pad seq axis (axis 1 for k/v with ndim>=3; states have no seq)
            if dst.ndim >= 3 and src.shape[1] != dst.shape[1]:
                pad = [(0, 0)] * src.ndim
                pad[1] = (0, dst.shape[1] - src.shape[1])
                src = jnp.pad(src, pad)
            return dst.at[slot].set(src[0])
        return dst

    def walk(dst, src):
        if isinstance(dst, dict):
            return {k: walk(dst[k], src[k]) for k in dst}
        if isinstance(dst, list):
            return [walk(d, s) for d, s in zip(dst, src)]
        # stacked leaves: (n_repeat, B, ...) -> insert along axis 1
        if dst.ndim == src.ndim and dst.shape[0] == src.shape[0] and (
                src.ndim >= 2 and src.shape[1] == 1):
            if src.ndim >= 4 and src.shape[2] != dst.shape[2]:
                pad = [(0, 0)] * src.ndim
                pad[2] = (0, dst.shape[2] - src.shape[2])
                src = jnp.pad(src, pad)
            return dst.at[:, slot].set(src[:, 0])
        return ins(dst, src)

    return walk(cache, cache_r)
