"""Token sampling."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample_token(logits, temperature: float, key):
    """logits: (V,). temperature 0 = greedy."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature).astype(jnp.int32)
