"""On-line trajectory reduction (paper §5.2 schema iii).

Trajectories sampled at fixed sim-time grid points are reduced to
running (count, mean, M2) Welford accumulators per (grid point,
species) — mean / variance / 90% confidence exactly as the paper's
Fig. 1 — while the raw window is discarded (memory-bounded streaming).

`merge` is Chan's parallel merge: associative, so the reduction forms a
tree across lanes, shards and pods (the paper's single collector thread,
made hierarchical — DESIGN.md §7).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

Z90 = 1.6448536269514722  # two-sided 90% normal quantile


class Welford(NamedTuple):
    n: jax.Array  # (...,) float32 count
    mean: jax.Array
    m2: jax.Array


def init_welford(shape) -> Welford:
    z = jnp.zeros(shape, jnp.float32)
    return Welford(n=z, mean=jnp.zeros_like(z), m2=jnp.zeros_like(z))


def update_batch(acc: Welford, x, mask=None) -> Welford:
    """Fold a batch of samples. x: (B, ...) folding over axis 0;
    mask: (B,) optional validity."""
    if mask is None:
        mask = jnp.ones(x.shape[0], bool)
    m = mask.astype(jnp.float32)
    while m.ndim < x.ndim:
        m = m[..., None]
    xb = x.astype(jnp.float32) * m
    nb = jnp.broadcast_to(m, x.shape).sum(axis=0)
    mean_b = jnp.where(nb > 0, xb.sum(axis=0) / jnp.maximum(nb, 1), 0.0)
    m2_b = (((x.astype(jnp.float32) - mean_b) * m) ** 2).sum(axis=0)
    return merge(acc, Welford(n=nb, mean=mean_b, m2=m2_b))


def merge(a: Welford, b: Welford) -> Welford:
    n = a.n + b.n
    safe = jnp.maximum(n, 1.0)
    d = b.mean - a.mean
    mean = a.mean + d * (b.n / safe)
    m2 = a.m2 + b.m2 + d * d * (a.n * b.n / safe)
    return Welford(n=n, mean=jnp.where(n > 0, mean, 0.0), m2=m2)


def merge_over_axis(acc: Welford, axis: str) -> Welford:
    """Tree-merge accumulators across a mesh axis inside shard_map.

    Exact merge of (n, mean, m2) via psum identities:
      N = Σn;  MEAN = Σ(n·mean)/N;  M2 = Σ m2 + Σ n·mean² − N·MEAN²
    (algebraically identical to pairwise Chan merges, but one psum.)
    """
    n = jax.lax.psum(acc.n, axis)
    s1 = jax.lax.psum(acc.n * acc.mean, axis)
    s2 = jax.lax.psum(acc.m2 + acc.n * acc.mean * acc.mean, axis)
    safe = jnp.maximum(n, 1.0)
    mean = s1 / safe
    m2 = s2 - n * mean * mean
    return Welford(n=n, mean=jnp.where(n > 0, mean, 0.0),
                   m2=jnp.maximum(m2, 0.0))


# ---------------------------------------------------------------- blocks
# The sharded farm pins the statistics merge tree to a fixed number of
# *virtual blocks* (Partitioning.stat_blocks) so the reduced records
# depend only on the block partition — never on how many physical
# shards computed them. Each block is a contiguous run of instances;
# a shard owns blocks/n_shards of them; the cross-shard wire format is
# ONE psum of the (blocks, ...) partial-accumulator stack (zeros are
# exact additive identities, so the gathered stack is bitwise identical
# to the unsharded one), and the final merge over the block axis is the
# same fixed-shape reduce everywhere.


def blocked_welford(obs, n_blocks: int) -> Welford:
    """Per-block Welford partials: obs (I, ...) -> leaves (V, ...).

    Block b covers the contiguous instance rows [b*I/V, (b+1)*I/V)."""
    xb = obs.reshape((n_blocks, obs.shape[0] // n_blocks) + obs.shape[1:])
    return jax.vmap(
        lambda x: update_batch(init_welford(x.shape[1:]), x))(xb)


def merge_blocks(acc: Welford) -> Welford:
    """Canonical merge of a (V, ...) stack of block accumulators.

    Same psum identities as `merge_over_axis`, but as a fixed (V,)-shape
    reduce over the leading block axis, so every path (sharded or not)
    folds the identical stack with the identical tree. V == 1 returns
    the single block unchanged (bitwise — the unblocked legacy path)."""
    if acc.n.shape[0] == 1:
        return Welford(*(a[0] for a in acc))
    n = acc.n.sum(axis=0)
    s1 = (acc.n * acc.mean).sum(axis=0)
    s2 = (acc.m2 + acc.n * acc.mean * acc.mean).sum(axis=0)
    safe = jnp.maximum(n, 1.0)
    mean = s1 / safe
    m2 = s2 - n * mean * mean
    return Welford(n=n, mean=jnp.where(n > 0, mean, 0.0),
                   m2=jnp.maximum(m2, 0.0))


def gather_blocks_over_axis(acc: Welford, axis: str,
                            n_shards: int) -> Welford:
    """Assemble the full (V, ...) block stack across a mesh axis with a
    single psum — the sharded farm's wire format.

    Each shard scatters its local (V/K, ...) partials into its rows of
    a zeroed (3, V, ...) buffer; the psum tree then moves exactly
    O(V x n_obs) floats per window, and because every position sums one
    value plus K-1 exact zeros, the gathered stack is bit-identical to
    the stack an unsharded run computes directly."""
    v_loc = acc.n.shape[0]
    v_total = v_loc * n_shards
    k = jax.lax.axis_index(axis)
    stacked = jnp.stack([acc.n, acc.mean, acc.m2])  # (3, V/K, ...)
    buf = jnp.zeros((3, v_total) + acc.n.shape[1:], jnp.float32)
    buf = jax.lax.dynamic_update_slice_in_dim(buf, stacked, k * v_loc,
                                              axis=1)
    full = jax.lax.psum(buf, axis)
    return Welford(n=full[0], mean=full[1], m2=full[2])


class Stats(NamedTuple):
    n: jax.Array
    mean: jax.Array
    var: jax.Array
    ci90: jax.Array  # half-width of the 90% confidence interval


def finalize(acc: Welford) -> Stats:
    var = acc.m2 / jnp.maximum(acc.n - 1.0, 1.0)
    sem = jnp.sqrt(var / jnp.maximum(acc.n, 1.0))
    return Stats(n=acc.n, mean=acc.mean, var=var, ci90=Z90 * sem)


def grouped_stats(obs, group_ids, n_groups: int) -> Stats:
    """Per-group statistics over the instance axis (sweep points).

    obs: (I, n_obs) one window's samples; group_ids: (I,) int32 group of
    each instance; n_groups static. Returns Stats with (n_groups, n_obs)
    leaves — the per-sweep-point reduction of paper §3.1.2, still one
    masked Welford fold per group so it composes with merge_over_axis.
    """
    def one(g):
        return update_batch(init_welford(obs.shape[1:]), obs,
                            mask=group_ids == g)

    return finalize(jax.vmap(one)(jnp.arange(n_groups)))


def blocked_stats(obs, n_blocks: int = 1) -> Stats:
    """Window statistics under the fixed `n_blocks` merge tree.

    n_blocks == 1 is exactly the legacy single update_batch fold (the
    engine's historical records); n_blocks > 1 reduces per-block
    partials with `merge_blocks` — the form whose result is invariant
    to sharding over any shard count dividing n_blocks."""
    if n_blocks == 1:
        return finalize(update_batch(init_welford(obs.shape[1:]), obs))
    return finalize(merge_blocks(blocked_welford(obs, n_blocks)))


def blocked_grouped_welford(obs, group_ids, n_groups: int,
                            n_blocks: int) -> Welford:
    """Per-(block, group) masked partials: leaves (V, n_groups, ...)."""
    bs = obs.shape[0] // n_blocks
    xb = obs.reshape((n_blocks, bs) + obs.shape[1:])
    gb = group_ids.reshape(n_blocks, bs)

    def one_block(x, g):
        def one_group(gid):
            return update_batch(init_welford(x.shape[1:]), x,
                                mask=g == gid)

        return jax.vmap(one_group)(jnp.arange(n_groups))

    return jax.vmap(one_block)(xb, gb)
