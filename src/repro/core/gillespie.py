"""Batched exact SSA (Gillespie direct method) with sim-time windows.

The paper's three logical steps (Match → Resolve → Update, §2.3) are
all dense tensor ops over the lane axis:

  Match   = `propensities` (lanes × reactions matrix)
  Resolve = exponential waiting time + inverse-CDF reaction choice
  Update  = one-hot × stoichiometry matmul

`advance_to(horizon)` is the schema-(ii) time slice: every lane steps
until its clock would cross the horizon; the crossing event is NOT
applied — the lane freezes exactly at the horizon (valid by
memorylessness of the exponential), which makes the frozen state the
exact trajectory sample at the grid point. Lanes that finish early are
masked — the SIMD analogue of a stopped instance object.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.reactions import (
    ReactionSystem,
    SparseTables,
    comb_factors,
    propensities,
    require_dense_capable,
    sparse_tables,
)
from repro.core.stream import counter_uniforms, ctr_add


class LaneState(NamedTuple):
    x: jax.Array  # (B, S) float32 counts
    t: jax.Array  # (B,) float32 sim clocks
    key: jax.Array  # (B, 2) uint32 per-lane stream key (never advances)
    ctr: jax.Array  # (B,) uint32 RNG draw counter, low word
    ctr_hi: jax.Array  # (B,) uint32 RNG draw counter, high word (carry)
    steps: jax.Array  # (B,) int32 solver iterations that advanced the lane
    #   (exact SSA: events fired; tau-leap: accepted leaps + fallback
    #   events — the per-method work metric)
    leaps: jax.Array  # (B,) int32 accepted tau-leaps (0 on exact paths)
    dead: jax.Array  # (B,) bool — no reaction can ever fire again
    no_leap: jax.Array  # (B,) bool — steering forced this lane to exact
    #   SSA (tau-leap lanes only; ignored by exact paths). Rides the
    #   pool pytree so it flows through donation, scan carries,
    #   shard_map and checkpoints without extra plumbing.


def init_lanes(system: ReactionSystem, n_lanes: int, seed: int,
               x0=None) -> LaneState:
    x0 = jnp.asarray(system.x0 if x0 is None else x0, jnp.float32)
    if x0.ndim == 1:
        x0 = jnp.broadcast_to(x0, (n_lanes, x0.shape[0]))
    keys = jax.random.split(jax.random.PRNGKey(seed), n_lanes)
    return LaneState(
        x=x0.astype(jnp.float32),
        t=jnp.zeros((n_lanes,), jnp.float32),
        key=jax.vmap(jax.random.key_data)(keys) if keys.dtype != jnp.uint32
        else keys,
        ctr=jnp.zeros((n_lanes,), jnp.uint32),
        ctr_hi=jnp.zeros((n_lanes,), jnp.uint32),
        steps=jnp.zeros((n_lanes,), jnp.int32),
        leaps=jnp.zeros((n_lanes,), jnp.int32),
        dead=jnp.zeros((n_lanes,), bool),
        no_leap=jnp.zeros((n_lanes,), bool),
    )


def _uniforms(state: LaneState):
    """Counter-based draw: (u1, u2) for each lane's current event index.

    A draw is a pure function of (lane key, ctr) — `stream.
    counter_uniforms` — so the fused kernel regenerates the identical
    stream in VREGs and parity with the kernel path is bitwise for any
    chunking (DESIGN.md §3c). The key itself never advances; only the
    per-lane counter does (by 1 per *active* step, i.e. per consumed
    draw).
    """
    return counter_uniforms(state.key[:, 0], state.key[:, 1], state.ctr,
                            state.ctr_hi)


def ssa_step(state: LaneState, system_tensors, horizon) -> LaneState:
    """One vectorised direct-method step, masked at the horizon.

    system_tensors: (idx, coef, delta_f32, rates) as jnp arrays; rates
    may be (R,) or (B, R).
    """
    idx, coef, delta, rates = system_tensors
    active = (state.t < horizon) & ~state.dead
    a = propensities(state.x, idx, coef, rates)  # (B, R)
    a0 = a.sum(axis=1)
    dead = a0 <= 0.0
    u1, u2 = _uniforms(state)
    tau = -jnp.log(u1) / jnp.maximum(a0, 1e-30)
    t_next = state.t + tau
    fire = active & ~dead & (t_next <= horizon)
    # inverse-CDF choice: first j with cumsum(a_j) >= u2 * a0
    cum = jnp.cumsum(a, axis=1)
    thresh = (u2 * a0)[:, None]
    j = jnp.argmax(cum >= thresh, axis=1)  # (B,)
    dx = delta[j]  # (B, S)
    x = jnp.where(fire[:, None], state.x + dx, state.x)
    # clocks: fired lanes advance to t_next; active lanes whose next
    # event would cross freeze at the horizon; dead lanes jump to horizon
    t = jnp.where(fire, t_next,
                  jnp.where(active, jnp.minimum(horizon, state.t + tau),
                            state.t))
    t = jnp.where(active & (dead | (t_next > horizon)), horizon, t)
    lo, hi = ctr_add(state.ctr, state.ctr_hi, active.astype(jnp.uint32))
    return LaneState(
        x=x,
        t=t,
        key=state.key,
        ctr=lo,
        ctr_hi=hi,
        steps=state.steps + fire.astype(jnp.int32),
        leaps=state.leaps,
        dead=state.dead | (active & dead),
        no_leap=state.no_leap,
    )


def system_tensors(system: ReactionSystem, rates=None, *,
                   require_dense: bool = True):
    """Dense gather-form tensors. Dense evaluation unrolls C(n, c) to
    MAX_COEF, so by default this refuses systems with larger
    coefficients (run those with sparse=True)."""
    if require_dense:
        require_dense_capable(system)
    return (
        jnp.asarray(system.reactant_idx),
        jnp.asarray(system.reactant_coef),
        jnp.asarray(system.delta, jnp.float32),
        jnp.asarray(system.rates if rates is None else rates, jnp.float32),
    )


def sparse_system_tensors(tables: SparseTables):
    """Device-side sparse tables as one tuple: (idx_pad (R+1, M),
    coef_pad (R+1, M), dep_idx (R+1, K), delta_idx (R+1, D),
    delta_val (R+1, D), max_c). Threaded opaquely through window bodies
    and kernels the way `system_tensors` tuples are."""
    return (
        jnp.asarray(tables.reactant_idx),
        jnp.asarray(tables.reactant_coef),
        jnp.asarray(tables.dep_idx),
        jnp.asarray(tables.delta_idx),
        jnp.asarray(tables.delta_val),
        int(tables.max_coef),
    )


def pad_rates(rates):
    """Append the PAD reaction's zero rate: (R,) -> (R+1,) or (B, R) ->
    (B, R+1). Done ONCE per window so the per-event dep gather stays
    O(out-degree)."""
    rates = jnp.asarray(rates, jnp.float32)
    if rates.ndim == 1:
        return jnp.concatenate([rates, jnp.zeros((1,), rates.dtype)])
    return jnp.concatenate(
        [rates, jnp.zeros((rates.shape[0], 1), rates.dtype)], axis=1)


def initial_propensities(x, sp, rates):
    """Dense evaluation seeding the carried (B, R) propensity vector.

    Propensities are a pure function of x, so re-seeding at any window
    or chunk boundary reproduces the carried value bitwise — which is
    what lets every execution granularity (host loop, fused window,
    kernel chunks) share one contract. Uses the SAME slot order and
    rates-first association as the dense path; the unroll bound differs
    only in exact no-op iterations.
    """
    idx_pad, coef_pad, _, _, _, max_c = sp
    return propensities(x, idx_pad[:-1], coef_pad[:-1], rates, max_c)


def bind_sparse_step(sp, rates):
    """Hoist the per-window table packing for `sparse_ssa_step`.

    XLA:CPU gathers pay per-OP overhead that dwarfs the handful of
    elements each one moves, so the per-event table lookups are fused
    into TWO row gathers: every reaction row j carries its whole update
    recipe contiguously —

      int_tab[j]  = [delta_idx (D) | dep(j) (K) | reactant idx of each
                     dep row, flattened (K·M)]
      flt_tab[j]  = [delta_val (D) | reactant coef of each dep row
                     (K·M) | rates of each dep row (K)]

    both with the all-pad row R at the end (non-firing lanes index it).
    The dep-row rates fold into flt_tab only for shared (R,)-shaped
    rates; per-instance sweep rates stay a separate (B, R+1) operand
    gathered per event (`rates2d`). Packing is pure memory layout —
    every value is the same float/int the unpacked tables held — and
    runs once per window/chunk launch, so the per-event cost is
    O(out-degree) gathers regardless of how the rates are shaped.

    Returns (int_tab, flt_tab, rates2d, max_c, d, k, m).
    """
    idx_pad, coef_pad, dep_idx, delta_idx, delta_val, max_c = sp
    d = delta_idx.shape[1]
    k = dep_idx.shape[1]
    m = idx_pad.shape[1]
    r1 = dep_idx.shape[0]
    ridx = idx_pad[dep_idx].reshape(r1, k * m)
    int_tab = jnp.concatenate([delta_idx, dep_idx, ridx], axis=1)
    coefs = coef_pad[dep_idx].reshape(r1, k * m).astype(jnp.float32)
    rp = pad_rates(rates)
    if rp.ndim == 1:
        flt_tab = jnp.concatenate([delta_val, coefs, rp[dep_idx]], axis=1)
        rates2d = None
    else:
        flt_tab = jnp.concatenate([delta_val, coefs], axis=1)
        rates2d = rp
    return (int_tab, flt_tab, rates2d, max_c, d, k, m)


def resolve_carry(a):
    """(a, a0, cum) — the Resolve inputs the sparse step carries.

    a0 and cum are the SAME `a.sum(axis=1)` / `jnp.cumsum(a, axis=1)`
    the dense step computes per event, evaluated eagerly whenever `a`
    changes (seed time, and the tail of every `sparse_ssa_step`)
    instead of lazily at the top of the next step. Same ops on the same
    values — the pipelining exists so that each loop iteration only
    WRITES the carried `a` buffer (the dep-row scatter): with no
    read-before-write hazard on `a`, XLA updates it in place instead of
    copying the (B, R) buffer every event.
    """
    return a, a.sum(axis=1), jnp.cumsum(a, axis=1)


def sparse_ssa_step(state: LaneState, aci, bound, horizon):
    """One direct-method step with dependency-graph propensity updates.

    Identical Resolve/clock/counter logic to `ssa_step`, but Match and
    Update are sparse: `aci = (a, a0, cum)` (`resolve_carry`) carries
    the (B, R) propensity vector (invariant: bitwise equal to
    `propensities(state.x, ...)`) with its Resolve reductions, the
    Update scatters the fired reaction's delta list, and only the
    dep(j) rows of `a` are recomputed — O(out-degree) gathered work per
    event instead of O(R·M). The O(R) elementwise Resolve (sum/cumsum)
    is retained: the inverse-CDF choice must accumulate in dense order
    to stay bitwise identical to the reference.

    bound: `bind_sparse_step(sp, rates)` — packed once per window.
    Returns (LaneState, aci).
    """
    a, a0, cum = aci
    int_tab, flt_tab, rates2d, max_c, d, k, m = bound
    r = int_tab.shape[0] - 1
    b, s = state.x.shape
    active = (state.t < horizon) & ~state.dead
    dead = a0 <= 0.0
    u1, u2 = _uniforms(state)
    tau = -jnp.log(u1) / jnp.maximum(a0, 1e-30)
    t_next = state.t + tau
    fire = active & ~dead & (t_next <= horizon)
    thresh = (u2 * a0)[:, None]
    j = jnp.argmax(cum >= thresh, axis=1)  # (B,)
    rows = jnp.arange(b)[:, None]
    # jd: the fired reaction, or the all-pad row R for lanes that did
    # not fire — the two packed-row gathers below ride this single
    # select instead of each masking its own (B, ·) result
    jd = jnp.where(fire, j, r)
    it = int_tab[jd]  # (B, D + K + K·M)
    ft = flt_tab[jd]  # (B, D + K·M [+ K])
    didx, dep = it[:, :d], it[:, d:d + k]
    ridx = it[:, d + k:]
    dval, coefs = ft[:, :d], ft[:, d:d + k * m].reshape(b, k, m)
    if rates2d is None:
        rate_rows = ft[:, d + k * m:]
    else:
        rate_rows = jnp.take_along_axis(rates2d, dep, axis=1)
    # sparse Update: scatter the fired delta list; pad slots (and every
    # slot of non-firing lanes, via row R) point at column S and are
    # dropped. Bitwise equal to the dense x + delta[j]: untouched
    # entries are x + 0.0 there, and populations are never -0.0.
    x = state.x.at[rows, didx].add(dval, mode="drop")
    # dependency-graph Match: recompute ONLY dep(j) rows from the new x;
    # rows outside dep(j) keep their carried value — their reactant
    # populations did not change, so a recomputation would return the
    # identical bits. Same scalar math as `propensities`: pad-slot pops
    # gather the neutral 1.0 (out-of-range fill), the comb unroll runs
    # all M slots batched (exact no-ops past each coef), and the slot
    # products multiply rates-first in slot order.
    pops = jnp.take_along_axis(x, ridx, axis=1, mode="fill",
                               fill_value=1.0).reshape(b, k, m)
    f = comb_factors(pops, coefs, max_c)
    a_new = rate_rows.astype(x.dtype)
    for mm in range(m):
        a_new = a_new * f[:, :, mm]
    a = a.at[rows, dep].set(a_new, mode="drop")
    # an active lane either fired (clock -> t_next) or froze at the
    # horizon: ~fire for an active lane means dead (tau = +inf) or an
    # overshooting t_next, and both froze to `horizon` in the dense
    # step's where-chain too — same values, two selects instead of four
    t = jnp.where(active, jnp.where(fire, t_next, horizon), state.t)
    lo, hi = ctr_add(state.ctr, state.ctr_hi, active.astype(jnp.uint32))
    return LaneState(
        x=x,
        t=t,
        key=state.key,
        ctr=lo,
        ctr_hi=hi,
        steps=state.steps + fire.astype(jnp.int32),
        leaps=state.leaps,
        dead=state.dead | (active & dead),
        no_leap=state.no_leap,
    ), resolve_carry(a)


def make_advance_fn(step_fn, tensors3, max_steps: Optional[int],
                    sparse=None):
    """Build `advance(lane_slice, rates, horizon) -> LaneState`: the
    masked per-lane loop to the horizon, bounded by max_steps when set.

    This is THE loop every execution path shares — the fused/sharded
    window bodies scan it per lane group and the host-loop strategy
    jits it per group — so the horizon-freeze and step-bound semantics
    live in exactly one place.

    `step_fn(state, (idx, coef, delta, rates), horizon) -> state` is
    the per-lane algorithm (`ssa_step`, `tau_leap.make_tau_step(...)`,
    dense or gather-Match). `sparse` switches to the dependency-graph
    exact step: pass the `sparse_system_tensors` tuple; the carry is
    then (LaneState, propensity vector), seeded densely on entry —
    bitwise identical to a dense run because propensities are a pure
    function of x.
    """
    idx_t = coef_t = delta_t = None
    if tensors3 is not None:
        idx_t, coef_t, delta_t = tensors3

    def advance(sl: LaneState, rates, horizon):
        def lane_cond(s):
            return jnp.any((s.t < horizon) & ~s.dead)

        if sparse is None:
            tensors = (idx_t, coef_t, delta_t, rates)
            cond, init = lane_cond, sl

            def body(s):
                return step_fn(s, tensors, horizon)

            def unwrap(c):
                return c
        else:
            bound = bind_sparse_step(sparse, rates)
            init = (sl, resolve_carry(
                initial_propensities(sl.x, sparse, rates)))

            def cond(c):
                return lane_cond(c[0])

            def body(c):
                return sparse_ssa_step(c[0], c[1], bound, horizon)

            def unwrap(c):
                return c[0]

        if max_steps is None:
            out = unwrap(jax.lax.while_loop(cond, body, init))
        else:
            out = unwrap(jax.lax.fori_loop(
                0, max_steps,
                lambda _, c: jax.lax.cond(cond(c), body, lambda c_: c_, c),
                init))
        return out._replace(
            t=jnp.where(out.dead, jnp.maximum(out.t, horizon), out.t))

    return advance


def advance_to(state: LaneState, system_tensors, horizon,
               max_steps: Optional[int] = None) -> LaneState:
    """Advance every lane exactly to `horizon` (schema-ii time slice)."""
    horizon = jnp.asarray(horizon, jnp.float32)

    def cond(s):
        return jnp.any((s.t < horizon) & ~s.dead)

    def body(s):
        return ssa_step(s, system_tensors, horizon)

    if max_steps is None:
        out = jax.lax.while_loop(cond, body, state)
    else:
        def bounded_body(i, s):
            return jax.lax.cond(cond(s), body, lambda s: s, s)

        out = jax.lax.fori_loop(0, max_steps, bounded_body, state)
    # lanes that ran out of events still advance their clock
    t = jnp.where(out.dead, jnp.maximum(out.t, horizon), out.t)
    return out._replace(t=t)


def run_reference_trajectory(system: ReactionSystem, t_grid, seed: int = 0):
    """Single-lane convenience wrapper: X sampled on t_grid. Host loop,
    used by tests and the fig-1 style outputs."""
    st = init_lanes(system, 1, seed)
    tensors = system_tensors(system)
    out = []
    step = jax.jit(lambda s, h: advance_to(s, tensors, h))
    for h in t_grid:
        st = step(st, float(h))
        out.append(st.x[0])
    return jnp.stack(out)  # (T, S)
