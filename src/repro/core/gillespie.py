"""Batched exact SSA (Gillespie direct method) with sim-time windows.

The paper's three logical steps (Match → Resolve → Update, §2.3) are
all dense tensor ops over the lane axis:

  Match   = `propensities` (lanes × reactions matrix)
  Resolve = exponential waiting time + inverse-CDF reaction choice
  Update  = one-hot × stoichiometry matmul

`advance_to(horizon)` is the schema-(ii) time slice: every lane steps
until its clock would cross the horizon; the crossing event is NOT
applied — the lane freezes exactly at the horizon (valid by
memorylessness of the exponential), which makes the frozen state the
exact trajectory sample at the grid point. Lanes that finish early are
masked — the SIMD analogue of a stopped instance object.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.reactions import ReactionSystem, propensities
from repro.core.stream import counter_uniforms, ctr_add


class LaneState(NamedTuple):
    x: jax.Array  # (B, S) float32 counts
    t: jax.Array  # (B,) float32 sim clocks
    key: jax.Array  # (B, 2) uint32 per-lane stream key (never advances)
    ctr: jax.Array  # (B,) uint32 RNG draw counter, low word
    ctr_hi: jax.Array  # (B,) uint32 RNG draw counter, high word (carry)
    steps: jax.Array  # (B,) int32 solver iterations that advanced the lane
    #   (exact SSA: events fired; tau-leap: accepted leaps + fallback
    #   events — the per-method work metric)
    leaps: jax.Array  # (B,) int32 accepted tau-leaps (0 on exact paths)
    dead: jax.Array  # (B,) bool — no reaction can ever fire again
    no_leap: jax.Array  # (B,) bool — steering forced this lane to exact
    #   SSA (tau-leap lanes only; ignored by exact paths). Rides the
    #   pool pytree so it flows through donation, scan carries,
    #   shard_map and checkpoints without extra plumbing.


def init_lanes(system: ReactionSystem, n_lanes: int, seed: int,
               x0=None) -> LaneState:
    x0 = jnp.asarray(system.x0 if x0 is None else x0, jnp.float32)
    if x0.ndim == 1:
        x0 = jnp.broadcast_to(x0, (n_lanes, x0.shape[0]))
    keys = jax.random.split(jax.random.PRNGKey(seed), n_lanes)
    return LaneState(
        x=x0.astype(jnp.float32),
        t=jnp.zeros((n_lanes,), jnp.float32),
        key=jax.vmap(jax.random.key_data)(keys) if keys.dtype != jnp.uint32
        else keys,
        ctr=jnp.zeros((n_lanes,), jnp.uint32),
        ctr_hi=jnp.zeros((n_lanes,), jnp.uint32),
        steps=jnp.zeros((n_lanes,), jnp.int32),
        leaps=jnp.zeros((n_lanes,), jnp.int32),
        dead=jnp.zeros((n_lanes,), bool),
        no_leap=jnp.zeros((n_lanes,), bool),
    )


def _uniforms(state: LaneState):
    """Counter-based draw: (u1, u2) for each lane's current event index.

    A draw is a pure function of (lane key, ctr) — `stream.
    counter_uniforms` — so the fused kernel regenerates the identical
    stream in VREGs and parity with the kernel path is bitwise for any
    chunking (DESIGN.md §3c). The key itself never advances; only the
    per-lane counter does (by 1 per *active* step, i.e. per consumed
    draw).
    """
    return counter_uniforms(state.key[:, 0], state.key[:, 1], state.ctr,
                            state.ctr_hi)


def ssa_step(state: LaneState, system_tensors, horizon) -> LaneState:
    """One vectorised direct-method step, masked at the horizon.

    system_tensors: (idx, coef, delta_f32, rates) as jnp arrays; rates
    may be (R,) or (B, R).
    """
    idx, coef, delta, rates = system_tensors
    active = (state.t < horizon) & ~state.dead
    a = propensities(state.x, idx, coef, rates)  # (B, R)
    a0 = a.sum(axis=1)
    dead = a0 <= 0.0
    u1, u2 = _uniforms(state)
    tau = -jnp.log(u1) / jnp.maximum(a0, 1e-30)
    t_next = state.t + tau
    fire = active & ~dead & (t_next <= horizon)
    # inverse-CDF choice: first j with cumsum(a_j) >= u2 * a0
    cum = jnp.cumsum(a, axis=1)
    thresh = (u2 * a0)[:, None]
    j = jnp.argmax(cum >= thresh, axis=1)  # (B,)
    dx = delta[j]  # (B, S)
    x = jnp.where(fire[:, None], state.x + dx, state.x)
    # clocks: fired lanes advance to t_next; active lanes whose next
    # event would cross freeze at the horizon; dead lanes jump to horizon
    t = jnp.where(fire, t_next,
                  jnp.where(active, jnp.minimum(horizon, state.t + tau),
                            state.t))
    t = jnp.where(active & (dead | (t_next > horizon)), horizon, t)
    lo, hi = ctr_add(state.ctr, state.ctr_hi, active.astype(jnp.uint32))
    return LaneState(
        x=x,
        t=t,
        key=state.key,
        ctr=lo,
        ctr_hi=hi,
        steps=state.steps + fire.astype(jnp.int32),
        leaps=state.leaps,
        dead=state.dead | (active & dead),
        no_leap=state.no_leap,
    )


def system_tensors(system: ReactionSystem, rates=None):
    return (
        jnp.asarray(system.reactant_idx),
        jnp.asarray(system.reactant_coef),
        jnp.asarray(system.delta, jnp.float32),
        jnp.asarray(system.rates if rates is None else rates, jnp.float32),
    )


def advance_to(state: LaneState, system_tensors, horizon,
               max_steps: Optional[int] = None) -> LaneState:
    """Advance every lane exactly to `horizon` (schema-ii time slice)."""
    horizon = jnp.asarray(horizon, jnp.float32)

    def cond(s):
        return jnp.any((s.t < horizon) & ~s.dead)

    def body(s):
        return ssa_step(s, system_tensors, horizon)

    if max_steps is None:
        out = jax.lax.while_loop(cond, body, state)
    else:
        def bounded_body(i, s):
            return jax.lax.cond(cond(s), body, lambda s: s, s)

        out = jax.lax.fori_loop(0, max_steps, bounded_body, state)
    # lanes that ran out of events still advance their clock
    t = jnp.where(out.dead, jnp.maximum(out.t, horizon), out.t)
    return out._replace(t=t)


def run_reference_trajectory(system: ReactionSystem, t_grid, seed: int = 0):
    """Single-lane convenience wrapper: X sampled on t_grid. Host loop,
    used by tests and the fig-1 style outputs."""
    st = init_lanes(system, 1, seed)
    tensors = system_tensors(system)
    out = []
    step = jax.jit(lambda s, h: advance_to(s, tensors, h))
    for h in t_grid:
        st = step(st, float(h))
        out.append(st.x[0])
    return jnp.stack(out)  # (T, S)
