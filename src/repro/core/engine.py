"""SimulationEngine — the multicore-aware simulator, TPU-pod native.

Runs an ensemble of stochastic CWC simulations (replicas and/or a
parameter sweep) under one of the paper's three schemas:

  schema "i"   static farm, post-hoc reduction (baseline)
  schema "ii"  time-sliced self-balancing farm, post-hoc reduction
  schema "iii" time-sliced farm + ON-LINE windowed reduction (the
               paper's best variant; memory-bounded)

Hot path: the whole instance pool advances one window as ONE pytree
through a single jitted, donated `window_step` — the scheduler's groups
become a device-side permutation plus a `lax.scan` over fixed-size lane
slices, so a window costs one dispatch instead of one gather/advance/
scatter round trip per group. With `SimConfig.use_kernel` the step is
instead the Pallas fused SSA window (kernels/): a device-side chunk
while_loop with in-VREG counter-based RNG — still one dispatch per
window, zero mid-window host syncs, and bitwise identical to the
unfused path (DESIGN.md §3c). The legacy host-driven per-group loop is
kept behind `SimConfig.host_loop` as the benchmark baseline; all paths
are bit-identical because every per-lane operation is unchanged.
With `SimConfig.window_block=W` whole runs go device-resident:
W windows fuse into ONE dispatch (a lax.scan inside the strategy) whose
per-window products land in an on-device record ring, and the engine's
depth-K pipelined collector (`SimConfig.pipeline_depth`, "auto" to
profile) keeps up to K blocks in flight before blocking on the oldest
ring pull — so dispatches AND host syncs amortise to 1/W per window,
the collector's host work hides behind K blocks of device compute, and
records stay bitwise identical for any W and any K (DESIGN.md §3e).
Ring snapshots (`enable_snapshots`) let checkpoint() save the collected
frontier while blocks stay in flight instead of flushing the pipeline.

Distribution: with a `Partitioning` (or a mesh), the instance pool is
sharded over the mesh's data axis (each shard = a farm worker); the
same window body runs per shard under `compat.shard_map`, and
per-window statistics are reduced with a single psum tree
(`reduction.gather_blocks_over_axis` + `merge_blocks`) so only
O(stat_blocks x species) floats ever cross pods. Dispatch-path selection (host loop / fused /
sharded) lives in `core/dispatch.py` as one explicit strategy seam.
Fault tolerance: `checkpoint()`/`restore()` serialise the pool +
scheduler + accumulators + emitted records (gather-on-save); restore
re-places the pool on the current mesh (reshard-on-restore), and
trajectories are deterministic per-instance (keyed RNG), so a restart —
even with a different mesh shape — resumes bit-identically.

NOTE: constructing `SimulationEngine` directly is deprecated — use the
declarative front-end, `repro.api.simulate(Experiment(...))` (see
DESIGN.md for the migration table). The old surface is kept as a thin
shim over the same engine.
"""
from __future__ import annotations

import collections
import math
import time
import warnings
from dataclasses import dataclass
from functools import partial
from typing import NamedTuple, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import reduction
from repro.core.cwc.compile import compile_model
from repro.core.cwc.rules import CWCModel
from repro.core.dispatch import Partitioning, select_dispatch
from repro.core.gillespie import (
    LaneState,
    init_lanes,
    make_advance_fn,
    sparse_system_tensors,
    ssa_step,
    system_tensors,
)
from repro.core.reactions import ReactionSystem, sparse_tables
from repro.core.scheduler import Scheduler
from repro.core.stream import StatsRecord, StatsStream
from repro.ckpt import store as ckpt_store
from repro.runtime.fault import InvariantViolation
from repro.runtime.straggler import WindowWatchdog
from repro.stats.sketch import SketchSpec, WindowSketch, window_sketch
from repro.steer.policy import Steering, SteeringActions, SteeringPolicy


@dataclass(frozen=True)
class SimConfig:
    n_instances: int = 128
    t_end: float = 10.0
    n_windows: int = 50
    n_lanes: int = 128  # SIMD width per slice group
    schema: str = "iii"  # i | ii | iii
    policy: str = "on_demand"  # static_rr | on_demand | predictive
    seed: int = 0
    max_steps_per_window: Optional[int] = None
    use_kernel: bool = False  # fused Pallas window (see kernels/)
    host_loop: bool = False  # legacy per-group gather/scatter dispatch
    # kernel-path chunking: each window is ONE dispatch running up to
    # kernel_max_chunks kernel launches of kernel_chunk_steps fused
    # events in a device-side while_loop; a window needing more raises
    # FusedWindowTruncated (never silently truncates)
    kernel_chunk_steps: int = 256
    kernel_max_chunks: int = 64
    # simulation algorithm: "exact" (Gillespie direct SSA) or
    # "tau_leap" (adaptive Cao tau selection + Poisson reaction counts,
    # per-lane exact fallback — core/tau_leap.py). Composes with every
    # dispatch path (host_loop | fused | sharded, x use_kernel).
    method: str = "exact"
    tau_eps: float = 0.03  # Cao bound: max relative propensity drift
    tau_fallback: float = 10.0  # leap only when tau covers >= this
    #   many expected SSA events (else per-lane exact SSA step)
    # superstep width: fuse this many windows into ONE device dispatch
    # (a lax.scan over window horizons inside the fused/sharded
    # strategies) with the per-window records accumulated in an
    # on-device ring and pulled per block by the engine's pipelined
    # collector — dispatches and host syncs amortise to 1/window_block
    # per window. 1 (default) is the unchanged per-window path;
    # records are bitwise identical for any value (DESIGN.md §3e).
    window_block: int = 1
    # superstep pipeline depth (DESIGN.md §3e): how many dispatched
    # window blocks may sit in flight (ring pull outstanding) after
    # each collector turn before the oldest is collected. 1 is the
    # PR 5 double-buffer; K > 1 keeps K rings queued so the collector's
    # host-side reduce/emit work is hidden behind K blocks of device
    # compute; "auto" measures the first collected block's blocking
    # pull vs host-reduce walls and picks a depth from that profile
    # (resolve_auto_depth). Depth only changes WHEN rings are pulled,
    # never what was computed — records/sketches/grouped stats/
    # trajectories/steering are bitwise identical for any depth. Each
    # in-flight block holds a full record ring (plus a pool snapshot
    # when ring-snapshot checkpointing is enabled), and
    # peak_buffered_bytes accounts for all of them. Irrelevant (depth
    # is effectively 1) when window_block == 1 or under host_loop.
    pipeline_depth: Union[int, str] = 1
    # sparse large-network encoding (DESIGN.md §3g): CSR-style padded
    # reactant tables + a precomputed reaction dependency graph so a
    # firing recomputes only the affected propensities (O(out-degree)
    # per event instead of O(R·M)), and the kernels hold only
    # O(R·(M+K+D)) sparse tables instead of O(S·R) one-hots. Composes
    # with every strategy × method × window_block; trajectories and
    # records are BITWISE identical to the dense path. Also lifts the
    # dense MAX_COEF ceiling (table-free comb unroll to the system's
    # actual max coefficient).
    sparse: bool = False
    # engine invariant guards (DESIGN.md §3h): host-side checks on the
    # per-window statistics the collect path has ALREADY pulled
    # (non-finite moments, negative populations, ring/record count
    # disagreement) — zero extra device syncs. A trip raises a typed
    # InvariantViolation; the in-memory pool is untrusted from that
    # point and a supervisor recovers from the last durable checkpoint.
    guards: bool = True

    def __post_init__(self):
        if self.window_block < 1:
            raise ValueError(
                f"SimConfig.window_block must be >= 1, got "
                f"{self.window_block}")
        if self.window_block > 1 and self.host_loop:
            raise ValueError(
                "window_block > 1 needs the fused or sharded dispatch "
                "strategy; host_loop is the per-window round-trip "
                "baseline (set window_block=1)")
        if self.kernel_chunk_steps < 1:
            raise ValueError(
                f"SimConfig.kernel_chunk_steps must be >= 1, got "
                f"{self.kernel_chunk_steps}")
        if self.kernel_max_chunks < 1:
            raise ValueError(
                f"SimConfig.kernel_max_chunks must be >= 1, got "
                f"{self.kernel_max_chunks}")
        if isinstance(self.pipeline_depth, str):
            if self.pipeline_depth != "auto":
                raise ValueError(
                    f"SimConfig.pipeline_depth must be an int >= 1 or "
                    f"'auto', got {self.pipeline_depth!r}")
        elif self.pipeline_depth < 1:
            raise ValueError(
                f"SimConfig.pipeline_depth must be >= 1, got "
                f"{self.pipeline_depth}")
        if self.method not in ("exact", "tau_leap"):
            raise ValueError(
                f"SimConfig.method must be 'exact' or 'tau_leap', got "
                f"{self.method!r}")
        if not self.tau_eps > 0:
            raise ValueError(
                f"SimConfig.tau_eps must be > 0, got {self.tau_eps}")
        if self.tau_fallback < 0:
            raise ValueError(
                f"SimConfig.tau_fallback must be >= 0, got "
                f"{self.tau_fallback}")


# pipeline_depth="auto" bounds: floor keeps at least the PR 5 depth-1
# overlap plus one queued block; cap bounds memory (each in-flight
# block holds a full record ring)
AUTO_DEPTH_MIN = 2
AUTO_DEPTH_MAX = 8


def resolve_auto_depth(pull_s: float, host_s: float) -> int:
    """Pick a pipeline depth from the first collected block's profile.

    `pull_s` is the blocking ring-pull wall with a cold pipeline (a
    proxy for one block's remaining device+transfer time when the
    collector asks) and `host_s` the collector's host-side
    reduce/emit wall for that block. Queueing K blocks gives the
    device ~K blocks of runway while the host works, so the depth
    that hides the host work is 1 + ceil(host_s / pull_s), clamped to
    [AUTO_DEPTH_MIN, AUTO_DEPTH_MAX]. The probe only tunes WHEN rings
    are pulled — results are bitwise identical for any outcome.
    """
    if pull_s <= 0:
        return AUTO_DEPTH_MIN
    return max(AUTO_DEPTH_MIN,
               min(AUTO_DEPTH_MAX, 1 + math.ceil(host_s / pull_s)))


class _InFlight(NamedTuple):
    """One dispatched-but-uncollected superstep in the pipeline."""
    w0: int  # first window of the block
    n_win: int  # windows in the block
    pull: dict  # device record ring + queued eager folds
    dispatch_wall: float  # host wall to ENQUEUE the block (async)
    obs_row_bytes: int  # one window's obs footprint (schema-iii acct)
    ring_bytes: int  # whole queued ring (+ snapshot) device footprint
    snapshot: Optional[LaneState]  # pool copy taken BEFORE dispatch
    #   (the dispatch donates the pool) — lets checkpoint() save this
    #   block's entry boundary while it is still in flight


def resolve_observables(model: CWCModel | ReactionSystem):
    """(system, obs_names, obs_idx) for a model — the single source of
    the observable-column derivation (engine and repro.api share it)."""
    if isinstance(model, CWCModel):
        system, meta = compile_model(model)
        names = list(meta["observables"]) or list(meta["species"])
        idx = [v for v in meta["observables"].values()] or [
            [i] for i in range(system.n_species)]
    else:
        system = model
        names = list(model.species_names)
        idx = [[i] for i in range(model.n_species)]
    return system, names, idx


class SimulationEngine:
    def __init__(self, model: CWCModel | ReactionSystem, cfg: SimConfig,
                 rates=None, mesh=None, observables: Optional[list] = None,
                 group_ids=None, record_trajectories: bool = False,
                 partitioning: Optional[Partitioning] = None,
                 sketch: Optional[SketchSpec] = None,
                 steering: Optional[Steering] = None,
                 _deprecated: bool = True):
        if _deprecated:
            warnings.warn(
                "constructing SimulationEngine directly is deprecated; "
                "use repro.api.simulate(Experiment(...))",
                DeprecationWarning, stacklevel=2)
        self.system, self.obs_names, self.obs_idx = resolve_observables(
            model)
        self.cfg = cfg
        # a bare mesh (the historically inert `mesh=` kwarg) now means
        # "shard the pool over the mesh's first axis" — see DESIGN.md's
        # migration note; pass `partitioning=` for explicit control
        if partitioning is None and mesh is not None:
            axis = mesh.axis_names[0]
            partitioning = Partitioning(n_shards=mesh.shape[axis],
                                        axis=axis)
        self.partitioning = partitioning
        n_shards = partitioning.n_shards if partitioning else 1
        if partitioning is not None:
            partitioning.validate(cfg.n_instances)
        self._stats_blocks = partitioning.blocks if partitioning else 1
        # per-instance rates (parameter sweep) or shared
        if rates is None:
            self.rates = np.broadcast_to(
                self.system.rates, (cfg.n_instances, self.system.n_reactions))
        else:
            self.rates = np.asarray(rates, np.float32)
            assert self.rates.shape == (cfg.n_instances,
                                        self.system.n_reactions)
        self.grid = np.linspace(cfg.t_end / cfg.n_windows, cfg.t_end,
                                cfg.n_windows)
        self.stream = StatsStream()
        self.scheduler = Scheduler(
            cfg.n_instances,
            min(cfg.n_lanes, cfg.n_instances // n_shards),
            policy=("static_rr" if cfg.schema == "i" else cfg.policy),
            n_shards=n_shards)
        # dense gather-form tensors are always built (the sparse exact
        # path still seeds its carried propensity vector with the dense
        # evaluation, and sparse tau keeps the dense delta matmuls);
        # the MAX_COEF ceiling only binds when the dense comb unroll
        # would actually be used
        self._tensors_base = system_tensors(self.system,
                                            require_dense=not cfg.sparse)
        self._sparse_tensors = (sparse_system_tensors(
            sparse_tables(self.system)) if cfg.sparse else None)
        self._window = 0
        # superstep pipeline (window_block > 1): windows DISPATCHED to
        # the device run ahead of windows COLLECTED (records emitted);
        # each in-flight block's record ring waits here until its
        # blocking pull — which the collector hides behind the next
        # block's device compute (run_block)
        self._dispatched = 0
        self._pending: collections.deque = collections.deque()
        # depth-K pipeline: resolved in-flight block budget (None while
        # pipeline_depth="auto" awaits its first-collect probe), the
        # probe's measurements, and per-ring accounting/telemetry
        self._depth: Optional[int] = (
            None if cfg.pipeline_depth == "auto"
            else int(cfg.pipeline_depth))
        self.depth_probe: Optional[dict] = None
        self.peak_inflight_blocks = 0
        # ring-snapshot checkpointing (off until a caller that intends
        # to checkpoint mid-run opts in): when enabled, every dispatch
        # first copies the pool so checkpoint() can save the oldest
        # in-flight block's entry boundary instead of flushing
        self._snap_enabled = False
        self.n_snapshot_saves = 0
        self.n_ckpt_flushes = 0
        # the most recent aligned-ENTRY snapshot of a block that ended
        # mid-grid (a max_windows cut): (w0, pool) — checkpoint() rolls
        # a mid-block save back to this boundary so the file always
        # restores under the run's own window_block
        self._aligned_snap: Optional[tuple] = None
        # opt-in export of the per-window blocked Welford PARTIAL
        # stacks (n, mean, m2) feeding each pooled record — the farm
        # worker's seam: a coordinator concatenates worker stacks in
        # global block order and re-runs the same merge_blocks +
        # finalize, reproducing the single-process records bitwise
        self._export_partials = False
        self._block_partials: list = []
        # grouped analogue (per-window (V, G, n_obs) masked partial
        # stacks): the reference grouped fold merges per-(block, group)
        # partials — zero partials included — so worker-local FINALIZED
        # rows are NOT bit-identical to it; the coordinator must embed
        # worker partial stacks into the global (V, G) layout and rerun
        # the same merge
        self._grouped_partials: list = []
        self._gp_fn = None  # lazily-jitted blocked_grouped_welford
        # farm worker seam: (v_total, v0, g_total, g0) — when set, the
        # grouped fold runs over the GLOBAL zero-extended (V, G) stack
        # (zero partials are exact terms in the Sigma-form merge), so a
        # worker's per-point stats — the steering policy's inputs —
        # carry the single-process reference bits even with one local
        # block, where merge_blocks would otherwise short-circuit
        self._stats_layout: Optional[tuple] = None
        # block-level wall attribution: (w0, n_win, dispatch_s,
        # collect_s) per collected unit — dispatch_s is enqueue wall
        # (async, excludes device compute), collect_s is blocking ring
        # pull + host-side reduce/emit (see Telemetry.block_walls)
        self.block_walls: list[tuple] = []
        # device-side predictive cost carry (in-scan regroup seam);
        # seeded lazily from scheduler._cost, invalidated whenever the
        # host rewrites cost out-of-band (restore, steering)
        self._cost_dev: Optional[jax.Array] = None
        # per-lane algorithm (the method seam): exact SSA or tau-leap —
        # the dispatch strategies consume `_lane_step` (unfused bodies)
        # and `_make_chunk_loop` (Pallas kernel bodies)
        if cfg.method == "tau_leap":
            from repro.core import tau_leap

            self._gi_tab = jnp.asarray(tau_leap.gi_tables(self.system))
            self._rmask = jnp.asarray(tau_leap.reactant_mask(self.system))
            # the sparse seam keeps tau-leap Match in gather form
            # (bitwise equal to the one-hot form, no MAX_COEF ceiling)
            self._lane_step = tau_leap.make_tau_step(
                self._gi_tab, self._rmask, cfg.tau_eps, cfg.tau_fallback,
                gather_max_c=(max(self.system.max_coef, 1)
                              if cfg.sparse else None))
        else:
            self._lane_step = ssa_step
        # schemas i/ii always buffer raw per-window samples; schema iii
        # only on explicit opt-in (it forfeits the memory bound)
        self._record_trajectories = record_trajectories
        self._samples: list = []
        self._peak_buffered = 0
        self.wall_times: list[float] = []
        # telemetry: device dispatches and blocking device->host pulls
        self.n_dispatches = 0
        self.n_host_syncs = 0
        # per-window method telemetry (from the same single record
        # pull): solver iterations and accepted tau-leaps — their
        # difference is the exact-fallback share
        self.window_steps: list[int] = []
        self.window_leaps: list[int] = []
        self._cum_steps = 0
        self._cum_leaps = 0
        # optional grouped (per-sweep-point) reduction
        self._group_ids = None
        self._group_ids_dev = None
        self._grouped_fn = None
        self._n_groups = 0
        self._grouped: list[reduction.Stats] = []
        # streaming sketches (DESIGN.md §3f): resolved bin geometry +
        # the per-window pulled WindowSketch list; the sharded dispatch
        # reads `_sketch` at build time (sketch counts ride its ring
        # with one psum), the fused/host paths compute eagerly from obs
        self._sketch_spec = sketch
        self._sketch = None
        if sketch is not None:
            x0 = np.asarray(self.system.x0, np.float64)
            obs0 = np.asarray(
                [x0[list(ii)].sum() for ii in self.obs_idx], np.float64)
            self._sketch = sketch.resolve(obs0)
        self._sketch_fn_cache = None
        self._sketches: list[WindowSketch] = []
        if group_ids is not None:
            self.set_groups(group_ids)
        # adaptive steering (DESIGN.md §3f): a between-block controller
        # consulted by run_block at superstep boundaries
        self._steer: Optional[SteeringPolicy] = None
        if steering is not None and steering.enabled:
            steering.validate()
            if cfg.host_loop:
                raise ValueError(
                    "steering is driven from the superstep collector; "
                    "the host_loop baseline has no block boundary to "
                    "steer at (use the fused or sharded strategy)")
            if steering.bimodality and sketch is None:
                raise ValueError(
                    "Steering.bimodality reads window histograms — "
                    "configure a SketchSpec as well")
            if steering.tau_switch and cfg.method != "tau_leap":
                raise ValueError(
                    "Steering.tau_switch only applies to "
                    "method='tau_leap' runs")
            if (isinstance(cfg.pipeline_depth, int)
                    and cfg.pipeline_depth > 1):
                raise ValueError(
                    "steering is incompatible with an explicit "
                    f"pipeline_depth={cfg.pipeline_depth}: decisions "
                    "must see block k before block k+1 dispatches "
                    "(lock-step). Use pipeline_depth=1 or 'auto' "
                    "(which resolves to 1 under steering)")
            # steered runs are lock-step BY CONSTRUCTION: resolve
            # "auto" to 1 here (no probe) so the forcing is visible in
            # pipeline_depth_effective rather than silent in run_block
            self._depth = 1
            self._steer = SteeringPolicy(
                steering, cfg.n_instances,
                n_points=(self._n_groups or 1),
                n_windows=cfg.n_windows,
                tau_leap=(cfg.method == "tau_leap"))
        # straggler watchdog: observes per-window wall clock on every
        # collect path; flagged windows surface in result telemetry
        self.watchdog = WindowWatchdog()
        # dispatch-path selection: one explicit strategy seam
        # (core/dispatch.py) — host loop / fused / sharded
        self._perm_cache: Optional[jax.Array] = None
        self._dispatch, self.mesh = select_dispatch(self, mesh)
        self._pool = self._dispatch.place(
            init_lanes(self.system, cfg.n_instances, cfg.seed))
        self._rates_dev = self._dispatch.place(jnp.asarray(self.rates))

    # -------------------------------------------------------- re-spec
    def set_rates(self, rates) -> None:
        """Install a per-instance (I, R) rate matrix (parameter sweep).
        Must happen before the first window runs."""
        assert self._window == 0, "rates must be set before running"
        rates = np.asarray(rates, np.float32)
        assert rates.shape == (self.cfg.n_instances, self.system.n_reactions)
        self.rates = rates
        self._rates_dev = self._dispatch.place(jnp.asarray(rates))

    def set_groups(self, group_ids) -> None:
        """Enable grouped reduction: group_ids (I,) maps each instance
        to a reduction group (e.g. its sweep point)."""
        ids = np.asarray(group_ids, np.int32)
        assert ids.shape == (self.cfg.n_instances,)
        self._group_ids = ids
        self._group_ids_dev = jnp.asarray(ids)
        self._n_groups = int(ids.max()) + 1
        self._sketch_fn_cache = None  # closes over the group map
        if self._stats_blocks == 1:
            # legacy single-fold form (bit-identical historical records)
            self._grouped_fn = jax.jit(partial(
                reduction.grouped_stats, n_groups=self._n_groups))
        else:
            # jit the per-block partials; fold the (V, G, n_obs) stack
            # eagerly — the same op sequence the sharded dispatch uses,
            # so grouped stats stay bitwise mesh-shape-independent
            stack_fn = jax.jit(partial(
                reduction.blocked_grouped_welford,
                n_groups=self._n_groups, n_blocks=self._stats_blocks))

            def grouped_fn(obs, gids):
                return reduction.finalize(
                    reduction.merge_blocks(stack_fn(obs, gids)))

            self._grouped_fn = grouped_fn
        if self._stats_layout is not None:
            # farm worker: embed the local (V_loc, G_loc) partial stack
            # into the global layout at (v0, g0), run the reference
            # Sigma-form fold (the zero rows force past merge_blocks'
            # V == 1 shortcut and contribute exact-zero terms), then
            # slice this shard's rows back out — bit-identical to the
            # single-process grouped stats the steering thresholds saw
            v_tot, v0, g_tot, g0 = self._stats_layout
            g_loc = self._n_groups
            layout_stack_fn = jax.jit(partial(
                reduction.blocked_grouped_welford,
                n_groups=g_loc, n_blocks=self._stats_blocks))

            def grouped_global_fn(obs, gids):
                local = layout_stack_fn(obs, gids)

                def embed(leaf):
                    full = jnp.zeros(
                        (v_tot, g_tot) + leaf.shape[2:], leaf.dtype)
                    return full.at[v0:v0 + leaf.shape[0],
                                   g0:g0 + g_loc].set(leaf)

                st = reduction.finalize(reduction.merge_blocks(
                    reduction.Welford(*(embed(l) for l in local))))
                return reduction.Stats(
                    *(l[g0:g0 + g_loc] for l in st))

            self._grouped_fn = grouped_global_fn

    def set_global_stats_layout(self, v_total: int, v0: int,
                                g_total: int, g0: int) -> None:
        """Farm worker seam: declare where this shard's stat blocks and
        groups sit in the GLOBAL (V, G) layout so grouped per-point
        stats are computed through the zero-extended reference fold
        (see set_groups). Must run before the first window."""
        assert self._window == 0, "layout must be set before running"
        self._stats_layout = (int(v_total), int(v0),
                              int(g_total), int(g0))
        if self._group_ids is not None:
            self.set_groups(self._group_ids)

    # ------------------------------------------------------------------
    def _make_chunk_loop(self):
        """Pallas chunk loop for the kernel paths, method-resolved and
        chunk-budget-bound: (pool, tensors4, horizon) -> FusedWindowOut.
        Built lazily so kernel modules only import when use_kernel."""
        from repro.kernels import ops

        cfg = self.cfg
        if cfg.method == "tau_leap":
            if cfg.sparse:
                return partial(ops.sparse_tau_window_chunk_loop,
                               gi=self._gi_tab, rmask=self._rmask,
                               eps=cfg.tau_eps, fallback=cfg.tau_fallback,
                               max_c=max(self.system.max_coef, 1),
                               chunk_steps=cfg.kernel_chunk_steps,
                               max_chunks=cfg.kernel_max_chunks)
            return partial(ops.tau_window_chunk_loop,
                           gi=self._gi_tab, rmask=self._rmask,
                           eps=cfg.tau_eps, fallback=cfg.tau_fallback,
                           chunk_steps=cfg.kernel_chunk_steps,
                           max_chunks=cfg.kernel_max_chunks)
        if cfg.sparse:
            return partial(ops.sparse_window_chunk_loop,
                           sp=self._sparse_tensors,
                           chunk_steps=cfg.kernel_chunk_steps,
                           max_chunks=cfg.kernel_max_chunks)
        return partial(ops.window_chunk_loop,
                       chunk_steps=cfg.kernel_chunk_steps,
                       max_chunks=cfg.kernel_max_chunks)

    # ------------------------------------------------------------------
    def _make_advance_fn(self):
        """Per-lane-slice advance for the UNFUSED bodies (the encoding
        × method seam in one place): `advance(lane_slice, rates,
        horizon) -> LaneState`. Dense exact/tau iterate `_lane_step`;
        sparse exact runs the dependency-graph step with its carried
        propensity vector; sparse tau is `_lane_step` built with the
        gather-form Match. All bitwise identical to dense."""
        cfg = self.cfg
        idx_t, coef_t, delta_t, _ = self._tensors_base
        if cfg.sparse and cfg.method != "tau_leap":
            return make_advance_fn(None, None, cfg.max_steps_per_window,
                                   sparse=self._sparse_tensors)
        return make_advance_fn(self._lane_step,
                               (idx_t, coef_t, delta_t),
                               cfg.max_steps_per_window)

    # ------------------------------------------------------------------
    def _sketch_eval(self):
        """Jitted eager sketch for the paths whose dispatch does not
        produce one device-side (fused/host loop): obs (I, n_obs) ->
        (hist, rare). Same ops as the sharded in-body sketch, and
        integer counts, so the results are bitwise identical."""
        if self._sketch_fn_cache is None:
            sk = self._sketch
            gids = self._group_ids_dev
            n_g = self._n_groups if gids is not None else 1
            thr = sk.thresholds if sk.n_thr else None

            def fn(obs):
                g = (gids if gids is not None
                     else jnp.zeros((obs.shape[0],), jnp.int32))
                return window_sketch(obs, g, n_g, sk.lo, sk.width,
                                     sk.n_bins, thr)

            self._sketch_fn_cache = jax.jit(fn)
        return self._sketch_fn_cache

    # ------------------------------------------------------------------
    def _permutation(self) -> jax.Array:
        """Concatenated, padded scheduler groups as a device index map."""
        if self.scheduler.policy != "predictive" and \
                self._perm_cache is not None:
            return self._perm_cache
        perm = jnp.asarray(
            np.concatenate(self.scheduler.groups()).astype(np.int32))
        if self.scheduler.policy != "predictive":
            self._perm_cache = perm
        return perm

    # ------------------------------------------------------------------
    @property
    def pipeline_depth(self) -> int:
        """Resolved in-flight block budget. For pipeline_depth="auto"
        this is 1 until the first collected block's probe resolves it
        (resolve_auto_depth)."""
        return self._depth if self._depth is not None else 1

    @property
    def pipeline_depth_effective(self) -> int:
        """The depth the collector actually runs at. Steering forces
        lock-step (depth 1) regardless of the requested depth; this is
        the visible record of that forcing (Telemetry,
        recovery_report)."""
        return 1 if self._steer is not None else self.pipeline_depth

    def enable_block_partials(self) -> None:
        """Opt in to exporting the per-window blocked Welford PARTIALS
        (n, mean, m2 per stat block) alongside each pooled record. The
        multi-process farm worker needs them: its local records cover
        only its own instance rows, so the coordinator re-merges the
        partial stacks of all workers (in global block order) with the
        same merge_blocks + finalize fold to reproduce the
        single-process records bitwise. They ride the existing combined
        pull and the engine checkpoint (bp_* keys)."""
        self._export_partials = True

    def _grouped_partials_fn(self):
        """Jitted per-(block, group) masked partial stack over THIS
        engine's instance rows — exported so the farm coordinator can
        embed it into the global (V, G) partial layout and rerun the
        reference grouped merge bitwise."""
        if self._gp_fn is None:
            self._gp_fn = jax.jit(partial(
                reduction.blocked_grouped_welford,
                n_groups=self._n_groups,
                n_blocks=self._stats_blocks))
        return self._gp_fn

    def enable_snapshots(self) -> None:
        """Opt in to ring-snapshot checkpointing: every subsequent
        block dispatch first copies the pool (the dispatch donates its
        operand), so checkpoint() can save the oldest in-flight
        block's entry boundary — which IS the collected frontier —
        without flushing the pipeline. Costs one pool copy per
        dispatch; callers that never checkpoint mid-run leave it off."""
        self._snap_enabled = True

    def _cost_device(self) -> jax.Array:
        """Device-resident predictive cost carry for the in-scan
        regroup (float32, sharded like the pool). The host float64 EMA
        (scheduler._cost, updated at collect time) stays the canonical
        copy for checkpoints and parity; the device carry only decides
        grouping, which is execution packaging — any divergence in the
        low bits can reorder groups but never change a record."""
        if self._cost_dev is None:
            self._cost_dev = self._dispatch.place(
                jnp.asarray(self.scheduler._cost, jnp.float32))
        return self._cost_dev

    # ------------------------------------------------------------------
    def run_window(self) -> StatsRecord:
        """Advance every instance to the next grid point. All three
        schemas share this window loop — they differ in grouping policy
        (schema i: static_rr) and in what is buffered (i/ii: raw
        samples for post-hoc use; iii: nothing beyond the running
        accumulator). HOW the pool advances (host loop / fused /
        sharded) is the dispatch strategy's concern."""
        if self._pending:  # mixing with supersteps: drain them first
            self.flush()
        cfg = self.cfg
        horizon = float(self.grid[self._window])
        t0 = time.perf_counter()
        res = self._dispatch.advance(horizon)
        if self.scheduler.policy == "predictive":
            steps_delta = res.steps_delta
            if steps_delta is not None and not isinstance(
                    steps_delta, np.ndarray):
                steps_delta = np.asarray(steps_delta)
                self.n_host_syncs += 1
            self.scheduler.record_costs(
                np.arange(cfg.n_instances), steps_delta)
        self.wall_times.append(time.perf_counter() - t0)
        self.watchdog.observe(self._window, self.wall_times[-1])
        obs = res.obs
        stats = (res.stats if res.stats is not None
                 else reduction.blocked_stats(obs, self._stats_blocks))
        sk_dev = None
        if self._sketch is not None:
            sk_dev = (res.sketch if res.sketch is not None
                      else self._sketch_eval()(obs))
        bw_dev = (reduction.blocked_welford(obs, self._stats_blocks)
                  if self._export_partials else None)
        gp_dev = (self._grouped_partials_fn()(obs, self._group_ids_dev)
                  if self._export_partials and self._group_ids is not None
                  else None)
        # ONE combined blocking pull per window, AFTER the timer (so
        # window_wall_times stays an async-dispatch measure on every
        # path): record stats + per-method step/leap telemetry + (on
        # the kernel path) the truncation scalar — the flag used to be
        # its own pull, costing the kernel path a second host sync per
        # window (BENCH_PR3 `host_syncs_per_window: 2.0`)
        t_pull = time.perf_counter()
        pulled = jax.device_get(dict(
            mean=stats.mean, var=stats.var, ci90=stats.ci90, n=stats.n,
            steps=self._pool.steps.sum(), leaps=self._pool.leaps.sum(),
            **({} if res.truncated is None
               else {"truncated": res.truncated}),
            **({} if sk_dev is None else {"sk_hist": sk_dev[0]}),
            **({} if sk_dev is None or sk_dev[1] is None
               else {"sk_rare": sk_dev[1]}),
            **({} if bw_dev is None
               else {"bw_n": bw_dev.n, "bw_mean": bw_dev.mean,
                     "bw_m2": bw_dev.m2}),
            **({} if gp_dev is None
               else {"gp_n": gp_dev.n, "gp_mean": gp_dev.mean,
                     "gp_m2": gp_dev.m2})))
        self.n_host_syncs += 1
        if bool(pulled.get("truncated", False)):
            # a silently partial window must never become a record
            self._raise_truncated(self._window, horizon)
        if cfg.guards:
            self._guard_stats(self._window, pulled["mean"], pulled["var"])
        # the device sums are int32 and wrap once pool-wide cumulative
        # counts pass 2^31; tracking residues mod 2^32 and taking
        # modular deltas keeps every per-window value exact (a single
        # window's work is far below 2^31)
        steps_cum = int(pulled["steps"]) & 0xFFFFFFFF
        leaps_cum = int(pulled["leaps"]) & 0xFFFFFFFF
        self.window_steps.append(
            (steps_cum - self._cum_steps) & 0xFFFFFFFF)
        self.window_leaps.append(
            (leaps_cum - self._cum_leaps) & 0xFFFFFFFF)
        self._cum_steps, self._cum_leaps = steps_cum, leaps_cum
        if sk_dev is not None:
            self._sketches.append(WindowSketch(
                hist=np.asarray(pulled["sk_hist"]),
                rare=(np.asarray(pulled["sk_rare"])
                      if "sk_rare" in pulled else None)))
        if bw_dev is not None:
            self._block_partials.append(reduction.Welford(
                n=np.asarray(pulled["bw_n"]),
                mean=np.asarray(pulled["bw_mean"]),
                m2=np.asarray(pulled["bw_m2"])))
        if gp_dev is not None:
            self._grouped_partials.append(reduction.Welford(
                n=np.asarray(pulled["gp_n"]),
                mean=np.asarray(pulled["gp_mean"]),
                m2=np.asarray(pulled["gp_m2"])))
        if cfg.schema in ("i", "ii") or self._record_trajectories:
            self._samples.append(np.asarray(obs))
            self.n_host_syncs += 1
            self._peak_buffered = max(
                self._peak_buffered,
                sum(s.nbytes for s in self._samples))
        else:  # schema iii: on-line reduction, window dropped immediately
            self._peak_buffered = max(self._peak_buffered, obs.nbytes)
        if self._grouped_fn is not None:
            g = (res.grouped if res.grouped is not None
                 else self._grouped_fn(obs, self._group_ids_dev))
            self._grouped.append(
                reduction.Stats(*(np.asarray(v) for v in g)))
            self.n_host_syncs += 1
        rec = StatsRecord(
            t=horizon, window=self._window,
            mean=pulled["mean"], var=pulled["var"],
            ci90=pulled["ci90"], n=float(pulled["n"].max()))
        self.stream.emit(rec)
        # window-level walls ARE measurable here: one block_walls row
        # per window — dispatch = async enqueue wall, collect =
        # blocking pull + host emit (Telemetry.block_walls)
        self.block_walls.append(
            (self._window, 1, self.wall_times[-1],
             time.perf_counter() - t_pull))
        self._window += 1
        self._dispatched = self._window
        return rec

    # -------------------------------------------------- supersteps
    def _raise_truncated(self, window: int, horizon: float):
        """The one FusedWindowTruncated raise for both the per-window
        and the superstep collect path. Everything still in flight was
        dispatched from the truncated (partial-window) pool, so the
        pipeline is dropped first — no later accessor's flush() may
        re-raise from a getter or turn the invalid state into records —
        and the dispatch cursor rewinds to the collected frontier so a
        caller that catches the error and drives on re-runs from the
        failed window instead of silently skipping the dropped ones
        (the per-window path has this property for free)."""
        from repro.kernels.ops import FusedWindowTruncated

        self._pending.clear()
        self._dispatched = self._window
        self._cost_dev = None  # advanced past the dropped blocks
        cfg = self.cfg
        raise FusedWindowTruncated(
            f"window {window} (horizon {horizon:g}) exhausted "
            f"kernel_max_chunks={cfg.kernel_max_chunks} x "
            f"kernel_chunk_steps={cfg.kernel_chunk_steps} events with "
            "live lanes still below the horizon; raise those limits "
            "or use more windows")

    def _raise_invariant(self, window: int, check: str, detail: str):
        """Typed invariant-guard raise, shared by the per-window and
        superstep collect paths. Same in-flight hygiene as
        _raise_truncated: the pipeline was dispatched from the
        now-untrusted pool, so it is dropped, and the dispatch cursor
        rewinds to the collected frontier — a supervisor that catches
        the error restores a checkpoint and replays from there."""
        self._pending.clear()
        self._dispatched = self._window
        self._cost_dev = None  # advanced past the dropped blocks
        raise InvariantViolation(
            f"engine invariant {check!r} violated at window {window}: "
            f"{detail} — the pool state is untrusted; recover from the "
            "last checkpoint (set SimConfig.guards=False to disable)",
            window=window, check=check)

    def _guard_stats(self, window: int, mean, var) -> None:
        """Cheap host-side invariant checks on the per-window moments
        the collect path already pulled (no extra device syncs).
        Observables are sums of species counts, so a finite simulation
        can only produce finite, non-negative means; NaN/inf means a
        poisoned pool (propensity overflow, bad rates, fault
        injection), a negative mean means population underflow."""
        mean = np.asarray(mean)
        var = np.asarray(var)
        if not (np.isfinite(mean).all() and np.isfinite(var).all()):
            self._raise_invariant(
                window, "non_finite_stats",
                "window statistics contain NaN/inf (non-finite "
                "propensities or poisoned lane state)")
        if (mean < 0.0).any():
            self._raise_invariant(
                window, "negative_population",
                f"window mean dipped below zero (min {mean.min():g}); "
                "species counts can never be negative")

    def _next_block_windows(self, limit: int) -> int:
        """Size of the next superstep: realigned to the absolute
        window_block grid (so an in-process mid-grid start — a
        max_windows cut, or a restore from a window_block boundary —
        converges back onto block boundaries; restore() itself rejects
        MID-block checkpoints), capped by the grid end and the caller's
        dispatch limit."""
        w0 = self._dispatched
        wb = self.cfg.window_block
        return min(wb - (w0 % wb), len(self.grid) - w0, limit - w0)

    def _dispatch_block(self, limit: int) -> None:
        """Launch the next W windows as ONE device dispatch and queue
        the resulting record ring for a later (pipelined) pull. The
        per-window statistics folds run here EAGERLY on device arrays —
        the same op sequence the per-window path uses — and are queued
        with the ring; `copy_to_host_async` starts their device->host
        movement so the blocking `device_get` in _collect_block mostly
        finds the bytes already on host."""
        cfg = self.cfg
        w0 = self._dispatched
        n_win = self._next_block_windows(limit)
        horizons = self.grid[w0:w0 + n_win]
        snapshot = None
        if self._snap_enabled:
            # the dispatch donates the pool, so the copy of this
            # block's ENTRY boundary must happen before it; outside
            # the dispatch timer — it is checkpoint overhead, not
            # enqueue wall
            snapshot = jax.tree_util.tree_map(jnp.copy, self._pool)
        t0 = time.perf_counter()
        res = self._dispatch.advance_block(horizons)
        stats = (res.stats if res.stats is not None else [
            reduction.blocked_stats(res.obs[w], self._stats_blocks)
            for w in range(n_win)])
        pull = dict(stats=stats, steps=res.steps_end,
                    leaps=res.leaps_end)
        if self._grouped_fn is not None:
            pull["grouped"] = (res.grouped if res.grouped is not None
                               else [self._grouped_fn(
                                   res.obs[w], self._group_ids_dev)
                                   for w in range(n_win)])
        if self._sketch is not None:
            if res.sketch is not None:  # sharded: rode the ring (psum)
                pull["sk_hist"] = res.sketch[0]
                if res.sketch[1] is not None:
                    pull["sk_rare"] = res.sketch[1]
            else:  # fused: eager per-window sketch from the obs ring
                per = [self._sketch_eval()(res.obs[w])
                       for w in range(n_win)]
                pull["sk_hist"] = [p[0] for p in per]
                if per and per[0][1] is not None:
                    pull["sk_rare"] = [p[1] for p in per]
        if self._export_partials:
            bw = [reduction.blocked_welford(res.obs[w], self._stats_blocks)
                  for w in range(n_win)]
            pull["bw_n"] = [b.n for b in bw]
            pull["bw_mean"] = [b.mean for b in bw]
            pull["bw_m2"] = [b.m2 for b in bw]
            if self._group_ids is not None:
                gp = [self._grouped_partials_fn()(
                    res.obs[w], self._group_ids_dev)
                    for w in range(n_win)]
                pull["gp_n"] = [g.n for g in gp]
                pull["gp_mean"] = [g.mean for g in gp]
                pull["gp_m2"] = [g.m2 for g in gp]
        if res.truncated is not None:
            pull["truncated"] = res.truncated
        if cfg.schema in ("i", "ii") or self._record_trajectories:
            pull["obs"] = res.obs
        if res.steps_delta is not None:
            pull["steps_delta"] = res.steps_delta
        dispatch_wall = time.perf_counter() - t0
        for leaf in jax.tree_util.tree_leaves(pull):
            copy = getattr(leaf, "copy_to_host_async", None)
            if callable(copy):
                copy()
        # per-ring memory accounting: EVERY queued ring (and snapshot)
        # is live simultaneously at depth K, so peak_buffered must see
        # their sum, not one block's footprint
        ring_bytes = sum(
            getattr(leaf, "nbytes", 0)
            for leaf in jax.tree_util.tree_leaves((pull, snapshot)))
        self._pending.append(_InFlight(
            w0, n_win, pull, dispatch_wall,
            res.obs.nbytes // n_win, ring_bytes, snapshot))
        self._dispatched = w0 + n_win
        self.peak_inflight_blocks = max(
            self.peak_inflight_blocks, len(self._pending))
        self._peak_buffered = max(
            self._peak_buffered,
            sum(e.ring_bytes for e in self._pending)
            + sum(s.nbytes for s in self._samples))

    def _collect_block(self) -> None:
        """Blocking pull + host-side reduction of the OLDEST in-flight
        superstep: ONE combined device_get for the whole ring (stats,
        telemetry, truncation, optional samples/grouped), then the
        exact per-window record emission the per-window path performs."""
        cfg = self.cfg
        ent = self._pending.popleft()
        w0, n_win, pull = ent.w0, ent.n_win, ent.pull
        dispatch_wall, obs_row_bytes = ent.dispatch_wall, ent.obs_row_bytes
        if (ent.snapshot is not None and cfg.window_block > 1
                and w0 % cfg.window_block == 0
                and (w0 + n_win) % cfg.window_block):
            # this block was cut short (a max_windows dispatch limit):
            # keep its aligned ENTRY snapshot so a later checkpoint()
            # at the mid-block frontier can serve a boundary-aligned
            # save instead of a file restore() would reject
            self._aligned_snap = (w0, ent.snapshot)
        t0 = time.perf_counter()
        pulled = jax.device_get(pull)
        self.n_host_syncs += 1
        pull_s = time.perf_counter() - t0
        wall = dispatch_wall + pull_s
        # per-window walls are NOT measurable under block dispatch (one
        # enqueue + one ring pull covers the whole block): feed the
        # watchdog ONE block-level sample at per-window scale instead
        # of n_win identical slices that would poison its median
        self.watchdog.observe_block(w0, n_win, wall)
        trunc = pulled.get("truncated")
        if cfg.guards and (len(pulled["stats"]) != n_win
                           or len(pulled["steps"]) != n_win):
            # ring/record disagreement: the device ring and the queued
            # block descriptor no longer agree on the window count
            self._raise_invariant(
                w0, "ring_record_mismatch",
                f"superstep ring holds {len(pulled['stats'])} stat "
                f"rows / {len(pulled['steps'])} telemetry rows for a "
                f"{n_win}-window block at window {w0}")
        for w in range(n_win):
            self.wall_times.append(wall / n_win)
            if trunc is not None and trunc[w]:
                self._raise_truncated(w0 + w, float(self.grid[w0 + w]))
            if cfg.guards:
                s_w = pulled["stats"][w]
                self._guard_stats(w0 + w, s_w.mean, s_w.var)
            steps_cum = int(pulled["steps"][w]) & 0xFFFFFFFF
            leaps_cum = int(pulled["leaps"][w]) & 0xFFFFFFFF
            self.window_steps.append(
                (steps_cum - self._cum_steps) & 0xFFFFFFFF)
            self.window_leaps.append(
                (leaps_cum - self._cum_leaps) & 0xFFFFFFFF)
            self._cum_steps, self._cum_leaps = steps_cum, leaps_cum
            if "obs" in pulled:
                self._samples.append(np.asarray(pulled["obs"][w]))
                self._peak_buffered = max(
                    self._peak_buffered,
                    sum(s.nbytes for s in self._samples))
            else:
                self._peak_buffered = max(self._peak_buffered,
                                          obs_row_bytes)
            if "grouped" in pulled:
                self._grouped.append(reduction.Stats(
                    *(np.asarray(v) for v in pulled["grouped"][w])))
            if "sk_hist" in pulled:
                self._sketches.append(WindowSketch(
                    hist=np.asarray(pulled["sk_hist"][w]),
                    rare=(np.asarray(pulled["sk_rare"][w])
                          if "sk_rare" in pulled else None)))
            if "bw_n" in pulled:
                self._block_partials.append(reduction.Welford(
                    n=np.asarray(pulled["bw_n"][w]),
                    mean=np.asarray(pulled["bw_mean"][w]),
                    m2=np.asarray(pulled["bw_m2"][w])))
            if "gp_n" in pulled:
                self._grouped_partials.append(reduction.Welford(
                    n=np.asarray(pulled["gp_n"][w]),
                    mean=np.asarray(pulled["gp_mean"][w]),
                    m2=np.asarray(pulled["gp_m2"][w])))
            if "steps_delta" in pulled:
                # per-window EMA updates in window order — the cost
                # state at every block boundary matches the per-window
                # path's; regrouping itself waits for the next block
                self.scheduler.record_costs(
                    np.arange(cfg.n_instances),
                    np.asarray(pulled["steps_delta"][w]))
            s = pulled["stats"][w]
            rec = StatsRecord(
                t=float(self.grid[w0 + w]), window=w0 + w,
                mean=s.mean, var=s.var, ci90=s.ci90,
                n=float(s.n.max()))
            self.stream.emit(rec)
            self._window += 1
        host_s = time.perf_counter() - t0 - pull_s
        self.block_walls.append((w0, n_win, dispatch_wall,
                                 pull_s + host_s))
        if self._depth is None:
            # pipeline_depth="auto": the first collect ran at depth 1
            # (cold pipeline), so pull_s approximates one block's
            # remaining device+transfer time and host_s the collector
            # work to hide behind it
            self._depth = resolve_auto_depth(pull_s, host_s)
            self.depth_probe = dict(
                dispatch_s=dispatch_wall, pull_s=pull_s, host_s=host_s,
                collect_dispatch_ratio=(
                    (pull_s + host_s) / max(dispatch_wall, 1e-9)),
                depth=self._depth)

    def run_block(self, dispatch_limit: Optional[int] = None,
                  pipeline: bool = True) -> int:
        """One turn of the pipelined superstep loop (window_block > 1):
        dispatch the next window block if any remains below
        `dispatch_limit` (an absolute window index), then collect the
        oldest in-flight block once more than `pipeline_depth` blocks
        are queued behind it — or once dispatching is done — so
        host-side reduction and sinks for block k run while the device
        simulates blocks k+1..k+K. Depth only changes WHEN rings are
        pulled, never what was computed: records are bitwise identical
        for any depth. With `pipeline=False` the freshly dispatched
        block is collected immediately (no dispatch-ahead) — the
        strict lock-step mode steering relies on. Callers that
        checkpoint per block no longer need it: enable_snapshots() +
        checkpoint() saves the collected frontier while blocks stay in
        flight. Returns the number of windows collected this call.

        With steering active the pipeline is forced off: the policy's
        decision point must see block k's records BEFORE block k+1 is
        dispatched (a dispatch-ahead block would run on pre-decision
        state), and the decision is applied here, at the collected
        boundary."""
        if self._steer is not None:
            pipeline = False
        limit = len(self.grid)
        if dispatch_limit is not None:
            limit = min(limit, dispatch_limit)
        if self._dispatched < limit:
            self._dispatch_block(limit)
        before = self._window
        depth = self.pipeline_depth  # "auto" acts as 1 until resolved
        if self._pending and (not pipeline
                              or len(self._pending) > depth
                              or self._dispatched >= limit):
            self._collect_block()
        collected = self._window - before
        if (self._steer is not None and collected and not self._pending
                and self._dispatched == self._window
                and self._window < len(self.grid)):
            self._steer_boundary()
        return collected

    def flush(self) -> None:
        """Collect every in-flight superstep so the emitted records
        catch up with the dispatched pool state (checkpoint() forces
        this — saves always land on a window boundary)."""
        while self._pending:
            self._collect_block()

    # --------------------------------------------------------- steering
    def _steer_boundary(self) -> None:
        """One decision point: hand the policy the freshest per-point
        stats, the latest window sketch, and the exact per-lane
        step/leap counters, then apply whatever it decides. Every input
        is bitwise path-invariant, so the decision sequence is too."""
        pulled = jax.device_get(dict(steps=self._pool.steps,
                                     leaps=self._pool.leaps))
        self.n_host_syncs += 1
        # int32 device counters wrap at 2^31; keep the unsigned residue
        # so the policy's deltas stay exact mod 2^32
        steps = np.asarray(pulled["steps"]).astype(np.int64) & 0xFFFFFFFF
        leaps = np.asarray(pulled["leaps"]).astype(np.int64) & 0xFFFFFFFF
        if self._grouped:
            g = self._grouped[-1]
            point_stats = {"mean": np.asarray(g.mean),
                           "ci90": np.asarray(g.ci90)}
        elif self.stream.records():
            r = self.stream.records()[-1]
            point_stats = {"mean": np.asarray(r.mean),
                           "ci90": np.asarray(r.ci90)}
        else:
            point_stats = None
        hist = self._sketches[-1].hist if self._sketches else None
        gids = (self._group_ids if self._group_ids is not None
                else np.zeros(self.cfg.n_instances, np.int32))
        actions = self._steer.decide(self._window, point_stats, hist,
                                     gids, steps, leaps)
        if actions.any:
            self._apply_steering(actions)

    def _apply_steering(self, a: SteeringActions) -> None:
        """Apply a decision to the device pool. Pull-edit-replace: the
        pool is tiny next to a window's compute and decision points are
        rare, so one gather + one re-place (resharding under the
        sharded strategy) beats a bespoke jitted scatter here."""
        arrs = {f: np.array(getattr(self._pool, f))  # writable copies
                for f in LaneState._fields}
        self.n_host_syncs += 1
        if np.asarray(a.stop_lanes).any():
            arrs["dead"] = arrs["dead"] | np.asarray(a.stop_lanes)
        moves = np.asarray(a.moves)
        if moves.size:
            dst, src = moves[:, 0], moves[:, 1]
            # trajectory splitting: clone the donor's state, keep the
            # moved lane's OWN RNG stream (key/ctr) — it diverges from
            # the donor immediately, an extra replica from here on
            for f in ("x", "t", "dead"):
                arrs[f][dst] = arrs[f][src]
            self.rates = np.array(self.rates)
            self.rates[dst] = self.rates[src]
            self._rates_dev = self._dispatch.place(
                jnp.asarray(self.rates))
            self.scheduler._cost[dst] = self.scheduler._cost[src]
            self._cost_dev = None  # host rewrote cost out-of-band
        if a.no_leap is not None:
            arrs["no_leap"] = np.asarray(a.no_leap, bool)
        self._pool = self._dispatch.place(LaneState(
            **{f: jnp.asarray(v) for f, v in arrs.items()}))
        if a.new_group_ids is not None:
            # every point keeps >= 1 lane, so n_groups is unchanged and
            # the sharded dispatch cache stays valid; only the operand
            # content changes
            self.set_groups(np.asarray(a.new_group_ids))
            self._group_ids_dev = self._dispatch.place(
                self._group_ids_dev)

    def sketches(self) -> list[WindowSketch]:
        """Per-window WindowSketch list (empty without a SketchSpec)."""
        self.flush()
        return list(self._sketches)

    def steering_report(self) -> Optional[dict]:
        """The policy's savings + decision summary (None when no
        steering is active)."""
        if self._steer is None:
            return None
        self.flush()
        return self._steer.report()

    def _observe(self) -> jax.Array:
        cols = [self._pool.x[:, idx].sum(axis=1) for idx in self.obs_idx]
        return jnp.stack(cols, axis=1)

    def run(self) -> list[StatsRecord]:
        # steered runs go through the block loop even at window_block=1
        # (steering decisions live at the collected block boundary)
        if self.cfg.window_block == 1 and self._steer is None:
            while self._window < len(self.grid):
                self.run_window()
        else:
            while self._window < len(self.grid):
                self.run_block()
        return self.stream.records()

    # ------------------------------------------------------------ fault
    def checkpoint(self, path: str) -> None:
        """One-file snapshot: pool + scheduler + emitted records (+ any
        buffered samples/grouped stats). Cost is O(pool + buffered
        state): constant per call under schema iii (nothing is
        buffered), but grows with the sample buffer under schemas
        i/ii — prefer schema iii for per-window checkpointing.

        Gather-on-save: `np.asarray` on a sharded pool gathers the
        global arrays, so the file never depends on the mesh shape —
        any engine (any shard count) can restore it.

        Supersteps: with ring snapshots enabled (enable_snapshots),
        the oldest in-flight block's ENTRY snapshot is the pool at the
        collected frontier — exactly the boundary every already-emitted
        record agrees on — so the save happens WITHOUT flushing the
        pipeline and later blocks keep computing underneath it.
        Without snapshots (or with nothing in flight) saving flushes
        first, as before: every in-flight block is collected so the
        saved pool and the saved records agree on one boundary.

        A `max_windows` cut landing MID-block (frontier not on a
        window_block boundary) rolls the save back to the cut block's
        aligned ENTRY snapshot (kept by _collect_block): the file then
        sits on a block boundary and always restores under the run's
        own window_block, and the save still never flushes
        (ckpt_flushes stays 0 for mid-block cuts too). Histories are
        truncated to the rolled-back window; resume re-runs the tail."""
        p = None
        win = self._window
        if self._pending:
            snap = self._pending[0].snapshot
            if snap is not None:
                # invariant: blocks collect in order, so the oldest
                # pending block's first window IS self._window
                assert self._pending[0].w0 == self._window
                p = snap
                self.n_snapshot_saves += 1
            else:
                self.n_ckpt_flushes += 1
        if p is None:
            self.flush()
            p = self._pool
            win = self._window
            wb = self.cfg.window_block
            if wb > 1 and win % wb and win != len(self.grid):
                aligned = getattr(self, "_aligned_snap", None)
                if aligned is not None and aligned[0] == win - win % wb:
                    win, p = aligned
                    self.n_snapshot_saves += 1
        extra = {}
        recs = [r for r in self.stream.records() if r.window < win]
        if recs:
            extra = dict(
                rec_t=np.asarray([r.t for r in recs], np.float64),
                rec_window=np.asarray([r.window for r in recs], np.int64),
                rec_mean=np.stack([r.mean for r in recs]),
                rec_var=np.stack([r.var for r in recs]),
                rec_ci90=np.stack([r.ci90 for r in recs]),
                rec_n=np.asarray([r.n for r in recs], np.float64))
        if self._samples[:win]:
            extra["samples"] = np.stack(self._samples[:win], axis=1)
        if self._grouped[:win]:
            for name in ("n", "mean", "var", "ci90"):
                extra[f"grouped_{name}"] = np.stack(
                    [getattr(g, name) for g in self._grouped[:win]])
        if self._sketches[:win]:
            extra["sketch_hist"] = np.stack(
                [s.hist for s in self._sketches[:win]])
            if self._sketches[0].rare is not None:
                extra["sketch_rare"] = np.stack(
                    [s.rare for s in self._sketches[:win]])
        if self._block_partials[:win]:
            for name in ("n", "mean", "m2"):
                extra[f"bp_{name}"] = np.stack(
                    [getattr(b, name)
                     for b in self._block_partials[:win]])
        if self._grouped_partials[:win]:
            for name in ("n", "mean", "m2"):
                extra[f"gpp_{name}"] = np.stack(
                    [getattr(b, name)
                     for b in self._grouped_partials[:win]])
        if self._group_ids is not None:
            # steering reallocation rewrites the lane->point map, so it
            # is run state, not just construction input
            extra["group_ids"] = self._group_ids
        if self._steer is not None:
            for k, v in self._steer.state_dict().items():
                extra[f"steer_{k}"] = v
        # atomic + checksummed (ckpt.store.save_atomic): a crash
        # mid-save never clobbers the previous snapshot, and restore
        # detects truncation/corruption instead of loading garbage
        ckpt_store.save_atomic(path, dict(
            x=np.asarray(p.x), t=np.asarray(p.t),
            key=np.asarray(p.key), ctr=np.asarray(p.ctr),
            ctr_hi=np.asarray(p.ctr_hi),
            steps=np.asarray(p.steps), leaps=np.asarray(p.leaps),
            dead=np.asarray(p.dead), no_leap=np.asarray(p.no_leap),
            window=win,
            cost=self.scheduler._cost, rates=self.rates, **extra))

    def restore(self, path: str) -> None:
        # integrity-checked load (ckpt.store.verify): truncated or
        # garbage files raise a typed CheckpointCorrupt naming the path
        # and the failure instead of surfacing a raw numpy/KeyError;
        # pre-hardening magic-less snapshots still load
        z = ckpt_store.verify(
            path, required=("x", "t", "key", "steps", "dead",
                            "window", "cost"))
        # supersteps advance window_block windows per dispatch, so a
        # resume must start on a block boundary of THIS engine's grid;
        # a checkpoint cut mid-block (e.g. by a max_windows stop under
        # a different window_block) is rejected up front, before any
        # state is touched
        saved_window = int(z["window"])
        wb = self.cfg.window_block
        if wb > 1 and saved_window % wb and saved_window != len(self.grid):
            raise ValueError(
                f"checkpoint at window {saved_window} is mid-block for "
                f"window_block={wb}: supersteps advance {wb} windows "
                "per dispatch, so resume needs a checkpoint on a "
                "window_block boundary — resume with window_block=1 "
                f"(or a divisor of {saved_window}), or re-save the "
                "checkpoint at a multiple of window_block")
        self._pending.clear()  # in-flight rings predate the restore
        self._aligned_snap = None
        self._cost_dev = None  # reseed the in-scan carry from `cost`
        # reshard-on-restore: checkpoints hold the gathered global pool
        # (mesh-shape-agnostic); the current dispatch re-places it on
        # whatever mesh THIS engine runs on
        # pre-counter-RNG checkpoints carry no `ctr`: restart those
        # streams at draw 0 (still exact SSA by memorylessness, but not
        # bitwise vs an uninterrupted pre-upgrade run); pre-widening
        # checkpoints carry no `ctr_hi`/`leaps`: restore with the high
        # word (and leap count) 0 — bitwise, since every stream below
        # 2^32 draws has hi = 0 by construction
        n = z["t"].shape[0]
        ctr = z["ctr"] if "ctr" in z else np.zeros((n,), np.uint32)
        ctr_hi = z["ctr_hi"] if "ctr_hi" in z else np.zeros((n,), np.uint32)
        leaps = z["leaps"] if "leaps" in z else np.zeros((n,), np.int32)
        # pre-steering checkpoints carry no `no_leap`: no lane was
        # pinned, so all-False restores bitwise
        no_leap = z["no_leap"] if "no_leap" in z else np.zeros((n,), bool)
        self._pool = self._dispatch.place(LaneState(
            x=jnp.asarray(z["x"]), t=jnp.asarray(z["t"]),
            key=jnp.asarray(z["key"]), ctr=jnp.asarray(ctr),
            ctr_hi=jnp.asarray(ctr_hi),
            steps=jnp.asarray(z["steps"]), leaps=jnp.asarray(leaps),
            dead=jnp.asarray(z["dead"]),
            no_leap=jnp.asarray(no_leap, bool)))
        self._window = saved_window
        self._dispatched = saved_window
        # per-window telemetry restarts from the restored cumulative
        # counts (deltas stay per-window, not since-process-start);
        # same mod-2^32 residue the wrapping device int32 sums produce
        self.window_steps, self.window_leaps = [], []
        self._cum_steps = int(
            np.asarray(z["steps"], np.int64).sum()) & 0xFFFFFFFF
        self._cum_leaps = int(
            np.asarray(leaps, np.int64).sum()) & 0xFFFFFFFF
        self.scheduler._cost = z["cost"]
        if "rates" in z:
            self.rates = np.asarray(z["rates"], np.float32)
            self._rates_dev = self._dispatch.place(jnp.asarray(self.rates))
        if "group_ids" in z:
            # the saved map reflects any steering reallocations
            self.set_groups(np.asarray(z["group_ids"], np.int32))
            self._group_ids_dev = self._dispatch.place(
                self._group_ids_dev)
        if self._steer is not None:
            st = {k[len("steer_"):]: z[k] for k in z
                  if k.startswith("steer_")}
            if st:
                self._steer.load_state(st)
        # re-populate already-emitted records (buffer only — sinks are
        # not replayed so a resumed CSV does not double-write)
        self.stream.buffer.clear()
        if "rec_t" in z:
            for i in range(len(z["rec_t"])):
                self.stream.buffer.append(StatsRecord(
                    t=float(z["rec_t"][i]), window=int(z["rec_window"][i]),
                    mean=z["rec_mean"][i], var=z["rec_var"][i],
                    ci90=z["rec_ci90"][i], n=float(z["rec_n"][i])))
        if "samples" in z:
            s = z["samples"]
            self._samples = [s[:, w] for w in range(s.shape[1])]
        else:
            self._samples = []
        if "grouped_n" in z:
            self._grouped = [
                reduction.Stats(n=z["grouped_n"][w], mean=z["grouped_mean"][w],
                                var=z["grouped_var"][w],
                                ci90=z["grouped_ci90"][w])
                for w in range(len(z["grouped_n"]))]
        else:
            self._grouped = []
        if "sketch_hist" in z:
            sh = z["sketch_hist"]
            sr = z["sketch_rare"] if "sketch_rare" in z else None
            self._sketches = [WindowSketch(
                hist=sh[w], rare=(sr[w] if sr is not None else None))
                for w in range(len(sh))]
        else:
            self._sketches = []
        if "bp_n" in z:
            self._block_partials = [
                reduction.Welford(n=z["bp_n"][w], mean=z["bp_mean"][w],
                                  m2=z["bp_m2"][w])
                for w in range(len(z["bp_n"]))]
        else:
            self._block_partials = []
        if "gpp_n" in z:
            self._grouped_partials = [
                reduction.Welford(n=z["gpp_n"][w], mean=z["gpp_mean"][w],
                                  m2=z["gpp_m2"][w])
                for w in range(len(z["gpp_n"]))]
        else:
            self._grouped_partials = []

    @property
    def peak_buffered_bytes(self) -> int:
        return self._peak_buffered

    def trajectories(self) -> Optional[np.ndarray]:
        """(I, T, n_obs) raw samples. Buffered for schemas i/ii; for
        schema iii only when record_trajectories was requested."""
        self.flush()
        if not self._samples:
            return None
        return np.stack(self._samples, axis=1)

    def grouped_stats(self) -> list[reduction.Stats]:
        """Per-window grouped Stats ((n_groups, n_obs) leaves) when a
        grouped reduction is enabled via set_groups()."""
        self.flush()
        return list(self._grouped)
