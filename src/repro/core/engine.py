"""SimulationEngine — the multicore-aware simulator, TPU-pod native.

Runs an ensemble of stochastic CWC simulations (replicas and/or a
parameter sweep) under one of the paper's three schemas:

  schema "i"   static farm, post-hoc reduction (baseline)
  schema "ii"  time-sliced self-balancing farm, post-hoc reduction
  schema "iii" time-sliced farm + ON-LINE windowed reduction (the
               paper's best variant; memory-bounded)

Hot path: the whole instance pool advances one window as ONE pytree
through a single jitted, donated `window_step` — the scheduler's groups
become a device-side permutation plus a `lax.scan` over fixed-size lane
slices, so a window costs one dispatch instead of one gather/advance/
scatter round trip per group. The legacy host-driven per-group loop is
kept behind `SimConfig.host_loop` (and for the Pallas fused kernel,
whose chunk loop must stay host-driven) as the benchmark baseline; both
paths are bit-identical because every per-lane operation is unchanged.

Distribution: the instance pool is sharded over the mesh's data axes
(each shard = a farm worker); per-window statistics are reduced with a
single psum tree (`reduction.merge_over_axis`) so only O(species)
floats ever cross pods. Fault tolerance: `checkpoint()`/`restore()`
serialise the pool + scheduler + accumulators + emitted records;
trajectories are deterministic per-instance (keyed RNG), so a restart —
even with a different mesh — resumes bit-identically.

NOTE: constructing `SimulationEngine` directly is deprecated — use the
declarative front-end, `repro.api.simulate(Experiment(...))` (see
DESIGN.md for the migration table). The old surface is kept as a thin
shim over the same engine.
"""
from __future__ import annotations

import time
import warnings
from dataclasses import dataclass
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import reduction
from repro.core.cwc.compile import compile_model
from repro.core.cwc.rules import CWCModel
from repro.core.gillespie import LaneState, init_lanes, ssa_step, system_tensors
from repro.core.reactions import ReactionSystem
from repro.core.scheduler import Scheduler
from repro.core.stream import StatsRecord, StatsStream


@dataclass(frozen=True)
class SimConfig:
    n_instances: int = 128
    t_end: float = 10.0
    n_windows: int = 50
    n_lanes: int = 128  # SIMD width per slice group
    schema: str = "iii"  # i | ii | iii
    policy: str = "on_demand"  # static_rr | on_demand | predictive
    seed: int = 0
    max_steps_per_window: Optional[int] = None
    use_kernel: bool = False  # fused Pallas SSA step (see kernels/)
    host_loop: bool = False  # legacy per-group gather/scatter dispatch


def resolve_observables(model: CWCModel | ReactionSystem):
    """(system, obs_names, obs_idx) for a model — the single source of
    the observable-column derivation (engine and repro.api share it)."""
    if isinstance(model, CWCModel):
        system, meta = compile_model(model)
        names = list(meta["observables"]) or list(meta["species"])
        idx = [v for v in meta["observables"].values()] or [
            [i] for i in range(system.n_species)]
    else:
        system = model
        names = list(model.species_names)
        idx = [[i] for i in range(model.n_species)]
    return system, names, idx


class SimulationEngine:
    def __init__(self, model: CWCModel | ReactionSystem, cfg: SimConfig,
                 rates=None, mesh=None, observables: Optional[list] = None,
                 group_ids=None, record_trajectories: bool = False,
                 _deprecated: bool = True):
        if _deprecated:
            warnings.warn(
                "constructing SimulationEngine directly is deprecated; "
                "use repro.api.simulate(Experiment(...))",
                DeprecationWarning, stacklevel=2)
        self.system, self.obs_names, self.obs_idx = resolve_observables(
            model)
        self.cfg = cfg
        self.mesh = mesh
        # per-instance rates (parameter sweep) or shared
        if rates is None:
            self.rates = np.broadcast_to(
                self.system.rates, (cfg.n_instances, self.system.n_reactions))
        else:
            self.rates = np.asarray(rates, np.float32)
            assert self.rates.shape == (cfg.n_instances,
                                        self.system.n_reactions)
        self.grid = np.linspace(cfg.t_end / cfg.n_windows, cfg.t_end,
                                cfg.n_windows)
        self.stream = StatsStream()
        self.scheduler = Scheduler(
            cfg.n_instances, min(cfg.n_lanes, cfg.n_instances),
            policy=("static_rr" if cfg.schema == "i" else cfg.policy))
        self._tensors_base = system_tensors(self.system)
        self._pool = init_lanes(self.system, cfg.n_instances, cfg.seed)
        self._rates_dev = jnp.asarray(self.rates)
        self._window = 0
        # schemas i/ii always buffer raw per-window samples; schema iii
        # only on explicit opt-in (it forfeits the memory bound)
        self._record_trajectories = record_trajectories
        self._samples: list = []
        self._peak_buffered = 0
        self.wall_times: list[float] = []
        # telemetry: device dispatches and blocking device->host pulls
        self.n_dispatches = 0
        self.n_host_syncs = 0
        # optional grouped (per-sweep-point) reduction
        self._group_ids = None
        self._group_ids_dev = None
        self._grouped_fn = None
        self._grouped: list[reduction.Stats] = []
        if group_ids is not None:
            self.set_groups(group_ids)
        # dispatch path: one fused window_step by default; host-driven
        # per-group loop for the Pallas kernel (its chunk loop cannot be
        # jitted whole) or when explicitly requested as a baseline
        self._use_host_loop = cfg.host_loop or cfg.use_kernel
        self._perm_cache: Optional[jax.Array] = None
        if self._use_host_loop:
            self._advance = self._make_advance()
            self._window_step = None
        else:
            self._advance = None
            self._window_step = self._make_window_step()

    # -------------------------------------------------------- re-spec
    def set_rates(self, rates) -> None:
        """Install a per-instance (I, R) rate matrix (parameter sweep).
        Must happen before the first window runs."""
        assert self._window == 0, "rates must be set before running"
        rates = np.asarray(rates, np.float32)
        assert rates.shape == (self.cfg.n_instances, self.system.n_reactions)
        self.rates = rates
        self._rates_dev = jnp.asarray(rates)

    def set_groups(self, group_ids) -> None:
        """Enable grouped reduction: group_ids (I,) maps each instance
        to a reduction group (e.g. its sweep point)."""
        ids = np.asarray(group_ids, np.int32)
        assert ids.shape == (self.cfg.n_instances,)
        self._group_ids = ids
        self._group_ids_dev = jnp.asarray(ids)
        self._grouped_fn = jax.jit(partial(
            reduction.grouped_stats, n_groups=int(ids.max()) + 1))

    # ------------------------------------------------------------------
    def _make_advance(self):
        """Legacy per-group advance (host dispatch loop baseline)."""
        idx_t, coef_t, delta_t, _ = self._tensors_base
        cfg = self.cfg

        if cfg.use_kernel:
            from repro.kernels.ops import fused_window

            def advance(pool_slice, rates, horizon):
                # host-driven chunk loop (pallas_call inside is jit'd);
                # must NOT be wrapped in jax.jit itself
                return fused_window(pool_slice, (idx_t, coef_t, delta_t,
                                                 rates), horizon)

            return advance
        else:
            max_steps = cfg.max_steps_per_window

            def advance(pool_slice: LaneState, rates, horizon):
                tensors = (idx_t, coef_t, delta_t, rates)

                def cond(s):
                    return jnp.any((s.t < horizon) & ~s.dead)

                def body(s):
                    return ssa_step(s, tensors, horizon)

                if max_steps is None:
                    out = jax.lax.while_loop(cond, body, pool_slice)
                else:
                    out = jax.lax.fori_loop(
                        0, max_steps,
                        lambda _, s: jax.lax.cond(
                            cond(s), body, lambda s_: s_, s),
                        pool_slice)
                return out._replace(
                    t=jnp.where(out.dead, jnp.maximum(out.t, horizon), out.t))

        return jax.jit(advance, donate_argnums=(0,))

    def _make_window_step(self):
        """One jitted, donated step advancing the WHOLE pool a window.

        The scheduler's lane groups become a device-side permutation;
        `lax.scan` walks the fixed-size lane slices (the SIMD groups)
        sequentially on device, so the host dispatches once per window
        instead of once per group, and no pool state ever round-trips.
        Per-lane operations are identical to the host path — the two are
        bit-identical.
        """
        idx_t, coef_t, delta_t, _ = self._tensors_base
        n_lanes = self.scheduler.n_lanes
        obs_idx = tuple(tuple(int(i) for i in ii) for ii in self.obs_idx)
        max_steps = self.cfg.max_steps_per_window

        def window_step(pool: LaneState, rates, perm, horizon):
            n_groups = perm.shape[0] // n_lanes

            def take(a):
                return a[perm].reshape((n_groups, n_lanes) + a.shape[1:])

            lanes = LaneState(*(take(a) for a in pool))
            rates_g = take(rates)

            def advance_group(carry, grp):
                sl, r = grp
                tensors = (idx_t, coef_t, delta_t, r)

                def cond(s):
                    return jnp.any((s.t < horizon) & ~s.dead)

                def body(s):
                    return ssa_step(s, tensors, horizon)

                if max_steps is None:
                    out = jax.lax.while_loop(cond, body, sl)
                else:
                    out = jax.lax.fori_loop(
                        0, max_steps,
                        lambda _, s: jax.lax.cond(
                            cond(s), body, lambda s_: s_, s),
                        sl)
                out = out._replace(
                    t=jnp.where(out.dead, jnp.maximum(out.t, horizon), out.t))
                return carry, out

            _, advanced = jax.lax.scan(advance_group, 0, (lanes, rates_g))
            flat = jax.tree_util.tree_map(
                lambda a: a.reshape((n_groups * n_lanes,) + a.shape[2:]),
                advanced)
            # duplicate padding indices write identical data — safe
            new_pool = LaneState(*(
                p.at[perm].set(v) for p, v in zip(pool, flat)))
            cols = [new_pool.x[:, list(ii)].sum(axis=1) for ii in obs_idx]
            obs = jnp.stack(cols, axis=1)
            return new_pool, obs, new_pool.steps - pool.steps

        return jax.jit(window_step, donate_argnums=(0,))

    def _permutation(self) -> jax.Array:
        """Concatenated, padded scheduler groups as a device index map."""
        if self.scheduler.policy != "predictive" and \
                self._perm_cache is not None:
            return self._perm_cache
        perm = jnp.asarray(
            np.concatenate(self.scheduler.groups()).astype(np.int32))
        if self.scheduler.policy != "predictive":
            self._perm_cache = perm
        return perm

    def _gather(self, idx) -> tuple[LaneState, jax.Array]:
        p = self._pool
        sl = LaneState(x=p.x[idx], t=p.t[idx], key=p.key[idx],
                       steps=p.steps[idx], dead=p.dead[idx])
        return sl, jnp.asarray(self.rates[idx])

    def _scatter(self, idx, sl: LaneState) -> None:
        p = self._pool
        # guard duplicate padding indices: later writes win (identical data)
        self._pool = LaneState(
            x=p.x.at[idx].set(sl.x), t=p.t.at[idx].set(sl.t),
            key=p.key.at[idx].set(sl.key), steps=p.steps.at[idx].set(sl.steps),
            dead=p.dead.at[idx].set(sl.dead))

    def _advance_window_host(self, horizon: float):
        """Legacy baseline: per-group gather → advance → scatter."""
        predictive = self.scheduler.policy == "predictive"
        steps_before = None
        if predictive:
            steps_before = np.asarray(self._pool.steps)
            self.n_host_syncs += 1
        for idx in self.scheduler.groups():
            sl, rates = self._gather(idx)
            sl = self._advance(sl, rates, horizon)
            self._scatter(idx, sl)
            self.n_dispatches += 1
        steps_delta = None
        if predictive:
            steps_delta = np.asarray(self._pool.steps) - steps_before
            self.n_host_syncs += 1
        return self._observe(), steps_delta

    # ------------------------------------------------------------------
    def run_window(self) -> StatsRecord:
        """Advance every instance to the next grid point. All three
        schemas share this window loop — they differ in grouping policy
        (schema i: static_rr) and in what is buffered (i/ii: raw
        samples for post-hoc use; iii: nothing beyond the running
        accumulator)."""
        cfg = self.cfg
        horizon = float(self.grid[self._window])
        t0 = time.perf_counter()
        if self._use_host_loop:
            obs, steps_delta = self._advance_window_host(horizon)
        else:
            self._pool, obs, steps_delta = self._window_step(
                self._pool, self._rates_dev, self._permutation(), horizon)
            self.n_dispatches += 1
        if self.scheduler.policy == "predictive":
            if steps_delta is not None and not isinstance(
                    steps_delta, np.ndarray):
                steps_delta = np.asarray(steps_delta)
                self.n_host_syncs += 1
            self.scheduler.record_costs(
                np.arange(cfg.n_instances), steps_delta)
        self.wall_times.append(time.perf_counter() - t0)

        if cfg.schema in ("i", "ii") or self._record_trajectories:
            self._samples.append(np.asarray(obs))
            self.n_host_syncs += 1
            self._peak_buffered = max(
                self._peak_buffered,
                sum(s.nbytes for s in self._samples))
        else:  # schema iii: on-line reduction, window dropped immediately
            self._peak_buffered = max(self._peak_buffered, obs.nbytes)
        acc = reduction.init_welford(obs.shape[1:])
        acc = reduction.update_batch(acc, obs)
        stats = reduction.finalize(acc)
        if self._grouped_fn is not None:
            g = self._grouped_fn(obs, self._group_ids_dev)
            self._grouped.append(
                reduction.Stats(*(np.asarray(v) for v in g)))
            self.n_host_syncs += 1
        rec = StatsRecord(
            t=horizon, window=self._window,
            mean=np.asarray(stats.mean), var=np.asarray(stats.var),
            ci90=np.asarray(stats.ci90), n=float(np.asarray(stats.n).max()))
        self.n_host_syncs += 1
        self.stream.emit(rec)
        self._window += 1
        return rec

    def _observe(self) -> jax.Array:
        cols = [self._pool.x[:, idx].sum(axis=1) for idx in self.obs_idx]
        return jnp.stack(cols, axis=1)

    def run(self) -> list[StatsRecord]:
        while self._window < len(self.grid):
            self.run_window()
        return self.stream.records()

    # ------------------------------------------------------------ fault
    def checkpoint(self, path: str) -> None:
        """One-file snapshot: pool + scheduler + emitted records (+ any
        buffered samples/grouped stats). Cost is O(pool + buffered
        state): constant per call under schema iii (nothing is
        buffered), but grows with the sample buffer under schemas
        i/ii — prefer schema iii for per-window checkpointing."""
        p = self._pool
        extra = {}
        recs = self.stream.records()
        if recs:
            extra = dict(
                rec_t=np.asarray([r.t for r in recs], np.float64),
                rec_window=np.asarray([r.window for r in recs], np.int64),
                rec_mean=np.stack([r.mean for r in recs]),
                rec_var=np.stack([r.var for r in recs]),
                rec_ci90=np.stack([r.ci90 for r in recs]),
                rec_n=np.asarray([r.n for r in recs], np.float64))
        if self._samples:
            extra["samples"] = np.stack(self._samples, axis=1)
        if self._grouped:
            for name in ("n", "mean", "var", "ci90"):
                extra[f"grouped_{name}"] = np.stack(
                    [getattr(g, name) for g in self._grouped])
        np.savez(
            path, x=np.asarray(p.x), t=np.asarray(p.t),
            key=np.asarray(p.key), steps=np.asarray(p.steps),
            dead=np.asarray(p.dead), window=self._window,
            cost=self.scheduler._cost, rates=self.rates, **extra)

    def restore(self, path: str) -> None:
        z = np.load(path if path.endswith(".npz") else path + ".npz")
        self._pool = LaneState(
            x=jnp.asarray(z["x"]), t=jnp.asarray(z["t"]),
            key=jnp.asarray(z["key"]), steps=jnp.asarray(z["steps"]),
            dead=jnp.asarray(z["dead"]))
        self._window = int(z["window"])
        self.scheduler._cost = z["cost"]
        if "rates" in z:
            self.rates = np.asarray(z["rates"], np.float32)
            self._rates_dev = jnp.asarray(self.rates)
        # re-populate already-emitted records (buffer only — sinks are
        # not replayed so a resumed CSV does not double-write)
        self.stream.buffer.clear()
        if "rec_t" in z:
            for i in range(len(z["rec_t"])):
                self.stream.buffer.append(StatsRecord(
                    t=float(z["rec_t"][i]), window=int(z["rec_window"][i]),
                    mean=z["rec_mean"][i], var=z["rec_var"][i],
                    ci90=z["rec_ci90"][i], n=float(z["rec_n"][i])))
        if "samples" in z:
            s = z["samples"]
            self._samples = [s[:, w] for w in range(s.shape[1])]
        else:
            self._samples = []
        if "grouped_n" in z:
            self._grouped = [
                reduction.Stats(n=z["grouped_n"][w], mean=z["grouped_mean"][w],
                                var=z["grouped_var"][w],
                                ci90=z["grouped_ci90"][w])
                for w in range(len(z["grouped_n"]))]
        else:
            self._grouped = []

    @property
    def peak_buffered_bytes(self) -> int:
        return self._peak_buffered

    def trajectories(self) -> Optional[np.ndarray]:
        """(I, T, n_obs) raw samples. Buffered for schemas i/ii; for
        schema iii only when record_trajectories was requested."""
        if not self._samples:
            return None
        return np.stack(self._samples, axis=1)

    def grouped_stats(self) -> list[reduction.Stats]:
        """Per-window grouped Stats ((n_groups, n_obs) leaves) when a
        grouped reduction is enabled via set_groups()."""
        return list(self._grouped)
