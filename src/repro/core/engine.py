"""SimulationEngine — the multicore-aware simulator, TPU-pod native.

Runs an ensemble of stochastic CWC simulations (replicas and/or a
parameter sweep) under one of the paper's three schemas:

  schema "i"   static farm, post-hoc reduction (baseline)
  schema "ii"  time-sliced self-balancing farm, post-hoc reduction
  schema "iii" time-sliced farm + ON-LINE windowed reduction (the
               paper's best variant; memory-bounded)

Distribution: the instance pool is sharded over the mesh's data axes
(each shard = a farm worker); per-window statistics are reduced with a
single psum tree (`reduction.merge_over_axis`) so only O(species)
floats ever cross pods. Fault tolerance: `checkpoint()`/`restore()`
serialise the pool + scheduler + accumulators; trajectories are
deterministic per-instance (keyed RNG), so a restart — even with a
different mesh — resumes bit-identically.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import reduction
from repro.core.cwc.compile import compile_model
from repro.core.cwc.rules import CWCModel
from repro.core.gillespie import LaneState, init_lanes, ssa_step, system_tensors
from repro.core.reactions import ReactionSystem
from repro.core.scheduler import Scheduler
from repro.core.stream import StatsRecord, StatsStream


@dataclass(frozen=True)
class SimConfig:
    n_instances: int = 128
    t_end: float = 10.0
    n_windows: int = 50
    n_lanes: int = 128  # SIMD width per slice group
    schema: str = "iii"  # i | ii | iii
    policy: str = "on_demand"  # static_rr | on_demand | predictive
    seed: int = 0
    max_steps_per_window: Optional[int] = None
    use_kernel: bool = False  # fused Pallas SSA step (see kernels/)


class SimulationEngine:
    def __init__(self, model: CWCModel | ReactionSystem, cfg: SimConfig,
                 rates=None, mesh=None, observables: Optional[list] = None):
        if isinstance(model, CWCModel):
            self.system, meta = compile_model(model)
            self.obs_names = list(meta["observables"]) or list(
                meta["species"])
            self.obs_idx = [v for v in meta["observables"].values()] or [
                [i] for i in range(self.system.n_species)]
        else:
            self.system = model
            self.obs_names = list(self.system.species_names)
            self.obs_idx = [[i] for i in range(self.system.n_species)]
        self.cfg = cfg
        self.mesh = mesh
        # per-instance rates (parameter sweep) or shared
        if rates is None:
            self.rates = np.broadcast_to(
                self.system.rates, (cfg.n_instances, self.system.n_reactions))
        else:
            self.rates = np.asarray(rates, np.float32)
            assert self.rates.shape == (cfg.n_instances,
                                        self.system.n_reactions)
        self.grid = np.linspace(cfg.t_end / cfg.n_windows, cfg.t_end,
                                cfg.n_windows)
        self.stream = StatsStream()
        self.scheduler = Scheduler(
            cfg.n_instances, min(cfg.n_lanes, cfg.n_instances),
            policy=("static_rr" if cfg.schema == "i" else cfg.policy))
        self._tensors_base = system_tensors(self.system)
        self._pool = init_lanes(self.system, cfg.n_instances, cfg.seed)
        self._window = 0
        self._samples: list = []  # schemas i/ii: raw per-window samples
        self._peak_buffered = 0
        self.wall_times: list[float] = []
        self._advance = self._make_advance()

    # ------------------------------------------------------------------
    def _make_advance(self):
        idx_t, coef_t, delta_t, _ = self._tensors_base
        cfg = self.cfg

        if cfg.use_kernel:
            from repro.kernels.ops import fused_window

            def advance(pool_slice, rates, horizon):
                # host-driven chunk loop (pallas_call inside is jit'd);
                # must NOT be wrapped in jax.jit itself
                return fused_window(pool_slice, (idx_t, coef_t, delta_t,
                                                 rates), horizon)

            return advance
        else:
            def advance(pool_slice: LaneState, rates, horizon):
                tensors = (idx_t, coef_t, delta_t, rates)

                def cond(s):
                    return jnp.any((s.t < horizon) & ~s.dead)

                def body(s):
                    return ssa_step(s, tensors, horizon)

                out = jax.lax.while_loop(cond, body, pool_slice)
                return out._replace(
                    t=jnp.where(out.dead, jnp.maximum(out.t, horizon), out.t))

        return jax.jit(advance, donate_argnums=(0,))

    def _gather(self, idx) -> tuple[LaneState, jax.Array]:
        p = self._pool
        sl = LaneState(x=p.x[idx], t=p.t[idx], key=p.key[idx],
                       steps=p.steps[idx], dead=p.dead[idx])
        return sl, jnp.asarray(self.rates[idx])

    def _scatter(self, idx, sl: LaneState) -> None:
        p = self._pool
        # guard duplicate padding indices: later writes win (identical data)
        self._pool = LaneState(
            x=p.x.at[idx].set(sl.x), t=p.t.at[idx].set(sl.t),
            key=p.key.at[idx].set(sl.key), steps=p.steps.at[idx].set(sl.steps),
            dead=p.dead.at[idx].set(sl.dead))

    # ------------------------------------------------------------------
    def run_window(self) -> StatsRecord:
        """Advance every instance to the next grid point (schema ii/iii
        slice; schema i groups also pass through here — the grouping
        policy is what differs)."""
        cfg = self.cfg
        horizon = float(self.grid[self._window])
        t0 = time.perf_counter()
        for idx in self.scheduler.groups():
            sl, rates = self._gather(idx)
            steps_before = np.asarray(sl.steps)
            sl = self._advance(sl, rates, horizon)
            self._scatter(idx, sl)
            if self.scheduler.policy == "predictive":
                self.scheduler.record_costs(
                    np.asarray(idx), np.asarray(sl.steps) - steps_before)
        self.wall_times.append(time.perf_counter() - t0)

        obs = self._observe()  # (I, n_obs)
        if cfg.schema in ("i", "ii"):
            self._samples.append(np.asarray(obs))
            self._peak_buffered = max(
                self._peak_buffered,
                sum(s.nbytes for s in self._samples))
            acc = reduction.init_welford(obs.shape[1:])
            acc = reduction.update_batch(acc, obs)
        else:  # schema iii: on-line reduction, window dropped immediately
            acc = reduction.init_welford(obs.shape[1:])
            acc = reduction.update_batch(acc, obs)
            self._peak_buffered = max(self._peak_buffered, obs.nbytes)
        stats = reduction.finalize(acc)
        rec = StatsRecord(
            t=horizon, window=self._window,
            mean=np.asarray(stats.mean), var=np.asarray(stats.var),
            ci90=np.asarray(stats.ci90), n=float(np.asarray(stats.n).max()))
        self.stream.emit(rec)
        self._window += 1
        return rec

    def _observe(self) -> jax.Array:
        cols = [self._pool.x[:, idx].sum(axis=1) for idx in self.obs_idx]
        return jnp.stack(cols, axis=1)

    def run(self) -> list[StatsRecord]:
        if self.cfg.schema == "i":
            return self._run_schema_i()
        while self._window < len(self.grid):
            self.run_window()
        return self.stream.records()

    def _run_schema_i(self) -> list[StatsRecord]:
        """Static farm: each group runs its full trajectory (all windows)
        before the next group starts; reduction strictly post-hoc."""
        cfg = self.cfg
        groups = self.scheduler.groups()
        all_samples = np.zeros(
            (cfg.n_instances, len(self.grid), len(self.obs_idx)), np.float32)
        for idx in groups:
            for w, horizon in enumerate(self.grid):
                sl, rates = self._gather(idx)
                t0 = time.perf_counter()
                sl = self._advance(sl, rates, float(horizon))
                self.wall_times.append(time.perf_counter() - t0)
                self._scatter(idx, sl)
                obs = np.asarray(self._observe())[idx]
                all_samples[idx, w] = obs
        self._peak_buffered = all_samples.nbytes
        # post-hoc reduction
        for w, horizon in enumerate(self.grid):
            acc = reduction.init_welford((len(self.obs_idx),))
            acc = reduction.update_batch(acc, jnp.asarray(all_samples[:, w]))
            stats = reduction.finalize(acc)
            self.stream.emit(StatsRecord(
                t=float(horizon), window=w,
                mean=np.asarray(stats.mean), var=np.asarray(stats.var),
                ci90=np.asarray(stats.ci90), n=float(cfg.n_instances)))
        self._window = len(self.grid)
        return self.stream.records()

    # ------------------------------------------------------------ fault
    def checkpoint(self, path: str) -> None:
        p = self._pool
        np.savez(
            path, x=np.asarray(p.x), t=np.asarray(p.t),
            key=np.asarray(p.key), steps=np.asarray(p.steps),
            dead=np.asarray(p.dead), window=self._window,
            cost=self.scheduler._cost, rates=self.rates)

    def restore(self, path: str) -> None:
        z = np.load(path if path.endswith(".npz") else path + ".npz")
        self._pool = LaneState(
            x=jnp.asarray(z["x"]), t=jnp.asarray(z["t"]),
            key=jnp.asarray(z["key"]), steps=jnp.asarray(z["steps"]),
            dead=jnp.asarray(z["dead"]))
        self._window = int(z["window"])
        self.scheduler._cost = z["cost"]

    @property
    def peak_buffered_bytes(self) -> int:
        return self._peak_buffered

    def trajectories(self) -> Optional[np.ndarray]:
        """(I, T, n_obs) raw samples (schemas i/ii only)."""
        if self.cfg.schema == "iii" or not self._samples:
            return None
        if self.cfg.schema == "i":
            return None
        return np.stack(self._samples, axis=1)
