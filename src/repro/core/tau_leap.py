"""Adaptive tau-leaping — the second simulation algorithm (DESIGN.md §3d).

Exact SSA pays one Resolve/Update per reaction event, so stiff or
large-population models (propensity sums in the thousands) burn tens of
thousands of steps per window. Tau-leaping (Gillespie 2001; the
adaptive step-size selection of Cao, Gillespie & Petzold 2006) trades
exactness for order-of-magnitude fewer steps while keeping the ensemble
statistics: pick a leap `tau` over which no propensity changes by more
than a fraction `eps`, fire each reaction `K_j ~ Poisson(a_j tau)`
times at once, and fall back to exact SSA wherever a leap would be no
cheaper than a few exact steps.

Everything here is written as ONE vectorised step (`tau_step_core`) in
plain jnp elementwise + `lax.dot` ops over the lane axis — the SAME
function is traced by the host/fused window bodies and called inside
the Pallas kernel body (`kernels/ssa_step.tau_window_call`), which is
what makes fused / unfused / kernel / sharded tau-leap trajectories
bitwise identical, exactly like the exact-SSA paths.

Randomness comes from the per-lane counter stream
(`core/stream.counter_uniforms`): a leap consumes `ceil(R/2)` counter
blocks (one uniform per reaction, inverse-transform Poisson), an exact
fallback step consumes one block (tau + choice) — a pure function of
(lane key, 64-bit counter), so any chunking, shard count, or
checkpoint/resume replays the identical stream.

Per-lane algorithm for one step (all lanes in lock-step, masked):

  1. propensities a_j (MXU one-hot matmuls, identical op sequence to
     the exact kernel) and the Cao g_i-bounded candidate tau;
  2. if tau * a0 < `fallback` (a leap would cover fewer than a few SSA
     steps), do ONE exact SSA step instead (identical math and stream
     consumption as `gillespie.ssa_step`);
  3. otherwise draw K_j ~ Poisson(a_j tau) by inverse transform; if
     any population would go negative, REJECT, halve tau and retry
     with fresh draws; a second rejection falls back to one exact SSA
     step (which cannot go negative) — bounded work, guaranteed
     progress, deterministic stream accounting;
  4. leap lanes land at min(t + tau, horizon) (tau is pre-clamped to
     the window horizon, so the frozen state is the window sample);
     exact-fallback lanes keep `ssa_step`'s freeze-at-horizon
     semantics.

`steps` counts solver iterations that advanced a lane (leaps + fired
fallback events) — the work metric the engine's per-window telemetry
reports against exact SSA; `leaps` counts accepted leaps only, so
`steps - leaps` is the exact-fallback share.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gillespie import LaneState
from repro.core.reactions import MAX_COEF, ReactionSystem, comb_factors
from repro.core.stream import counter_uniforms, ctr_add

#: default fraction by which a leap may change any propensity (Cao'06)
DEFAULT_EPS = 0.03
#: leap only when tau covers at least this many expected SSA events
DEFAULT_FALLBACK = 10.0
#: cap on any single Poisson mean a_j*tau, so the inverse-transform
#: unroll below never truncates: P(X > POISSON_KMAX | lam <= LAM_MAX)
#: < 1e-18 — beyond f32 resolution
LAM_MAX = 16.0
POISSON_KMAX = 64


# ------------------------------------------------------------ host prep
def gi_tables(system: ReactionSystem) -> np.ndarray:
    """(MAX_COEF, S) float32 coefficient table for the Cao g_i bound.

    g_i(x) = T[0,i] + sum_{k>=1} T[k,i] / max(x_i - k, 1), from the
    highest-order reaction (HOR) consuming species i: for an order-o
    HOR taking c copies of i,

        g = o + (o/c) * sum_{k=1}^{c-1} k / (x - k)

    which reproduces the standard cases (o=1: 1; o=2,c=2: 2 + 1/(x-1);
    o=3,c=3: 3 + 1/(x-1) + 2/(x-2); o=3,c=2: (3/2)(2 + 1/(x-1))).
    Ties on o prefer the larger c (the more conservative bound).
    Species never consumed get g = 1 (masked out of the tau min by
    `reactant_mask` anyway)."""
    s = system.n_species
    # rows up to the actual max coefficient (sparse path lifts the
    # MAX_COEF ceiling); extra rows stay zero for small-coef systems,
    # and gi consumers loop over gi.shape[0], adding exact +0.0 terms
    tab = np.zeros((max(MAX_COEF, system.max_coef), s), np.float32)
    tab[0] = 1.0
    best = np.zeros((2, s), np.int64)  # (o, c) of the HOR per species
    for j in range(system.n_reactions):
        order = int(system.reactant_coef[j].sum())
        for i, c in zip(system.reactant_idx[j], system.reactant_coef[j]):
            if c <= 0 or i >= s:
                continue
            o_old, c_old = best[0, i], best[1, i]
            if (order, c) > (o_old, c_old):
                best[0, i], best[1, i] = order, c
    for i in range(s):
        o, c = int(best[0, i]), int(best[1, i])
        if o == 0:
            continue
        tab[0, i] = float(o)
        for k in range(1, c):
            tab[k, i] = o / c * k
    return tab


def reactant_mask(system: ReactionSystem) -> np.ndarray:
    """(S,) float32: 1 where the species is consumed by some reaction —
    only those populations bound the Cao tau."""
    s = system.n_species
    mask = np.zeros((s,), np.float32)
    for j in range(system.n_reactions):
        for i, c in zip(system.reactant_idx[j], system.reactant_coef[j]):
            if c > 0 and i < s:
                mask[i] = 1.0
    return mask


def onehot_tensors(idx, coef_rm, n_species: int):
    """(e (M, S+pad stripped, R), coef_k (M, R)) in MXU one-hot form,
    built from the gather-form (idx, coef) tensors at trace time (so it
    compiles away). Shared by the kernel chunk loops (kernels/ops.py)
    and the host-traced tau-leap step."""
    r, m = idx.shape[0], idx.shape[1]
    s = n_species
    e = jnp.zeros((m, s + 1, r), jnp.float32).at[
        jnp.arange(m)[:, None], idx.T, jnp.arange(r)[None, :]].set(
        (coef_rm.T > 0).astype(jnp.float32))[:, :s, :]
    return e, jnp.asarray(coef_rm.T, jnp.float32)


# ------------------------------------------------------- step primitives
def poisson_from_uniform(u, lam, kmax: int = POISSON_KMAX):
    """Inverse-transform Poisson: smallest k with CDF(k) >= u, as f32.

    Exactly ONE uniform per variate (fixed stream consumption), a
    fori_loop of `kmax` pmf recurrence terms (VREG-only ops — runs
    unchanged inside the Pallas kernel body). `lam` must be <= LAM_MAX
    (callers clamp tau), so the truncation tail is ~0."""
    pmf = jnp.exp(-lam)
    cdf = pmf
    k = (cdf < u).astype(jnp.float32)

    def body(i, carry):
        pmf, cdf, k = carry
        pmf = pmf * (lam / i)
        cdf = cdf + pmf
        return pmf, cdf, k + (cdf < u).astype(jnp.float32)

    _, _, k = jax.lax.fori_loop(1, kmax, body, (pmf, cdf, k))
    return k


def tau_step_core(x, t, dead, k0, k1, ctr, ctr_hi, steps, leaps,
                  e, coef, delta, rates, gi, rmask, horizon, *,
                  eps: float, fallback: float,
                  lam_max: float = LAM_MAX, kmax: int = POISSON_KMAX,
                  gather_match=None):
    """One vectorised tau-leap-or-fallback step over the lane axis.

    x (B,S) f32; t (B,) f32; dead (B,) bool; k0/k1/ctr/ctr_hi (B,) u32;
    steps/leaps (B,) i32; e (M,S,R) f32 one-hots; coef (M,R) f32;
    delta (R,S) f32; rates (B,R) or (R,) f32; gi (>=MAX_COEF,S) f32
    (`gi_tables`); rmask (S,) f32 (`reactant_mask`); horizon scalar.

    Returns (x, t, dead, ctr, ctr_hi, steps, leaps). Pure jnp — traced
    by host jit AND the Pallas kernel body, bitwise identically.

    `fallback` may be a scalar or a (B,) per-lane array (the steering
    layer's exact<->tau auto-switch feeds +inf for switched lanes); it
    only enters the `do_leap` comparison, which broadcasts.

    `gather_match=(idx (R,M) i32, coef_rm (R,M) i32, max_c)` switches
    Match to the sparse gather form — no (M,S,R) one-hot tensors, the
    comb unroll bounded by the system's actual max coefficient — in
    which case `e`/`coef` may be None. A real slot gathers the same
    population the one-hot dot accumulates (one x entry plus exact
    +0.0 terms) and a pad slot yields factor 1.0 on both forms, so the
    two Matches are bitwise identical. The leap bookkeeping
    (mu/sig2/dx) stays dense: those are genuine f32 SUMS over species,
    and re-associating them would change bits — so sparse tau-leap
    saves Match work and one-hot memory only (documented in
    DESIGN.md §3g).
    """
    b, s = x.shape
    r = delta.shape[0]
    n_pairs = (r + 1) // 2  # counter blocks per leap attempt
    if rates.ndim == 1:
        rates = jnp.broadcast_to(rates, (b, r))

    active = (t < horizon) & ~dead
    # --- Match (identical op sequence to the exact kernel) ---
    a = rates
    if gather_match is not None:
        g_idx, g_coef, max_c = gather_match
        xp = jnp.concatenate([x, jnp.ones((b, 1), x.dtype)], axis=1)
        pops_g = xp[:, g_idx]  # (B, R, M)
        for m in range(g_idx.shape[1]):
            a = a * comb_factors(pops_g[:, :, m], g_coef[None, :, m],
                                 max_c)
    else:
        for m in range(e.shape[0]):
            pops = jax.lax.dot(x, e[m], preferred_element_type=jnp.float32)
            a = a * comb_factors(pops, coef[m][None, :])
    a0 = a.sum(axis=1)
    now_dead = a0 <= 0.0
    alive = active & ~now_dead

    # --- Cao tau candidate: bound the relative propensity drift ---
    mu = jax.lax.dot(a, delta, preferred_element_type=jnp.float32)
    sig2 = jax.lax.dot(a, delta * delta,
                       preferred_element_type=jnp.float32)
    g = jnp.broadcast_to(gi[0][None, :], x.shape)
    for k in range(1, gi.shape[0]):
        g = g + gi[k][None, :] / jnp.maximum(x - k, 1.0)
    bnd = jnp.maximum(eps * x / g, 1.0)
    consuming = rmask[None, :] > 0.0
    r1 = jnp.where(consuming & (jnp.abs(mu) > 0.0),
                   bnd / jnp.maximum(jnp.abs(mu), 1e-30), jnp.inf)
    r2 = jnp.where(consuming & (sig2 > 0.0),
                   (bnd * bnd) / jnp.maximum(sig2, 1e-30), jnp.inf)
    tau_c = jnp.minimum(r1, r2).min(axis=1)  # (B,)

    # clamp the leap to the window horizon and the Poisson-unroll
    # bound; per-lane method choice on the CLAMPED tau (always finite
    # for live lanes — tau_c is inf when no consumed species bounds the
    # drift, and an unclamped gate would then leap past any
    # `fallback`, breaking the fallback=inf == exact-SSA degeneration)
    a_max = a.max(axis=1)
    tau_l = jnp.minimum(jnp.minimum(tau_c, horizon - t),
                        lam_max / jnp.maximum(a_max, 1e-30))
    do_leap = alive & (tau_l * a0 >= fallback)
    tau_h = 0.5 * tau_l

    def slab(off):
        """R uniforms per lane from the n_pairs counter blocks at
        ctr + off (off: uint32 scalar or (B,) array)."""
        us = []
        for p in range(n_pairs):
            lo, hi = ctr_add(ctr, ctr_hi, jnp.uint32(p) + off)
            u1, u2 = counter_uniforms(k0, k1, lo, hi)
            us.extend([u1, u2])
        return jnp.stack(us[:r], axis=-1)  # (B, R)

    # --- leap attempt 1, then a halved-tau retry on rejection ---
    kc1 = poisson_from_uniform(slab(jnp.uint32(0)), a * tau_l[:, None],
                               kmax)
    dx1 = jax.lax.dot(kc1, delta, preferred_element_type=jnp.float32)
    ok1 = ((x + dx1) >= 0.0).all(axis=1)
    kc2 = poisson_from_uniform(slab(jnp.uint32(n_pairs)),
                               a * tau_h[:, None], kmax)
    dx2 = jax.lax.dot(kc2, delta, preferred_element_type=jnp.float32)
    ok2 = ((x + dx2) >= 0.0).all(axis=1)
    leap1 = do_leap & ok1
    leap2 = do_leap & ~ok1 & ok2
    leaped = leap1 | leap2

    # --- exact SSA sub-step: non-leaping lanes, double-rejects, and
    # (for stream parity with ssa_step) lanes that just went dead ---
    exact_lane = active & ~leaped
    e_off = jnp.where(do_leap & ~leaped,
                      jnp.uint32(2 * n_pairs), jnp.uint32(0))
    lo_e, hi_e = ctr_add(ctr, ctr_hi, e_off)
    u1, u2 = counter_uniforms(k0, k1, lo_e, hi_e)
    tau_e = -jnp.log(u1) / jnp.maximum(a0, 1e-30)
    t_next = t + tau_e
    fire = exact_lane & ~now_dead & (t_next <= horizon)
    cum = jnp.cumsum(a, axis=1)
    ge = cum >= (u2 * a0)[:, None]
    first = ge & ~jnp.concatenate(
        [jnp.zeros_like(ge[:, :1]), ge[:, :-1]], axis=1)
    onehot = jnp.where(fire[:, None], first.astype(jnp.float32), 0.0)
    dx_e = jax.lax.dot(onehot, delta, preferred_element_type=jnp.float32)

    # --- apply ---
    dx = jnp.where(leap1[:, None], dx1,
                   jnp.where(leap2[:, None], dx2, dx_e))
    x_new = x + dx
    t_new = jnp.where(leap1, jnp.minimum(t + tau_l, horizon),
                      jnp.where(leap2, jnp.minimum(t + tau_h, horizon),
                                jnp.where(fire, t_next,
                                          jnp.where(exact_lane, horizon,
                                                    t))))
    dead_new = dead | (active & now_dead)
    # deterministic stream accounting: ok1 leap = n_pairs blocks,
    # retried leap = 2*n_pairs, exact sub-step = +1, idle = 0
    consumed = (jnp.where(do_leap,
                          jnp.where(ok1, jnp.uint32(n_pairs),
                                    jnp.uint32(2 * n_pairs)),
                          jnp.uint32(0))
                + exact_lane.astype(jnp.uint32))
    lo_n, hi_n = ctr_add(ctr, ctr_hi, consumed)
    steps_new = steps + (leaped | fire).astype(jnp.int32)
    leaps_new = leaps + leaped.astype(jnp.int32)
    return x_new, t_new, dead_new, lo_n, hi_n, steps_new, leaps_new


# --------------------------------------------------------- host wrapper
def make_tau_step(gi, rmask, eps: float, fallback: float,
                  gather_max_c: int | None = None):
    """`ssa_step`-shaped per-lane step for the dispatch seam: returns
    step(state: LaneState, system_tensors, horizon) -> LaneState, where
    system_tensors is the gather-form (idx, coef, delta, rates) tuple —
    converted to the kernel's one-hot form at trace time so the host
    paths run the exact op sequence the Pallas body runs.

    `gather_max_c` (the sparse seam) keeps Match in gather form with
    that comb unroll bound instead — bitwise identical, no (M, S, R)
    one-hots, and no MAX_COEF ceiling."""
    gi = jnp.asarray(gi, jnp.float32)
    rmask = jnp.asarray(rmask, jnp.float32)

    def tau_step(state: LaneState, system_tensors, horizon) -> LaneState:
        idx, coef_rm, delta_f, rates = system_tensors
        if gather_max_c is None:
            e, coef_k = onehot_tensors(idx, coef_rm, state.x.shape[1])
            gm = None
        else:
            e = coef_k = None
            gm = (idx, coef_rm, gather_max_c)
        # steering's per-lane exact<->tau switch: a lane with no_leap
        # set sees an infinite fallback threshold, so its `do_leap`
        # gate is always False and it takes exact SSA steps (identical
        # math and stream consumption to gillespie.ssa_step). With
        # no_leap all-False this reduces bitwise to the scalar gate.
        fb = jnp.where(state.no_leap, jnp.float32(jnp.inf),
                       jnp.float32(fallback))
        x, t, dead, lo, hi, steps, leaps = tau_step_core(
            state.x, state.t, state.dead,
            state.key[:, 0], state.key[:, 1], state.ctr, state.ctr_hi,
            state.steps, state.leaps,
            e, coef_k, jnp.asarray(delta_f, jnp.float32),
            jnp.asarray(rates, jnp.float32), gi, rmask,
            jnp.asarray(horizon, jnp.float32),
            eps=eps, fallback=fb, gather_match=gm)
        return LaneState(x=x, t=t, key=state.key, ctr=lo, ctr_hi=hi,
                         steps=steps, leaps=leaps, dead=dead,
                         no_leap=state.no_leap)

    return tau_step


def advance_to(state: LaneState, system, horizon, gi=None, rmask=None,
               eps: float = DEFAULT_EPS, fallback: float = DEFAULT_FALLBACK
               ) -> LaneState:
    """Standalone tau-leap window advance (tests / notebooks — the
    engine goes through the dispatch seam instead)."""
    from repro.core.gillespie import system_tensors

    tensors = system_tensors(system)
    step = make_tau_step(gi_tables(system) if gi is None else gi,
                         reactant_mask(system) if rmask is None else rmask,
                         eps, fallback)
    horizon = jnp.asarray(horizon, jnp.float32)

    def cond(s):
        return jnp.any((s.t < horizon) & ~s.dead)

    out = jax.lax.while_loop(cond, partial(step, system_tensors=tensors,
                                           horizon=horizon), state)
    t = jnp.where(out.dead, jnp.maximum(out.t, horizon), out.t)
    return out._replace(t=t)
