"""First-class data streams (paper guideline G1).

Trajectory statistics flow out of the engine as a stream of
(sim-time, Stats) records. Sinks attach as callbacks; the CSV sink
writes incrementally (no trajectory is ever fully buffered — schema
iii's memory bound). A bounded in-memory buffer with drop-oldest
backpressure mirrors the FastFlow buffered collector.

Sinks have an explicit lifecycle: anything exposing `close()` is closed
by `StatsStream.close()`, which `repro.api.simulate()` and the CLI call
when a run completes. `CsvSink` holds its file handle open for the whole
run and flushes once on close (not per row).
"""
from __future__ import annotations

import collections
import csv
from dataclasses import dataclass
from typing import Callable, Optional

import numpy as np


@dataclass
class StatsRecord:
    t: float
    window: int
    mean: np.ndarray  # (n_obs,)
    var: np.ndarray
    ci90: np.ndarray
    n: float


class StatsStream:
    """Push-based stream with bounded buffering."""

    def __init__(self, maxlen: int = 100_000):
        self.buffer: collections.deque = collections.deque(maxlen=maxlen)
        self.sinks: list[Callable[[StatsRecord], None]] = []
        self.dropped = 0

    def attach(self, sink: Callable[[StatsRecord], None]) -> None:
        self.sinks.append(sink)

    def emit(self, rec: StatsRecord) -> None:
        if len(self.buffer) == self.buffer.maxlen:
            self.dropped += 1
        self.buffer.append(rec)
        for s in self.sinks:
            s(rec)

    def records(self) -> list[StatsRecord]:
        return list(self.buffer)

    def close(self) -> None:
        """Close every sink that has a close() lifecycle."""
        for s in self.sinks:
            close = getattr(s, "close", None)
            if callable(close):
                close()


class CsvSink:
    """Incremental CSV writer for the stats stream.

    One open file handle for the run; rows go through the OS buffer and
    are flushed on close() (per-row flushing dominated small-window
    runs). Usable as a context manager; `StatsStream.close()` /
    `simulate()` close it automatically, and the finaliser is a safety
    net for abandoned handles.
    """

    def __init__(self, path: str, obs_names: list[str]):
        self.path = path
        self.obs_names = list(obs_names)
        self._f = open(path, "w", newline="")
        self._w = csv.writer(self._f)
        header = ["t", "n"]
        for n in self.obs_names:
            header += [f"{n}_mean", f"{n}_var", f"{n}_ci90"]
        self._w.writerow(header)
        self.closed = False

    def __call__(self, rec: StatsRecord) -> None:
        if self.closed:
            raise ValueError(f"CsvSink({self.path!r}) is closed")
        row = [f"{rec.t:.6g}", f"{rec.n:.0f}"]
        for i in range(len(self.obs_names)):
            row += [f"{rec.mean[i]:.6g}", f"{rec.var[i]:.6g}",
                    f"{rec.ci90[i]:.6g}"]
        self._w.writerow(row)

    def close(self) -> None:
        if not self.closed:
            self.closed = True
            self._f.flush()
            self._f.close()

    def __enter__(self) -> "CsvSink":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self):  # safety net — prefer explicit close()
        try:
            self.close()
        except Exception:
            pass


def csv_sink(path: str, obs_names: list[str]) -> CsvSink:
    """Back-compat constructor for CsvSink (old functional sink API)."""
    return CsvSink(path, obs_names)
