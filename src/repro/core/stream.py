"""First-class streams (paper guideline G1): random numbers in,
statistics out.

OUT: trajectory statistics flow out of the engine as a stream of
(sim-time, Stats) records. Sinks attach as callbacks; the CSV sink
writes incrementally (no trajectory is ever fully buffered — schema
iii's memory bound). A bounded in-memory buffer with drop-oldest
backpressure mirrors the FastFlow buffered collector.

Sinks have an explicit lifecycle: anything exposing `close()` is closed
by `StatsStream.close()`, which `repro.api.simulate()` and the CLI call
when a run completes. `CsvSink` holds its file handle open for the whole
run and flushes once on close (not per row).

IN: every lane consumes a counter-based random-number stream
(`counter_uniforms`): draw n of lane (k0, k1) is threefry2x32 applied
to the 64-bit counter block (n_lo, n_hi) under key (k0, k1) — both
cipher counter words are live, so the per-lane period is 2^64 draws
(the low word wraps with a carry into the high word, `ctr_add`).
Because a draw is a
pure function of (lane key, event index) — no chained key splitting —
the fused Pallas kernel, the unfused jnp path, resume-from-checkpoint,
and any chunk size all consume the *identical* stream, and the kernel
can generate its uniforms in VREGs with zero HBM traffic
(DESIGN.md §3c). The block cipher below is the standard 20-round
threefry2x32 (Salmon et al., SC'11), written in plain `jnp` uint32 ops
so the same code runs inside a Pallas kernel body and in host-traced
jit code, bitwise identically.
"""
from __future__ import annotations

import collections
import csv
from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

# ------------------------------------------------------------------ RNG
#: uniforms are clamped to [U_MIN, 1) so -log(u) stays finite
U_MIN = 1e-12

_ROT = (13, 15, 26, 6, 17, 29, 16, 24)


def threefry2x32(k0, k1, c0, c1):
    """One threefry2x32 block: counter (c0, c1) under key (k0, k1).

    All arguments are uint32 arrays of one broadcastable shape; returns
    two uint32 arrays of random bits. Elementwise, so it vectorises over
    the lane axis and runs unchanged inside a Pallas kernel (VREG ops
    only: add/xor/rotate).
    """

    def rotl(x, r):
        return (x << jnp.uint32(r)) | (x >> jnp.uint32(32 - r))

    ks = (k0, k1, k0 ^ k1 ^ jnp.uint32(0x1BD11BDA))
    x0 = c0 + ks[0]
    x1 = c1 + ks[1]
    for block in range(5):
        rots = _ROT[:4] if block % 2 == 0 else _ROT[4:]
        for r in rots:
            x0 = x0 + x1
            x1 = rotl(x1, r) ^ x0
        x0 = x0 + ks[(block + 1) % 3]
        x1 = x1 + ks[(block + 2) % 3] + jnp.uint32(block + 1)
    return x0, x1


def bits_to_uniform(bits):
    """uint32 random bits -> float32 uniform on [U_MIN, 1).

    Standard mantissa trick: the top 23 bits become the mantissa of a
    float in [1, 2), shifted down to [0, 1) — exact, division-free, and
    expressible in a kernel (bitcast + subtract).
    """
    f = jax.lax.bitcast_convert_type(
        (bits >> jnp.uint32(9)) | jnp.uint32(0x3F800000), jnp.float32)
    return jnp.maximum(f - 1.0, U_MIN)


def counter_uniforms(k0, k1, ctr, ctr_hi=None):
    """(u1, u2) for event index `ctr` of the lane streams keyed (k0, k1).

    k0/k1/ctr: uint32 arrays (any matching shape; typically (B,));
    ctr_hi: optional uint32 high counter word (defaults to 0 — bitwise
    identical to the historical single-word stream). One threefry block
    yields both uniforms an SSA event consumes (tau and the reaction
    choice); tau-leaping consumes several blocks per leap.

    The draw index is the 64-bit (ctr_hi, ctr) pair fed to the cipher's
    two counter words, so a single lane's stream period is 2^64 draws —
    unreachable. `ctr_add` is the one carry implementation every path
    (host-traced, Pallas kernel, checkpoint restore) shares, which is
    what keeps the low-word wrap bitwise reproducible too.
    """
    if ctr_hi is None:
        ctr_hi = jnp.zeros_like(ctr)
    b0, b1 = threefry2x32(k0, k1, ctr, ctr_hi)
    return bits_to_uniform(b0), bits_to_uniform(b1)


def ctr_add(ctr, ctr_hi, inc):
    """64-bit counter bump as two uint32 words: (lo, hi) after lo += inc
    with carry into hi. `inc` is uint32 (< 2^32), so the wrap test is a
    single unsigned compare. Plain jnp ops — runs unchanged inside a
    Pallas kernel body and in host-traced code, bitwise identically."""
    lo = ctr + inc
    return lo, ctr_hi + (lo < ctr).astype(jnp.uint32)


@dataclass
class StatsRecord:
    t: float
    window: int
    mean: np.ndarray  # (n_obs,)
    var: np.ndarray
    ci90: np.ndarray
    n: float


class StatsStream:
    """Push-based stream with bounded buffering."""

    def __init__(self, maxlen: int = 100_000):
        self.buffer: collections.deque = collections.deque(maxlen=maxlen)
        self.sinks: list[Callable[[StatsRecord], None]] = []
        self.dropped = 0

    def attach(self, sink: Callable[[StatsRecord], None]) -> None:
        self.sinks.append(sink)

    def emit(self, rec: StatsRecord) -> None:
        if len(self.buffer) == self.buffer.maxlen:
            self.dropped += 1
        self.buffer.append(rec)
        for s in self.sinks:
            s(rec)

    def records(self) -> list[StatsRecord]:
        return list(self.buffer)

    def close(self) -> None:
        """Close every sink that has a close() lifecycle."""
        for s in self.sinks:
            close = getattr(s, "close", None)
            if callable(close):
                close()


class CsvSink:
    """Incremental CSV writer for the stats stream.

    One open file handle for the run; rows go through the OS buffer and
    are flushed on close() (per-row flushing dominated small-window
    runs). Usable as a context manager; `StatsStream.close()` /
    `simulate()` close it automatically, and the finaliser is a safety
    net for abandoned handles.
    """

    def __init__(self, path: str, obs_names: list[str]):
        self.path = path
        self.obs_names = list(obs_names)
        self._f = open(path, "w", newline="")
        self._w = csv.writer(self._f)
        header = ["t", "n"]
        for n in self.obs_names:
            header += [f"{n}_mean", f"{n}_var", f"{n}_ci90"]
        self._w.writerow(header)
        self.closed = False

    def __call__(self, rec: StatsRecord) -> None:
        if self.closed:
            raise ValueError(f"CsvSink({self.path!r}) is closed")
        row = [f"{rec.t:.6g}", f"{rec.n:.0f}"]
        for i in range(len(self.obs_names)):
            row += [f"{rec.mean[i]:.6g}", f"{rec.var[i]:.6g}",
                    f"{rec.ci90[i]:.6g}"]
        self._w.writerow(row)

    def close(self) -> None:
        if not self.closed:
            self.closed = True
            self._f.flush()
            self._f.close()

    def __enter__(self) -> "CsvSink":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self):  # safety net — prefer explicit close()
        try:
            self.close()
        except Exception:
            pass


def csv_sink(path: str, obs_names: list[str]) -> CsvSink:
    """Back-compat constructor for CsvSink (old functional sink API)."""
    return CsvSink(path, obs_names)
