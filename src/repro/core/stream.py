"""First-class data streams (paper guideline G1).

Trajectory statistics flow out of the engine as a stream of
(sim-time, Stats) records. Sinks attach as callbacks; the CSV sink
writes incrementally (no trajectory is ever fully buffered — schema
iii's memory bound). A bounded in-memory buffer with drop-oldest
backpressure mirrors the FastFlow buffered collector.
"""
from __future__ import annotations

import collections
import csv
import io
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np


@dataclass
class StatsRecord:
    t: float
    window: int
    mean: np.ndarray  # (n_obs,)
    var: np.ndarray
    ci90: np.ndarray
    n: float


class StatsStream:
    """Push-based stream with bounded buffering."""

    def __init__(self, maxlen: int = 100_000):
        self.buffer: collections.deque = collections.deque(maxlen=maxlen)
        self.sinks: list[Callable[[StatsRecord], None]] = []
        self.dropped = 0

    def attach(self, sink: Callable[[StatsRecord], None]) -> None:
        self.sinks.append(sink)

    def emit(self, rec: StatsRecord) -> None:
        if len(self.buffer) == self.buffer.maxlen:
            self.dropped += 1
        self.buffer.append(rec)
        for s in self.sinks:
            s(rec)

    def records(self) -> list[StatsRecord]:
        return list(self.buffer)


def csv_sink(path: str, obs_names: list[str]) -> Callable[[StatsRecord], None]:
    f = open(path, "w", newline="")
    w = csv.writer(f)
    header = ["t", "n"]
    for n in obs_names:
        header += [f"{n}_mean", f"{n}_var", f"{n}_ci90"]
    w.writerow(header)

    def sink(rec: StatsRecord) -> None:
        row = [f"{rec.t:.6g}", f"{rec.n:.0f}"]
        for i in range(len(obs_names)):
            row += [f"{rec.mean[i]:.6g}", f"{rec.var[i]:.6g}",
                    f"{rec.ci90[i]:.6g}"]
        w.writerow(row)
        f.flush()

    return sink
