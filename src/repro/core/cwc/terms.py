"""CWC terms (paper §2.1).

A term is a multiset of simple terms; a simple term is an atom or a
compartment (wrap | content)^label. Multisets are collections.Counter
over atom names; compartments are explicit objects so nesting is
preserved. This symbolic representation feeds both the faithful
sequential simulator (reference.py) and the tensorising compiler
(compile.py).
"""
from __future__ import annotations

from collections import Counter
from dataclasses import dataclass, field
from typing import Iterator, Optional

TOP = "⊤"  # the top-level compartment label


@dataclass
class Compartment:
    label: str
    wrap: Counter  # atoms on the membrane
    content: "Term"

    def copy(self) -> "Compartment":
        return Compartment(self.label, Counter(self.wrap), self.content.copy())


@dataclass
class Term:
    """Multiset of atoms + list of compartments."""

    atoms: Counter = field(default_factory=Counter)
    compartments: list = field(default_factory=list)

    def copy(self) -> "Term":
        return Term(Counter(self.atoms),
                    [c.copy() for c in self.compartments])

    def walk(self, path=()) -> Iterator[tuple[tuple, str, "Term"]]:
        """Yield (path, label, content) for every compartment context,
        including the top level."""
        label = TOP if not path else None
        yield path, label, self
        for i, comp in enumerate(self.compartments):
            yield from _walk_comp(comp, path + (i,))

    def total_atoms(self) -> int:
        return (sum(self.atoms.values())
                + sum(c.content.total_atoms() + sum(c.wrap.values())
                      for c in self.compartments))


def _walk_comp(comp: Compartment, path) -> Iterator:
    yield path, comp.label, comp.content
    for i, sub in enumerate(comp.content.compartments):
        yield from _walk_comp(sub, path + (i,))


def atoms(*names: str, **counts: int) -> Counter:
    c = Counter()
    for n in names:
        c[n] += 1
    for n, k in counts.items():
        c[n] += k
    return c


def term(atom_counts: Optional[dict] = None, comps: Optional[list] = None) -> Term:
    return Term(Counter(atom_counts or {}), comps or [])


def comp(label: str, wrap: Optional[dict] = None,
         content: Optional[Term] = None) -> Compartment:
    return Compartment(label, Counter(wrap or {}), content or Term())
