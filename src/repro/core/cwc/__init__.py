"""Calculus of Wrapped Compartments: terms, rules, compiler, reference."""
