"""Faithful sequential CWC simulator — the paper's Fig. 3 pseudo-code.

This is the reproduction of the ORIGINAL tool (§2.3): Match walks the
nested term recursively building a weighted matchset (binomial
combination counting); Resolve draws (tau, mu) per Gillespie; Update
rewrites the matched compartment in place. Pure Python + numpy RNG —
deliberately unvectorised; it is both the fidelity baseline (fig-4
style measurements) and the oracle for the tensorised engine.
"""
from __future__ import annotations

import math
from collections import Counter

import numpy as np

from repro.core.cwc.rules import CWCModel, Rule, TransportRule
from repro.core.cwc.terms import TOP, Compartment, Term


class Match:
    __slots__ = ("rule", "path", "rate", "child_index")

    def __init__(self, rule, path, rate, child_index=None):
        self.rule = rule
        self.path = path
        self.rate = rate
        self.child_index = child_index


def _content_at(term: Term, path) -> Term:
    node = term
    for i in path:
        node = node.compartments[i].content
    return node


def _label_at(term: Term, path) -> str:
    if not path:
        return TOP
    node = term
    for i in path[:-1]:
        node = node.compartments[i].content
    return node.compartments[path[-1]].label


def match_populations(lhs: Counter, content: Counter) -> float:
    """Paper Fig. 3 Match_Populations: product of binomials."""
    count = 1.0
    for atom, k in lhs.items():
        n = content.get(atom, 0)
        if n < k:
            return 0.0
        count *= math.comb(n, k)
    return count


def build_matchset(term: Term, rules) -> list:
    """Paper Fig. 3 Match: recursive walk over the subject tree."""
    matchset = []

    def visit(path):
        content = _content_at(term, path)
        label = _label_at(term, path)
        for r in rules:
            if isinstance(r, Rule) and r.label == label:
                cnt = match_populations(r.lhs_counter(), content.atoms)
                if cnt > 0:
                    matchset.append(Match(r, path, cnt * r.k))
            elif isinstance(r, TransportRule) and r.label == label:
                for i, comp in enumerate(content.compartments):
                    if comp.label != r.child_label:
                        continue
                    if r.direction == "in":
                        n = content.atoms.get(r.atom, 0)
                    else:
                        n = comp.content.atoms.get(r.atom, 0)
                    if n > 0:
                        matchset.append(Match(r, path, n * r.k, i))
        for i in range(len(content.compartments)):
            visit(path + (i,))  # recursive step [non-SIMD in the paper]

    visit(())
    return matchset


def apply_match(term: Term, m: Match) -> None:
    """Paper Fig. 3 Update (in place)."""
    content = _content_at(term, m.path)
    if isinstance(m.rule, Rule):
        for a, c in m.rule.lhs:
            content.atoms[a] -= c
            if content.atoms[a] <= 0:
                del content.atoms[a]
        for a, c in m.rule.rhs:
            content.atoms[a] += c
    else:  # transport
        child = content.compartments[m.child_index].content
        src, dst = ((content, child) if m.rule.direction == "in"
                    else (child, content))
        src.atoms[m.rule.atom] -= 1
        if src.atoms[m.rule.atom] <= 0:
            del src.atoms[m.rule.atom]
        dst.atoms[m.rule.atom] += 1


def simulation_step(term: Term, rules, t: float, rng) -> tuple[float, bool]:
    """One Match/Resolve/Update step. Returns (new_t, alive)."""
    matchset = build_matchset(term, rules)
    if not matchset:
        return t, False
    rates = np.array([m.rate for m in matchset])
    r_total = rates.sum()
    tau = rng.exponential(1.0 / r_total)
    mu = rng.choice(len(matchset), p=rates / r_total)
    apply_match(term, matchset[mu])
    return t + tau, True


def simulate(model: CWCModel, t_grid, seed: int = 0,
             observe=None) -> np.ndarray:
    """Run one trajectory, sampling observables on t_grid.

    Returns (len(t_grid), n_observables). observe(term) -> tuple
    defaults to the model's (label, atom) observables summed over
    matching compartments.
    """
    rng = np.random.default_rng(seed)
    term = model.initial_term()
    rules = model.rules
    if observe is None:
        def observe(term):
            out = []
            for label, atom in model.observables:
                tot = 0
                for path, lab, content in term.walk():
                    eff = lab if lab is not None else _label_at(term, path)
                    if eff == label:
                        tot += content.atoms.get(atom, 0)
                out.append(tot)
            return out

    t = 0.0
    alive = True
    samples = []
    # peek-ahead stepping: freeze state when the next event crosses a grid
    # point (memoryless redraw afterwards, as in the tensor engine)
    for t_target in t_grid:
        while alive and t < t_target:
            matchset = build_matchset(term, rules)
            if not matchset:
                alive = False
                break
            rates = np.array([m.rate for m in matchset])
            r_total = rates.sum()
            tau = rng.exponential(1.0 / r_total)
            if t + tau > t_target:
                t = t_target
                break
            mu = rng.choice(len(matchset), p=rates / r_total)
            apply_match(term, matchset[mu])
            t += tau
        samples.append(observe(term))
    return np.asarray(samples, np.float64)


def matchset_rates(model: CWCModel, term: Term) -> dict:
    """name -> rate for the current term (oracle for compiled propensities)."""
    out = {}
    for m in build_matchset(term, model.rules):
        label = _label_at(term, m.path)
        key = (getattr(m.rule, "name", "") or str(m.rule), m.path,
               m.child_index)
        out[key] = out.get(key, 0.0) + m.rate
    return out
