"""CWC model library (the paper's experimental systems).

* `lotka_volterra(n)` — the n-species prey/predator chains of Fig. 4
  (n=2 is the classic model used in Fig. 7).
* `ecoli_gene_regulation()` — gene regulation with negative feedback in
  an E. coli cell compartment (the Fig. 1 experiment's model family).
* `membrane_transport()` — compartment demo: molecules crossing a cell
  membrane, exercising the CWC compartment fragment.
* `ring{8,80}` / `lattice8x8` — generated large structured models
  (`cwc.compile.cell_ring_model` / `cell_lattice_model`): a local
  gene-expression/cargo motif repeated over a ring or torus of coupled
  cells. Hundreds of species/reactions with motif-bounded dependency
  out-degree — the sparse engine's target class (DESIGN.md §3g).
"""
from __future__ import annotations

from repro.core.cwc.compile import cell_lattice_model, cell_ring_model
from repro.core.cwc.rules import CWCModel, Rule, TransportRule
from repro.core.cwc.terms import TOP, comp, term


def lotka_volterra(n_species: int = 2, k_reproduce: float = 1.0,
                   k_eat: float = 0.005, k_die: float = 0.6,
                   prey0: int = 1000, pred0: int = 1000) -> CWCModel:
    """n-species cyclic prey/predator chain (n=2: classic LV).

    Species s_i preys on s_{i-1}; s_0 reproduces; the last dies.
    """
    assert n_species >= 2
    names = [f"s{i}" for i in range(n_species)]
    rules = [Rule.make(TOP, {names[0]: 1}, {names[0]: 2}, k_reproduce,
                       "reproduce")]
    for i in range(1, n_species):
        rules.append(Rule.make(
            TOP, {names[i - 1]: 1, names[i]: 1}, {names[i]: 2},
            k_eat, f"eat{i}"))
    rules.append(Rule.make(TOP, {names[-1]: 1}, {}, k_die, "die"))

    init_atoms = {names[0]: prey0, names[-1]: pred0}
    for i in range(1, n_species - 1):
        init_atoms[names[i]] = 100

    return CWCModel(
        rules=tuple(rules),
        init_fn=lambda: term(init_atoms),
        observables=tuple((TOP, n) for n in names),
        name=f"lotka-volterra-{n_species}",
    )


def ecoli_gene_regulation(k_transcribe: float = 0.5,
                          k_translate: float = 0.12,
                          k_mrna_decay: float = 0.06,
                          k_prot_decay: float = 0.02,
                          k_bind: float = 0.0005,
                          k_unbind: float = 0.2) -> CWCModel:
    """Gene regulation with negative feedback inside an `ecoli` cell:

      gene        -> gene + mrna       (transcription)
      mrna        -> mrna + protein    (translation)
      mrna        -> ∅                 (decay)
      protein     -> ∅                 (decay)
      gene + protein <-> gene_blocked  (repression)
    """
    L = "ecoli"
    rules = (
        Rule.make(L, {"gene": 1}, {"gene": 1, "mrna": 1}, k_transcribe,
                  "transcribe"),
        Rule.make(L, {"mrna": 1}, {"mrna": 1, "protein": 1}, k_translate,
                  "translate"),
        Rule.make(L, {"mrna": 1}, {}, k_mrna_decay, "mrna-decay"),
        Rule.make(L, {"protein": 1}, {}, k_prot_decay, "protein-decay"),
        Rule.make(L, {"gene": 1, "protein": 1}, {"gene_blocked": 1}, k_bind,
                  "repress"),
        Rule.make(L, {"gene_blocked": 1}, {"gene": 1, "protein": 1},
                  k_unbind, "derepress"),
    )

    def init():
        return term(comps=[comp(L, wrap={"m": 1},
                                content=term({"gene": 10}))])

    return CWCModel(rules=rules, init_fn=init,
                    observables=((L, "mrna"), (L, "protein")),
                    name="ecoli-gene-regulation")


def membrane_transport(k_in: float = 0.1, k_out: float = 0.05,
                       k_react: float = 0.01, n0: int = 500) -> CWCModel:
    """Nutrient `a` diffuses into a cell, reacts to product `b`, which
    is exported. Exercises TransportRules across the membrane."""
    L = "cell"
    rules = (
        TransportRule(TOP, "a", L, "in", k_in, "uptake"),
        Rule.make(L, {"a": 2}, {"b": 1}, k_react, "dimerise"),
        TransportRule(TOP, "b", L, "out", k_out, "export"),
    )

    def init():
        return term({"a": n0}, comps=[comp(L, content=term({}))])

    return CWCModel(rules=rules, init_fn=init,
                    observables=((TOP, "a"), (L, "a"), (L, "b"), (TOP, "b")),
                    name="membrane-transport")


MODELS = {
    "lv2": lambda: lotka_volterra(2),
    "lv4": lambda: lotka_volterra(4),
    "lv8": lambda: lotka_volterra(8),
    "ecoli": ecoli_gene_regulation,
    "transport": membrane_transport,
    "ring8": lambda: cell_ring_model(8),       # S=32, R=56 (tests)
    "ring80": lambda: cell_ring_model(80),     # S=320, R=560 (bench)
    "lattice8x8": lambda: cell_lattice_model(8, 8),  # S=256, R=512
}
