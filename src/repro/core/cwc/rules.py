"""CWC stochastic rewrite rules (paper §2.1–2.2).

Supported rule forms (the tensorisable fragment — DESIGN.md §6):

* `Rule(label, lhs, rhs, k)` — atom rewriting inside compartments of
  type `label`:  ℓ : a b X  -k->  c X   (X = rest of content, implicit).
* `TransportRule(label, atom, child_label, direction, k)` — an atom
  crosses the membrane of a child compartment with type `child_label`
  inside a compartment of type `label` ("in"), or leaves it ("out").
  One reaction is instantiated per (parent context, child instance).

Rules that create/destroy compartments fall outside this fragment and
are handled by the sequential reference simulator only (documented
restriction).
"""
from __future__ import annotations

from collections import Counter
from dataclasses import dataclass, field
from typing import Optional


@dataclass(frozen=True)
class Rule:
    label: str  # compartment type the rule applies in
    lhs: tuple  # sorted ((atom, coef), ...)
    rhs: tuple
    k: float
    name: str = ""

    @staticmethod
    def make(label: str, lhs: dict, rhs: dict, k: float, name: str = "") -> "Rule":
        return Rule(label, tuple(sorted(lhs.items())),
                    tuple(sorted(rhs.items())), float(k),
                    name or f"{label}:{lhs}->{rhs}")

    def lhs_counter(self) -> Counter:
        return Counter(dict(self.lhs))

    def rhs_counter(self) -> Counter:
        return Counter(dict(self.rhs))


@dataclass(frozen=True)
class TransportRule:
    label: str  # parent compartment type
    atom: str
    child_label: str
    direction: str  # "in" | "out"
    k: float
    name: str = ""

    def __post_init__(self):
        assert self.direction in ("in", "out")


@dataclass(frozen=True)
class CWCModel:
    """Initial term + rules + observables."""

    rules: tuple
    init_fn: object  # () -> Term (kept callable so instances are fresh)
    observables: tuple  # (compartment-path-label, atom) pairs to report
    name: str = "cwc-model"

    def initial_term(self):
        return self.init_fn()
