"""CWC → ReactionSystem compiler (the compile-time tree matching).

The paper's Match phase walks the subject tree per step (§2.3, the
non-SIMD part, Fig. 3). For static compartment topologies we hoist that
walk to compile time: every compartment instance in the initial term is
enumerated once; each (rule, matching compartment instance) pair
becomes one dense reaction. The run-time Match is then the propensity
matrix — fully vectorised (DESIGN.md §2/§6).
"""
from __future__ import annotations

from collections import Counter

import numpy as np

from repro.core.cwc.rules import CWCModel, Rule, TransportRule
from repro.core.cwc.terms import TOP, Term, comp, term
from repro.core.reactions import MAX_REACTANTS, ReactionSystem, make_system


def compile_model(model: CWCModel) -> tuple[ReactionSystem, dict]:
    """Returns (system, meta). meta maps species index -> (path, atom)
    and lists per-observable species indices."""
    t0 = model.initial_term()

    # 1. enumerate compartment contexts (path () = top level)
    contexts: list[tuple[tuple, str]] = []  # (path, label)
    content_by_path: dict = {}
    for path, label, content in t0.walk():
        if label is None:
            # nested compartment label — recover from the object
            node = t0
            for i in path[:-1]:
                node = node.compartments[i].content
            label = node.compartments[path[-1]].label
        contexts.append((path, label))
        content_by_path[path] = content

    # 2. alphabet per context: atoms in the initial content + any atom
    #    mentioned by a rule applicable to the context's label
    alphabet: dict = {}
    for path, label in contexts:
        names = set(content_by_path[path].atoms)
        for r in model.rules:
            if isinstance(r, Rule) and r.label == label:
                names |= {a for a, _ in r.lhs} | {a for a, _ in r.rhs}
            if isinstance(r, TransportRule):
                if r.label == label:
                    names.add(r.atom)
                if r.child_label == label:
                    names.add(r.atom)
        alphabet[path] = sorted(names)

    species = []
    sidx = {}
    for path, label in contexts:
        for a in alphabet[path]:
            sidx[(path, a)] = len(species)
            species.append(f"{_path_str(path, label)}/{a}")

    # 3. instantiate reactions
    reactions = []
    names = []
    for path, label in contexts:
        for r in model.rules:
            if isinstance(r, Rule) and r.label == label:
                lhs = {_species_name(path, label, a): c for a, c in r.lhs}
                rhs = {_species_name(path, label, a): c for a, c in r.rhs}
                reactions.append((lhs, rhs, r.k))
                names.append(f"{r.name}@{_path_str(path, label)}")
            elif isinstance(r, TransportRule) and r.label == label:
                # one reaction per child instance with the right label
                for i, compi in enumerate(content_by_path[path].compartments):
                    if compi.label != r.child_label:
                        continue
                    child_path = path + (i,)
                    parent_sp = _species_name(path, label, r.atom)
                    child_sp = _species_name(child_path, compi.label, r.atom)
                    if r.direction == "in":
                        lhs, rhs = {parent_sp: 1}, {child_sp: 1}
                    else:
                        lhs, rhs = {child_sp: 1}, {parent_sp: 1}
                    reactions.append((lhs, rhs, r.k))
                    names.append(
                        f"{r.name or 'transport'}@{_path_str(path, label)}"
                        f"->{i}")

    # 4. initial state
    x0 = {}
    for path, label in contexts:
        for a, c in content_by_path[path].atoms.items():
            x0[_species_name(path, label, a)] = c

    # reactions/x0 already use species-name keys; make_system maps them
    # onto the canonical species order
    sys = make_system(species, reactions, x0, names)

    obs_idx = {}
    for obs in model.observables:
        path_label, atom = obs
        for (path, label) in contexts:
            if _path_str(path, label) == path_label or label == path_label:
                key = f"{_path_str(path, label)}/{atom}"
                if key in species:
                    obs_idx.setdefault(f"{path_label}/{atom}", []).append(
                        species.index(key))
    meta = {"species": species, "observables": obs_idx}
    return sys, meta


# ---------------------------------------------------------------------
# Large structured model generators (the sparse engine's target class).
#
# Real compartmentalised models scale by REPEATING a motif over a
# topology — a ring of coupled cells, a tissue lattice — not by making
# one compartment's chemistry huge. Compiled through `compile_model`,
# n coupled cells become S ≈ 4n species and R ≈ 7n reactions whose
# dependency graph has out-degree bounded by the motif (≈ 5), NOT by n:
# firing a reaction in cell i touches only cell i's species and the
# shared carrier slot for cell i, so the sparse engine's per-event cost
# stays O(1) in the number of cells while the dense path pays O(R).


def cell_ring_model(n_cells: int, k_express: float = 4.0,
                    k_decay: float = 0.05, k_dim: float = 0.002,
                    k_unpack: float = 0.5, k_hop: float = 1.0,
                    k_export: float = 0.3, k_import: float = 0.8,
                    p0: int = 40) -> CWCModel:
    """A ring of `n_cells` coupled cells passing a cargo clockwise.

    Cell i (compartment label ``c{i}``) runs a local motif —

      g        -> g + p      (express)
      p        -> ∅          (decay)
      2 p      -> w{i}       (dimerise: packages cargo; coefficient 2)
      w{i}     -> 2 p        (unpack: received cargo releases payload)

    — and couples to its clockwise neighbour through the top level:
    ``w{i}`` is exported out of cell i, relabelled ``w{(i+1) % n}`` by a
    TOP hop rule, and imported into cell i+1. The cargo atom is named
    per DESTINATION slot, so each TOP species is consumed by exactly
    one import and one hop: the reaction dependency graph stays
    motif-bounded (max out-degree ~5) no matter how large the ring is.

    Sizes: S = 4n (g, p, w{i} per cell + n TOP carrier slots),
    R = 7n (4 local + hop + export + import per cell).
    """
    if n_cells < 2:
        raise ValueError(f"cell_ring_model needs >= 2 cells, "
                         f"got {n_cells}")
    rules = []
    for i in range(n_cells):
        lab, w, w_next = f"c{i}", f"w{i}", f"w{(i + 1) % n_cells}"
        rules += [
            Rule.make(lab, {"g": 1}, {"g": 1, "p": 1}, k_express,
                      f"express{i}"),
            Rule.make(lab, {"p": 1}, {}, k_decay, f"decay{i}"),
            Rule.make(lab, {"p": 2}, {w: 1}, k_dim, f"dimerise{i}"),
            Rule.make(lab, {w: 1}, {"p": 2}, k_unpack, f"unpack{i}"),
            # at TOP the cargo is relabelled for its destination cell
            Rule.make(TOP, {w: 1}, {w_next: 1}, k_hop, f"hop{i}"),
            TransportRule(TOP, w, lab, "out", k_export, f"export{i}"),
            TransportRule(TOP, w, lab, "in", k_import, f"import{i}"),
        ]

    def init(n=n_cells, p0=p0):
        return term(comps=[comp(f"c{i}", content=term({"g": 1, "p": p0}))
                           for i in range(n)])

    return CWCModel(
        rules=tuple(rules), init_fn=init,
        observables=(("c0", "p"), ("c0", "w0"), (TOP, "w0")),
        name=f"cell-ring-{n_cells}")


def cell_lattice_model(rows: int, cols: int, k_express: float = 4.0,
                       k_decay: float = 0.05, k_dim: float = 0.002,
                       k_unpack: float = 0.5, k_hop: float = 1.0,
                       k_export: float = 0.3, k_import: float = 0.8,
                       p0: int = 40) -> CWCModel:
    """`cell_ring_model`'s motif on a rows × cols torus: each cell's
    exported cargo hops east or south with equal rate, so every TOP
    carrier is consumed by TWO hop rules + one import (out-degree still
    motif-bounded). Sizes: S = 4·rows·cols, R = 8·rows·cols."""
    if rows < 1 or cols < 1 or rows * cols < 2:
        raise ValueError(f"cell_lattice_model needs >= 2 cells, "
                         f"got {rows}x{cols}")
    n = rows * cols

    def cid(r, c):
        return (r % rows) * cols + (c % cols)

    rules = []
    for r in range(rows):
        for c in range(cols):
            i = cid(r, c)
            lab, w = f"c{i}", f"w{i}"
            w_east, w_south = f"w{cid(r, c + 1)}", f"w{cid(r + 1, c)}"
            rules += [
                Rule.make(lab, {"g": 1}, {"g": 1, "p": 1}, k_express,
                          f"express{i}"),
                Rule.make(lab, {"p": 1}, {}, k_decay, f"decay{i}"),
                Rule.make(lab, {"p": 2}, {w: 1}, k_dim, f"dimerise{i}"),
                Rule.make(lab, {w: 1}, {"p": 2}, k_unpack, f"unpack{i}"),
                Rule.make(TOP, {w: 1}, {w_east: 1}, k_hop, f"hop-e{i}"),
                Rule.make(TOP, {w: 1}, {w_south: 1}, k_hop, f"hop-s{i}"),
                TransportRule(TOP, w, lab, "out", k_export, f"export{i}"),
                TransportRule(TOP, w, lab, "in", k_import, f"import{i}"),
            ]

    def init(n=n, p0=p0):
        return term(comps=[comp(f"c{i}", content=term({"g": 1, "p": p0}))
                           for i in range(n)])

    return CWCModel(
        rules=tuple(rules), init_fn=init,
        observables=(("c0", "p"), ("c0", "w0"), (TOP, "w0")),
        name=f"cell-lattice-{rows}x{cols}")


def _path_str(path, label) -> str:
    return (label if not path else
            f"{label}[{'.'.join(map(str, path))}]")


def _species_name(path, label, atom) -> str:
    return f"{_path_str(path, label)}/{atom}"
