"""CWC → ReactionSystem compiler (the compile-time tree matching).

The paper's Match phase walks the subject tree per step (§2.3, the
non-SIMD part, Fig. 3). For static compartment topologies we hoist that
walk to compile time: every compartment instance in the initial term is
enumerated once; each (rule, matching compartment instance) pair
becomes one dense reaction. The run-time Match is then the propensity
matrix — fully vectorised (DESIGN.md §2/§6).
"""
from __future__ import annotations

from collections import Counter

import numpy as np

from repro.core.cwc.rules import CWCModel, Rule, TransportRule
from repro.core.cwc.terms import TOP, Term
from repro.core.reactions import MAX_REACTANTS, ReactionSystem, make_system


def compile_model(model: CWCModel) -> tuple[ReactionSystem, dict]:
    """Returns (system, meta). meta maps species index -> (path, atom)
    and lists per-observable species indices."""
    t0 = model.initial_term()

    # 1. enumerate compartment contexts (path () = top level)
    contexts: list[tuple[tuple, str]] = []  # (path, label)
    content_by_path: dict = {}
    for path, label, content in t0.walk():
        if label is None:
            # nested compartment label — recover from the object
            node = t0
            for i in path[:-1]:
                node = node.compartments[i].content
            label = node.compartments[path[-1]].label
        contexts.append((path, label))
        content_by_path[path] = content

    # 2. alphabet per context: atoms in the initial content + any atom
    #    mentioned by a rule applicable to the context's label
    alphabet: dict = {}
    for path, label in contexts:
        names = set(content_by_path[path].atoms)
        for r in model.rules:
            if isinstance(r, Rule) and r.label == label:
                names |= {a for a, _ in r.lhs} | {a for a, _ in r.rhs}
            if isinstance(r, TransportRule):
                if r.label == label:
                    names.add(r.atom)
                if r.child_label == label:
                    names.add(r.atom)
        alphabet[path] = sorted(names)

    species = []
    sidx = {}
    for path, label in contexts:
        for a in alphabet[path]:
            sidx[(path, a)] = len(species)
            species.append(f"{_path_str(path, label)}/{a}")

    # 3. instantiate reactions
    reactions = []
    names = []
    for path, label in contexts:
        for r in model.rules:
            if isinstance(r, Rule) and r.label == label:
                lhs = {_species_name(path, label, a): c for a, c in r.lhs}
                rhs = {_species_name(path, label, a): c for a, c in r.rhs}
                reactions.append((lhs, rhs, r.k))
                names.append(f"{r.name}@{_path_str(path, label)}")
            elif isinstance(r, TransportRule) and r.label == label:
                # one reaction per child instance with the right label
                for i, compi in enumerate(content_by_path[path].compartments):
                    if compi.label != r.child_label:
                        continue
                    child_path = path + (i,)
                    parent_sp = _species_name(path, label, r.atom)
                    child_sp = _species_name(child_path, compi.label, r.atom)
                    if r.direction == "in":
                        lhs, rhs = {parent_sp: 1}, {child_sp: 1}
                    else:
                        lhs, rhs = {child_sp: 1}, {parent_sp: 1}
                    reactions.append((lhs, rhs, r.k))
                    names.append(
                        f"{r.name or 'transport'}@{_path_str(path, label)}"
                        f"->{i}")

    # 4. initial state
    x0 = {}
    for path, label in contexts:
        for a, c in content_by_path[path].atoms.items():
            x0[_species_name(path, label, a)] = c

    # reactions/x0 already use species-name keys; make_system maps them
    # onto the canonical species order
    sys = make_system(species, reactions, x0, names)

    obs_idx = {}
    for obs in model.observables:
        path_label, atom = obs
        for (path, label) in contexts:
            if _path_str(path, label) == path_label or label == path_label:
                key = f"{_path_str(path, label)}/{atom}"
                if key in species:
                    obs_idx.setdefault(f"{path_label}/{atom}", []).append(
                        species.index(key))
    meta = {"species": species, "observables": obs_idx}
    return sys, meta


def _path_str(path, label) -> str:
    return (label if not path else
            f"{label}[{'.'.join(map(str, path))}]")


def _species_name(path, label, atom) -> str:
    return f"{_path_str(path, label)}/{atom}"
