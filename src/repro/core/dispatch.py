"""Window-dispatch strategies — the engine's execution seam.

Three ways to advance the instance pool one window, selected by
`select_dispatch` (SimulationEngine no longer branches on booleans
inside its window loop):

  host_loop : legacy per-group gather -> advance -> scatter round trips
              (the benchmark baseline);
  fused     : one jitted, donated `window_step` over the whole pool
              (device-side permutation + lax.scan over lane slices);
  sharded   : the same window body wrapped in `compat.shard_map` over a
              mesh data axis — each shard advances its contiguous slice
              of the pool locally, and per-window Welford accumulators
              (plain and grouped) are assembled device-side with ONE
              psum per window (`reduction.gather_blocks_over_axis`),
              so only O(stat_blocks x n_obs) floats ever cross shards;
              the tiny final fold is `reduction.merge_blocks`.

`use_kernel=True` composes with ALL three: the Pallas fused-window
chunk loop is a device-side `lax.while_loop` (kernels/ops.py), so the
fused strategy runs it as its one-dispatch-per-window step, the
sharded strategy runs it per shard under shard_map (the paper's two
families — single-simulation speedup × simulation farm — composed),
and the host loop keeps it per group as the baseline.

The per-lane ALGORITHM is a second, orthogonal seam
(`SimConfig.method` × `SimConfig.sparse`): the unfused bodies take the
engine-built `advance_fn(lane_slice, rates, horizon)`
(`gillespie.make_advance_fn` — exact `gillespie.ssa_step`,
`tau_leap.make_tau_step`, dense or sparse dependency-graph), the
kernel bodies take the engine-built chunk loop (exact or tau, dense or
sparse — `engine._make_chunk_loop`); every strategy × method ×
encoding pairing stays bit-identical per lane.

A third, orthogonal seam is the SUPERSTEP width
(`SimConfig.window_block`): the fused and sharded strategies expose
`advance_block(horizons)`, a `lax.scan` over W window horizons inside
ONE jitted (donated) dispatch — both the unfused window body and the
Pallas kernel chunk loop nest under the scan — accumulating per-window
observables, Welford partials, steps/leaps telemetry, and (kernel
paths) truncation flags into an on-device `(W, ...)` record ring
(`BlockResult`). The engine's async collector pulls a whole ring with
one blocking sync, so dispatches AND host syncs amortise to 1/W per
window (DESIGN.md §3e). The host loop stays per-window by design (it
is the round-trip baseline the superstep is measured against).

All paths are bit-identical per lane (counter-based per-lane RNG,
`core/stream.counter_uniforms`; identical per-lane ops — including
kernel vs unfused, see DESIGN.md §3c). The sharded path additionally
pins the statistics merge tree to `Partitioning.blocks` virtual
blocks, so its StatsRecords are bit-identical for ANY shard count
dividing the block count — including the unsharded fused path
configured with the same `stat_blocks` — which is what makes
checkpoints mesh-shape-agnostic. Supersteps preserve all of it:
the scan body IS the per-window body, and the per-window statistics
are computed by the same ops on the same values, so records are
bitwise identical for ANY `window_block` (window_block=1 runs the
unchanged legacy per-window path).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.core import reduction
from repro.core.gillespie import LaneState
from repro.stats.sketch import window_sketch


@dataclass(frozen=True)
class Partitioning:
    """How the instance pool is distributed over a device mesh.

    n_shards: devices along the farm's data axis; each owns the
    contiguous instance block [k*I/K, (k+1)*I/K).
    axis: mesh axis name (the psum/shard_map axis).
    stat_blocks: virtual blocks the per-window statistics reduce over
    (defaults to n_shards). Records depend on this number — never on
    the physical shard count — so pin it when comparing runs across
    mesh shapes or resuming a checkpoint on a different device count.
    """

    n_shards: int = 1
    axis: str = "data"
    stat_blocks: Optional[int] = None

    @property
    def blocks(self) -> int:
        return (self.stat_blocks if self.stat_blocks is not None
                else max(self.n_shards, 1))

    def validate(self, n_instances: int) -> None:
        if self.n_shards < 1:
            raise ValueError(
                f"Partitioning.n_shards must be >= 1, got {self.n_shards}")
        if not self.axis or not isinstance(self.axis, str):
            raise ValueError(
                f"Partitioning.axis must be a mesh axis name, "
                f"got {self.axis!r}")
        if n_instances % self.n_shards:
            raise ValueError(
                f"n_instances ({n_instances}) must divide evenly over "
                f"Partitioning.n_shards ({self.n_shards})")
        v = self.blocks
        if v < 1:
            raise ValueError(
                f"Partitioning.stat_blocks must be >= 1, got {v}")
        if v % self.n_shards:
            raise ValueError(
                f"Partitioning.stat_blocks ({v}) must be a multiple of "
                f"n_shards ({self.n_shards}) so each shard owns whole "
                "blocks")
        if n_instances % v:
            raise ValueError(
                f"n_instances ({n_instances}) must divide evenly into "
                f"Partitioning.stat_blocks ({v}) blocks")

    def degrade(self, n_instances: int, n_lost: int = 1) -> "Partitioning":
        """Elastic shard-loss degradation: the largest valid shard
        count d <= n_shards - n_lost — d must divide both n_instances
        and the stat-block count (d=1 always qualifies, so any loss
        short of every device degrades rather than dies).

        stat_blocks is PINNED to the ORIGINAL partitioning's block
        count: records depend on stat_blocks, never on the physical
        shard count, so a run resumed on the survivors stays bitwise
        identical to the uninterrupted one (the PR 2
        reshard-on-restore contract, now exercised by fault recovery).
        """
        if n_lost < 1:
            raise ValueError(f"n_lost must be >= 1, got {n_lost}")
        if n_lost >= self.n_shards:
            raise ValueError(
                f"cannot degrade: lost {n_lost} of {self.n_shards} "
                "shards (no survivors)")
        blocks = self.blocks
        target = self.n_shards - n_lost
        d = next(d for d in range(target, 0, -1)
                 if n_instances % d == 0 and blocks % d == 0)
        out = Partitioning(n_shards=d, axis=self.axis, stat_blocks=blocks)
        out.validate(n_instances)
        return out


class WindowResult(NamedTuple):
    """What one dispatched window hands back to the engine.

    obs: (I, n_obs) window samples (device array; sharded under the
    sharded strategy — only pulled when trajectories are buffered).
    steps_delta: per-instance events this window (None on the host path
    unless the predictive policy asked for it).
    stats / grouped: per-window Stats already reduced device-side
    (sharded strategy), or None when the engine should compute them
    from `obs`.
    truncated: device bool/int scalar on the kernel path — nonzero iff
    the fused window's chunk budget ran out with live lanes below the
    horizon (the engine raises FusedWindowTruncated); None on the
    unfused paths, whose while_loop has no chunk budget.
    sketch: (hist, rare) int32 device sketches (repro/stats) when the
    engine configured a SketchSpec — reduced device-side with one psum
    under the sharded strategy (integer sums: shard partials are
    bitwise the full-pool counts), or None when the engine should
    compute them eagerly from `obs` (fused strategy).
    """

    obs: Any
    steps_delta: Any
    stats: Optional[reduction.Stats]
    grouped: Optional[reduction.Stats]
    truncated: Any = None
    sketch: Any = None


class BlockResult(NamedTuple):
    """One superstep's on-device record ring: W windows advanced in ONE
    dispatch, every per-window product stacked over a leading (W,) axis
    and left on device until the engine's collector pulls the block.

    obs: (W, I, n_obs) per-window samples (sharded over I under the
    sharded strategy).
    steps_end / leaps_end: (W,) int32 pool-total step/leap counts at
    each window's end (cumulative — the collector takes mod-2^32
    deltas exactly like the per-window path).
    stats / grouped: length-W lists of per-window Stats already reduced
    device-side (sharded strategy — the same psum-gather + eager fold
    the per-window path uses), or None when the engine should compute
    them from `obs` rows (fused strategy, mirroring its per-window
    eager reduction).
    steps_delta: (W, I) per-instance events per window — only produced
    under the predictive policy (the scheduler's EMA costs are updated
    window-by-window at collect time; regrouping happens at block
    boundaries, which never changes a trajectory — lane groups are
    execution packaging, not semantics).
    truncated: (W,) int32 on the kernel paths — nonzero entries mark
    windows whose chunk budget ran out (the collector raises
    FusedWindowTruncated naming the first one); None on unfused paths.
    sketch: (hist, rare) stacked (W, ...) int32 sketches riding the
    ring (sharded strategy, one psum each — exact integer sums), or
    None when the engine computes them eagerly from `obs` rows (fused
    strategy).
    """

    obs: Any
    steps_end: Any
    leaps_end: Any
    stats: Optional[list] = None
    grouped: Optional[list] = None
    steps_delta: Any = None
    truncated: Any = None
    sketch: Any = None


def _obs_extractor(obs_idx):
    """Normalised device-side observable extraction, shared by BOTH
    window-body factories — a single definition is what keeps the
    kernel and unfused paths' records bitwise comparable."""
    obs_idx = tuple(tuple(int(i) for i in ii) for ii in obs_idx)

    def extract(x):
        cols = [x[:, list(ii)].sum(axis=1) for ii in obs_idx]
        return jnp.stack(cols, axis=1)

    return extract


def make_window_body(advance_fn, n_lanes: int, obs_idx):
    """The shared whole-pool window advance: permutation gather,
    lax.scan over fixed-size lane slices (each running the masked
    per-lane advance to the horizon), inverse scatter, device-side
    observables.

    `advance_fn(lane_slice, rates, horizon) -> LaneState` is the
    engine-built per-slice loop (`gillespie.make_advance_fn` — dense
    exact, tau-leap, or the sparse dependency-graph step with its
    carried propensity vector); the window machinery is method- and
    encoding-agnostic.

    Used verbatim by BOTH the fused and the sharded strategies (the
    sharded one applies it per shard with shard-local indices), which
    is what keeps their per-lane trajectories bit-identical.
    """
    extract_obs = _obs_extractor(obs_idx)

    def window_body(pool: LaneState, rates, perm, horizon):
        n_groups = perm.shape[0] // n_lanes

        def take(a):
            return a[perm].reshape((n_groups, n_lanes) + a.shape[1:])

        lanes = LaneState(*(take(a) for a in pool))
        rates_g = take(rates)

        def advance_group(carry, grp):
            sl, r = grp
            return carry, advance_fn(sl, r, horizon)

        _, advanced = jax.lax.scan(advance_group, 0, (lanes, rates_g))
        flat = jax.tree_util.tree_map(
            lambda a: a.reshape((n_groups * n_lanes,) + a.shape[2:]),
            advanced)
        # duplicate padding indices write identical data — safe
        new_pool = LaneState(*(
            p.at[perm].set(v) for p, v in zip(pool, flat)))
        return new_pool, extract_obs(new_pool.x), \
            new_pool.steps - pool.steps

    return window_body


class _Dispatch:
    """Base strategy: holds a back-reference to the engine, advances
    the pool one window, and accounts its own telemetry."""

    name = "?"

    def __init__(self, engine):
        self.eng = engine

    def place(self, tree):
        """Device placement for pool-shaped pytrees (leading instance
        axis). Identity except under sharding."""
        return tree

    def advance(self, horizon) -> WindowResult:
        raise NotImplementedError

    def advance_block(self, horizons) -> BlockResult:
        """Advance the pool over a whole block of window horizons in
        one dispatch (superstep). Only the fused and sharded strategies
        implement it; the host loop is the per-window baseline
        (SimConfig rejects window_block > 1 with host_loop)."""
        raise NotImplementedError(
            f"dispatch strategy {self.name!r} has no superstep path; "
            "window_block > 1 needs the fused or sharded strategy")


class HostLoopDispatch(_Dispatch):
    """Legacy baseline: per-group gather -> advance -> scatter, one
    dispatch per (group x window) — with or without the fused kernel
    inside each group's launch."""

    name = "host_loop"

    def __init__(self, engine):
        super().__init__(engine)
        self._advance_fn = self._make_advance()

    def _make_advance(self):
        eng = self.eng
        cfg = eng.cfg

        if cfg.use_kernel:
            idx_t, coef_t, delta_t, _ = eng._tensors_base
            # the chunk loop is one jitted launch (device-side
            # while_loop): one dispatch per group, no mid-window host
            # syncs — exact or tau-leap, dense or sparse, per the
            # engine's method/encoding
            chunk_loop = eng._make_chunk_loop()

            def advance(pool_slice, rates, horizon):
                return chunk_loop(
                    pool_slice, (idx_t, coef_t, delta_t, rates), horizon)

            return jax.jit(advance, donate_argnums=(0,))

        # the engine-built per-slice loop — the SAME advance the fused/
        # sharded window bodies scan, jitted per group here
        return jax.jit(eng._make_advance_fn(), donate_argnums=(0,))

    def _gather(self, idx) -> tuple[LaneState, jax.Array]:
        p = self.eng._pool
        sl = LaneState(*(a[idx] for a in p))
        # index the cached device rates — no per-window host re-upload
        return sl, self.eng._rates_dev[idx]

    def _scatter(self, idx, sl: LaneState) -> None:
        p = self.eng._pool
        # guard duplicate padding indices: later writes win (same data)
        self.eng._pool = LaneState(
            *(a.at[idx].set(v) for a, v in zip(p, sl)))

    def advance(self, horizon) -> WindowResult:
        eng = self.eng
        use_kernel = eng.cfg.use_kernel
        predictive = eng.scheduler.policy == "predictive"
        steps_before = None
        truncated = None
        if predictive:
            steps_before = np.asarray(eng._pool.steps)
            eng.n_host_syncs += 1
        for idx in eng.scheduler.groups():
            sl, rates = self._gather(idx)
            out = self._advance_fn(sl, rates, horizon)
            eng.n_dispatches += 1
            if use_kernel:
                # device-scalar truncation flags OR together lazily —
                # no per-group (or per-chunk) host pull
                truncated = (out.truncated if truncated is None
                             else truncated | out.truncated)
                sl = out.state
            else:
                sl = out
            self._scatter(idx, sl)
        steps_delta = None
        if predictive:
            steps_delta = np.asarray(eng._pool.steps) - steps_before
            eng.n_host_syncs += 1
        return WindowResult(eng._observe(), steps_delta, None, None,
                            truncated)


def make_kernel_window_body(tensors3, obs_idx, chunk_loop_fn):
    """Whole-pool window advance through a Pallas fused kernel chunk
    loop: one device-side while_loop + observable extraction, traceable
    under jit (fused strategy) and shard_map (sharded strategy).

    `chunk_loop_fn(pool, (idx, coef, delta, rates), horizon) ->
    FusedWindowOut` is the engine-built loop (`_make_chunk_loop`) —
    exact SSA (`ops.window_chunk_loop`) or tau-leap
    (`ops.tau_window_chunk_loop`) with the chunk budget bound in.

    No permutation/group scan: the kernel's lane-block grid IS the
    SIMD grouping, and every per-lane op is independent, so scheduler
    groups would not change a single trajectory.

    Returns (new_pool, obs, steps_delta, truncated)."""
    idx_t, coef_t, delta_t = tensors3
    extract_obs = _obs_extractor(obs_idx)

    def window_body(pool: LaneState, rates, horizon):
        out = chunk_loop_fn(pool, (idx_t, coef_t, delta_t, rates),
                            horizon)
        new_pool = out.state
        return new_pool, extract_obs(new_pool.x), \
            new_pool.steps - pool.steps, out.truncated

    return window_body


class FusedDispatch(_Dispatch):
    """One jitted, donated window_step for the whole pool — one device
    dispatch per window (DESIGN.md §3). With `use_kernel=True` the
    step is the Pallas fused-window chunk loop instead of the
    permutation + lax.scan body — still one dispatch per window, now
    with the SSA inner loop resident in VMEM."""

    name = "fused"

    def __init__(self, engine):
        super().__init__(engine)
        cfg = engine.cfg
        idx_t, coef_t, delta_t, _ = engine._tensors_base
        self._kernel = cfg.use_kernel
        if self._kernel:
            body = make_kernel_window_body(
                (idx_t, coef_t, delta_t), engine.obs_idx,
                engine._make_chunk_loop())
        else:
            body = make_window_body(engine._make_advance_fn(),
                                    engine.scheduler.n_lanes,
                                    engine.obs_idx)
        self._body = body
        self._step = jax.jit(body, donate_argnums=(0,))
        self._block_step = None  # built lazily on first superstep

    def advance(self, horizon) -> WindowResult:
        eng = self.eng
        if self._kernel:
            eng._pool, obs, steps_delta, truncated = self._step(
                eng._pool, eng._rates_dev, horizon)
            eng.n_dispatches += 1
            return WindowResult(obs, steps_delta, None, None, truncated)
        eng._pool, obs, steps_delta = self._step(
            eng._pool, eng._rates_dev, eng._permutation(), horizon)
        eng.n_dispatches += 1
        return WindowResult(obs, steps_delta, None, None)

    def _build_block(self):
        """ONE jitted, donated superstep: lax.scan of the window body
        over a (W,) horizon vector, stacking per-window obs + telemetry
        into the record ring. The scan body is the SAME window body the
        per-window step jits, so per-lane trajectories (and therefore
        records) are bitwise independent of window_block."""
        body = self._body
        kernel = self._kernel
        predictive = self.eng.scheduler.policy == "predictive"
        # in-scan predictive regroup: the cost EMA rides the scan carry
        # (float32 device copy of the scheduler's EMA) and every window
        # re-sorts groups from it — per-window regrouping with ZERO
        # host round trips, where the host-perm form could only regroup
        # at block boundaries. Grouping is execution packaging, never
        # semantics (per-lane ops are independent, padding duplicates
        # write identical data), so records stay bitwise identical to
        # the host-perm path; steps_d still rides the ring so the host
        # EMA (the canonical float64 copy) updates at collect time
        # exactly as before. The kernel body has no grouping at all.
        in_scan = predictive and not kernel
        if in_scan:
            take_pos = jnp.asarray(self.eng.scheduler.take_positions())
            alpha = self.eng.scheduler.ema_alpha

            def cost_block_body(pool, rates, cost, horizons):
                def step(carry, h):
                    p, c = carry
                    perm = jnp.argsort(c, stable=True)[take_pos]
                    new_pool, obs, steps_d = body(p, rates, perm, h)
                    new_c = (1 - alpha) * c + \
                        alpha * steps_d.astype(c.dtype)
                    ring = (obs, new_pool.steps.sum(),
                            new_pool.leaps.sum(), jnp.int32(0), steps_d)
                    return (new_pool, new_c), ring

                return jax.lax.scan(step, (pool, cost), horizons)

            return jax.jit(cost_block_body, donate_argnums=(0, 2))

        def block_body(pool, rates, perm, horizons):
            def step(p, h):
                if kernel:
                    new_pool, obs, steps_d, trunc = body(p, rates, h)
                    trunc = trunc.astype(jnp.int32)
                else:
                    new_pool, obs, steps_d = body(p, rates, perm, h)
                    trunc = jnp.int32(0)
                ring = (obs, new_pool.steps.sum(), new_pool.leaps.sum(),
                        trunc) + ((steps_d,) if predictive else ())
                return new_pool, ring

            return jax.lax.scan(step, pool, horizons)

        return jax.jit(block_body, donate_argnums=(0,))

    def advance_block(self, horizons) -> BlockResult:
        eng = self.eng
        if self._block_step is None:
            self._block_step = self._build_block()
        predictive = eng.scheduler.policy == "predictive"
        in_scan = predictive and not self._kernel
        hvec = jnp.asarray(horizons, jnp.float32)
        if in_scan:
            (eng._pool, eng._cost_dev), ring = self._block_step(
                eng._pool, eng._rates_dev, eng._cost_device(), hvec)
        else:
            perm = None if self._kernel else eng._permutation()
            eng._pool, ring = self._block_step(
                eng._pool, eng._rates_dev, perm, hvec)
        eng.n_dispatches += 1
        obs, steps_end, leaps_end, trunc = ring[:4]
        return BlockResult(
            obs=obs, steps_end=steps_end, leaps_end=leaps_end,
            steps_delta=(ring[4] if predictive else None),
            truncated=(trunc if self._kernel else None))


class ShardedDispatch(_Dispatch):
    """The fused window body sharded over a mesh data axis.

    Pool, rates, and the scheduler permutation are sharded per device
    (in_specs P(axis)); each shard advances its own lane slices with
    shard-local indices; per-window statistic partials cross shards
    through `reduction.gather_blocks_over_axis` (one psum) and come
    back replicated, so the host sees one dispatch and O(1) pulls per
    window regardless of shard count.
    """

    name = "sharded"

    def __init__(self, engine, mesh, partitioning: Partitioning):
        super().__init__(engine)
        part = partitioning
        if engine.cfg.n_instances % part.n_shards:
            raise ValueError(
                f"n_instances={engine.cfg.n_instances} not divisible by "
                f"n_shards={part.n_shards}")
        if part.axis not in mesh.shape:
            raise ValueError(
                f"mesh has no axis {part.axis!r} (axes: "
                f"{tuple(mesh.axis_names)})")
        if mesh.shape[part.axis] != part.n_shards:
            raise ValueError(
                f"mesh axis {part.axis!r} has size "
                f"{mesh.shape[part.axis]}, but Partitioning.n_shards is "
                f"{part.n_shards}")
        self.mesh = mesh
        self.part = part
        self._sharding = NamedSharding(mesh, P(part.axis))
        self._step = None
        # cache key: (grouped?, n_groups) — the jitted step closes over
        # both, so a set_groups() with a new group count must rebuild
        self._step_key: Optional[tuple] = None
        self._block_step = None
        self._block_key: Optional[tuple] = None

    def place(self, tree):
        return jax.tree_util.tree_map(
            lambda a: jax.device_put(a, self._sharding), tree)

    def _build(self, grouped: bool):
        eng = self.eng
        part = self.part
        axis, n_shards = part.axis, part.n_shards
        per_shard = eng.cfg.n_instances // n_shards
        v_loc = part.blocks // n_shards
        n_groups = eng._n_groups if grouped else 0
        use_kernel = eng.cfg.use_kernel
        sk = eng._sketch  # SketchParams or None (frozen per engine)
        idx_t, coef_t, delta_t, _ = eng._tensors_base
        if use_kernel:
            # per-shard Pallas fused window: the paper's two families
            # (single-simulation speedup x simulation farm) composed
            kbody = make_kernel_window_body(
                (idx_t, coef_t, delta_t), eng.obs_idx,
                eng._make_chunk_loop())
        else:
            body = make_window_body(eng._make_advance_fn(),
                                    eng.scheduler.n_lanes, eng.obs_idx)

        def local(pool, rates, perm, gids, horizon):
            if use_kernel:
                new_pool, obs, steps_delta, trunc = kbody(pool, rates,
                                                          horizon)
                # any-shard truncation, replicated so one device scalar
                # answers for the whole farm
                trunc = jax.lax.psum(trunc.astype(jnp.int32), axis)
            else:
                k = jax.lax.axis_index(axis)
                perm_loc = perm - k * per_shard  # global -> shard-local
                new_pool, obs, steps_delta = body(pool, rates, perm_loc,
                                                  horizon)
                # a constant is already replicated — no collective
                trunc = jnp.int32(0)
            # psum-gather the per-block partial accumulators; the final
            # O(V) fold runs eagerly host-side (advance() below) with
            # the exact op sequence the unsharded path uses, so records
            # stay bitwise independent of the mesh shape
            acc = reduction.blocked_welford(obs, v_loc)
            stack = reduction.gather_blocks_over_axis(acc, axis,
                                                      n_shards)
            outs = (new_pool, obs, steps_delta, trunc, stack)
            if grouped:
                gacc = reduction.blocked_grouped_welford(
                    obs, gids, n_groups, v_loc)
                gstack = reduction.gather_blocks_over_axis(gacc, axis,
                                                           n_shards)
                outs = outs + (gstack,)
            if sk is not None:
                # int32 counts: shard-partial psum is bitwise the
                # full-pool sum (integer addition is associative with
                # exact identity), so sketches are mesh-shape-agnostic
                g = gids if grouped else jnp.zeros((obs.shape[0],),
                                                   jnp.int32)
                hist, rare = window_sketch(
                    obs, g, n_groups if grouped else 1, sk.lo, sk.width,
                    sk.n_bins, sk.thresholds if sk.n_thr else None)
                outs = outs + (jax.lax.psum(hist, axis),)
                if rare is not None:
                    outs = outs + (jax.lax.psum(rare, axis),)
            return outs

        sh = P(axis)
        out_specs = (sh, sh, sh, P(), P()) + ((P(),) if grouped else ())
        if sk is not None:
            out_specs = out_specs + (P(),) * (1 + (1 if sk.n_thr else 0))
        # the kernel body never reads the scheduler permutation (its
        # lane-block grid IS the grouping) — drop the operand so the
        # host neither assembles nor ships it each window
        if use_kernel and grouped:
            def wrapped(pool, rates, gids, horizon):
                return local(pool, rates, None, gids, horizon)

            in_specs = (sh, sh, sh, P())
        elif use_kernel:
            def wrapped(pool, rates, horizon):
                return local(pool, rates, None, None, horizon)

            in_specs = (sh, sh, P())
        elif grouped:
            wrapped = local
            in_specs = (sh, sh, sh, sh, P())
        else:
            def wrapped(pool, rates, perm, horizon):
                return local(pool, rates, perm, None, horizon)

            in_specs = (sh, sh, sh, P())
        fn = compat.shard_map(wrapped, mesh=self.mesh, in_specs=in_specs,
                              out_specs=out_specs, check_vma=False)
        return jax.jit(fn, donate_argnums=(0,))

    def _build_block(self, grouped: bool):
        """The sharded superstep: the SAME per-window local body
        (window advance + per-block Welford partials + one psum gather)
        wrapped in a lax.scan over the horizon vector, all inside one
        shard_map'd, jitted, donated dispatch. Per-window gathered
        stat stacks come back replicated with a leading (W,) axis; the
        eager merge_blocks/finalize fold stays OUTSIDE the jit
        (advance_block below), exactly like the per-window path, so
        records are bitwise independent of both the mesh shape and
        window_block."""
        eng = self.eng
        part = self.part
        axis, n_shards = part.axis, part.n_shards
        per_shard = eng.cfg.n_instances // n_shards
        v_loc = part.blocks // n_shards
        n_groups = eng._n_groups if grouped else 0
        use_kernel = eng.cfg.use_kernel
        predictive = eng.scheduler.policy == "predictive"
        # in-scan predictive regroup (see FusedDispatch._build_block):
        # the shard-LOCAL cost slice rides the scan carry and each
        # window re-sorts within the shard — the same shard-locality
        # the host groups() enforces, with zero host round trips.
        # take_positions() replicates the host padding rule, and its
        # positions are shard-local, so argsort output needs no
        # global->local shift
        in_scan = predictive and not use_kernel
        sk = eng._sketch
        idx_t, coef_t, delta_t, _ = eng._tensors_base
        if use_kernel:
            kbody = make_kernel_window_body(
                (idx_t, coef_t, delta_t), eng.obs_idx,
                eng._make_chunk_loop())
        else:
            body = make_window_body(eng._make_advance_fn(),
                                    eng.scheduler.n_lanes, eng.obs_idx)
        if in_scan:
            take_pos = jnp.asarray(eng.scheduler.take_positions())
            alpha = eng.scheduler.ema_alpha

        def local(pool, rates, pc, gids, horizons):
            # `pc` is the third operand: the global permutation for the
            # host-perm form, the shard-local cost slice when in_scan
            def step(carry, h):
                p, c = carry if in_scan else (carry, None)
                if use_kernel:
                    new_pool, obs, steps_d, trunc = kbody(p, rates, h)
                    trunc = jax.lax.psum(trunc.astype(jnp.int32), axis)
                else:
                    if in_scan:
                        perm_loc = jnp.argsort(c, stable=True)[take_pos]
                    else:
                        k = jax.lax.axis_index(axis)
                        perm_loc = pc - k * per_shard
                    new_pool, obs, steps_d = body(p, rates, perm_loc, h)
                    trunc = jnp.int32(0)
                acc = reduction.blocked_welford(obs, v_loc)
                stack = reduction.gather_blocks_over_axis(acc, axis,
                                                          n_shards)
                # int32 pool-total counters are exact mod 2^32, so the
                # psum equals the eager global sum the per-window path
                # pulls
                ring = (obs, trunc, stack,
                        jax.lax.psum(new_pool.steps.sum(), axis),
                        jax.lax.psum(new_pool.leaps.sum(), axis))
                if grouped:
                    gacc = reduction.blocked_grouped_welford(
                        obs, gids, n_groups, v_loc)
                    ring = ring + (reduction.gather_blocks_over_axis(
                        gacc, axis, n_shards),)
                if sk is not None:
                    g = gids if grouped else jnp.zeros(
                        (obs.shape[0],), jnp.int32)
                    hist, rare = window_sketch(
                        obs, g, n_groups if grouped else 1, sk.lo,
                        sk.width, sk.n_bins,
                        sk.thresholds if sk.n_thr else None)
                    ring = ring + (jax.lax.psum(hist, axis),)
                    if rare is not None:
                        ring = ring + (jax.lax.psum(rare, axis),)
                if predictive:
                    ring = ring + (steps_d,)
                if in_scan:
                    new_c = (1 - alpha) * c + \
                        alpha * steps_d.astype(c.dtype)
                    return (new_pool, new_c), ring
                return new_pool, ring

            init = (pool, pc) if in_scan else pool
            return jax.lax.scan(step, init, horizons)

        sh = P(axis)
        rsh = P(None, axis)  # (W, I_loc, ...) rings: windows leading
        ring_specs = (rsh, P(), P(), P(), P())
        if grouped:
            ring_specs = ring_specs + (P(),)
        if sk is not None:
            ring_specs = ring_specs + (P(),) * (1 + (1 if sk.n_thr
                                                     else 0))
        if predictive:
            ring_specs = ring_specs + (rsh,)
        out_specs = (((sh, sh) if in_scan else sh), ring_specs)
        if use_kernel and grouped:
            def wrapped(pool, rates, gids, horizons):
                return local(pool, rates, None, gids, horizons)

            in_specs = (sh, sh, sh, P())
        elif use_kernel:
            def wrapped(pool, rates, horizons):
                return local(pool, rates, None, None, horizons)

            in_specs = (sh, sh, P())
        elif grouped:
            wrapped = local
            in_specs = (sh, sh, sh, sh, P())
        else:
            def wrapped(pool, rates, pc, horizons):
                return local(pool, rates, pc, None, horizons)

            in_specs = (sh, sh, sh, P())
        fn = compat.shard_map(wrapped, mesh=self.mesh, in_specs=in_specs,
                              out_specs=out_specs, check_vma=False)
        return jax.jit(fn, donate_argnums=((0, 2) if in_scan else (0,)))

    def advance_block(self, horizons) -> BlockResult:
        eng = self.eng
        grouped = eng._group_ids_dev is not None
        predictive = eng.scheduler.policy == "predictive"
        in_scan = predictive and not eng.cfg.use_kernel
        key = (grouped, eng._n_groups if grouped else 0)
        if self._block_step is None or self._block_key != key:
            self._block_step = self._build_block(grouped)
            self._block_key = key
        step_args = [eng._pool, eng._rates_dev]
        if not eng.cfg.use_kernel:
            # third operand: shard-local cost carry (in-scan regroup)
            # or the host-assembled global permutation
            step_args.append(eng._cost_device() if in_scan
                             else eng._permutation())
        if grouped:
            step_args.append(eng._group_ids_dev)
        carry, ring = self._block_step(
            *step_args, jnp.asarray(horizons, jnp.float32))
        if in_scan:
            eng._pool, eng._cost_dev = carry
        else:
            eng._pool = carry
        eng.n_dispatches += 1
        obs, trunc, stack, steps_end, leaps_end = ring[:5]
        i = 5
        gstack = None
        if grouped:
            gstack = ring[i]
            i += 1
        sketch = None
        if eng._sketch is not None:
            hist = ring[i]
            i += 1
            rare = None
            if eng._sketch.n_thr:
                rare = ring[i]
                i += 1
            sketch = (hist, rare)
        steps_delta = ring[i] if predictive else None
        n_windows = len(horizons)
        # per-window eager fold — the exact op sequence the per-window
        # sharded advance() (and the unsharded path) uses
        stats = [reduction.finalize(reduction.merge_blocks(
            reduction.Welford(*(a[w] for a in stack))))
            for w in range(n_windows)]
        gstats = None
        if grouped:
            gstats = [reduction.finalize(reduction.merge_blocks(
                reduction.Welford(*(a[w] for a in gstack))))
                for w in range(n_windows)]
        return BlockResult(
            obs=obs, steps_end=steps_end, leaps_end=leaps_end,
            stats=stats, grouped=gstats, steps_delta=steps_delta,
            truncated=(trunc if eng.cfg.use_kernel else None),
            sketch=sketch)

    def advance(self, horizon) -> WindowResult:
        eng = self.eng
        grouped = eng._group_ids_dev is not None
        key = (grouped, eng._n_groups if grouped else 0)
        if self._step is None or self._step_key != key:
            self._step = self._build(grouped)
            self._step_key = key
        step_args = [eng._pool, eng._rates_dev]
        if not eng.cfg.use_kernel:
            step_args.append(eng._permutation())
        if grouped:
            step_args.append(eng._group_ids_dev)
        outs = self._step(*step_args, horizon)
        eng._pool, obs, steps_delta, trunc, stack = outs[:5]
        i = 5
        gstats = None
        if grouped:
            gstats = reduction.finalize(reduction.merge_blocks(outs[i]))
            i += 1
        sketch = None
        if eng._sketch is not None:
            hist = outs[i]
            i += 1
            rare = None
            if eng._sketch.n_thr:
                rare = outs[i]
                i += 1
            sketch = (hist, rare)
        stats = reduction.finalize(reduction.merge_blocks(stack))
        eng.n_dispatches += 1
        truncated = trunc if eng.cfg.use_kernel else None
        return WindowResult(obs, steps_delta, stats, gstats, truncated,
                            sketch)


def select_dispatch(engine, mesh):
    """Resolve the engine's (cfg, partitioning, mesh) to one strategy.

    Returns (dispatch, mesh): the mesh is built here (via
    `compat.make_mesh`) when a multi-shard Partitioning arrives without
    one.
    """
    cfg = engine.cfg
    part = engine.partitioning
    if part is not None and part.n_shards > 1:
        if cfg.host_loop:
            raise ValueError(
                "sharded dispatch is incompatible with host_loop=True; "
                "the host loop is a single-device baseline")
        part.validate(cfg.n_instances)
        if mesh is None:
            n_dev = len(jax.devices())
            if part.n_shards > n_dev:
                raise ValueError(
                    f"Partitioning.n_shards={part.n_shards} but only "
                    f"{n_dev} device(s) are visible (set XLA_FLAGS="
                    "--xla_force_host_platform_device_count=N to farm "
                    "over forced host devices)")
            mesh = compat.make_mesh((part.n_shards,), (part.axis,))
        return ShardedDispatch(engine, mesh, part), mesh
    if cfg.host_loop:
        return HostLoopDispatch(engine), mesh
    return FusedDispatch(engine), mesh
