# The paper's primary contribution: multicore-aware stochastic
# simulation of biological systems (CWC + Gillespie), adapted to TPU
# pods. See DESIGN.md §2 for the hardware-adaptation mapping.
