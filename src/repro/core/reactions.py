"""Tensorised reaction systems.

A `ReactionSystem` is the compile-time residue of a CWC model (see
`core/cwc/compile.py`): every (rewrite rule × compartment instance) pair
becomes one reaction over a flat species vector. The run-time engine
only ever sees dense tensors — this is the structure-of-arrays layout
that makes the whole Gillespie step SIMD across instances (DESIGN.md §2).

Propensities follow the paper's combination counting: for a reactant
with multiplicity c and population n the factor is C(n, c) (number of
distinct combinations), times the kinetic constant.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from math import comb
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

MAX_REACTANTS = 4  # max distinct species on a rule LHS (CWC rules are small)
# C(n, c) evaluation (`propensities` / kernels `_comb_factors`) is
# unrolled to c <= MAX_COEF; larger multiplicities MUST be rejected at
# construction — they would yield silently wrong propensities
MAX_COEF = 4


@dataclass(frozen=True)
class ReactionSystem:
    """S species, R reactions.

    reactant_idx:  (R, MAX_REACTANTS) int32 — species index, S = padding
    reactant_coef: (R, MAX_REACTANTS) int32 — multiplicity, 0 = padding
                   (each entry <= MAX_COEF, enforced at construction)
    delta:         (R, S) int32 — product-minus-reactant stoichiometry
    rates:         (R,) float32 — kinetic constants
    species_names / reaction_names: labels for reporting
    x0:            (S,) initial state
    """

    reactant_idx: np.ndarray
    reactant_coef: np.ndarray
    delta: np.ndarray
    rates: np.ndarray
    x0: np.ndarray
    species_names: tuple[str, ...]
    reaction_names: tuple[str, ...]

    def __post_init__(self):
        bad = np.argwhere(np.asarray(self.reactant_coef) > MAX_COEF)
        if bad.size:
            j, m = (int(v) for v in bad[0])
            name = (self.reaction_names[j]
                    if j < len(self.reaction_names) else f"r{j}")
            raise ValueError(
                f"reaction {name!r} has stoichiometric coefficient "
                f"{int(self.reactant_coef[j, m])} > MAX_COEF={MAX_COEF}: "
                "the combination factors C(n, c) are unrolled to "
                f"c <= {MAX_COEF}, so this system would evaluate to "
                "silently wrong propensities")

    @property
    def n_species(self) -> int:
        return self.delta.shape[1]

    @property
    def n_reactions(self) -> int:
        return self.delta.shape[0]

    def with_rates(self, rates) -> "ReactionSystem":
        import dataclasses

        return dataclasses.replace(
            self, rates=np.asarray(rates, np.float32))

    def validate(self) -> None:
        r, s = self.n_reactions, self.n_species
        assert self.reactant_idx.shape == (r, MAX_REACTANTS)
        assert self.reactant_coef.shape == (r, MAX_REACTANTS)
        assert self.rates.shape == (r,)
        assert self.x0.shape == (s,)
        assert (self.reactant_idx <= s).all()
        # delta must be consistent with reactants (no negative-below-LHS)
        lhs = np.zeros((r, s), np.int64)
        for j in range(r):
            for i, c in zip(self.reactant_idx[j], self.reactant_coef[j]):
                if c > 0:
                    lhs[j, i] += c
        assert ((lhs + self.delta) >= 0).all(), "products went negative"


def make_system(species: Sequence[str],
                reactions: Sequence[tuple[dict, dict, float]],
                x0: dict,
                names: Optional[Sequence[str]] = None) -> ReactionSystem:
    """reactions: list of (reactants {name: coef}, products {name: coef}, k)."""
    sidx = {s: i for i, s in enumerate(species)}
    r = len(reactions)
    s = len(species)
    idx = np.full((r, MAX_REACTANTS), s, np.int32)
    coef = np.zeros((r, MAX_REACTANTS), np.int32)
    delta = np.zeros((r, s), np.int32)
    rates = np.zeros((r,), np.float32)
    for j, (lhs, rhs, k) in enumerate(reactions):
        assert len(lhs) <= MAX_REACTANTS, f"rule {j} has too many reactants"
        for m, (name, c) in enumerate(sorted(lhs.items())):
            idx[j, m] = sidx[name]
            coef[j, m] = c
            delta[j, sidx[name]] -= c
        for name, c in rhs.items():
            delta[j, sidx[name]] += c
        rates[j] = k
    x0_arr = np.zeros((s,), np.float32)
    for name, v in x0.items():
        x0_arr[sidx[name]] = v
    sys = ReactionSystem(
        reactant_idx=idx, reactant_coef=coef, delta=delta, rates=rates,
        x0=x0_arr, species_names=tuple(species),
        reaction_names=tuple(names) if names else tuple(
            f"r{j}" for j in range(r)))
    sys.validate()
    return sys


def _comb_table(max_coef: int = 8):
    """C(n, c) via falling factorial / c! — differentiable-free, exact for
    counts < 2^24 in fp32."""
    return None  # computed inline; kept for documentation


def comb_factors(pops, coef, max_c: int = MAX_COEF):
    """C(pops, coef) unrolled to coef <= max_c: pops (B, R) f32, coef
    (R,) or (B, R). Coefficients beyond MAX_COEF are rejected at
    `ReactionSystem` construction, so the unroll bound is safe. Plain
    jnp ops — shared by the Pallas kernel bodies (kernels/propensity.py
    re-exports it) and the MXU-form host propensities (core/tau_leap)."""
    ff = jnp.ones_like(pops)
    fact = jnp.ones_like(pops)
    for i in range(max_c):
        active = coef > i
        ff = jnp.where(active, ff * jnp.maximum(pops - i, 0.0), ff)
        fact = jnp.where(active, fact * (i + 1), fact)
    return ff / fact


def propensities(x, sys_idx, sys_coef, rates):
    """Batched mass-action propensities.

    x: (B, S) float32 counts; sys_idx (R, M); sys_coef (R, M);
    rates (R,) or (B, R) for per-instance parameter sweeps.
    Returns (B, R) float32.

    The product accumulates in the SAME association order as the Pallas
    kernel bodies (rates first, then one `comb_factors` factor per
    reactant slot): the slot factors are bitwise equal on both paths
    (combination counts of integer populations), so pinning the
    multiply order is what makes kernel <-> unfused trajectories
    bitwise identical for EVERY system — the old
    `prod(factors) * rates` order could differ in the last ulp once a
    rate times a factor rounded.
    """
    b, s = x.shape
    xp = jnp.concatenate([x, jnp.ones((b, 1), x.dtype)], axis=1)  # pad slot
    pops = xp[:, sys_idx]  # (B, R, M)
    a = jnp.broadcast_to(jnp.asarray(rates, x.dtype),
                         (b, sys_idx.shape[0]))
    for m in range(sys_idx.shape[1]):
        # C(n, c) per slot (c <= MAX_COEF, unrolled; larger rejected at
        # ReactionSystem construction)
        a = a * comb_factors(pops[:, :, m], sys_coef[None, :, m])
    return a


def propensities_ref(x, system: ReactionSystem, rates=None) -> np.ndarray:
    """Numpy oracle (exact combinatorics)."""
    x = np.asarray(x)
    rates = np.asarray(rates if rates is not None else system.rates)
    b = x.shape[0]
    out = np.zeros((b, system.n_reactions), np.float64)
    for bi in range(b):
        for j in range(system.n_reactions):
            a = 1.0
            for i, c in zip(system.reactant_idx[j], system.reactant_coef[j]):
                if c > 0:
                    a *= comb(int(x[bi, i]), int(c))
            out[bi, j] = a * (rates[bi, j] if rates.ndim == 2 else rates[j])
    return out
