"""Tensorised reaction systems.

A `ReactionSystem` is the compile-time residue of a CWC model (see
`core/cwc/compile.py`): every (rewrite rule × compartment instance) pair
becomes one reaction over a flat species vector. The run-time engine
only ever sees dense tensors — this is the structure-of-arrays layout
that makes the whole Gillespie step SIMD across instances (DESIGN.md §2).

Propensities follow the paper's combination counting: for a reactant
with multiplicity c and population n the factor is C(n, c) (number of
distinct combinations), times the kinetic constant.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from math import comb
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

MAX_REACTANTS = 4  # max distinct species on a rule LHS (CWC rules are small)
# The DENSE path unrolls C(n, c) (`propensities` / kernels
# `_comb_factors`) to c <= MAX_COEF and rejects larger multiplicities
# when dense tensors are built (`require_dense_capable`) — they would
# yield silently wrong propensities. The SPARSE path is table-free: it
# unrolls to the system's actual `max_coef`, so any multiplicity works.
MAX_COEF = 4


@dataclass(frozen=True)
class ReactionSystem:
    """S species, R reactions.

    reactant_idx:  (R, MAX_REACTANTS) int32 — species index, S = padding
    reactant_coef: (R, MAX_REACTANTS) int32 — multiplicity, 0 = padding
                   (dense paths require <= MAX_COEF, checked when dense
                   tensors are built; the sparse path takes any value)
    delta:         (R, S) int32 — product-minus-reactant stoichiometry
    rates:         (R,) float32 — kinetic constants
    species_names / reaction_names: labels for reporting
    x0:            (S,) initial state
    """

    reactant_idx: np.ndarray
    reactant_coef: np.ndarray
    delta: np.ndarray
    rates: np.ndarray
    x0: np.ndarray
    species_names: tuple[str, ...]
    reaction_names: tuple[str, ...]

    @property
    def max_coef(self) -> int:
        """Largest reactant multiplicity — the sparse `comb_factors`
        unroll bound. Dense paths additionally require <= MAX_COEF."""
        c = np.asarray(self.reactant_coef)
        return int(c.max()) if c.size else 0

    @property
    def n_species(self) -> int:
        return self.delta.shape[1]

    @property
    def n_reactions(self) -> int:
        return self.delta.shape[0]

    def with_rates(self, rates) -> "ReactionSystem":
        import dataclasses

        return dataclasses.replace(
            self, rates=np.asarray(rates, np.float32))

    def validate(self) -> None:
        r, s = self.n_reactions, self.n_species
        assert self.reactant_idx.shape == (r, MAX_REACTANTS)
        assert self.reactant_coef.shape == (r, MAX_REACTANTS)
        assert self.rates.shape == (r,)
        assert self.x0.shape == (s,)
        assert (self.reactant_idx <= s).all()
        # delta must be consistent with reactants (no negative-below-LHS)
        lhs = np.zeros((r, s), np.int64)
        for j in range(r):
            for i, c in zip(self.reactant_idx[j], self.reactant_coef[j]):
                if c > 0:
                    lhs[j, i] += c
        assert ((lhs + self.delta) >= 0).all(), "products went negative"


def make_system(species: Sequence[str],
                reactions: Sequence[tuple[dict, dict, float]],
                x0: dict,
                names: Optional[Sequence[str]] = None) -> ReactionSystem:
    """reactions: list of (reactants {name: coef}, products {name: coef}, k)."""
    sidx = {s: i for i, s in enumerate(species)}
    r = len(reactions)
    s = len(species)
    idx = np.full((r, MAX_REACTANTS), s, np.int32)
    coef = np.zeros((r, MAX_REACTANTS), np.int32)
    delta = np.zeros((r, s), np.int32)
    rates = np.zeros((r,), np.float32)
    for j, (lhs, rhs, k) in enumerate(reactions):
        assert len(lhs) <= MAX_REACTANTS, f"rule {j} has too many reactants"
        for m, (name, c) in enumerate(sorted(lhs.items())):
            idx[j, m] = sidx[name]
            coef[j, m] = c
            delta[j, sidx[name]] -= c
        for name, c in rhs.items():
            delta[j, sidx[name]] += c
        rates[j] = k
    x0_arr = np.zeros((s,), np.float32)
    for name, v in x0.items():
        x0_arr[sidx[name]] = v
    sys = ReactionSystem(
        reactant_idx=idx, reactant_coef=coef, delta=delta, rates=rates,
        x0=x0_arr, species_names=tuple(species),
        reaction_names=tuple(names) if names else tuple(
            f"r{j}" for j in range(r)))
    sys.validate()
    return sys


def require_dense_capable(system: ReactionSystem) -> None:
    """Reject systems the DENSE path would silently mis-evaluate.

    The dense `comb_factors` unroll is fixed at c <= MAX_COEF; a larger
    stoichiometric coefficient yields wrong propensities, so it must be
    refused wherever dense tensors are built. The sparse path
    (`sparse=True`) unrolls to the actual `system.max_coef` and has no
    such ceiling.
    """
    coef = np.asarray(system.reactant_coef)
    bad = np.argwhere(coef > MAX_COEF)
    if bad.size:
        j, m = (int(v) for v in bad[0])
        name = (system.reaction_names[j]
                if j < len(system.reaction_names) else f"r{j}")
        raise ValueError(
            f"reaction {name!r} has stoichiometric coefficient "
            f"{int(coef[j, m])} > MAX_COEF={MAX_COEF}: the dense path "
            f"unrolls the combination factors C(n, c) to c <= {MAX_COEF} "
            "and would evaluate silently wrong propensities — run this "
            "system with sparse=True (table-free unroll to the actual "
            "max coefficient)")


@dataclass(frozen=True)
class SparseTables:
    """Device-ready sparse structure derived from a ReactionSystem.

    All tables are padded to rectangular shapes so gather/scatter stays
    jit/scan/Pallas-compatible; pad entries use out-of-range indices and
    are dropped with `mode="drop"` scatters (or gather a neutral slot).

    reactant_idx / reactant_coef / rate_pad: (R+1, M) — the reactant
        tables with one extra PAD reaction row (idx = S, coef = 0,
        rate = 0) so a dependency-list pad entry (R) gathers a row that
        evaluates to propensity 0 and is then dropped on scatter.
    dep_idx: (R+1, K) int32 — dep(j): the reactions whose reactant
        populations change when j fires (R = pad). Row R is all-pad,
        used by lanes that did not fire. K = max out-degree.
    delta_idx: (R+1, D) int32 — species changed by j (S = pad). Row R
        is all-pad so non-firing lanes index it directly (one gather,
        no mask) and their scatter drops.
    delta_val: (R+1, D) float32 — the signed change (0 at pads).
    max_coef: the table-free comb_factors unroll bound.
    """

    reactant_idx: np.ndarray
    reactant_coef: np.ndarray
    rate_pad: np.ndarray
    dep_idx: np.ndarray
    delta_idx: np.ndarray
    delta_val: np.ndarray
    max_coef: int

    @property
    def out_degree(self) -> int:
        return self.dep_idx.shape[1]


def sparse_tables(system: ReactionSystem) -> SparseTables:
    """Precompute the reaction dependency graph + sparse stoichiometry.

    dep(j) = { r : reactants(r) ∩ changed(j) ≠ ∅ } — after j fires,
    only these propensities can differ; every other reaction's reactant
    populations are untouched, so its (recomputed) propensity would be
    bitwise identical and the stale value is exact. This is what makes
    the per-event update cost O(out-degree) instead of O(R).
    """
    r, s = system.n_reactions, system.n_species
    delta = np.asarray(system.delta)
    idx = np.asarray(system.reactant_idx)
    coef = np.asarray(system.reactant_coef)

    # species -> reactions that consume it (reactant with coef > 0)
    by_species: list[list[int]] = [[] for _ in range(s)]
    for j in range(r):
        for i, c in zip(idx[j], coef[j]):
            if c > 0:
                by_species[int(i)].append(j)

    changed = [np.nonzero(delta[j])[0] for j in range(r)]
    deps = []
    for j in range(r):
        dj: set[int] = set()
        for i in changed[j]:
            dj.update(by_species[int(i)])
        deps.append(sorted(dj))

    k = max((len(d) for d in deps), default=1) or 1
    d_max = max((len(c) for c in changed), default=1) or 1

    dep_idx = np.full((r + 1, k), r, np.int32)  # row r = all-pad
    for j, dj in enumerate(deps):
        dep_idx[j, :len(dj)] = dj
    delta_idx = np.full((r + 1, d_max), s, np.int32)  # row r = all-pad
    delta_val = np.zeros((r + 1, d_max), np.float32)
    for j, ci in enumerate(changed):
        delta_idx[j, :len(ci)] = ci
        delta_val[j, :len(ci)] = delta[j, ci]

    m = idx.shape[1]
    idx_pad = np.concatenate([idx, np.full((1, m), s, np.int32)], axis=0)
    coef_pad = np.concatenate([coef, np.zeros((1, m), np.int32)], axis=0)
    rate_pad = np.concatenate(
        [np.asarray(system.rates, np.float32), np.zeros((1,), np.float32)])
    return SparseTables(
        reactant_idx=idx_pad, reactant_coef=coef_pad, rate_pad=rate_pad,
        dep_idx=dep_idx, delta_idx=delta_idx, delta_val=delta_val,
        max_coef=max(system.max_coef, 1))


def comb_factors(pops, coef, max_c: int = MAX_COEF):
    """C(pops, coef) unrolled to coef <= max_c: pops (B, R) f32, coef
    (R,) or (B, R). The dense callers use the fixed MAX_COEF bound
    (larger coefficients rejected by `require_dense_capable`); the
    sparse path passes the system's actual `max_coef`. Iterations with
    coef <= i are exact no-ops (`where` keeps the running value), so a
    LARGER unroll bound never changes the bits of a smaller-coef system.
    Plain jnp ops — shared by the Pallas kernel bodies
    (kernels/propensity.py re-exports it) and the MXU-form host
    propensities (core/tau_leap)."""
    ff = jnp.ones_like(pops)
    fact = jnp.ones_like(pops)
    for i in range(max_c):
        active = coef > i
        ff = jnp.where(active, ff * jnp.maximum(pops - i, 0.0), ff)
        fact = jnp.where(active, fact * (i + 1), fact)
    return ff / fact


def propensities(x, sys_idx, sys_coef, rates, max_c: int = MAX_COEF):
    """Batched mass-action propensities.

    x: (B, S) float32 counts; sys_idx (R, M); sys_coef (R, M);
    rates (R,) or (B, R) for per-instance parameter sweeps.
    max_c: comb_factors unroll bound — MAX_COEF on the dense path,
    the system's actual max_coef on the sparse path.
    Returns (B, R) float32.

    The product accumulates in the SAME association order as the Pallas
    kernel bodies (rates first, then one `comb_factors` factor per
    reactant slot): the slot factors are bitwise equal on both paths
    (combination counts of integer populations), so pinning the
    multiply order is what makes kernel <-> unfused trajectories
    bitwise identical for EVERY system — the old
    `prod(factors) * rates` order could differ in the last ulp once a
    rate times a factor rounded.
    """
    b, s = x.shape
    xp = jnp.concatenate([x, jnp.ones((b, 1), x.dtype)], axis=1)  # pad slot
    pops = xp[:, sys_idx]  # (B, R, M)
    a = jnp.broadcast_to(jnp.asarray(rates, x.dtype),
                         (b, sys_idx.shape[0]))
    for m in range(sys_idx.shape[1]):
        # C(n, c) per slot, unrolled to max_c (dense callers must have
        # passed `require_dense_capable` for the default bound)
        a = a * comb_factors(pops[:, :, m], sys_coef[None, :, m], max_c)
    return a


def propensities_partitioned(x, sys_idx, sys_coef, rates, max_c: int,
                             part: int):
    """`propensities`, with the per-slot comb work species-partitioned.

    Reshapes the (B, R[, M]) elementwise unroll to (B·part, R/part[, M])
    so ONE simulation's reaction axis spreads across `part` lanes of a
    kernel block — the layout that fills the vector unit when a single
    large network runs at small batch. Requires part | R. Every element
    sees the identical scalar computation, so the result is BITWISE
    equal to `propensities` for any partition factor.
    """
    b, s = x.shape
    r, m = sys_idx.shape
    if part <= 1 or r % part:
        return propensities(x, sys_idx, sys_coef, rates, max_c)
    xp = jnp.concatenate([x, jnp.ones((b, 1), x.dtype)], axis=1)
    pops = xp[:, sys_idx]  # (B, R, M)
    a = jnp.broadcast_to(jnp.asarray(rates, x.dtype), (b, r))
    coef_b = jnp.broadcast_to(sys_coef[None].astype(x.dtype), (b, r, m))
    rp = r // part
    a_p = a.reshape(b * part, rp)
    pops_p = pops.reshape(b * part, rp, m)
    coef_p = coef_b.reshape(b * part, rp, m)
    for mm in range(m):
        a_p = a_p * comb_factors(pops_p[:, :, mm], coef_p[:, :, mm], max_c)
    return a_p.reshape(b, r)


def propensities_ref(x, system: ReactionSystem, rates=None) -> np.ndarray:
    """Numpy oracle (exact combinatorics)."""
    x = np.asarray(x)
    rates = np.asarray(rates if rates is not None else system.rates)
    b = x.shape[0]
    out = np.zeros((b, system.n_reactions), np.float64)
    for bi in range(b):
        for j in range(system.n_reactions):
            a = 1.0
            for i, c in zip(system.reactant_idx[j], system.reactant_coef[j]):
                if c > 0:
                    a *= comb(int(x[bi, i]), int(c))
            out[bi, j] = a * (rates[bi, j] if rates.ndim == 2 else rates[j])
    return out
