"""Parameter sweeps (paper §3.1.2: replicas OR parameter sweeping).

A sweep maps named kinetic constants over per-instance values, yielding
the (I, R) rate matrix the engine consumes. Replicas of each sweep
point are interleaved so on-line reduction can still aggregate per
point (grouped reduction helper included).
"""
from __future__ import annotations

from dataclasses import dataclass
from itertools import product
from typing import Sequence

import numpy as np

from repro.core.reactions import ReactionSystem


@dataclass(frozen=True)
class SweepSpec:
    """values: {reaction_name: [v1, v2, ...]} — full factorial."""

    values: tuple  # ((reaction_name, (v, ...)), ...)
    replicas: int = 1

    @staticmethod
    def make(values: dict, replicas: int = 1) -> "SweepSpec":
        return SweepSpec(tuple((k, tuple(v)) for k, v in values.items()),
                         replicas)

    def points(self) -> list[dict]:
        names = [k for k, _ in self.values]
        grids = [v for _, v in self.values]
        return [dict(zip(names, combo)) for combo in product(*grids)]

    def n_instances(self) -> int:
        return len(self.points()) * self.replicas


def _matching_reactions(system: ReactionSystem, name: str) -> list[int]:
    """Rule names compile to one reaction per compartment context
    ("<rule>@<ctx>"); a sweep on the rule name touches all of them."""
    idx = [j for j, rn in enumerate(system.reaction_names)
           if rn == name or rn.split("@", 1)[0] == name]
    if not idx:
        raise KeyError(f"no reaction matches {name!r}: "
                       f"{system.reaction_names}")
    return idx


def sweep_rates(system: ReactionSystem, spec: SweepSpec) -> np.ndarray:
    """(I, R) rate matrix; instance i = point (i // replicas)."""
    pts = spec.points()
    out = np.broadcast_to(
        system.rates, (len(pts) * spec.replicas, system.n_reactions)).copy()
    for p, overrides in enumerate(pts):
        for name, v in overrides.items():
            for j in _matching_reactions(system, name):
                out[p * spec.replicas:(p + 1) * spec.replicas, j] = v
    return out.astype(np.float32)


def point_slices(spec: SweepSpec) -> list[slice]:
    return [slice(p * spec.replicas, (p + 1) * spec.replicas)
            for p in range(len(spec.points()))]
