"""Instance scheduling — the paper's Fig. 5 schemas, vectorised.

An *instance* is one stochastic simulation (replica or sweep point);
a *lane* is a row of the SIMD engine. The scheduler decides which
instances occupy the lanes for each (window × slot):

* `static_rr` (schema i): instances are partitioned into fixed
  round-robin groups that never re-form, whatever their relative cost
  (the paper's load-imbalance case). Since the engine unified all
  schemas onto the windowed pool loop, group membership — not
  trajectory-major execution order — is what distinguishes it; per-lane
  results are order-invariant either way (keyed RNG).
* `on_demand` (schema ii/iii): all instances advance window-by-window,
  sliced into lane-width groups per window (fixed sim-time slices, the
  stop/restart instance objects of §5.2(ii) realised as gather/scatter
  on the pool).
* `predictive` (schema ii/iii + history heuristics): like on_demand but
  groups are formed by sorting instances on an EMA of their per-window
  event cost, so lock-step groups are cost-homogeneous and masked idle
  work shrinks (the paper's "predictive heuristics based on instance
  history").

When the pool is sharded over a mesh axis (`n_shards > 1`), grouping —
including the predictive cost sort — happens *within* each shard's
contiguous instance block, so every lane group lives on one device and
the window permutation never implies a cross-shard gather.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class Scheduler:
    n_instances: int
    n_lanes: int
    policy: str = "on_demand"  # static_rr | on_demand | predictive
    ema_alpha: float = 0.5
    n_shards: int = 1  # > 1: group within contiguous shard blocks only
    _cost: np.ndarray = field(default=None, repr=False)

    def __post_init__(self):
        assert self.n_instances % self.n_shards == 0, (
            f"n_instances={self.n_instances} not divisible by "
            f"n_shards={self.n_shards}")
        self._cost = np.zeros(self.n_instances, np.float64)

    def groups(self) -> list[np.ndarray]:
        """Lane-width instance-index groups for the next window,
        shard-major: groups never mix instances from different shard
        blocks, and every shard yields the same number of groups (its
        block size is uniform), so the concatenated permutation splits
        evenly across devices."""
        per = self.n_instances // self.n_shards
        out = []
        for k in range(self.n_shards):
            lo = k * per
            order = np.arange(lo, lo + per)
            if self.policy == "predictive":
                order = lo + np.argsort(self._cost[lo:lo + per],
                                        kind="stable")
            ngroups = (per + self.n_lanes - 1) // self.n_lanes
            for g in range(ngroups):
                idx = order[g * self.n_lanes:(g + 1) * self.n_lanes]
                if len(idx) < self.n_lanes:  # pad by repeating (masked)
                    idx = np.concatenate(
                        [idx, np.full(self.n_lanes - len(idx), idx[-1])])
                out.append(idx.astype(np.int32))
        return out

    def take_positions(self) -> np.ndarray:
        """Positions into a shard-local stable-sorted order that
        reproduce `groups()`'s padding rule on device.

        `groups()` pads a ragged tail group by repeating its last
        *occupied* index; in sorted-order space that is simply position
        `per - 1`. The device-side predictive regroup (dispatch.py
        in-scan cost sort) computes `order = argsort(cost)` per shard
        and gathers `order[take_positions()]` — bit-identical to the
        concatenated host `groups()` permutation for that shard.
        """
        per = self.n_instances // self.n_shards
        ngroups = (per + self.n_lanes - 1) // self.n_lanes
        pos = np.arange(ngroups * self.n_lanes)
        return np.minimum(pos, per - 1).astype(np.int32)

    def record_costs(self, idx: np.ndarray, steps: np.ndarray) -> None:
        """Update per-instance EMA cost with events used this window."""
        a = self.ema_alpha
        self._cost[idx] = (1 - a) * self._cost[idx] + a * steps

    def imbalance(self) -> float:
        """Coefficient of variation of instance costs (diagnostics)."""
        c = self._cost
        return float(c.std() / max(c.mean(), 1e-9))
