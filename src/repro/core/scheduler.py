"""Instance scheduling — the paper's Fig. 5 schemas, vectorised.

An *instance* is one stochastic simulation (replica or sweep point);
a *lane* is a row of the SIMD engine. The scheduler decides which
instances occupy the lanes for each (window × slot):

* `static_rr` (schema i): instances are partitioned into fixed
  round-robin groups that never re-form, whatever their relative cost
  (the paper's load-imbalance case). Since the engine unified all
  schemas onto the windowed pool loop, group membership — not
  trajectory-major execution order — is what distinguishes it; per-lane
  results are order-invariant either way (keyed RNG).
* `on_demand` (schema ii/iii): all instances advance window-by-window,
  sliced into lane-width groups per window (fixed sim-time slices, the
  stop/restart instance objects of §5.2(ii) realised as gather/scatter
  on the pool).
* `predictive` (schema ii/iii + history heuristics): like on_demand but
  groups are formed by sorting instances on an EMA of their per-window
  event cost, so lock-step groups are cost-homogeneous and masked idle
  work shrinks (the paper's "predictive heuristics based on instance
  history").
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class Scheduler:
    n_instances: int
    n_lanes: int
    policy: str = "on_demand"  # static_rr | on_demand | predictive
    ema_alpha: float = 0.5
    _cost: np.ndarray = field(default=None, repr=False)

    def __post_init__(self):
        self._cost = np.zeros(self.n_instances, np.float64)

    def groups(self) -> list[np.ndarray]:
        """Lane-width instance-index groups for the next window."""
        order = np.arange(self.n_instances)
        if self.policy == "predictive":
            order = np.argsort(self._cost, kind="stable")
        ngroups = (self.n_instances + self.n_lanes - 1) // self.n_lanes
        out = []
        for g in range(ngroups):
            idx = order[g * self.n_lanes:(g + 1) * self.n_lanes]
            if len(idx) < self.n_lanes:  # pad by repeating (masked anyway)
                idx = np.concatenate(
                    [idx, np.full(self.n_lanes - len(idx), idx[-1])])
            out.append(idx.astype(np.int32))
        return out

    def record_costs(self, idx: np.ndarray, steps: np.ndarray) -> None:
        """Update per-instance EMA cost with events used this window."""
        a = self.ema_alpha
        self._cost[idx] = (1 - a) * self._cost[idx] + a * steps

    def imbalance(self) -> float:
        """Coefficient of variation of instance costs (diagnostics)."""
        c = self._cost
        return float(c.std() / max(c.mean(), 1e-9))
