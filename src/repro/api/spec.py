"""Typed experiment specification (the declarative front-end).

Everything the old imperative surface expressed through `SimConfig`
kwargs and stringly-typed `"i"/"ii"/"iii"` schemas is a validated,
composable spec here (see DESIGN.md for the migration table). A spec is
pure data: building one performs no compilation and touches no device —
`simulate()` does that. The one stateful exception is `sinks`: those
are live callables (a CsvSink opens its file when constructed and is
closed when the run completes), so build fresh sinks per simulate()
call rather than reusing one spec's sinks across runs.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from enum import Enum
from typing import Optional, Sequence, Union

import numpy as np

from repro.core.cwc.rules import CWCModel
from repro.core.dispatch import Partitioning
from repro.core.reactions import ReactionSystem
from repro.core.sweep import SweepSpec
from repro.runtime.supervisor import Recovery
from repro.stats.sketch import SketchSpec
from repro.steer.policy import Steering

__all__ = [
    "Ensemble", "Experiment", "ExperimentError", "Method",
    "Partitioning", "Policy", "Recovery", "Reduction", "Schedule",
    "Schema", "SketchSpec", "Steering",
]


class ExperimentError(ValueError):
    """A spec failed validation; the message names the offending field."""


class Schema(Enum):
    """The paper's three parallelisation schemas (Fig. 5)."""

    STATIC_FARM = "i"       # static farm, post-hoc reduction
    TIME_SLICED = "ii"      # self-balancing farm, post-hoc reduction
    ONLINE = "iii"          # time-sliced farm + on-line windowed reduction

    @classmethod
    def coerce(cls, v: Union["Schema", str]) -> "Schema":
        if isinstance(v, cls):
            return v
        for member in cls:
            if v in (member.value, member.name, member.name.lower()):
                return member
        raise ExperimentError(
            f"unknown schema {v!r}; expected one of "
            f"{[m.value for m in cls]} or {[m.name for m in cls]}")


class Policy(Enum):
    """Lane-grouping policy for the scheduler."""

    STATIC_RR = "static_rr"
    ON_DEMAND = "on_demand"
    PREDICTIVE = "predictive"  # EMA-cost-sorted groups (§5.2 heuristics)

    @classmethod
    def coerce(cls, v: Union["Policy", str]) -> "Policy":
        if isinstance(v, cls):
            return v
        for member in cls:
            if v in (member.value, member.name, member.name.lower()):
                return member
        raise ExperimentError(
            f"unknown policy {v!r}; expected one of "
            f"{[m.value for m in cls]}")


class Method(Enum):
    """The per-lane simulation algorithm (DESIGN.md §3d).

    EXACT is Gillespie's direct SSA — one Resolve/Update per reaction
    event. TAU_LEAP fires Poisson bundles of events over an adaptive
    Cao-bounded leap, falling back per lane to exact SSA wherever a
    leap would cover fewer than `tau_fallback` events — approximate in
    distribution, exact in reproducibility (same counter-based stream,
    bitwise identical across fused/kernel/sharded paths and
    checkpoint/resume)."""

    EXACT = "exact"
    TAU_LEAP = "tau_leap"

    @classmethod
    def coerce(cls, v: Union["Method", str]) -> "Method":
        if isinstance(v, cls):
            return v
        for member in cls:
            if v in (member.value, member.name, member.name.lower()):
                return member
        raise ExperimentError(
            f"unknown method {v!r}; expected one of "
            f"{[m.value for m in cls]}")


class Reduction(Enum):
    """What the per-window statistics aggregate over."""

    ENSEMBLE = "ensemble"    # pool every instance (replicas of one point)
    PER_POINT = "per_point"  # grouped per sweep point (paper §3.1.2)


@dataclass(frozen=True)
class Ensemble:
    """How many stochastic instances, and over which parameter points.

    `replicas` is the number of instances per sweep point (or the total
    ensemble size when there is no sweep). The embedded SweepSpec always
    carries the same replica count — use `Ensemble.make` to build one
    from a plain dict.
    """

    replicas: int = 1
    sweep: Optional[SweepSpec] = None

    @staticmethod
    def make(replicas: int = 1,
             sweep: Union[dict, SweepSpec, None] = None) -> "Ensemble":
        if isinstance(sweep, dict):
            sweep = SweepSpec.make(sweep, replicas)
        elif isinstance(sweep, SweepSpec):
            sweep = SweepSpec(sweep.values, replicas)
        return Ensemble(replicas=replicas, sweep=sweep)

    @property
    def n_points(self) -> int:
        return len(self.sweep.points()) if self.sweep else 1

    @property
    def n_instances(self) -> int:
        return self.n_points * self.replicas

    def group_ids(self) -> np.ndarray:
        """(I,) sweep-point id per instance (instance i -> point i//m)."""
        return np.repeat(np.arange(self.n_points, dtype=np.int32),
                         self.replicas)

    def validate(self) -> None:
        if self.replicas < 1:
            raise ExperimentError(
                f"Ensemble.replicas must be >= 1, got {self.replicas}")
        if self.sweep is not None:
            if self.sweep.replicas != self.replicas:
                raise ExperimentError(
                    f"Ensemble.replicas ({self.replicas}) disagrees with "
                    f"sweep.replicas ({self.sweep.replicas}); build via "
                    "Ensemble.make(replicas=..., sweep=...)")
            if not self.sweep.points():
                raise ExperimentError("sweep has no points (empty values)")
            for name, vals in self.sweep.values:
                if len(vals) == 0:
                    raise ExperimentError(
                        f"sweep axis {name!r} has no values")


@dataclass(frozen=True)
class Schedule:
    """The simulation-time grid and its parallelisation schema."""

    t_end: float
    n_windows: int
    schema: Schema = Schema.ONLINE
    policy: Policy = Policy.ON_DEMAND
    max_steps_per_window: Optional[int] = None

    def __post_init__(self):
        object.__setattr__(self, "schema", Schema.coerce(self.schema))
        object.__setattr__(self, "policy", Policy.coerce(self.policy))

    def validate(self) -> None:
        if not self.t_end > 0:
            raise ExperimentError(
                f"Schedule.t_end must be > 0, got {self.t_end}")
        if self.n_windows < 1:
            raise ExperimentError(
                f"Schedule.n_windows must be >= 1, got {self.n_windows}")
        if (self.schema is Schema.STATIC_FARM
                and self.policy is Policy.PREDICTIVE):
            raise ExperimentError(
                "schema STATIC_FARM (i) uses static round-robin groups; "
                "policy PREDICTIVE is only meaningful for time-sliced "
                "schemas (ii/iii)")
        if self.max_steps_per_window is not None \
                and self.max_steps_per_window < 1:
            raise ExperimentError(
                "Schedule.max_steps_per_window must be >= 1 or None, "
                f"got {self.max_steps_per_window}")


@dataclass(frozen=True)
class Experiment:
    """One fully-specified ensemble simulation.

    sinks: callables receiving each StatsRecord; anything with a
    `close()` is closed when the run completes.
    record_trajectories: buffer raw per-window samples even under
    schema ONLINE (forfeits its memory bound — opt-in).
    host_loop: legacy per-group host dispatch (benchmark baseline).
    use_kernel: advance windows through the Pallas fused SSA kernel
    (one device dispatch per window, in-VREG counter-based RNG) —
    bitwise identical to the unfused path and composable with
    `partitioning` (per-shard kernel under shard_map).
    kernel_chunk_steps / kernel_max_chunks: the kernel path's per-window
    event budget — up to max_chunks chunk iterations of chunk_steps
    fused events in one device-side while_loop; a window needing more
    raises FusedWindowTruncated naming these knobs (never a silent
    partial window). Changing them never changes a trajectory, only
    where the budget cuts off.
    partitioning: shard the instance pool over a device mesh
    (`Partitioning(n_shards=..., stat_blocks=...)`); records depend on
    `stat_blocks` (the statistics merge tree), never on the physical
    shard count, so pin it when comparing runs across mesh shapes.
    method: the per-lane algorithm — Method.EXACT (default) or
    Method.TAU_LEAP (adaptive tau-leaping, §3d); composes with every
    dispatch path. tau_eps: Cao drift bound (leap sizes scale with it);
    tau_fallback: minimum expected events per leap before a lane falls
    back to exact SSA for that step. Neither changes EXACT runs.
    window_block: superstep width — fuse this many windows into ONE
    device dispatch (a lax.scan over window horizons), with per-window
    records accumulated in an on-device ring and collected by an async
    pipelined pull, so dispatches and blocking host syncs amortise to
    1/window_block per window (DESIGN.md §3e). Records are bitwise
    identical for any value; composes with use_kernel, partitioning,
    and method, but not host_loop (the per-window baseline). With a
    checkpoint_path, saves land on block boundaries (served from the
    in-flight ring's entry snapshot — the pipeline keeps running), and
    resuming needs a checkpoint on a window_block boundary.
    pipeline_depth: how many dispatched window blocks may stay in
    flight before the collector blocks on the oldest ring (DESIGN.md
    §3e). 1 (default) is the classic double-buffer; K > 1 hides the
    collector's host-side reduce/emit work behind K blocks of device
    compute; "auto" profiles the first collected block (blocking-pull
    wall vs host-reduce wall) and picks a depth from that ratio. Depth
    only changes WHEN rings are pulled — records, sketches, grouped
    stats, trajectories, and steering decisions are bitwise identical
    for any value. Each in-flight block buffers a full record ring
    (Telemetry.peak_buffered_bytes accounts for all of them).
    Irrelevant when window_block == 1 or under host_loop.
    sketch: stream device-side per-window sketches (fixed-bin
    histograms, rare-event counters — repro/stats, DESIGN.md §3f)
    alongside the Welford records; read them back via
    `SimulationResult.sketches()`. Integer-count merges keep them
    bitwise identical across dispatch paths, shard counts, and
    superstep widths.
    sparse: large-network encoding (DESIGN.md §3g) — CSR-style padded
    reactant/stoichiometry tables plus a precomputed reaction
    dependency graph, so each SSA event recomputes only the affected
    propensities (O(out-degree) instead of O(R)) and tau-leaping uses
    the gather-form Match (no dense one-hot tensors). Bitwise identical
    to the dense encoding on every dispatch path, and lifts the dense
    path's MAX_COEF unroll ceiling (coefficients > 4 require
    sparse=True).
    steering: adaptive between-block control (repro/steer) — early-stop
    converged sweep points, reallocate their freed replicas, per-lane
    exact<->tau auto-switch, bimodality flags. Decisions are a pure
    function of (seed, Steering), ride checkpoints, and `Steering()`
    (all levers off) is bitwise identical to no steering at all.
    Incompatible with host_loop (no block boundary to steer at);
    bimodality needs a sketch; tau_switch needs Method.TAU_LEAP.
    recovery: supervised self-healing lifecycle (DESIGN.md §3h) —
    simulate() hands the run to `runtime.supervisor.RunSupervisor`:
    cadenced atomic checkpoints with retention, bounded-backoff restart
    from the newest VALID snapshot on any typed recoverable fault,
    elastic shard-loss degradation, straggler re-dispatch, and
    deterministic fault-injection drills. Records/sketches/steering
    decisions from a supervised run (faults or not) are bitwise
    identical to the unsupervised run. Owns checkpointing, so it is
    mutually exclusive with simulate()'s checkpoint_path/resume.
    """

    model: Union[CWCModel, ReactionSystem]
    ensemble: Ensemble
    schedule: Schedule
    reduction: Reduction = Reduction.ENSEMBLE
    sinks: Sequence = ()
    seed: int = 0
    n_lanes: int = 128
    record_trajectories: bool = False
    use_kernel: bool = False
    kernel_chunk_steps: int = 256
    kernel_max_chunks: int = 64
    host_loop: bool = False
    partitioning: Optional[Partitioning] = None
    method: Method = Method.EXACT
    tau_eps: float = 0.03
    tau_fallback: float = 10.0
    window_block: int = 1
    pipeline_depth: Union[int, str] = 1
    sparse: bool = False
    sketch: Optional[SketchSpec] = None
    steering: Optional[Steering] = None
    recovery: Optional[Recovery] = None

    def __post_init__(self):
        object.__setattr__(self, "method", Method.coerce(self.method))

    def validate(self) -> None:
        if not isinstance(self.model, (CWCModel, ReactionSystem)):
            raise ExperimentError(
                "Experiment.model must be a CWCModel or ReactionSystem, "
                f"got {type(self.model).__name__}")
        if not isinstance(self.ensemble, Ensemble):
            raise ExperimentError(
                "Experiment.ensemble must be an Ensemble "
                f"(got {type(self.ensemble).__name__}); wrap a SweepSpec "
                "via Ensemble.make(replicas=..., sweep=...)")
        if not isinstance(self.schedule, Schedule):
            raise ExperimentError(
                "Experiment.schedule must be a Schedule, "
                f"got {type(self.schedule).__name__}")
        self.ensemble.validate()
        self.schedule.validate()
        if not isinstance(self.reduction, Reduction):
            raise ExperimentError(
                f"Experiment.reduction must be a Reduction enum, "
                f"got {self.reduction!r}")
        if self.n_lanes < 1:
            raise ExperimentError(
                f"Experiment.n_lanes must be >= 1, got {self.n_lanes}")
        if self.use_kernel and self.schedule.max_steps_per_window:
            raise ExperimentError(
                "max_steps_per_window is not honoured by the fused "
                "Pallas kernel path (use_kernel=True); drop one of them")
        if self.kernel_chunk_steps < 1:
            raise ExperimentError(
                f"Experiment.kernel_chunk_steps must be >= 1, got "
                f"{self.kernel_chunk_steps}")
        if self.kernel_max_chunks < 1:
            raise ExperimentError(
                f"Experiment.kernel_max_chunks must be >= 1, got "
                f"{self.kernel_max_chunks}")
        if self.window_block < 1:
            raise ExperimentError(
                f"Experiment.window_block must be >= 1, got "
                f"{self.window_block}")
        if self.window_block > 1 and self.host_loop:
            raise ExperimentError(
                "window_block > 1 needs the fused or sharded dispatch "
                "strategy; host_loop is the per-window round-trip "
                "baseline (set window_block=1)")
        if isinstance(self.pipeline_depth, str):
            if self.pipeline_depth != "auto":
                raise ExperimentError(
                    f"Experiment.pipeline_depth must be an int >= 1 or "
                    f"'auto', got {self.pipeline_depth!r}")
        elif (not isinstance(self.pipeline_depth, int)
                or self.pipeline_depth < 1):
            raise ExperimentError(
                f"Experiment.pipeline_depth must be an int >= 1 or "
                f"'auto', got {self.pipeline_depth!r}")
        # method itself needs no check here: __post_init__ coerced it
        # (or raised ExperimentError) at construction
        if not self.tau_eps > 0:
            raise ExperimentError(
                f"Experiment.tau_eps must be > 0, got {self.tau_eps}")
        if self.tau_fallback < 0:
            raise ExperimentError(
                f"Experiment.tau_fallback must be >= 0, got "
                f"{self.tau_fallback}")
        if self.partitioning is not None:
            if not isinstance(self.partitioning, Partitioning):
                raise ExperimentError(
                    "Experiment.partitioning must be a Partitioning, "
                    f"got {type(self.partitioning).__name__}")
            try:
                self.partitioning.validate(self.ensemble.n_instances)
            except ValueError as e:
                raise ExperimentError(str(e)) from e
            if self.partitioning.n_shards > 1 and self.host_loop:
                raise ExperimentError(
                    "partitioning with n_shards > 1 is incompatible "
                    "with host_loop (a host-driven single-device "
                    "baseline); use_kernel composes with sharding")
        if self.sketch is not None:
            if not isinstance(self.sketch, SketchSpec):
                raise ExperimentError(
                    "Experiment.sketch must be a SketchSpec, "
                    f"got {type(self.sketch).__name__}")
            try:
                self.sketch.validate()
            except ValueError as e:
                raise ExperimentError(str(e)) from e
        if self.steering is not None:
            if not isinstance(self.steering, Steering):
                raise ExperimentError(
                    "Experiment.steering must be a Steering, "
                    f"got {type(self.steering).__name__}")
            try:
                self.steering.validate()
            except ValueError as e:
                raise ExperimentError(str(e)) from e
            if self.steering.enabled:
                if self.host_loop:
                    raise ExperimentError(
                        "steering is driven from the superstep "
                        "collector; host_loop has no block boundary "
                        "to steer at")
                if self.steering.bimodality and self.sketch is None:
                    raise ExperimentError(
                        "Steering.bimodality reads window histograms; "
                        "set Experiment.sketch as well")
                if self.steering.tau_switch \
                        and self.method is not Method.TAU_LEAP:
                    raise ExperimentError(
                        "Steering.tau_switch only applies to "
                        "method=Method.TAU_LEAP runs")
                if (isinstance(self.pipeline_depth, int)
                        and self.pipeline_depth > 1):
                    raise ExperimentError(
                        "steering forces lock-step collection "
                        "(decisions must see block k before block k+1 "
                        "dispatches), which is incompatible with an "
                        f"explicit pipeline_depth={self.pipeline_depth};"
                        " use pipeline_depth=1 or 'auto' (resolves to "
                        "1 under steering — see "
                        "Telemetry.pipeline_depth_effective)")
        if self.recovery is not None:
            if not isinstance(self.recovery, Recovery):
                raise ExperimentError(
                    "Experiment.recovery must be a Recovery, "
                    f"got {type(self.recovery).__name__}")
            try:
                self.recovery.validate()
            except ValueError as e:
                raise ExperimentError(str(e)) from e
            if self.recovery.workers > 1:
                self._validate_farm()
        for s in self.sinks:
            if not callable(s):
                raise ExperimentError(f"sink {s!r} is not callable")

    def _validate_farm(self) -> None:
        """Cross-checks for the multi-process farm
        (Recovery.workers > 1, DESIGN.md §3i). The bitwise-merge
        contract needs clean shard boundaries: whole stat blocks (and,
        per point, whole sweep points) per worker."""
        w = self.recovery.workers
        if self.partitioning is not None and self.partitioning.n_shards > 1:
            raise ExperimentError(
                "Recovery.workers shards the ensemble at the PROCESS "
                "level; in-process device sharding inside each worker "
                f"(Partitioning.n_shards={self.partitioning.n_shards}) "
                "is not supported — use Partitioning(n_shards=1, "
                "stat_blocks=...) to pin the statistics partition, or "
                "drop workers")
        n_inst = self.ensemble.n_instances
        blocks = (self.partitioning.blocks
                  if self.partitioning is not None else w)
        if blocks % w or n_inst % blocks:
            raise ExperimentError(
                f"Recovery.workers={w} needs each worker to own whole "
                f"stat blocks: stat_blocks ({blocks}) must be a "
                f"multiple of workers and divide n_instances "
                f"({n_inst})")
        if self.reduction is Reduction.PER_POINT \
                and self.ensemble.n_points % w:
            raise ExperimentError(
                f"Recovery.workers={w} with Reduction.PER_POINT needs "
                "each worker to own whole sweep points: n_points "
                f"({self.ensemble.n_points}) must divide evenly over "
                "workers")
        if self.steering is not None and self.steering.enabled:
            if self.steering.reallocate:
                raise ExperimentError(
                    "Steering.reallocate moves lanes ACROSS sweep "
                    "points, which cannot be replayed inside "
                    "process-local shards; drop reallocate or run "
                    "with workers=1")
            if (self.steering.ci_rel_tol > 0 or self.steering.bimodality) \
                    and self.reduction is not Reduction.PER_POINT:
                raise ExperimentError(
                    "steering convergence decisions under "
                    "Recovery.workers > 1 need per-point statistics "
                    "(each worker owns whole points and reproduces "
                    "the global decision locally); use "
                    "Reduction.PER_POINT or workers=1")

    # convenience constructors ----------------------------------------
    def with_(self, **changes) -> "Experiment":
        return dataclasses.replace(self, **changes)
