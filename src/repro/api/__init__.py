"""repro.api — declarative experiment layer (DESIGN.md §1).

An `Experiment` composes a model (CWCModel or ReactionSystem), an
`Ensemble` (replicas and/or a parameter sweep), a `Schedule` (time grid
plus typed `Schema`/`Policy` enums), a `Reduction` mode, and output
sinks. `simulate(experiment)` validates, compiles, runs, and returns a
`SimulationResult` handle.

    from repro.api import (Ensemble, Experiment, Schedule, Schema,
                           simulate)

    result = simulate(Experiment(
        model=lotka_volterra(2),
        ensemble=Ensemble.make(replicas=64),
        schedule=Schedule(t_end=10.0, n_windows=50, schema=Schema.ONLINE),
    ))
    result.means()        # (windows, n_obs)
    result.telemetry      # wall time, peak memory, dispatch counts
"""
from repro.api.result import SimulationResult, Telemetry
from repro.api.run import simulate
from repro.api.spec import (
    Ensemble,
    Experiment,
    ExperimentError,
    Method,
    Partitioning,
    Policy,
    Recovery,
    Reduction,
    Schedule,
    Schema,
    SketchSpec,
    Steering,
)
from repro.core.stream import CsvSink
from repro.core.sweep import SweepSpec
from repro.runtime.fault import FailurePlan

__all__ = [
    "CsvSink",
    "Ensemble",
    "Experiment",
    "ExperimentError",
    "FailurePlan",
    "Method",
    "Partitioning",
    "Policy",
    "Recovery",
    "Reduction",
    "Schedule",
    "Schema",
    "SimulationResult",
    "SketchSpec",
    "Steering",
    "SweepSpec",
    "Telemetry",
    "simulate",
]
