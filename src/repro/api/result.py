"""SimulationResult — the handle `simulate()` returns.

Wraps the running (or finished) engine: streamed records, per-sweep-
point grouped statistics, raw trajectories, wall-time / peak-memory
telemetry, and the checkpoint()/resume() lifecycle. The handle owns the
run loop so a partially-run experiment (``max_windows=``) can be
continued in-process, or from a checkpoint file in a later process.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core.stream import StatsRecord


@dataclass(frozen=True)
class Telemetry:
    """Run telemetry (DESIGN.md §6).

    dispatches: jitted device launches for pool advancement — the new
    window_step path pays one per window, the legacy host loop one per
    (group × window), and supersteps (window_block=W) one per BLOCK
    (1/W per window).
    host_syncs: blocking device->host pulls (stats, samples, costs);
    under supersteps one combined ring pull per block, so the
    amortised per-window rate drops below 1.
    window_wall_times: per-window wall shares. On per-window paths this
    is an async-dispatch measure (the blocking pull is excluded); under
    supersteps each entry is 1/W of its block's dispatch + collect
    wall, so the hidden pull is included.
    peak_buffered_bytes: engine-side trajectory buffering high-water
    mark (schema iii's memory bound).
    peak_rss_bytes: process high-water RSS where the platform reports
    it (None otherwise).
    steps_per_window: pool-total solver iterations per window (exact:
    events fired; tau-leap: accepted leaps + exact-fallback events) —
    the per-method work metric the tau-leap speedup claim is measured
    in. leaps_per_window: accepted tau-leaps per window (all zero on
    Method.EXACT); steps - leaps is the exact-fallback share.

    WALL ATTRIBUTION (block_walls): under pipelined dispatch the only
    well-defined host walls are block-level, and they split in two:
    * DISPATCH-WALL — host time to ENQUEUE a unit's device work (build
      operands, launch the async dispatch, queue the eager folds). It
      deliberately EXCLUDES device compute, which proceeds
      asynchronously underneath later enqueues.
    * COLLECT-WALL — the blocking record-ring pull (device wait +
      transfer remainder after the async prefetch) PLUS the host-side
      reduce/emit work for the unit. This is the wall the pipeline
      depth exists to hide: at depth K the collector blocks only once
      K blocks are queued behind the oldest.
    Each block_walls row is (first_window, n_windows, dispatch_s,
    collect_s) — one row per window on per-window paths (where
    window-level walls ARE measurable), one per block under
    supersteps. window_wall_times remains the legacy per-window share
    (block dispatch+pull wall / n_windows) for dashboards that want a
    per-window series.
    """

    wall_time_s: float
    window_wall_times: tuple
    peak_buffered_bytes: int
    dispatches: int
    host_syncs: int
    peak_rss_bytes: Optional[int]
    steps_per_window: tuple = ()
    leaps_per_window: tuple = ()
    # straggler watchdog (runtime/straggler.py): (window, wall_s,
    # rolling_median) entries whose wall share exceeded the watchdog
    # factor x the rolling median, and the flagged fraction over ALL
    # observed windows (a monotone counter — NOT the bounded median
    # window, which saturates at its maxlen)
    straggler_windows: tuple = ()
    straggler_rate: float = 0.0
    # supervised runs (Experiment.recovery): engine teardown+restore
    # cycles the RunSupervisor performed; 0 for unsupervised runs and
    # for supervised runs that never faulted
    restarts: int = 0
    # straggler re-dispatches the supervisor performed (EngineStall
    # recoveries) — tracked apart from `restarts` so slow windows never
    # consume the crash budget
    stall_redispatches: int = 0
    # depth-K superstep pipeline (DESIGN.md §3e)
    block_walls: tuple = ()  # (w0, n_win, dispatch_s, collect_s) rows
    pipeline_depth: int = 1  # resolved depth ("auto" probes 1st block)
    # the depth the collector actually ran at: steering forces
    # lock-step, so a steered run reports 1 here no matter what depth
    # was requested ("auto" resolves to 1; explicit >1 is rejected at
    # validation) — the forcing is visible, never silent
    pipeline_depth_effective: int = 1
    peak_inflight_blocks: int = 0  # max queued rings observed
    snapshot_saves: int = 0  # checkpoints served from a ring snapshot
    ckpt_flushes: int = 0  # checkpoints that had to flush the pipeline


def _peak_rss_bytes() -> Optional[int]:
    try:
        import resource
        import sys

        ru = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
        # linux reports KiB, macOS bytes
        return ru * (1 if sys.platform == "darwin" else 1024)
    except Exception:
        return None


class SimulationResult:
    def __init__(self, experiment, engine):
        self.experiment = experiment
        self._engine = engine
        self._wall_time = 0.0

    # ------------------------------------------------------------ run
    def resume(self, max_windows: Optional[int] = None,
               checkpoint_path: Optional[str] = None) -> "SimulationResult":
        """Advance the experiment, at most `max_windows` windows (all
        remaining if None), checkpointing after every window when a
        path is given. Returns self for chaining.

        With `window_block > 1` the run advances in pipelined
        supersteps: up to `pipeline_depth` blocks are dispatched ahead
        of the oldest ring pull, so host-side reduction and sinks
        overlap device simulation. A `checkpoint_path` saves after
        every collected block, on that block's boundary — served from
        the in-flight ring's entry SNAPSHOT (engine.enable_snapshots),
        so saving no longer disables the dispatch-ahead or flushes the
        pipeline. `max_windows` may cut the final block short — such a
        mid-block checkpoint can only be resumed with a window_block
        dividing its window index."""
        eng = self._engine
        t0 = time.perf_counter()
        done = 0
        try:
            # steered runs always use the block loop (decision points
            # live at collected block boundaries), even window_block=1
            if eng.cfg.window_block == 1 and eng._steer is None:
                while eng._window < len(eng.grid) and (
                        max_windows is None or done < max_windows):
                    eng.run_window()
                    done += 1
                    if checkpoint_path:
                        eng.checkpoint(checkpoint_path)
            else:
                limit = len(eng.grid) if max_windows is None else min(
                    len(eng.grid), eng._window + max_windows)
                if checkpoint_path and eng._steer is None:
                    # each save lands on the just-collected block's
                    # boundary, served from the oldest in-flight ring's
                    # entry snapshot — the pipeline keeps running
                    # underneath (steered runs are lock-step anyway)
                    eng.enable_snapshots()
                while eng._window < limit:
                    got = eng.run_block(dispatch_limit=limit)
                    if checkpoint_path and got:
                        eng.checkpoint(checkpoint_path)
                eng.flush()
        finally:
            self._wall_time += time.perf_counter() - t0
        if self.completed:
            eng.stream.close()
        return self

    def checkpoint(self, path: str) -> None:
        """Serialise pool + scheduler + emitted records to `path`."""
        self._engine.checkpoint(path)

    @property
    def completed(self) -> bool:
        return self._engine._window >= len(self._engine.grid)

    @property
    def windows_run(self) -> int:
        return self._engine._window

    # ----------------------------------------------------------- data
    @property
    def obs_names(self) -> list[str]:
        return list(self._engine.obs_names)

    @property
    def records(self) -> list[StatsRecord]:
        return self._engine.stream.records()

    def means(self) -> np.ndarray:
        """(windows_run, n_obs) ensemble means."""
        return np.stack([r.mean for r in self.records])

    @property
    def t_grid(self) -> np.ndarray:
        return np.asarray(self._engine.grid)

    def trajectories(self) -> Optional[np.ndarray]:
        """(I, T, n_obs) raw samples — schemas i/ii always; schema iii
        when Experiment.record_trajectories was set."""
        return self._engine.trajectories()

    def per_point(self) -> Optional[dict]:
        """Grouped per-sweep-point statistics (Reduction.PER_POINT).

        Returns {"mean"|"var"|"ci90"|"n": (windows, points, n_obs)},
        plus "points": the sweep point dicts, or None when the run used
        a pooled ensemble reduction.
        """
        grouped = self._engine.grouped_stats()
        if not grouped:
            return None
        out = {
            "n": np.stack([g.n for g in grouped]),
            "mean": np.stack([g.mean for g in grouped]),
            "var": np.stack([g.var for g in grouped]),
            "ci90": np.stack([g.ci90 for g in grouped]),
        }
        sweep = self.experiment.ensemble.sweep
        out["points"] = sweep.points() if sweep else [{}]
        return out

    def final_state(self) -> np.ndarray:
        """(I, S) species counts at the last completed window."""
        return np.asarray(self._engine._pool.x)

    def sketches(self) -> list:
        """Per-window `WindowSketch`es (hist (G, n_obs, n_bins) int32,
        rare (G, n_obs, n_thr) int32 or None) when the Experiment
        carried a SketchSpec; empty list otherwise. Derive quantiles or
        bimodality flags with `repro.stats.quantiles_from_hist` /
        `bimodality_from_hist`."""
        return self._engine.sketches()

    def steering_report(self) -> Optional[dict]:
        """The steering policy's savings + decision summary (stopped
        points, windows saved, pinned lanes, bimodal flags, decision
        log), or None when the Experiment carried no active
        Steering."""
        return self._engine.steering_report()

    def recovery_report(self) -> Optional[dict]:
        """The RunSupervisor's event log + summary (restarts, faults
        by kind, final shard count after any elastic degradation,
        ordered events), or None when the Experiment carried no
        Recovery."""
        return getattr(self, "_recovery", None)

    # ------------------------------------------------------ telemetry
    @property
    def telemetry(self) -> Telemetry:
        eng = self._engine
        return Telemetry(
            wall_time_s=self._wall_time,
            window_wall_times=tuple(eng.wall_times),
            peak_buffered_bytes=eng.peak_buffered_bytes,
            dispatches=eng.n_dispatches,
            host_syncs=eng.n_host_syncs,
            peak_rss_bytes=_peak_rss_bytes(),
            steps_per_window=tuple(eng.window_steps),
            leaps_per_window=tuple(eng.window_leaps),
            straggler_windows=tuple(eng.watchdog.flagged),
            straggler_rate=eng.watchdog.straggler_rate(),
            restarts=getattr(self, "_restarts", 0),
            stall_redispatches=getattr(self, "_stall_redispatches", 0),
            block_walls=tuple(eng.block_walls),
            pipeline_depth=eng.pipeline_depth,
            pipeline_depth_effective=getattr(
                eng, "pipeline_depth_effective", eng.pipeline_depth),
            peak_inflight_blocks=eng.peak_inflight_blocks,
            snapshot_saves=eng.n_snapshot_saves,
            ckpt_flushes=eng.n_ckpt_flushes)

    def __repr__(self) -> str:
        state = "completed" if self.completed else (
            f"{self.windows_run}/{len(self._engine.grid)} windows")
        return (f"SimulationResult({state}, instances="
                f"{self.experiment.ensemble.n_instances}, "
                f"schema={self.experiment.schedule.schema.value!r})")
