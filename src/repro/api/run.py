"""simulate() — validate, compile, run, return a result handle.

This is the single entry point over the engine: it resolves the typed
spec onto `SimulationEngine` (schema/policy enums -> engine strings,
sweep -> per-instance rate matrix, PER_POINT reduction -> instance
group ids), attaches sinks, and drives the window loop through the
returned `SimulationResult` so checkpoint/resume and partial runs share
one code path.
"""
from __future__ import annotations

import os
from typing import Optional

from repro.api.result import SimulationResult
from repro.api.spec import Experiment, ExperimentError, Reduction
from repro.core.engine import SimConfig, SimulationEngine
from repro.core.sweep import sweep_rates


def observable_names(model) -> list[str]:
    """The observable column names an Experiment on `model` will report
    (what a CsvSink wants), without building an engine."""
    from repro.core.engine import resolve_observables

    return resolve_observables(model)[1]


def build_engine(experiment: Experiment, mesh=None,
                 shard=None) -> SimulationEngine:
    """Compile an Experiment down to a ready-to-run engine (no windows
    are run). Exposed for benchmarks; prefer simulate().

    When the Experiment carries a multi-shard Partitioning and no mesh
    is supplied, the farm's mesh is built by the dispatch seam
    (`core/dispatch.select_dispatch`) with
    `compat.make_mesh((n_shards,), (axis,))`.

    `shard=(lo, hi, stat_blocks)` is the multi-process farm worker's
    seam (runtime/worker.py): the engine covers only instance rows
    [lo, hi) of the GLOBAL ensemble — same seed, rates/group ids
    sliced to the range, and (crucially) RNG key rows taken from the
    GLOBAL `jax.random.split(PRNGKey(seed), I)` table, so each lane's
    counter-based stream is the one the single-process run would give
    it. `stat_blocks` is the worker's share of the global Welford
    block partition (contiguous, so worker blocks ARE global blocks)."""
    experiment.validate()
    ens = experiment.ensemble
    sched = experiment.schedule
    part = experiment.partitioning
    lo, hi = (0, ens.n_instances) if shard is None else shard[:2]
    if shard is not None:
        from repro.core.dispatch import Partitioning

        part = Partitioning(n_shards=1, stat_blocks=shard[2])
    cfg = SimConfig(
        n_instances=hi - lo,
        t_end=float(sched.t_end),
        n_windows=sched.n_windows,
        n_lanes=min(experiment.n_lanes, hi - lo),
        schema=sched.schema.value,
        policy=sched.policy.value,
        seed=experiment.seed,
        max_steps_per_window=sched.max_steps_per_window,
        use_kernel=experiment.use_kernel,
        host_loop=experiment.host_loop,
        kernel_chunk_steps=experiment.kernel_chunk_steps,
        kernel_max_chunks=experiment.kernel_max_chunks,
        method=experiment.method.value,
        tau_eps=experiment.tau_eps,
        tau_fallback=experiment.tau_fallback,
        window_block=experiment.window_block,
        pipeline_depth=experiment.pipeline_depth,
        sparse=experiment.sparse)
    group_ids = (ens.group_ids()
                 if experiment.reduction is Reduction.PER_POINT else None)
    if group_ids is not None and shard is not None:
        # points are contiguous replica runs, so the slice is whole
        # points; re-base to 0 so the worker's grouped rows line up
        # with its local point index (global index = local + base)
        group_ids = group_ids[lo:hi] - group_ids[lo]
    try:
        engine = SimulationEngine(
            experiment.model, cfg, mesh=mesh, group_ids=group_ids,
            record_trajectories=experiment.record_trajectories,
            partitioning=part, sketch=experiment.sketch,
            steering=experiment.steering, _deprecated=False)
    except ValueError as e:
        # dispatch-seam errors (device count, mesh/partitioning
        # mismatch) surface in the API's vocabulary
        raise ExperimentError(str(e)) from e
    if shard is not None:
        import jax.numpy as jnp

        from repro.core.gillespie import init_lanes

        global_pool = init_lanes(engine.system, ens.n_instances,
                                 experiment.seed)
        engine._pool = engine._dispatch.place(engine._pool._replace(
            key=jnp.asarray(global_pool.key)[lo:hi]))
        if group_ids is not None:
            # declare this shard's place in the GLOBAL (V, G) stats
            # layout: shards are contiguous, block size is uniform, so
            # worker blocks/points ARE global blocks/points at an
            # offset — the engine folds grouped stats through the
            # zero-extended global stack (steering sees reference bits)
            bs = (hi - lo) // shard[2]
            engine.set_global_stats_layout(
                v_total=ens.n_instances // bs, v0=lo // bs,
                g_total=ens.n_points, g0=lo // ens.replicas)
    if ens.sweep is not None:
        try:
            rates = sweep_rates(engine.system, ens.sweep)
        except KeyError as e:
            raise ExperimentError(
                f"sweep names a rate the model does not define: {e}; "
                f"reactions are {list(engine.system.reaction_names)}"
            ) from e
        engine.set_rates(rates[lo:hi] if shard is not None else rates)
    return engine


def simulate(experiment: Experiment, *,
             checkpoint_path: Optional[str] = None,
             resume: bool = False,
             max_windows: Optional[int] = None,
             mesh=None) -> SimulationResult:
    """Run an Experiment end to end.

    checkpoint_path: checkpoint after every window (and the restore
    source when resume=True).
    resume: restore pool/records from checkpoint_path before running —
    the file must exist; records emitted before the checkpoint are
    replayed into the result buffer AND into this run's sinks (a fresh
    CsvSink starts from an empty file, so the replay keeps it
    complete).
    max_windows: stop after this many windows; the returned handle's
    `.resume()` continues the same run in-process.

    With `experiment.recovery` set, the run is handed to
    `runtime.supervisor.RunSupervisor` (cadenced checkpoints +
    restart-on-fault + elastic degradation, DESIGN.md §3h). The
    supervisor owns checkpointing and drives the run to completion,
    so checkpoint_path/resume/max_windows are rejected alongside it.
    """
    if experiment.recovery is not None:
        if checkpoint_path or resume or max_windows is not None:
            raise ExperimentError(
                "Experiment.recovery owns checkpointing and drives the "
                "run to completion; drop checkpoint_path/resume/"
                "max_windows (set Recovery.ckpt_dir and cadence "
                "instead)")
        if experiment.recovery.workers > 1:
            # multi-process elastic farm: a coordinator process shards
            # the ensemble over worker processes and merges their
            # results bitwise (DESIGN.md §3i)
            from repro.runtime.coordinator import FarmCoordinator

            return FarmCoordinator(experiment,
                                   experiment.recovery).run()
        from repro.runtime.supervisor import RunSupervisor

        return RunSupervisor(experiment, experiment.recovery,
                             mesh=mesh).run()
    engine = build_engine(experiment, mesh=mesh)
    if resume:
        if not checkpoint_path:
            raise ExperimentError("resume=True requires checkpoint_path")
        path = (checkpoint_path if checkpoint_path.endswith(".npz")
                else checkpoint_path + ".npz")
        if not os.path.exists(path):
            raise ExperimentError(
                f"resume=True but no checkpoint at {path!r}")
        try:
            engine.restore(checkpoint_path)
        except ValueError as e:
            # e.g. a mid-block checkpoint under window_block > 1
            raise ExperimentError(str(e)) from e
    for sink in experiment.sinks:
        engine.stream.attach(sink)
        for rec in engine.stream.records():  # replay restored windows
            sink(rec)
    result = SimulationResult(experiment, engine)
    return result.resume(max_windows=max_windows,
                         checkpoint_path=checkpoint_path)
