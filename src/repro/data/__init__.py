"""Data pipeline: deterministic synthetic streams with prefetch."""
