"""Synthetic token pipeline — a first-class stream (guideline G1/G3).

Deterministic per (seed, step): any replica can regenerate any batch,
which is what makes replica re-spawn after a failure trivial (the data
cursor is just the step index — no reader state to recover). Prefetch
runs in a background thread with a bounded double-buffer, so host→device
transfer overlaps the device step (the lock-free SPSC queue analogue).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclass
class DataConfig:
    seed: int = 0
    zipf_a: float = 1.2  # skewed token distribution (realistic softmax load)


def synth_batch(cfg: ModelConfig, batch: int, seq: int, step: int,
                dcfg: DataConfig = DataConfig()) -> dict:
    """Deterministic synthetic LM batch for a given step index."""
    rng = np.random.default_rng(np.random.SeedSequence(
        [dcfg.seed, step, 0xB10B]))
    v = cfg.vocab_size
    toks = rng.zipf(dcfg.zipf_a, size=(batch, seq + 1)).astype(np.int64)
    toks = (toks - 1) % v
    out: dict = {}
    if cfg.frontend == "vision":
        p = cfg.frontend_tokens
        out["embeds"] = rng.standard_normal(
            (batch, p, cfg.d_model)).astype(np.float32)
        out["tokens"] = toks[:, :seq - p].astype(np.int32)
        out["targets"] = toks[:, 1:seq + 1].astype(np.int32)
        mask = np.ones((batch, seq), np.float32)
        mask[:, :p] = 0.0  # no loss on image positions
        out["loss_mask"] = mask
    else:
        out["tokens"] = toks[:, :seq].astype(np.int32)
        out["targets"] = toks[:, 1:].astype(np.int32)
        out["loss_mask"] = np.ones((batch, seq), np.float32)
    return out


class PrefetchPipeline:
    """Bounded background prefetch (depth-2 double buffer by default)."""

    def __init__(self, cfg: ModelConfig, batch: int, seq: int,
                 start_step: int = 0, depth: int = 2,
                 dcfg: DataConfig = DataConfig()):
        self.cfg, self.batch, self.seq, self.dcfg = cfg, batch, seq, dcfg
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self) -> None:
        step = self._step
        while not self._stop.is_set():
            b = synth_batch(self.cfg, self.batch, self.seq, step, self.dcfg)
            b["_step"] = step
            while not self._stop.is_set():
                try:
                    self._q.put(b, timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __next__(self) -> dict:
        return self._q.get()

    def __iter__(self) -> Iterator[dict]:
        return self

    def close(self) -> None:
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
