"""Device-side streaming sketches over the window record stream.

The Welford records (core/reduction.py) answer "what are the moments";
the sketches here answer the distribution-shape and tail questions the
steering layer acts on — per (sweep point, observable):

* a fixed-bin histogram (`n_bins` equal-width bins over [lo, hi], with
  both overflow tails clamped into the edge bins), from which p10/p50/
  p90 quantile estimates and a bimodality flag are derived host-side;
* rare-event counters: how many instances sit at or above each
  configured threshold this window.

MERGE DISCIPLINE (the §3f associativity rule): every sketch is an
int32 COUNT array and every merge is elementwise integer addition —
fully associative AND commutative, with all-zeros as the exact
identity. A shard's partial histogram psum'd over the mesh axis is
therefore bitwise identical to the full-pool histogram the unsharded
fused path computes, for any shard count and any summation order —
the same invariant `reduction.gather_blocks_over_axis` engineers for
the float Welford stacks, obtained for free here by staying integer.
The per-window sketch depends only on the window's observable samples,
so it is also bitwise independent of `window_block` (the superstep
scan body computes the identical values).

Quantile estimation is deliberately reservoir-free (a P² estimator
keeps five floating marks whose merge is NOT associative; a reservoir
breaks the counter-stream reproducibility budget): quantiles are read
off the histogram CDF host-side with linear interpolation inside the
holding bin, so their worst-case error is one bin width — a bound the
tests assert against offline numpy quantiles.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple, Optional, Sequence, Union

import jax.numpy as jnp
import numpy as np

__all__ = [
    "SketchSpec", "SketchParams", "WindowSketch", "window_sketch",
    "quantiles_from_hist", "bimodality_from_hist",
]


@dataclass(frozen=True)
class SketchSpec:
    """What to sketch. Pure data — `resolve()` turns it into the
    device-ready per-observable bin geometry.

    n_bins: histogram bins per (point, observable); error of any
    histogram-derived quantile is bounded by one bin width.
    lo / hi: histogram support, a scalar (shared by every observable)
    or one value per observable. hi=None auto-scales per observable
    from the model's initial state: hi_j = max(8 * obs_j(t=0), 32) —
    deterministic, and generous enough for birth-death style growth.
    thresholds: rare-event levels; each window counts instances with
    obs >= threshold (same thresholds applied to every observable).
    """

    n_bins: int = 32
    lo: Union[float, Sequence[float]] = 0.0
    hi: Union[float, Sequence[float], None] = None
    thresholds: Sequence[float] = ()

    def validate(self) -> None:
        if self.n_bins < 2:
            raise ValueError(
                f"SketchSpec.n_bins must be >= 2, got {self.n_bins}")
        if self.hi is not None:
            lo = np.atleast_1d(np.asarray(self.lo, np.float64))
            hi = np.atleast_1d(np.asarray(self.hi, np.float64))
            if lo.shape[0] > 1 and hi.shape[0] > 1 \
                    and lo.shape[0] != hi.shape[0]:
                raise ValueError(
                    f"SketchSpec.lo/hi lengths disagree: "
                    f"{lo.shape[0]} vs {hi.shape[0]}")
            if np.any(np.broadcast_arrays(hi, lo)[0]
                      <= np.broadcast_arrays(hi, lo)[1]):
                raise ValueError("SketchSpec.hi must exceed lo")

    def resolve(self, obs0: np.ndarray) -> "SketchParams":
        """Bind the spec to a model: obs0 (n_obs,) is the observable
        vector at t=0 (used only when hi=None)."""
        self.validate()
        n_obs = int(np.asarray(obs0).shape[0])
        lo = np.broadcast_to(
            np.atleast_1d(np.asarray(self.lo, np.float32)),
            (n_obs,)).astype(np.float32)
        if self.hi is None:
            hi = np.maximum(8.0 * np.asarray(obs0, np.float32), 32.0)
            hi = np.maximum(hi, lo + 1.0).astype(np.float32)
        else:
            hi = np.broadcast_to(
                np.atleast_1d(np.asarray(self.hi, np.float32)),
                (n_obs,)).astype(np.float32)
        width = ((hi - lo) / self.n_bins).astype(np.float32)
        return SketchParams(
            lo=lo, width=width, n_bins=int(self.n_bins),
            thresholds=np.asarray(tuple(self.thresholds), np.float32))


class SketchParams(NamedTuple):
    """Resolved bin geometry (host numpy; callers device_put as
    needed). lo/width: (n_obs,); thresholds: (n_thr,) (possibly
    empty — then no rare counters are produced)."""

    lo: np.ndarray
    width: np.ndarray
    n_bins: int
    thresholds: np.ndarray

    @property
    def n_thr(self) -> int:
        return int(self.thresholds.shape[0])

    def edges(self) -> np.ndarray:
        """(n_obs, n_bins + 1) bin edges."""
        k = np.arange(self.n_bins + 1, dtype=np.float32)
        return self.lo[:, None] + self.width[:, None] * k[None, :]


class WindowSketch(NamedTuple):
    """One window's pulled sketch: hist (G, n_obs, n_bins) int32 and
    rare (G, n_obs, n_thr) int32 or None (no thresholds configured)."""

    hist: np.ndarray
    rare: Optional[np.ndarray]


def window_sketch(obs, gids, n_groups: int, lo, width, n_bins: int,
                  thresholds=None):
    """Sketch one window's samples: obs (I, n_obs) f32, gids (I,) int32
    group (sweep point) of each instance. Returns (hist, rare):
    hist (n_groups, n_obs, n_bins) int32, rare (n_groups, n_obs, n_thr)
    int32 or None when thresholds is None/empty.

    Values below lo land in bin 0, values at/above hi in bin
    n_bins - 1 (clamped tails — the mass is never dropped, so the
    histogram total always equals the group's instance count).

    Pure jnp on int32 counts: runs identically inside the sharded
    shard_map body (followed by ONE psum — integer adds are exact and
    associative, so shard partials sum bitwise to the full-pool
    histogram) and eagerly on the fused path's full-pool obs.
    """
    lo = jnp.asarray(lo, jnp.float32)
    width = jnp.asarray(width, jnp.float32)
    b = jnp.floor((obs.astype(jnp.float32) - lo[None, :])
                  / width[None, :])
    b = jnp.clip(b, 0.0, float(n_bins - 1)).astype(jnp.int32)  # (I, O)
    onehot = (b[:, :, None]
              == jnp.arange(n_bins, dtype=jnp.int32)[None, None, :])
    gmask = (gids[:, None]
             == jnp.arange(n_groups, dtype=jnp.int32)[None, :])  # (I, G)
    hist = (gmask[:, :, None, None]
            & onehot[:, None, :, :]).astype(jnp.int32).sum(axis=0)
    rare = None
    if thresholds is not None and int(thresholds.shape[0]):
        thr = jnp.asarray(thresholds, jnp.float32)
        over = obs.astype(jnp.float32)[:, :, None] >= thr[None, None, :]
        rare = (gmask[:, :, None, None]
                & over[:, None, :, :]).astype(jnp.int32).sum(axis=0)
    return hist, rare


# ------------------------------------------------------- host analysis
def quantiles_from_hist(hist: np.ndarray, lo, width,
                        qs=(0.1, 0.5, 0.9)) -> np.ndarray:
    """Histogram-CDF quantile estimates, deterministic numpy.

    hist: (..., n_obs, n_bins) int counts; lo/width: (n_obs,).
    Returns (..., n_obs, len(qs)) float64. The q-quantile is read off
    the inclusive bin CDF with linear interpolation inside the holding
    bin — error is bounded by one bin width for any distribution whose
    support lies inside [lo, hi] (tails are clamped into edge bins, so
    edge-bin estimates saturate at the support boundary).
    """
    hist = np.asarray(hist, np.float64)
    lo = np.asarray(lo, np.float64)
    width = np.asarray(width, np.float64)
    n_bins = hist.shape[-1]
    cdf = np.cumsum(hist, axis=-1)
    total = np.maximum(cdf[..., -1:], 1.0)
    out = np.empty(hist.shape[:-1] + (len(qs),), np.float64)
    for k, q in enumerate(qs):
        target = q * total[..., 0]
        j = np.sum(cdf < target[..., None], axis=-1)
        j = np.minimum(j, n_bins - 1)
        below = np.take_along_axis(
            np.concatenate([np.zeros_like(cdf[..., :1]), cdf], axis=-1),
            j[..., None], axis=-1)[..., 0]
        in_bin = np.take_along_axis(hist, j[..., None], axis=-1)[..., 0]
        frac = np.where(in_bin > 0, (target - below)
                        / np.maximum(in_bin, 1.0), 0.5)
        out[..., k] = lo + width * (j + np.clip(frac, 0.0, 1.0))
    return out


def bimodality_from_hist(hist: np.ndarray, min_frac: float = 0.1,
                         valley_frac: float = 0.5) -> np.ndarray:
    """Deterministic two-peak test on (..., n_bins) int histograms.

    Flags a histogram as bimodal when two local maxima, each holding
    >= min_frac of the total mass after 3-bin box smoothing, are
    separated by a valley whose depth is <= valley_frac x the smaller
    peak. Returns a (...,) bool array. Integer-exact inputs + fixed
    float ops -> the same flag on every dispatch path.
    """
    h = np.asarray(hist, np.float64)
    sm = h.copy()
    sm[..., 1:-1] = (h[..., :-2] + h[..., 1:-1] + h[..., 2:]) / 3.0
    total = np.maximum(h.sum(axis=-1), 1.0)

    flat = sm.reshape(-1, sm.shape[-1])
    tot = total.reshape(-1)
    out = np.zeros(flat.shape[0], bool)
    for i in range(flat.shape[0]):
        row = flat[i]
        peaks = [j for j in range(row.shape[0])
                 if row[j] >= min_frac * tot[i]
                 and (j == 0 or row[j] >= row[j - 1])
                 and (j == row.shape[0] - 1 or row[j] > row[j + 1])]
        for a in range(len(peaks)):
            for b in range(a + 1, len(peaks)):
                lo_p, hi_p = peaks[a], peaks[b]
                if hi_p - lo_p < 2:
                    continue
                valley = row[lo_p + 1:hi_p].min()
                if valley <= valley_frac * min(row[lo_p], row[hi_p]):
                    out[i] = True
        if out[i]:
            continue
    return out.reshape(sm.shape[:-1])
