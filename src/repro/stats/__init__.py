"""Streaming analytics over the simulation record stream (DESIGN.md §3f).

Device-side sketches (fixed-bin histograms, rare-event threshold
counters) accumulated per window alongside the Welford records, plus
the host-side estimators (quantiles, bimodality) the steering layer
consumes. All merges are associative integer sums, so sketches are
bitwise identical across fused/sharded dispatch, any shard count, and
any superstep width.
"""
from repro.stats.sketch import (
    SketchParams,
    SketchSpec,
    WindowSketch,
    bimodality_from_hist,
    quantiles_from_hist,
    window_sketch,
)

__all__ = [
    "SketchParams", "SketchSpec", "WindowSketch",
    "bimodality_from_hist", "quantiles_from_hist", "window_sketch",
]
