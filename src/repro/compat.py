"""Version-compat shims for the JAX public API.

The codebase targets the modern surface (`jax.make_mesh(...,
axis_types=...)`, `jax.shard_map(..., check_vma=...)`); older releases
(<= 0.4.x) expose neither `jax.sharding.AxisType` nor a top-level
`shard_map`. Everything that builds meshes or shard_maps goes through
this module so one guarded lookup covers both worlds.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax

# `jax.sharding.AxisType` (and the `axis_types=` kwarg on make_mesh)
# landed after 0.4.x; None means "legacy jax — omit the kwarg".
AXIS_TYPE_AUTO = getattr(
    getattr(jax.sharding, "AxisType", None), "Auto", None)


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
              devices: Optional[Sequence] = None) -> jax.sharding.Mesh:
    """`jax.make_mesh` with Auto axis types where the kwarg exists."""
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if AXIS_TYPE_AUTO is not None:
        kwargs["axis_types"] = (AXIS_TYPE_AUTO,) * len(axis_names)
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """`jax.shard_map`, falling back to the experimental entry point.

    `check_vma` (new name) maps onto `check_rep` (old name); both gate
    the same replication/varying-axes check.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)
