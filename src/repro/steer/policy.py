"""SteeringPolicy — the between-block controller.

The engine's superstep collector (`engine.run_block`) hands the policy
one DECISION POINT per collected block: the freshest per-point Welford
statistics, the block's window sketches (repro/stats), and the exact
per-lane step/leap counters. The policy returns `SteeringActions`; the
engine applies them to the device pool before dispatching the next
block. StochKit-FF's insight, made multicore-aware: reduce
trajectories online, and USE what the reduction learns while the farm
is still running.

Four levers (each independently enabled on the `Steering` spec):

* EARLY-STOP: a sweep point whose per-observable relative CI
  half-width (ci90 / max(|mean|, 1)) stays under `ci_rel_tol` after
  `min_windows` windows is converged — its lanes are marked dead, so
  subsequent windows cost it nothing (dead lanes freeze; the window
  while_loop skips them by construction).
* REALLOCATE: all but one of a freshly stopped point's lanes are
  re-seeded onto the live point with the WORST relative CI, cloning a
  donor lane's trajectory state (x, t, dead) while keeping the moved
  lane's OWN RNG stream — trajectory splitting: the clone shares the
  donor's past but diverges immediately, adding an extra replica from
  the boundary on. One lane stays behind so the stopped point's
  grouped record keeps a defined (frozen) value.
* TAU-SWITCH: per-lane exact<->tau-leap auto-switch. A tau-leap lane
  whose EMA leap share (accepted leaps / solver steps per block) sits
  below `min_leap_frac` after `tau_switch_min_steps` steps is spending
  its steps on rejected-leap bookkeeping — it is pinned to exact SSA
  (`LaneState.no_leap`), where the same steps cost one counter block
  each instead of a leap attempt's 2-3.
* BIMODALITY: histograms whose smoothed shape shows two separated
  modes (`stats.bimodality_from_hist`) are flagged into the decision
  log — a mean/CI record is misleading there, and downstream analyses
  (and the CLI) surface the flag.

DETERMINISM CONTRACT: decisions are pure functions of the sketch and
counter values — which are themselves bitwise identical across
dispatch paths, shard counts, and superstep widths — evaluated in a
fixed order with integer/argmax tie-breaks. A steered run is therefore
exactly reproducible from (seed, Steering spec), and a crash-restored
run (the policy state rides the engine checkpoint via `state_dict`)
replays the identical decision sequence.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from typing import NamedTuple, Optional

import numpy as np

from repro.stats.sketch import bimodality_from_hist

__all__ = ["Steering", "SteeringActions", "SteeringPolicy"]


@dataclass(frozen=True)
class Steering:
    """The steering spec (pure data; see module docstring for the four
    levers). All levers default OFF: `Steering()` is the identity
    policy, and a run carrying it is bitwise identical to one with no
    steering at all (the engine still calls decide(), which returns
    empty actions and touches nothing).

    ci_rel_tol: early-stop when every observable's ci90 / max(|mean|,
    1) falls below this (0 disables).
    min_windows: never stop a point before this many windows.
    check_every: make decisions every Nth block boundary.
    reallocate: move a stopped point's lanes (all but one) to the live
    point with the worst relative CI.
    tau_switch: enable the per-lane exact<->tau auto-switch
    (Method.TAU_LEAP runs only).
    min_leap_frac / tau_switch_min_steps: switch a lane to exact once
    its EMA leap share is below the fraction and it has taken at least
    the step count.
    ema_alpha: EMA weight for the per-lane step/leap block rates.
    bimodality: flag bimodal (point, observable) histograms (needs a
    SketchSpec on the experiment).
    """

    ci_rel_tol: float = 0.0
    min_windows: int = 4
    check_every: int = 1
    reallocate: bool = False
    tau_switch: bool = False
    min_leap_frac: float = 0.1
    tau_switch_min_steps: int = 256
    ema_alpha: float = 0.5
    bimodality: bool = False

    @property
    def enabled(self) -> bool:
        return (self.ci_rel_tol > 0 or self.reallocate
                or self.tau_switch or self.bimodality)

    def validate(self) -> None:
        if self.ci_rel_tol < 0:
            raise ValueError(
                f"Steering.ci_rel_tol must be >= 0, got {self.ci_rel_tol}")
        if self.min_windows < 1:
            raise ValueError(
                f"Steering.min_windows must be >= 1, got "
                f"{self.min_windows}")
        if self.check_every < 1:
            raise ValueError(
                f"Steering.check_every must be >= 1, got "
                f"{self.check_every}")
        if not 0 <= self.min_leap_frac <= 1:
            raise ValueError(
                f"Steering.min_leap_frac must be in [0, 1], got "
                f"{self.min_leap_frac}")
        if not 0 < self.ema_alpha <= 1:
            raise ValueError(
                f"Steering.ema_alpha must be in (0, 1], got "
                f"{self.ema_alpha}")
        if self.reallocate and not self.ci_rel_tol > 0:
            raise ValueError(
                "Steering.reallocate needs early-stopping "
                "(ci_rel_tol > 0) to free any lanes")


class SteeringActions(NamedTuple):
    """What the engine should apply before the next block.

    stop_lanes: (I,) bool — mark these lanes dead (early-stopped
    points, minus any lanes being moved).
    moves: (n_moves, 2) int32 [lane, donor] pairs — clone donor state
    onto lane (reallocation); empty (0, 2) when none.
    new_group_ids: (I,) int32 or None — regrouped sweep-point ids
    after moves.
    no_leap: (I,) bool or None — updated per-lane exact-SSA pins.
    """

    stop_lanes: np.ndarray
    moves: np.ndarray
    new_group_ids: Optional[np.ndarray]
    no_leap: Optional[np.ndarray]

    @property
    def any(self) -> bool:
        return (bool(self.stop_lanes.any()) or len(self.moves) > 0
                or self.new_group_ids is not None
                or self.no_leap is not None)


def _empty_actions(n_instances: int) -> SteeringActions:
    return SteeringActions(
        stop_lanes=np.zeros(n_instances, bool),
        moves=np.zeros((0, 2), np.int32),
        new_group_ids=None, no_leap=None)


class SteeringPolicy:
    """Host-side controller state + decision log for one run.

    Construct once per engine (engine.set_steering does); feed it
    decision points via `decide()`. All state is numpy and serialises
    through `state_dict()`/`load_state()` for checkpoint/restore.
    """

    def __init__(self, spec: Steering, n_instances: int, n_points: int,
                 n_windows: int, tau_leap: bool):
        spec.validate()
        self.spec = spec
        self.n_instances = n_instances
        self.n_points = max(n_points, 1)
        self.n_windows = n_windows
        self.tau_leap = tau_leap
        self.stopped = np.zeros(self.n_points, bool)
        self.stop_window = np.full(self.n_points, -1, np.int64)
        self.no_leap = np.zeros(n_instances, bool)
        self.ema_steps = np.zeros(n_instances, np.float64)
        self.ema_leap_frac = np.zeros(n_instances, np.float64)
        self.prev_steps = np.zeros(n_instances, np.int64)
        self.prev_leaps = np.zeros(n_instances, np.int64)
        self.blocks_seen = 0
        self.decisions: list[dict] = []
        self.bimodal_flags: list[dict] = []

    # ------------------------------------------------------------ state
    def state_dict(self) -> dict:
        """Flat numpy mapping for np.savez (engine.checkpoint prefixes
        the keys); the decision log rides as one JSON string."""
        return dict(
            stopped=self.stopped, stop_window=self.stop_window,
            no_leap=self.no_leap, ema_steps=self.ema_steps,
            ema_leap_frac=self.ema_leap_frac,
            prev_steps=self.prev_steps, prev_leaps=self.prev_leaps,
            blocks_seen=np.int64(self.blocks_seen),
            log=np.array(json.dumps(
                {"decisions": self.decisions,
                 "bimodal": self.bimodal_flags})))

    def load_state(self, d: dict) -> None:
        self.stopped = np.asarray(d["stopped"], bool).copy()
        self.stop_window = np.asarray(d["stop_window"], np.int64).copy()
        self.no_leap = np.asarray(d["no_leap"], bool).copy()
        self.ema_steps = np.asarray(d["ema_steps"], np.float64).copy()
        self.ema_leap_frac = np.asarray(
            d["ema_leap_frac"], np.float64).copy()
        self.prev_steps = np.asarray(d["prev_steps"], np.int64).copy()
        self.prev_leaps = np.asarray(d["prev_leaps"], np.int64).copy()
        self.blocks_seen = int(d["blocks_seen"])
        log = json.loads(str(np.asarray(d["log"])))
        self.decisions = list(log["decisions"])
        self.bimodal_flags = list(log["bimodal"])

    # ----------------------------------------------------------- decide
    def decide(self, window: int, point_stats: Optional[dict],
               sketch_hist: Optional[np.ndarray],
               group_ids: np.ndarray, steps: np.ndarray,
               leaps: np.ndarray) -> SteeringActions:
        """One decision point, AFTER the block ending at `window`
        (exclusive) was collected.

        point_stats: {"mean"|"ci90": (G, n_obs)} for the latest
        window — per-point grouped stats when available, else the
        pooled ensemble record as a single point. sketch_hist:
        (G, n_obs, n_bins) int32 latest-window histogram or None.
        group_ids: (I,) current lane->point map. steps/leaps: (I,)
        cumulative per-lane counters (exact ints, path-invariant).
        """
        spec = self.spec
        self.blocks_seen += 1
        self._update_emas(steps, leaps)
        if not spec.enabled \
                or (self.blocks_seen - 1) % spec.check_every:
            return _empty_actions(self.n_instances)

        actions = _empty_actions(self.n_instances)
        if spec.bimodality and sketch_hist is not None:
            self._flag_bimodal(window, sketch_hist)
        newly = np.zeros(self.n_points, bool)
        if spec.ci_rel_tol > 0 and point_stats is not None \
                and window >= spec.min_windows:
            newly = self._early_stop(window, point_stats)
        moves, gids = self._reallocate(window, newly, point_stats,
                                       group_ids)
        stop = newly[group_ids] & self.stopped[group_ids]
        if len(moves):
            stop[moves[:, 0]] = False  # moved lanes live on elsewhere
        new_no_leap = self._tau_switch(window)
        return SteeringActions(
            stop_lanes=stop, moves=moves, new_group_ids=gids,
            no_leap=new_no_leap)

    # ---------------------------------------------------------- helpers
    def _update_emas(self, steps: np.ndarray, leaps: np.ndarray) -> None:
        a = self.spec.ema_alpha
        ds = (np.asarray(steps, np.int64) - self.prev_steps).astype(
            np.float64)
        dl = (np.asarray(leaps, np.int64) - self.prev_leaps).astype(
            np.float64)
        frac = np.where(ds > 0, dl / np.maximum(ds, 1.0),
                        self.ema_leap_frac)
        self.ema_steps = (1 - a) * self.ema_steps + a * ds
        self.ema_leap_frac = (1 - a) * self.ema_leap_frac + a * frac
        self.prev_steps = np.asarray(steps, np.int64).copy()
        self.prev_leaps = np.asarray(leaps, np.int64).copy()

    def _rel_ci(self, point_stats: dict) -> np.ndarray:
        mean = np.asarray(point_stats["mean"], np.float64)
        ci = np.asarray(point_stats["ci90"], np.float64)
        if mean.ndim == 1:  # pooled ensemble record -> one point
            mean, ci = mean[None, :], ci[None, :]
        return ci / np.maximum(np.abs(mean), 1.0)

    def _early_stop(self, window: int, point_stats: dict) -> np.ndarray:
        rel = self._rel_ci(point_stats)
        conv = (rel < self.spec.ci_rel_tol).all(axis=1)
        g = min(len(conv), self.n_points)
        newly = np.zeros(self.n_points, bool)
        newly[:g] = conv[:g] & ~self.stopped[:g]
        if newly.any():
            self.stopped |= newly
            self.stop_window[newly] = window
            self.decisions.append({
                "window": int(window), "action": "stop",
                "points": np.flatnonzero(newly).tolist(),
                "rel_ci": [round(float(rel[p].max()), 6)
                           for p in np.flatnonzero(newly)]})
        return newly

    def _reallocate(self, window: int, newly: np.ndarray,
                    point_stats: Optional[dict], group_ids: np.ndarray):
        if not self.spec.reallocate or not newly.any() \
                or point_stats is None:
            return np.zeros((0, 2), np.int32), None
        live = ~self.stopped
        if not live.any():
            return np.zeros((0, 2), np.int32), None
        rel = self._rel_ci(point_stats).max(axis=1)
        score = np.where(live[:min(len(rel), self.n_points)],
                         rel[:self.n_points], -np.inf)
        target = int(np.argmax(score))  # first max: deterministic
        donors = np.flatnonzero(group_ids == target)
        if not len(donors):
            return np.zeros((0, 2), np.int32), None
        moves = []
        gids = group_ids.copy()
        for p in np.flatnonzero(newly):
            lanes = np.flatnonzero(group_ids == p)
            for i, lane in enumerate(lanes[1:]):  # keep lanes[0] behind
                donor = donors[i % len(donors)]
                moves.append((int(lane), int(donor)))
                gids[lane] = target
        if not moves:
            return np.zeros((0, 2), np.int32), None
        self.decisions.append({
            "window": int(window), "action": "reallocate",
            "target": target, "n_moved": len(moves)})
        return np.asarray(moves, np.int32), gids

    def _tau_switch(self, window: int) -> Optional[np.ndarray]:
        if not (self.spec.tau_switch and self.tau_leap):
            return None
        seen = self.prev_steps >= self.spec.tau_switch_min_steps
        pin = (seen & ~self.no_leap
               & (self.ema_leap_frac < self.spec.min_leap_frac))
        if not pin.any():
            return None
        self.no_leap |= pin
        self.decisions.append({
            "window": int(window), "action": "no_leap",
            "n_lanes": int(pin.sum()),
            "total_pinned": int(self.no_leap.sum())})
        return self.no_leap.copy()

    def _flag_bimodal(self, window: int, hist: np.ndarray) -> None:
        flags = bimodality_from_hist(hist)  # (G, n_obs)
        for g, o in zip(*np.nonzero(flags)):
            self.bimodal_flags.append({
                "window": int(window), "point": int(g),
                "obs": int(o)})

    # ------------------------------------------------------------ report
    def report(self) -> dict:
        """Savings + decision summary (the SimulationResult accessor
        and the bench early-stop row read this)."""
        w = self.n_windows
        active = np.where(self.stopped, self.stop_window, w)
        simulated = int(np.minimum(active, w).sum())
        total = self.n_points * w
        return {
            "n_points": self.n_points,
            "stopped_points": np.flatnonzero(self.stopped).tolist(),
            "stop_windows": {int(p): int(self.stop_window[p])
                             for p in np.flatnonzero(self.stopped)},
            "point_windows_total": total,
            "point_windows_simulated": simulated,
            "windows_saved_ratio": (total / simulated
                                    if simulated else float(total)),
            "lanes_pinned_exact": int(self.no_leap.sum()),
            "bimodal_flags": list(self.bimodal_flags),
            "decisions": list(self.decisions),
        }
