"""Adaptive steering: the between-block controller (DESIGN.md §3f).

Consumes the device-side sketches (repro/stats) at superstep
boundaries and decides — deterministically from (seed, policy) —
which sweep points to early-stop, where to reallocate their freed
replicas, which lanes to switch between exact SSA and tau-leaping,
and which distributions to flag as bimodal.
"""
from repro.steer.policy import Steering, SteeringActions, SteeringPolicy

__all__ = ["Steering", "SteeringActions", "SteeringPolicy"]
