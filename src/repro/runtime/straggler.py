"""Straggler mitigation for the simulation farm.

Two mechanisms (paper G4, adapted):
* `WindowWatchdog` — per-window wall-time monitor; a group whose
  wall time exceeds `factor` × the running median is flagged; the
  scheduler's predictive policy then re-sorts its instances into
  cost-homogeneous groups (lock-step waste shrinks).
* at multi-pod scale, a pod that misses `max_missed` window barriers is
  declared lost; its instance slice is re-queued on the survivors from
  the last checkpoint (see runtime/fault.py drill).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

import numpy as np


@dataclass
class WindowWatchdog:
    factor: float = 3.0
    history: deque = field(default_factory=lambda: deque(maxlen=64))
    flagged: list = field(default_factory=list)
    # monotone count of windows covered by observations; `history` is a
    # bounded median window (maxlen=64) and must never be the rate
    # denominator — on runs longer than 64 windows `flagged` keeps
    # growing while len(history) saturates and the rate drifts past 1.0
    observed: int = 0

    def observe(self, window: int, wall_s: float) -> bool:
        """Returns True if this window is a straggler."""
        med = float(np.median(self.history)) if self.history else wall_s
        self.history.append(wall_s)
        self.observed += 1
        if self.history and wall_s > self.factor * max(med, 1e-9):
            self.flagged.append((window, wall_s, med))
            return True
        return False

    def observe_block(self, window: int, n_windows: int,
                      wall_s: float) -> bool:
        """Observe a superstep block as ONE sample at per-window scale.

        Under pipelined block dispatch the only measurable wall is
        block-level (dispatch enqueue + blocking ring pull); slicing it
        uniformly into `n_windows` fake per-window samples would feed
        the median n_windows correlated copies and hide any
        within-block straggler entirely. Record one `wall_s/n_windows`
        sample against the block's first window, but advance `observed`
        by the real window count so `straggler_rate` keeps a per-window
        denominator.
        """
        per = wall_s / max(n_windows, 1)
        med = float(np.median(self.history)) if self.history else per
        self.history.append(per)
        self.observed += max(n_windows, 1)
        if self.history and per > self.factor * max(med, 1e-9):
            self.flagged.append((window, per, med))
            return True
        return False

    def straggler_rate(self) -> float:
        return len(self.flagged) / self.observed if self.observed else 0.0


@dataclass
class FrontierWatchdog:
    """Coordinator-level per-worker progress monitor (multi-process
    farm). Each heartbeat reports a worker's collected window frontier;
    a worker whose frontier trails the median of the currently-running
    workers by >= `grace_windows` is flagged as a frontier straggler.

    This is telemetry, not a kill switch: liveness is the heartbeat
    TIMEOUT's job (a stalled worker stops writing heartbeats and gets
    killed + restarted); the frontier watchdog catches the slow-but-
    alive case — a worker making progress at a fraction of the farm's
    pace — and surfaces it in `recovery_report()` so operators see the
    skew before it becomes the ensemble's critical path."""

    grace_windows: int = 4
    frontiers: dict = field(default_factory=dict)    # worker -> window
    flagged: list = field(default_factory=list)      # (worker, win, med)
    observed: int = 0

    def observe(self, worker: int, window: int) -> bool:
        """Record worker's frontier; True if it now lags the median."""
        prev = self.frontiers.get(worker, -1)
        self.frontiers[worker] = max(prev, int(window))
        self.observed += 1
        if len(self.frontiers) < 2:
            return False
        med = float(np.median(list(self.frontiers.values())))
        if med - self.frontiers[worker] >= self.grace_windows:
            self.flagged.append((worker, self.frontiers[worker], med))
            return True
        return False

    def forget(self, worker: int) -> None:
        """Drop a retired/finished worker from the median pool."""
        self.frontiers.pop(worker, None)

    def straggler_rate(self) -> float:
        return len(self.flagged) / self.observed if self.observed else 0.0
