"""Straggler mitigation for the simulation farm.

Two mechanisms (paper G4, adapted):
* `WindowWatchdog` — per-window wall-time monitor; a group whose
  wall time exceeds `factor` × the running median is flagged; the
  scheduler's predictive policy then re-sorts its instances into
  cost-homogeneous groups (lock-step waste shrinks).
* at multi-pod scale, a pod that misses `max_missed` window barriers is
  declared lost; its instance slice is re-queued on the survivors from
  the last checkpoint (see runtime/fault.py drill).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

import numpy as np


@dataclass
class WindowWatchdog:
    factor: float = 3.0
    history: deque = field(default_factory=lambda: deque(maxlen=64))
    flagged: list = field(default_factory=list)

    def observe(self, window: int, wall_s: float) -> bool:
        """Returns True if this window is a straggler."""
        med = float(np.median(self.history)) if self.history else wall_s
        self.history.append(wall_s)
        if self.history and wall_s > self.factor * max(med, 1e-9):
            self.flagged.append((window, wall_s, med))
            return True
        return False

    def straggler_rate(self) -> float:
        seen = len(self.history)
        return len(self.flagged) / seen if seen else 0.0
