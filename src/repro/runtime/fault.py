"""Fault tolerance runtime: typed recoverable faults + injection.

At 1000+ nodes the design assumptions are:
* node loss is routine — the window boundary (simulation) / block
  boundary (supersteps) is the re-sync point;
* per-instance counter RNG makes simulation work *relocatable*: any
  shard can re-run a lost instance bit-identically from the last
  checkpoint, so every fault below is recoverable by restore + replay;
* a fault is a VALUE, not a log line: the hierarchy here is what the
  engine raises (invariant guards), what the injector simulates, and
  what `runtime.supervisor.RunSupervisor` catches and classifies.

`FailurePlan` holds a deterministic schedule — explicit
{window: kind} entries plus an optional seeded probabilistic layer
(`random_rate`, drawn once per plan seed, NOT per run) — and
`FailureInjector` fires each scheduled fault exactly once.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np


class RecoverableError(Exception):
    """Base for faults the supervisor recovers from by restore+replay.

    `window` is the engine window the fault surfaced at (-1 unknown);
    `kind` is the FAULT_KINDS tag used for classification/telemetry."""

    kind = "crash"

    def __init__(self, message: str, window: int = -1):
        super().__init__(message)
        self.window = window


class EngineCrash(RecoverableError):
    """Simulated or detected process death: rebuild + restore."""

    kind = "crash"


class DeviceLost(RecoverableError):
    """A shard's device dropped out: rebuild on survivors (elastic
    degradation via Partitioning.degrade + reshard-on-restore)."""

    kind = "device_lost"

    def __init__(self, message: str, window: int = -1, n_lost: int = 1):
        super().__init__(message, window)
        self.n_lost = n_lost


class EngineStall(RecoverableError):
    """A window breached the straggler watchdog hard enough to
    abandon: supervised re-dispatch of the offending block."""

    kind = "stall"


class HostLost(RecoverableError):
    """A worker PROCESS died (exit, SIGKILL, missing result bundle, or
    a missed-heartbeat timeout with the process gone): the coordinator
    restarts it from its newest valid namespaced checkpoint, and past
    `max_worker_restarts` reassigns the shard to a survivor."""

    kind = "host_lost"

    def __init__(self, message: str, window: int = -1, worker: int = -1):
        super().__init__(message, window)
        self.worker = worker


class WorkerStall(RecoverableError):
    """A worker process is alive but its heartbeat went stale past the
    timeout (SIGSTOP, livelock, swap death): the coordinator kills and
    restarts it — same recovery path as HostLost, different telemetry
    tag so drills can tell dead from wedged."""

    kind = "worker_stall"

    def __init__(self, message: str, window: int = -1, worker: int = -1):
        super().__init__(message, window)
        self.worker = worker


class InvariantViolation(RecoverableError):
    """An engine invariant guard tripped (non-finite statistics,
    negative populations, ring/record disagreement): the in-memory
    state is untrusted, recover from the last durable checkpoint."""

    kind = "nan_pool"

    def __init__(self, message: str, window: int = -1, check: str = ""):
        super().__init__(message, window)
        self.check = check


# The typed fault vocabulary (injection + classification):
#   crash        kill the engine between windows; restore newest ckpt
#   device_lost  drop a shard; restore onto a degraded partitioning
#   ckpt_corrupt corrupt the newest checkpoint THEN crash — one fault
#                deterministically exercises fallback-past-corrupt
#   stall        watchdog-grade stall; re-dispatch = restore + replay
#   nan_pool     poison the lane pool; the engine's own invariant
#                guard must detect it (tests the guard, not the plan)
#   host_lost    SIGKILL a worker PROCESS (coordinator-level farms
#                only); restart from its namespaced checkpoint store
#   worker_stall SIGSTOP a worker process past the heartbeat timeout;
#                the coordinator must detect the stale heartbeat,
#                kill, and restart
FAULT_KINDS = ("crash", "device_lost", "ckpt_corrupt", "stall", "nan_pool",
               "host_lost", "worker_stall")


@dataclass
class FailurePlan:
    """Deterministic failure schedule.

    `schedule` maps window (or training step) -> fault kind. On top of
    the explicit entries, `random_rate` > 0 adds a seeded probabilistic
    layer: `materialize(n_windows)` draws per-window crash faults with
    that probability from `np.random.default_rng(seed)` — the same
    (seed, rate, n_windows) always yields the same schedule, so
    probabilistic drills replay bitwise too.
    """

    schedule: dict = field(default_factory=dict)
    seed: int = 0
    random_rate: float = 0.0
    random_kind: str = "crash"

    def __post_init__(self):
        for kind in self.schedule.values():
            if kind not in FAULT_KINDS:
                raise ValueError(
                    f"unknown fault kind {kind!r}; expected one of "
                    f"{FAULT_KINDS}")
        if not 0.0 <= self.random_rate <= 1.0:
            raise ValueError(
                f"random_rate must be in [0, 1], got {self.random_rate}")
        if self.random_kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown random_kind {self.random_kind!r}; expected "
                f"one of {FAULT_KINDS}")

    def materialize(self, n_windows: int) -> dict:
        """Concrete {window: kind} for a run of `n_windows` windows:
        explicit entries win; seeded draws fill the rest."""
        out = dict(self.schedule)
        if self.random_rate > 0.0:
            rng = np.random.default_rng(self.seed)
            hits = rng.random(n_windows) < self.random_rate
            for w in np.nonzero(hits)[0]:
                out.setdefault(int(w), self.random_kind)
        return out


class FailureInjector:
    """Fires each scheduled fault exactly once (a restarted run passes
    the same window again during replay — the fault must not refire or
    the drill would never converge)."""

    def __init__(self, plan: FailurePlan, n_windows: Optional[int] = None):
        self.plan = plan
        self.schedule = (plan.materialize(n_windows)
                         if n_windows is not None else dict(plan.schedule))
        self.events: list = []
        self._fired: set = set()

    def maybe_fail(self, step: int) -> Optional[str]:
        if step in self._fired:
            return None
        kind = self.schedule.get(step)
        if kind:
            self._fired.add(step)
            self.events.append((step, kind))
        return kind


def run_sim_with_failures(make_engine, ckpt_path: str, plan: FailurePlan,
                          ckpt_every: int = 1):
    """Drill: run a SimulationEngine, killing and restoring it per plan.

    `make_engine() -> SimulationEngine`. On a fault, the engine object
    is discarded (simulating a lost pod) and rebuilt from the last
    checkpoint. Returns the stream records of the surviving run.

    This is the minimal single-checkpoint drill used by the engine
    tests; the production loop with cadence/retention/elastic recovery
    is `runtime.supervisor.RunSupervisor`.
    """
    eng = make_engine()
    inj = FailureInjector(plan, n_windows=len(eng.grid))
    eng.checkpoint(ckpt_path)
    records = {}
    guard = 0
    while eng._window < len(eng.grid):
        w = eng._window
        if inj.maybe_fail(w):
            eng = make_engine()
            eng.restore(ckpt_path)
            continue
        rec = eng.run_window()
        records[rec.window] = rec
        if (w + 1) % ckpt_every == 0:
            eng.checkpoint(ckpt_path)
        guard += 1
        assert guard < 10 * len(eng.grid), "drill did not converge"
    ordered = [records[w] for w in range(len(eng.grid))]
    return ordered, inj.events
