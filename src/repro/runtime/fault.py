"""Fault tolerance runtime: failure injection + recovery drills.

At 1000+ nodes the design assumptions are:
* node loss is routine — the window boundary (simulation) / step
  boundary (training) is the re-sync point;
* per-instance RNG keys make simulation work *relocatable*: any shard
  can re-run a lost instance bit-identically from the last checkpoint;
* the deterministic data pipeline makes training replicas re-spawnable
  from (checkpoint step, data cursor = step).

`FailureInjector` drives drills on the in-process engines; the tests
assert bit-identical results with and without injected failures.
"""
from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Optional

import numpy as np


@dataclass
class FailurePlan:
    """Deterministic failure schedule: {window_or_step: kind}."""

    schedule: dict
    seed: int = 0


class FailureInjector:
    def __init__(self, plan: FailurePlan):
        self.plan = plan
        self.events: list = []

    def maybe_fail(self, step: int) -> Optional[str]:
        kind = self.plan.schedule.get(step)
        if kind:
            self.events.append((step, kind))
        return kind


def run_sim_with_failures(make_engine, ckpt_path: str, plan: FailurePlan,
                          ckpt_every: int = 1):
    """Drill: run a SimulationEngine, killing and restoring it per plan.

    `make_engine() -> SimulationEngine`. On 'crash', the engine object is
    discarded (simulating a lost pod) and rebuilt from the last
    checkpoint. Returns the stream records of the surviving run.
    """
    inj = FailureInjector(plan)
    eng = make_engine()
    eng.checkpoint(ckpt_path)
    records = {}
    crashed: set = set()
    guard = 0
    while eng._window < len(eng.grid):
        w = eng._window
        if w in plan.schedule and w not in crashed:
            crashed.add(w)
            inj.maybe_fail(w)
            eng = make_engine()
            eng.restore(ckpt_path)
            continue
        rec = eng.run_window()
        records[rec.window] = rec
        if (w + 1) % ckpt_every == 0:
            eng.checkpoint(ckpt_path)
        guard += 1
        assert guard < 10 * len(eng.grid), "drill did not converge"
    ordered = [records[w] for w in range(len(eng.grid))]
    return ordered, inj.events


def run_train_with_failures(make_state, train_step, batches, ckpt_dir: str,
                            plan: FailurePlan, save_fn, restore_fn,
                            ckpt_every: int = 2):
    """Drill: training loop with crash/restore at step granularity.

    Determinism contract: restored run must produce the same losses as
    an uninterrupted run (asserted in tests).
    """
    inj = FailureInjector(plan)
    state = make_state()
    save_fn(state, 0)
    losses = {}
    crashed: set = set()
    step = 0
    while step < len(batches):
        if step in plan.schedule and step not in crashed:
            crashed.add(step)
            inj.maybe_fail(step)
            state, step = restore_fn()
            continue
        state, metrics = train_step(state, batches[step])
        losses[step] = float(np.asarray(metrics["loss"]))
        step += 1
        if step % ckpt_every == 0:
            save_fn(state, step)
    return state, [losses[i] for i in range(len(batches))], inj.events
