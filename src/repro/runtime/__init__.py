"""Runtime: typed faults + injection, straggler watchdog, and the
supervised recovery loop (RunSupervisor)."""
from repro.runtime.fault import (  # noqa: F401
    FAULT_KINDS,
    DeviceLost,
    EngineCrash,
    EngineStall,
    FailureInjector,
    FailurePlan,
    InvariantViolation,
    RecoverableError,
)
