"""Runtime: failure injection/recovery, straggler mitigation."""
