"""FarmCoordinator — a coordinator process that outlives its workers.

The multi-process elastic ensemble farm (DESIGN.md §3i): the
coordinator partitions an Experiment's ensemble (sweep points x
replicas) into `Recovery.workers` contiguous shards, launches each
shard as a separate WORKER PROCESS (`runtime.worker`, itself the
existing RunSupervisor loop with cadenced checkpoints under a
per-worker namespace inside the shared ckpt_dir), and supervises the
fleet through a heartbeat-file protocol:

* each worker writes an atomic JSON heartbeat (window frontier,
  checkpoint frontier, straggler rate) every ``heartbeat_s / 2``;
* a heartbeat stale for ``3 x heartbeat_s`` is a typed `WorkerStall`
  (the worker is SIGKILLed — which also unwedges a SIGSTOPped
  process — and restarted);
* a dead process, or a live exit without a verifying result bundle,
  is a typed `HostLost`;
* every restart waits a bounded exponential backoff
  (``backoff_base_s * 2^(restarts-1)``, capped at ``backoff_max_s``)
  and resumes from the newest VALID checkpoint in the worker's own
  namespace — corrupt files are skipped by the worker's
  RunSupervisor, exactly as in the single-process story;
* a worker that dies more than ``max_worker_restarts`` times is
  RETIRED (elastic host-loss degradation): its shard goes back on the
  queue and the first survivor that finishes its own shard picks it
  up — same namespace, so the reassigned run resumes from the retired
  worker's checkpoints;
* a coordinator-level `FrontierWatchdog` flags workers whose window
  frontier falls behind the fleet median (telemetry — liveness is the
  heartbeat timeout's job).

WHY THE MERGE IS BITWISE (the contract every drill asserts): worker
lanes take their RNG key rows from the GLOBAL key table (counter-based
threefry streams are position-independent), so each lane simulates the
identical trajectory it would in one process; the statistics partition
is pinned (each worker owns whole stat blocks of the global Welford
block partition), and workers export per-window Welford PARTIAL
stacks; the coordinator concatenates those stacks in global block
order and re-runs the same associative `merge_blocks` + `finalize`
fold the single-process engine uses. Grouped per-point stats, sketch
histograms (pure counts), trajectories, and steering decisions merge
by concatenation / integer addition. The final `SimulationResult` is
therefore bitwise identical to `Partitioning(n_shards=1,
stat_blocks=B)` run in a single process — no matter how many workers
died, stalled, or were reassigned on the way.

Fault injection (`Recovery.inject`) is PROCESS-level here: `host_lost`
/ `crash` SIGKILL a worker, `worker_stall` / `stall` SIGSTOP it past
the heartbeat timeout, `ckpt_corrupt` truncates the newest checkpoint
in the target's namespace and then kills it. Each scheduled fault
fires once, on the first worker whose heartbeat frontier crosses the
scheduled window. The coordinator itself has no checkpoint: its only
state is the shard queue, which is a pure function of the Experiment —
a crashed coordinator is rerun from scratch and workers' completed
result bundles / checkpoints make the rerun cheap.
"""
from __future__ import annotations

import collections
import dataclasses
import json
import os
import signal
import subprocess
import sys
import time
from pathlib import Path
from types import SimpleNamespace
from typing import Optional

import numpy as np

try:  # models may close over lambdas (observables, init_fn) — plain
    import cloudpickle as _pickle  # pickle rejects those; cloudpickle
except ImportError:  # output still loads with stdlib pickle.load
    import pickle as _pickle

from repro.ckpt import store as ckpt_store
from repro.core import reduction
from repro.core.stream import StatsRecord, StatsStream
from repro.runtime.fault import FailureInjector
from repro.runtime.straggler import FrontierWatchdog
from repro.runtime.supervisor import Recovery
from repro.stats.sketch import WindowSketch

# process-level fault kinds the coordinator can inject (see module
# docstring); engine-internal kinds (device_lost, nan_pool) belong to
# the worker's own Recovery.inject and are rejected here
_INJECTABLE = {
    "host_lost": "kill", "crash": "kill",
    "worker_stall": "stop", "stall": "stop",
    "ckpt_corrupt": "corrupt",
}


class _Shard:
    """One contiguous slice of the global ensemble and its on-disk
    protocol endpoints (spec / heartbeat / result bundle paths)."""

    def __init__(self, index: int, lo: int, hi: int, blocks: int,
                 ckpt_dir: str):
        self.index = index
        self.lo, self.hi, self.blocks = lo, hi, blocks
        self.namespace = f"shard{index:02d}"
        self.spec_path = os.path.join(
            ckpt_dir, f"{self.namespace}__spec.pkl")
        self.hb_path = os.path.join(
            ckpt_dir, f"hb_{self.namespace}.json")
        # contains no "ckpt_", so the checkpoint store's namespaced
        # listing can never mistake a result bundle for a checkpoint
        self.result_path = os.path.join(
            ckpt_dir, f"{self.namespace}__result.npz")
        self.owner = index  # original slot; differs after reassignment
        self.bundle: Optional[dict] = None


class _Slot:
    """One worker slot ("host"): the unit the restart budget and
    retirement apply to. Slot i initially runs shard i."""

    def __init__(self, index: int):
        self.index = index
        self.proc: Optional[subprocess.Popen] = None
        self.shard: Optional[_Shard] = None
        self.restarts = 0
        self.retired = False
        self.next_start = 0.0
        self.launch_t = 0.0
        self.shards_run: list[int] = []


class FarmCoordinator:
    """Drives one Experiment across `recovery.workers` worker
    processes and merges their results bitwise. `run()` returns the
    same SimulationResult handle simulate() does, with
    `recovery_report()` carrying the farm's event log."""

    def __init__(self, experiment, recovery: Recovery):
        recovery.validate()
        experiment.validate()
        self.experiment = experiment
        self.recovery = recovery
        ens = experiment.ensemble
        k = recovery.workers
        n_inst = ens.n_instances
        blocks = (experiment.partitioning.blocks
                  if experiment.partitioning is not None else k)
        per = n_inst // k
        self.n_windows = experiment.schedule.n_windows
        self.shards = [
            _Shard(i, i * per, (i + 1) * per, blocks // k,
                   recovery.ckpt_dir)
            for i in range(k)]
        self._events: list[dict] = []
        self._faults: dict = {}
        self._total_restarts = 0
        self._reassignments = 0
        self.watchdog = FrontierWatchdog()
        self._injector = None
        if recovery.inject is not None:
            self._injector = FailureInjector(recovery.inject,
                                             n_windows=self.n_windows)
            bad = [kind for kind in self._injector.schedule.values()
                   if kind not in _INJECTABLE]
            if bad:
                raise ValueError(
                    f"fault kind(s) {sorted(set(bad))} cannot be "
                    "injected at the farm coordinator (process) level;"
                    f" coordinator kinds are {sorted(_INJECTABLE)} — "
                    "engine-internal kinds run under a workers=1 "
                    "Recovery")

    # ------------------------------------------------------------- api
    def run(self):
        from repro.api.result import SimulationResult  # lazy: no cycle

        rec = self.recovery
        t0 = time.perf_counter()
        os.makedirs(rec.ckpt_dir, exist_ok=True)
        for sh in self.shards:
            self._write_spec(sh)
        slots = [_Slot(i) for i in range(rec.workers)]
        for slot, sh in zip(slots, self.shards):
            slot.shard = sh
        pending: collections.deque = collections.deque()
        done: set = set()
        poll = min(0.2, rec.heartbeat_s / 4.0)
        try:
            while len(done) < len(self.shards):
                now = time.time()
                for slot in slots:
                    if slot.retired or slot.proc is not None:
                        continue
                    if slot.shard is None:
                        if not pending:
                            continue
                        sh = pending.popleft()
                        slot.shard = sh
                        self._reassignments += 1
                        self._log("shard_reassigned", shard=sh.index,
                                  from_worker=sh.owner,
                                  to_worker=slot.index)
                        sh.owner = slot.index
                    if now >= slot.next_start:
                        self._launch(slot)
                if pending and all(s.retired or s.proc is None
                                   and s.shard is None for s in slots):
                    raise RuntimeError(
                        "farm dead: every worker slot is retired "
                        f"({self._total_restarts} restarts) with "
                        f"{len(pending)} shard(s) unfinished; raise "
                        "Recovery.max_worker_restarts or fix the "
                        "underlying fault")
                time.sleep(poll)
                for slot in slots:
                    if slot.proc is None:
                        continue
                    self._poll_slot(slot, pending, done)
        finally:
            for slot in slots:
                if slot.proc is not None:
                    self._kill(slot.proc)
                    slot.proc = None
        wall = time.perf_counter() - t0
        view = self._merge()
        for sink in self.experiment.sinks:
            view.stream.attach(sink)
            for r in view.stream.records():
                sink(r)
        view.stream.close()
        result = SimulationResult(self.experiment, view)
        result._wall_time = wall
        result._restarts = self._total_restarts
        result._stall_redispatches = sum(
            sh.bundle["_meta"]["report"].get("stall_redispatches", 0)
            for sh in self.shards)
        result._recovery = self._report(slots)
        return result

    # --------------------------------------------------- process layer
    def _write_spec(self, sh: _Shard) -> None:
        worker_rec = dataclasses.replace(
            self.recovery, workers=1, namespace=sh.namespace,
            inject=None)
        spec = {
            "experiment": self.experiment.with_(sinks=(), recovery=None),
            "recovery": worker_rec,
            "shard": (sh.lo, sh.hi, sh.blocks),
            "shard_index": sh.index,
            "heartbeat_path": sh.hb_path,
            "result_path": sh.result_path,
        }
        with open(sh.spec_path, "wb") as f:
            _pickle.dump(spec, f)

    def _launch(self, slot: _Slot) -> None:
        import repro

        sh = slot.shard
        try:
            os.remove(sh.hb_path)  # a stale file must not look alive
        except FileNotFoundError:
            pass
        env = dict(os.environ)
        src_root = str(Path(repro.__file__).resolve().parents[1])
        env["PYTHONPATH"] = src_root + (
            os.pathsep + env["PYTHONPATH"]
            if env.get("PYTHONPATH") else "")
        log_path = os.path.join(self.recovery.ckpt_dir,
                                f"{sh.namespace}.log")
        with open(log_path, "ab") as logf:
            slot.proc = subprocess.Popen(
                [sys.executable, "-m", "repro.runtime.worker",
                 sh.spec_path],
                stdout=logf, stderr=subprocess.STDOUT, env=env)
        slot.launch_t = time.time()
        if sh.index not in slot.shards_run:
            slot.shards_run.append(sh.index)
        self._log("worker_launched", worker=slot.index, shard=sh.index,
                  pid=slot.proc.pid, attempt=slot.restarts)

    def _poll_slot(self, slot: _Slot, pending, done: set) -> None:
        rec = self.recovery
        sh = slot.shard
        hb = self._read_heartbeat(sh)
        if hb is not None:
            if self.watchdog.observe(sh.index, int(hb.get("window", 0))):
                self._log("frontier_straggler", worker=slot.index,
                          shard=sh.index, window=int(hb["window"]))
            self._maybe_inject(slot, int(hb.get("window", 0)))
        rc = slot.proc.poll()
        if rc is not None:
            slot.proc = None
            bundle = self._load_bundle(sh) if rc == 0 else None
            if bundle is not None:
                sh.bundle = bundle
                done.add(sh.index)
                slot.shard = None
                self.watchdog.forget(sh.index)
                self._log("worker_done", worker=slot.index,
                          shard=sh.index)
            else:
                why = (f"exit code {rc}" if rc != 0 else
                       "exit 0 without a verifying result bundle")
                self._fault(slot, pending, "host_lost",
                            f"worker process died ({why})",
                            window=-1 if hb is None
                            else int(hb.get("window", -1)))
            return
        now = time.time()
        grace = max(60.0, 10.0 * rec.heartbeat_s)
        if hb is not None:
            try:
                stale = now - os.path.getmtime(sh.hb_path)
            except OSError:
                return
            # during "init" (engine build + restore + jit compile) XLA
            # can hold the GIL long enough to starve the heartbeat
            # thread — judge init-phase workers by the launch grace,
            # running workers by the 3 x heartbeat_s contract
            limit = (grace if hb.get("phase") == "init"
                     else 3.0 * rec.heartbeat_s)
            if stale > limit and now - slot.launch_t > limit:
                self._kill(slot.proc)
                slot.proc = None
                self._fault(slot, pending, "worker_stall",
                            f"heartbeat stale for {stale:.1f}s "
                            f"(limit {limit:.1f}s, heartbeat_s="
                            f"{rec.heartbeat_s})",
                            window=int(hb.get("window", -1)))
        elif now - slot.launch_t > grace:
            # never wrote a first heartbeat: hung before liveness
            self._kill(slot.proc)
            slot.proc = None
            self._fault(slot, pending, "worker_stall",
                        "no heartbeat after launch grace", window=-1)

    def _fault(self, slot: _Slot, pending, kind: str, msg: str,
               window: int) -> None:
        rec = self.recovery
        sh = slot.shard
        self._faults[kind] = self._faults.get(kind, 0) + 1
        self._log("fault", kind=kind, worker=slot.index,
                  shard=sh.index, window=window, error=msg)
        try:
            os.remove(sh.hb_path)
        except FileNotFoundError:
            pass
        slot.restarts += 1
        self._total_restarts += 1
        if slot.restarts > rec.max_worker_restarts:
            slot.retired = True
            slot.shard = None
            pending.append(sh)
            self._log("worker_retired", worker=slot.index,
                      shard=sh.index, restarts=slot.restarts)
        else:
            backoff = (min(rec.backoff_max_s,
                           rec.backoff_base_s * 2 ** (slot.restarts - 1))
                       if rec.backoff_base_s > 0 else 0.0)
            slot.next_start = time.time() + backoff
            self._log("restart_scheduled", worker=slot.index,
                      shard=sh.index, backoff_s=backoff,
                      attempt=slot.restarts)

    @staticmethod
    def _kill(proc: subprocess.Popen) -> None:
        for sig in (signal.SIGCONT, signal.SIGKILL):
            try:
                proc.send_signal(sig)  # CONT first: a SIGSTOPped
            except (ProcessLookupError, OSError):  # child must still
                pass                               # die on KILL
        try:
            proc.wait(timeout=30)
        except subprocess.TimeoutExpired:
            pass

    def _read_heartbeat(self, sh: _Shard) -> Optional[dict]:
        try:
            with open(sh.hb_path) as f:
                return json.loads(f.read())
        except (FileNotFoundError, json.JSONDecodeError, OSError):
            return None

    def _load_bundle(self, sh: _Shard) -> Optional[dict]:
        try:
            arrays = ckpt_store.verify(
                sh.result_path,
                required=("window", "grid", "final_x", "meta"))
        except (ckpt_store.CheckpointCorrupt, FileNotFoundError):
            return None
        if int(arrays["window"]) != self.n_windows:
            return None
        arrays["_meta"] = json.loads(str(arrays.pop("meta")))
        return arrays

    # -------------------------------------------------- fault injection
    def _maybe_inject(self, slot: _Slot, frontier: int) -> None:
        if self._injector is None or slot.proc is None:
            return
        for w in sorted(self._injector.schedule):
            if w > frontier:
                break
            kind = self._injector.maybe_fail(w)
            if kind is None:
                continue
            self._log("fault_injected", kind=kind, window=w,
                      worker=slot.index, shard=slot.shard.index)
            mode = _INJECTABLE[kind]
            if mode in ("kill", "corrupt"):
                # corrupt mode kills FIRST: truncating while the worker
                # is alive races a concurrent cadence save (which could
                # replace the corrupt file with a fresh checkpoint
                # before the restart reads it)
                self._kill(slot.proc)
                if mode == "corrupt":
                    self._corrupt_newest(slot.shard)
            elif mode == "stop":
                try:
                    slot.proc.send_signal(signal.SIGSTOP)
                except (ProcessLookupError, OSError):
                    pass
            return  # at most one injection per poll

    def _corrupt_newest(self, sh: _Shard) -> None:
        ckpts = ckpt_store.list_checkpoints(self.recovery.ckpt_dir,
                                            sh.namespace)
        if not ckpts:
            return
        _, path = ckpts[-1]
        size = os.path.getsize(path)
        with open(path, "r+b") as f:
            f.truncate(max(1, size // 2))

    # ----------------------------------------------------------- merge
    def _merge(self):
        bundles = [sh.bundle for sh in self.shards]  # global order
        metas = [b["_meta"] for b in bundles]
        grid = np.asarray(bundles[0]["grid"])
        w_total = self.n_windows
        stream = StatsStream()
        if all("bp_n" in b for b in bundles):
            import jax.numpy as jnp

            # concatenate worker partial stacks in global block order
            # and re-run the exact single-process merge_blocks +
            # finalize fold per window — this is the bitwise step
            bp_n = np.concatenate([b["bp_n"] for b in bundles], axis=1)
            bp_mean = np.concatenate(
                [b["bp_mean"] for b in bundles], axis=1)
            bp_m2 = np.concatenate([b["bp_m2"] for b in bundles], axis=1)
            for w in range(w_total):
                st = reduction.finalize(reduction.merge_blocks(
                    reduction.Welford(n=jnp.asarray(bp_n[w]),
                                      mean=jnp.asarray(bp_mean[w]),
                                      m2=jnp.asarray(bp_m2[w]))))
                n = np.asarray(st.n)
                stream.emit(StatsRecord(
                    t=float(grid[w]), window=w,
                    mean=np.asarray(st.mean), var=np.asarray(st.var),
                    ci90=np.asarray(st.ci90), n=float(n.max())))
        grouped: list = []
        if all("gp_n" in b for b in bundles):
            import jax.numpy as jnp

            # the reference grouped fold merges per-(block, group)
            # masked partials — including the ZERO partials of groups
            # with no members in a block — so worker-local finalized
            # rows are not bit-identical to it; instead embed each
            # worker's (V_k, G_k) partial stack into the global (V, G)
            # layout (zeros elsewhere, exactly what the masked update
            # produces for memberless groups) and rerun the same fold
            v_tot = sum(b["gp_n"].shape[1] for b in bundles)
            g_tot = sum(b["gp_n"].shape[2] for b in bundles)
            tail = bundles[0]["gp_n"].shape[3:]
            for w in range(w_total):
                leaves = []
                for name in ("n", "mean", "m2"):
                    full = np.zeros((v_tot, g_tot) + tail,
                                    bundles[0][f"gp_{name}"].dtype)
                    v0 = g0 = 0
                    for b in bundles:
                        part = b[f"gp_{name}"][w]
                        vk, gk = part.shape[:2]
                        full[v0:v0 + vk, g0:g0 + gk] = part
                        v0 += vk
                        g0 += gk
                    leaves.append(jnp.asarray(full))
                st = reduction.finalize(reduction.merge_blocks(
                    reduction.Welford(*leaves)))
                grouped.append(reduction.Stats(
                    *(np.asarray(v) for v in st)))
        sketches: list = []
        if all("sketch_hist" in b for b in bundles):
            pooled = not grouped  # G == 1 everywhere: counts add
            for w in range(w_total):
                hists = [b["sketch_hist"][w] for b in bundles]
                rares = ([b["sketch_rare"][w] for b in bundles]
                         if all("sketch_rare" in b for b in bundles)
                         else None)
                if pooled:
                    hist = np.sum(hists, axis=0, dtype=np.int32)
                    rare = (np.sum(rares, axis=0, dtype=np.int32)
                            if rares is not None else None)
                else:
                    hist = np.concatenate(hists, axis=0)
                    rare = (np.concatenate(rares, axis=0)
                            if rares is not None else None)
                sketches.append(WindowSketch(hist=hist, rare=rare))
        samples = (np.concatenate([b["samples"] for b in bundles], axis=0)
                   if all("samples" in b for b in bundles) else None)
        sketch_params = (SimpleNamespace(
            lo=np.asarray(bundles[0]["sketch_lo"]),
            width=np.asarray(bundles[0]["sketch_width"]))
            if "sketch_lo" in bundles[0] else None)
        final_x = np.concatenate([b["final_x"] for b in bundles], axis=0)
        return _FarmEngineView(
            experiment=self.experiment, grid=grid, stream=stream,
            grouped=grouped, sketches=sketches, samples=samples,
            final_x=final_x, metas=metas, sketch_params=sketch_params,
            steering=_merge_steering(
                metas, self.experiment.ensemble.replicas, w_total),
            watchdog_flagged=list(self.watchdog.flagged))

    # ---------------------------------------------------------- report
    def _report(self, slots) -> dict:
        return {
            "workers": self.recovery.workers,
            "restarts": self._total_restarts,
            "faults_by_kind": dict(self._faults),
            "reassignments": self._reassignments,
            "pipeline_depth_effective": max(
                m["telemetry"]["pipeline_depth_effective"]
                for m in (sh.bundle["_meta"] for sh in self.shards)),
            "frontier_stragglers": list(self.watchdog.flagged),
            "per_worker": {
                s.index: {"restarts": s.restarts, "retired": s.retired,
                          "shards_run": list(s.shards_run)}
                for s in slots},
            "worker_reports": {
                sh.index: sh.bundle["_meta"]["report"]
                for sh in self.shards},
            # engine-only run wall per shard (final successful attempt)
            # — process lifetime minus this is the worker's startup
            # cost (interpreter + jax import + jit), the part a farm
            # duplicates per process but overlaps on real multicore
            "worker_walls": {
                sh.index: sh.bundle["_meta"]["telemetry"]["wall_time_s"]
                for sh in self.shards},
            "events": list(self._events),
        }

    def _log(self, event: str, **detail) -> None:
        self._events.append({"event": event,
                             "t": round(time.time(), 3), **detail})


# -------------------------------------------------------------- merge
def _merge_steering(metas: list, replicas: int,
                    n_windows: int) -> Optional[dict]:
    """Merge worker-local steering reports into the global report the
    single-process run would have produced.

    Each worker steers its own whole sweep points, so its decisions ARE
    the global decisions restricted to its point range: stop entries
    concatenate (point indices offset by the worker's base point, in
    ascending shard order — matching the single flatnonzero scan),
    no_leap entries sum lane counts per window with `total_pinned`
    rebuilt from every worker's last-seen cumulative count."""
    reps = [m.get("steering") for m in metas]
    if all(r is None for r in reps):
        return None
    p0s = [m["lo"] // replicas for m in metas]
    stop: dict = {}
    noleap: dict = {}
    bimodal: list = []
    for wk, (rep, p0) in enumerate(zip(reps, p0s)):
        if rep is None:
            continue
        for d in rep["decisions"]:
            if d["action"] == "stop":
                stop.setdefault(d["window"], []).append(
                    ([p + p0 for p in d["points"]], d["rel_ci"]))
            elif d["action"] == "no_leap":
                noleap.setdefault(d["window"], []).append((wk, d))
        for f in rep.get("bimodal_flags", []):
            bimodal.append({"window": f["window"],
                            "point": f["point"] + p0, "obs": f["obs"]})
    decisions: list = []
    totals = [0] * len(metas)
    for w in sorted(set(stop) | set(noleap)):
        if w in stop:  # decide() logs stops before no_leap pins
            pts: list = []
            ci: list = []
            for p_list, ci_list in stop[w]:
                pts += p_list
                ci += ci_list
            decisions.append({"window": w, "action": "stop",
                              "points": pts, "rel_ci": ci})
        if w in noleap:
            n_new = 0
            for wk, d in noleap[w]:
                totals[wk] = d["total_pinned"]
                n_new += d["n_lanes"]
            decisions.append({"window": w, "action": "no_leap",
                              "n_lanes": n_new,
                              "total_pinned": sum(totals)})
    bimodal.sort(key=lambda f: (f["window"], f["point"], f["obs"]))
    stop_windows: dict = {}
    stopped: list = []
    total = simulated = 0
    pinned = 0
    for rep, p0 in zip(reps, p0s):
        if rep is None:
            continue
        total += rep["point_windows_total"]
        simulated += rep["point_windows_simulated"]
        pinned += rep["lanes_pinned_exact"]
        stopped += [p + p0 for p in rep["stopped_points"]]
        for p, w in rep["stop_windows"].items():
            stop_windows[int(p) + p0] = int(w)
    stopped.sort()
    return {
        "n_points": sum(r["n_points"] for r in reps if r is not None),
        "stopped_points": stopped,
        "stop_windows": {p: stop_windows[p] for p in sorted(stop_windows)},
        "point_windows_total": total,
        "point_windows_simulated": simulated,
        "windows_saved_ratio": (total / simulated if simulated
                                else float(total)),
        "lanes_pinned_exact": pinned,
        "bimodal_flags": bimodal,
        "decisions": decisions,
    }


def _pad_last(seq: list, n: int) -> list:
    """Last n entries, left-padded with zeros — restarted workers keep
    only post-restore telemetry, so series can be short."""
    tail = list(seq)[-n:]
    return [0.0] * (n - len(tail)) + tail


class _FarmEngineView:
    """A merged, finished pseudo-engine: exactly the attribute surface
    SimulationResult reads, fed from the workers' merged bundles. It
    cannot run further windows — `resume()` on the handle is a no-op
    (the run is complete) and `checkpoint()` is rejected."""

    def __init__(self, experiment, grid, stream, grouped, sketches,
                 samples, final_x, metas, sketch_params, steering,
                 watchdog_flagged):
        self.grid = grid
        self._sketch = sketch_params
        self.stream = stream
        self.obs_names = list(metas[0]["obs_names"])
        self.cfg = SimpleNamespace(window_block=experiment.window_block)
        self._steer = None
        self._window = len(grid)
        self._pool = SimpleNamespace(x=final_x)
        self._grouped = grouped
        self._sketches = sketches
        self._samples = samples
        self._steering = steering
        tels = [m["telemetry"] for m in metas]
        w = len(grid)
        self.wall_times = [
            max(col) for col in zip(*(
                _pad_last(t["window_wall_times"], w) for t in tels))]
        self.peak_buffered_bytes = max(
            t["peak_buffered_bytes"] for t in tels)
        self.n_dispatches = sum(t["dispatches"] for t in tels)
        self.n_host_syncs = sum(t["host_syncs"] for t in tels)
        # per-window step/leap counts only merge when every worker has
        # a full-length series (no mid-run restarts trimmed it)
        if all(len(t["steps_per_window"]) == w for t in tels):
            self.window_steps = [
                sum(col) & 0xFFFFFFFF
                for col in zip(*(t["steps_per_window"] for t in tels))]
            self.window_leaps = [
                sum(col) & 0xFFFFFFFF
                for col in zip(*(t["leaps_per_window"] for t in tels))]
        else:
            self.window_steps = []
            self.window_leaps = []
        observed = sum(t["watchdog_observed"] for t in tels)
        flagged = sorted(
            (tuple(f) for t in tels for f in t["straggler_windows"]),
            key=lambda f: f[0])
        self.watchdog = SimpleNamespace(
            flagged=flagged + [("frontier",) + tuple(f)
                               for f in watchdog_flagged],
            straggler_rate=lambda: (len(flagged) / observed
                                    if observed else 0.0))
        self.block_walls = [tuple(bw) for t in tels
                            for bw in t["block_walls"]]
        self.block_walls.sort(key=lambda b: b[0])
        self.pipeline_depth = max(t["pipeline_depth"] for t in tels)
        self.pipeline_depth_effective = max(
            t["pipeline_depth_effective"] for t in tels)
        self.peak_inflight_blocks = max(
            t["peak_inflight_blocks"] for t in tels)
        self.n_snapshot_saves = sum(t["snapshot_saves"] for t in tels)
        self.n_ckpt_flushes = sum(t["ckpt_flushes"] for t in tels)

    # ----------------------------------------------- result interface
    def flush(self) -> None:
        pass  # nothing in flight: the farm merged finished bundles

    def trajectories(self):
        return self._samples

    def grouped_stats(self):
        return list(self._grouped)

    def sketches(self):
        return list(self._sketches)

    def steering_report(self):
        return self._steering

    def checkpoint(self, path: str) -> None:
        raise RuntimeError(
            "a farm result is already complete and has no live pool to "
            "checkpoint; per-worker checkpoints live under "
            "Recovery.ckpt_dir namespaces")
