"""RunSupervisor — the self-healing ensemble-farm lifecycle.

The engine (core/engine.py) turns faults into typed RecoverableErrors
and checkpoints into atomic, checksummed, mesh-shape-agnostic
snapshots; this module is the loop that turns those two properties
into "a campaign survives anything short of losing every device":

* cadenced checkpoints — saved on window/block boundaries under a
  keep-last-K `RetentionPolicy` (ckpt.store), named by window;
* crash detection + bounded-backoff restart — any RecoverableError
  tears the engine down, sleeps an exponential backoff, rebuilds, and
  restores the newest checkpoint that VERIFIES, falling back past
  corrupt/truncated files (and to a fresh window-0 start if none
  survive);
* elastic shard-loss degradation — a DeviceLost fault shrinks the
  Partitioning via `degrade()` (stat_blocks pinned, so records stay
  bitwise) and the rebuild lands on the surviving shards through the
  reshard-on-restore path;
* straggler escalation — WindowWatchdog breaches stop being
  telemetry-only: with `redispatch_stragglers` the supervisor raises
  an EngineStall and re-dispatches the offending block from the last
  checkpoint (bounded to one retry per window — replay is bitwise, so
  the retry costs wall time, never correctness);
* deterministic fault injection — a FailurePlan (explicit schedule +
  seeded probabilistic layer) drives drills through the SAME recovery
  machinery production faults use.

The recovery contract (DESIGN.md §3h): because trajectories are a pure
function of (seed, counter-RNG state) and checkpoints carry the full
pool + RNG counters + emitted records + steering state, a run
suffering ANY injected fault sequence produces records, sketches, and
steering decisions bitwise identical to the uninterrupted run. Sinks
are attached only after the run succeeds (records replay into them
once), so restarts never double-write.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.ckpt import store as ckpt_store
from repro.runtime.fault import (
    DeviceLost,
    EngineCrash,
    EngineStall,
    FailureInjector,
    FailurePlan,
    RecoverableError,
)


@dataclass(frozen=True)
class Recovery:
    """Supervised-recovery spec (Experiment(recovery=Recovery(...))).

    ckpt_dir: directory for cadenced checkpoints (created on run).
    cadence: checkpoint every N windows. Rounded up to a multiple of
    the experiment's window_block so every save lands on a superstep
    boundary (restore rejects mid-block snapshots).
    keep_last: RetentionPolicy depth; >= 2 keeps a fallback candidate
    behind the newest file, which is what makes recovery survive a
    corrupt newest checkpoint.
    max_restarts: recoveries allowed before the run is declared dead
    (a RuntimeError carrying the last fault).
    backoff_base_s/backoff_max_s: bounded exponential restart backoff
    (base * 2^(restart-1), capped).
    elastic: on DeviceLost, degrade the Partitioning to the surviving
    shards (stat_blocks pinned — records stay bitwise) instead of
    retrying at full width.
    redispatch_stragglers: escalate WindowWatchdog breaches into a
    supervised re-dispatch of the offending block (one retry per
    window).
    inject: deterministic fault drill plan (runtime.fault.FailurePlan);
    None in production.

    MULTI-PROCESS FARM (workers > 1, DESIGN.md §3i): the run is driven
    by a coordinator PROCESS (runtime.coordinator.FarmCoordinator)
    that shards the ensemble across `workers` worker processes, each
    running its own RunSupervisor over its shard with namespaced
    checkpoints in the shared ckpt_dir.
    workers: worker process count (1 = in-process supervisor, the
    single-process path above).
    heartbeat_s: worker heartbeat write interval; a worker whose
    heartbeat goes stale for 3 x heartbeat_s is declared stalled
    (killed + restarted), a dead process is HostLost.
    max_worker_restarts: per-worker restart budget; past it the worker
    is retired and its shard is reassigned to a survivor (elastic
    host-loss degradation). Restarting from the shard's own namespaced
    checkpoints keeps records bitwise.
    namespace: this supervisor's checkpoint namespace inside ckpt_dir
    ("" = un-namespaced). Set by the farm worker runner; coexisting
    namespaces never list/prune/restore each other's files.
    """

    ckpt_dir: str = "recovery"
    cadence: int = 1
    keep_last: int = 3
    max_restarts: int = 8
    backoff_base_s: float = 0.0
    backoff_max_s: float = 30.0
    elastic: bool = True
    redispatch_stragglers: bool = False
    inject: Optional[FailurePlan] = None
    workers: int = 1
    heartbeat_s: float = 2.0
    max_worker_restarts: int = 2
    namespace: str = ""

    def validate(self) -> None:
        if not self.ckpt_dir:
            raise ValueError("Recovery.ckpt_dir must be a directory path")
        if self.cadence < 1:
            raise ValueError(
                f"Recovery.cadence must be >= 1, got {self.cadence}")
        if self.keep_last < 1:
            raise ValueError(
                f"Recovery.keep_last must be >= 1, got {self.keep_last}")
        if self.max_restarts < 0:
            raise ValueError(
                f"Recovery.max_restarts must be >= 0, got "
                f"{self.max_restarts}")
        if self.backoff_base_s < 0 or self.backoff_max_s < 0:
            raise ValueError("Recovery backoff times must be >= 0")
        if self.inject is not None \
                and not isinstance(self.inject, FailurePlan):
            raise ValueError(
                "Recovery.inject must be a runtime.fault.FailurePlan, "
                f"got {type(self.inject).__name__}")
        if self.workers < 1:
            raise ValueError(
                f"Recovery.workers must be >= 1, got {self.workers}")
        if self.heartbeat_s <= 0:
            raise ValueError(
                f"Recovery.heartbeat_s must be > 0, got "
                f"{self.heartbeat_s}")
        if self.max_worker_restarts < 0:
            raise ValueError(
                f"Recovery.max_worker_restarts must be >= 0, got "
                f"{self.max_worker_restarts}")
        ckpt_store.checkpoint_name(0, self.namespace)  # charset check


class RunSupervisor:
    """Owns one Experiment's engine lifecycle end to end (see module
    docstring). `run()` returns the same SimulationResult handle
    simulate() does, with `recovery_report()` populated."""

    def __init__(self, experiment, recovery: Recovery, mesh=None):
        recovery.validate()
        self.experiment = experiment
        self.recovery = recovery
        self.mesh = mesh
        self._part = experiment.partitioning
        self._restarts = 0
        # straggler re-dispatches (EngineStall) are routine
        # rebalancing, not crashes: they get their own counter and
        # never consume the max_restarts budget
        self._stall_redispatches = 0
        self._events: list[dict] = []
        self._stall_retried: set[int] = set()
        self._injector = (
            FailureInjector(recovery.inject,
                            n_windows=experiment.schedule.n_windows)
            if recovery.inject is not None else None)
        # saves must land on superstep boundaries: round the cadence up
        # to a multiple of window_block
        wb = max(1, experiment.window_block)
        self._cadence = ((max(recovery.cadence, wb) + wb - 1) // wb) * wb
        # frontier of the newest durable checkpoint (for heartbeats)
        self._ckpt_frontier = -1
        self._depth_effective = 1

    # ------------------------------------------------------------- api
    def run(self):
        from repro.api.result import SimulationResult  # lazy: no cycle

        rec = self.recovery
        os.makedirs(rec.ckpt_dir, exist_ok=True)
        t0 = time.perf_counter()
        while True:
            engine = self._build()
            self._restore_newest_valid(engine)
            try:
                self._drive(engine)
                self._depth_effective = engine.pipeline_depth_effective
                break
            except RecoverableError as e:
                self._handle_fault(e)
        # sinks attach only now, after the run succeeded: the record
        # buffer replays into each exactly once, so a run that
        # restarted five times still writes one CSV
        for sink in self.experiment.sinks:
            engine.stream.attach(sink)
            for r in engine.stream.records():
                sink(r)
        engine.stream.close()
        result = SimulationResult(self.experiment, engine)
        result._wall_time = time.perf_counter() - t0
        result._restarts = self._restarts
        result._stall_redispatches = self._stall_redispatches
        result._recovery = self.report()
        return result

    def report(self) -> dict:
        """Recovery event log + summary counters."""
        kinds: dict = {}
        for ev in self._events:
            if ev["event"] == "fault":
                kinds[ev["kind"]] = kinds.get(ev["kind"], 0) + 1
        return {
            "restarts": self._restarts,
            "stall_redispatches": self._stall_redispatches,
            "faults_by_kind": kinds,
            "final_n_shards": (self._part.n_shards
                               if self._part is not None else None),
            "pipeline_depth_effective": self._depth_effective,
            "events": list(self._events),
        }

    # ------------------------------------------------------ lifecycle
    def _log(self, event: str, **detail) -> None:
        self._events.append({"event": event, **detail})

    def _build(self):
        from repro.api.run import build_engine  # lazy: api imports us

        exp = self.experiment.with_(sinks=(), recovery=None,
                                    partitioning=self._part)
        return build_engine(exp, mesh=self.mesh)

    def _restore_newest_valid(self, engine) -> None:
        """Restore the newest checkpoint that verifies, falling back
        past corrupt/truncated files; a fresh window-0 start if none
        survive."""
        for w, path in reversed(
                ckpt_store.list_checkpoints(self.recovery.ckpt_dir,
                                            self.recovery.namespace)):
            try:
                engine.restore(path)
            except ckpt_store.CheckpointCorrupt as e:
                self._log("corrupt_checkpoint_skipped", window=w,
                          path=path, error=str(e))
                continue
            self._log("restored", window=w, path=path)
            return
        self._log("fresh_start")

    def _drive(self, engine) -> None:
        rec = self.recovery
        n = len(engine.grid)
        per_window = engine.cfg.window_block == 1 and engine._steer is None
        if not per_window and engine._steer is None:
            # cadence saves are served from the oldest in-flight ring's
            # entry snapshot (engine.checkpoint), so the dispatch-ahead
            # never halts at a save boundary and the pipeline keeps its
            # full depth through every save (steered runs are lock-step
            # anyway — snapshots would be dead weight there)
            engine.enable_snapshots()
        if not ckpt_store.list_checkpoints(rec.ckpt_dir, rec.namespace):
            self._save(engine)  # window-0 anchor: a crash before the
            #                     first cadence save still restores
        while engine._window < n:
            w = engine._window
            next_save = min(n, (w // self._cadence + 1) * self._cadence)
            if per_window:
                self._inject(engine, w, w + 1)
                engine.run_window()
            else:
                self._inject(engine, w, min(w + engine.cfg.window_block, n))
                engine.run_block()
            self._check_stragglers(engine)
            # the cadence is a window_block multiple and blocks collect
            # in grid-aligned order, so the collected frontier lands on
            # every save boundary exactly — no flush needed to hit it
            if engine._window >= next_save:
                self._save(engine)
            self._progress(engine)

    def _save(self, engine) -> None:
        rec = self.recovery
        path = os.path.join(
            rec.ckpt_dir,
            ckpt_store.checkpoint_name(engine._window, rec.namespace))
        engine.checkpoint(path)
        pruned = ckpt_store.RetentionPolicy(rec.keep_last).apply(
            rec.ckpt_dir, rec.namespace)
        self._ckpt_frontier = engine._window
        self._log("checkpoint", window=engine._window, path=path,
                  pruned=len(pruned))

    def _progress(self, engine) -> None:
        """Per-iteration progress hook: a no-op here; the farm worker
        overrides it to feed the heartbeat writer (window frontier,
        checkpoint frontier, straggler rate)."""

    def _handle_fault(self, e: RecoverableError) -> None:
        rec = self.recovery
        if isinstance(e, EngineStall):
            # straggler re-dispatch: rebuild+restore+replay like any
            # fault, but on its OWN counter — a few slow windows must
            # never consume the crash max_restarts budget — and with no
            # backoff sleep (delaying the retry of a slow window only
            # makes it slower; boundedness comes from the injector's
            # fire-once schedule and the watchdog's one-retry-per-
            # window set, not from a restart cap)
            self._stall_redispatches += 1
            self._log("fault", kind=e.kind, window=e.window,
                      stall_redispatch=self._stall_redispatches,
                      error=str(e))
            return
        self._restarts += 1
        self._log("fault", kind=e.kind, window=e.window,
                  restart=self._restarts, error=str(e))
        if self._restarts > rec.max_restarts:
            raise RuntimeError(
                f"run declared dead after {self._restarts} restarts "
                f"(Recovery.max_restarts={rec.max_restarts}); last "
                f"fault: {e}") from e
        if isinstance(e, DeviceLost) and rec.elastic \
                and self._part is not None and self._part.n_shards > 1:
            n_inst = self.experiment.ensemble.n_instances
            old = self._part.n_shards
            self._part = self._part.degrade(n_inst, e.n_lost)
            self._log("degraded", from_shards=old,
                      to_shards=self._part.n_shards)
        delay = min(rec.backoff_max_s,
                    rec.backoff_base_s * (2 ** (self._restarts - 1)))
        if delay > 0:
            time.sleep(delay)

    # ------------------------------------------------------ escalation
    def _check_stragglers(self, engine) -> None:
        if not self.recovery.redispatch_stragglers:
            return
        for w, wall, med in engine.watchdog.flagged:
            if w in self._stall_retried:
                continue
            # one retry per window: replay is bitwise, so if the window
            # is systematically slow the retry changes nothing and the
            # run proceeds instead of looping
            self._stall_retried.add(w)
            raise EngineStall(
                f"window {w} breached the straggler watchdog "
                f"({wall:.4f}s vs rolling median {med:.4f}s); "
                "re-dispatching its block from the last checkpoint",
                window=w)

    # ------------------------------------------------------- injection
    def _inject(self, engine, w_lo: int, w_hi: int) -> None:
        if self._injector is None:
            return
        for wi in range(w_lo, w_hi):
            kind = self._injector.maybe_fail(wi)
            if kind is None:
                continue
            self._log("fault_injected", window=wi, kind=kind)
            if kind == "crash":
                raise EngineCrash(f"injected crash before window {wi}",
                                  window=wi)
            if kind == "device_lost":
                raise DeviceLost(
                    f"injected device loss before window {wi}",
                    window=wi, n_lost=1)
            if kind == "ckpt_corrupt":
                # corrupt the newest snapshot THEN crash: one fault
                # deterministically exercises fallback-past-corrupt
                self._corrupt_newest()
                raise EngineCrash(
                    f"injected crash (after checkpoint corruption) "
                    f"before window {wi}", window=wi)
            if kind == "stall":
                raise EngineStall(
                    f"injected stall at window {wi}; re-dispatching",
                    window=wi)
            if kind == "nan_pool":
                # poison the pool and DON'T raise: the engine's own
                # invariant guard must detect it (this drills the
                # guard, not the injector)
                self._poison_pool(engine)

    def _corrupt_newest(self) -> None:
        cks = ckpt_store.list_checkpoints(self.recovery.ckpt_dir,
                                          self.recovery.namespace)
        if not cks:
            return
        path = cks[-1][1]
        size = os.path.getsize(path)
        # truncate rather than flip bytes: a byte flip can land in zip
        # header padding and survive verification; a half-length file
        # deterministically fails to load
        with open(path, "r+b") as f:
            f.truncate(size // 2)
        self._log("checkpoint_corrupted", path=path)

    def _poison_pool(self, engine) -> None:
        from repro.core.gillespie import LaneState

        arrs = {f: np.array(getattr(engine._pool, f))
                for f in LaneState._fields}
        arrs["x"][:] = np.nan  # float32 pool: NaN propagates to stats
        import jax.numpy as jnp

        engine._pool = engine._dispatch.place(LaneState(
            **{f: jnp.asarray(v) for f, v in arrs.items()}))
