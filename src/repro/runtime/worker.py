"""Farm worker process — one ensemble shard under a RunSupervisor.

Launched by `runtime.coordinator.FarmCoordinator` as
``python -m repro.runtime.worker <spec.pkl>``. The spec pickle carries
the full Experiment (sinks/recovery stripped), this worker's shard
``(lo, hi, stat_blocks)``, a per-shard Recovery whose `namespace` keys
this shard's checkpoints inside the SHARED ckpt_dir, and the heartbeat
/ result paths.

The worker is the existing single-process machinery, re-based onto a
slice of the global ensemble:

* the engine is built through `api.run.build_engine(shard=...)`: same
  seed and knobs, instance rows [lo, hi), RNG key rows taken from the
  GLOBAL key table (counter-based streams are position-independent, so
  lane i simulates the identical trajectory it would in the
  single-process run), rates/group ids sliced to the range;
* `RunSupervisor` drives it with cadenced namespaced checkpoints —
  engine-level faults (nan guards, …) recover in-process exactly as
  before; process death is the COORDINATOR's job;
* a daemon thread writes newline-terminated JSON heartbeats
  (window frontier, checkpoint frontier, straggler rate) every
  ``heartbeat_s / 2``; a SIGSTOP freezes it, which is precisely how
  the coordinator detects a stalled worker;
* on completion the worker writes an atomic, checksummed result bundle
  (`ckpt.store.save_atomic`): per-window Welford PARTIAL stacks for
  the bitwise record merge, grouped/sketch/trajectory slices, final
  pool state, and a JSON meta blob (supervisor report, telemetry,
  steering report). A worker relaunched AFTER finishing restores its
  final checkpoint, falls straight through the drive loop, and
  rewrites the same bundle — so a crash between "done" and "bundle
  durable" is recoverable too.
"""
from __future__ import annotations

import json
import os
import pickle
import sys
import threading
import time

import numpy as np

from repro.ckpt import store as ckpt_store
from repro.runtime.supervisor import Recovery, RunSupervisor


def _write_json_atomic(path: str, payload: dict) -> None:
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        f.write(json.dumps(payload) + "\n")
    os.replace(tmp, path)


class WorkerSupervisor(RunSupervisor):
    """RunSupervisor over one shard: builds the engine through the
    shard seam (global RNG key rows, sliced rates/groups, partials
    export on) and feeds the heartbeat writer from the drive loop."""

    def __init__(self, experiment, recovery: Recovery, shard: tuple,
                 shard_index: int, heartbeat_path: str):
        super().__init__(experiment, recovery)
        self._shard = tuple(shard)
        self._shard_index = int(shard_index)
        self._hb_path = heartbeat_path
        self._hb_lock = threading.Lock()
        # phase "init" covers engine build + first restore + jit
        # compile, where XLA holds the GIL long enough to starve the
        # heartbeat thread — the coordinator applies the launch grace
        # instead of the run-phase staleness timeout until "run"
        self._hb_state = {"shard": self._shard_index, "pid": os.getpid(),
                          "window": 0, "ckpt_window": -1,
                          "straggler_rate": 0.0, "phase": "init"}
        self._hb_stop = threading.Event()

    # ------------------------------------------------------- heartbeat
    def write_heartbeat(self) -> None:
        with self._hb_lock:
            payload = dict(self._hb_state, time=time.time())
        _write_json_atomic(self._hb_path, payload)

    def start_heartbeat(self) -> None:
        self.write_heartbeat()  # announce liveness before the run

        def beat():
            while not self._hb_stop.wait(self.recovery.heartbeat_s / 2):
                self.write_heartbeat()

        threading.Thread(target=beat, daemon=True).start()

    def stop_heartbeat(self) -> None:
        self._hb_stop.set()

    def _progress(self, engine) -> None:
        with self._hb_lock:
            self._hb_state.update(
                window=engine._window,
                ckpt_window=self._ckpt_frontier,
                straggler_rate=engine.watchdog.straggler_rate(),
                phase="run")

    # ----------------------------------------------------------- build
    def _build(self):
        from repro.api.run import build_engine  # lazy: api imports us

        exp = self.experiment.with_(sinks=(), recovery=None,
                                    partitioning=None)
        engine = build_engine(exp, shard=self._shard)
        engine.enable_block_partials()
        return engine


def _result_bundle(experiment, result, sup: WorkerSupervisor) -> dict:
    eng = result._engine
    W = eng._window
    arrays = {
        "window": np.int64(W),
        "grid": np.asarray(eng.grid, np.float64),
        "final_x": np.asarray(eng._pool.x),
    }
    if eng._block_partials:
        for name in ("n", "mean", "m2"):
            arrays[f"bp_{name}"] = np.stack(
                [getattr(b, name) for b in eng._block_partials])
    if eng._grouped_partials:
        for name in ("n", "mean", "m2"):
            arrays[f"gp_{name}"] = np.stack(
                [getattr(b, name) for b in eng._grouped_partials])
    traj = eng.trajectories()
    if traj is not None:
        arrays["samples"] = traj
    grouped = eng.grouped_stats()
    if grouped:
        for name in ("n", "mean", "var", "ci90"):
            arrays[f"grouped_{name}"] = np.stack(
                [getattr(g, name) for g in grouped])
    sketches = eng.sketches()
    if sketches:
        arrays["sketch_hist"] = np.stack([s.hist for s in sketches])
        if sketches[0].rare is not None:
            arrays["sketch_rare"] = np.stack([s.rare for s in sketches])
    if eng._sketch is not None:  # bin params for downstream quantiles
        arrays["sketch_lo"] = np.asarray(eng._sketch.lo)
        arrays["sketch_width"] = np.asarray(eng._sketch.width)
    tele = result.telemetry
    meta = {
        "shard": sup._shard_index,
        "lo": sup._shard[0], "hi": sup._shard[1],
        "obs_names": list(eng.obs_names),
        "report": sup.report(),
        "steering": eng.steering_report(),
        "telemetry": {
            "wall_time_s": tele.wall_time_s,
            "window_wall_times": list(tele.window_wall_times),
            "peak_buffered_bytes": int(tele.peak_buffered_bytes),
            "dispatches": int(tele.dispatches),
            "host_syncs": int(tele.host_syncs),
            "steps_per_window": [int(v) for v in tele.steps_per_window],
            "leaps_per_window": [int(v) for v in tele.leaps_per_window],
            "straggler_windows": [list(v) for v in
                                  tele.straggler_windows],
            "watchdog_observed": int(eng.watchdog.observed),
            "block_walls": [list(v) for v in tele.block_walls],
            "pipeline_depth": int(tele.pipeline_depth),
            "pipeline_depth_effective": int(
                tele.pipeline_depth_effective),
            "peak_inflight_blocks": int(tele.peak_inflight_blocks),
            "snapshot_saves": int(tele.snapshot_saves),
            "ckpt_flushes": int(tele.ckpt_flushes),
            "restarts": int(tele.restarts),
            "stall_redispatches": int(tele.stall_redispatches),
        },
    }
    arrays["meta"] = np.array(json.dumps(meta))
    return arrays


def run_worker(spec_path: str) -> int:
    with open(spec_path, "rb") as f:
        spec = pickle.load(f)
    sup = WorkerSupervisor(
        spec["experiment"], spec["recovery"], spec["shard"],
        spec["shard_index"], spec["heartbeat_path"])
    sup.start_heartbeat()
    try:
        result = sup.run()
        # final heartbeat with the completed frontier, then the bundle
        sup._hb_state.update(window=result.windows_run, phase="done")
        sup.write_heartbeat()
        ckpt_store.save_atomic(
            spec["result_path"],
            _result_bundle(spec["experiment"], result, sup))
    finally:
        sup.stop_heartbeat()
    return 0


def main(argv) -> int:
    if len(argv) != 1:
        print("usage: python -m repro.runtime.worker <spec.pkl>",
              file=sys.stderr)
        return 2
    return run_worker(argv[0])


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
