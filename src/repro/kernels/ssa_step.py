"""Pallas TPU kernel: fused multi-step SSA window, RNG in VREGs.

The flagship hardware adaptation (DESIGN.md §2/§4): the paper found the
single SSA step too fine-grained for any inter-core parallelism and
nearly SIMD-proof *within* one instance. Here the ENTIRE Monte Carlo
inner loop runs inside one kernel with the lane state (X, t) resident
in VMEM across `n_steps` iterations:

  per step (all in VMEM / VREGs):
    Match   — A = k · Π C(X@E_m, coef)        (MXU matmuls)
    Resolve — u1, u2 = threefry2x32(key, ctr) (VREG counter-based draw)
              tau = -ln(u1)/a0;  one-hot(j) from inverse-CDF on cumsum
    Update  — X += onehot(j) @ delta          (MXU matmul)

HBM traffic per window: X/t/flags/key/ctr once each way — nothing that
scales with the step count. There is NO uniform-stream operand: the
uniforms are generated in-register from the per-lane (key, ctr) stream
(`core/stream.counter_uniforms`), which is the memory-wall guideline
(§3.2.3/3.1.2) applied to the HBM↔VMEM boundary.

Because the draw is a pure function of (lane key, event counter), the
kernel consumes the IDENTICAL stream as the unfused
`gillespie.ssa_step` — trajectories are bitwise equal for ANY chunk
size, across window boundaries, and across shard counts (tested).

Grid: lane blocks only (reactions stay whole in VMEM — CWC systems are
small-R; an R-tiled variant would add a cross-tile argmin, not needed
here).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.reactions import MAX_COEF, MAX_REACTANTS
from repro.core.stream import counter_uniforms, ctr_add
from repro.core.tau_leap import tau_step_core
from repro.kernels.propensity import _comb_factors

LANE_BLK = 256


def _window_kernel(x_ref, t_ref, dead_ref, key_ref, ctr_ref, ctrhi_ref,
                   e_ref, coef_ref, delta_ref, rates_ref, horizon_ref,
                   x_out, t_out, dead_out, steps_out, ctr_out, ctrhi_out,
                   n_steps: int):
    x = x_ref[...].astype(jnp.float32)  # (BL, S)
    t = t_ref[...]  # (BL,)
    dead = dead_ref[...] > 0  # (BL,)
    k0 = key_ref[:, 0]  # (BL,) uint32 — stream key, read once
    k1 = key_ref[:, 1]
    ctr = ctr_ref[...]  # (BL,) uint32 — draw counter low word, in VREGs
    ctr_hi = ctrhi_ref[...]  # (BL,) uint32 — high word (carry)
    horizon = horizon_ref[0]
    steps = jnp.zeros_like(t, jnp.float32)

    def step(i, carry):
        x, t, dead, steps, ctr, ctr_hi = carry
        active = (t < horizon) & ~dead
        # --- Match (MXU) ---
        a = rates_ref[...]
        for m in range(MAX_REACTANTS):
            pops = jax.lax.dot(x, e_ref[m],
                               preferred_element_type=jnp.float32)
            a = a * _comb_factors(pops, coef_ref[m][None, :])
        a0 = a.sum(axis=1)
        now_dead = a0 <= 0.0
        # --- Resolve (counter-based draw, VREGs only) ---
        u1, u2 = counter_uniforms(k0, k1, ctr, ctr_hi)
        tau = -jnp.log(u1) / jnp.maximum(a0, 1e-30)
        t_next = t + tau
        fire = active & ~now_dead & (t_next <= horizon)
        cum = jnp.cumsum(a, axis=1)
        thresh = (u2 * a0)[:, None]
        ge = cum >= thresh
        first = ge & ~jnp.concatenate(
            [jnp.zeros_like(ge[:, :1]), ge[:, :-1]], axis=1)
        onehot = jnp.where(fire[:, None], first.astype(jnp.float32), 0.0)
        # --- Update (MXU) ---
        dx = jax.lax.dot(onehot, delta_ref[...],
                         preferred_element_type=jnp.float32)
        x = x + dx
        t = jnp.where(fire, t_next,
                      jnp.where(active, horizon, t))
        dead = dead | (active & now_dead)
        steps = steps + fire.astype(jnp.float32)
        ctr, ctr_hi = ctr_add(ctr, ctr_hi, active.astype(jnp.uint32))
        return x, t, dead, steps, ctr, ctr_hi

    x, t, dead, steps, ctr, ctr_hi = jax.lax.fori_loop(
        0, n_steps, step, (x, t, dead, steps, ctr, ctr_hi))
    x_out[...] = x
    t_out[...] = t
    dead_out[...] = dead.astype(jnp.int32)
    steps_out[...] = steps.astype(jnp.int32)
    ctr_out[...] = ctr
    ctrhi_out[...] = ctr_hi


@partial(jax.jit, static_argnames=("n_steps", "interpret"))
def ssa_window_call(x, t, dead, key, ctr, ctr_hi, e, coef, delta, rates,
                    horizon, *, n_steps: int, interpret: bool = True):
    """Run up to n_steps fused SSA events per lane toward `horizon`.

    x: (B,S) f32; t: (B,) f32; dead: (B,) int32; key: (B,2) uint32;
    ctr/ctr_hi: (B,) uint32; e: (M,S,R); coef: (M,R) f32;
    delta: (R,S) f32; rates: (B,R) or (R,).
    Returns (x, t, dead, steps_taken, ctr, ctr_hi).
    """
    b, s = x.shape
    r = delta.shape[0]
    if rates.ndim == 1:
        rates = jnp.broadcast_to(rates, (b, r))
    bl = min(LANE_BLK, b)
    grid = (pl.cdiv(b, bl),)
    horizon_arr = jnp.asarray([horizon], jnp.float32)
    kernel = partial(_window_kernel, n_steps=n_steps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bl, s), lambda i: (i, 0)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((bl, 2), lambda i: (i, 0)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((MAX_REACTANTS, s, r), lambda i: (0, 0, 0)),
            pl.BlockSpec((MAX_REACTANTS, r), lambda i: (0, 0)),
            pl.BlockSpec((r, s), lambda i: (0, 0)),
            pl.BlockSpec((bl, r), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bl, s), lambda i: (i, 0)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((bl,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.uint32),
            jax.ShapeDtypeStruct((b,), jnp.uint32),
        ],
        interpret=interpret,
    )(x, t, dead, key, ctr, ctr_hi, e, coef, delta, rates, horizon_arr)


def _tau_window_kernel(x_ref, t_ref, dead_ref, noleap_ref, key_ref,
                       ctr_ref, ctrhi_ref, e_ref, coef_ref, delta_ref,
                       rates_ref, gi_ref, rmask_ref, horizon_ref,
                       x_out, t_out, dead_out, steps_out, leaps_out,
                       ctr_out, ctrhi_out,
                       n_steps: int, eps: float, fallback: float):
    """Fused multi-step tau-leap window: the SAME `tau_step_core` the
    host paths trace, iterated with the lane state resident in VMEM —
    propensity/moment/update matmuls on the MXU, Poisson
    inverse-transform and counter-based draws in VREGs.

    noleap_ref: (BL,) int32 — nonzero lanes take exact SSA steps only
    (steering's per-lane exact<->tau switch): their effective fallback
    threshold is +inf, computed once in VREGs; the static scalar
    `fallback` stays a jit-time constant for everyone else."""
    x = x_ref[...].astype(jnp.float32)
    t = t_ref[...]
    dead = dead_ref[...] > 0
    fb = jnp.where(noleap_ref[...] > 0, jnp.float32(jnp.inf),
                   jnp.float32(fallback))
    k0 = key_ref[:, 0]
    k1 = key_ref[:, 1]
    ctr = ctr_ref[...]
    ctr_hi = ctrhi_ref[...]
    horizon = horizon_ref[0]
    steps = jnp.zeros_like(t, jnp.int32)
    leaps = jnp.zeros_like(t, jnp.int32)

    def step(i, carry):
        x, t, dead, ctr, ctr_hi, steps, leaps = carry
        x, t, dead, ctr, ctr_hi, steps, leaps = tau_step_core(
            x, t, dead, k0, k1, ctr, ctr_hi, steps, leaps,
            e_ref[...], coef_ref[...], delta_ref[...], rates_ref[...],
            gi_ref[...], rmask_ref[...], horizon,
            eps=eps, fallback=fb)
        return x, t, dead, ctr, ctr_hi, steps, leaps

    x, t, dead, ctr, ctr_hi, steps, leaps = jax.lax.fori_loop(
        0, n_steps, step, (x, t, dead, ctr, ctr_hi, steps, leaps))
    x_out[...] = x
    t_out[...] = t
    dead_out[...] = dead.astype(jnp.int32)
    steps_out[...] = steps
    leaps_out[...] = leaps
    ctr_out[...] = ctr
    ctrhi_out[...] = ctr_hi


@partial(jax.jit, static_argnames=("n_steps", "interpret", "eps",
                                   "fallback"))
def tau_window_call(x, t, dead, no_leap, key, ctr, ctr_hi, e, coef,
                    delta, rates, gi, rmask, horizon, *, n_steps: int,
                    eps: float, fallback: float, interpret: bool = True):
    """Run up to n_steps fused tau-leap iterations per lane toward
    `horizon`. Shapes as `ssa_window_call` plus no_leap (B,) int32
    (nonzero = lane forced to exact SSA — steering's per-lane method
    switch), gi (MAX_COEF,S) and rmask (S,) from
    `core.tau_leap.gi_tables`/`reactant_mask`.
    Returns (x, t, dead, steps_delta, leaps_delta, ctr, ctr_hi)."""
    b, s = x.shape
    r = delta.shape[0]
    if rates.ndim == 1:
        rates = jnp.broadcast_to(rates, (b, r))
    bl = min(LANE_BLK, b)
    grid = (pl.cdiv(b, bl),)
    horizon_arr = jnp.asarray([horizon], jnp.float32)
    kernel = partial(_tau_window_kernel, n_steps=n_steps, eps=eps,
                     fallback=fallback)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bl, s), lambda i: (i, 0)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((bl, 2), lambda i: (i, 0)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((MAX_REACTANTS, s, r), lambda i: (0, 0, 0)),
            pl.BlockSpec((MAX_REACTANTS, r), lambda i: (0, 0)),
            pl.BlockSpec((r, s), lambda i: (0, 0)),
            pl.BlockSpec((bl, r), lambda i: (i, 0)),
            pl.BlockSpec((MAX_COEF, s), lambda i: (0, 0)),
            pl.BlockSpec((s,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bl, s), lambda i: (i, 0)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((bl,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.uint32),
            jax.ShapeDtypeStruct((b,), jnp.uint32),
        ],
        interpret=interpret,
    )(x, t, dead, no_leap, key, ctr, ctr_hi, e, coef, delta, rates, gi,
      rmask, horizon_arr)
