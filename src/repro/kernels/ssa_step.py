"""Pallas TPU kernel: fused multi-step SSA window.

The flagship hardware adaptation (DESIGN.md §2/§4): the paper found the
single SSA step too fine-grained for any inter-core parallelism and
nearly SIMD-proof *within* one instance. Here the ENTIRE Monte Carlo
inner loop runs inside one kernel with the lane state (X, t) resident
in VMEM across `n_steps` iterations:

  per step (all in VMEM / VREGs):
    Match   — A = k · Π C(X@E_m, coef)        (MXU matmuls)
    Resolve — tau = -ln(u1)/a0;  one-hot(j) from inverse-CDF on cumsum
    Update  — X += onehot(j) @ delta          (MXU matmul)

HBM traffic per window: X/t/flags once each way + the uniform stream,
instead of O(state × steps) — the memory-wall guideline (§3.2.3/3.1.2)
applied to the HBM↔VMEM boundary.

Uniforms are precomputed from the SAME per-lane threefry sequence as
the unfused `gillespie.ssa_step`, so kernel and jnp paths produce
bit-identical trajectories (tested).

Grid: lane blocks only (reactions stay whole in VMEM — CWC systems are
small-R; an R-tiled variant would add a cross-tile argmin, not needed
here).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.reactions import MAX_REACTANTS
from repro.kernels.propensity import _comb_factors

LANE_BLK = 256


def _window_kernel(x_ref, t_ref, dead_ref, u_ref, e_ref, coef_ref,
                   delta_ref, rates_ref, horizon_ref,
                   x_out, t_out, dead_out, steps_out, n_steps: int):
    x = x_ref[...].astype(jnp.float32)  # (BL, S)
    t = t_ref[...]  # (BL,)
    dead = dead_ref[...] > 0  # (BL,)
    horizon = horizon_ref[0]
    steps = jnp.zeros_like(t, jnp.float32)

    def step(i, carry):
        x, t, dead, steps = carry
        active = (t < horizon) & ~dead
        # --- Match (MXU) ---
        a = rates_ref[...]
        for m in range(MAX_REACTANTS):
            pops = jax.lax.dot(x, e_ref[m],
                               preferred_element_type=jnp.float32)
            a = a * _comb_factors(pops, coef_ref[m][None, :])
        a0 = a.sum(axis=1)
        now_dead = a0 <= 0.0
        # --- Resolve ---
        u1 = u_ref[:, i, 0]
        u2 = u_ref[:, i, 1]
        tau = -jnp.log(u1) / jnp.maximum(a0, 1e-30)
        t_next = t + tau
        fire = active & ~now_dead & (t_next <= horizon)
        cum = jnp.cumsum(a, axis=1)
        thresh = (u2 * a0)[:, None]
        ge = cum >= thresh
        first = ge & ~jnp.concatenate(
            [jnp.zeros_like(ge[:, :1]), ge[:, :-1]], axis=1)
        onehot = jnp.where(fire[:, None], first.astype(jnp.float32), 0.0)
        # --- Update (MXU) ---
        dx = jax.lax.dot(onehot, delta_ref[...],
                         preferred_element_type=jnp.float32)
        x = x + dx
        t = jnp.where(fire, t_next,
                      jnp.where(active, horizon, t))
        dead = dead | (active & now_dead)
        steps = steps + fire.astype(jnp.float32)
        return x, t, dead, steps

    x, t, dead, steps = jax.lax.fori_loop(
        0, n_steps, step, (x, t, dead, steps))
    x_out[...] = x
    t_out[...] = t
    dead_out[...] = dead.astype(jnp.int32)
    steps_out[...] = steps.astype(jnp.int32)


@partial(jax.jit, static_argnames=("n_steps", "interpret"))
def ssa_window_call(x, t, dead, uniforms, e, coef, delta, rates, horizon,
                    *, n_steps: int, interpret: bool = True):
    """Run up to n_steps fused SSA events per lane toward `horizon`.

    x: (B,S) f32; t: (B,) f32; dead: (B,) int32; uniforms: (B, n_steps, 2);
    e: (M,S,R); coef: (M,R) f32; delta: (R,S) f32; rates: (B,R) or (R,).
    Returns (x, t, dead, steps_taken).
    """
    b, s = x.shape
    r = delta.shape[0]
    if rates.ndim == 1:
        rates = jnp.broadcast_to(rates, (b, r))
    bl = min(LANE_BLK, b)
    grid = (pl.cdiv(b, bl),)
    horizon_arr = jnp.asarray([horizon], jnp.float32)
    kernel = partial(_window_kernel, n_steps=n_steps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bl, s), lambda i: (i, 0)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((bl, n_steps, 2), lambda i: (i, 0, 0)),
            pl.BlockSpec((MAX_REACTANTS, s, r), lambda i: (0, 0, 0)),
            pl.BlockSpec((MAX_REACTANTS, r), lambda i: (0, 0)),
            pl.BlockSpec((r, s), lambda i: (0, 0)),
            pl.BlockSpec((bl, r), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bl, s), lambda i: (i, 0)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((bl,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
        ],
        interpret=interpret,
    )(x, t, dead, uniforms, e, coef, delta, rates, horizon_arr)
