"""Pallas TPU kernel: fused multi-step SSA window, RNG in VREGs.

The flagship hardware adaptation (DESIGN.md §2/§4): the paper found the
single SSA step too fine-grained for any inter-core parallelism and
nearly SIMD-proof *within* one instance. Here the ENTIRE Monte Carlo
inner loop runs inside one kernel with the lane state (X, t) resident
in VMEM across `n_steps` iterations:

  per step (all in VMEM / VREGs):
    Match   — A = k · Π C(X@E_m, coef)        (MXU matmuls)
    Resolve — u1, u2 = threefry2x32(key, ctr) (VREG counter-based draw)
              tau = -ln(u1)/a0;  one-hot(j) from inverse-CDF on cumsum
    Update  — X += onehot(j) @ delta          (MXU matmul)

HBM traffic per window: X/t/flags/key/ctr once each way — nothing that
scales with the step count. There is NO uniform-stream operand: the
uniforms are generated in-register from the per-lane (key, ctr) stream
(`core/stream.counter_uniforms`), which is the memory-wall guideline
(§3.2.3/3.1.2) applied to the HBM↔VMEM boundary.

Because the draw is a pure function of (lane key, event counter), the
kernel consumes the IDENTICAL stream as the unfused
`gillespie.ssa_step` — trajectories are bitwise equal for ANY chunk
size, across window boundaries, and across shard counts (tested).

Grid: lane blocks only (reactions stay whole in VMEM — CWC systems are
small-R; an R-tiled variant would add a cross-tile argmin, not needed
here).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.gillespie import LaneState, resolve_carry, sparse_ssa_step
from repro.core.reactions import (
    MAX_REACTANTS,
    propensities_partitioned,
)
from repro.core.stream import counter_uniforms, ctr_add
from repro.core.tau_leap import tau_step_core
from repro.kernels.propensity import _comb_factors, resolve_interpret

LANE_BLK = 256


def species_partition(b: int, r: int, lane_blk: int = LANE_BLK) -> int:
    """Partition factor for the in-kernel dense propensity seed: the
    largest power-of-two divisor of R such that b·part <= lane_blk.
    One LARGE simulation's R-wide Match work is reshaped across `part`
    lanes of the block (species-partitioned stepping) instead of
    leaving the lane axis mostly idle at small batch. Pure shape
    arithmetic — the partitioned evaluation is bitwise identical to the
    unpartitioned one for any factor."""
    part = 1
    while b * part * 2 <= lane_blk and r % (part * 2) == 0:
        part *= 2
    return part


def _window_kernel(x_ref, t_ref, dead_ref, key_ref, ctr_ref, ctrhi_ref,
                   e_ref, coef_ref, delta_ref, rates_ref, horizon_ref,
                   x_out, t_out, dead_out, steps_out, ctr_out, ctrhi_out,
                   n_steps: int):
    x = x_ref[...].astype(jnp.float32)  # (BL, S)
    t = t_ref[...]  # (BL,)
    dead = dead_ref[...] > 0  # (BL,)
    k0 = key_ref[:, 0]  # (BL,) uint32 — stream key, read once
    k1 = key_ref[:, 1]
    ctr = ctr_ref[...]  # (BL,) uint32 — draw counter low word, in VREGs
    ctr_hi = ctrhi_ref[...]  # (BL,) uint32 — high word (carry)
    horizon = horizon_ref[0]
    steps = jnp.zeros_like(t, jnp.float32)

    def step(i, carry):
        x, t, dead, steps, ctr, ctr_hi = carry
        active = (t < horizon) & ~dead
        # --- Match (MXU) ---
        a = rates_ref[...]
        for m in range(MAX_REACTANTS):
            pops = jax.lax.dot(x, e_ref[m],
                               preferred_element_type=jnp.float32)
            a = a * _comb_factors(pops, coef_ref[m][None, :])
        a0 = a.sum(axis=1)
        now_dead = a0 <= 0.0
        # --- Resolve (counter-based draw, VREGs only) ---
        u1, u2 = counter_uniforms(k0, k1, ctr, ctr_hi)
        tau = -jnp.log(u1) / jnp.maximum(a0, 1e-30)
        t_next = t + tau
        fire = active & ~now_dead & (t_next <= horizon)
        cum = jnp.cumsum(a, axis=1)
        thresh = (u2 * a0)[:, None]
        ge = cum >= thresh
        first = ge & ~jnp.concatenate(
            [jnp.zeros_like(ge[:, :1]), ge[:, :-1]], axis=1)
        onehot = jnp.where(fire[:, None], first.astype(jnp.float32), 0.0)
        # --- Update (MXU) ---
        dx = jax.lax.dot(onehot, delta_ref[...],
                         preferred_element_type=jnp.float32)
        x = x + dx
        t = jnp.where(fire, t_next,
                      jnp.where(active, horizon, t))
        dead = dead | (active & now_dead)
        steps = steps + fire.astype(jnp.float32)
        ctr, ctr_hi = ctr_add(ctr, ctr_hi, active.astype(jnp.uint32))
        return x, t, dead, steps, ctr, ctr_hi

    x, t, dead, steps, ctr, ctr_hi = jax.lax.fori_loop(
        0, n_steps, step, (x, t, dead, steps, ctr, ctr_hi))
    x_out[...] = x
    t_out[...] = t
    dead_out[...] = dead.astype(jnp.int32)
    steps_out[...] = steps.astype(jnp.int32)
    ctr_out[...] = ctr
    ctrhi_out[...] = ctr_hi


@partial(jax.jit, static_argnames=("n_steps", "interpret"))
def ssa_window_call(x, t, dead, key, ctr, ctr_hi, e, coef, delta, rates,
                    horizon, *, n_steps: int,
                    interpret: bool | None = None):
    """Run up to n_steps fused SSA events per lane toward `horizon`.

    x: (B,S) f32; t: (B,) f32; dead: (B,) int32; key: (B,2) uint32;
    ctr/ctr_hi: (B,) uint32; e: (M,S,R); coef: (M,R) f32;
    delta: (R,S) f32; rates: (B,R) or (R,).
    `interpret=None` auto-selects the compiled kernel on TPU/GPU.
    Returns (x, t, dead, steps_taken, ctr, ctr_hi).
    """
    interpret = resolve_interpret(interpret)
    b, s = x.shape
    r = delta.shape[0]
    if rates.ndim == 1:
        rates = jnp.broadcast_to(rates, (b, r))
    bl = min(LANE_BLK, b)
    grid = (pl.cdiv(b, bl),)
    horizon_arr = jnp.asarray([horizon], jnp.float32)
    kernel = partial(_window_kernel, n_steps=n_steps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bl, s), lambda i: (i, 0)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((bl, 2), lambda i: (i, 0)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((MAX_REACTANTS, s, r), lambda i: (0, 0, 0)),
            pl.BlockSpec((MAX_REACTANTS, r), lambda i: (0, 0)),
            pl.BlockSpec((r, s), lambda i: (0, 0)),
            pl.BlockSpec((bl, r), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bl, s), lambda i: (i, 0)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((bl,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.uint32),
            jax.ShapeDtypeStruct((b,), jnp.uint32),
        ],
        interpret=interpret,
    )(x, t, dead, key, ctr, ctr_hi, e, coef, delta, rates, horizon_arr)


def _tau_window_kernel(x_ref, t_ref, dead_ref, noleap_ref, key_ref,
                       ctr_ref, ctrhi_ref, e_ref, coef_ref, delta_ref,
                       rates_ref, gi_ref, rmask_ref, horizon_ref,
                       x_out, t_out, dead_out, steps_out, leaps_out,
                       ctr_out, ctrhi_out,
                       n_steps: int, eps: float, fallback: float):
    """Fused multi-step tau-leap window: the SAME `tau_step_core` the
    host paths trace, iterated with the lane state resident in VMEM —
    propensity/moment/update matmuls on the MXU, Poisson
    inverse-transform and counter-based draws in VREGs.

    noleap_ref: (BL,) int32 — nonzero lanes take exact SSA steps only
    (steering's per-lane exact<->tau switch): their effective fallback
    threshold is +inf, computed once in VREGs; the static scalar
    `fallback` stays a jit-time constant for everyone else."""
    x = x_ref[...].astype(jnp.float32)
    t = t_ref[...]
    dead = dead_ref[...] > 0
    fb = jnp.where(noleap_ref[...] > 0, jnp.float32(jnp.inf),
                   jnp.float32(fallback))
    k0 = key_ref[:, 0]
    k1 = key_ref[:, 1]
    ctr = ctr_ref[...]
    ctr_hi = ctrhi_ref[...]
    horizon = horizon_ref[0]
    steps = jnp.zeros_like(t, jnp.int32)
    leaps = jnp.zeros_like(t, jnp.int32)

    def step(i, carry):
        x, t, dead, ctr, ctr_hi, steps, leaps = carry
        x, t, dead, ctr, ctr_hi, steps, leaps = tau_step_core(
            x, t, dead, k0, k1, ctr, ctr_hi, steps, leaps,
            e_ref[...], coef_ref[...], delta_ref[...], rates_ref[...],
            gi_ref[...], rmask_ref[...], horizon,
            eps=eps, fallback=fb)
        return x, t, dead, ctr, ctr_hi, steps, leaps

    x, t, dead, ctr, ctr_hi, steps, leaps = jax.lax.fori_loop(
        0, n_steps, step, (x, t, dead, ctr, ctr_hi, steps, leaps))
    x_out[...] = x
    t_out[...] = t
    dead_out[...] = dead.astype(jnp.int32)
    steps_out[...] = steps
    leaps_out[...] = leaps
    ctr_out[...] = ctr
    ctrhi_out[...] = ctr_hi


@partial(jax.jit, static_argnames=("n_steps", "interpret", "eps",
                                   "fallback"))
def tau_window_call(x, t, dead, no_leap, key, ctr, ctr_hi, e, coef,
                    delta, rates, gi, rmask, horizon, *, n_steps: int,
                    eps: float, fallback: float,
                    interpret: bool | None = None):
    """Run up to n_steps fused tau-leap iterations per lane toward
    `horizon`. Shapes as `ssa_window_call` plus no_leap (B,) int32
    (nonzero = lane forced to exact SSA — steering's per-lane method
    switch), gi (>=MAX_COEF,S) and rmask (S,) from
    `core.tau_leap.gi_tables`/`reactant_mask`.
    `interpret=None` auto-selects the compiled kernel on TPU/GPU.
    Returns (x, t, dead, steps_delta, leaps_delta, ctr, ctr_hi)."""
    interpret = resolve_interpret(interpret)
    b, s = x.shape
    r = delta.shape[0]
    if rates.ndim == 1:
        rates = jnp.broadcast_to(rates, (b, r))
    bl = min(LANE_BLK, b)
    grid = (pl.cdiv(b, bl),)
    horizon_arr = jnp.asarray([horizon], jnp.float32)
    kernel = partial(_tau_window_kernel, n_steps=n_steps, eps=eps,
                     fallback=fallback)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bl, s), lambda i: (i, 0)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((bl, 2), lambda i: (i, 0)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((MAX_REACTANTS, s, r), lambda i: (0, 0, 0)),
            pl.BlockSpec((MAX_REACTANTS, r), lambda i: (0, 0)),
            pl.BlockSpec((r, s), lambda i: (0, 0)),
            pl.BlockSpec((bl, r), lambda i: (i, 0)),
            pl.BlockSpec((gi.shape[0], s), lambda i: (0, 0)),
            pl.BlockSpec((s,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bl, s), lambda i: (i, 0)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((bl,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.uint32),
            jax.ShapeDtypeStruct((b,), jnp.uint32),
        ],
        interpret=interpret,
    )(x, t, dead, no_leap, key, ctr, ctr_hi, e, coef, delta, rates, gi,
      rmask, horizon_arr)


def _sparse_window_kernel(x_ref, t_ref, dead_ref, key_ref, ctr_ref,
                          ctrhi_ref, idxp_ref, coefp_ref, itab_ref,
                          ftab_ref, rates_ref, horizon_ref,
                          x_out, t_out, dead_out, steps_out, ctr_out,
                          ctrhi_out,
                          n_steps: int, max_c: int, d: int, k: int,
                          part: int, packed_rates: bool):
    """Fused multi-step SPARSE exact-SSA window.

    VMEM holds only the O(R·(M+K+D)) sparse tables — no (M, S, R)
    one-hots and no (R, S) dense delta — so a network with thousands of
    species AND reactions fits where the dense kernel's operands would
    blow the budget. The per-step body is the SAME
    `gillespie.sparse_ssa_step` the host paths trace (dependency-graph
    Match update, scatter Update, carried (BL, R) propensity vector)
    over the SAME packed row tables (`gillespie.bind_sparse_step` —
    int_tab/flt_tab, one recipe row per reaction); the carry is seeded
    ONCE per kernel launch by the species-partitioned dense evaluation
    (`propensities_partitioned`, factor `part`) — a pure function of x,
    so chunk boundaries cannot change bits. `packed_rates` says the
    dep-row rates live inside flt_tab (shared (R,) rates); otherwise
    they are gathered per event from the (BL, R+1) rates operand
    (per-instance sweeps). Gather/scatter in the body are jnp
    masked-index ops: they run in the interpreter off-TPU and lower to
    Mosaic dynamic-gather on TPU.
    """
    x = x_ref[...].astype(jnp.float32)  # (BL, S)
    t = t_ref[...]
    dead = dead_ref[...] > 0
    key = key_ref[...]
    ctr = ctr_ref[...]
    ctr_hi = ctrhi_ref[...]
    horizon = horizon_ref[0]
    rates_pad = rates_ref[...]  # (BL, R+1)
    idxp = idxp_ref[...]
    m = idxp.shape[1]
    # species-partitioned seed: one simulation's R-wide Match spread
    # across `part` lanes of the block
    a = propensities_partitioned(
        x, idxp[:-1], coefp_ref[...][:-1], rates_pad[:, :-1], max_c,
        part)
    bound = (itab_ref[...], ftab_ref[...],
             None if packed_rates else rates_pad, max_c, d, k, m)
    zeros_i = jnp.zeros_like(t, jnp.int32)
    state = LaneState(x=x, t=t, key=key, ctr=ctr, ctr_hi=ctr_hi,
                      steps=zeros_i, leaps=zeros_i, dead=dead,
                      no_leap=jnp.zeros_like(dead))

    def step(i, carry):
        st, aci = carry
        return sparse_ssa_step(st, aci, bound, horizon)

    state, _ = jax.lax.fori_loop(0, n_steps, step,
                                 (state, resolve_carry(a)))
    x_out[...] = state.x
    t_out[...] = state.t
    dead_out[...] = state.dead.astype(jnp.int32)
    steps_out[...] = state.steps  # started at 0: already the delta
    ctr_out[...] = state.ctr
    ctrhi_out[...] = state.ctr_hi


@partial(jax.jit, static_argnames=("n_steps", "max_c", "d", "k",
                                   "packed_rates", "interpret"))
def sparse_window_call(x, t, dead, key, ctr, ctr_hi, idx_pad, coef_pad,
                       int_tab, flt_tab, rates_pad, horizon,
                       *, n_steps: int, max_c: int, d: int, k: int,
                       packed_rates: bool,
                       interpret: bool | None = None):
    """Run up to n_steps sparse SSA events per lane toward `horizon`.

    idx_pad/coef_pad (R+1, M) i32 as in
    `gillespie.sparse_system_tensors` (seed only); int_tab
    (R+1, D+K+K·M) i32 and flt_tab (R+1, D+K·M[+K]) f32 from
    `gillespie.bind_sparse_step` (`packed_rates` = its rates2d was
    None); rates_pad (B, R+1) or (R+1,) f32 (`gillespie.pad_rates`).
    Returns (x, t, dead, steps_delta, ctr, ctr_hi) — bitwise identical
    to iterating the host `sparse_ssa_step`, which is itself bitwise
    identical to the dense path.
    """
    interpret = resolve_interpret(interpret)
    b, s = x.shape
    r1, m = idx_pad.shape
    r = r1 - 1
    wi = int_tab.shape[1]
    wf = flt_tab.shape[1]
    if rates_pad.ndim == 1:
        rates_pad = jnp.broadcast_to(rates_pad, (b, r1))
    bl = min(LANE_BLK, b)
    grid = (pl.cdiv(b, bl),)
    part = species_partition(bl, r)
    horizon_arr = jnp.asarray([horizon], jnp.float32)
    kernel = partial(_sparse_window_kernel, n_steps=n_steps, max_c=max_c,
                     d=d, k=k, part=part, packed_rates=packed_rates)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bl, s), lambda i: (i, 0)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((bl, 2), lambda i: (i, 0)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((r1, m), lambda i: (0, 0)),
            pl.BlockSpec((r1, m), lambda i: (0, 0)),
            pl.BlockSpec((r1, wi), lambda i: (0, 0)),
            pl.BlockSpec((r1, wf), lambda i: (0, 0)),
            pl.BlockSpec((bl, r1), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bl, s), lambda i: (i, 0)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((bl,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.uint32),
            jax.ShapeDtypeStruct((b,), jnp.uint32),
        ],
        interpret=interpret,
    )(x, t, dead, key, ctr, ctr_hi, idx_pad, coef_pad, int_tab,
      flt_tab, rates_pad, horizon_arr)


def _sparse_tau_window_kernel(x_ref, t_ref, dead_ref, noleap_ref,
                              key_ref, ctr_ref, ctrhi_ref, idx_ref,
                              coef_ref, delta_ref, rates_ref, gi_ref,
                              rmask_ref, horizon_ref,
                              x_out, t_out, dead_out, steps_out,
                              leaps_out, ctr_out, ctrhi_out,
                              n_steps: int, eps: float, fallback: float,
                              max_c: int):
    """`_tau_window_kernel` with the gather-form Match: reactant tables
    (R, M) in VMEM instead of (M, S, R) one-hots, comb unroll bounded
    by the system's actual max coefficient. Leap bookkeeping
    (mu/sig2/dx) keeps the dense delta matmuls — those sums must stay
    in dense association order to preserve bits."""
    x = x_ref[...].astype(jnp.float32)
    t = t_ref[...]
    dead = dead_ref[...] > 0
    fb = jnp.where(noleap_ref[...] > 0, jnp.float32(jnp.inf),
                   jnp.float32(fallback))
    k0 = key_ref[:, 0]
    k1 = key_ref[:, 1]
    ctr = ctr_ref[...]
    ctr_hi = ctrhi_ref[...]
    horizon = horizon_ref[0]
    steps = jnp.zeros_like(t, jnp.int32)
    leaps = jnp.zeros_like(t, jnp.int32)
    gm = (idx_ref[...], coef_ref[...], max_c)

    def step(i, carry):
        x, t, dead, ctr, ctr_hi, steps, leaps = carry
        x, t, dead, ctr, ctr_hi, steps, leaps = tau_step_core(
            x, t, dead, k0, k1, ctr, ctr_hi, steps, leaps,
            None, None, delta_ref[...], rates_ref[...],
            gi_ref[...], rmask_ref[...], horizon,
            eps=eps, fallback=fb, gather_match=gm)
        return x, t, dead, ctr, ctr_hi, steps, leaps

    x, t, dead, ctr, ctr_hi, steps, leaps = jax.lax.fori_loop(
        0, n_steps, step, (x, t, dead, ctr, ctr_hi, steps, leaps))
    x_out[...] = x
    t_out[...] = t
    dead_out[...] = dead.astype(jnp.int32)
    steps_out[...] = steps
    leaps_out[...] = leaps
    ctr_out[...] = ctr
    ctrhi_out[...] = ctr_hi


@partial(jax.jit, static_argnames=("n_steps", "eps", "fallback", "max_c",
                                   "interpret"))
def sparse_tau_window_call(x, t, dead, no_leap, key, ctr, ctr_hi, idx,
                           coef, delta, rates, gi, rmask, horizon, *,
                           n_steps: int, eps: float, fallback: float,
                           max_c: int, interpret: bool | None = None):
    """`tau_window_call` with gather-form Match (sparse seam): idx/coef
    are the (R, M) int32 reactant tables (NOT one-hots); everything
    else as the dense call. Bitwise identical to it — a real slot
    gathers the population the one-hot dot accumulates exactly, and
    pad slots contribute factor 1.0 on both forms."""
    interpret = resolve_interpret(interpret)
    b, s = x.shape
    r, m = idx.shape
    if rates.ndim == 1:
        rates = jnp.broadcast_to(rates, (b, r))
    bl = min(LANE_BLK, b)
    grid = (pl.cdiv(b, bl),)
    horizon_arr = jnp.asarray([horizon], jnp.float32)
    kernel = partial(_sparse_tau_window_kernel, n_steps=n_steps, eps=eps,
                     fallback=fallback, max_c=max_c)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bl, s), lambda i: (i, 0)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((bl, 2), lambda i: (i, 0)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((r, m), lambda i: (0, 0)),
            pl.BlockSpec((r, m), lambda i: (0, 0)),
            pl.BlockSpec((r, s), lambda i: (0, 0)),
            pl.BlockSpec((bl, r), lambda i: (i, 0)),
            pl.BlockSpec((gi.shape[0], s), lambda i: (0, 0)),
            pl.BlockSpec((s,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bl, s), lambda i: (i, 0)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((bl,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.uint32),
            jax.ShapeDtypeStruct((b,), jnp.uint32),
        ],
        interpret=interpret,
    )(x, t, dead, no_leap, key, ctr, ctr_hi, idx, coef, delta, rates,
      gi, rmask, horizon_arr)
