"""Pallas TPU flash attention (beyond-paper optimization, §Perf).

Not a paper hot-spot — the paper's contribution is the simulator — but
the roofline iteration (EXPERIMENTS.md §Perf) identified materialised
attention-score HBM traffic as the dominant memory term of the LM
train/prefill cells. This kernel keeps score tiles in VMEM: HBM traffic
becomes O(Q + K + V + O) per layer, the standard flash behaviour.

Three kernels with shared tiling (grid over (batch, q-head, q-block)):
  * forward — online softmax, saves logsumexp L per row;
  * dq — recomputes P from (q, k, L), accumulates dq over kv blocks;
  * dkv — recomputes P per q block, accumulates (dk, dv) over q blocks
    (grid over kv blocks).

GQA is handled in the index maps (kv block index = head // group) — no
materialised head expansion. Causal masking is applied per tile.
`jax.custom_vjp` wires fwd/bwd; oracle = models.attention.full_attention
under `jax.grad` (tests sweep shapes/dtypes in interpret mode).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 512
DEFAULT_BLOCK_K = 512
NEG_INF = -1e30


def _causal_mask(qi, ki, bq, bk):
    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    return qpos >= kpos


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, *, bq, bk, nk, causal,
                scale):
    qi = pl.program_id(2)
    q = q_ref[0, :, 0, :].astype(jnp.float32) * scale  # (bq, hd)
    m = jnp.full((bq,), NEG_INF, jnp.float32)
    l = jnp.zeros((bq,), jnp.float32)
    acc = jnp.zeros((bq, q_ref.shape[-1]), jnp.float32)

    kv_hi = ((qi + 1) * bq + bk - 1) // bk if causal else nk

    def body(ki, carry):
        m, l, acc = carry
        k = k_ref[0, pl.dslice(ki * bk, bk), 0, :].astype(jnp.float32)
        v = v_ref[0, pl.dslice(ki * bk, bk), 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            s = jnp.where(_causal_mask(qi, ki, bq, bk), s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=1)
        acc_new = acc * alpha[:, None] + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, kv_hi, body, (m, l, acc))
    l_safe = jnp.maximum(l, 1e-30)
    o_ref[0, :, 0, :] = (acc / l_safe[:, None]).astype(o_ref.dtype)
    lse_ref[0, 0, :] = m + jnp.log(l_safe)


# ---------------------------------------------------------------------------
# backward: dq (grid over q blocks), dkv (grid over kv blocks)
# ---------------------------------------------------------------------------


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
               *, bq, bk, nk, causal, scale):
    qi = pl.program_id(2)
    q = q_ref[0, :, 0, :].astype(jnp.float32)
    do = do_ref[0, :, 0, :].astype(jnp.float32)
    lse = lse_ref[0, 0, pl.dslice(qi * bq, bq)]
    delta = delta_ref[0, 0, pl.dslice(qi * bq, bq)]
    dq = jnp.zeros_like(q)

    kv_hi = ((qi + 1) * bq + bk - 1) // bk if causal else nk

    def body(ki, dq):
        k = k_ref[0, pl.dslice(ki * bk, bk), 0, :].astype(jnp.float32)
        v = v_ref[0, pl.dslice(ki * bk, bk), 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q * scale, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            s = jnp.where(_causal_mask(qi, ki, bq, bk), s, NEG_INF)
        p = jnp.exp(s - lse[:, None])
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * scale
        return dq + jax.lax.dot(ds.astype(k.dtype), k,
                                preferred_element_type=jnp.float32)

    dq = jax.lax.fori_loop(0, kv_hi, body, dq)
    dq_ref[0, :, 0, :] = dq.astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, *, bq, bk, nq, causal, scale, group):
    ki = pl.program_id(2)
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    dk = jnp.zeros_like(k)
    dv = jnp.zeros_like(v)

    q_lo = 0 if not causal else (ki * bk) // bq

    def body(qi, carry):
        dk, dv = carry
        q = q_ref[0, pl.dslice(qi * bq, bq), 0, :].astype(jnp.float32)
        do = do_ref[0, pl.dslice(qi * bq, bq), 0, :].astype(jnp.float32)
        lse = lse_ref[0, 0, pl.dslice(qi * bq, bq)]
        delta = delta_ref[0, 0, pl.dslice(qi * bq, bq)]
        s = jax.lax.dot_general(q * scale, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            s = jnp.where(_causal_mask(qi, ki, bq, bk), s, NEG_INF)
        p = jnp.exp(s - lse[:, None])  # (bq, bk)
        dv = dv + jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())),
                                      preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * scale  # (bq, bk)
        dk = dk + jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())),
                                      preferred_element_type=jnp.float32)
        return dk, dv

    dk, dv = jax.lax.fori_loop(q_lo, nq, body, (dk, dv))
    dk_ref[0, :, 0, :] = dk.astype(dk_ref.dtype)
    dv_ref[0, :, 0, :] = dv.astype(dv_ref.dtype)


# ---------------------------------------------------------------------------
# pallas_call wrappers + custom_vjp
# ---------------------------------------------------------------------------


def _specs(b, s, h, hd, bq, group):
    """Forward/backward shared BlockSpecs. Grid: (B, H, q-blocks)."""
    q_spec = pl.BlockSpec((1, bq, 1, hd), lambda bi, hi, qi: (bi, qi, hi, 0))
    kv_spec = pl.BlockSpec((1, s, 1, hd),
                           lambda bi, hi, qi: (bi, 0, hi // group, 0))
    lse_spec = pl.BlockSpec((1, 1, bq), lambda bi, hi, qi: (bi, hi, qi))
    return q_spec, kv_spec, lse_spec


def _flash_fwd(q, k, v, causal, bq, bk, interpret):
    b, s, h, hd = q.shape
    skv = k.shape[1]
    kv_heads = k.shape[2]
    group = h // kv_heads
    bq = min(bq, s)
    bk = min(bk, skv)
    nq, nk = pl.cdiv(s, bq), pl.cdiv(skv, bk)
    scale = hd ** -0.5
    q_spec, kv_spec, lse_spec = _specs(b, s, h, hd, bq, group)
    kernel = functools.partial(_fwd_kernel, bq=bq, bk=bk, nk=nk,
                               causal=causal, scale=scale)
    o, lse = pl.pallas_call(
        kernel,
        grid=(b, h, nq),
        in_specs=[q_spec, kv_spec, kv_spec],
        out_specs=[q_spec, lse_spec],
        out_shape=[jax.ShapeDtypeStruct(q.shape, q.dtype),
                   jax.ShapeDtypeStruct((b, h, s), jnp.float32)],
        interpret=interpret,
    )(q, k, v)
    return o, lse


def _flash_bwd(q, k, v, o, lse, do, causal, bq, bk, interpret):
    b, s, h, hd = q.shape
    skv = k.shape[1]
    kv_heads = k.shape[2]
    group = h // kv_heads
    bq = min(bq, s)
    bk = min(bk, skv)
    nq, nk = pl.cdiv(s, bq), pl.cdiv(skv, bk)
    scale = hd ** -0.5
    delta = jnp.einsum("bshd,bshd->bhs", o.astype(jnp.float32),
                       do.astype(jnp.float32))
    q_spec, kv_spec, lse_spec = _specs(b, s, h, hd, bq, group)

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, bq=bq, bk=bk, nk=nk, causal=causal,
                          scale=scale),
        grid=(b, h, nq),
        in_specs=[q_spec, kv_spec, kv_spec, q_spec,
                  pl.BlockSpec((1, 1, s), lambda bi, hi, qi: (bi, hi, 0)),
                  pl.BlockSpec((1, 1, s), lambda bi, hi, qi: (bi, hi, 0))],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    # dkv: grid over kv blocks; one q-head per program accumulates into
    # its kv head's gradient — sum over the group outside.
    kv_blk = pl.BlockSpec((1, bk, 1, hd), lambda bi, hi, ki: (bi, ki, hi // group, 0))
    full_q = pl.BlockSpec((1, s, 1, hd), lambda bi, hi, ki: (bi, 0, hi, 0))
    full_lse = pl.BlockSpec((1, 1, s), lambda bi, hi, ki: (bi, hi, 0))
    dk_h, dv_h = pl.pallas_call(
        functools.partial(_dkv_kernel, bq=bq, bk=bk, nq=nq, causal=causal,
                          scale=scale, group=group),
        grid=(b, h, nk),
        in_specs=[full_q, kv_blk, kv_blk, full_q, full_lse, full_lse],
        out_specs=[
            pl.BlockSpec((1, bk, 1, hd), lambda bi, hi, ki: (bi, ki, hi, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda bi, hi, ki: (bi, ki, hi, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((b, skv, h, hd), jnp.float32),
                   jax.ShapeDtypeStruct((b, skv, h, hd), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    dk = dk_h.reshape(b, skv, kv_heads, group, hd).sum(3).astype(k.dtype)
    dv = dv_h.reshape(b, skv, kv_heads, group, hd).sum(3).astype(v.dtype)
    return dq, dk, dv


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q, k, v, causal: bool = True,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False):
    """q: (B,S,H,hd); k/v: (B,Skv,KV,hd) with H % KV == 0. Returns (B,S,H,hd)."""
    o, _ = _flash_fwd(q, k, v, causal, block_q, block_k, interpret)
    return o


def _vjp_fwd(q, k, v, causal, block_q, block_k, interpret):
    o, lse = _flash_fwd(q, k, v, causal, block_q, block_k, interpret)
    return o, (q, k, v, o, lse)


def _vjp_bwd(causal, block_q, block_k, interpret, res, do):
    q, k, v, o, lse = res
    dq, dk, dv = _flash_bwd(q, k, v, o, lse, do, causal, block_q, block_k,
                            interpret)
    return dq, dk, dv


flash_attention.defvjp(_vjp_fwd, _vjp_bwd)


def attention_flops(b, s, h, hd, causal: bool, train: bool) -> float:
    """Analytic FLOPs for the roofline compute term (pallas custom calls
    report zero flops in cost_analysis). fwd = 4·B·H·S²·hd (QKᵀ + PV),
    halved when causal; bwd ≈ 2.5x fwd (recompute + 3 grad matmuls)."""
    fwd = 4.0 * b * h * s * s * hd * (0.5 if causal else 1.0)
    return fwd * (3.5 if train else 1.0)
