"""Pure-jnp oracles for the Pallas kernels (bitwise comparable)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.reactions import MAX_REACTANTS, propensities
from repro.core.stream import counter_uniforms, ctr_add


def propensity_ref(x, idx, coef, rates):
    """Gather-based propensities — oracle for kernels/propensity.py."""
    if rates.ndim == 1:
        rates = jnp.broadcast_to(rates, (x.shape[0], rates.shape[0]))
    return propensities(x, idx, coef, rates)


def ssa_window_ref(x, t, dead, key, ctr, ctr_hi, idx, coef, delta, rates,
                   horizon, n_steps: int):
    """Consume the same counter-based (key, ctr) stream as the fused
    kernel — oracle for kernels/ssa_step.py.
    Returns (x, t, dead, steps, ctr, ctr_hi)."""
    b = x.shape[0]
    if rates.ndim == 1:
        rates = jnp.broadcast_to(rates, (b, rates.shape[0]))
    dead = dead.astype(bool)
    steps = jnp.zeros((b,), jnp.int32)
    k0, k1 = key[:, 0], key[:, 1]

    def step(i, carry):
        x, t, dead, steps, ctr, ctr_hi = carry
        active = (t < horizon) & ~dead
        a = propensities(x, idx, coef, rates)
        a0 = a.sum(axis=1)
        now_dead = a0 <= 0.0
        u1, u2 = counter_uniforms(k0, k1, ctr, ctr_hi)
        tau = -jnp.log(u1) / jnp.maximum(a0, 1e-30)
        t_next = t + tau
        fire = active & ~now_dead & (t_next <= horizon)
        cum = jnp.cumsum(a, axis=1)
        j = jnp.argmax(cum >= (u2 * a0)[:, None], axis=1)
        x = jnp.where(fire[:, None], x + delta[j], x)
        t = jnp.where(fire, t_next, jnp.where(active, horizon, t))
        dead = dead | (active & now_dead)
        steps = steps + fire.astype(jnp.int32)
        ctr, ctr_hi = ctr_add(ctr, ctr_hi, active.astype(jnp.uint32))
        return x, t, dead, steps, ctr, ctr_hi

    x, t, dead, steps, ctr, ctr_hi = jax.lax.fori_loop(
        0, n_steps, step, (x, t, dead, steps, ctr, ctr_hi))
    return x, t, dead.astype(jnp.int32), steps, ctr, ctr_hi
