"""Pure-jnp oracles for the Pallas kernels (bitwise comparable)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.reactions import MAX_REACTANTS, propensities


def propensity_ref(x, idx, coef, rates):
    """Gather-based propensities — oracle for kernels/propensity.py."""
    if rates.ndim == 1:
        rates = jnp.broadcast_to(rates, (x.shape[0], rates.shape[0]))
    return propensities(x, idx, coef, rates)


def ssa_window_ref(x, t, dead, uniforms, idx, coef, delta, rates, horizon,
                   n_steps: int):
    """Consume the same uniform stream as the fused kernel — oracle for
    kernels/ssa_step.py. Returns (x, t, dead, steps)."""
    b = x.shape[0]
    if rates.ndim == 1:
        rates = jnp.broadcast_to(rates, (b, rates.shape[0]))
    dead = dead.astype(bool)
    steps = jnp.zeros((b,), jnp.int32)

    def step(i, carry):
        x, t, dead, steps = carry
        active = (t < horizon) & ~dead
        a = propensities(x, idx, coef, rates)
        a0 = a.sum(axis=1)
        now_dead = a0 <= 0.0
        u1 = uniforms[:, i, 0]
        u2 = uniforms[:, i, 1]
        tau = -jnp.log(u1) / jnp.maximum(a0, 1e-30)
        t_next = t + tau
        fire = active & ~now_dead & (t_next <= horizon)
        cum = jnp.cumsum(a, axis=1)
        j = jnp.argmax(cum >= (u2 * a0)[:, None], axis=1)
        x = jnp.where(fire[:, None], x + delta[j], x)
        t = jnp.where(fire, t_next, jnp.where(active, horizon, t))
        dead = dead | (active & now_dead)
        steps = steps + fire.astype(jnp.int32)
        return x, t, dead, steps

    x, t, dead, steps = jax.lax.fori_loop(0, n_steps, step,
                                          (x, t, dead, steps))
    return x, t, dead.astype(jnp.int32), steps
