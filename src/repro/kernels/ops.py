"""Jit'd wrappers around the Pallas kernels.

`interpret=True` on CPU (this container) executes the kernel bodies in
Python for correctness validation; on TPU the same `pallas_call`s
compile to Mosaic. `fused_window` integrates the fused SSA kernel with
the engine's LaneState, generating the SAME per-lane threefry uniform
stream the unfused path would consume, so both paths are bit-identical.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gillespie import LaneState
from repro.core.reactions import ReactionSystem
from repro.kernels.propensity import propensity_call, reactant_onehots
from repro.kernels.ssa_step import ssa_window_call

ON_TPU = jax.default_backend() == "tpu"
DEFAULT_CHUNK_STEPS = 256


def system_kernel_tensors(system: ReactionSystem):
    """(E, coef_f32, delta_f32) device tensors for the kernels."""
    e = jnp.asarray(reactant_onehots(system))
    coef = jnp.asarray(system.reactant_coef.T, jnp.float32)  # (M, R)
    delta = jnp.asarray(system.delta, jnp.float32)
    return e, coef, delta


def propensity(x, system_tensors_k, rates, interpret: bool | None = None):
    e, coef, _ = system_tensors_k
    interp = (not ON_TPU) if interpret is None else interpret
    return propensity_call(x, e, coef, rates, interpret=interp)


@partial(jax.jit, static_argnames=("n",))
def _draw_uniform_stream(key, n: int):
    """(B,2) uint32 keys -> (new_keys, uniforms (B, n, 2)) matching the
    unfused gillespie._uniforms consumption order."""

    def one_lane(k):
        def body(k, _):
            kk = jax.random.wrap_key_data(k, impl="threefry2x32")
            k1, k2 = jax.random.split(kk)
            u = jax.random.uniform(k2, (2,), jnp.float32, 1e-12, 1.0)
            return jax.random.key_data(k1), u

        return jax.lax.scan(body, k, None, length=n)

    new_key, us = jax.vmap(one_lane)(key)
    return new_key, us


class FusedWindowOut(NamedTuple):
    """fused_window result + the telemetry its host-driven chunk loop
    accrues (threaded back into the engine's counters).

    n_dispatches: device launches — two per executed chunk (the uniform
    stream draw and the fused kernel call).
    n_host_syncs: blocking device->host pulls — one per `bool(...)`
    continuation check, including the final check that ends the loop.
    """

    state: LaneState
    n_dispatches: int
    n_host_syncs: int


def fused_window(pool: LaneState, tensors, horizon,
                 chunk_steps: int = DEFAULT_CHUNK_STEPS,
                 interpret: bool | None = None,
                 max_chunks: int = 64) -> FusedWindowOut:
    """Advance every lane to `horizon` using the fused kernel.

    tensors: (idx, coef, delta, rates) as in gillespie.system_tensors —
    converted to kernel form here. Chunks of `chunk_steps` fused events
    run back-to-back until all lanes cross the horizon.
    """
    idx, coef_rm, delta_f, rates = tensors
    s = pool.x.shape[1]
    r = delta_f.shape[0]
    # build one-hots from (idx, coef) — same info, MXU layout
    m = idx.shape[1]
    e = jnp.zeros((m, s + 1, r), jnp.float32).at[
        jnp.arange(m)[:, None], idx.T, jnp.arange(r)[None, :]].set(
        (coef_rm.T > 0).astype(jnp.float32))[:, :s, :]
    coef_k = jnp.asarray(coef_rm.T, jnp.float32)
    interp = (not ON_TPU) if interpret is None else interpret

    x, t, dead = pool.x, pool.t, pool.dead.astype(jnp.int32)
    key = pool.key
    steps_total = pool.steps
    n_dispatches = 0
    n_host_syncs = 0
    for _ in range(max_chunks):
        n_host_syncs += 1  # the bool() below blocks on the device
        if not bool(jnp.any((t < horizon) & (dead == 0))):
            break
        key, uniforms = _draw_uniform_stream(key, chunk_steps)
        x, t, dead, steps = ssa_window_call(
            x, t, dead, uniforms, e, coef_k, delta_f, rates, horizon,
            n_steps=chunk_steps, interpret=interp)
        n_dispatches += 2
        steps_total = steps_total + steps
        # NOTE on determinism: within a window the kernel consumes the
        # identical uniform stream as the unfused path (bitwise-equal
        # trajectories, tested). Across windows the key advances by
        # chunk_steps splits regardless of how many draws were used, so
        # kernel-vs-unfused parity across windows is distributional, not
        # bitwise (both exact SSA; memorylessness makes redraws valid).
    t = jnp.where(dead > 0, jnp.maximum(t, horizon), t)
    return FusedWindowOut(
        state=LaneState(x=x, t=t, key=key, steps=steps_total,
                        dead=dead > 0),
        n_dispatches=n_dispatches, n_host_syncs=n_host_syncs)
