"""Jit'd wrappers around the Pallas kernels.

`interpret=True` on CPU (this container) executes the kernel bodies in
Python for correctness validation; on TPU the same `pallas_call`s
compile to Mosaic. `fused_window` advances a lane pool one whole
sim-time window as ONE device dispatch: a device-side `lax.while_loop`
runs back-to-back `chunk_steps`-event kernel launches until every lane
crosses the horizon, with the continuation predicate computed on
device. There is no uniform-stream operand and no host round trip —
the kernel draws its randomness in VREGs from the counter-based
per-lane stream (`core/stream.counter_uniforms`), the SAME stream the
unfused `gillespie.ssa_step` consumes, so kernel↔unfused trajectories
are bitwise identical for any `chunk_steps`, across window boundaries,
and across shard counts.

Both chunk loops are plain traced `lax.while_loop`s with no host
dependence, so they nest unchanged under the superstep window scan
(`SimConfig.window_block` — dispatch strategies scan W windows of
this loop inside ONE dispatch, DESIGN.md §3e) as well as under
shard_map.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.gillespie import LaneState, pad_rates
from repro.core.reactions import ReactionSystem
from repro.core.tau_leap import onehot_tensors
from repro.kernels.propensity import (
    propensity_call,
    reactant_onehots,
    resolve_interpret,
)
from repro.kernels.ssa_step import ssa_window_call

ON_TPU = jax.default_backend() == "tpu"
DEFAULT_CHUNK_STEPS = 256
DEFAULT_MAX_CHUNKS = 64


class FusedWindowTruncated(RuntimeError):
    """A fused window hit its chunk budget with live lanes still below
    the horizon — results past the truncation point would silently be a
    partial window. Raise `max_chunks`/`chunk_steps` (engine:
    `SimConfig.kernel_max_chunks`/`kernel_chunk_steps`) or shrink the
    window."""


def system_kernel_tensors(system: ReactionSystem):
    """(E, coef_f32, delta_f32) device tensors for the kernels."""
    e = jnp.asarray(reactant_onehots(system))
    coef = jnp.asarray(system.reactant_coef.T, jnp.float32)  # (M, R)
    delta = jnp.asarray(system.delta, jnp.float32)
    return e, coef, delta


def propensity(x, system_tensors_k, rates, interpret: bool | None = None):
    e, coef, _ = system_tensors_k
    return propensity_call(x, e, coef, rates,
                           interpret=resolve_interpret(interpret))


class FusedWindowOut(NamedTuple):
    """fused_window result + single-launch telemetry.

    The chunk loop runs on device, so there are no host-side dispatch/
    sync counters any more — a window is ONE dispatch and ZERO
    mid-window host syncs by construction. What remains:

    n_chunks: int32 scalar (device) — kernel chunk iterations the
    while_loop executed.
    truncated: bool scalar (device) — True iff the `max_chunks` budget
    ran out with live lanes still below the horizon, i.e. the returned
    state is a PARTIAL window. Callers must surface this (the engine
    raises `FusedWindowTruncated`); it was previously silent.
    """

    state: LaneState
    n_chunks: jax.Array
    truncated: jax.Array


def window_chunk_loop(pool: LaneState, tensors, horizon,
                      chunk_steps: int = DEFAULT_CHUNK_STEPS,
                      interpret: bool | None = None,
                      max_chunks: int = DEFAULT_MAX_CHUNKS
                      ) -> FusedWindowOut:
    """Traceable core of `fused_window` (no jit wrapper of its own).

    Exposed separately so the sharded dispatch can run it per shard
    inside `shard_map` and the engine's fused dispatch can fuse it with
    device-side observable extraction in one jitted step.

    tensors: (idx, coef, delta, rates) as in gillespie.system_tensors —
    converted to kernel form here (traced, so it compiles away).
    """
    idx, coef_rm, delta_f, rates = tensors
    # build one-hots from (idx, coef) — same info, MXU layout
    e, coef_k = onehot_tensors(idx, coef_rm, pool.x.shape[1])
    interp = resolve_interpret(interpret)
    key = pool.key

    def chunk(x, t, dead, ctr, ctr_hi, horizon):
        x, t, dead, steps_d, ctr, ctr_hi = ssa_window_call(
            x, t, dead, key, ctr, ctr_hi, e, coef_k, delta_f, rates,
            horizon, n_steps=chunk_steps, interpret=interp)
        return x, t, dead, steps_d, jnp.zeros_like(steps_d), ctr, ctr_hi

    return _chunk_while(pool, horizon, chunk, max_chunks)


def tau_window_chunk_loop(pool: LaneState, tensors, horizon, gi, rmask,
                          eps: float, fallback: float,
                          chunk_steps: int = DEFAULT_CHUNK_STEPS,
                          interpret: bool | None = None,
                          max_chunks: int = DEFAULT_MAX_CHUNKS
                          ) -> FusedWindowOut:
    """`window_chunk_loop`, but each chunk is the fused tau-leap kernel
    (`tau_window_call`) — up to chunk_steps leap-or-fallback iterations
    per launch, the whole window still ONE device dispatch. gi/rmask:
    device tensors from `core.tau_leap.gi_tables`/`reactant_mask`.
    Same chunk budget + truncation flag semantics as the exact loop."""
    from repro.kernels.ssa_step import tau_window_call

    idx, coef_rm, delta_f, rates = tensors
    e, coef_k = onehot_tensors(idx, coef_rm, pool.x.shape[1])
    interp = resolve_interpret(interpret)
    key = pool.key
    # steering's per-lane exact<->tau switch rides as a (B,) operand;
    # the kernel never writes it, so it is closed over (not carried)
    no_leap = pool.no_leap.astype(jnp.int32)

    def chunk(x, t, dead, ctr, ctr_hi, horizon):
        return tau_window_call(
            x, t, dead, no_leap, key, ctr, ctr_hi, e, coef_k, delta_f,
            rates, gi, rmask, horizon, n_steps=chunk_steps, eps=eps,
            fallback=fallback, interpret=interp)

    return _chunk_while(pool, horizon, chunk, max_chunks)


def sparse_window_chunk_loop(pool: LaneState, tensors, horizon, *,
                             sp, chunk_steps: int = DEFAULT_CHUNK_STEPS,
                             interpret: bool | None = None,
                             max_chunks: int = DEFAULT_MAX_CHUNKS
                             ) -> FusedWindowOut:
    """`window_chunk_loop` through the SPARSE exact kernel
    (`kernels.ssa_step.sparse_window_call`): dependency-graph propensity
    updates inside the kernel, only the O(R·(M+K+D)) sparse tables in
    VMEM. `sp` is the `gillespie.sparse_system_tensors` tuple (bound in
    by the engine); of `tensors` only the rates slot is used — the
    dense idx/coef/delta are not materialised on this path. Same chunk
    budget, truncation and stream semantics as the dense loop, and the
    trajectories are bitwise identical to it."""
    from repro.core.gillespie import bind_sparse_step
    from repro.kernels.ssa_step import sparse_window_call

    rates = tensors[3]
    idx_pad, coef_pad = sp[0], sp[1]
    interp = resolve_interpret(interpret)
    key = pool.key
    rates_pad = pad_rates(rates)
    # pack the per-reaction recipe rows ONCE per window launch — the
    # kernel then pays two row gathers per event (see bind_sparse_step)
    int_tab, flt_tab, rates2d, max_c, d, k, m = bind_sparse_step(
        sp, rates)

    def chunk(x, t, dead, ctr, ctr_hi, horizon):
        x, t, dead, steps_d, ctr, ctr_hi = sparse_window_call(
            x, t, dead, key, ctr, ctr_hi, idx_pad, coef_pad, int_tab,
            flt_tab, rates_pad, horizon, n_steps=chunk_steps,
            max_c=max_c, d=d, k=k, packed_rates=rates2d is None,
            interpret=interp)
        return x, t, dead, steps_d, jnp.zeros_like(steps_d), ctr, ctr_hi

    return _chunk_while(pool, horizon, chunk, max_chunks)


def sparse_tau_window_chunk_loop(pool: LaneState, tensors, horizon, gi,
                                 rmask, eps: float, fallback: float, *,
                                 max_c: int,
                                 chunk_steps: int = DEFAULT_CHUNK_STEPS,
                                 interpret: bool | None = None,
                                 max_chunks: int = DEFAULT_MAX_CHUNKS
                                 ) -> FusedWindowOut:
    """`tau_window_chunk_loop` with the gather-form Match kernel
    (`sparse_tau_window_call`): no (M, S, R) one-hot operands, comb
    unroll bounded by the system's actual max coefficient (the sparse
    seam's MAX_COEF lift). Bitwise identical to the dense tau loop."""
    from repro.kernels.ssa_step import sparse_tau_window_call

    idx, coef_rm, delta_f, rates = tensors
    interp = resolve_interpret(interpret)
    key = pool.key
    no_leap = pool.no_leap.astype(jnp.int32)

    def chunk(x, t, dead, ctr, ctr_hi, horizon):
        return sparse_tau_window_call(
            x, t, dead, no_leap, key, ctr, ctr_hi, idx, coef_rm, delta_f,
            rates, gi, rmask, horizon, n_steps=chunk_steps, eps=eps,
            fallback=fallback, max_c=max_c, interpret=interp)

    return _chunk_while(pool, horizon, chunk, max_chunks)


def _chunk_while(pool: LaneState, horizon, chunk, max_chunks: int
                 ) -> FusedWindowOut:
    """Shared device-side chunk loop: run `chunk` kernel launches
    back-to-back in a `lax.while_loop` until every lane crosses the
    horizon or the budget runs out. `chunk(x, t, dead, ctr, ctr_hi,
    horizon) -> (x, t, dead, steps_delta, leaps_delta, ctr, ctr_hi)`
    is the per-method fused kernel call (exact or tau-leap — the one
    place their chunk-budget/truncation semantics are defined)."""
    horizon = jnp.asarray(horizon, jnp.float32)

    def live(t, dead):
        return (t < horizon) & (dead == 0)

    def cond(carry):
        x, t, dead, ctr, ctr_hi, steps, leaps, n = carry
        return (n < max_chunks) & jnp.any(live(t, dead))

    def body(carry):
        x, t, dead, ctr, ctr_hi, steps, leaps, n = carry
        x, t, dead, steps_d, leaps_d, ctr, ctr_hi = chunk(
            x, t, dead, ctr, ctr_hi, horizon)
        return (x, t, dead, ctr, ctr_hi, steps + steps_d,
                leaps + leaps_d, n + 1)

    x, t, dead, ctr, ctr_hi, steps, leaps, n_chunks = jax.lax.while_loop(
        cond, body, (pool.x, pool.t, pool.dead.astype(jnp.int32),
                     pool.ctr, pool.ctr_hi, pool.steps, pool.leaps,
                     jnp.int32(0)))
    truncated = jnp.any(live(t, dead))
    t = jnp.where(dead > 0, jnp.maximum(t, horizon), t)
    state = LaneState(x=x, t=t, key=pool.key, ctr=ctr, ctr_hi=ctr_hi,
                      steps=steps, leaps=leaps, dead=dead > 0,
                      no_leap=pool.no_leap)
    return FusedWindowOut(state=state, n_chunks=n_chunks,
                          truncated=truncated)


@partial(jax.jit,
         static_argnames=("chunk_steps", "interpret", "max_chunks"),
         donate_argnums=(0,))
def fused_window(pool: LaneState, tensors, horizon,
                 chunk_steps: int = DEFAULT_CHUNK_STEPS,
                 interpret: bool | None = None,
                 max_chunks: int = DEFAULT_MAX_CHUNKS) -> FusedWindowOut:
    """Advance every lane to `horizon` using the fused kernel — one
    device dispatch for the whole window.

    The chunk loop is a device-side `lax.while_loop`; nothing is pulled
    to the host mid-window (check `.truncated` after the fact — a
    device scalar — to learn whether the `max_chunks` iteration bound
    cut a window short).
    """
    return window_chunk_loop(pool, tensors, horizon,
                             chunk_steps=chunk_steps, interpret=interpret,
                             max_chunks=max_chunks)
