"""Pallas TPU kernel: the Match phase as MXU matmuls.

The paper's `Match_Populations` (product of binomials over reactant
populations) was the SSE target in §5.1 — and gained ~nothing, because
2010-era AoS code could only vectorise *within* one instance. The TPU
adaptation flips the vector axis: lanes (instances) × reactions tiles,
and the population gather becomes a **one-hot matmul** so the MXU does
the Match:

    pops[m] = X @ E[m]        E[m]: (S, R) one-hot of reactant slot m
    A       = k · Π_m C(pops[m], coef[m])

Tiling: X block (LANE_BLK, S) resident in VMEM; reactions tiled by
R_BLK. All factors unrolled over MAX_REACTANTS (CWC rules are small).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.reactions import (
    MAX_COEF,
    MAX_REACTANTS,
    ReactionSystem,
    comb_factors,
)

LANE_BLK = 256
R_BLK = 256

# kernel bodies spell it _comb_factors; the implementation lives in
# core.reactions so kernel-free code (core/tau_leap.py) shares it
_comb_factors = comb_factors

#: backends whose Mosaic/Triton lowering we compile for — everything
#: else (CPU, METAL, ...) runs the kernel bodies in the interpreter
COMPILED_BACKENDS = ("tpu", "gpu", "cuda", "rocm")


def resolve_interpret(interpret: bool | None, backend: str | None = None
                      ) -> bool:
    """Resolve a kernel-call `interpret` argument: an explicit value
    wins; `None` auto-selects — compiled on TPU/GPU, interpreter
    elsewhere. Every kernel entry point defaults to None, so callers
    get the compiled path on accelerators WITHOUT opting in (the old
    `interpret=True` default silently pinned the interpreter).
    `backend` overrides `jax.default_backend()` (for tests)."""
    if interpret is not None:
        return interpret
    if backend is None:
        backend = jax.default_backend()
    return backend.lower() not in COMPILED_BACKENDS


def reactant_onehots(system: ReactionSystem) -> np.ndarray:
    """(M, S, R) one-hot matrices E[m][s, j] = 1 iff reactant slot m of
    reaction j is species s. Padding slots are all-zero columns."""
    m, s, r = MAX_REACTANTS, system.n_species, system.n_reactions
    e = np.zeros((m, s, r), np.float32)
    for j in range(r):
        for mm in range(m):
            idx = system.reactant_idx[j, mm]
            if system.reactant_coef[j, mm] > 0 and idx < s:
                e[mm, idx, j] = 1.0
    return e


def _propensity_kernel(x_ref, e_ref, coef_ref, rates_ref, out_ref):
    """One (lane-block × reaction-block) tile."""
    x = x_ref[...]  # (BL, S)
    a = jnp.ones((x.shape[0], coef_ref.shape[1]), jnp.float32)
    for m in range(MAX_REACTANTS):
        pops = jax.lax.dot(x, e_ref[m],
                           preferred_element_type=jnp.float32)  # (BL, Rb)
        coef = coef_ref[m]  # (Rb,)
        a = a * _comb_factors(pops, coef[None, :])
    out_ref[...] = a * rates_ref[...]


@partial(jax.jit, static_argnames=("interpret",))
def propensity_call(x, e, coef, rates, *, interpret: bool | None = None):
    """x: (B, S) f32; e: (M, S, R); coef: (M, R) f32; rates (B, R) or (R,).

    Returns (B, R) propensities. `interpret=None` auto-selects the
    compiled kernel on TPU/GPU (`resolve_interpret`).
    """
    interpret = resolve_interpret(interpret)
    b, s = x.shape
    r = e.shape[-1]
    if rates.ndim == 1:
        rates = jnp.broadcast_to(rates, (b, r))
    bl = min(LANE_BLK, b)
    rb = min(R_BLK, r)
    grid = (pl.cdiv(b, bl), pl.cdiv(r, rb))
    return pl.pallas_call(
        _propensity_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bl, s), lambda i, j: (i, 0)),
            pl.BlockSpec((MAX_REACTANTS, s, rb), lambda i, j: (0, 0, j)),
            pl.BlockSpec((MAX_REACTANTS, rb), lambda i, j: (0, j)),
            pl.BlockSpec((bl, rb), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bl, rb), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, r), jnp.float32),
        interpret=interpret,
    )(x, e, coef, rates)
