"""Sharded checkpoint store with async save and elastic restore.

Layout: <dir>/step_<n>/manifest.json + arrays.npz (flattened pytree
paths). Restore re-places every leaf with the CURRENT topology's
sharding — a checkpoint written on one mesh restores onto any other
(elastic rescale), because leaves are stored unsharded and resharded at
load. On a real multi-host pod each host would write its addressable
shards (the manifest layout already keys by leaf path); the single-
process container stores full arrays.

Integrity: every array file carries a checksum in the manifest;
`latest_step` only advances after a fsync'd manifest rename (crash
during save never corrupts the previous checkpoint).

Single-file engine checkpoints (the `.npz` snapshots
`SimulationEngine.checkpoint()` writes) are hardened here too:
`save_atomic` is write-temp-fsync-rename with an embedded magic tag and
a per-content sha256, `verify` loads with typed `CheckpointCorrupt`
errors naming the path and the detected failure (unreadable/truncated
archive, bad magic, checksum mismatch, missing key), and
`RetentionPolicy` + `list_checkpoints` give the supervisor's cadenced
checkpoint directory keep-last-K semantics (DESIGN.md §3h).
"""
from __future__ import annotations

import glob
import hashlib
import json
import os
import re
import shutil
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import numpy as np


class CheckpointCorrupt(Exception):
    """A checkpoint failed integrity verification. The message names
    the offending path and the detected failure mode (unreadable or
    truncated archive, bad magic, checksum mismatch, missing key)."""


# magic + format version embedded in every hardened engine checkpoint;
# files without it (pre-PR8 raw np.savez snapshots) still verify via
# the legacy branch so old checkpoints keep restoring
_CKPT_MAGIC = b"REPRO-CKPT-v1"
_MAGIC_KEY = "__ckpt_magic__"
_SHA_KEY = "__ckpt_sha256__"


def _with_npz(path: str) -> str:
    return path if path.endswith(".npz") else path + ".npz"


def _digest_arrays(arrays: dict) -> str:
    """Content digest over (key, dtype, shape, bytes) in sorted key
    order — independent of npz member ordering/compression details."""
    h = hashlib.sha256()
    for k in sorted(arrays):
        a = np.ascontiguousarray(arrays[k])
        h.update(k.encode())
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    return h.hexdigest()


def save_atomic(path: str, arrays: dict) -> str:
    """Atomic single-file checkpoint write: the payload (plus magic tag
    and content sha256) lands in a same-directory temp file, is
    fsync'd, then renamed over `path` — a crash mid-save never leaves
    a half-written file where a valid checkpoint (or nothing) should
    be. Returns the final path (with `.npz`)."""
    path = _with_npz(path)
    payload = {k: np.asarray(v) for k, v in arrays.items()}
    digest = _digest_arrays(payload)
    payload[_MAGIC_KEY] = np.frombuffer(_CKPT_MAGIC, np.uint8).copy()
    payload[_SHA_KEY] = np.frombuffer(digest.encode("ascii"),
                                      np.uint8).copy()
    tmp = f"{path}.tmp.{os.getpid()}"
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **payload)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
        # make the RENAME durable too: fsync'ing the file covers its
        # bytes, but the directory entry lives in the directory — a
        # power cut after replace() can otherwise resurface the old
        # file (or nothing) at `path`. Snapshot-served cadence saves
        # make checkpoints frequent and cheap, so recovery now leans on
        # the newest file actually existing after a crash.
        dfd = os.open(os.path.dirname(os.path.abspath(path)), os.O_RDONLY)
        try:
            os.fsync(dfd)
        except OSError:
            pass  # some filesystems reject directory fsync; best effort
        finally:
            os.close(dfd)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)
    return path


def verify(path: str, required: tuple = ()) -> dict:
    """Load + integrity-check a single-file checkpoint.

    Returns {key: np.ndarray} with the integrity keys stripped. Raises
    `CheckpointCorrupt` naming `path` and the failure: an unreadable or
    truncated archive, a bad magic tag, a content-checksum mismatch, or
    a missing required key. Files without the magic tag (pre-hardening
    raw np.savez snapshots) skip the checksum comparison but still get
    readability and required-key checks."""
    path = _with_npz(path)
    try:
        with np.load(path) as z:
            arrays = {k: np.asarray(z[k]) for k in z.files}
    except FileNotFoundError:
        raise
    except Exception as e:
        raise CheckpointCorrupt(
            f"checkpoint {path!r} is unreadable (truncated or not a "
            f"valid npz archive): {type(e).__name__}: {e}") from e
    if _MAGIC_KEY in arrays:
        magic = bytes(arrays.pop(_MAGIC_KEY).tobytes())
        if magic != _CKPT_MAGIC:
            raise CheckpointCorrupt(
                f"checkpoint {path!r} has a bad magic tag "
                f"({magic!r} != {_CKPT_MAGIC!r}) — not a repro "
                "checkpoint, or written by an incompatible version")
        if _SHA_KEY not in arrays:
            raise CheckpointCorrupt(
                f"checkpoint {path!r} carries a magic tag but no "
                "content checksum — partial or tampered write")
        stored = arrays.pop(_SHA_KEY).tobytes().decode("ascii", "replace")
        actual = _digest_arrays(arrays)
        if actual != stored:
            raise CheckpointCorrupt(
                f"checkpoint {path!r} failed verification: checksum "
                f"mismatch (stored {stored[:12]}…, content "
                f"{actual[:12]}…) — the file was corrupted after it "
                "was written")
    missing = [k for k in required if k not in arrays]
    if missing:
        raise CheckpointCorrupt(
            f"checkpoint {path!r} is missing required key(s) "
            f"{missing} — truncated save or foreign file")
    return arrays


# anchored: a namespaced file (`shard00__ckpt_...`) must NOT match the
# un-namespaced store, and vice versa — farm workers share one ckpt_dir
# and each store may only ever see (list, prune, restore) its own files
_CKPT_RE = re.compile(r"(?:([A-Za-z0-9][A-Za-z0-9.\-]*)__)?ckpt_(\d+)\.npz")
_NS_RE = re.compile(r"[A-Za-z0-9][A-Za-z0-9.\-]*")


def _check_namespace(namespace: str) -> str:
    if namespace and _NS_RE.fullmatch(namespace) is None:
        raise ValueError(
            f"checkpoint namespace {namespace!r} must match "
            "[A-Za-z0-9][A-Za-z0-9.-]* (no underscores: '__' separates "
            "the namespace from the checkpoint name)")
    return namespace


def checkpoint_name(window: int, namespace: str = "") -> str:
    """Canonical cadenced-checkpoint file name for a window boundary.
    With a `namespace` (one farm worker's store inside a shared
    ckpt_dir) the name is prefixed `<namespace>__`."""
    base = f"ckpt_{window:08d}.npz"
    return f"{_check_namespace(namespace)}__{base}" if namespace else base


def list_checkpoints(directory: str,
                     namespace: str = "") -> list[tuple[int, str]]:
    """[(window, path)] of cadenced checkpoints under `directory`
    belonging to `namespace` ("" = the un-namespaced store), sorted
    oldest -> newest. Foreign-namespace files and temp files from
    interrupted atomic saves are ignored (the match is anchored on the
    full basename, so partial `.npz.tmp.<pid>` leftovers never
    qualify)."""
    _check_namespace(namespace)
    out = []
    for p in glob.glob(os.path.join(directory, "*ckpt_*.npz")):
        m = _CKPT_RE.fullmatch(os.path.basename(p))
        if m and (m.group(1) or "") == namespace:
            out.append((int(m.group(2)), p))
    return sorted(out)


@dataclass(frozen=True)
class RetentionPolicy:
    """Keep the newest `keep_last` cadenced checkpoints; prune the
    rest oldest-first. keep_last >= 2 is what lets recovery fall back
    PAST a corrupt newest checkpoint (DESIGN.md §3h)."""

    keep_last: int = 3

    def validate(self) -> None:
        if self.keep_last < 1:
            raise ValueError(
                f"RetentionPolicy.keep_last must be >= 1, got "
                f"{self.keep_last}")

    def apply(self, directory: str, namespace: str = "") -> list[str]:
        """Prune beyond keep_last; returns the removed paths. Only
        files in `namespace` are counted or removed — coexisting
        stores in a shared directory never prune each other."""
        ckpts = list_checkpoints(directory, namespace)
        removed = []
        for _, p in ckpts[:max(0, len(ckpts) - self.keep_last)]:
            os.remove(p)
            removed.append(p)
        return removed


def _flatten(tree: Any) -> tuple[dict[str, np.ndarray], dict[str, str]]:
    """Returns (arrays, exotic-dtype map). Non-native dtypes (bf16, fp8)
    are stored as byte-width-matched uint views; the manifest records
    the real dtype for restore."""
    flat, dtypes = {}, {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V" or arr.dtype.name not in np.sctypeDict:
            dtypes[key] = arr.dtype.name
            arr = arr.view({1: np.uint8, 2: np.uint16, 4: np.uint32}[
                arr.dtype.itemsize])
        flat[key] = arr
    return flat, dtypes


def _unexotic(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    import ml_dtypes

    return arr.view(np.dtype(getattr(ml_dtypes, dtype_name)))


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save(tree: Any, directory: str, step: int) -> str:
    """Synchronous checkpoint write. Returns the checkpoint path."""
    tmp = os.path.join(directory, f".tmp_step_{step}")
    final = os.path.join(directory, f"step_{step}")
    os.makedirs(tmp, exist_ok=True)
    flat, dtypes = _flatten(tree)
    npz_path = os.path.join(tmp, "arrays.npz")
    np.savez(npz_path, **flat)
    digest = hashlib.sha256(open(npz_path, "rb").read()).hexdigest()
    manifest = {
        "step": step,
        "keys": sorted(flat),
        "dtypes": dtypes,
        "sha256": digest,
        "time": time.time(),
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_"):
            if os.path.exists(os.path.join(directory, name, "manifest.json")):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(template: Any, directory: str, step: Optional[int] = None,
            shardings: Any = None) -> Any:
    """Restore into the structure of `template`. With `shardings`
    (pytree of NamedSharding for the CURRENT mesh) each leaf is placed
    shard-by-shard — elastic across mesh changes."""
    if step is None:
        step = latest_step(directory)
        assert step is not None, f"no checkpoints under {directory}"
    path = os.path.join(directory, f"step_{step}")
    manifest = json.load(open(os.path.join(path, "manifest.json")))
    data = np.load(os.path.join(path, "arrays.npz"))
    digest = hashlib.sha256(
        open(os.path.join(path, "arrays.npz"), "rb").read()).hexdigest()
    if digest != manifest["sha256"]:
        raise CheckpointCorrupt(
            f"checkpoint {path!r} failed verification: arrays.npz "
            f"checksum mismatch vs manifest (stored "
            f"{manifest['sha256'][:12]}…, file {digest[:12]}…)")

    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(template)
    sh_leaves = (jax.tree.leaves(shardings) if shardings is not None
                 else [None] * len(leaves_p))
    out = []
    exotic = manifest.get("dtypes", {})
    for (pth, leaf), sh in zip(leaves_p, sh_leaves):
        key = "/".join(_path_str(p) for p in pth)
        arr = data[key]
        if key in exotic:
            arr = _unexotic(arr, exotic[key])
        if hasattr(leaf, "dtype") and arr.dtype != leaf.dtype:
            arr = arr.astype(leaf.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


class AsyncWriter:
    """Background checkpoint writer (one in flight; drops none)."""

    def __init__(self):
        self._thread: Optional[threading.Thread] = None
        self.last_path: Optional[str] = None

    def submit(self, tree: Any, directory: str, step: int) -> None:
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot off-device

        def work():
            self.last_path = save(host_tree, directory, step)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
