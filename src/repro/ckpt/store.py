"""Sharded checkpoint store with async save and elastic restore.

Layout: <dir>/step_<n>/manifest.json + arrays.npz (flattened pytree
paths). Restore re-places every leaf with the CURRENT topology's
sharding — a checkpoint written on one mesh restores onto any other
(elastic rescale), because leaves are stored unsharded and resharded at
load. On a real multi-host pod each host would write its addressable
shards (the manifest layout already keys by leaf path); the single-
process container stores full arrays.

Integrity: every array file carries a checksum in the manifest;
`latest_step` only advances after a fsync'd manifest rename (crash
during save never corrupts the previous checkpoint).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from typing import Any, Callable, Optional

import jax
import numpy as np


def _flatten(tree: Any) -> tuple[dict[str, np.ndarray], dict[str, str]]:
    """Returns (arrays, exotic-dtype map). Non-native dtypes (bf16, fp8)
    are stored as byte-width-matched uint views; the manifest records
    the real dtype for restore."""
    flat, dtypes = {}, {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V" or arr.dtype.name not in np.sctypeDict:
            dtypes[key] = arr.dtype.name
            arr = arr.view({1: np.uint8, 2: np.uint16, 4: np.uint32}[
                arr.dtype.itemsize])
        flat[key] = arr
    return flat, dtypes


def _unexotic(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    import ml_dtypes

    return arr.view(np.dtype(getattr(ml_dtypes, dtype_name)))


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save(tree: Any, directory: str, step: int) -> str:
    """Synchronous checkpoint write. Returns the checkpoint path."""
    tmp = os.path.join(directory, f".tmp_step_{step}")
    final = os.path.join(directory, f"step_{step}")
    os.makedirs(tmp, exist_ok=True)
    flat, dtypes = _flatten(tree)
    npz_path = os.path.join(tmp, "arrays.npz")
    np.savez(npz_path, **flat)
    digest = hashlib.sha256(open(npz_path, "rb").read()).hexdigest()
    manifest = {
        "step": step,
        "keys": sorted(flat),
        "dtypes": dtypes,
        "sha256": digest,
        "time": time.time(),
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_"):
            if os.path.exists(os.path.join(directory, name, "manifest.json")):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(template: Any, directory: str, step: Optional[int] = None,
            shardings: Any = None) -> Any:
    """Restore into the structure of `template`. With `shardings`
    (pytree of NamedSharding for the CURRENT mesh) each leaf is placed
    shard-by-shard — elastic across mesh changes."""
    if step is None:
        step = latest_step(directory)
        assert step is not None, f"no checkpoints under {directory}"
    path = os.path.join(directory, f"step_{step}")
    manifest = json.load(open(os.path.join(path, "manifest.json")))
    data = np.load(os.path.join(path, "arrays.npz"))
    digest = hashlib.sha256(
        open(os.path.join(path, "arrays.npz"), "rb").read()).hexdigest()
    assert digest == manifest["sha256"], "checkpoint corrupted"

    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(template)
    sh_leaves = (jax.tree.leaves(shardings) if shardings is not None
                 else [None] * len(leaves_p))
    out = []
    exotic = manifest.get("dtypes", {})
    for (pth, leaf), sh in zip(leaves_p, sh_leaves):
        key = "/".join(_path_str(p) for p in pth)
        arr = data[key]
        if key in exotic:
            arr = _unexotic(arr, exotic[key])
        if hasattr(leaf, "dtype") and arr.dtype != leaf.dtype:
            arr = arr.astype(leaf.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


class AsyncWriter:
    """Background checkpoint writer (one in flight; drops none)."""

    def __init__(self):
        self._thread: Optional[threading.Thread] = None
        self.last_path: Optional[str] = None

    def submit(self, tree: Any, directory: str, step: int) -> None:
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot off-device

        def work():
            self.last_path = save(host_tree, directory, step)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
