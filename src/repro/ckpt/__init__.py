"""Checkpointing: atomic sharded store, async writer, elastic restore,
hardened single-file engine snapshots (save_atomic/verify/retention)."""
from repro.ckpt.store import (  # noqa: F401
    CheckpointCorrupt,
    RetentionPolicy,
    checkpoint_name,
    list_checkpoints,
    save_atomic,
    verify,
)
