"""Checkpointing: atomic sharded store, async writer, elastic restore."""
