"""Logical-axis sharding rules → physical mesh layouts.

The model code annotates parameters and activations with *logical* axis
names ("embed", "ffn", "q_heads", ...). A `Topology` resolves these to
physical mesh axes according to the selected `ShardingConfig` strategy,
the mesh shape, and per-arch divisibility (axes that do not divide the
mesh axis size fall back to replication — GSPMD padding is deliberately
avoided: padded shards waste MXU cycles; see DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import cached_property
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import MeshSpec, ModelConfig, ShardingConfig

# Logical axis vocabulary ----------------------------------------------------
# params
VOCAB = "vocab"
EMBED = "embed"  # d_model
FFN = "ffn"  # MLP intermediate
Q_HEADS = "q_heads"
KV_HEADS = "kv_heads"
HEAD_DIM = "head_dim"
EXPERTS = "experts"
EXPERT_FFN = "expert_ffn"
INNER = "inner"  # state-space/recurrent mixer d_inner (retired archs)
STATE = "state"  # ssm state dim
CONV = "conv"
LAYERS = "layers"  # stacked scan axis
# activations
BATCH = "batch"
SEQ = "seq"
KV_SEQ = "kv_seq"  # decode-cache sequence axis
REPL = None  # explicit "replicated"


def make_mesh_from_spec(spec: MeshSpec, devices: Optional[Sequence] = None) -> Mesh:
    devs = list(devices) if devices is not None else list(jax.devices())
    need = spec.n_devices
    if len(devs) < need:
        raise ValueError(f"mesh {spec.shape} needs {need} devices, have {len(devs)}")
    return compat.make_mesh(spec.shape, spec.axes, devices=devs[:need])


@dataclass(frozen=True)
class Topology:
    """Binds a mesh + model + sharding strategy; resolves logical axes."""

    mesh: Mesh
    model: ModelConfig
    sharding: ShardingConfig

    # ------------------------------------------------------------------
    @cached_property
    def axis_sizes(self) -> dict[str, int]:
        return dict(zip(self.mesh.axis_names, self.mesh.devices.shape))

    @property
    def data_axes(self) -> tuple[str, ...]:
        return tuple(a for a in ("pod", "data") if a in self.axis_sizes)

    @property
    def model_axis(self) -> Optional[str]:
        return "model" if "model" in self.axis_sizes else None

    @property
    def dp_size(self) -> int:
        out = 1
        for a in self.data_axes:
            out *= self.axis_sizes[a]
        return out

    @property
    def tp_size(self) -> int:
        return self.axis_sizes.get("model", 1)

    def _divides(self, dim: int, axes) -> bool:
        size = 1
        for a in axes if isinstance(axes, tuple) else (axes,):
            size *= self.axis_sizes.get(a, 1)
        return dim % size == 0 and dim >= size

    # ------------------------------------------------------------------
    @cached_property
    def rules(self) -> dict[str, object]:
        """logical name -> physical axis (str | tuple | None)."""
        m = self.model
        tp = self.model_axis

        r: dict[str, object] = {
            VOCAB: tp if self._divides(m.padded_vocab, tp) else None,
            EMBED: None,
            FFN: tp if m.d_ff and self._divides(m.d_ff, tp) else None,
            Q_HEADS: tp if self._divides(m.n_heads, tp) else None,
            KV_HEADS: tp if self._divides(m.n_kv_heads, tp) else None,
            HEAD_DIM: None,
            EXPERTS: None,
            EXPERT_FFN: None,
            INNER: tp if self._divides(m.d_inner, tp) else None,
            STATE: None,
            CONV: None,
            LAYERS: None,
            BATCH: self.data_axes if len(self.data_axes) > 1 else (self.data_axes[0] if self.data_axes else None),
            SEQ: None,
            KV_SEQ: None,
        }
        if m.n_experts:
            moe_ff = m.moe_d_ff or m.d_ff
            if self.sharding.expert_parallel and self._divides(m.n_experts, tp):
                r[EXPERTS] = tp
                r[EXPERT_FFN] = None
            elif self._divides(moe_ff, tp):
                r[EXPERTS] = None
                r[EXPERT_FFN] = tp
        if self.sharding.seq_sharded_kv:
            ax = self.sharding.kv_seq_axis
            if ax in self.axis_sizes:
                r[KV_SEQ] = ax
                if ax == "data":
                    # long_500k decodes batch=1: batch axis unshardable.
                    r[BATCH] = None
        if self.sharding.seq_sharded_activations:
            r[SEQ] = tp
        return r

    @cached_property
    def fsdp_axis(self) -> Optional[str]:
        """FSDP: params get this extra axis on their largest free dim."""
        if self.sharding.strategy == "fsdp_tp" and "data" in self.axis_sizes:
            return "data"
        return None

    # ------------------------------------------------------------------
    def spec(self, logical: Sequence[Optional[str]], *, fsdp: bool = False,
             shape: Optional[Sequence[int]] = None) -> P:
        """Resolve logical axes to a PartitionSpec.

        With fsdp=True (parameters), additionally shard the largest
        still-replicated dim over the `data` axis when divisible.
        """
        phys = []
        used: set[str] = set()
        for name in logical:
            ax = self.rules.get(name) if name else None
            if ax is None:
                phys.append(None)
                continue
            axs = ax if isinstance(ax, tuple) else (ax,)
            if any(a in used for a in axs):
                phys.append(None)
                continue
            used.update(axs)
            phys.append(ax)
        if fsdp and self.fsdp_axis and self.fsdp_axis not in used and shape is not None:
            # choose the largest unsharded, divisible dim
            best, best_size = -1, 0
            for i, (p_ax, dim) in enumerate(zip(phys, shape)):
                if p_ax is None and dim % self.axis_sizes[self.fsdp_axis] == 0 and dim > best_size:
                    best, best_size = i, dim
            if best >= 0:
                phys[best] = self.fsdp_axis
        return P(*phys)

    def named(self, logical: Sequence[Optional[str]], **kw) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical, **kw))

    def constrain(self, x, *logical: Optional[str]):
        """with_sharding_constraint by logical axes (no-op on 1-device meshes)."""
        if self.mesh.devices.size == 1:
            return x
        return jax.lax.with_sharding_constraint(x, self.named(logical))

    # convenience activation specs ------------------------------------
    def batch_spec(self, *trailing: Optional[str]) -> NamedSharding:
        return self.named((BATCH, *trailing))

    def replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())


def smoke_topology(model: ModelConfig, sharding: ShardingConfig | None = None) -> Topology:
    """1-device topology with production axis names (for CPU tests)."""
    mesh = compat.make_mesh((1, 1), ("data", "model"),
                            devices=jax.devices()[:1])
    return Topology(mesh, model, sharding or ShardingConfig(strategy="dp_tp"))
