"""Sharding: logical-axis rules resolved against the device mesh."""
