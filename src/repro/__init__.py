"""repro — multicore-aware stochastic simulation of biological systems,
TPU-pod native.

Reproduction + extension of Aldinucci et al. 2010 (CWC + FastFlow
parallelisation schemas) as a production JAX framework. See DESIGN.md.
"""

__version__ = "1.0.0"
__paper__ = ("On Designing Multicore-aware Simulators for Biological "
             "Systems (Aldinucci, Coppo, Damiani, Drocco, Torquati, "
             "Troina; 2010 / Euromicro PDP 2011)")
