"""ShapeDtypeStruct input stand-ins for every (arch × shape) cell.

Weak-type-correct, sharding-annotated, no device allocation — the same
pattern the dry-run and the roofline benchs consume.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import (
    KIND_DECODE,
    KIND_PREFILL,
    KIND_TRAIN,
    ModelConfig,
    ShapeConfig,
)
from repro.models.common import DTYPES
from repro.sharding.rules import BATCH, Topology


def _sds(topo: Topology, shape, dtype, *logical):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=topo.named(logical))


def train_inputs(cfg: ModelConfig, shape: ShapeConfig, topo: Topology) -> dict:
    b, s = shape.global_batch, shape.seq_len
    dt = DTYPES[cfg.dtype]
    ints = jnp.int32
    batch: dict = {}
    if cfg.is_encoder_decoder:
        batch["frames"] = _sds(topo, (b, s, cfg.d_model), dt, BATCH, None, None)
        batch["tokens"] = _sds(topo, (b, s), ints, BATCH, None)
    elif cfg.frontend == "vision":
        p = cfg.frontend_tokens
        batch["embeds"] = _sds(topo, (b, p, cfg.d_model), dt, BATCH, None, None)
        batch["tokens"] = _sds(topo, (b, s - p), ints, BATCH, None)
    else:
        batch["tokens"] = _sds(topo, (b, s), ints, BATCH, None)
    batch["targets"] = _sds(topo, (b, s), ints, BATCH, None)
    batch["loss_mask"] = _sds(topo, (b, s), jnp.float32, BATCH, None)
    return batch


def prefill_inputs(cfg: ModelConfig, shape: ShapeConfig, topo: Topology) -> dict:
    batch = train_inputs(cfg, shape, topo)
    batch.pop("targets")
    batch.pop("loss_mask")
    return batch


def decode_inputs(cfg: ModelConfig, shape: ShapeConfig, topo: Topology,
                  model) -> tuple:
    """(cache, token, pos) stand-ins for serve_step."""
    b, s = shape.global_batch, shape.seq_len
    if cfg.is_encoder_decoder:
        cache_shape = jax.eval_shape(
            lambda: model.init_cache(b, s, cross_len=s))
    else:
        cache_shape = jax.eval_shape(lambda: model.init_cache(b, s))
    shardings = model.cache_shardings()

    def attach(sds, sh):
        return jax.ShapeDtypeStruct(sds.shape, sds.dtype, sharding=sh)

    cache = jax.tree.map(attach, cache_shape, _match_tree(shardings, cache_shape))
    token = _sds(topo, (b, 1), jnp.int32, BATCH, None)
    pos = _sds(topo, (b,), jnp.int32, BATCH)
    return cache, token, pos


def _match_tree(shardings, cache_shape):
    """Broadcast the sharding tree to the cache tree (cache leaves under
    a cache entry map 1:1; cross_k/v reuse the entry's sharding dict)."""
    def walk(sh, ca):
        if isinstance(ca, dict):
            return {k: walk(sh.get(k) if isinstance(sh, dict) else sh, v)
                    for k, v in ca.items()}
        if isinstance(ca, (list, tuple)):
            return type(ca)(walk(s, c) for s, c in zip(sh, ca))
        return sh

    return walk(shardings, cache_shape)


def inputs_for(cfg: ModelConfig, shape: ShapeConfig, topo: Topology, model):
    if shape.kind == KIND_TRAIN:
        return train_inputs(cfg, shape, topo)
    if shape.kind == KIND_PREFILL:
        return prefill_inputs(cfg, shape, topo)
    if shape.kind == KIND_DECODE:
        return decode_inputs(cfg, shape, topo, model)
    raise ValueError(shape.kind)
