"""Launchers: mesh construction, dry-run, train/serve/simulate drivers."""
