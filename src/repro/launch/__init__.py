"""Launchers: mesh construction, HLO analysis, the simulate CLI."""
