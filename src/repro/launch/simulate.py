"""Simulation driver — the paper's tool as a CLI over repro.api.

  PYTHONPATH=src python -m repro.launch.simulate --model ecoli \
      --instances 100 --t-end 50 --windows 100 --schema iii \
      --out ecoli_stats.csv

Parameter sweeps ride the same entry point:

  ... --model lv2 --sweep die=0.3,0.6,1.2 --replicas 32 --per-point
"""
from __future__ import annotations

import argparse
import os

from repro.api import (
    CsvSink,
    Ensemble,
    Experiment,
    Method,
    Partitioning,
    Policy,
    Reduction,
    Schedule,
    Schema,
    simulate,
)
from repro.core.cwc.models import MODELS


def _parse_sweep(specs: list[str]) -> dict:
    """["die=0.3,0.6", "grow=1,2"] -> {"die": [...], "grow": [...]}."""
    out = {}
    for s in specs:
        name, _, vals = s.partition("=")
        if not vals:
            raise SystemExit(f"--sweep expects name=v1,v2,... got {s!r}")
        out[name] = [float(v) for v in vals.split(",")]
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", choices=list(MODELS), default="lv2")
    ap.add_argument("--instances", type=int, default=100,
                    help="ensemble size (replicas per point with --sweep)")
    ap.add_argument("--replicas", type=int, default=None,
                    help="alias for --instances (sweep wording)")
    ap.add_argument("--sweep", action="append", default=[],
                    metavar="NAME=V1,V2,...",
                    help="full-factorial rate sweep; repeatable")
    ap.add_argument("--per-point", action="store_true",
                    help="grouped per-sweep-point reduction")
    ap.add_argument("--t-end", type=float, default=10.0)
    ap.add_argument("--windows", type=int, default=50)
    ap.add_argument("--lanes", type=int, default=128)
    ap.add_argument("--schema", choices=["i", "ii", "iii"], default="iii")
    ap.add_argument("--policy", choices=["static_rr", "on_demand",
                                         "predictive"], default="on_demand")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--method", choices=["exact", "tau_leap"],
                    default="exact",
                    help="per-lane algorithm: exact Gillespie SSA or "
                    "adaptive tau-leaping (Poisson bundles of events "
                    "per Cao-bounded leap, per-lane exact fallback)")
    ap.add_argument("--tau-eps", type=float, default=0.03,
                    help="tau-leap Cao drift bound (bigger = longer "
                    "leaps, coarser approximation)")
    ap.add_argument("--tau-fallback", type=float, default=10.0,
                    help="leap only when it covers at least this many "
                    "expected SSA events; below it the lane takes an "
                    "exact step")
    ap.add_argument("--kernel", action="store_true",
                    help="use the fused Pallas SSA kernel")
    ap.add_argument("--window-block", type=int, default=1,
                    help="superstep width: fuse this many windows into "
                    "one device dispatch with an async pipelined "
                    "record pull (amortises dispatches and host syncs "
                    "to 1/N per window; records are bit-identical for "
                    "any value; incompatible with --host-loop)")
    ap.add_argument("--host-loop", action="store_true",
                    help="legacy per-group dispatch (benchmark baseline)")
    ap.add_argument("--devices", type=int, default=None,
                    help="shard the instance pool over N devices (mesh "
                    "data axis); N must divide the ensemble size")
    ap.add_argument("--stat-blocks", type=int, default=None,
                    help="virtual blocks the per-window statistics "
                    "reduce over (default: --devices); pin it to keep "
                    "records bit-identical across device counts")
    ap.add_argument("--ckpt", default=None,
                    help="checkpoint file: written per window, resumed "
                    "from when it already exists")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    replicas = args.replicas if args.replicas is not None else args.instances
    model = MODELS[args.model]()
    experiment = Experiment(
        model=model,
        ensemble=Ensemble.make(replicas=replicas,
                               sweep=_parse_sweep(args.sweep) or None),
        schedule=Schedule(t_end=args.t_end, n_windows=args.windows,
                          schema=Schema.coerce(args.schema),
                          policy=Policy.coerce(args.policy)),
        reduction=(Reduction.PER_POINT if args.per_point
                   else Reduction.ENSEMBLE),
        seed=args.seed,
        n_lanes=args.lanes,
        method=Method.coerce(args.method),
        tau_eps=args.tau_eps,
        tau_fallback=args.tau_fallback,
        use_kernel=args.kernel,
        host_loop=args.host_loop,
        window_block=args.window_block,
        partitioning=(Partitioning(n_shards=args.devices,
                                   stat_blocks=args.stat_blocks)
                      if args.devices else None))

    if args.out:
        from repro.api.run import observable_names

        experiment = experiment.with_(
            sinks=(CsvSink(args.out, observable_names(model)),))

    resume = bool(args.ckpt) and os.path.exists(
        args.ckpt if args.ckpt.endswith(".npz") else args.ckpt + ".npz")
    if resume:
        print(f"resuming from {args.ckpt}")
    result = simulate(experiment, checkpoint_path=args.ckpt, resume=resume)

    tele = result.telemetry
    print(f"model={model.name} schema={args.schema} "
          f"method={args.method} "
          f"instances={experiment.ensemble.n_instances} "
          f"windows={len(result.records)} "
          f"wall={tele.wall_time_s:.2f}s "
          f"dispatches={tele.dispatches} host_syncs={tele.host_syncs} "
          f"peak_buffered={tele.peak_buffered_bytes}B")
    if args.method == "tau_leap":
        steps = sum(tele.steps_per_window)
        leaps = sum(tele.leaps_per_window)
        print(f"  tau-leap: {steps} solver steps = {leaps} leaps + "
              f"{steps - leaps} exact-fallback events")
    last = result.records[-1]
    for name, m, v, ci in zip(result.obs_names, last.mean, last.var,
                              last.ci90):
        print(f"  {name:24s} mean={m:10.2f} var={v:12.2f} ci90=±{ci:.3f}")
    pp = result.per_point()
    if pp is not None and len(pp["points"]) > 1:
        print("per-sweep-point final means:")
        for p, point in enumerate(pp["points"]):
            vals = " ".join(f"{name}={m:.1f}" for name, m in
                            zip(result.obs_names, pp["mean"][-1, p]))
            print(f"  {point}: {vals}")


if __name__ == "__main__":
    main()
