"""Simulation driver — the paper's tool as a CLI.

  PYTHONPATH=src python -m repro.launch.simulate --model ecoli \
      --instances 100 --t-end 50 --windows 100 --schema iii \
      --out ecoli_stats.csv
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.cwc.models import MODELS
from repro.core.engine import SimConfig, SimulationEngine
from repro.core.stream import csv_sink


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", choices=list(MODELS), default="lv2")
    ap.add_argument("--instances", type=int, default=100)
    ap.add_argument("--t-end", type=float, default=10.0)
    ap.add_argument("--windows", type=int, default=50)
    ap.add_argument("--lanes", type=int, default=128)
    ap.add_argument("--schema", choices=["i", "ii", "iii"], default="iii")
    ap.add_argument("--policy", choices=["static_rr", "on_demand",
                                         "predictive"], default="on_demand")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--kernel", action="store_true",
                    help="use the fused Pallas SSA kernel")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    model = MODELS[args.model]()
    cfg = SimConfig(n_instances=args.instances, t_end=args.t_end,
                    n_windows=args.windows, n_lanes=args.lanes,
                    schema=args.schema, policy=args.policy, seed=args.seed,
                    use_kernel=args.kernel)
    eng = SimulationEngine(model, cfg)
    if args.out:
        eng.stream.attach(csv_sink(args.out, eng.obs_names))

    t0 = time.time()
    if args.ckpt:
        import os

        if os.path.exists(args.ckpt):
            eng.restore(args.ckpt)
            print(f"resumed at window {eng._window}")
        while eng._window < len(eng.grid):
            eng.run_window()
            eng.checkpoint(args.ckpt)
    else:
        eng.run()
    wall = time.time() - t0

    recs = eng.stream.records()
    print(f"model={model.name} schema={args.schema} "
          f"instances={args.instances} windows={len(recs)} "
          f"wall={wall:.2f}s peak_buffered={eng.peak_buffered_bytes}B")
    last = recs[-1]
    for name, m, v, ci in zip(eng.obs_names, last.mean, last.var, last.ci90):
        print(f"  {name:24s} mean={m:10.2f} var={v:12.2f} ci90=±{ci:.3f}")


if __name__ == "__main__":
    main()
