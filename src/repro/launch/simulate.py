"""Simulation driver — the paper's tool as a CLI over repro.api.

  PYTHONPATH=src python -m repro.launch.simulate --model ecoli \
      --instances 100 --t-end 50 --windows 100 --schema iii \
      --out ecoli_stats.csv

Parameter sweeps ride the same entry point:

  ... --model lv2 --sweep die=0.3,0.6,1.2 --replicas 32 --per-point
"""
from __future__ import annotations

import argparse
import os

from repro.api import (
    CsvSink,
    Ensemble,
    Experiment,
    Method,
    Partitioning,
    Policy,
    Reduction,
    Schedule,
    Schema,
    SketchSpec,
    Steering,
    simulate,
)
from repro.core.cwc.models import MODELS


def _parse_sweep(specs: list[str]) -> dict:
    """["die=0.3,0.6", "grow=1,2"] -> {"die": [...], "grow": [...]}."""
    out = {}
    for s in specs:
        name, _, vals = s.partition("=")
        if not vals:
            raise SystemExit(f"--sweep expects name=v1,v2,... got {s!r}")
        out[name] = [float(v) for v in vals.split(",")]
    return out


def _parse_depth(s: str):
    """--pipeline-depth operand: 'auto' or a positive int."""
    if s == "auto":
        return s
    try:
        return int(s)
    except ValueError:
        raise SystemExit(
            f"--pipeline-depth expects a positive int or 'auto', got {s!r}"
        ) from None


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", choices=list(MODELS), default="lv2")
    ap.add_argument("--instances", type=int, default=100,
                    help="ensemble size (replicas per point with --sweep)")
    ap.add_argument("--replicas", type=int, default=None,
                    help="alias for --instances (sweep wording)")
    ap.add_argument("--sweep", action="append", default=[],
                    metavar="NAME=V1,V2,...",
                    help="full-factorial rate sweep; repeatable")
    ap.add_argument("--per-point", action="store_true",
                    help="grouped per-sweep-point reduction")
    ap.add_argument("--t-end", type=float, default=10.0)
    ap.add_argument("--windows", type=int, default=50)
    ap.add_argument("--lanes", type=int, default=128)
    ap.add_argument("--schema", choices=["i", "ii", "iii"], default="iii")
    ap.add_argument("--policy", choices=["static_rr", "on_demand",
                                         "predictive"], default="on_demand")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--method", choices=["exact", "tau_leap"],
                    default="exact",
                    help="per-lane algorithm: exact Gillespie SSA or "
                    "adaptive tau-leaping (Poisson bundles of events "
                    "per Cao-bounded leap, per-lane exact fallback)")
    ap.add_argument("--tau-eps", type=float, default=0.03,
                    help="tau-leap Cao drift bound (bigger = longer "
                    "leaps, coarser approximation)")
    ap.add_argument("--tau-fallback", type=float, default=10.0,
                    help="leap only when it covers at least this many "
                    "expected SSA events; below it the lane takes an "
                    "exact step")
    ap.add_argument("--kernel", action="store_true",
                    help="use the fused Pallas SSA kernel")
    ap.add_argument("--window-block", type=int, default=1,
                    help="superstep width: fuse this many windows into "
                    "one device dispatch with an async pipelined "
                    "record pull (amortises dispatches and host syncs "
                    "to 1/N per window; records are bit-identical for "
                    "any value; incompatible with --host-loop)")
    ap.add_argument("--pipeline-depth", default="1", metavar="K",
                    help="superstep pipeline depth: keep K dispatched "
                    "window blocks in flight before the collector "
                    "blocks on the oldest record ring (default 1, the "
                    "double-buffer), or 'auto' to profile the first "
                    "collected block's pull-vs-host-reduce walls and "
                    "pick a depth; records are bit-identical for any "
                    "value (only WHEN rings are pulled changes)")
    ap.add_argument("--sparse", action="store_true",
                    help="sparse large-network engine: CSR reactant "
                    "tables + reaction dependency graph, O(out-degree) "
                    "propensity updates per event instead of O(R); "
                    "bitwise identical to the dense encoding and "
                    "required for stoichiometric coefficients > 4")
    ap.add_argument("--host-loop", action="store_true",
                    help="legacy per-group dispatch (benchmark baseline)")
    ap.add_argument("--devices", type=int, default=None,
                    help="shard the instance pool over N devices (mesh "
                    "data axis); N must divide the ensemble size")
    ap.add_argument("--stat-blocks", type=int, default=None,
                    help="virtual blocks the per-window statistics "
                    "reduce over (default: --devices); pin it to keep "
                    "records bit-identical across device counts")
    ap.add_argument("--ckpt", default=None,
                    help="checkpoint file: written per window, resumed "
                    "from when it already exists (mutually exclusive "
                    "with --recover-dir)")
    ap.add_argument("--recover-dir", default=None, metavar="DIR",
                    help="supervised self-healing run (RunSupervisor): "
                    "cadenced atomic checkpoints under DIR, bounded-"
                    "backoff restart from the newest valid snapshot on "
                    "any recoverable fault, elastic shard-loss "
                    "degradation; mutually exclusive with --ckpt")
    ap.add_argument("--ckpt-every", type=int, default=1, metavar="N",
                    help="supervised checkpoint cadence in windows "
                    "(rounded up to a multiple of --window-block)")
    ap.add_argument("--keep-last", type=int, default=3, metavar="K",
                    help="supervised checkpoint retention depth; >= 2 "
                    "keeps a fallback behind a corrupt newest file")
    ap.add_argument("--max-restarts", type=int, default=8,
                    help="recoveries before the run is declared dead")
    ap.add_argument("--workers", type=int, default=1, metavar="N",
                    help="multi-process elastic farm (needs "
                    "--recover-dir): shard the ensemble over N worker "
                    "processes under a coordinator that heartbeat-"
                    "supervises, restarts, and — past the restart "
                    "budget — reassigns dead workers' shards; results "
                    "merge bitwise vs the single-process run")
    ap.add_argument("--heartbeat-s", type=float, default=2.0,
                    help="farm worker heartbeat interval; stale for "
                    "3x this = stalled worker (killed + restarted)")
    ap.add_argument("--max-worker-restarts", type=int, default=2,
                    help="per-worker restart budget; past it the "
                    "worker is retired and its shard reassigned to a "
                    "survivor")
    ap.add_argument("--redispatch-stragglers", action="store_true",
                    help="escalate watchdog breaches into a supervised "
                    "re-dispatch of the offending block (one retry per "
                    "window; replay is bitwise)")
    ap.add_argument("--inject", action="append", default=[],
                    metavar="W:KIND",
                    help="fault drill: inject KIND (crash|device_lost|"
                    "ckpt_corrupt|stall|nan_pool) before window W; "
                    "repeatable (needs --recover-dir)")
    ap.add_argument("--inject-rate", type=float, default=0.0,
                    help="fault drill: seeded per-window crash "
                    "probability on top of --inject")
    ap.add_argument("--inject-seed", type=int, default=0,
                    help="seed for --inject-rate draws (same seed = "
                    "same fault schedule)")
    ap.add_argument("--sketch-bins", type=int, default=0,
                    help="stream per-window fixed-bin histograms with "
                    "this many bins per (point, observable); p10/p50/"
                    "p90 estimates print at the end (0 = off)")
    ap.add_argument("--sketch-threshold", action="append", default=[],
                    type=float, metavar="LEVEL",
                    help="rare-event counter: instances with obs >= "
                    "LEVEL per window; repeatable (needs --sketch-bins)")
    ap.add_argument("--early-stop", type=float, default=0.0,
                    metavar="REL_CI",
                    help="steering: stop a sweep point once every "
                    "observable's ci90/|mean| falls below REL_CI "
                    "(0 = off)")
    ap.add_argument("--steer-min-windows", type=int, default=4,
                    help="never early-stop a point before this many "
                    "windows")
    ap.add_argument("--reallocate", action="store_true",
                    help="steering: move a stopped point's freed lanes "
                    "to the live point with the worst relative CI "
                    "(needs --early-stop)")
    ap.add_argument("--tau-switch", action="store_true",
                    help="steering: pin lanes whose EMA leap share "
                    "stays low to exact SSA (tau_leap runs only)")
    ap.add_argument("--flag-bimodal", action="store_true",
                    help="steering: flag bimodal (point, observable) "
                    "histograms (needs --sketch-bins)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    replicas = args.replicas if args.replicas is not None else args.instances
    model = MODELS[args.model]()
    experiment = Experiment(
        model=model,
        ensemble=Ensemble.make(replicas=replicas,
                               sweep=_parse_sweep(args.sweep) or None),
        schedule=Schedule(t_end=args.t_end, n_windows=args.windows,
                          schema=Schema.coerce(args.schema),
                          policy=Policy.coerce(args.policy)),
        reduction=(Reduction.PER_POINT if args.per_point
                   else Reduction.ENSEMBLE),
        seed=args.seed,
        n_lanes=args.lanes,
        method=Method.coerce(args.method),
        tau_eps=args.tau_eps,
        tau_fallback=args.tau_fallback,
        use_kernel=args.kernel,
        host_loop=args.host_loop,
        window_block=args.window_block,
        pipeline_depth=_parse_depth(args.pipeline_depth),
        sparse=args.sparse,
        partitioning=(Partitioning(n_shards=args.devices,
                                   stat_blocks=args.stat_blocks)
                      if args.devices else None),
        sketch=(SketchSpec(n_bins=args.sketch_bins,
                           thresholds=tuple(args.sketch_threshold))
                if args.sketch_bins else None),
        steering=(Steering(ci_rel_tol=args.early_stop,
                           min_windows=args.steer_min_windows,
                           reallocate=args.reallocate,
                           tau_switch=args.tau_switch,
                           bimodality=args.flag_bimodal)
                  if (args.early_stop or args.tau_switch
                      or args.flag_bimodal) else None))

    if args.recover_dir:
        if args.ckpt:
            raise SystemExit("--recover-dir owns checkpointing; drop "
                             "--ckpt")
        from repro.api import Recovery
        from repro.runtime.fault import FAULT_KINDS, FailurePlan

        schedule = {}
        for spec in args.inject:
            w, _, kind = spec.partition(":")
            if not w.isdigit() or kind not in FAULT_KINDS:
                raise SystemExit(
                    f"--inject expects W:KIND with KIND in "
                    f"{FAULT_KINDS}, got {spec!r}")
            schedule[int(w)] = kind
        plan = (FailurePlan(schedule=schedule, seed=args.inject_seed,
                            random_rate=args.inject_rate)
                if (schedule or args.inject_rate) else None)
        experiment = experiment.with_(recovery=Recovery(
            ckpt_dir=args.recover_dir, cadence=args.ckpt_every,
            keep_last=args.keep_last, max_restarts=args.max_restarts,
            redispatch_stragglers=args.redispatch_stragglers,
            workers=args.workers, heartbeat_s=args.heartbeat_s,
            max_worker_restarts=args.max_worker_restarts,
            inject=plan))
    elif args.workers > 1:
        raise SystemExit("--workers needs --recover-dir (the farm's "
                         "shared checkpoint directory)")

    if args.out:
        from repro.api.run import observable_names

        experiment = experiment.with_(
            sinks=(CsvSink(args.out, observable_names(model)),))

    resume = bool(args.ckpt) and os.path.exists(
        args.ckpt if args.ckpt.endswith(".npz") else args.ckpt + ".npz")
    if resume:
        print(f"resuming from {args.ckpt}")
    result = simulate(experiment, checkpoint_path=args.ckpt, resume=resume)

    tele = result.telemetry
    print(f"model={model.name} schema={args.schema} "
          f"method={args.method} "
          f"instances={experiment.ensemble.n_instances} "
          f"windows={len(result.records)} "
          f"wall={tele.wall_time_s:.2f}s "
          f"dispatches={tele.dispatches} host_syncs={tele.host_syncs} "
          f"peak_buffered={tele.peak_buffered_bytes}B")
    if args.method == "tau_leap":
        steps = sum(tele.steps_per_window)
        leaps = sum(tele.leaps_per_window)
        print(f"  tau-leap: {steps} solver steps = {leaps} leaps + "
              f"{steps - leaps} exact-fallback events")
    last = result.records[-1]
    for name, m, v, ci in zip(result.obs_names, last.mean, last.var,
                              last.ci90):
        print(f"  {name:24s} mean={m:10.2f} var={v:12.2f} ci90=±{ci:.3f}")
    pp = result.per_point()
    if pp is not None and len(pp["points"]) > 1:
        print("per-sweep-point final means:")
        for p, point in enumerate(pp["points"]):
            vals = " ".join(f"{name}={m:.1f}" for name, m in
                            zip(result.obs_names, pp["mean"][-1, p]))
            print(f"  {point}: {vals}")
    if tele.straggler_windows:
        print(f"stragglers: {len(tele.straggler_windows)} window(s) "
              f"flagged (rate {tele.straggler_rate:.2f}): " + ", ".join(
                  f"w{w} {wall * 1e3:.0f}ms vs median {med * 1e3:.0f}ms"
                  for w, wall, med in tele.straggler_windows[:5]))
    sks = result.sketches()
    if sks:
        from repro.stats import quantiles_from_hist

        sk_params = result._engine._sketch
        q = quantiles_from_hist(sks[-1].hist, sk_params.lo,
                                sk_params.width)
        print("final-window quantile estimates (p10/p50/p90):")
        for g in range(q.shape[0]):
            for o, name in enumerate(result.obs_names):
                tag = f"point {g} " if q.shape[0] > 1 else ""
                print(f"  {tag}{name:20s} "
                      f"{q[g, o, 0]:8.1f} {q[g, o, 1]:8.1f} "
                      f"{q[g, o, 2]:8.1f}")
    rep = result.steering_report()
    if rep is not None:
        print(f"steering: {len(rep['stopped_points'])}/{rep['n_points']}"
              f" points early-stopped, "
              f"{rep['point_windows_simulated']}/"
              f"{rep['point_windows_total']} point-windows simulated "
              f"({rep['windows_saved_ratio']:.2f}x saved), "
              f"{rep['lanes_pinned_exact']} lanes pinned exact, "
              f"{len(rep['bimodal_flags'])} bimodal flags")
        for d in rep["decisions"]:
            print(f"  w{d['window']}: {d}")
    rec = result.recovery_report()
    if rec is not None and "workers" in rec:  # farm coordinator report
        print(f"farm: {rec['workers']} workers, {rec['restarts']} "
              f"worker restart(s), {rec['reassignments']} shard "
              f"reassignment(s), faults={rec['faults_by_kind'] or '{}'}")
        for w, pw in rec["per_worker"].items():
            tag = " RETIRED" if pw["retired"] else ""
            print(f"  worker {w}: {pw['restarts']} restart(s), shards "
                  f"{pw['shards_run']}{tag}")
        for ev in rec["events"]:
            if ev["event"] in ("fault_injected", "fault",
                               "worker_retired", "shard_reassigned"):
                print(f"  {ev}")
    elif rec is not None:
        print(f"recovery: {rec['restarts']} restart(s), faults="
              f"{rec['faults_by_kind'] or '{}'}"
              + (f", degraded to {rec['final_n_shards']} shard(s)"
                 if rec["final_n_shards"] is not None else ""))
        for ev in rec["events"]:
            if ev["event"] in ("fault_injected", "fault", "degraded",
                               "corrupt_checkpoint_skipped"):
                print(f"  {ev}")


if __name__ == "__main__":
    main()
