"""End-to-end training driver (runs on CPU for smoke/examples; same code
path drives pods — the mesh/topology comes from flags).

  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/run1
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import store
from repro.configs.base import OptimizerConfig, ShardingConfig
from repro.configs.registry import ARCH_NAMES, get_config, get_smoke_config
from repro.data.pipeline import PrefetchPipeline
from repro.models import build_model
from repro.sharding.rules import smoke_topology
from repro.train.optim import init_opt_state
from repro.train.step import make_train_step


def train_loop(arch: str, *, smoke: bool = True, steps: int = 50,
               batch: int = 8, seq: int = 128, grad_accum: int = 1,
               ckpt_dir: str | None = None, ckpt_every: int = 20,
               resume: bool = False, log_every: int = 10,
               lr: float = 1e-3, seed: int = 0, quiet: bool = False):
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    topo = smoke_topology(cfg)
    model = build_model(cfg, topo, remat="none", scan_layers=True)
    ocfg = OptimizerConfig(lr=lr, warmup_steps=max(5, steps // 10),
                           total_steps=steps)
    scfg = ShardingConfig(strategy="dp_tp", grad_accum=grad_accum)
    step_fn = jax.jit(make_train_step(model, ocfg, scfg), donate_argnums=(0,))

    start_step = 0
    if resume and ckpt_dir and store.latest_step(ckpt_dir) is not None:
        start_step = store.latest_step(ckpt_dir)
        params = model.init(jax.random.PRNGKey(seed))
        template = {"params": params, "opt": init_opt_state(params, ocfg)}
        state = store.restore(template, ckpt_dir)
        if not quiet:
            print(f"resumed from step {start_step}")
    else:
        params = model.init(jax.random.PRNGKey(seed))
        state = {"params": params, "opt": init_opt_state(params, ocfg)}

    pipe = PrefetchPipeline(cfg, batch, seq, start_step=start_step)
    writer = store.AsyncWriter()
    losses = []
    t0 = time.time()
    try:
        for i in range(start_step, steps):
            b = next(pipe)
            b.pop("_step")
            state, metrics = step_fn(state, b)
            loss = float(np.asarray(metrics["loss"]))
            losses.append(loss)
            if not quiet and (i % log_every == 0 or i == steps - 1):
                tok_s = batch * seq * max(1, i + 1 - start_step) / (
                    time.time() - t0)
                print(f"step {i:5d} loss {loss:.4f} "
                      f"lr {float(np.asarray(metrics['lr'])):.2e} "
                      f"gnorm {float(np.asarray(metrics['grad_norm'])):.2f} "
                      f"tok/s {tok_s:,.0f}")
            if ckpt_dir and (i + 1) % ckpt_every == 0:
                writer.submit(state, ckpt_dir, i + 1)
        writer.wait()
        if ckpt_dir:
            store.save(state, ckpt_dir, steps)
    finally:
        pipe.close()
    return state, losses


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="llama3-8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()
    train_loop(args.arch, smoke=args.smoke, steps=args.steps,
               batch=args.batch, seq=args.seq, grad_accum=args.grad_accum,
               ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
               resume=args.resume, lr=args.lr)


if __name__ == "__main__":
    main()
