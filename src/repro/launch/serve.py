"""Serving driver: continuous-batching decode over a smoke/full model.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b \
      --requests 16 --slots 4 --cache-len 64
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.registry import ARCH_NAMES, get_smoke_config
from repro.models import build_model
from repro.serve.engine import Request, ServeEngine
from repro.sharding.rules import smoke_topology


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="llama3-8b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    if cfg.is_encoder_decoder:
        raise SystemExit("serve driver targets decoder-only archs")
    model = build_model(cfg, smoke_topology(cfg))
    params = model.init(jax.random.PRNGKey(args.seed))
    engine = ServeEngine(model, params, n_slots=args.slots,
                         cache_len=args.cache_len, seed=args.seed)

    rng = np.random.default_rng(args.seed)
    reqs = []
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              size=int(rng.integers(3, 12))).astype(np.int32)
        reqs.append(Request(uid=i, prompt=prompt,
                            max_new_tokens=int(rng.integers(
                                2, args.max_new + 1)),
                            temperature=args.temperature))
        engine.submit(reqs[-1])

    t0 = time.time()
    engine.run()
    wall = time.time() - t0
    total = sum(len(r.out_tokens) for r in reqs)
    print(f"arch={args.arch} requests={len(reqs)} tokens={total} "
          f"wall={wall:.2f}s ({total/max(wall,1e-9):.1f} tok/s) "
          f"ticks={engine.ticks} utilisation={engine.utilisation:.0%}")


if __name__ == "__main__":
    main()
