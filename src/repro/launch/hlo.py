"""Optimized-HLO analysis: collective inventory for the roofline.

`cost_analysis()` does not report collective traffic, so we parse the
compiled module text. Per-device moved bytes use ring-algorithm factors:

  all-reduce        2(g-1)/g · result_bytes
  all-gather        (g-1)/g  · result_bytes          (result = gathered)
  reduce-scatter    (g-1)    · result_bytes          (input = g · result)
  all-to-all        (g-1)/g  · buffer_bytes
  collective-permute 1       · buffer_bytes

g = replica-group size parsed from `replica_groups=[N,G]<=[...]` (iota
form) or literal `{{...}}` lists. Async pairs (`-start`/`-done`) are
counted once at the `-start`.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?P<shape>\([^=]*?\)|[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<start>-start)?\(",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUPS_LIT_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


@dataclass
class Collective:
    op: str
    bytes_buffer: int  # result-buffer bytes (per device program)
    group_size: int
    count: int = 1

    @property
    def moved_bytes(self) -> float:
        g = max(self.group_size, 1)
        if self.op == "all-reduce":
            f = 2 * (g - 1) / g
        elif self.op == "all-gather":
            f = (g - 1) / g
        elif self.op == "reduce-scatter":
            f = float(g - 1)
        elif self.op == "all-to-all":
            f = (g - 1) / g
        else:  # collective-permute
            f = 1.0
        return f * self.bytes_buffer * self.count


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> list[Collective]:
    """Inventory of collectives in an optimized HLO module (per-device)."""
    agg: dict[tuple, Collective] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if "-done" in line.split("=", 1)[-1][:60] and not m.group("start"):
            continue
        op = m.group("op")
        nbytes = _shape_bytes(m.group("shape"))
        gm = _GROUPS_IOTA_RE.search(line)
        if gm:
            group = int(gm.group(2))
        else:
            gl = _GROUPS_LIT_RE.search(line)
            group = len(gl.group(1).split(",")) if gl else 1
        key = (op, nbytes, group)
        if key in agg:
            agg[key].count += 1
        else:
            agg[key] = Collective(op, nbytes, group)
    return list(agg.values())


def collective_summary(colls: list[Collective]) -> dict:
    by_op: dict[str, float] = defaultdict(float)
    counts: dict[str, int] = defaultdict(int)
    for c in colls:
        by_op[c.op] += c.moved_bytes
        counts[c.op] += c.count
    total = sum(by_op.values())
    return {
        "moved_bytes_per_device": total,
        "by_op": dict(by_op),
        "counts": dict(counts),
    }
