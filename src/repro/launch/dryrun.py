import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT lower + compile every (arch × shape × mesh)
cell on placeholder devices; record memory/cost/collective analysis.

The two lines above MUST stay the first statements in this module —
jax locks the device count at first init.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
      --shape train_4k --mesh single [--out artifacts/]
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.base import (
    SHAPES,
    V5E,
    OptimizerConfig,
    ShardingConfig,
    shape_applicable,
)
from repro.configs.registry import (
    ARCH_NAMES,
    default_sharding,
    dryrun_cells,
    get_config,
)
from repro.launch.hlo import collective_summary, parse_collectives
from repro.launch.inputs import inputs_for
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.sharding.rules import Topology
from repro.train.step import abstract_train_state, make_train_step


def _flops_bytes(compiled) -> tuple[float, float]:
    ca = compiled.cost_analysis() or {}
    return float(ca.get("flops", 0.0)), float(ca.get("bytes accessed", 0.0))


def _memory(compiled) -> dict:
    ma = compiled.memory_analysis()
    return {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "peak_bytes": int(ma.argument_size_in_bytes + ma.output_size_in_bytes
                          + ma.temp_size_in_bytes - ma.alias_size_in_bytes),
    }


def flash_kernel_terms(cfg, shape, n_dev: int) -> tuple[float, float]:
    """Analytic (flops, hbm_bytes) PER DEVICE of the Pallas flash
    attention kernels for this cell (kernels/flash_attention.py): with
    --attn flash the dry-run compiles the O(S) stub and adds these back.

    fwd flops = 4·B·H·S²·hd (x0.5 causal); bwd ≈ 2.5x fwd.
    HBM: fwd reads q,k,v, writes o+lse; bwd reads q,k,v,o,do,lse, writes
    dq,dk,dv — ≈ (4·|q| + 2·|kv|) fwd and ~2.5x that for train.
    """
    from repro.kernels.flash_attention import attention_flops

    hd = cfg.resolved_head_dim
    b, s = shape.global_batch, shape.seq_len
    train = shape.kind == "train"
    specs = list(cfg.layer_specs())
    n_self = sum(1 for sp in specs if sp.mixer == "attn")
    flops = n_self * attention_flops(b, s, cfg.n_heads, hd, True, train)
    q_bytes = b * s * cfg.n_heads * hd * 2
    kv_bytes = b * s * cfg.n_kv_heads * hd * 2
    io = n_self * (4 * q_bytes + 2 * kv_bytes) * (2.5 if train else 1.0)
    if cfg.is_encoder_decoder:
        n_enc = len(cfg.encoder_layer_specs())
        # encoder self (bidir) + decoder cross (bidir)
        flops += (n_enc + n_self) * attention_flops(
            b, s, cfg.n_heads, hd, False, train)
        io *= 3.0
    return flops / n_dev, io / n_dev


def model_flops(cfg, shape) -> float:
    """6·N_active·D for train; 2·N_active per generated/processed token
    otherwise (forward only)."""
    n = cfg.active_param_count()
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             sharding: ShardingConfig | None = None,
             out_dir: str | None = None, tag: str = "",
             attn: str = "chunked") -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    scfg = sharding or default_sharding(arch, shape)
    if not multi_pod:
        # Unroll the layer stack: cost_analysis counts loop bodies once,
        # so faithful roofline terms need the repeats materialised. The
        # multi-pod pass keeps lax.scan (it proves sharding coherence,
        # not cost terms) for tractable compile times.
        scfg = dataclasses.replace(scfg, scan_layers=False)

    from repro.models.attention import set_attention_impl

    use_flash = attn == "flash" and shape.kind in ("train", "prefill")
    set_attention_impl("linear_stub" if use_flash else "auto")
    try:
        topo = Topology(mesh, cfg, scfg)
        model = build_model(cfg, topo, remat=scfg.remat,
                            scan_layers=scfg.scan_layers)
        return _run_cell_inner(cfg, shape, arch, shape_name, multi_pod,
                               mesh, scfg, topo, model, out_dir, tag,
                               use_flash)
    finally:
        set_attention_impl("auto")


def _run_cell_inner(cfg, shape, arch, shape_name, multi_pod, mesh, scfg,
                    topo, model, out_dir, tag, use_flash):

    t0 = time.time()
    params = model.abstract_params()

    if shape.kind == "train":
        state = abstract_train_state(model, OptimizerConfig())
        batch = inputs_for(cfg, shape, topo, model)
        step = make_train_step(model, OptimizerConfig(), scfg)
        with jax.set_mesh(mesh):
            lowered = jax.jit(step, donate_argnums=(0,)).lower(state, batch)
    elif shape.kind == "prefill":
        batch = inputs_for(cfg, shape, topo, model)

        def prefill_step(params, batch):
            return model.prefill(params, batch)

        with jax.set_mesh(mesh):
            lowered = jax.jit(prefill_step).lower(params, batch)
    else:  # decode
        cache, token, pos = inputs_for(cfg, shape, topo, model)

        def serve_step(params, cache, token, pos):
            return model.decode_step(params, cache, token, pos)

        with jax.set_mesh(mesh):
            lowered = jax.jit(serve_step, donate_argnums=(1,)).lower(
                params, cache, token, pos)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    flops, bytes_acc = _flops_bytes(compiled)
    mem = _memory(compiled)
    colls = parse_collectives(compiled.as_text())
    csum = collective_summary(colls)

    n_dev = mesh.devices.size
    flash = None
    if use_flash:
        # add the Pallas flash kernels' analytic terms (the compiled
        # graph carries the O(S) stub instead of score materialisation)
        f_flops, f_bytes = flash_kernel_terms(cfg, shape, n_dev)
        flops += f_flops
        bytes_acc += f_bytes
        flash = {"kernel_flops_per_device": f_flops,
                 "kernel_bytes_per_device": f_bytes}
    t_comp = flops / V5E.peak_flops_bf16
    t_mem = bytes_acc / V5E.hbm_bw
    t_coll = csum["moved_bytes_per_device"] / V5E.ici_bw
    terms = {"compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    result = {
        "flash_adjustment": flash,
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi_pod" if multi_pod else "single_pod",
        "n_devices": n_dev,
        "sharding": dataclasses.asdict(scfg),
        "flops_per_device": flops,
        "bytes_per_device": bytes_acc,
        "memory": mem,
        "collectives": csum,
        "roofline": {
            **terms,
            "dominant": dominant,
            "bound_s": max(terms.values()),
            "compute_fraction": (t_comp / max(terms.values())
                                 if max(terms.values()) > 0 else 0.0),
        },
        "model_flops_global": mf,
        "useful_flops_ratio": (mf / (flops * n_dev)) if flops else 0.0,
        "fits_hbm": mem["peak_bytes"] <= V5E.hbm_bytes,
        "lower_s": t_lower,
        "compile_s": t_compile,
        "tag": tag,
    }
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        mesh_tag = "mp" if multi_pod else "sp"
        suffix = f"-{tag}" if tag else ""
        path = os.path.join(out_dir,
                            f"{arch}-{shape_name}-{mesh_tag}{suffix}.json")
        with open(path, "w") as f:
            json.dump(result, f, indent=1)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true",
                    help="run every applicable cell")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--tag", default="", help="variant tag for §Perf runs")
    ap.add_argument("--attn", choices=["chunked", "flash"], default="chunked")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    cells = (dryrun_cells() if args.all
             else [(args.arch, SHAPES[args.shape])])

    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            name = f"{arch} × {shape.name} × {'2x16x16' if mp else '16x16'}"
            try:
                r = run_cell(arch, shape.name, mp, out_dir=args.out,
                             tag=args.tag, attn=args.attn)
                if "skipped" in r:
                    print(f"SKIP {name}: {r['skipped']}")
                    continue
                rf = r["roofline"]
                print(f"OK   {name}: dominant={rf['dominant']} "
                      f"bound={rf['bound_s']*1e3:.2f}ms "
                      f"compute%={100*rf['compute_fraction']:.0f} "
                      f"peak={r['memory']['peak_bytes']/2**30:.2f}GiB "
                      f"fits={r['fits_hbm']} compile={r['compile_s']:.0f}s")
            except Exception as e:
                failures += 1
                print(f"FAIL {name}: {type(e).__name__}: {e}")
                traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} dry-run cells failed")


if __name__ == "__main__":
    main()
