"""Production meshes.

Defined as functions (never module-level constants) so importing this
module never touches jax device state. The dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 *before* any jax
import; smoke tests and benchmarks see the real (1-device) platform.
"""
from __future__ import annotations

import jax

from repro.configs.base import MULTI_POD, SINGLE_POD, MeshSpec
from repro.sharding.rules import make_mesh_from_spec


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """(16,16)=256 chips/pod ('data','model'); multi-pod: (2,16,16)."""
    spec = MULTI_POD if multi_pod else SINGLE_POD
    return make_mesh_from_spec(spec)


def production_mesh_spec(*, multi_pod: bool = False) -> MeshSpec:
    return MULTI_POD if multi_pod else SINGLE_POD
