"""GQA attention: full, chunked (online-softmax, memory-bounded for 32k+),
decode with KV cache, and sequence-sharded distributed flash-decode.

All softmax math in fp32; matmuls accumulate in fp32.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import ModelConfig
from repro.models.common import ParamDef, einsum, einsum_out
from repro.models.rope import apply_rope
from repro.sharding.rules import (
    EMBED,
    HEAD_DIM,
    KV_HEADS,
    KV_SEQ,
    Q_HEADS,
    Topology,
)

NEG_INF = -1e30

# When seq_len exceeds this, use the chunked online-softmax path.
FULL_ATTN_MAX_SEQ = 2048
Q_CHUNK = 1024
KV_CHUNK = 1024


def attn_defs(cfg: ModelConfig) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    defs = {
        "wq": ParamDef((d, h, hd), (EMBED, Q_HEADS, HEAD_DIM)),
        "wk": ParamDef((d, kv, hd), (EMBED, KV_HEADS, HEAD_DIM)),
        "wv": ParamDef((d, kv, hd), (EMBED, KV_HEADS, HEAD_DIM)),
        "wo": ParamDef((h, hd, d), (Q_HEADS, HEAD_DIM, EMBED)),
    }
    if cfg.mlp_bias:  # archs with biases use them in attention too
        defs["bq"] = ParamDef((h, hd), (Q_HEADS, HEAD_DIM), init="zeros")
        defs["bk"] = ParamDef((kv, hd), (KV_HEADS, HEAD_DIM), init="zeros")
        defs["bv"] = ParamDef((kv, hd), (KV_HEADS, HEAD_DIM), init="zeros")
        defs["bo"] = ParamDef((d,), (EMBED,), init="zeros")
    return defs


def project_qkv(params, x, cfg: ModelConfig, positions=None):
    """x: (B, S, D) -> q (B,S,H,hd), k,v (B,S,KV,hd)."""
    q = einsum("bsd,dhk->bshk", x, params["wq"])
    k = einsum("bsd,dhk->bshk", x, params["wk"])
    v = einsum("bsd,dhk->bshk", x, params["wv"])
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    if cfg.use_rope:
        if positions is None:
            positions = jnp.arange(x.shape[1], dtype=jnp.int32)[None, :]
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def out_proj(params, o):
    y = einsum_out("bshk,hkd->bsd", o, params["wo"])
    if "bo" in params:
        y = y + params["bo"]
    return y


def _expand_kv(k, n_heads: int):
    """(B,S,KV,hd) -> (B,S,H,hd) by repeating each KV head."""
    kv = k.shape[-2]
    if kv == n_heads:
        return k
    return jnp.repeat(k, n_heads // kv, axis=-2)


def full_attention(q, k, v, *, causal: bool, q_offset: int = 0):
    """Quadratic attention; fine for seq <= ~8k. q:(B,Sq,H,hd), k/v:(B,Sk,KV,hd)."""
    h = q.shape[-2]
    k = _expand_kv(k, h)
    v = _expand_kv(v, h)
    scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bqhk,bshk->bhqs", q, k,
                        preferred_element_type=jnp.float32) * scale
    if causal:
        sq, sk = q.shape[1], k.shape[1]
        qpos = jnp.arange(sq)[:, None] + q_offset
        kpos = jnp.arange(sk)[None, :]
        logits = jnp.where(qpos >= kpos, logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhqs,bshk->bqhk", w.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.astype(q.dtype)


def chunked_attention(q, k, v, *, causal: bool, q_chunk: int = Q_CHUNK,
                      kv_chunk: int = KV_CHUNK):
    """Online-softmax attention, O(S·chunk) memory. Shapes as full_attention."""
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    k = _expand_kv(k, h)
    v = _expand_kv(v, h)
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, sk)
    assert sq % q_chunk == 0 and sk % kv_chunk == 0
    nq, nk = sq // q_chunk, sk // kv_chunk
    scale = hd ** -0.5

    qs = q.reshape(b, nq, q_chunk, h, hd)
    ks = k.reshape(b, nk, kv_chunk, h, hd)
    vs = v.reshape(b, nk, kv_chunk, h, hd)

    def q_body(qi, q_blk):
        # online softmax over kv chunks
        m0 = jnp.full((b, h, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, q_chunk), jnp.float32)
        o0 = jnp.zeros((b, q_chunk, h, hd), jnp.float32)

        @jax.checkpoint  # flash-style: recompute scores in backward
        def kv_body(carry, inputs):
            m, l, o = carry
            ki, k_blk, v_blk = inputs
            logits = jnp.einsum("bqhk,bshk->bhqs", q_blk, k_blk,
                                preferred_element_type=jnp.float32) * scale
            if causal:
                qpos = qi * q_chunk + jnp.arange(q_chunk)[:, None]
                kpos = ki * kv_chunk + jnp.arange(kv_chunk)[None, :]
                logits = jnp.where(qpos >= kpos, logits, NEG_INF)
            m_new = jnp.maximum(m, logits.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(logits - m_new[..., None])
            l_new = l * alpha + p.sum(axis=-1)
            o_new = o * alpha.transpose(0, 2, 1)[..., None] + jnp.einsum(
                "bhqs,bshk->bqhk", p.astype(v_blk.dtype), v_blk,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, o_new), None

        (m, l, o), _ = jax.lax.scan(
            kv_body, (m0, l0, o0),
            (jnp.arange(nk), ks.swapaxes(0, 1), vs.swapaxes(0, 1)))
        o = o / jnp.maximum(l.transpose(0, 2, 1)[..., None], 1e-30)
        return o.astype(q.dtype)

    outs = jax.lax.map(lambda args: q_body(*args),
                       (jnp.arange(nq), qs.swapaxes(0, 1)))
    return outs.swapaxes(0, 1).reshape(b, sq, h, hd)


def attention(q, k, v, *, causal: bool):
    # full attention for short sequences, chunked online-softmax above
    # FULL_ATTN_MAX_SEQ (the pluggable flash/dry-run impl switch left
    # with the pruned LLM-training skeleton)
    if max(q.shape[1], k.shape[1]) <= FULL_ATTN_MAX_SEQ:
        return full_attention(q, k, v, causal=causal)
    # outer checkpoint keeps cross-layer residuals at O(q,k,v,out);
    # the inner kv_body checkpoint keeps in-attention residuals at
    # O(carry) per chunk — together: flash-attention memory behaviour
    return jax.checkpoint(
        lambda q, k, v: chunked_attention(q, k, v, causal=causal))(q, k, v)


# ---------------------------------------------------------------------------
# Decode paths
# ---------------------------------------------------------------------------


def decode_attention(q, k_cache, v_cache, slot, valid_len):
    """Single-token decode over a pre-written cache (no concat copies —
    the new token's K/V must already sit at `slot`).

    q: (B,1,H,hd); caches (B,S,KV,hd); slot/valid_len: (B,) int32.
    Attends over positions < valid_len plus `slot`.
    """
    h = q.shape[-2]
    s = k_cache.shape[1]
    scale = q.shape[-1] ** -0.5
    k_all = _expand_kv(k_cache, h)
    v_all = _expand_kv(v_cache, h)
    logits = jnp.einsum("bqhk,bshk->bhqs", q, k_all,
                        preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(s)[None, :]
    mask = (pos < valid_len[:, None]) | (pos == slot[:, None])
    logits = jnp.where(mask[:, None, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhqs,bshk->bqhk", w.astype(v_all.dtype), v_all,
                   preferred_element_type=jnp.float32)
    return o.astype(q.dtype)


def write_kv_slot(k_cache, v_cache, k_new, v_new, slot):
    """In-place (donation-friendly) per-batch ring write at `slot`."""
    bidx = jnp.arange(k_cache.shape[0])
    return (k_cache.at[bidx, slot].set(k_new[:, 0].astype(k_cache.dtype)),
            v_cache.at[bidx, slot].set(v_new[:, 0].astype(v_cache.dtype)))


def decode_attention_seqsharded(q, k_cache, v_cache, k_new, v_new,
                                slot, valid_len, topo: Topology):
    """Distributed flash-decode with in-shard cache writes.

    The KV cache is sharded on its sequence axis across one mesh axis
    (`data` for long-context small-batch, `model` for big-cache batched
    decode). Each shard writes the new token's K/V iff it owns `slot`,
    computes partial (max, sum-exp, weighted-V), and partials combine
    exactly via psum — a two-pass-free distributed softmax. No cache
    copy or cross-shard scatter ever materialises.

    Returns (o, new_k_cache, new_v_cache).
    """
    from repro.sharding.rules import KV_SEQ

    mesh = topo.mesh
    axis = topo.rules[KV_SEQ]
    assert axis in topo.axis_sizes, "seq-sharded decode needs a KV_SEQ axis"
    h = q.shape[-2]
    scale = q.shape[-1] ** -0.5
    s_global = k_cache.shape[1]
    n_shards = topo.axis_sizes[axis]
    s_local = s_global // n_shards

    def local(q, k_loc, v_loc, k_new, v_new, slot, valid_len):
        idx = jax.lax.axis_index(axis)
        # write the new token's K/V into the owning shard's slice
        owns = (slot // s_local) == idx  # (B,)
        lslot = slot % s_local
        bidx = jnp.arange(q.shape[0])
        k_upd = jnp.where(owns[:, None, None],
                          k_new[:, 0].astype(k_loc.dtype),
                          k_loc[bidx, lslot])
        v_upd = jnp.where(owns[:, None, None],
                          v_new[:, 0].astype(v_loc.dtype),
                          v_loc[bidx, lslot])
        k_loc = k_loc.at[bidx, lslot].set(k_upd)
        v_loc = v_loc.at[bidx, lslot].set(v_upd)

        k_l = _expand_kv(k_loc, h)
        v_l = _expand_kv(v_loc, h)
        logits = jnp.einsum("bqhk,bshk->bhqs", q, k_l,
                            preferred_element_type=jnp.float32) * scale
        gpos = idx * s_local + jnp.arange(s_local)
        valid = (gpos[None, :] < valid_len[:, None]) | (
            gpos[None, :] == slot[:, None])
        logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
        m = logits.max(axis=-1, keepdims=True)  # (b,h,q,1) local max
        p = jnp.exp(logits - m)
        l = p.sum(axis=-1, keepdims=True)
        o = jnp.einsum("bhqs,bshk->bhqk", p.astype(v_l.dtype), v_l,
                       preferred_element_type=jnp.float32)
        # exact combine across shards (guard all-masked shards)
        m = jnp.where(jnp.isfinite(m), m, NEG_INF)
        g_m = jax.lax.pmax(m, axis)
        corr = jnp.exp(m - g_m)
        l_g = jax.lax.psum(l * corr, axis)
        o_g = jax.lax.psum(o * corr, axis)
        out = (o_g / jnp.maximum(l_g, 1e-30)).transpose(0, 2, 1, 3)
        return out.astype(q.dtype), k_loc, v_loc

    batch_rule = topo.rules["batch"] if axis != "data" else None
    pspec_cache = P(batch_rule, axis, None, None)
    rep = P(batch_rule, None, None, None)
    return compat.shard_map(
        local, mesh=mesh,
        in_specs=(rep, pspec_cache, pspec_cache, rep, rep, P(batch_rule),
                  P(batch_rule)),
        out_specs=(rep, pspec_cache, pspec_cache), check_vma=False,
    )(q, k_cache, v_cache, k_new, v_new, slot, valid_len)
