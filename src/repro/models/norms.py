"""Normalisation layers (fp32 internals, params in model dtype)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.common import ParamDef
from repro.sharding.rules import EMBED


def norm_defs(d_model: int, kind: str) -> dict:
    defs = {"scale": ParamDef((d_model,), (EMBED,), init="ones")}
    if kind == "layernorm":
        defs["bias"] = ParamDef((d_model,), (EMBED,), init="zeros")
    return defs


def apply_norm(params: dict, x, kind: str, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * (var + eps) ** -0.5
        y = y * params["scale"].astype(jnp.float32)
    elif kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * (var + eps) ** -0.5
        y = y * params["scale"].astype(jnp.float32)
        if "bias" in params:
            y = y + params["bias"].astype(jnp.float32)
    else:
        raise ValueError(kind)
    return y.astype(x.dtype)
