"""xLSTM mixers: chunkwise-parallel stabilized mLSTM and recurrent sLSTM.

mLSTM (matrix memory, exponential gating): training/prefill uses the
chunkwise form — within a chunk a masked quadratic (like linear
attention), across chunks an exact recurrence on the stabilized carry
(C_hat, n_hat, m) with C_true = exp(m)·C_hat. Derivation (chunk-local
cumsum F, g_s = i_s − F_s, M_t = max(m_prev, cummax g_s), m_t = F_t+M_t):

  Ĉ_t = exp(m_prev−M_t)·Ĉ_prev + Σ_{s≤t} exp(g_s−M_t)·k_s v_sᵀ
  h_t = (q_t·Ĉ_t) / max(|q_t·n̂_t|, exp(−m_t))

which reduces to the official single-step stabilized recurrence for
chunk length 1. sLSTM (scalar memory, block-diagonal recurrence) is
inherently sequential — `lax.scan` over time, exactly as the xLSTM
paper prescribes (and why only 1 block in 8 is sLSTM).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamDef, einsum, einsum_out
from repro.sharding.rules import (
    CONV,
    EMBED,
    FFN,
    HEAD_DIM,
    INNER,
    Q_HEADS,
    Topology,
)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def mlstm_defs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di = cfg.xlstm_expand * d
    h = cfg.n_heads
    hd = di // h
    k = cfg.mamba_d_conv
    return {
        "in_proj": ParamDef((d, 2 * di), (EMBED, INNER)),
        "conv_w": ParamDef((k, di), (CONV, INNER), scale=0.5),
        "conv_b": ParamDef((di,), (INNER,), init="zeros"),
        "wq": ParamDef((h, hd, hd), (Q_HEADS, HEAD_DIM, None)),
        "wk": ParamDef((h, hd, hd), (Q_HEADS, HEAD_DIM, None)),
        "wv": ParamDef((h, hd, hd), (Q_HEADS, HEAD_DIM, None)),
        "w_i": ParamDef((di, h), (INNER, None), scale=0.1),
        "b_i": ParamDef((h,), (None,), init="zeros"),
        "w_f": ParamDef((di, h), (INNER, None), scale=0.1),
        "b_f": ParamDef((h,), (None,), init="ones", scale=3.0),
        "out_norm": ParamDef((di,), (INNER,), init="ones"),
        "out_proj": ParamDef((di, d), (INNER, EMBED)),
    }


class MLSTMState(NamedTuple):
    c: jax.Array  # (B, H, dk, dv) fp32, stabilized
    n: jax.Array  # (B, H, dk) fp32, stabilized
    m: jax.Array  # (B, H) fp32 stabilizer
    conv: jax.Array  # (B, k-1, di)


def init_mlstm_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> MLSTMState:
    di = cfg.xlstm_expand * cfg.d_model
    h = cfg.n_heads
    hd = di // h
    return MLSTMState(
        c=jnp.zeros((batch, h, hd, hd), jnp.float32),
        n=jnp.zeros((batch, h, hd), jnp.float32),
        m=jnp.full((batch, h), -1e30, jnp.float32),
        conv=jnp.zeros((batch, cfg.mamba_d_conv - 1, di), dtype),
    )


def _mlstm_qkvgates(params, x, cfg: ModelConfig, conv_state=None):
    """x: (B,T,d). Returns q,k,v (B,T,H,hd); i_log,f_log (B,T,H); z (B,T,di);
    new conv state."""
    from repro.models.mamba import _causal_conv

    b, t, d = x.shape
    di = cfg.xlstm_expand * d
    h = cfg.n_heads
    hd = di // h
    xz = einsum("btd,de->bte", x, params["in_proj"])
    xin, z = jnp.split(xz, 2, axis=-1)
    xc, new_conv = _causal_conv(xin, params["conv_w"], params["conv_b"],
                                conv_state)
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)
    xh = xc.reshape(b, t, h, hd)
    q = einsum("bthd,hde->bthe", xh, params["wq"])
    k = einsum("bthd,hde->bthe", xh, params["wk"]) * (hd ** -0.5)
    v = einsum("bthd,hde->bthe", xin.reshape(b, t, h, hd), params["wv"])
    i_log = einsum("btd,dh->bth", xc, params["w_i"],
                   dtype=jnp.float32) + params["b_i"].astype(jnp.float32)
    f_raw = einsum("btd,dh->bth", xc, params["w_f"],
                   dtype=jnp.float32) + params["b_f"].astype(jnp.float32)
    f_log = jax.nn.log_sigmoid(f_raw)
    return q, k, v, i_log, f_log, z, new_conv


def _mlstm_chunk(q, k, v, i_log, f_log, state):
    """One chunk. q,k,v: (B,L,H,hd); gates (B,L,H). state: (c,n,m)."""
    b, el, h, hd = q.shape
    qf = q.astype(jnp.float32).transpose(0, 2, 1, 3)  # (B,H,L,hd)
    kf = k.astype(jnp.float32).transpose(0, 2, 1, 3)
    vf = v.astype(jnp.float32).transpose(0, 2, 1, 3)
    i_l = i_log.transpose(0, 2, 1)  # (B,H,L)
    f_l = f_log.transpose(0, 2, 1)
    c_prev, n_prev, m_prev = state

    F = jnp.cumsum(f_l, axis=-1)  # inclusive
    g = i_l - F  # (B,H,L)
    M = jnp.maximum(m_prev[..., None], jax.lax.cummax(g, axis=2))  # (B,H,L)
    m_t = F + M

    # intra-chunk: scores_ts = (q_t·k_s)·exp(g_s − M_t), s ≤ t
    qk = jnp.einsum("bhte,bhse->bhts", qf, kf,
                    preferred_element_type=jnp.float32)
    decay = jnp.exp(g[:, :, None, :] - M[..., None])  # (B,H,t,s)
    mask = jnp.tril(jnp.ones((el, el), bool))
    w = jnp.where(mask, qk * decay, 0.0)
    num_intra = jnp.einsum("bhts,bhse->bhte", w, vf,
                           preferred_element_type=jnp.float32)
    den_intra = w.sum(axis=-1)  # (B,H,L)

    # inter-chunk history
    inter_scale = jnp.exp(m_prev[..., None] - M)  # (B,H,L)
    qc = jnp.einsum("bhte,bhef->bhtf", qf, c_prev,
                    preferred_element_type=jnp.float32)
    num_inter = qc * inter_scale[..., None]
    den_inter = jnp.einsum("bhte,bhe->bht", qf, n_prev,
                           preferred_element_type=jnp.float32) * inter_scale

    num = num_intra + num_inter
    den = den_intra + den_inter
    floor = jnp.exp(-m_t)
    h_out = num / jnp.maximum(jnp.abs(den), floor)[..., None]

    # carry update
    m_end = M[..., -1]  # = max(m_prev, max_s g_s)
    scale_hist = jnp.exp(m_prev - m_end)[..., None, None]
    kv_scale = jnp.exp(g - m_end[..., None])  # (B,H,L)
    c_new = scale_hist * c_prev + jnp.einsum(
        "bhse,bhsf,bhs->bhef", kf, vf, kv_scale,
        preferred_element_type=jnp.float32)
    n_new = scale_hist[..., 0] * n_prev + jnp.einsum(
        "bhse,bhs->bhe", kf, kv_scale, preferred_element_type=jnp.float32)
    m_new = F[..., -1] + m_end
    return h_out.transpose(0, 2, 1, 3), (c_new, n_new, m_new)


def _head_rmsnorm(h, scale, eps=1e-6):
    """Per-head RMS norm on (B,T,H,hd), scale (di,)."""
    b, t, nh, hd = h.shape
    var = jnp.mean(h * h, axis=-1, keepdims=True)
    y = h * (var + eps) ** -0.5
    return y.reshape(b, t, nh * hd) * scale.astype(jnp.float32)


def apply_mlstm(params, x, cfg: ModelConfig, topo: Topology | None = None,
                state: MLSTMState | None = None):
    """x: (B,T,d) -> (y, final state)."""
    b, t, d = x.shape
    di = cfg.xlstm_expand * d
    conv_state = state.conv if state is not None else None
    q, k, v, i_log, f_log, z, new_conv = _mlstm_qkvgates(
        params, x, cfg, conv_state)
    if state is None:
        h_heads = cfg.n_heads
        hd = di // h_heads
        carry = (jnp.zeros((b, h_heads, hd, hd), jnp.float32),
                 jnp.zeros((b, h_heads, hd), jnp.float32),
                 jnp.full((b, h_heads), -1e30, jnp.float32))
    else:
        carry = (state.c, state.n, state.m)

    chunk = min(cfg.chunk_size, t)
    if t % chunk:
        chunk = t
    nc = t // chunk

    def body(c, inp):
        qc, kc, vc, ic, fc = inp
        h_out, c_new = _mlstm_chunk(qc, kc, vc, ic, fc, c)
        return c_new, h_out

    def split(a):
        return a.reshape(b, nc, chunk, *a.shape[2:]).swapaxes(0, 1)

    carry, hs = jax.lax.scan(
        body, carry, (split(q), split(k), split(v), split(i_log), split(f_log)))
    hs = hs.swapaxes(0, 1).reshape(b, t, cfg.n_heads, -1)
    y = _head_rmsnorm(hs, params["out_norm"])
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = einsum_out("bte,ed->btd", y, params["out_proj"])
    return out, MLSTMState(c=carry[0], n=carry[1], m=carry[2], conv=new_conv)


def mlstm_decode_step(params, x, cfg: ModelConfig, state: MLSTMState):
    """Official stabilized single-step recurrence. x: (B,1,d)."""
    q, k, v, i_log, f_log, z, new_conv = _mlstm_qkvgates(
        params, x, cfg, state.conv)
    qf = q[:, 0].astype(jnp.float32)  # (B,H,hd)
    kf = k[:, 0].astype(jnp.float32)
    vf = v[:, 0].astype(jnp.float32)
    i_l = i_log[:, 0]  # (B,H)
    f_l = f_log[:, 0]
    m_new = jnp.maximum(f_l + state.m, i_l)
    f_s = jnp.exp(f_l + state.m - m_new)[..., None]
    i_s = jnp.exp(i_l - m_new)[..., None]
    c = f_s[..., None] * state.c + i_s[..., None] * (
        kf[..., :, None] * vf[..., None, :])
    n = f_s * state.n + i_s * kf
    num = jnp.einsum("bhe,bhef->bhf", qf, c,
                     preferred_element_type=jnp.float32)
    den = jnp.einsum("bhe,bhe->bh", qf, n,
                     preferred_element_type=jnp.float32)
    floor = jnp.exp(-m_new)
    h = num / jnp.maximum(jnp.abs(den), floor)[..., None]
    y = _head_rmsnorm(h[:, None].transpose(0, 1, 2, 3).reshape(
        x.shape[0], 1, cfg.n_heads, -1), params["out_norm"])
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = einsum("bte,ed->btd", y, params["out_proj"])
    return out, MLSTMState(c=c, n=n, m=m_new, conv=new_conv)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_defs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    h = cfg.n_heads
    hd = d // h
    d_ffs = int(round(4 * d / 3 / 64)) * 64 or 64
    gate = lambda: {
        "w": ParamDef((d, d), (EMBED, INNER)),
        "r": ParamDef((h, hd, hd), (Q_HEADS, HEAD_DIM, None)),
        "b": ParamDef((d,), (INNER,), init="zeros"),
    }
    return {
        "gi": gate(), "gf": gate(), "gz": gate(), "go": gate(),
        "out_norm": ParamDef((d,), (EMBED,), init="ones"),
        "ffn": {
            "w_up": ParamDef((d, d_ffs), (EMBED, FFN)),
            "w_down": ParamDef((d_ffs, d), (FFN, EMBED)),
        },
    }


class SLSTMState(NamedTuple):
    c: jax.Array  # (B, d) fp32
    n: jax.Array
    h: jax.Array
    m: jax.Array


def init_slstm_state(cfg: ModelConfig, batch: int) -> SLSTMState:
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return SLSTMState(c=z, n=z, h=z, m=jnp.full((batch, d), -1e30, jnp.float32))


def _slstm_step(params, x_t, st: SLSTMState, cfg: ModelConfig):
    """x_t: (B,d). Block-diagonal recurrence per head."""
    b, d = x_t.shape
    h_heads = cfg.n_heads
    hd = d // h_heads
    h_prev = st.h.reshape(b, h_heads, hd)

    def gate(g):
        wx = einsum("bd,de->be", x_t, params[g]["w"], dtype=jnp.float32)
        rh = jnp.einsum("bhd,hde->bhe", h_prev, params[g]["r"].astype(jnp.float32),
                        preferred_element_type=jnp.float32).reshape(b, d)
        return wx + rh + params[g]["b"].astype(jnp.float32)

    i_t, f_t, z_t, o_t = gate("gi"), gate("gf"), gate("gz"), gate("go")
    f_log = jax.nn.log_sigmoid(f_t)
    m_new = jnp.maximum(f_log + st.m, i_t)
    i_s = jnp.exp(i_t - m_new)
    f_s = jnp.exp(f_log + st.m - m_new)
    c = f_s * st.c + i_s * jnp.tanh(z_t)
    n = f_s * st.n + i_s
    h = jax.nn.sigmoid(o_t) * c / jnp.maximum(n, 1e-6)
    return SLSTMState(c=c, n=n, h=h, m=m_new)


def apply_slstm(params, x, cfg: ModelConfig, topo: Topology | None = None,
                state: SLSTMState | None = None):
    """x: (B,T,d) -> (y, final state). Sequential scan (faithful to paper)."""
    b, t, d = x.shape
    st = state if state is not None else init_slstm_state(cfg, b)

    def body(st, x_t):
        st2 = _slstm_step(params, x_t, st, cfg)
        return st2, st2.h

    st_f, hs = jax.lax.scan(body, st, x.swapaxes(0, 1))
    hs = hs.swapaxes(0, 1)  # (B,T,d) fp32
    var = jnp.mean(hs * hs, axis=-1, keepdims=True)
    y = hs * (var + 1e-6) ** -0.5 * params["out_norm"].astype(jnp.float32)
    # post-up-projection FFN (xLSTM paper: factor 4/3, GeLU)
    up = einsum("btd,df->btf", y.astype(x.dtype), params["ffn"]["w_up"])
    up = jax.nn.gelu(up.astype(jnp.float32)).astype(x.dtype)
    out = einsum_out("btf,fd->btd", up, params["ffn"]["w_down"])
    return out, st_f


def slstm_decode_step(params, x, cfg: ModelConfig, state: SLSTMState):
    y, st = apply_slstm(params, x, cfg, None, state)
    return y, st

