"""Channel-mixing FFNs: SwiGLU / GeGLU / non-gated GELU(+bias)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamDef, einsum, einsum_out
from repro.sharding.rules import EMBED, FFN


def mlp_defs(cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    if cfg.mlp_variant in ("swiglu", "geglu"):
        defs = {
            "w_gate": ParamDef((d, f), (EMBED, FFN)),
            "w_up": ParamDef((d, f), (EMBED, FFN)),
            "w_down": ParamDef((f, d), (FFN, EMBED)),
        }
    else:  # non-gated
        defs = {
            "w_up": ParamDef((d, f), (EMBED, FFN)),
            "w_down": ParamDef((f, d), (FFN, EMBED)),
        }
    if cfg.mlp_bias:
        defs["b_up"] = ParamDef((f,), (FFN,), init="zeros")
        defs["b_down"] = ParamDef((d,), (EMBED,), init="zeros")
    return defs


def _act(cfg: ModelConfig, x):
    if cfg.mlp_variant == "swiglu":
        return jax.nn.silu(x)
    return jax.nn.gelu(x)


def apply_mlp(params: dict, x, cfg: ModelConfig):
    """x: (..., d_model)."""
    up = einsum("...d,df->...f", x, params["w_up"])
    if "b_up" in params:
        up = up + params["b_up"]
    if "w_gate" in params:
        gate = einsum("...d,df->...f", x, params["w_gate"])
        h = _act(cfg, gate.astype(jnp.float32)).astype(x.dtype) * up
    else:
        h = _act(cfg, up.astype(jnp.float32)).astype(x.dtype)
    y = einsum_out("...f,fd->...d", h, params["w_down"])
    if "b_down" in params:
        y = y + params["b_down"]
    return y
