"""Mamba-1 selective SSM mixer.

Training/prefill uses a chunked parallel scan: `lax.scan` over sequence
chunks with a `lax.associative_scan` inside each chunk, so the
materialised state tensor is (B, chunk, d_inner, d_state) instead of
(B, T, d_inner, d_state). Decode is the exact single-step recurrence
with (ssm_state, conv_state) carried in the cache.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamDef, einsum, einsum_out
from repro.sharding.rules import CONV, EMBED, INNER, STATE, Topology


def _dt_rank(cfg: ModelConfig) -> int:
    return max(1, cfg.d_model // 16)


def mamba_defs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di = cfg.mamba_expand * d
    ds = cfg.mamba_d_state
    k = cfg.mamba_d_conv
    dtr = _dt_rank(cfg)
    return {
        "in_proj": ParamDef((d, 2 * di), (EMBED, INNER)),
        "conv_w": ParamDef((k, di), (CONV, INNER), scale=0.5),
        "conv_b": ParamDef((di,), (INNER,), init="zeros"),
        "x_proj": ParamDef((di, dtr + 2 * ds), (INNER, None)),
        "dt_proj": ParamDef((dtr, di), (None, INNER)),
        "dt_bias": ParamDef((di,), (INNER,), init="zeros"),
        "A_log": ParamDef((di, ds), (INNER, STATE), init="ones"),
        "D": ParamDef((di,), (INNER,), init="ones"),
        "out_proj": ParamDef((di, d), (INNER, EMBED)),
    }


class MambaState(NamedTuple):
    ssm: jax.Array  # (B, d_inner, d_state) fp32
    conv: jax.Array  # (B, d_conv - 1, d_inner)


def init_mamba_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> MambaState:
    di = cfg.mamba_expand * cfg.d_model
    return MambaState(
        ssm=jnp.zeros((batch, di, cfg.mamba_d_state), jnp.float32),
        conv=jnp.zeros((batch, cfg.mamba_d_conv - 1, di), dtype),
    )


def _causal_conv(x, conv_w, conv_b, conv_state=None):
    """Depthwise causal conv along seq. x: (B,T,di); conv_w: (k,di)."""
    k = conv_w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, T+k-1, di)
    out = sum(xp[:, i:i + x.shape[1]] * conv_w[i] for i in range(k))
    return out + conv_b, xp[:, -(k - 1):]  # new conv state = last k-1 inputs


def _ssm_inputs(params, xc, cfg: ModelConfig):
    """xc: (B,T,di) post-conv post-act. Returns deltaA (B,T,di,ds) and
    deltaBx (B,T,di,ds) plus C-matrix (B,T,ds)."""
    dtr = _dt_rank(cfg)
    ds = cfg.mamba_d_state
    proj = einsum("btd,de->bte", xc, params["x_proj"], dtype=jnp.float32)
    dt, bmat, cmat = jnp.split(proj, [dtr, dtr + ds], axis=-1)
    dt = jax.nn.softplus(
        einsum("btr,rd->btd", dt.astype(xc.dtype), params["dt_proj"],
               dtype=jnp.float32) + params["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(params["A_log"].astype(jnp.float32))  # (di, ds)
    delta_a = jnp.exp(dt[..., None] * a)  # (B,T,di,ds)
    delta_bx = (dt * xc.astype(jnp.float32))[..., None] * bmat[:, :, None, :]
    return delta_a, delta_bx, cmat


def _scan_chunk(carry_h, delta_a, delta_bx):
    """Associative scan within one chunk. carry_h: (B,di,ds)."""

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a2 * a1, a2 * b1 + b2

    cum_a, h_local = jax.lax.associative_scan(
        combine, (delta_a, delta_bx), axis=1)
    h = h_local + cum_a * carry_h[:, None]
    return h, h[:, -1]


def apply_mamba(params, x, cfg: ModelConfig, topo: Topology | None = None,
                state: MambaState | None = None):
    """Full-sequence mixer. x: (B,T,d) -> (y (B,T,d), final MambaState)."""
    b, t, d = x.shape
    di = cfg.mamba_expand * d
    xz = einsum("btd,de->bte", x, params["in_proj"])
    xin, z = jnp.split(xz, 2, axis=-1)
    if topo is not None:
        xin = topo.constrain(xin, "batch", None, INNER)
    conv_state = state.conv if state is not None else None
    xc, new_conv = _causal_conv(xin, params["conv_w"], params["conv_b"],
                                conv_state)
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)
    delta_a, delta_bx, cmat = _ssm_inputs(params, xc, cfg)

    h0 = state.ssm if state is not None else jnp.zeros(
        (b, di, cfg.mamba_d_state), jnp.float32)
    chunk = min(cfg.chunk_size, t)
    if t % chunk:
        chunk = t  # fallback: single chunk
    nc = t // chunk

    def body(h, inp):
        da, dbx = inp
        hs, h_new = _scan_chunk(h, da, dbx)
        return h_new, hs

    da_c = delta_a.reshape(b, nc, chunk, di, -1).swapaxes(0, 1)
    dbx_c = delta_bx.reshape(b, nc, chunk, di, -1).swapaxes(0, 1)
    h_final, hs = jax.lax.scan(body, h0, (da_c, dbx_c))
    hs = hs.swapaxes(0, 1).reshape(b, t, di, -1)
    y = jnp.einsum("btds,bts->btd", hs, cmat,
                   preferred_element_type=jnp.float32)
    y = y + params["D"].astype(jnp.float32) * xc.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = einsum_out("bte,ed->btd", y, params["out_proj"])
    return out, MambaState(ssm=h_final, conv=new_conv)


def mamba_decode_step(params, x, cfg: ModelConfig, state: MambaState):
    """x: (B,1,d) -> (y (B,1,d), new state). Exact recurrence."""
    b = x.shape[0]
    xz = einsum("btd,de->bte", x, params["in_proj"])
    xin, z = jnp.split(xz, 2, axis=-1)
    xc, new_conv = _causal_conv(xin, params["conv_w"], params["conv_b"],
                                state.conv)
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)
    delta_a, delta_bx, cmat = _ssm_inputs(params, xc, cfg)
    h = delta_a[:, 0] * state.ssm + delta_bx[:, 0]  # (B,di,ds)
    y = jnp.einsum("bds,bs->bd", h, cmat[:, 0],
                   preferred_element_type=jnp.float32)[:, None]
    y = y + params["D"].astype(jnp.float32) * xc.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = einsum_out("bte,ed->btd", y, params["out_proj"])
    return out, MambaState(ssm=h, conv=new_conv)


def mamba_ref(params, x, cfg: ModelConfig):
    """Pure sequential reference (oracle for tests)."""
    b, t, d = x.shape
    state = init_mamba_state(cfg, b, x.dtype)
    ys = []
    for i in range(t):
        y, state = mamba_decode_step(params, x[:, i:i + 1], cfg, state)
        ys.append(y)
    return jnp.concatenate(ys, axis=1), state
