"""Mixture-of-Experts FFN.

Execution paths:

* `moe_dense_oracle` — computes every expert for every token, weighted by
  the router. O(E·T·d·f): tests-only correctness oracle.
* `apply_moe` — production path under `shard_map`:
  - tokens are local to each data shard (no global sort);
  - **EP** (experts sharded over `model`): each model shard additionally
    takes a 1/ep slice of the local tokens, routes them, packs a static
    `(E, cap, d)` buffer via argsort-by-expert, `all_to_all` exchanges
    expert buffers, batched expert matmuls run on the local E/ep experts,
    a second `all_to_all` returns outputs, and an `all_gather` restores
    the token axis. This is the Switch/MegaBlocks dispatch mapped onto
    TPU ICI collectives.
  - **TP-in-expert** fallback (expert FFN dim sharded over `model`):
    dispatch is replicated over `model`, expert matmuls are sliced on the
    FFN dim, outputs psum over `model`. Used when E doesn't divide the
    mesh or per-token work is too small for all_to_all (decode).

Capacity-factor token dropping follows standard practice: overflow
tokens contribute zero and flow through the residual. Router aux loss is
Switch-style `E · Σ_e f_e · p_e`.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import ModelConfig
from repro.models.common import ParamDef, einsum
from repro.models.mlp import apply_mlp
from repro.sharding.rules import BATCH, EMBED, EXPERT_FFN, EXPERTS, Topology


def moe_defs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    f = cfg.moe_d_ff or cfg.d_ff
    e = cfg.n_experts
    defs = {
        "router": ParamDef((d, e), (EMBED, EXPERTS)),
        "w_gate": ParamDef((e, d, f), (EXPERTS, EMBED, EXPERT_FFN)),
        "w_up": ParamDef((e, d, f), (EXPERTS, EMBED, EXPERT_FFN)),
        "w_down": ParamDef((e, f, d), (EXPERTS, EXPERT_FFN, EMBED)),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        defs["shared"] = {
            "w_gate": ParamDef((d, fs), (EMBED, EXPERT_FFN)),
            "w_up": ParamDef((d, fs), (EMBED, EXPERT_FFN)),
            "w_down": ParamDef((fs, d), (EXPERT_FFN, EMBED)),
        }
    return defs


def _capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = int(cfg.capacity_factor * n_tokens * cfg.n_experts_per_token
            / cfg.n_experts)
    return max(cfg.n_experts_per_token, c)


def _router_probs(params, x, cfg: ModelConfig):
    logits = einsum("...d,de->...e", x, params["router"], dtype=jnp.float32)
    return jax.nn.softmax(logits, axis=-1)


def _aux_loss(probs, expert_ids, cfg: ModelConfig):
    one_hot = jax.nn.one_hot(expert_ids, cfg.n_experts)  # (T, k, E)
    f = one_hot.sum(axis=(0, 1)) / (probs.shape[0] * cfg.n_experts_per_token)
    p = probs.mean(axis=0)
    return cfg.n_experts * jnp.sum(f * p)


def _expert_ffn(cfg, w_gate, w_up, w_down, h):
    """h: (E, C, d); weights (E, d, f)/(E, f, d) (possibly f-shards)."""
    up = jnp.einsum("ecd,edf->ecf", h, w_up, preferred_element_type=jnp.float32)
    gate = jnp.einsum("ecd,edf->ecf", h, w_gate,
                      preferred_element_type=jnp.float32)
    act = jax.nn.silu(gate) if cfg.mlp_variant == "swiglu" else jax.nn.gelu(gate)
    inner = (act * up).astype(h.dtype)
    return jnp.einsum("ecf,efd->ecd", inner, w_down,
                      preferred_element_type=jnp.float32)


def _dispatch(x_flat, expert_ids, cfg: ModelConfig, capacity: int):
    """Pack tokens into a static (E, capacity, d) buffer, sorted by expert.

    Returns (buffer, dst_e, dst_c, keep, token_idx, order)."""
    t, d = x_flat.shape
    e, k = cfg.n_experts, cfg.n_experts_per_token
    flat_ids = expert_ids.reshape(-1)  # (T*k,)
    order = jnp.argsort(flat_ids)  # stable
    sorted_ids = flat_ids[order]
    counts = jnp.bincount(flat_ids, length=e)
    offsets = jnp.cumsum(counts) - counts
    rank = jnp.arange(t * k) - offsets[sorted_ids]
    keep = rank < capacity
    token_idx = order // k
    dst_e = jnp.where(keep, sorted_ids, 0)
    dst_c = jnp.where(keep, rank, 0)
    src = x_flat[token_idx] * keep[:, None].astype(x_flat.dtype)
    buf = jnp.zeros((e, capacity, d), x_flat.dtype).at[dst_e, dst_c].add(src)
    return buf, dst_e, dst_c, keep, token_idx, order


def _combine(out_buf, dst_e, dst_c, keep, token_idx, order, weights, t):
    gathered = out_buf[dst_e, dst_c].astype(jnp.float32) * keep[:, None]
    w_flat = weights.reshape(-1)[order]
    d = gathered.shape[-1]
    return jnp.zeros((t, d), jnp.float32).at[token_idx].add(
        gathered * w_flat[:, None])


def moe_routed_local(params, x_flat, cfg: ModelConfig, *, capacity: int,
                     psum_axis: Optional[str] = None):
    """Routed experts over local tokens (no shared experts). (T,d)->(T,d) fp32."""
    t, _ = x_flat.shape
    probs = _router_probs(params, x_flat, cfg)
    weights, expert_ids = jax.lax.top_k(probs, cfg.n_experts_per_token)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    buf, dst_e, dst_c, keep, token_idx, order = _dispatch(
        x_flat, expert_ids, cfg, capacity)
    out_buf = _expert_ffn(cfg, params["w_gate"], params["w_up"],
                          params["w_down"], buf)
    if psum_axis is not None:
        out_buf = jax.lax.psum(out_buf, psum_axis)
    y = _combine(out_buf, dst_e, dst_c, keep, token_idx, order, weights, t)
    aux = _aux_loss(probs, expert_ids, cfg)
    return y, aux


def apply_moe(params, x, cfg: ModelConfig, topo: Topology):
    """x: (B, S, d) -> (y, aux_loss)."""
    b, s, d = x.shape
    mesh = topo.mesh

    if mesh.devices.size == 1:
        x_flat = x.reshape(-1, d)
        y, aux = moe_routed_local(params, x_flat, cfg,
                                  capacity=_capacity(cfg, b * s))
        if cfg.n_shared_experts:
            y = y + apply_mlp(params["shared"], x_flat, cfg).astype(jnp.float32)
        return y.astype(x.dtype).reshape(b, s, d), aux

    data_axes = topo.data_axes
    dp = topo.dp_size
    ep = topo.tp_size
    batch_rule = topo.rules[BATCH]
    x_spec = P(batch_rule, None, None)
    expert_rule = topo.rules[EXPERTS]
    ffn_rule = topo.rules[EXPERT_FFN]
    # local tokens per data shard
    t_local = (b // max(dp, 1)) * s if batch_rule else b * s

    use_ep = (expert_rule is not None and t_local % ep == 0
              and t_local >= ep * cfg.n_experts_per_token)

    if use_ep:
        return _apply_moe_ep(params, x, cfg, topo, x_spec)
    if expert_rule is not None:
        # decode-sized token counts: weights stay expert-sharded; every
        # shard routes the (tiny, already-replicated) token set against
        # its local experts and outputs psum over `model`. Moves O(T·d)
        # instead of all-gathering O(E·d·f) weights (§Perf H2:
        # 158 GB/step -> ~MB/step on a 52B MoE decode).
        return _apply_moe_ep_small(params, x, cfg, topo, x_spec)

    # ---- TP-in-expert fallback: dispatch replicated over `model` -------
    w_e = P(None, None, ffn_rule)
    w_d = P(None, ffn_rule, None)
    p_specs = {"router": P(None, None), "w_gate": w_e, "w_up": w_e,
               "w_down": w_d}
    if cfg.n_shared_experts:
        p_specs["shared"] = {"w_gate": P(None, ffn_rule),
                             "w_up": P(None, ffn_rule),
                             "w_down": P(ffn_rule, None)}
    capacity = _capacity(cfg, t_local)

    def body(params, x_local):
        bl, sl, _ = x_local.shape
        x_flat = x_local.reshape(-1, d)
        y, aux = moe_routed_local(params, x_flat, cfg, capacity=capacity,
                                  psum_axis="model" if ffn_rule else None)
        if cfg.n_shared_experts:
            ys = apply_mlp(params["shared"], x_flat, cfg).astype(jnp.float32)
            if ffn_rule:
                ys = jax.lax.psum(ys, "model")
            y = y + ys
        aux = jax.lax.pmean(aux, data_axes) if data_axes else aux
        return y.astype(x_local.dtype).reshape(bl, sl, d), aux

    return compat.shard_map(body, mesh=mesh, in_specs=(p_specs, x_spec),
                         out_specs=(x_spec, P()), check_vma=False)(params, x)


def _apply_moe_ep_small(params, x, cfg: ModelConfig, topo: Topology, x_spec):
    """Expert-parallel MoE for small token counts (decode): each model
    shard computes its local experts over ALL local tokens; outputs
    combine with one psum. No weight movement."""
    mesh = topo.mesh
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.n_experts_per_token
    ep = topo.tp_size
    e_local = e // ep
    data_axes = topo.data_axes
    w_e = P("model", None, None)
    p_specs = {"router": P(None, None), "w_gate": w_e, "w_up": w_e,
               "w_down": w_e}
    if cfg.n_shared_experts:
        p_specs["shared"] = {"w_gate": P(None, "model"),
                             "w_up": P(None, "model"),
                             "w_down": P("model", None)}

    def body(params, x_local):
        bl, sl, _ = x_local.shape
        x_flat = x_local.reshape(-1, d)
        t = x_flat.shape[0]
        idx = jax.lax.axis_index("model")
        e_lo = idx * e_local
        probs = _router_probs(params, x_flat, cfg)
        weights, expert_ids = jax.lax.top_k(probs, k)
        weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
        cap = t * k  # no drops at decode size
        flat_ids = expert_ids.reshape(-1)
        owned = (flat_ids >= e_lo) & (flat_ids < e_lo + e_local)
        local_ids = jnp.where(owned, flat_ids - e_lo, 0)
        slot = jnp.arange(t * k)
        token_idx = slot // k
        src = x_flat[token_idx] * owned[:, None].astype(x_flat.dtype)
        buf = jnp.zeros((e_local, cap, d), x_flat.dtype).at[
            local_ids, slot].add(src)
        out = _expert_ffn(cfg, params["w_gate"], params["w_up"],
                          params["w_down"], buf)
        gathered = out[local_ids, slot].astype(jnp.float32) * owned[:, None]
        w_flat = weights.reshape(-1)
        y = jnp.zeros((t, d), jnp.float32).at[token_idx].add(
            gathered * w_flat[:, None])
        if cfg.n_shared_experts:
            ys = apply_mlp(params["shared"], x_flat, cfg).astype(jnp.float32)
            y = y + ys  # shared partials join the same psum below
        y = jax.lax.psum(y, "model")
        aux = _aux_loss(probs, expert_ids, cfg)
        aux = jax.lax.pmean(aux, data_axes) if data_axes else aux
        return y.astype(x_local.dtype).reshape(bl, sl, d), aux

    return compat.shard_map(body, mesh=mesh, in_specs=(p_specs, x_spec),
                         out_specs=(x_spec, P()), check_vma=False)(params, x)


def _apply_moe_ep(params, x, cfg: ModelConfig, topo: Topology, x_spec):
    """Expert parallelism with token-slicing over `model` during dispatch."""
    mesh = topo.mesh
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.n_experts_per_token
    ep = topo.tp_size
    e_local = e // ep
    data_axes = topo.data_axes
    w_e = P("model", None, None)
    p_specs = {"router": P(None, None), "w_gate": w_e, "w_up": w_e,
               "w_down": w_e}
    if cfg.n_shared_experts:
        # shared experts: TP on their FFN dim over `model`
        p_specs["shared"] = {"w_gate": P(None, "model"),
                             "w_up": P(None, "model"),
                             "w_down": P("model", None)}

    def body(params, x_local):
        bl, sl, _ = x_local.shape
        x_flat = x_local.reshape(-1, d)
        t = x_flat.shape[0]
        tm = t // ep
        idx = jax.lax.axis_index("model")
        x_me = jax.lax.dynamic_slice_in_dim(x_flat, idx * tm, tm, 0)

        probs = _router_probs(params, x_me, cfg)
        weights, expert_ids = jax.lax.top_k(probs, k)
        weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
        cap = _capacity(cfg, tm)
        buf, dst_e, dst_c, keep, token_idx, order = _dispatch(
            x_me, expert_ids, cfg, cap)
        # (E, cap, d) -> (E/ep, ep*cap, d): shard i keeps experts
        # [i*e_local, (i+1)*e_local) with buffers from every model shard.
        buf = jax.lax.all_to_all(buf, "model", split_axis=0, concat_axis=1,
                                 tiled=True)
        out = _expert_ffn(cfg, params["w_gate"], params["w_up"],
                          params["w_down"], buf).astype(x_flat.dtype)
        # inverse exchange: (E/ep, ep*cap, d) -> (E, cap, d)
        out = jax.lax.all_to_all(out, "model", split_axis=1, concat_axis=0,
                                 tiled=True)
        y_me = _combine(out, dst_e, dst_c, keep, token_idx, order, weights, tm)
        y = jax.lax.all_gather(y_me, "model", axis=0, tiled=True)  # (t, d)
        if cfg.n_shared_experts:
            # shared experts = TP over `model` on the FFN dim, computed on
            # the full local token set (every shard holds all t tokens).
            ys = apply_mlp(params["shared"], x_flat, cfg).astype(jnp.float32)
            y = y + jax.lax.psum(ys, "model")
        y = y.astype(x_local.dtype)
        aux = _aux_loss(probs, expert_ids, cfg)
        aux = jax.lax.pmean(aux, ("model", *data_axes))
        return y.reshape(bl, sl, d), aux

    return compat.shard_map(body, mesh=mesh, in_specs=(p_specs, x_spec),
                         out_specs=(x_spec, P()), check_vma=False)(params, x)


def moe_dense_oracle(params, x, cfg: ModelConfig):
    """All-experts reference (tests only)."""
    b, s, d = x.shape
    x_flat = x.reshape(-1, d)
    probs = _router_probs(params, x_flat, cfg)
    weights, expert_ids = jax.lax.top_k(probs, cfg.n_experts_per_token)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    h = jnp.repeat(x_flat[None, :, :], cfg.n_experts, axis=0)  # (E,T,d)
    out_all = _expert_ffn(cfg, params["w_gate"], params["w_up"],
                          params["w_down"], h)  # (E,T,d) fp32
    gate = jnp.zeros((x_flat.shape[0], cfg.n_experts), jnp.float32)
    gate = gate.at[jnp.arange(x_flat.shape[0])[:, None], expert_ids].add(weights)
    y = jnp.einsum("etd,te->td", out_all, gate)
    aux = _aux_loss(probs, expert_ids, cfg)
    if cfg.n_shared_experts:
        y = y + apply_mlp(params["shared"], x_flat, cfg).astype(jnp.float32)
    return y.astype(x.dtype).reshape(b, s, d), aux
