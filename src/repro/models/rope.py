"""Rotary position embeddings."""
from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
