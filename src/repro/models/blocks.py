"""Block assembly and layer stacking.

A block = pre-norm attention mixer + pre-norm FFN (dense / moe /
none), with optional parallel-residual (command-r).

Layer stacks are decomposed into `prefix + pattern × n_repeat` (e.g.
deepseek: 1 dense layer + 27 MoE). The
repeated pattern is executed with `lax.scan` over stacked params —
compile time and HLO size stay O(pattern), not O(n_layers) — with
optional per-step remat.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import (
    FFN_DENSE,
    FFN_MOE,
    FFN_NONE,
    MIXER_ATTN,
    LayerSpec,
    ModelConfig,
)
from repro.models import attention as attn_mod
from repro.models.common import ParamDef
from repro.models.mlp import apply_mlp, mlp_defs
from repro.models.moe import apply_moe, moe_defs
from repro.models.norms import apply_norm, norm_defs
from repro.sharding.rules import BATCH, EMBED, KV_HEADS, KV_SEQ, SEQ, Topology

# ---------------------------------------------------------------------------
# Layer grouping
# ---------------------------------------------------------------------------


class LayerGroups(NamedTuple):
    prefix: tuple[LayerSpec, ...]
    pattern: tuple[LayerSpec, ...]
    n_repeat: int


def layer_groups(specs: tuple[LayerSpec, ...], max_period: int = 12) -> LayerGroups:
    n = len(specs)
    for prefix_len in range(0, n):
        rest = specs[prefix_len:]
        m = len(rest)
        for p in range(1, min(max_period, m) + 1):
            if m % p:
                continue
            if all(rest[i] == rest[i % p] for i in range(m)):
                return LayerGroups(specs[:prefix_len], rest[:p], m // p)
    return LayerGroups(specs[:-1], specs[-1:], 1)


# ---------------------------------------------------------------------------
# Single block
# ---------------------------------------------------------------------------


def block_defs(cfg: ModelConfig, spec: LayerSpec) -> dict:
    d = {"norm1": norm_defs(cfg.d_model, cfg.norm)}
    if spec.mixer == MIXER_ATTN:
        d["mixer"] = attn_mod.attn_defs(cfg)
    else:
        raise ValueError(spec.mixer)
    if spec.ffn == FFN_DENSE:
        d["ffn"] = mlp_defs(cfg)
        if not cfg.parallel_block:
            d["norm2"] = norm_defs(cfg.d_model, cfg.norm)
    elif spec.ffn == FFN_MOE:
        d["ffn"] = moe_defs(cfg)
        if not cfg.parallel_block:
            d["norm2"] = norm_defs(cfg.d_model, cfg.norm)
    return d


def init_block_cache(cfg: ModelConfig, spec: LayerSpec, batch: int,
                     cache_len: int, dtype):
    """Decode-time cache entry for one block."""
    hd = cfg.resolved_head_dim
    if spec.mixer == MIXER_ATTN:
        return {
            "k": jnp.zeros((batch, cache_len, cfg.n_kv_heads, hd), dtype),
            "v": jnp.zeros((batch, cache_len, cfg.n_kv_heads, hd), dtype),
        }
    raise ValueError(spec.mixer)


def block_cache_logical(cfg: ModelConfig, spec: LayerSpec):
    """Logical axes for each cache leaf (mirrors init_block_cache)."""
    if spec.mixer == MIXER_ATTN:
        return {"k": (BATCH, KV_SEQ, KV_HEADS, None),
                "v": (BATCH, KV_SEQ, KV_HEADS, None)}
    raise ValueError(spec.mixer)


def _apply_attn_full(params, x, cfg, topo, positions):
    q, k, v = attn_mod.project_qkv(params, x, cfg, positions)
    o = attn_mod.attention(q, k, v, causal=True)
    return attn_mod.out_proj(params, o), {"k": k, "v": v}


def _apply_attn_decode(params, x, cfg, topo, cache, pos):
    """x: (B,1,d); cache k/v (B,S,KV,hd); pos (B,) current write index."""
    positions = pos[:, None]
    q, k_new, v_new = attn_mod.project_qkv(params, x, cfg, positions)
    s = cache["k"].shape[1]
    slot = jnp.minimum(pos, s - 1)  # ring write
    if topo is not None and topo.rules.get(KV_SEQ):
        o, k_c, v_c = attn_mod.decode_attention_seqsharded(
            q, cache["k"], cache["v"], k_new, v_new, slot, pos, topo)
    else:
        k_c, v_c = attn_mod.write_kv_slot(cache["k"], cache["v"], k_new,
                                          v_new, slot)
        o = attn_mod.decode_attention(q, k_c, v_c, slot, valid_len=pos)
    new_cache = {"k": k_c, "v": v_c}
    return attn_mod.out_proj(params, o), new_cache


def apply_block(params, x, cfg: ModelConfig, topo: Topology, spec: LayerSpec,
                *, mode: str = "full", positions=None, cache: Optional[dict] = None,
                pos=None):
    """Returns (x, new_cache, aux).

    mode: "full" (train: no cache IO), "prefill" (returns built cache),
    "decode" (single token, consumes + updates cache).
    """
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(params["norm1"], x, cfg.norm)
    new_cache: dict = {}

    if spec.mixer == MIXER_ATTN:
        if mode == "decode":
            mix_out, kv = _apply_attn_decode(params["mixer"], h, cfg, topo,
                                             cache, pos)
            new_cache.update(kv)
        else:
            mix_out, kv = _apply_attn_full(params["mixer"], h, cfg, topo,
                                           positions)
            if mode == "prefill":
                new_cache.update(kv)
    else:
        raise ValueError(spec.mixer)

    if cfg.parallel_block and spec.ffn != FFN_NONE:
        # command-r: y = x + attn(n(x)) + ffn(n(x)) (shared norm)
        if spec.ffn == FFN_MOE:
            ffn_out, aux_l = apply_moe(params["ffn"], h, cfg, topo)
            aux = aux + aux_l
        else:
            ffn_out = apply_mlp(params["ffn"], h, cfg)
        x = x + mix_out + ffn_out
        return x, new_cache, aux

    x = x + mix_out
    if spec.ffn != FFN_NONE:
        h2 = apply_norm(params["norm2"], x, cfg.norm)
        if spec.ffn == FFN_MOE:
            ffn_out, aux_l = apply_moe(params["ffn"], h2, cfg, topo)
            aux = aux + aux_l
        else:
            ffn_out = apply_mlp(params["ffn"], h2, cfg)
        x = x + ffn_out
    if topo is not None:
        x = topo.constrain(x, BATCH, SEQ, EMBED)
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Stacks (prefix + scanned pattern)
# ---------------------------------------------------------------------------


def stack_defs(cfg: ModelConfig, specs: tuple[LayerSpec, ...]) -> dict:
    groups = layer_groups(specs)
    d: dict = {"prefix": [block_defs(cfg, s) for s in groups.prefix]}
    if groups.n_repeat:
        pat = {f"l{j}": block_defs(cfg, s)
               for j, s in enumerate(groups.pattern)}
        d["stack"] = jax.tree.map(
            lambda pd: pd.stacked(groups.n_repeat), pat,
            is_leaf=lambda x: isinstance(x, ParamDef))
    return d


def pad_cache(cache, cache_len: int):
    """Pad attention K/V cache seq axes (axis = ndim-3) out to cache_len
    so decode has ring-write headroom."""

    def walk(node):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                if k in ("k", "v"):
                    ax = v.ndim - 3
                    if v.shape[ax] < cache_len:
                        pad = [(0, 0)] * v.ndim
                        pad[ax] = (0, cache_len - v.shape[ax])
                        v = jnp.pad(v, pad)
                    out[k] = v
                else:
                    out[k] = walk(v)
            return out
        if isinstance(node, list):
            return [walk(x) for x in node]
        return node

    return walk(cache)


def stack_cache_init(cfg: ModelConfig, specs, batch: int, cache_len: int,
                     dtype):
    groups = layer_groups(specs)
    cache: dict = {"prefix": [
        init_block_cache(cfg, s, batch, cache_len, dtype)
        for s in groups.prefix]}
    if groups.n_repeat:
        pat = {f"l{j}": init_block_cache(cfg, s, batch, cache_len, dtype)
               for j, s in enumerate(groups.pattern)}
        cache["stack"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (groups.n_repeat, *a.shape)).copy(),
            pat)
    return cache


def apply_stack(params, x, cfg: ModelConfig, topo: Topology, specs,
                *, mode="full", positions=None, cache=None, pos=None,
                remat: str = "block", scan: bool = True):
    """Run the full layer stack. Returns (x, new_cache, aux).

    scan=True executes the repeated pattern with lax.scan (small HLO,
    fast compile); scan=False unrolls it (one HLO copy per repeat —
    required for faithful cost_analysis, which counts loop bodies once).
    """
    groups = layer_groups(specs)
    aux_total = jnp.zeros((), jnp.float32)
    new_cache: dict = {"prefix": []}

    for i, spec in enumerate(groups.prefix):
        c = cache["prefix"][i] if cache is not None else None
        x, nc, aux = apply_block(params["prefix"][i], x, cfg, topo, spec,
                                 mode=mode, positions=positions, cache=c,
                                 pos=pos)
        new_cache["prefix"].append(nc)
        aux_total = aux_total + aux

    if not groups.n_repeat:
        return x, new_cache, aux_total

    use_cache = cache is not None

    if not scan:
        # Unrolled execution (dry-run roofline fidelity).
        def one_repeat(x, aux_acc, p_slice, c_slice):
            ncs = {}
            for j, spec in enumerate(groups.pattern):
                cj = c_slice[f"l{j}"] if use_cache else None
                x, ncj, aux = apply_block(p_slice[f"l{j}"], x, cfg, topo,
                                          spec, mode=mode,
                                          positions=positions, cache=cj,
                                          pos=pos)
                ncs[f"l{j}"] = ncj
                aux_acc = aux_acc + aux
            return x, aux_acc, ncs

        if remat == "block":
            one_repeat = jax.checkpoint(one_repeat)
        nc_list = []
        for i in range(groups.n_repeat):
            p_slice = jax.tree.map(lambda a: a[i], params["stack"])
            c_slice = (jax.tree.map(lambda a: a[i], cache["stack"])
                       if use_cache else None)
            x, aux_total, ncs = one_repeat(x, aux_total, p_slice, c_slice)
            nc_list.append(ncs)
        if nc_list and jax.tree.leaves(nc_list[0]):
            new_cache["stack"] = jax.tree.map(
                lambda *xs: jnp.stack(xs), *nc_list)
        return x, new_cache, aux_total

    def body(carry, xs):
        xx, aux_acc = carry
        p_slice = xs[0]
        c_slice = xs[1] if use_cache else None
        ncs = {}
        for j, spec in enumerate(groups.pattern):
            cj = c_slice[f"l{j}"] if use_cache else None
            xx, ncj, aux = apply_block(p_slice[f"l{j}"], xx, cfg, topo, spec,
                                       mode=mode, positions=positions,
                                       cache=cj, pos=pos)
            ncs[f"l{j}"] = ncj
            aux_acc = aux_acc + aux
        return (xx, aux_acc), ncs

    if remat == "block":
        body = jax.checkpoint(body)
    xs = (params["stack"], cache["stack"]) if use_cache else (params["stack"],)
    (x, aux_total), stack_cache = jax.lax.scan(body, (x, aux_total), xs)
    new_cache["stack"] = stack_cache
    return x, new_cache, aux_total
