"""Spec-first parameter machinery.

Every module declares its parameters as a pytree of `ParamDef`s (shape +
logical axes + initializer). Params, shardings, and dry-run
ShapeDtypeStructs are all derived from the same tree, so they can never
drift apart.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding.rules import LAYERS, Topology

DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32, "float16": jnp.float16}


@dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    logical: tuple[Optional[str], ...]
    init: str = "fan_in"  # fan_in | zeros | ones | normal | embed
    scale: float = 1.0
    dtype: Optional[Any] = None  # override model dtype (e.g. fp32 gates)

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)

    def stacked(self, n: int) -> "ParamDef":
        return dataclasses.replace(
            self, shape=(n, *self.shape), logical=(LAYERS, *self.logical)
        )


ParamTree = Any  # nested dict of jnp arrays
DefTree = Any  # nested dict of ParamDef


def _init_one(key, d: ParamDef, default_dtype):
    dtype = d.dtype or default_dtype
    if d.init == "zeros":
        return jnp.zeros(d.shape, dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, dtype)
    if d.init == "normal":
        return (d.scale * jax.random.normal(key, d.shape, jnp.float32)).astype(dtype)
    if d.init == "embed":
        return (d.scale * jax.random.normal(key, d.shape, jnp.float32)).astype(dtype)
    if d.init == "fan_in":
        fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
        std = d.scale / np.sqrt(max(1, fan_in))
        return (std * jax.random.normal(key, d.shape, jnp.float32)).astype(dtype)
    raise ValueError(f"unknown init {d.init}")


def init_params(key, defs: DefTree, dtype_name: str) -> ParamTree:
    """Materialise a DefTree into arrays (deterministic per-leaf keys)."""
    default_dtype = DTYPES[dtype_name]
    leaves, treedef = jax.tree.flatten(
        defs, is_leaf=lambda x: isinstance(x, ParamDef)
    )
    keys = jax.random.split(key, len(leaves))
    vals = [_init_one(k, d, default_dtype) for k, d in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(defs: DefTree, dtype_name: str, topo: Topology) -> ParamTree:
    """ShapeDtypeStructs with shardings attached — for AOT dry-runs."""
    default_dtype = DTYPES[dtype_name]

    def mk(d: ParamDef):
        dt = d.dtype or default_dtype
        sh = topo.named(d.logical, fsdp=True, shape=d.shape)
        return jax.ShapeDtypeStruct(d.shape, dt, sharding=sh)

    return jax.tree.map(mk, defs, is_leaf=lambda x: isinstance(x, ParamDef))


def param_shardings(defs: DefTree, topo: Topology):
    return jax.tree.map(
        lambda d: topo.named(d.logical, fsdp=True, shape=d.shape),
        defs,
        is_leaf=lambda x: isinstance(x, ParamDef),
    )


def param_bytes(defs: DefTree, dtype_name: str) -> int:
    dt = DTYPES[dtype_name]
    total = 0
    for d in jax.tree.leaves(defs, is_leaf=lambda x: isinstance(x, ParamDef)):
        itemsize = jnp.dtype(d.dtype or dt).itemsize
        total += int(np.prod(d.shape)) * itemsize
    return total


# ---------------------------------------------------------------------------
# numerics helpers
# ---------------------------------------------------------------------------


def matmul(x, w, dtype=None):
    """Matmul emitting the model dtype directly (MXU-faithful: the TPU
    MXU accumulates fp32 internally regardless of the HLO output dtype,
    so emitting bf16 rounds once at the output — same numerics as
    fp32-accumulate-then-convert, without the fp32 fusion-boundary
    tensors that double HBM traffic)."""
    return jnp.matmul(x, w, preferred_element_type=dtype or x.dtype)


def einsum(subs, *args, dtype=None):
    """Einsum emitting `dtype` (default: input dtype). Pass
    dtype=jnp.float32 only where downstream math genuinely needs wide
    outputs (logits, router scores, gate exponents)."""
    return jnp.einsum(subs, *args, preferred_element_type=dtype or args[0].dtype)


def einsum_out(subs, *args):
    """Alias of einsum at input dtype — marks psum-adjacent projections
    (bf16 partial sums over ICI; standard Megatron-style practice)."""
    return jnp.einsum(subs, *args, preferred_element_type=args[0].dtype)
