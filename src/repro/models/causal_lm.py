"""Decoder-only causal LM (dense / MoE / SSM / hybrid / VLM families).

Public surface:
  model = CausalLM(cfg, topo)
  params = model.init(key)             # or model.abstract_params() for AOT
  loss, metrics = model.loss(params, batch)
  cache, logits = model.prefill(params, batch)
  logits, cache = model.decode_step(params, cache, token, pos)

Batch dict:
  tokens: (B, S_text) int32
  targets/loss_mask: (B, S) — training only
  embeds: (B, P, d) — VLM/audio frontends: precomputed prefix embeddings
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import blocks
from repro.models.common import (
    DTYPES,
    ParamDef,
    abstract_params,
    einsum,
    init_params,
    param_shardings,
)
from repro.models.norms import apply_norm, norm_defs
from repro.sharding.rules import BATCH, EMBED, SEQ, VOCAB, Topology


class CausalLM:
    def __init__(self, cfg: ModelConfig, topo: Topology,
                 remat: str = "block", scan_layers: bool = True):
        self.cfg = cfg
        self.topo = topo
        self.remat = remat
        self.scan_layers = scan_layers
        self.specs = cfg.layer_specs()

    # ------------------------------------------------------------- params
    def defs(self) -> dict:
        cfg = self.cfg
        d: dict = {
            "embed": ParamDef((cfg.padded_vocab, cfg.d_model), (VOCAB, EMBED),
                              init="embed", scale=0.02),
            "blocks": blocks.stack_defs(cfg, self.specs),
            "final_norm": norm_defs(cfg.d_model, cfg.norm),
        }
        if not cfg.tie_embeddings:
            d["head"] = ParamDef((cfg.d_model, cfg.padded_vocab),
                                 (EMBED, VOCAB))
        return d

    def init(self, key) -> Any:
        return init_params(key, self.defs(), self.cfg.dtype)

    def abstract_params(self) -> Any:
        return abstract_params(self.defs(), self.cfg.dtype, self.topo)

    def param_shardings(self) -> Any:
        return param_shardings(self.defs(), self.topo)

    # ------------------------------------------------------------ forward
    def _embed(self, params, batch) -> jax.Array:
        cfg = self.cfg
        tok_emb = jnp.take(params["embed"], batch["tokens"], axis=0)
        if cfg.frontend != "none" and "embeds" in batch:
            x = jnp.concatenate(
                [batch["embeds"].astype(tok_emb.dtype), tok_emb], axis=1)
        else:
            x = tok_emb
        return self.topo.constrain(x, BATCH, SEQ, EMBED)

    def _logits(self, params, x):
        cfg = self.cfg
        x = apply_norm(params["final_norm"], x, cfg.norm)
        head = params["embed"].T if cfg.tie_embeddings else params["head"]
        logits = einsum("bsd,dv->bsv", x, head, dtype=jnp.float32)
        # mask padded vocab entries
        pad_mask = jnp.where(
            jnp.arange(cfg.padded_vocab) < cfg.vocab_size, 0.0, -1e30)
        logits = logits + pad_mask
        return self.topo.constrain(logits, BATCH, SEQ, VOCAB)

    def forward(self, params, batch, mode: str = "full"):
        """Returns (logits, cache_or_None, aux)."""
        x = self._embed(params, batch)
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)[None, :]
        x, cache, aux = blocks.apply_stack(
            params["blocks"], x, self.cfg, self.topo, self.specs,
            mode=mode, positions=positions, remat=self.remat,
            scan=self.scan_layers)
        return self._logits(params, x), cache, aux

    # --------------------------------------------------------------- loss
    def loss(self, params, batch):
        logits, _, aux = self.forward(params, batch, mode="full")
        return lm_loss(logits, batch, self.cfg, aux)

    # -------------------------------------------------------------- serve
    def prefill(self, params, batch, cache_len: int | None = None):
        logits, cache, _ = self.forward(params, batch, mode="prefill")
        if cache_len is not None:
            cache = blocks.pad_cache(cache, cache_len)
        return cache, logits[:, -1:]

    def init_cache(self, batch_size: int, cache_len: int):
        return blocks.stack_cache_init(
            self.cfg, self.specs, batch_size, cache_len,
            DTYPES[self.cfg.dtype])

    def cache_shardings(self):
        return _cache_shardings(self.cfg, self.specs, self.topo)

    def decode_step(self, params, cache, token, pos):
        """token: (B,1) int32; pos: (B,) int32 write/mask index."""
        x = jnp.take(params["embed"], token, axis=0)
        x, new_cache, _ = blocks.apply_stack(
            params["blocks"], x, self.cfg, self.topo, self.specs,
            mode="decode", cache=cache, pos=pos, remat="none",
            scan=self.scan_layers)
        return self._logits(params, x), new_cache


def lm_loss(logits, batch, cfg: ModelConfig, aux):
    """Cross-entropy over unpadded vocab + router aux."""
    targets = batch["targets"]
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones(targets.shape, jnp.float32)
    # align: logits predict the NEXT token; batch supplies aligned targets
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(mask.sum(), 1.0)
    ce = -(ll * mask).sum() / denom
    total = ce + cfg.router_aux_loss * aux
    metrics = {"ce": ce, "aux": aux, "tokens": denom}
    return total, metrics


def _cache_shardings(cfg, specs, topo: Topology):
    groups = blocks.layer_groups(specs)
    out: dict = {"prefix": []}

    def entry(spec, stacked: bool):
        logical = blocks.block_cache_logical(cfg, spec)
        return {
            k: topo.named(("layers", *ax) if stacked else ax)
            for k, ax in logical.items()
        }

    for s in groups.prefix:
        out["prefix"].append(entry(s, False))
    if groups.n_repeat:
        out["stack"] = {f"l{j}": entry(s, True)
                        for j, s in enumerate(groups.pattern)}
    return out
