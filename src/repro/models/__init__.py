"""Model zoo: assigned-architecture families on a shared block substrate."""


def build_model(cfg, topo, remat: str = "block", scan_layers: bool = True):
    """Build the model for `cfg`. Every registered architecture is
    decoder-only (the encoder-decoder seamless-m4t family was pruned
    with `models/encdec.py`)."""
    from repro.models.causal_lm import CausalLM

    if cfg.is_encoder_decoder:
        raise ValueError(
            "encoder-decoder configs are no longer supported — the "
            "seamless-m4t family and models/encdec.py were removed; "
            "use a decoder-only arch from configs.registry")
    return CausalLM(cfg, topo, remat, scan_layers)
