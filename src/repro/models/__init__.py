"""Model zoo: assigned-architecture families on a shared block substrate."""
from repro.models.encdec import build_model  # noqa: F401
