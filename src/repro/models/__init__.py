"""Model zoo: assigned-architecture families on a shared block substrate."""


def build_model(cfg, topo, remat: str = "block", scan_layers: bool = True):
    """Build the model for `cfg`. Every registered architecture is
    decoder-only (the encoder-decoder seamless-m4t family was pruned
    with `models/encdec.py`)."""
    from repro.models.causal_lm import CausalLM

    return CausalLM(cfg, topo, remat, scan_layers)
