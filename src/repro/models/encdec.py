"""Encoder-decoder LM (seamless-m4t backbone).

Encoder consumes frontend frame embeddings (audio stub) or token
embeddings; decoder is causal with per-layer cross-attention. Decode
carries a self-attention KV cache plus precomputed cross K/V.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import blocks
from repro.models.causal_lm import lm_loss
from repro.models.common import (
    DTYPES,
    ParamDef,
    abstract_params,
    einsum,
    init_params,
    param_shardings,
)
from repro.models.norms import apply_norm, norm_defs
from repro.sharding.rules import BATCH, EMBED, SEQ, VOCAB, Topology


class EncDecLM:
    def __init__(self, cfg: ModelConfig, topo: Topology, remat: str = "block",
                 scan_layers: bool = True):
        assert cfg.is_encoder_decoder
        self.cfg = cfg
        self.topo = topo
        self.remat = remat
        self.scan_layers = scan_layers
        self.enc_specs = cfg.encoder_layer_specs()
        self.dec_specs = cfg.layer_specs()

    def defs(self) -> dict:
        cfg = self.cfg
        d: dict = {
            "embed": ParamDef((cfg.padded_vocab, cfg.d_model), (VOCAB, EMBED),
                              init="embed", scale=0.02),
            "encoder": blocks.stack_defs(cfg, self.enc_specs, cross=False),
            "enc_norm": norm_defs(cfg.d_model, cfg.norm),
            "decoder": blocks.stack_defs(cfg, self.dec_specs, cross=True),
            "final_norm": norm_defs(cfg.d_model, cfg.norm),
        }
        if not cfg.tie_embeddings:
            d["head"] = ParamDef((cfg.d_model, cfg.padded_vocab),
                                 (EMBED, VOCAB))
        return d

    def init(self, key) -> Any:
        return init_params(key, self.defs(), self.cfg.dtype)

    def abstract_params(self) -> Any:
        return abstract_params(self.defs(), self.cfg.dtype, self.topo)

    def param_shardings(self) -> Any:
        return param_shardings(self.defs(), self.topo)

    # ------------------------------------------------------------ encoder
    def encode(self, params, batch):
        cfg = self.cfg
        if cfg.frontend == "audio":
            x = batch["frames"].astype(DTYPES[cfg.dtype])
        else:
            x = jnp.take(params["embed"], batch["enc_tokens"], axis=0)
        x = self.topo.constrain(x, BATCH, SEQ, EMBED)
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)[None, :]
        x, _, _ = blocks.apply_stack(
            params["encoder"], x, cfg, self.topo, self.enc_specs,
            mode="encode", positions=positions, remat=self.remat,
            scan=self.scan_layers)
        return apply_norm(params["enc_norm"], x, cfg.norm)

    # ------------------------------------------------------------ decoder
    def _logits(self, params, x):
        cfg = self.cfg
        x = apply_norm(params["final_norm"], x, cfg.norm)
        head = params["embed"].T if cfg.tie_embeddings else params["head"]
        logits = einsum("bsd,dv->bsv", x, head, dtype=jnp.float32)
        pad_mask = jnp.where(
            jnp.arange(cfg.padded_vocab) < cfg.vocab_size, 0.0, -1e30)
        return logits + pad_mask

    def forward(self, params, batch, mode: str = "full"):
        enc_out = self.encode(params, batch)
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
        x = self.topo.constrain(x, BATCH, SEQ, EMBED)
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)[None, :]
        x, cache, aux = blocks.apply_stack(
            params["decoder"], x, self.cfg, self.topo, self.dec_specs,
            mode=mode, positions=positions, remat=self.remat, enc_out=enc_out,
            scan=self.scan_layers)
        return self._logits(params, x), cache, aux

    def loss(self, params, batch):
        logits, _, aux = self.forward(params, batch, mode="full")
        return lm_loss(logits, batch, self.cfg, aux)

    def prefill(self, params, batch, cache_len: int | None = None):
        logits, cache, _ = self.forward(params, batch, mode="prefill")
        if cache_len is not None:
            cache = blocks.pad_cache(cache, cache_len)
        return cache, logits[:, -1:]

    def init_cache(self, batch_size: int, cache_len: int, cross_len: int):
        return blocks.stack_cache_init(
            self.cfg, self.dec_specs, batch_size, cache_len,
            DTYPES[self.cfg.dtype], cross_len=cross_len)

    def cache_shardings(self):
        from repro.models.causal_lm import _cache_shardings

        return _cache_shardings(self.cfg, self.dec_specs, self.topo)

    def decode_step(self, params, cache, token, pos):
        x = jnp.take(params["embed"], token, axis=0)
        x, new_cache, _ = blocks.apply_stack(
            params["decoder"], x, self.cfg, self.topo, self.dec_specs,
            mode="decode", cache=cache, pos=pos, remat="none",
            scan=self.scan_layers)
        return self._logits(params, x), new_cache


def build_model(cfg: ModelConfig, topo: Topology, remat: str = "block",
                scan_layers: bool = True):
    from repro.models.causal_lm import CausalLM

    if cfg.is_encoder_decoder:
        return EncDecLM(cfg, topo, remat, scan_layers)
    return CausalLM(cfg, topo, remat, scan_layers)
