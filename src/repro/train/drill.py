"""Training crash/restore drill (moved out of the simulator's fault
runtime — it exercises the train loop, not the engine).

Determinism contract: a restored run must produce the same losses as
an uninterrupted run (asserted in tests/test_train.py), because the
data pipeline is deterministic in the step index and optimizer state
rides the checkpoint.
"""
from __future__ import annotations

import numpy as np

from repro.runtime.fault import FailureInjector, FailurePlan


def run_train_with_failures(make_state, train_step, batches, ckpt_dir: str,
                            plan: FailurePlan, save_fn, restore_fn,
                            ckpt_every: int = 2):
    """Drill: training loop with crash/restore at step granularity."""
    inj = FailureInjector(plan, n_windows=len(batches))
    state = make_state()
    save_fn(state, 0)
    losses = {}
    step = 0
    while step < len(batches):
        if inj.maybe_fail(step):
            state, step = restore_fn()
            continue
        state, metrics = train_step(state, batches[step])
        losses[step] = float(np.asarray(metrics["loss"]))
        step += 1
        if step % ckpt_every == 0:
            save_fn(state, step)
    return state, [losses[i] for i in range(len(batches))], inj.events
