"""AdamW with fp32 master weights, cosine schedule, global-norm clipping.

No external deps (optax is not available offline); implemented as pure
pytree transforms. Optimizer state leaves inherit their parameter's
sharding (which already carries FSDP axes), so ZeRO-style state
partitioning falls out of the param layout for free.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import OptimizerConfig


class AdamWState(NamedTuple):
    step: jax.Array  # () int32
    mu: Any  # fp32 pytree
    nu: Any  # fp32 pytree
    master: Any  # fp32 params (None when params already fp32)


def lr_schedule(cfg: OptimizerConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def init_opt_state(params, cfg: OptimizerConfig) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    needs_master = cfg.master_fp32 and any(
        p.dtype != jnp.float32 for p in jax.tree.leaves(params))
    master = (jax.tree.map(lambda p: p.astype(jnp.float32), params)
              if needs_master else None)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros), master=master)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def adamw_update(grads, state: AdamWState, params, cfg: OptimizerConfig):
    """Returns (new_params, new_state, metrics). grads may be bf16."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, grads)

    ref = state.master if state.master is not None else params

    def upd(p32, m, v):
        mhat = m / bc1
        vhat = v / bc2
        return p32 - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                           + cfg.weight_decay * p32)

    new_master = jax.tree.map(
        lambda p, m, v: upd(p.astype(jnp.float32), m, v), ref, mu, nu)
    new_params = jax.tree.map(
        lambda p, p32: p32.astype(p.dtype), params, new_master)
    new_state = AdamWState(
        step=step, mu=mu, nu=nu,
        master=new_master if state.master is not None else None)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, metrics
