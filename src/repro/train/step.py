"""Training step factories.

`make_train_step(model, ...)` builds the pjit train step:
  state = {"params", "opt": AdamWState, "step"}
  new_state, metrics = train_step(state, batch)

Features:
* grad accumulation — `lax.scan` over microbatches (activation memory
  divided by `grad_accum`, gradients accumulated in fp32);
* per-block remat (set on the model);
* donation of the state pytree (in-place update on device);
* `make_dp_train_step` — explicit data-parallel variant (params
  replicated, grads reduced with a *compressed* psum over the given
  axis) used to exercise the paper-motivated int8 error-feedback
  reduction end-to-end on small models.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import OptimizerConfig, ShardingConfig
from repro.train import compression
from repro.train.optim import AdamWState, adamw_update, init_opt_state


def init_train_state(model, key, ocfg: OptimizerConfig):
    params = model.init(key)
    return {"params": params, "opt": init_opt_state(params, ocfg)}


def abstract_train_state(model, ocfg: OptimizerConfig):
    """ShapeDtypeStructs (with shardings) for AOT lowering."""
    params = model.abstract_params()

    def f32(p):
        return jax.ShapeDtypeStruct(p.shape, jnp.float32, sharding=p.sharding)

    zeros = jax.tree.map(f32, params)
    master = jax.tree.map(f32, params) if any(
        p.dtype != jnp.float32 for p in jax.tree.leaves(params)) else None
    opt = AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32),
                     mu=zeros, nu=zeros, master=master)
    return {"params": params, "opt": opt}


def _split_microbatches(batch: dict, accum: int) -> dict:
    def split(x):
        b = x.shape[0]
        assert b % accum == 0, (b, accum)
        return x.reshape(accum, b // accum, *x.shape[1:])

    return jax.tree.map(split, batch)


def make_train_step(model, ocfg: OptimizerConfig, scfg: ShardingConfig) -> Callable:
    accum = max(1, scfg.grad_accum)

    def loss_fn(params, mb):
        loss, metrics = model.loss(params, mb)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state, batch):
        params = state["params"]
        if accum == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            mbs = _split_microbatches(batch, accum)
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def body(acc, mb):
                (l, m), g = grad_fn(params, mb)
                acc = jax.tree.map(
                    lambda a, gg: a + gg.astype(jnp.float32) / accum, acc, g)
                return acc, (l, m)

            grads, (losses, ms) = jax.lax.scan(body, zero, mbs)
            loss = losses.mean()
            metrics = jax.tree.map(lambda x: x.mean(), ms)

        new_params, new_opt, opt_metrics = adamw_update(
            grads, state["opt"], params, ocfg)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


# ---------------------------------------------------------------------------
# Explicit-DP step with compressed gradient reduction (paper-motivated)
# ---------------------------------------------------------------------------


def make_dp_train_step(model, ocfg: OptimizerConfig, mesh, axis: str = "data",
                       compress: bool = True) -> Callable:
    """Params replicated; batch sharded over `axis`; per-shard grads
    reduced with int8 error-feedback psum (or plain psum)."""

    def loss_fn(params, mb):
        loss, metrics = model.loss(params, mb)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    n_shards = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]

    def train_step(state, batch):
        def body(params, opt, err, batch):
            (loss, metrics), grads = grad_fn(params, batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32) / n_shards,
                                 grads)
            if compress:
                flat_g, tdef = jax.tree.flatten(grads)
                flat_e = jax.tree.leaves(err)
                red = [compression.compressed_psum(g, axis, e)
                       for g, e in zip(flat_g, flat_e)]
                grads = jax.tree.unflatten(tdef, [r[0] for r in red])
                new_err = jax.tree.unflatten(tdef, [r[1] for r in red])
            else:
                grads = jax.tree.map(lambda g: jax.lax.psum(g, axis), grads)
                new_err = err
            loss = jax.lax.pmean(loss, axis)
            new_params, new_opt, om = adamw_update(grads, opt, params, ocfg)
            return new_params, new_opt, new_err, dict(metrics, loss=loss, **om)

        batch_spec = jax.tree.map(lambda _: P(axis), batch)
        rep = jax.tree.map(lambda _: P(), state["params"])
        opt_spec = jax.tree.map(lambda _: P(), state["opt"])
        err_spec = jax.tree.map(lambda _: P(), state["error"])
        out = compat.shard_map(
            body, mesh=mesh,
            in_specs=(rep, opt_spec, err_spec, batch_spec),
            out_specs=(rep, opt_spec, err_spec,
                       jax.tree.map(lambda _: P(), {"ce": 0, "aux": 0,
                                                    "tokens": 0, "loss": 0,
                                                    "grad_norm": 0, "lr": 0})),
            check_vma=False,
        )(state["params"], state["opt"], state["error"], batch)
        new_params, new_opt, new_err, metrics = out
        return {"params": new_params, "opt": new_opt, "error": new_err}, metrics

    return train_step
