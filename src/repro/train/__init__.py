"""Training substrate: optimizer, step factories, gradient compression."""
