"""int8 compressed all-reduce with error feedback.

Motivation (paper §3.1.2 adapted to pods): at 1000+ nodes the inter-pod
links are the scarce resource. Payloads crossing the `pod` axis can be
quantised 4x (bf16→int8 / fp32→int8) with an error-feedback accumulator
(Seide et al. / EF21 style) keeping the scheme convergent.

Two integration points in this framework:
* `SimulationEngine` — cross-pod reduction of windowed Welford
  statistics (we own that psum explicitly).
* the explicit-DP train step (`train/step.py: make_dp_train_step`) —
  per-shard grads are reduced with `compressed_psum` instead of a plain
  psum. The pjit/implicit-collective train path keeps XLA's native
  all-reduce (compression there requires manual-reduction training, so
  it is opt-in by strategy).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def quantize_int8(x):
    """Per-tensor symmetric int8. Returns (q, scale)."""
    scale = jnp.maximum(jnp.max(jnp.abs(x)) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum(x, axis: str, error):
    """Error-feedback int8 psum over a mesh axis (call inside shard_map).

    x: fp32 shard-local contribution; error: fp32 accumulator of the
    same shape. Returns (psum result, new error).
    """
    corrected = x + error
    q, scale = quantize_int8(corrected)
    deq = q.astype(jnp.float32) * scale
    new_error = corrected - deq
    # int8 payload crosses the link; accumulate in fp32
    total = jax.lax.psum(deq, axis)
    return total, new_error


def init_error(tree: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), tree)
