"""OLMoE-1B-7B — 64-expert top-8 MoE [arXiv:2409.02060].

16L, d_model=2048, 16H (GQA kv=16), per-expert d_ff=1024, vocab 50304.
Every layer is MoE (no dense FFN layers, no shared experts).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,  # per-expert (moe_d_ff defaults to d_ff)
    moe_d_ff=1024,
    vocab_size=50304,
    n_experts=64,
    n_experts_per_token=8,
    mlp_variant="swiglu",
    norm="rmsnorm",
    rope_theta=10_000.0,
)


def smoke() -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    return ModelConfig(
        name="olmoe-1b-7b-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=96,
        moe_d_ff=96,
        vocab_size=512,
        n_experts=8,
        n_experts_per_token=2,
        mlp_variant="swiglu",
        dtype="float32",
    )
