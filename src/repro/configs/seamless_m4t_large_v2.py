"""SeamlessM4T-large-v2 — encoder-decoder multimodal backbone
[arXiv:2308.11596].

Backbone only (per instructions): 24L encoder + 24L decoder, d_model=1024,
16H (kv=16), d_ff=8192, vocab 256206. The speech frontend is a STUB —
`input_specs()` provides precomputed frame embeddings for the encoder.

Deviation note: the release's speech encoder is a conformer with relative
position; the backbone here uses RoPE in self-attention as the positional
mechanism (recorded in DESIGN.md hardware/fidelity notes).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,  # decoder layers
    n_encoder_layers=24,
    is_encoder_decoder=True,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    mlp_variant="gelu",
    mlp_bias=True,
    norm="layernorm",
    frontend="audio",
    frontend_tokens=0,  # encoder input is entirely frame embeddings
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2-smoke",
        family="audio",
        n_layers=2,
        n_encoder_layers=2,
        is_encoder_decoder=True,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab_size=512,
        mlp_variant="gelu",
        mlp_bias=True,
        norm="layernorm",
        frontend="audio",
        dtype="float32",
    )
