"""Jamba-v0.1 (52B total / 12B active) — Mamba + attention 1:7 interleave
with 16-expert top-2 MoE every other layer [arXiv:2403.19887].

32L, d_model=4096, 32H (GQA kv=8), d_ff=14336, vocab 65536.
Layer pattern (period 8): attention at offset 4, Mamba elsewhere;
MoE FFN on odd layers, dense FFN on even layers. No RoPE (Mamba carries
position). Hybrid -> runs long_500k (small KV: 4 attention layers).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    moe_d_ff=14336,
    vocab_size=65536,
    n_experts=16,
    n_experts_per_token=2,
    moe_layer_period=2,
    moe_layer_offset=1,
    attn_layer_period=8,
    attn_layer_offset=4,
    mamba_d_state=16,
    mamba_d_conv=4,
    mamba_expand=2,
    use_rope=False,
    mlp_variant="swiglu",
    norm="rmsnorm",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b-smoke",
        family="hybrid",
        n_layers=8,  # one full period: 7 mamba + 1 attn, alternating MoE
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        moe_d_ff=128,
        vocab_size=512,
        n_experts=4,
        n_experts_per_token=2,
        moe_layer_period=2,
        moe_layer_offset=1,
        attn_layer_period=8,
        attn_layer_offset=4,
        mamba_d_state=8,
        mamba_d_conv=4,
        mamba_expand=2,
        use_rope=False,
        chunk_size=16,
        dtype="float32",
    )
