"""Gemma-7B — dense decoder with GeGLU and head_dim=256 [arXiv:2403.08295].

28L, d_model=3072, 16H (kv=16), d_ff=24576, vocab 256000. Attention
projects 3072 -> 16*256 = 4096 (head_dim overrides d_model//n_heads).
Tied embeddings, RMSNorm.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256000,
    mlp_variant="geglu",
    norm="rmsnorm",
    tie_embeddings=True,
    rope_theta=10_000.0,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma-7b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=32,  # exercise the head_dim != d_model//n_heads path
        d_ff=128,
        vocab_size=512,
        mlp_variant="geglu",
        tie_embeddings=True,
        dtype="float32",
    )
