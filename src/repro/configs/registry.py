"""Architecture registry: `--arch <id>` resolution for launchers/tests.

Every assigned architecture is registered with its full (paper-exact)
config and a reduced same-family smoke config. The registry also applies
per-arch default sharding strategies (overridable from the CLI).
"""
from __future__ import annotations

from typing import Callable

from repro.configs import (
    command_r_35b,
    deepseek_moe_16b,
    gemma_7b,
    internvl2_1b,
    llama3_8b,
    olmoe_1b_7b,
    starcoder2_7b,
)
from repro.configs.base import (
    ALL_SHAPES,
    ModelConfig,
    ShapeConfig,
    ShardingConfig,
    shape_applicable,
)

_MODULES = {
    "olmoe-1b-7b": olmoe_1b_7b,
    "deepseek-moe-16b": deepseek_moe_16b,
    "internvl2-1b": internvl2_1b,
    "llama3-8b": llama3_8b,
    "starcoder2-7b": starcoder2_7b,
    "command-r-35b": command_r_35b,
    "gemma-7b": gemma_7b,
}

ARCH_NAMES = tuple(_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return _MODULES[name].CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    return _MODULES[name].smoke()


# Default sharding strategy per arch (hillclimbing varies these; see
# EXPERIMENTS.md §Perf). Models below ~2B keep pure DP+TP; larger models
# need FSDP over the data axis to fit optimizer state + activations.
_DEFAULT_STRATEGY: dict[str, ShardingConfig] = {
    "olmoe-1b-7b": ShardingConfig(strategy="fsdp_tp", grad_accum=2),
    "deepseek-moe-16b": ShardingConfig(strategy="fsdp_tp", grad_accum=2),
    "internvl2-1b": ShardingConfig(strategy="dp_tp", grad_accum=1),
    "llama3-8b": ShardingConfig(strategy="fsdp_tp", grad_accum=4),
    "starcoder2-7b": ShardingConfig(strategy="fsdp_tp", grad_accum=4),
    "command-r-35b": ShardingConfig(strategy="fsdp_tp", grad_accum=8),
    "gemma-7b": ShardingConfig(strategy="fsdp_tp", grad_accum=4),
}


def default_sharding(name: str, shape: ShapeConfig | None = None,
                     tp_size: int = 16) -> ShardingConfig:
    import dataclasses

    cfg = _DEFAULT_STRATEGY.get(name, ShardingConfig())
    if shape is None:
        return cfg
    if shape.kind in ("decode", "prefill"):
        # Inference holds no optimizer state: FSDP-sharded weights would
        # be all-gathered EVERY step (measured: 181 GB/step on a 52B
        # MoE decode — §Perf H2). Serving layout = TP only, replicated
        # over the data axes.
        cfg = dataclasses.replace(cfg, strategy="dp_tp", grad_accum=1)
    if shape.name == "long_500k":
        # batch=1, 500k KV/state: shard the cache sequence axis over `data`.
        cfg = dataclasses.replace(cfg, seq_sharded_kv=True,
                                  kv_seq_axis="data")
    elif shape.kind == "decode":
        model = get_config(name)
        if model.has_kv_cache and model.n_kv_heads % tp_size != 0:
            # KV heads can't use the model axis -> distributed flash-decode
            # with the cache sequence axis sharded over `model` instead.
            cfg = dataclasses.replace(cfg, seq_sharded_kv=True,
                                      kv_seq_axis="model")
    return cfg


def dryrun_cells() -> list[tuple[str, ShapeConfig]]:
    """Every applicable (arch x shape) pair for the dry-run matrix."""
    cells = []
    for name in ARCH_NAMES:
        model = get_config(name)
        for shape in ALL_SHAPES:
            ok, _why = shape_applicable(model, shape)
            if ok:
                cells.append((name, shape))
    return cells


def skipped_cells() -> list[tuple[str, str, str]]:
    """(arch, shape, reason) for cells excluded from the matrix."""
    out = []
    for name in ARCH_NAMES:
        model = get_config(name)
        for shape in ALL_SHAPES:
            ok, why = shape_applicable(model, shape)
            if not ok:
                out.append((name, shape.name, why))
    return out
