"""xLSTM-1.3B — sLSTM + mLSTM blocks (xLSTM[7:1]) [arXiv:2405.04517].

48 blocks, d_model=2048, 4 heads, no separate FFN (d_ff=0; the up/down
projection lives inside the block), vocab 50304. One sLSTM block per 8
(positions 7, 15, ...); the rest are chunkwise-parallel mLSTM blocks.
Recurrent state replaces the KV cache -> runs long_500k.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    use_rope=False,  # xLSTM has no positional embedding (recurrence carries order)
    slstm_every=8,
    slstm_offset=7,
    xlstm_expand=2,
    chunk_size=256,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="xlstm-1.3b-smoke",
        family="ssm",
        n_layers=4,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab_size=512,
        use_rope=False,
        slstm_every=4,
        slstm_offset=3,
        xlstm_expand=2,
        chunk_size=16,
        dtype="float32",
    )
