"""Configuration dataclasses for the repro framework.

Covers every assigned architecture family (dense / moe / ssm / hybrid /
vlm) plus the simulation-side (paper) configs, the input shapes, the
mesh, and the hardware model used for roofline analysis.

Configs are frozen dataclasses: hashable, usable as static args to jit.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# ---------------------------------------------------------------------------
# Per-layer structure
# ---------------------------------------------------------------------------

# Mixer kinds (the sequence-mixing half of a block).
MIXER_ATTN = "attn"
MIXER_MAMBA = "mamba"
MIXER_MLSTM = "mlstm"
MIXER_SLSTM = "slstm"

# FFN kinds (the channel-mixing half of a block).
FFN_DENSE = "dense"
FFN_MOE = "moe"
FFN_NONE = "none"


@dataclass(frozen=True)
class LayerSpec:
    """Structure of one transformer/SSM block."""

    mixer: str  # attn | mamba | mlstm | slstm
    ffn: str  # dense | moe | none


@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyperparameters.

    One instance fully determines parameter shapes; the `layer_specs()`
    method expands the per-layer structure (attention/mamba/moe interleave)
    used by hybrid architectures.
    """

    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads (gemma overrides to 256)

    # --- MLP ---
    mlp_variant: str = "swiglu"  # swiglu | geglu | gelu (non-gated)
    mlp_bias: bool = False

    # --- norm / residual topology ---
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    parallel_block: bool = False  # command-r style attn || mlp
    tie_embeddings: bool = False
    logits_softcap: float = 0.0

    # --- rotary embeddings ---
    rope_theta: float = 10_000.0
    use_rope: bool = True

    # --- MoE ---
    n_experts: int = 0
    n_experts_per_token: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0  # per-expert intermediate (fine-grained MoE)
    first_k_dense: int = 0  # deepseek: first k layers use a dense FFN
    moe_layer_period: int = 1  # jamba: MoE every `period` layers
    moe_layer_offset: int = 0
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.01

    # --- hybrid / SSM layer pattern ---
    attn_layer_period: int = 1  # jamba: attention every `period` layers
    attn_layer_offset: int = 0
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    # xLSTM: sLSTM block every `slstm_every` layers (at offset-th position);
    # 0 disables (all-mLSTM).
    slstm_every: int = 0
    slstm_offset: int = 0
    xlstm_expand: int = 2
    chunk_size: int = 256  # chunkwise-parallel chunk for mLSTM/mamba train

    # --- modality frontend stubs ---
    frontend: str = "none"  # none | vision | audio
    frontend_tokens: int = 0  # prefix positions supplied as embeddings

    # --- numerics ---
    dtype: str = "bfloat16"
    vocab_pad_to: int = 256

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, self.vocab_pad_to)

    @property
    def d_inner(self) -> int:
        """Inner width of mamba/xlstm mixers."""
        expand = self.mamba_expand if self.family != "ssm" else self.xlstm_expand
        return expand * self.d_model

    def mixer_for_layer(self, i: int) -> str:
        if self.family in ("dense", "moe", "vlm", "audio"):
            return MIXER_ATTN
        if self.family == "hybrid":
            if self.attn_layer_period and i % self.attn_layer_period == self.attn_layer_offset:
                return MIXER_ATTN
            return MIXER_MAMBA
        if self.family == "ssm":
            if self.slstm_every and i % self.slstm_every == self.slstm_offset:
                return MIXER_SLSTM
            return MIXER_MLSTM
        raise ValueError(f"unknown family {self.family}")

    def ffn_for_layer(self, i: int) -> str:
        if self.d_ff == 0 and self.n_experts == 0:
            return FFN_NONE
        if self.n_experts == 0:
            return FFN_DENSE
        if i < self.first_k_dense:
            return FFN_DENSE
        if i % self.moe_layer_period == self.moe_layer_offset:
            return FFN_MOE
        return FFN_DENSE

    def layer_specs(self) -> tuple[LayerSpec, ...]:
        return tuple(
            LayerSpec(self.mixer_for_layer(i), self.ffn_for_layer(i))
            for i in range(self.n_layers)
        )

    @property
    def has_kv_cache(self) -> bool:
        """True if any layer uses attention (needs a KV cache for decode)."""
        return any(s.mixer == MIXER_ATTN for s in self.layer_specs())

    @property
    def subquadratic(self) -> bool:
        """True if the arch can decode with O(1)-per-token state growth in
        the mixer majority (SSM/hybrid) — gate for long_500k."""
        return self.family in ("ssm", "hybrid")

    # ------------------------------------------------------------------
    # Parameter counting (for MODEL_FLOPS and memory napkin math).
    # ------------------------------------------------------------------
    def _attn_params(self) -> int:
        hd = self.resolved_head_dim
        q = self.d_model * self.n_heads * hd
        kv = 2 * self.d_model * self.n_kv_heads * hd
        o = self.n_heads * hd * self.d_model
        return q + kv + o

    def _dense_ffn_params(self, d_ff: int) -> int:
        mats = 3 if self.mlp_variant in ("swiglu", "geglu") else 2
        return mats * self.d_model * d_ff

    def _moe_ffn_params(self) -> tuple[int, int]:
        """(total, active-per-token) parameters of one MoE FFN layer."""
        d_ff = self.moe_d_ff or self.d_ff
        per_expert = self._dense_ffn_params(d_ff)
        router = self.d_model * self.n_experts
        shared = self.n_shared_experts * per_expert
        total = self.n_experts * per_expert + shared + router
        active = self.n_experts_per_token * per_expert + shared + router
        return total, active

    def _mamba_params(self) -> int:
        di, ds = self.mamba_expand * self.d_model, self.mamba_d_state
        in_proj = self.d_model * 2 * di
        conv = di * self.mamba_d_conv
        dt_rank = max(1, self.d_model // 16)
        x_proj = di * (dt_rank + 2 * ds)
        dt_proj = dt_rank * di
        out = di * self.d_model
        return in_proj + conv + x_proj + dt_proj + out + 2 * di  # A_log-ish, D

    def _mlstm_params(self) -> int:
        # mLSTM block: pre-up-projection (x2: cell input + output gate),
        # causal conv, block-diagonal per-head q/k/v, scalar i/f gates,
        # down projection.
        di = self.xlstm_expand * self.d_model
        hd = di // self.n_heads
        in_proj = self.d_model * 2 * di
        conv = di * self.mamba_d_conv
        qkv = 3 * self.n_heads * hd * hd  # block-diagonal
        gates = 2 * di  # i/f gate projections (per-channel -> per-head pooled)
        out = di * self.d_model
        return in_proj + conv + qkv + gates + out

    def _slstm_params(self) -> int:
        # sLSTM block: 4 gates x (dense input + block-diagonal recurrent),
        # plus the post-up-projection FFN (factor 4/3, GeLU) of the xLSTM
        # paper's sLSTM block.
        d, h = self.d_model, self.n_heads
        gates = 4 * (d * d + d * (d // max(1, h)))
        d_ffs = int(round(4 * d / 3))
        ffn = 2 * d * d_ffs
        return gates + ffn

    def param_count(self) -> int:
        n = self.padded_vocab * self.d_model  # embed
        if not self.tie_embeddings:
            n += self.padded_vocab * self.d_model
        for spec in self.layer_specs():
            if spec.mixer == MIXER_ATTN:
                n += self._attn_params()
            elif spec.mixer == MIXER_MAMBA:
                n += self._mamba_params()
            elif spec.mixer == MIXER_MLSTM:
                n += self._mlstm_params()
            elif spec.mixer == MIXER_SLSTM:
                n += self._slstm_params()
            if spec.ffn == FFN_DENSE:
                n += self._dense_ffn_params(self.d_ff)
            elif spec.ffn == FFN_MOE:
                total, _ = self._moe_ffn_params()
                n += total
            n += 2 * self.d_model  # norms
        return n

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed-active experts)."""
        n = self.padded_vocab * self.d_model
        if not self.tie_embeddings:
            n += self.padded_vocab * self.d_model
        for spec in self.layer_specs():
            if spec.mixer == MIXER_ATTN:
                n += self._attn_params()
            elif spec.mixer == MIXER_MAMBA:
                n += self._mamba_params()
            elif spec.mixer == MIXER_MLSTM:
                n += self._mlstm_params()
            elif spec.mixer == MIXER_SLSTM:
                n += self._slstm_params()
            if spec.ffn == FFN_DENSE:
                n += self._dense_ffn_params(self.d_ff)
            elif spec.ffn == FFN_MOE:
                _, active = self._moe_ffn_params()
                n += active
            n += 2 * self.d_model
        return n


# ---------------------------------------------------------------------------
# Input shapes
# ---------------------------------------------------------------------------

KIND_TRAIN = "train"
KIND_PREFILL = "prefill"
KIND_DECODE = "decode"


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


TRAIN_4K = ShapeConfig("train_4k", KIND_TRAIN, 4_096, 256)
PREFILL_32K = ShapeConfig("prefill_32k", KIND_PREFILL, 32_768, 32)
DECODE_32K = ShapeConfig("decode_32k", KIND_DECODE, 32_768, 128)
LONG_500K = ShapeConfig("long_500k", KIND_DECODE, 524_288, 1)

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES = {s.name: s for s in ALL_SHAPES}


def shape_applicable(model: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(applicable, reason-if-not). long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not model.subquadratic:
        return False, "long_500k skipped: pure full-attention arch (quadratic)"
    return True, ""


# ---------------------------------------------------------------------------
# Sharding / execution strategy
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShardingConfig:
    """How a model is laid out on the mesh for a given shape.

    `strategy` picks the parameter layout; the boolean knobs are the
    hillclimbing levers recorded in EXPERIMENTS.md §Perf.
    """

    strategy: str = "fsdp_tp"  # dp_tp | fsdp_tp
    expert_parallel: bool = True  # shard experts over the model axis
    seq_sharded_kv: bool = False  # decode: shard KV cache sequence axis
    kv_seq_axis: str = "data"  # mesh axis for the KV sequence shards
    seq_sharded_activations: bool = False  # sequence parallelism for residuals
    remat: str = "block"  # none | block | full
    grad_accum: int = 1  # microbatch count (train)
    scan_layers: bool = True  # scan over identical layer groups
    compress_grads: bool = False  # int8 error-feedback cross-pod all-reduce


@dataclass(frozen=True)
class MeshSpec:
    shape: tuple[int, ...]
    axes: tuple[str, ...]

    @property
    def n_devices(self) -> int:
        return math.prod(self.shape)

    @property
    def data_axes(self) -> tuple[str, ...]:
        return tuple(a for a in self.axes if a in ("pod", "data"))


SINGLE_POD = MeshSpec((16, 16), ("data", "model"))
MULTI_POD = MeshSpec((2, 16, 16), ("pod", "data", "model"))


# ---------------------------------------------------------------------------
# Hardware model (roofline constants — TPU v5e-class, per instructions)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class HardwareSpec:
    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12  # per chip
    hbm_bw: float = 819e9  # bytes/s per chip
    ici_bw: float = 50e9  # bytes/s per link
    hbm_bytes: float = 16e9  # per chip


V5E = HardwareSpec()


# ---------------------------------------------------------------------------
# Training / serving run configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    master_fp32: bool = True


@dataclass(frozen=True)
class TrainConfig:
    model: ModelConfig
    shape: ShapeConfig
    sharding: ShardingConfig = ShardingConfig()
    optimizer: OptimizerConfig = OptimizerConfig()
    seed: int = 0
    ckpt_every: int = 100
    ckpt_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10


def with_overrides(cfg, **kw):
    """Functional update for frozen configs."""
    return dataclasses.replace(cfg, **kw)
