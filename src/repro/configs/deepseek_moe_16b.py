"""DeepSeekMoE-16B — fine-grained MoE, 2 shared + 64 routed top-6
[arXiv:2401.06066].

28L, d_model=2048, 16H (GQA kv=16), per-expert d_ff=1408, vocab 102400.
First layer uses a dense FFN (intermediate 10944), as in the release.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=10944,  # dense-FFN layers (layer 0)
    moe_d_ff=1408,  # fine-grained routed/shared experts
    vocab_size=102400,
    n_experts=64,
    n_experts_per_token=6,
    n_shared_experts=2,
    first_k_dense=1,
    mlp_variant="swiglu",
    norm="rmsnorm",
    rope_theta=10_000.0,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b-smoke",
        family="moe",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=160,
        moe_d_ff=48,
        vocab_size=512,
        n_experts=8,
        n_experts_per_token=2,
        n_shared_experts=2,
        first_k_dense=1,
        mlp_variant="swiglu",
        dtype="float32",
    )
