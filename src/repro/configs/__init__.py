"""Config package: architecture, shape, mesh, hardware and run configs."""
