"""Llama-3-8B — dense GQA decoder, 128k vocab [arXiv:2407.21783].

32L, d_model=4096, 32H (GQA kv=8), d_ff=14336, vocab 128256, SwiGLU,
RMSNorm, RoPE theta 500k.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    mlp_variant="swiglu",
    norm="rmsnorm",
    rope_theta=500_000.0,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llama3-8b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        mlp_variant="swiglu",
        rope_theta=500_000.0,
        dtype="float32",
    )
