"""Command-R (c4ai-command-r-v01, 35B) — dense GQA decoder, no biases,
parallel attention/FFN residual block [hf:CohereForAI/c4ai-command-r-v01].

40L, d_model=8192, 64H (GQA kv=8), d_ff=22528, vocab 256000. LayerNorm
(no bias), tied embeddings, RoPE theta 8M.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22528,
    vocab_size=256000,
    mlp_variant="swiglu",
    norm="layernorm",
    parallel_block=True,
    tie_embeddings=True,
    rope_theta=8_000_000.0,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="command-r-35b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        mlp_variant="swiglu",
        norm="layernorm",
        parallel_block=True,
        tie_embeddings=True,
        dtype="float32",
    )
