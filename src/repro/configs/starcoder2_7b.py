"""StarCoder2-7B — dense GQA decoder with RoPE [arXiv:2402.19173].

32L, d_model=4608, 36H (GQA kv=4), d_ff=18432, vocab 49152.
Non-gated GELU MLP with biases; LayerNorm (the release uses standard
LayerNorm + bias throughout).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    d_ff=18432,
    vocab_size=49152,
    mlp_variant="gelu",
    mlp_bias=True,
    norm="layernorm",
    rope_theta=100_000.0,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-7b-smoke",
        family="dense",
        n_layers=2,
        d_model=72,  # keeps the 36-head flavour (9 heads x 8)
        n_heads=6,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        mlp_variant="gelu",
        mlp_bias=True,
        norm="layernorm",
        dtype="float32",
    )
