"""InternVL2-1B — InternViT vision frontend + Qwen2-0.5B-class LM backbone
[arXiv:2404.16821].

Backbone: 24L, d_model=896, 14H (GQA kv=2), d_ff=4864, vocab 151655.
Per instructions the ViT frontend is a STUB: `input_specs()` supplies
precomputed patch embeddings for the first `frontend_tokens` positions.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab_size=151655,
    mlp_variant="swiglu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,  # qwen2
    frontend="vision",
    frontend_tokens=256,  # one 448px tile -> 256 patch embeddings
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b-smoke",
        family="vlm",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        mlp_variant="swiglu",
        frontend="vision",
        frontend_tokens=8,
        dtype="float32",
    )
