"""Multi-process elastic ensemble farm drills (DESIGN.md §3i).

The contract under test: a farm of `Recovery.workers` worker PROCESSES
— each a RunSupervisor over one contiguous ensemble shard, supervised
by a coordinator through heartbeat files — produces a SimulationResult
whose records, per-point stats, trajectories, sketches, AND steering
decision log are BITWISE identical to the uninterrupted single-process
run with the same pinned statistics partition
(`Partitioning(n_shards=1, stat_blocks=B)`), no matter which workers
are SIGKILLed, SIGSTOPped, fed corrupt checkpoints, or retired and
reassigned along the way.

Worker lanes draw RNG key rows from the GLOBAL key table
(counter-based streams are position-independent) and grouped/pooled
statistics merge through the same associative Welford partial fold the
single-process engine uses — so equality is exact, not approximate.

Process drills are timing-dependent in WHERE a fault lands (a kill
scheduled at window w fires at the first heartbeat whose frontier
crossed w) but the merged result is timing-INDEPENDENT — which is the
point. Fault/restart counters therefore assert `>= 1` (a slow CI
machine can add a spurious-stall restart without breaking bitwiseness)
while the data assertions stay exact.
"""
import os

import numpy as np
import pytest

from repro.api import (
    Ensemble,
    Experiment,
    ExperimentError,
    Recovery,
    Reduction,
    Schedule,
    SketchSpec,
    Steering,
    simulate,
)
from repro.api.spec import Partitioning
from repro.core.reactions import make_system
from repro.runtime.fault import FailurePlan
from repro.runtime.straggler import FrontierWatchdog

# immigration-death sweep (X ~ Poisson, analytically mixed variance):
# high-lam points converge under the steering tolerance and stop early,
# low-lam points never do — so the drills exercise live steering
# decisions, not just pass-through statistics
LAMS = [50.0, 800.0, 50.0, 800.0, 50.0, 800.0]
REPLICAS, N_WINDOWS, WORKERS = 4, 12, 3


def _system():
    return make_system(
        ["A"], [({}, {"A": 1}, LAMS[0]), ({"A": 1}, {}, 1.0)],
        {"A": 0}, names=("birth", "death"))


def _exp(**kw):
    return Experiment(
        model=_system(),
        ensemble=Ensemble.make(replicas=REPLICAS,
                               sweep={"birth": LAMS}),
        schedule=Schedule(t_end=12.0, n_windows=N_WINDOWS),
        reduction=Reduction.PER_POINT,
        n_lanes=8, seed=5, window_block=2,
        steering=Steering(ci_rel_tol=0.03, min_windows=4),
        sketch=SketchSpec(n_bins=8),
        record_trajectories=True, **kw)


def _farm(tmp_path, schedule=None, **rec_kw):
    rec_kw.setdefault("workers", WORKERS)
    rec_kw.setdefault("heartbeat_s", 1.0)
    rec_kw.setdefault("cadence", 4)
    rec_kw.setdefault("keep_last", 3)
    rec_kw.setdefault("backoff_base_s", 0.0)
    inject = (FailurePlan(schedule=schedule)
              if schedule is not None else None)
    return simulate(_exp(recovery=Recovery(
        ckpt_dir=str(tmp_path / "farm"), inject=inject, **rec_kw)))


@pytest.fixture(scope="module")
def reference():
    """The uninterrupted single-process run every drill compares
    against — statistics partition pinned to the farm's block count."""
    return simulate(_exp(partitioning=Partitioning(
        n_shards=1, stat_blocks=WORKERS)))


def assert_farm_bitwise(ref, farm, ctx=""):
    assert len(ref.records) == len(farm.records), ctx
    for ra, rb in zip(ref.records, farm.records):
        assert ra.t == rb.t and ra.window == rb.window, ctx
        assert ra.n == rb.n, ctx
        assert (ra.mean == rb.mean).all(), ctx
        assert (ra.var == rb.var).all(), ctx
        assert (ra.ci90 == rb.ci90).all(), ctx
    ga, gb = ref.per_point(), farm.per_point()
    for f in ("n", "mean", "var", "ci90"):
        assert (np.asarray(ga[f]) == np.asarray(gb[f])).all(), (ctx, f)
    assert (np.asarray(ref.trajectories())
            == np.asarray(farm.trajectories())).all(), ctx
    assert (ref.final_state() == farm.final_state()).all(), ctx
    for sa, sb in zip(ref.sketches(), farm.sketches()):
        assert (np.asarray(sa.hist) == np.asarray(sb.hist)).all(), ctx
    assert ref.steering_report() == farm.steering_report(), ctx


def _events(report, name):
    return [e for e in report["events"] if e["event"] == name]


# --------------------------------------------------------- fault-free
def test_farm_fault_free_is_bitwise(reference, tmp_path):
    farm = _farm(tmp_path)
    assert_farm_bitwise(reference, farm)
    rep = farm.recovery_report()
    assert rep["workers"] == WORKERS
    assert rep["restarts"] == 0 and rep["faults_by_kind"] == {}
    assert rep["reassignments"] == 0
    assert len(_events(rep, "worker_launched")) == WORKERS
    assert len(_events(rep, "worker_done")) == WORKERS
    # steering forces lock-step in every worker, and that's VISIBLE
    assert rep["pipeline_depth_effective"] == 1
    assert sorted(rep["worker_reports"]) == list(range(WORKERS))
    # the farm actually steered: converged points stopped early
    assert farm.steering_report()["stopped_points"] == [1, 3, 5]


# ------------------------------------------------------------- drills
def test_farm_sigkill_drill_restarts_and_stays_bitwise(
        reference, tmp_path):
    """SIGKILL a worker mid-run: the coordinator sees the dead process
    (HostLost), restarts it after backoff, and the relaunched worker
    resumes from its newest namespaced checkpoint — merged result
    bitwise, event log enumerating the whole story."""
    farm = _farm(tmp_path, schedule={2: "host_lost"})
    assert_farm_bitwise(reference, farm, "sigkill")
    rep = farm.recovery_report()
    assert rep["restarts"] >= 1
    assert rep["faults_by_kind"].get("host_lost", 0) >= 1
    inj = _events(rep, "fault_injected")
    assert inj and inj[0]["kind"] == "host_lost"
    assert _events(rep, "fault") and _events(rep, "restart_scheduled")
    # every shard still finished
    assert len(_events(rep, "worker_done")) >= WORKERS


def test_farm_sigstop_stall_drill(reference, tmp_path):
    """SIGSTOP freezes a worker AND its heartbeat thread; the stale
    heartbeat crosses 3 x heartbeat_s, the coordinator SIGKILLs the
    wedged process (typed worker_stall) and restarts it."""
    farm = _farm(tmp_path, schedule={2: "worker_stall"})
    assert_farm_bitwise(reference, farm, "sigstop")
    rep = farm.recovery_report()
    assert rep["restarts"] >= 1
    assert rep["faults_by_kind"].get("worker_stall", 0) >= 1
    stalls = [e for e in _events(rep, "fault")
              if e["kind"] == "worker_stall"]
    assert stalls and "stale" in stalls[0]["error"]


def test_farm_corrupt_checkpoint_drill(reference, tmp_path):
    """Kill a worker AND truncate its newest checkpoint: the restarted
    worker's restore must fall back PAST the corrupt file (or to a
    fresh window-0 start) and still replay to the bitwise answer."""
    farm = _farm(tmp_path, schedule={3: "ckpt_corrupt"}, cadence=2)
    assert_farm_bitwise(reference, farm, "corrupt")
    rep = farm.recovery_report()
    assert rep["restarts"] >= 1
    inj = _events(rep, "fault_injected")
    assert inj and inj[0]["kind"] == "ckpt_corrupt"
    # the injected shard's final (successful) supervisor run logged
    # the corrupt file it skipped on restore
    shard = inj[0]["shard"]
    skipped = [e for e in rep["worker_reports"][shard]["events"]
               if e["event"] == "corrupt_checkpoint_skipped"]
    assert skipped, rep["worker_reports"][shard]["events"]


def test_farm_host_loss_reassigns_shard_to_survivor(
        reference, tmp_path):
    """Past max_worker_restarts the slot is RETIRED and its shard goes
    back on the queue; the first survivor that finishes its own shard
    picks it up — same namespace, so the reassigned run resumes from
    the retired worker's checkpoints and the merge stays bitwise."""
    farm = _farm(tmp_path, schedule={2: "host_lost"},
                 max_worker_restarts=0, heartbeat_s=2.0)
    assert_farm_bitwise(reference, farm, "reassign")
    rep = farm.recovery_report()
    assert rep["reassignments"] >= 1
    assert any(w["retired"] for w in rep["per_worker"].values())
    retired = _events(rep, "worker_retired")
    moved = _events(rep, "shard_reassigned")
    assert retired and moved
    # the reassigned shard landed on a DIFFERENT slot than its owner
    assert moved[0]["to_worker"] != moved[0]["from_worker"]
    # the picking-up slot ran more than one shard
    assert any(len(w["shards_run"]) > 1
               for w in rep["per_worker"].values())


# ------------------------------------------------- validation + units
def test_farm_rejects_device_sharding_inside_workers(tmp_path):
    with pytest.raises(ExperimentError, match="PROCESS"):
        simulate(_exp(
            partitioning=Partitioning(n_shards=2),
            recovery=Recovery(ckpt_dir=str(tmp_path / "x"), workers=3)))


def test_farm_rejects_ragged_block_partition(tmp_path):
    with pytest.raises(ExperimentError, match="whole stat blocks"):
        simulate(_exp(
            partitioning=Partitioning(n_shards=1, stat_blocks=4),
            recovery=Recovery(ckpt_dir=str(tmp_path / "x"), workers=3)))


def test_farm_rejects_cross_point_reallocation(tmp_path):
    exp = _exp(recovery=Recovery(ckpt_dir=str(tmp_path / "x"),
                                 workers=3))
    exp = exp.with_(steering=Steering(ci_rel_tol=0.03, min_windows=4,
                                      reallocate=True))
    with pytest.raises(ExperimentError, match="reallocate"):
        simulate(exp)


def test_farm_rejects_pooled_convergence_steering(tmp_path):
    exp = _exp(recovery=Recovery(ckpt_dir=str(tmp_path / "x"),
                                 workers=3))
    exp = exp.with_(reduction=Reduction.ENSEMBLE)
    with pytest.raises(ExperimentError, match="per-point"):
        simulate(exp)


def test_farm_rejects_engine_internal_fault_kinds(tmp_path):
    """nan_pool / device_lost are ENGINE faults — they drill the
    in-process supervisor, not the process farm."""
    with pytest.raises(ValueError, match="coordinator"):
        _farm(tmp_path, schedule={2: "nan_pool"})


def test_frontier_watchdog_flags_laggard():
    wd = FrontierWatchdog(grace_windows=4)
    wd.observe(0, 8)
    wd.observe(1, 8)
    assert not wd.observe(2, 8)
    assert wd.observe(2, 4) is False  # frontier is monotone: keeps 8
    wd.frontiers[2] = 4               # force a lag for the check
    assert wd.observe(2, 4)           # 8 - 4 >= grace -> flagged
    assert wd.flagged and wd.flagged[0][0] == 2
    rate = wd.straggler_rate()
    assert 0 < rate <= 1
    wd.forget(2)
    assert 2 not in wd.frontiers
