"""Data pipeline: determinism + prefetch equivalence."""
import numpy as np

from repro.configs.registry import get_smoke_config
from repro.data.pipeline import DataConfig, PrefetchPipeline, synth_batch


def test_deterministic_per_step():
    cfg = get_smoke_config("llama3-8b")
    a = synth_batch(cfg, 4, 16, step=3)
    b = synth_batch(cfg, 4, 16, step=3)
    c = synth_batch(cfg, 4, 16, step=4)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert (a["tokens"] != c["tokens"]).any()


def test_targets_are_shifted_tokens():
    cfg = get_smoke_config("llama3-8b")
    b = synth_batch(cfg, 2, 8, step=0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])


def test_vlm_mask_excludes_image_positions():
    cfg = get_smoke_config("internvl2-1b")
    b = synth_batch(cfg, 2, 16, step=0)
    p = cfg.frontend_tokens
    assert (b["loss_mask"][:, :p] == 0).all()
    assert (b["loss_mask"][:, p:] == 1).all()
    assert b["tokens"].shape == (2, 16 - p)


def test_prefetch_matches_direct_and_resumes():
    cfg = get_smoke_config("llama3-8b")
    pipe = PrefetchPipeline(cfg, 2, 8, start_step=5)
    try:
        for want in (5, 6, 7):
            got = next(pipe)
            assert got["_step"] == want
            direct = synth_batch(cfg, 2, 8, want)
            np.testing.assert_array_equal(got["tokens"], direct["tokens"])
    finally:
        pipe.close()


def test_tokens_within_vocab():
    cfg = get_smoke_config("gemma-7b")
    b = synth_batch(cfg, 4, 32, step=9)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < cfg.vocab_size
