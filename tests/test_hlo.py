"""HLO collective parser (roofline input)."""
from repro.launch.hlo import Collective, collective_summary, parse_collectives

SAMPLE = """
HloModule jit_step

%fused (x: f32[8,16]) -> f32[8,16] {
  ...
}

ENTRY %main {
  %ar = f32[8,4096,1024]{2,1,0} all-reduce(%p0), channel_id=1, replica_groups=[32,16]<=[512], use_global_device_ids=true, to_apply=%add
  %ag = bf16[256,1024]{1,0} all-gather(%p1), channel_id=2, replica_groups=[16,32]<=[512], dimensions={0}
  %ag2 = bf16[256,1024]{1,0} all-gather(%p1), channel_id=3, replica_groups=[16,32]<=[512], dimensions={0}
  %rs = f32[64,128]{1,0} reduce-scatter(%p2), channel_id=4, replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}
  %a2a = bf16[16,64]{1,0} all-to-all(%p3), channel_id=5, replica_groups=[64,8]<=[512]
  %cp = u32[4]{0} collective-permute(%p4), channel_id=6, source_target_pairs={{0,1}}
  %ars = (f32[100]{0}, f32[100]{0}) all-reduce-start(%p5, %p6), channel_id=7, replica_groups=[1,512]<=[512]
  %ard = (f32[100]{0}, f32[100]{0}) all-reduce-done(%ars)
}
"""


def test_parse_ops_and_groups():
    colls = parse_collectives(SAMPLE)
    by_op = {}
    for c in colls:
        by_op.setdefault(c.op, []).append(c)
    assert sum(c.count for c in by_op["all-reduce"]) == 2  # ar + ar-start
    assert sum(c.count for c in by_op["all-gather"]) == 2
    ar = [c for c in by_op["all-reduce"] if c.group_size == 16][0]
    assert ar.bytes_buffer == 8 * 4096 * 1024 * 4
    rs = by_op["reduce-scatter"][0]
    assert rs.group_size == 4  # literal groups
    assert by_op["collective-permute"][0].bytes_buffer == 16


def test_moved_bytes_factors():
    ar = Collective("all-reduce", 1000, 4)
    assert abs(ar.moved_bytes - 2 * 3 / 4 * 1000) < 1e-9
    ag = Collective("all-gather", 1000, 4)
    assert abs(ag.moved_bytes - 3 / 4 * 1000) < 1e-9
    rs = Collective("reduce-scatter", 1000, 4)
    assert abs(rs.moved_bytes - 3 * 1000) < 1e-9
    cp = Collective("collective-permute", 1000, 1)
    assert cp.moved_bytes == 1000


def test_summary_totals():
    colls = parse_collectives(SAMPLE)
    s = collective_summary(colls)
    assert s["moved_bytes_per_device"] > 0
    assert set(s["by_op"]) <= {"all-reduce", "all-gather", "reduce-scatter",
                               "all-to-all", "collective-permute"}
    # tuple-shaped async all-reduce counted once with both operands
    ar_small = [c for c in colls
                if c.op == "all-reduce" and c.group_size == 512][0]
    assert ar_small.bytes_buffer == 2 * 100 * 4
