"""CWC: terms, compiler, reference simulator, tensor-engine equivalence."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cwc import reference
from repro.core.cwc.compile import compile_model
from repro.core.cwc.models import (
    ecoli_gene_regulation,
    lotka_volterra,
    membrane_transport,
)
from repro.core.cwc.terms import TOP, atoms, comp, term
from repro.core.engine import SimConfig, SimulationEngine
from repro.core.reactions import propensities, propensities_ref


def test_term_walk_and_counts():
    t = term({"a": 3}, comps=[comp("cell", wrap={"m": 1},
                                   content=term({"b": 2}))])
    paths = [p for p, _, _ in t.walk()]
    assert paths == [(), (0,)]
    assert t.total_atoms() == 3 + 2 + 1


def test_compile_shapes():
    sys, meta = compile_model(lotka_volterra(2))
    assert sys.n_species == 2 and sys.n_reactions == 3
    sys, meta = compile_model(ecoli_gene_regulation())
    assert sys.n_species == 4 and sys.n_reactions == 6
    sys, meta = compile_model(membrane_transport())
    # a,b at top + a,b in cell; uptake + dimerise + export
    assert sys.n_species == 4 and sys.n_reactions == 3


@pytest.mark.parametrize("model_fn", [lotka_volterra, ecoli_gene_regulation,
                                      membrane_transport])
def test_compiled_propensities_match_reference_matchset(model_fn, rng):
    """The deterministic oracle: total match rate of the reference
    simulator == sum of compiled propensities, on random states."""
    model = model_fn()
    sys, meta = compile_model(model)
    # build a reference term whose contents mirror a random state vector
    for _ in range(5):
        x = rng.integers(0, 30, sys.n_species).astype(np.float32)
        t0 = model.initial_term()
        # overwrite counts: species names are "<ctx>/<atom>"
        by_path = {}
        for pth, lab, content in t0.walk():
            by_path[pth] = content
        for i, name in enumerate(sys.species_names):
            ctx, atom = name.rsplit("/", 1)
            path = _parse_path(ctx)
            c = by_path[path].atoms
            if x[i] > 0:
                c[atom] = int(x[i])
            elif atom in c:
                del c[atom]
        ms = reference.build_matchset(t0, model.rules)
        ref_total = sum(m.rate for m in ms)
        a = propensities(jnp.asarray(x[None]),
                         jnp.asarray(sys.reactant_idx),
                         jnp.asarray(sys.reactant_coef),
                         jnp.asarray(sys.rates))
        assert abs(float(a.sum()) - ref_total) < 1e-3 * max(1.0, ref_total)


def _parse_path(ctx: str):
    if "[" not in ctx:
        return ()
    inside = ctx[ctx.index("[") + 1:ctx.index("]")]
    return tuple(int(p) for p in inside.split("."))


def test_reference_simulator_runs():
    model = ecoli_gene_regulation()
    grid = np.linspace(1, 10, 10)
    out = reference.simulate(model, grid, seed=0)
    assert out.shape == (10, 2)
    assert (out >= 0).all()


def test_reference_vs_tensor_engine_statistical():
    """Means of the faithful sequential simulator vs the tensorised
    engine agree within CI on the E. coli model."""
    model = ecoli_gene_regulation()
    grid = np.linspace(2, 10, 5)
    n_ref = 30
    ref = np.stack([reference.simulate(model, grid, seed=s)
                    for s in range(n_ref)])  # (n, T, 2)
    cfg = SimConfig(n_instances=256, t_end=10.0, n_windows=5, n_lanes=256,
                    schema="iii", seed=1)
    eng = SimulationEngine(model, cfg)
    recs = eng.run()
    for w in range(5):
        m_t = recs[w].mean
        m_r = ref[:, w].mean(axis=0)
        sd_r = ref[:, w].std(axis=0) / np.sqrt(n_ref)
        err = np.abs(m_t - m_r)
        assert (err < 5 * sd_r + 2.0).all(), (w, m_t, m_r, sd_r)


def test_transport_conserves_mass():
    model = membrane_transport()
    cfg = SimConfig(n_instances=32, t_end=20.0, n_windows=4, n_lanes=32,
                    schema="iii", seed=2)
    eng = SimulationEngine(model, cfg)
    eng.run()
    x = np.asarray(eng._pool.x)  # columns: ⊤/a, ⊤/b, cell/a, cell/b
    names = eng.system.species_names
    a_tot = x[:, names.index("⊤/a")] + x[:, names.index("cell[0]/a")]
    b_tot = x[:, names.index("⊤/b")] + x[:, names.index("cell[0]/b")]
    assert ((a_tot + 2 * b_tot) == 500).all()
