"""Property-based path-parity tests: RANDOM small ReactionSystems must
produce bitwise-identical records and trajectories across the fused,
host-loop, and Pallas-kernel dispatch paths — for BOTH the exact SSA
and the tau-leap method — plus lane-grouping invariance.

The property runs through `hypothesis` when it is installed
(requirements-dev.txt lists it as optional), and ALWAYS through a
deterministic seeded sweep, so CI exercises the property even on
images without hypothesis.
"""
import numpy as np
import pytest

from repro.api import Ensemble, Experiment, Method, Schedule, simulate
from repro.core.reactions import MAX_COEF, MAX_REACTANTS, make_system

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional dependency
    HAVE_HYPOTHESIS = False


def random_system(seed: int):
    """A random well-formed ReactionSystem: 1-4 species, 1-5 reactions,
    reactant multiplicities within the MAX_COEF unroll, populations
    small enough to keep windows cheap but large enough that tau-leap
    sometimes actually leaps."""
    rng = np.random.default_rng(seed)
    s = int(rng.integers(1, 5))
    species = [f"X{i}" for i in range(s)]
    reactions = []
    for _ in range(int(rng.integers(1, 6))):
        n_react = int(rng.integers(0, min(2, s) + 1))  # 0 = source
        lhs_names = list(rng.choice(s, size=n_react, replace=False))
        lhs = {species[i]: int(rng.integers(1, min(MAX_COEF, 2) + 1))
               for i in lhs_names}
        assert len(lhs) <= MAX_REACTANTS
        n_prod = int(rng.integers(0, min(2, s) + 1))
        rhs = {species[i]: int(rng.integers(1, 3))
               for i in rng.choice(s, size=n_prod, replace=False)}
        k = float(10.0 ** rng.uniform(-2, 0.7))
        reactions.append((lhs, rhs, k))
    x0 = {name: int(rng.integers(0, 800)) for name in species}
    return make_system(species, reactions, x0)


def _run(system, method, seed, max_windows=None, checkpoint_path=None,
         resume=False, n_lanes=4, **kw):
    kw.setdefault("record_trajectories", True)
    return simulate(Experiment(
        model=system,
        ensemble=Ensemble.make(replicas=8),
        schedule=Schedule(t_end=0.3, n_windows=2),
        n_lanes=n_lanes, seed=seed, method=method, **kw),
        max_windows=max_windows, checkpoint_path=checkpoint_path,
        resume=resume)


def check_paths_bitwise(seed: int):
    """THE property: every dispatch path replays the identical
    per-lane trajectories, for both algorithms, on a random system."""
    system = random_system(seed)
    for method in (Method.EXACT, Method.TAU_LEAP):
        base = _run(system, method, seed)
        variants = {
            "host_loop": _run(system, method, seed, host_loop=True),
            "kernel": _run(system, method, seed, use_kernel=True,
                           kernel_chunk_steps=64,
                           kernel_max_chunks=4096),
            "wide_lanes": _run(system, method, seed, n_lanes=8),
        }
        for name, res in variants.items():
            assert (res.means() == base.means()).all(), (seed, method,
                                                         name)
            assert (res.trajectories() == base.trajectories()).all(), (
                seed, method, name)
            for a, b in zip(base.records, res.records):
                assert (a.var == b.var).all(), (seed, method, name)
                assert (a.ci90 == b.ci90).all(), (seed, method, name)
        # the two methods walk the same (key, ctr) streams — states
        # stay valid either way
        assert (base.trajectories() >= 0).all(), (seed, method)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_random_system_paths_bitwise_seeded(seed):
    """Deterministic sweep of the property (runs with or without
    hypothesis installed)."""
    check_paths_bitwise(seed)


if HAVE_HYPOTHESIS:
    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_random_system_paths_bitwise_hypothesis(seed):
        check_paths_bitwise(seed)
else:  # the decorators themselves need hypothesis — define a skip stub
    @pytest.mark.skip(reason="hypothesis not installed (optional)")
    def test_random_system_paths_bitwise_hypothesis():
        pass


@pytest.mark.parametrize("seed", [0, 1])
def test_random_system_checkpoint_resume_bitwise(seed, tmp_path):
    """Resume-from-checkpoint replays the identical stream on random
    systems too (the 64-bit counter is part of the lane state)."""
    system = random_system(seed)
    for method in (Method.EXACT, Method.TAU_LEAP):
        ck = str(tmp_path / f"ck_{method.value}_{seed}")
        clean = _run(system, method, seed)
        _run(system, method, seed, max_windows=1, checkpoint_path=ck)
        resumed = _run(system, method, seed, checkpoint_path=ck,
                       resume=True)
        assert (resumed.trajectories() == clean.trajectories()).all()
