"""Property-based path-parity tests: RANDOM small ReactionSystems must
produce bitwise-identical records and trajectories across the fused,
host-loop, and Pallas-kernel dispatch paths — for BOTH the exact SSA
and the tau-leap method — plus lane-grouping invariance.

The property runs through `hypothesis` when it is installed
(requirements-dev.txt lists it as optional), and ALWAYS through a
deterministic seeded sweep, so CI exercises the property even on
images without hypothesis.
"""
import numpy as np
import pytest

from repro.api import Ensemble, Experiment, Method, Schedule, simulate
from repro.core.reactions import MAX_COEF, MAX_REACTANTS, make_system

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional dependency
    HAVE_HYPOTHESIS = False


def random_system(seed: int):
    """A random well-formed ReactionSystem: 1-4 species, 1-5 reactions,
    reactant multiplicities within the MAX_COEF unroll, populations
    small enough to keep windows cheap but large enough that tau-leap
    sometimes actually leaps."""
    rng = np.random.default_rng(seed)
    s = int(rng.integers(1, 5))
    species = [f"X{i}" for i in range(s)]
    reactions = []
    for _ in range(int(rng.integers(1, 6))):
        n_react = int(rng.integers(0, min(2, s) + 1))  # 0 = source
        lhs_names = list(rng.choice(s, size=n_react, replace=False))
        lhs = {species[i]: int(rng.integers(1, min(MAX_COEF, 2) + 1))
               for i in lhs_names}
        assert len(lhs) <= MAX_REACTANTS
        n_prod = int(rng.integers(0, min(2, s) + 1))
        rhs = {species[i]: int(rng.integers(1, 3))
               for i in rng.choice(s, size=n_prod, replace=False)}
        k = float(10.0 ** rng.uniform(-2, 0.7))
        reactions.append((lhs, rhs, k))
    x0 = {name: int(rng.integers(0, 800)) for name in species}
    return make_system(species, reactions, x0)


def _run(system, method, seed, max_windows=None, checkpoint_path=None,
         resume=False, n_lanes=4, **kw):
    kw.setdefault("record_trajectories", True)
    return simulate(Experiment(
        model=system,
        ensemble=Ensemble.make(replicas=8),
        schedule=Schedule(t_end=0.3, n_windows=2),
        n_lanes=n_lanes, seed=seed, method=method, **kw),
        max_windows=max_windows, checkpoint_path=checkpoint_path,
        resume=resume)


def check_paths_bitwise(seed: int):
    """THE property: every dispatch path replays the identical
    per-lane trajectories, for both algorithms, on a random system."""
    system = random_system(seed)
    for method in (Method.EXACT, Method.TAU_LEAP):
        base = _run(system, method, seed)
        variants = {
            "host_loop": _run(system, method, seed, host_loop=True),
            "kernel": _run(system, method, seed, use_kernel=True,
                           kernel_chunk_steps=64,
                           kernel_max_chunks=4096),
            "wide_lanes": _run(system, method, seed, n_lanes=8),
        }
        for name, res in variants.items():
            assert (res.means() == base.means()).all(), (seed, method,
                                                         name)
            assert (res.trajectories() == base.trajectories()).all(), (
                seed, method, name)
            for a, b in zip(base.records, res.records):
                assert (a.var == b.var).all(), (seed, method, name)
                assert (a.ci90 == b.ci90).all(), (seed, method, name)
        # the two methods walk the same (key, ctr) streams — states
        # stay valid either way
        assert (base.trajectories() >= 0).all(), (seed, method)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_random_system_paths_bitwise_seeded(seed):
    """Deterministic sweep of the property (runs with or without
    hypothesis installed)."""
    check_paths_bitwise(seed)


if HAVE_HYPOTHESIS:
    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_random_system_paths_bitwise_hypothesis(seed):
        check_paths_bitwise(seed)
else:  # the decorators themselves need hypothesis — define a skip stub
    @pytest.mark.skip(reason="hypothesis not installed (optional)")
    def test_random_system_paths_bitwise_hypothesis():
        pass


@pytest.mark.parametrize("seed", [0, 1])
def test_random_system_checkpoint_resume_bitwise(seed, tmp_path):
    """Resume-from-checkpoint replays the identical stream on random
    systems too (the 64-bit counter is part of the lane state)."""
    system = random_system(seed)
    for method in (Method.EXACT, Method.TAU_LEAP):
        ck = str(tmp_path / f"ck_{method.value}_{seed}")
        clean = _run(system, method, seed)
        _run(system, method, seed, max_windows=1, checkpoint_path=ck)
        resumed = _run(system, method, seed, checkpoint_path=ck,
                       resume=True)
        assert (resumed.trajectories() == clean.trajectories()).all()


# --- sparse encoding parity (DESIGN.md §3g) --------------------------


def check_sparse_bitwise(seed: int):
    """THE sparse property: on a random system, every sparse
    configuration replays the DENSE trajectories bit-for-bit — records
    (mean/var/ci90), raw trajectories, and the step/leap telemetry.
    The dependency-graph update, the carried propensity vector, the
    gather-form tau Match, and the in-kernel species partitioning must
    all be invisible in the bits."""
    system = random_system(seed)
    for method in (Method.EXACT, Method.TAU_LEAP):
        dense = _run(system, method, seed)
        variants = {
            "sparse": _run(system, method, seed, sparse=True),
            "sparse_kernel": _run(system, method, seed, sparse=True,
                                  use_kernel=True, kernel_chunk_steps=64,
                                  kernel_max_chunks=4096),
            "sparse_superstep": _run(system, method, seed, sparse=True,
                                     window_block=2),
            "sparse_host_loop": _run(system, method, seed, sparse=True,
                                     host_loop=True),
        }
        for name, res in variants.items():
            assert (res.means() == dense.means()).all(), (seed, method,
                                                          name)
            assert (res.trajectories() == dense.trajectories()).all(), (
                seed, method, name)
            for a, b in zip(dense.records, res.records):
                assert a.t == b.t and a.n == b.n, (seed, method, name)
                assert (a.var == b.var).all(), (seed, method, name)
                assert (a.ci90 == b.ci90).all(), (seed, method, name)
            assert (res.telemetry.steps_per_window
                    == dense.telemetry.steps_per_window), (seed, method,
                                                           name)
            assert (res.telemetry.leaps_per_window
                    == dense.telemetry.leaps_per_window), (seed, method,
                                                           name)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_random_system_sparse_bitwise_seeded(seed):
    check_sparse_bitwise(seed)


if HAVE_HYPOTHESIS:
    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_random_system_sparse_bitwise_hypothesis(seed):
        check_sparse_bitwise(seed)
else:
    @pytest.mark.skip(reason="hypothesis not installed (optional)")
    def test_random_system_sparse_bitwise_hypothesis():
        pass


@pytest.mark.parametrize("seed", [0, 1])
def test_sparse_checkpoint_resume_bitwise(seed, tmp_path):
    """A sparse run's checkpoint resumes into the same stream — and
    that stream is still the dense one (the carried propensity vector
    is NOT part of the checkpoint: it is recomputed from x at the
    window boundary, a pure function of restored state)."""
    system = random_system(seed)
    for method in (Method.EXACT, Method.TAU_LEAP):
        ck = str(tmp_path / f"ck_sp_{method.value}_{seed}")
        dense = _run(system, method, seed)
        _run(system, method, seed, sparse=True, max_windows=1,
             checkpoint_path=ck)
        resumed = _run(system, method, seed, sparse=True,
                       checkpoint_path=ck, resume=True)
        assert (resumed.trajectories() == dense.trajectories()).all()


def test_sparse_sharded_bitwise():
    """Sparse composes with shard_map: on forced host devices the
    sharded sparse path reproduces the single-device DENSE records and
    trajectories bit-for-bit (subprocess: the main pytest process keeps
    the real 1-device platform)."""
    import os
    import subprocess
    import sys
    import textwrap

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    snippet = textwrap.dedent("""
        from repro.api import (Ensemble, Experiment, Partitioning,
                               Schedule, simulate)
        from tests.test_property import random_system

        def run(**kw):
            return simulate(Experiment(
                model=random_system(3),
                ensemble=Ensemble.make(replicas=16),
                schedule=Schedule(t_end=0.3, n_windows=2),
                n_lanes=8, seed=5, record_trajectories=True, **kw))

        for method in ("exact", "tau_leap"):
            dense = run(method=method)
            for kw in (dict(), dict(use_kernel=True)):
                shard = run(method=method, sparse=True,
                            partitioning=Partitioning(n_shards=4,
                                                      stat_blocks=4),
                            **kw)
                for a, b in zip(dense.records, shard.records):
                    assert a.t == b.t and a.n == b.n
                    assert (a.mean == b.mean).all(), (method, kw)
                    assert (a.var == b.var).all(), (method, kw)
                assert (dense.trajectories()
                        == shard.trajectories()).all(), (method, kw)
        print("SNIPPET-RAN")
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(repo, "src") + os.pathsep + repo
    out = subprocess.run([sys.executable, "-c", snippet],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "SNIPPET-RAN" in out.stdout


def test_high_coefficient_system_is_sparse_only():
    """Stoichiometric coefficients beyond the dense unroll cap
    (MAX_COEF=4) run ONLY through the sparse encoding — the dense path
    refuses (it would be silently wrong), and the sparse propensity
    math matches the exact-combinatorics numpy oracle."""
    import jax.numpy as jnp

    from repro.core.reactions import (propensities, propensities_ref,
                                      sparse_tables)

    sys5 = make_system(
        ["A", "P"],
        [({}, {"A": 1}, 30.0),
         ({"A": 1}, {}, 0.5),
         ({"A": MAX_COEF + 1}, {"P": 1}, 1e-4),
         ({"P": 1}, {}, 0.2)],
        {"A": 60},
        names=["feed", "decay", "pentamerise", "p-decay"])
    with pytest.raises(ValueError, match="sparse=True"):
        _run(sys5, Method.EXACT, seed=0)
    # the sparse unroll bound covers the real coefficient: check the
    # propensity math against the oracle at several population levels
    x = np.asarray([[n, 0.0] for n in (0, 3, 4, 5, 9, 60)], np.float32)
    a = propensities(jnp.asarray(x), jnp.asarray(sys5.reactant_idx),
                     jnp.asarray(sys5.reactant_coef),
                     jnp.asarray(sys5.rates),
                     max_c=sparse_tables(sys5).max_coef)
    np.testing.assert_allclose(np.asarray(a), propensities_ref(x, sys5),
                               rtol=1e-5, atol=1e-8)
    # and the full engine runs it: exact + tau, unfused + kernel, all
    # bitwise-identical to each other
    base = _run(sys5, Method.EXACT, seed=9, sparse=True)
    kern = _run(sys5, Method.EXACT, seed=9, sparse=True,
                use_kernel=True, kernel_chunk_steps=64,
                kernel_max_chunks=4096)
    assert (base.trajectories() == kern.trajectories()).all()
    tau = _run(sys5, Method.TAU_LEAP, seed=9, sparse=True)
    tau_k = _run(sys5, Method.TAU_LEAP, seed=9, sparse=True,
                 use_kernel=True, kernel_chunk_steps=64,
                 kernel_max_chunks=4096)
    assert (tau.trajectories() == tau_k.trajectories()).all()
    assert (base.trajectories() >= 0).all()
