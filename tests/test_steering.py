"""Steering controller (repro.steer): the identity contract, each
lever (early-stop, reallocate, tau-switch, bimodality), decision
determinism, and the FailureInjector crash-recovery drill — a steered
run killed at window boundaries must replay the identical decision
sequence and record bits from its checkpoints.
"""
import numpy as np
import pytest

from repro.api import (
    Ensemble,
    Experiment,
    ExperimentError,
    Method,
    Reduction,
    Schedule,
    SketchSpec,
    Steering,
    simulate,
)
from repro.api.run import build_engine
from repro.core.reactions import make_system
from repro.runtime.fault import FailureInjector, FailurePlan
from repro.steer.policy import SteeringPolicy

# mixed-variance immigration-death sweep: X(t) ~ Poisson(m(t)) with
# m(t) = (lam/mu)(1 - e^{-t}); at saturation the relative CI is
# 1.645 / sqrt(replicas * lam) — 0.010 for lam=800 (stops under
# tol=0.03 at the first decision point past min_windows) vs 0.042 for
# lam=50 (never stops)
LAMS = (50.0, 800.0)
REPLICAS, N_WINDOWS, T_END = 32, 8, 8.0


def _system():
    return make_system(
        ["A"], [({}, {"A": 1}, LAMS[0]), ({"A": 1}, {}, 1.0)],
        {"A": 0}, names=("birth", "death"))


def _exp(steering=None, **kw):
    kw.setdefault("window_block", 2)
    return Experiment(
        model=_system(),
        ensemble=Ensemble.make(replicas=REPLICAS,
                               sweep={"birth": list(LAMS)}),
        schedule=Schedule(t_end=T_END, n_windows=N_WINDOWS),
        reduction=Reduction.PER_POINT,
        n_lanes=16, seed=5, steering=steering, **kw)


_STOP = Steering(ci_rel_tol=0.03, min_windows=4)


def _rec_tuple(res):
    return [(r.t, r.n, r.mean.tobytes(), r.var.tobytes(),
             r.ci90.tobytes()) for r in res.records]


# ------------------------------------------------- identity contract
def test_all_off_steering_is_inert_and_bitwise():
    """`Steering()` (every lever off) never even instantiates the
    policy, and an ACTIVE policy that makes no decision (tolerance no
    point can meet) still leaves every record bit untouched — steered
    runs route through the block collector, so this also pins
    block-loop == per-window bitwise equality at window_block=1."""
    plain = simulate(_exp())
    inert = simulate(_exp(steering=Steering()))
    assert inert._engine._steer is None
    assert _rec_tuple(inert) == _rec_tuple(plain)

    active = simulate(_exp(steering=Steering(ci_rel_tol=1e-9)))
    assert active._engine._steer is not None
    assert active.steering_report()["decisions"] == []
    assert _rec_tuple(active) == _rec_tuple(plain)


# ------------------------------------------------------- early-stop
def test_early_stop_freezes_converged_point():
    res = simulate(_exp(steering=_STOP))
    rep = res.steering_report()
    assert rep["stopped_points"] == [1]  # lam=800 converged
    stop_w = rep["stop_windows"][1]
    assert stop_w == 4  # first decision point past min_windows
    # savings accounting: 8 + 4 of 16 point-windows simulated
    assert rep["point_windows_simulated"] == N_WINDOWS + stop_w
    assert rep["windows_saved_ratio"] == pytest.approx(16 / 12)

    pp = res.per_point()
    # the stopped point's record is frozen at its last live window...
    for w in range(stop_w, N_WINDOWS):
        assert (pp["mean"][w, 1] == pp["mean"][stop_w - 1, 1]).all()
        assert (pp["var"][w, 1] == pp["var"][stop_w - 1, 1]).all()
    # ...while the live (noisy) point keeps evolving
    assert not (pp["mean"][N_WINDOWS - 1, 0]
                == pp["mean"][stop_w - 1, 0]).all()


def test_steered_decisions_and_records_deterministic():
    """The determinism contract: (seed, Steering) fully determines the
    decision log and every record bit."""
    a, b = simulate(_exp(steering=_STOP)), simulate(_exp(steering=_STOP))
    assert a.steering_report()["decisions"] \
        == b.steering_report()["decisions"]
    assert _rec_tuple(a) == _rec_tuple(b)


# ------------------------------------------------------- reallocate
def test_reallocation_moves_freed_lanes_to_worst_point():
    res = simulate(_exp(steering=Steering(
        ci_rel_tol=0.03, min_windows=4, reallocate=True)))
    rep = res.steering_report()
    realloc = [d for d in rep["decisions"]
               if d["action"] == "reallocate"]
    assert len(realloc) == 1
    # all but one of the stopped point's lanes move to the live point
    assert realloc[0] == {"window": 4, "action": "reallocate",
                          "target": 0, "n_moved": REPLICAS - 1}
    pp = res.per_point()
    stop_w = rep["stop_windows"][1]
    # grouped counts re-shape at the boundary: the live point absorbs
    # the movers, the stopped point keeps one frozen lane behind
    assert pp["n"][stop_w - 1, 0, 0] == REPLICAS
    assert pp["n"][N_WINDOWS - 1, 0, 0] == 2 * REPLICAS - 1
    assert pp["n"][N_WINDOWS - 1, 1, 0] == 1
    # more replicas -> the live point's CI must tighten vs unsteered
    base = simulate(_exp(steering=_STOP))
    assert (pp["ci90"][N_WINDOWS - 1, 0]
            < base.per_point()["ci90"][N_WINDOWS - 1, 0]).all()


# ------------------------------------------------------- tau-switch
def test_tau_switch_pins_fallback_bound_lanes_exact():
    """A tau_fallback too high for any leap to be worth taking makes
    every lane pure exact-fallback; the EMA leap share sits at 0, so
    the switch pins the whole pool — without changing a record bit
    (pinned lanes take the same exact steps they already took)."""
    kw = dict(method=Method.TAU_LEAP, tau_fallback=1e6)
    steered = simulate(_exp(steering=Steering(
        tau_switch=True, tau_switch_min_steps=8), **kw))
    rep = steered.steering_report()
    assert rep["lanes_pinned_exact"] == 2 * REPLICAS
    pins = [d for d in rep["decisions"] if d["action"] == "no_leap"]
    assert pins and pins[0]["window"] == 2  # first block boundary
    assert np.asarray(steered._engine._pool.no_leap).all()
    plain = simulate(_exp(**kw))
    assert _rec_tuple(steered) == _rec_tuple(plain)


# ------------------------------------------------------- bimodality
def test_bimodality_flags_land_in_decision_log():
    """Policy-level: a synthetic two-mode histogram for (point 1,
    obs 0) is flagged at the decision point; nothing else is
    actioned."""
    pol = SteeringPolicy(Steering(bimodality=True), n_instances=4,
                         n_points=2, n_windows=4, tau_leap=False)
    hist = np.zeros((2, 1, 16), np.int32)
    hist[1, 0, 2:4] = (50, 45)
    hist[1, 0, 11:13] = (40, 48)
    z = np.zeros(4, np.int64)
    actions = pol.decide(2, None, hist, np.zeros(4, np.int32), z, z)
    assert not actions.any
    assert pol.bimodal_flags == [{"window": 2, "point": 1, "obs": 0}]
    assert pol.report()["bimodal_flags"] == pol.bimodal_flags


# ------------------------------------- crash recovery (FailureInjector)
def _steered_exp():
    return _exp(steering=Steering(ci_rel_tol=0.03, min_windows=4,
                                  reallocate=True),
                sketch=SketchSpec(n_bins=16, hi=1024.0))


def _block_drill(make_engine, path, plan):
    """run_sim_with_failures' block-loop sibling: steered engines
    advance via run_block (decisions live at collected block
    boundaries), so the drill checkpoints per collected block and
    rebuilds + restores on each scheduled crash."""
    inj = FailureInjector(plan)
    eng = make_engine()
    eng.checkpoint(path)
    crashed: set = set()
    guard = 0
    while eng._window < len(eng.grid):
        w = eng._window
        if w in plan.schedule and w not in crashed:
            crashed.add(w)
            inj.maybe_fail(w)
            eng = make_engine()  # the pod is gone; rebuild + restore
            eng.restore(path)
            continue
        if eng.run_block(pipeline=False):
            eng.checkpoint(path)
        guard += 1
        assert guard < 10 * len(eng.grid), "drill did not converge"
    return eng, inj.events


def test_steered_crash_recovery_replays_decisions_bitwise(tmp_path):
    """The recovery contract for steered runs: crash at two window
    boundaries (one BEFORE the first decision, one AFTER lanes were
    stopped and moved) — the surviving run's records, sketches, AND
    steering decision log are identical to an uninterrupted run's,
    because the policy state rides the checkpoint."""
    plan = FailurePlan(schedule={2: "crash", 6: "crash"})
    eng, events = _block_drill(
        lambda: build_engine(_steered_exp()),
        str(tmp_path / "steer_drill.npz"), plan)
    assert len(events) == 2

    clean = simulate(_steered_exp())
    assert clean.steering_report()["stopped_points"] == [1]
    assert eng.steering_report() == clean.steering_report()
    drill_recs = [(r.t, r.n, r.mean.tobytes(), r.var.tobytes(),
                   r.ci90.tobytes()) for r in eng.stream.records()]
    assert drill_recs == _rec_tuple(clean)
    for a, b in zip(eng.sketches(), clean.sketches()):
        assert (a.hist == b.hist).all()


# ------------------------------------- pipeline-depth forcing (§3e)
def test_steering_forces_pipeline_depth_auto_to_one():
    """Steered runs are lock-step BY CONSTRUCTION: decisions must see
    block k before block k+1 dispatches. pipeline_depth='auto' under
    steering resolves to 1 without probing, and the forcing is VISIBLE
    in telemetry rather than silent."""
    res = simulate(_exp(steering=_STOP, pipeline_depth="auto"))
    assert res.telemetry.pipeline_depth_effective == 1
    assert res.telemetry.pipeline_depth == 1
    # the same run unsteered probes freely (effective >= 1, and the
    # configured value stays "auto" -> reported as the resolved depth)
    free = simulate(_exp(pipeline_depth="auto"))
    assert free.telemetry.pipeline_depth_effective >= 1


def test_steering_rejects_explicit_deep_pipeline():
    """An EXPLICIT pipeline_depth > 1 with steering is a contradiction
    the user must resolve, not a silent override — the error names
    both knobs."""
    with pytest.raises(ExperimentError, match="pipeline_depth"):
        simulate(_exp(steering=_STOP, pipeline_depth=2))
