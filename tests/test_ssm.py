"""SSM mixers: chunkwise-parallel forms vs exact recurrences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.models.common import init_params
from repro.models.mamba import (
    apply_mamba,
    init_mamba_state,
    mamba_decode_step,
    mamba_defs,
    mamba_ref,
)
from repro.models.xlstm import (
    apply_mlstm,
    apply_slstm,
    init_mlstm_state,
    init_slstm_state,
    mlstm_decode_step,
    mlstm_defs,
    slstm_defs,
)


@pytest.fixture(scope="module")
def jcfg():
    return get_smoke_config("jamba-v0.1-52b")


@pytest.fixture(scope="module")
def xcfg():
    return get_smoke_config("xlstm-1.3b")


@pytest.mark.parametrize("t", [8, 16, 48])
def test_mamba_chunked_vs_sequential(jcfg, t):
    params = init_params(jax.random.PRNGKey(0), mamba_defs(jcfg), "float32")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, t, jcfg.d_model)) * 0.5
    y_par, st_par = apply_mamba(params, x, jcfg)
    y_ref, st_ref = mamba_ref(params, x, jcfg)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_ref),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(st_par.ssm), np.asarray(st_ref.ssm),
                               atol=1e-5)


def test_mamba_prefill_then_decode(jcfg):
    params = init_params(jax.random.PRNGKey(0), mamba_defs(jcfg), "float32")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 33, jcfg.d_model)) * 0.5
    y_full, _ = mamba_ref(params, x, jcfg)
    y_pre, st = apply_mamba(params, x[:, :32], jcfg)
    y_dec, _ = mamba_decode_step(params, x[:, 32:33], jcfg, st)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_full[:, 32:33]),
                               atol=1e-5)


@pytest.mark.parametrize("t", [16, 32])
def test_mlstm_chunkwise_vs_recurrent(xcfg, t):
    params = init_params(jax.random.PRNGKey(0), mlstm_defs(xcfg), "float32")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, t, xcfg.d_model)) * 0.5
    st = init_mlstm_state(xcfg, 2)
    ys = []
    for i in range(t):
        y, st = mlstm_decode_step(params, x[:, i:i + 1], xcfg, st)
        ys.append(y)
    y_ref = jnp.concatenate(ys, 1)
    y_par, st_par = apply_mlstm(params, x, xcfg)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_ref),
                               atol=5e-5)
    np.testing.assert_allclose(np.asarray(st_par.c), np.asarray(st.c),
                               atol=5e-5)
    np.testing.assert_allclose(np.asarray(st_par.m), np.asarray(st.m),
                               atol=5e-5)


def test_mlstm_stability_long_sequence(xcfg):
    """Exponential gating must not overflow over long horizons."""
    params = init_params(jax.random.PRNGKey(0), mlstm_defs(xcfg), "float32")
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 512, xcfg.d_model)) * 3.0
    y, st = apply_mlstm(params, x, xcfg)
    assert bool(jnp.isfinite(y).all())
    assert bool(jnp.isfinite(st.c).all())


def test_slstm_split_equals_full(xcfg):
    params = init_params(jax.random.PRNGKey(2), slstm_defs(xcfg), "float32")
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 24, xcfg.d_model)) * 0.5
    y_full, _ = apply_slstm(params, x, xcfg)
    ya, st = apply_slstm(params, x[:, :12], xcfg)
    yb, _ = apply_slstm(params, x[:, 12:], xcfg, state=st)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([ya, yb], 1)), np.asarray(y_full),
        atol=1e-6)
