"""Streaming sketches (repro.stats.sketch): unit semantics, the
statistical accuracy bound (streaming quantiles vs offline numpy
within one bin width), and the bitwise merge invariant across
fused | sharded x shard count x window_block (subprocess — forced
host devices, same harness discipline as test_sharded.py).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.api import (
    Ensemble,
    Experiment,
    Method,
    Schedule,
    SketchSpec,
    simulate,
)
from repro.core.reactions import make_system
from repro.stats import (
    bimodality_from_hist,
    quantiles_from_hist,
    window_sketch,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ------------------------------------------------------------- unit
def test_window_sketch_matches_numpy_binning():
    """Device binning == the offline numpy formula: clamp-to-edge bins,
    per-group counts, totals preserved (no dropped mass)."""
    rng = np.random.default_rng(3)
    n_i, n_obs, n_bins, n_groups = 64, 2, 8, 3
    obs = rng.uniform(-5.0, 45.0, (n_i, n_obs)).astype(np.float32)
    gids = rng.integers(0, n_groups, n_i).astype(np.int32)
    lo = np.zeros(n_obs, np.float32)
    width = np.full(n_obs, 32.0 / n_bins, np.float32)
    thr = np.asarray([10.0, 40.0], np.float32)

    hist, rare = window_sketch(obs, gids, n_groups, lo, width, n_bins,
                               thresholds=thr)
    hist, rare = np.asarray(hist), np.asarray(rare)
    assert hist.shape == (n_groups, n_obs, n_bins)
    assert rare.shape == (n_groups, n_obs, 2)

    b = np.clip(np.floor((obs - lo) / width), 0, n_bins - 1).astype(int)
    for g in range(n_groups):
        sel = gids == g
        for o in range(n_obs):
            ref = np.bincount(b[sel, o], minlength=n_bins)
            assert (hist[g, o] == ref).all(), (g, o)
            for k, level in enumerate(thr):
                assert rare[g, o, k] == (obs[sel, o] >= level).sum()
        # clamped tails: the histogram never drops mass
        assert hist[g].sum(axis=-1).tolist() == [sel.sum()] * n_obs


def test_window_sketch_merge_is_associative_partition_sum():
    """The §3f merge rule at the unit level: sketching two disjoint
    halves and adding the int32 counts is bitwise the full-pool sketch
    — the exact property the sharded psum relies on."""
    rng = np.random.default_rng(7)
    obs = rng.uniform(0.0, 30.0, (40, 1)).astype(np.float32)
    gids = rng.integers(0, 2, 40).astype(np.int32)
    lo, width = np.zeros(1, np.float32), np.full(1, 2.0, np.float32)
    full, _ = window_sketch(obs, gids, 2, lo, width, 16)
    a, _ = window_sketch(obs[:13], gids[:13], 2, lo, width, 16)
    b, _ = window_sketch(obs[13:], gids[13:], 2, lo, width, 16)
    assert (np.asarray(a) + np.asarray(b) == np.asarray(full)).all()


def test_quantiles_from_hist_within_one_bin_width():
    """Histogram-CDF quantiles vs np.quantile on the raw samples: the
    documented error bound is one bin width."""
    rng = np.random.default_rng(11)
    x = rng.gamma(4.0, 5.0, 4096).astype(np.float32)
    lo = np.zeros(1, np.float32)
    width = np.full(1, 100.0 / 64, np.float32)
    hist, _ = window_sketch(x[:, None], np.zeros(4096, np.int32), 1,
                            lo, width, 64)
    q = quantiles_from_hist(np.asarray(hist), lo, width)
    for k, p in enumerate((0.1, 0.5, 0.9)):
        err = abs(q[0, 0, k] - np.quantile(x, p))
        assert err <= float(width[0]), (p, err, float(width[0]))


def test_bimodality_flag():
    uni = np.zeros(16, int)
    uni[6:10] = (30, 60, 55, 20)
    bi = np.zeros(16, int)
    bi[2:4] = (50, 45)
    bi[11:13] = (40, 48)
    flags = bimodality_from_hist(np.stack([uni, bi]))
    assert flags.tolist() == [False, True]


# ------------------------------------------- statistical (end to end)
@pytest.mark.parametrize("method", [Method.EXACT, Method.TAU_LEAP])
def test_streaming_quantiles_track_offline_numpy(method):
    """End to end, both methods: per-window streaming sketches vs the
    offline histogram/quantile of the SAME trajectory samples — the
    histogram must be exact and the quantile within one bin width of
    np.quantile on the raw samples."""
    lam, mu = 200.0, 1.0
    sys_ = make_system(
        ["A"], [({}, {"A": 1}, lam), ({"A": 1}, {}, mu)], {"A": 0})
    res = simulate(Experiment(
        model=sys_,
        ensemble=Ensemble.make(replicas=256),
        schedule=Schedule(t_end=2.0, n_windows=4, schema="iii"),
        n_lanes=64, seed=11, method=method,
        record_trajectories=True,
        # explicit support: the hi=None auto-scale keys off obs(t=0)=0
        # here, which would clamp the whole Poisson bulk into the edge
        # bin (the documented bound needs support inside [lo, hi])
        sketch=SketchSpec(n_bins=48, hi=256.0, thresholds=(150.0,))))
    sks = res.sketches()
    assert len(sks) == 4
    traj = res.trajectories()  # (I, T, n_obs)
    pr = res._engine._sketch
    for w, sk in enumerate(sks):
        samples = traj[:, w, 0]
        # histogram exactness vs the numpy binning of the same samples
        b = np.clip(np.floor((samples - pr.lo[0]) / pr.width[0]),
                    0, pr.n_bins - 1).astype(int)
        ref = np.bincount(b, minlength=pr.n_bins)
        assert (sk.hist[0, 0] == ref).all(), (method, w)
        assert sk.rare[0, 0, 0] == (samples >= 150.0).sum()
        # quantile bound vs np.quantile on the raw samples
        q = quantiles_from_hist(sk.hist, pr.lo, pr.width)
        for k, p in enumerate((0.1, 0.5, 0.9)):
            err = abs(q[0, 0, k] - np.quantile(samples, p))
            assert err <= float(pr.width[0]), (method, w, p, err)


# --------------------------------------- bitwise across dispatch paths
_EXP = """
import numpy as np
from repro.api import (Ensemble, Experiment, Partitioning, Reduction,
                       Schedule, SketchSpec, simulate)
from repro.core.cwc.models import lotka_volterra

def make_exp(n_shards=None, window_block=1, **kw):
    return Experiment(
        model=lotka_volterra(2),
        ensemble=Ensemble.make(replicas=16, sweep={"die": [0.3, 1.2]}),
        schedule=Schedule(t_end=1.0, n_windows=4, schema="iii"),
        reduction=Reduction.PER_POINT,
        n_lanes=8, seed=11, window_block=window_block,
        sketch=SketchSpec(n_bins=16, thresholds=(4.0,)),
        partitioning=(Partitioning(n_shards=n_shards, stat_blocks=8)
                      if n_shards else None), **kw)

def stack(res):
    sks = res.sketches()
    return (np.stack([s.hist for s in sks]),
            np.stack([s.rare for s in sks]))
"""


def _run(body: str, devices: int = 8) -> str:
    """test_sharded.py's forced-device child harness (see its
    docstring for why the body must be dedented BEFORE prepending and
    why the sentinel is asserted)."""
    snippet = _EXP + textwrap.dedent(body) + '\nprint("SNIPPET-RAN")\n'
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", snippet],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "SNIPPET-RAN" in out.stdout, (
        "test body did not execute — harness regression")
    return out.stdout


def test_sketches_bitwise_across_shards_and_supersteps():
    """THE tentpole acceptance bar: identical sketch histograms and
    rare counters — bitwise — from the fused path, every shard count
    in {2, 4, 8}, and superstep width 4, in one forced-8-device
    child."""
    _run("""
    base_h, base_r = stack(simulate(make_exp()))
    assert base_h.dtype == np.int32 and base_r.dtype == np.int32
    for K in (2, 4, 8):
        for wb in (1, 4):
            h, r = stack(simulate(make_exp(n_shards=K,
                                           window_block=wb)))
            assert (h == base_h).all(), (K, wb)
            assert (r == base_r).all(), (K, wb)
    h, r = stack(simulate(make_exp(window_block=4)))
    assert (h == base_h).all() and (r == base_r).all()
    """)
