"""Pallas flash attention vs oracle: shape/dtype/causality sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.models.attention import full_attention


def _mk(b, s, h, kv, hd, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (b, s, h, hd), dtype)
    k = jax.random.normal(ks[1], (b, s, kv, hd), dtype)
    v = jax.random.normal(ks[2], (b, s, kv, hd), dtype)
    w = jax.random.normal(ks[3], (b, s, h, hd), dtype)
    return q, k, v, w


@pytest.mark.parametrize("b,s,h,kv,hd,bq,bk", [
    (1, 128, 4, 4, 32, 64, 64),    # MHA
    (2, 256, 4, 2, 32, 64, 128),   # GQA, rectangular blocks
    (1, 192, 6, 1, 16, 64, 64),    # MQA, uneven final block
    (2, 128, 8, 2, 64, 128, 32),
])
@pytest.mark.parametrize("causal", [True, False])
def test_forward_sweep(b, s, h, kv, hd, bq, bk, causal):
    q, k, v, _ = _mk(b, s, h, kv, hd, jnp.float32)
    o = flash_attention(q, k, v, causal, bq, bk, True)
    o_ref = full_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=2e-5)


@pytest.mark.parametrize("causal", [True, False])
def test_gradients_match_oracle(causal):
    q, k, v, w = _mk(2, 128, 4, 2, 32, jnp.float32, seed=3)

    def loss(fn):
        return lambda q, k, v: (fn(q, k, v) * w).sum()

    g_flash = jax.grad(loss(lambda q, k, v: flash_attention(
        q, k, v, causal, 64, 64, True)), argnums=(0, 1, 2))(q, k, v)
    g_ref = jax.grad(loss(lambda q, k, v: full_attention(
        q, k, v, causal=causal)), argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g_flash, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=5e-5)


def test_bf16_inputs():
    q, k, v, _ = _mk(1, 128, 4, 2, 32, jnp.bfloat16, seed=5)
    o = flash_attention(q, k, v, True, 64, 64, True)
    o_ref = full_attention(q, k, v, causal=True)
    assert o.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32), atol=3e-2)


def test_long_context_numerics():
    """Stability over many blocks (large logsumexp range)."""
    q, k, v, _ = _mk(1, 1024, 2, 2, 16, jnp.float32, seed=7)
    q = q * 4.0  # widen score range
    o = flash_attention(q, k, v, True, 128, 128, True)
    o_ref = full_attention(q, k, v, causal=True)
    assert bool(jnp.isfinite(o).all())
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=5e-5)
