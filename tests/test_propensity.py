"""Propensity math: oracle match + hypothesis invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.reactions import make_system, propensities, propensities_ref

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # kernel/oracle tests below still run without it
    hyp_only = pytest.mark.skip(
        reason="property tests need hypothesis "
        "(pip install -r requirements-dev.txt)")

    def settings(**_kw):  # noqa: D103 — stand-ins so decorators parse
        return hyp_only

    def given(*_a, **_kw):
        return lambda f: f

    class st:  # noqa: N801
        integers = floats = lists = staticmethod(lambda *a, **k: None)


def _random_system(rng, s=5, r=6):
    species = [f"x{i}" for i in range(s)]
    reactions = []
    for _ in range(r):
        n_re = rng.integers(0, 3)
        lhs = {}
        for _ in range(n_re):
            lhs[species[rng.integers(s)]] = int(rng.integers(1, 3))
        rhs = {species[rng.integers(s)]: 1}
        reactions.append((lhs, rhs, float(rng.uniform(0.1, 2.0))))
    return make_system(species, reactions, {species[0]: 10})


def test_matches_numpy_oracle(rng):
    for _ in range(10):
        sys = _random_system(rng)
        x = rng.integers(0, 25, (8, sys.n_species)).astype(np.float32)
        a = propensities(jnp.asarray(x), jnp.asarray(sys.reactant_idx),
                         jnp.asarray(sys.reactant_coef),
                         jnp.asarray(sys.rates))
        ref = propensities_ref(x, sys)
        np.testing.assert_allclose(np.asarray(a), ref, rtol=1e-5, atol=1e-6)


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 100), st.integers(0, 100), st.floats(0.01, 10.0))
def test_bimolecular_combination_count(na, nb, k):
    """Paper example: rate of `a b X -> c X` on n_a × n_b is k·n_a·n_b."""
    sys = make_system(["a", "b", "c"], [({"a": 1, "b": 1}, {"c": 1}, k)],
                      {"a": na, "b": nb})
    a = propensities(jnp.asarray([[na, nb, 0.0]], jnp.float32),
                     jnp.asarray(sys.reactant_idx),
                     jnp.asarray(sys.reactant_coef), jnp.asarray(sys.rates))
    assert np.isclose(float(a[0, 0]), k * na * nb, rtol=1e-5)


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 60), st.floats(0.01, 5.0))
def test_homodimer_binomial(n, k):
    """2a -> b fires at k·C(n,2) (combination counting, paper §2.2)."""
    sys = make_system(["a", "b"], [({"a": 2}, {"b": 1}, k)], {"a": n})
    a = propensities(jnp.asarray([[n, 0.0]], jnp.float32),
                     jnp.asarray(sys.reactant_idx),
                     jnp.asarray(sys.reactant_coef), jnp.asarray(sys.rates))
    assert np.isclose(float(a[0, 0]), k * n * (n - 1) / 2, rtol=1e-5)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, 50), min_size=3, max_size=3))
def test_nonnegative_and_zero_when_insufficient(counts):
    sys = make_system(["a", "b", "c"],
                      [({"a": 2, "b": 1}, {"c": 1}, 1.0)],
                      {"a": 0})
    x = jnp.asarray([counts], jnp.float32)
    a = propensities(x, jnp.asarray(sys.reactant_idx),
                     jnp.asarray(sys.reactant_coef), jnp.asarray(sys.rates))
    val = float(a[0, 0])
    assert val >= 0.0
    if counts[0] < 2 or counts[1] < 1:
        assert val == 0.0


def test_interpret_defaults_to_backend_not_true():
    """`interpret` used to default to True everywhere — a TPU run of
    the standalone propensity kernel would silently execute the Python
    interpreter path. The default is now backend-derived: compiled on
    every accelerator backend, interpret only where Pallas cannot
    compile (CPU). An explicit argument always wins."""
    from repro.kernels.propensity import COMPILED_BACKENDS, resolve_interpret

    for backend in COMPILED_BACKENDS:
        assert resolve_interpret(None, backend) is False
    assert resolve_interpret(None, "cpu") is True
    # explicit choice is never overridden
    assert resolve_interpret(True, "tpu") is True
    assert resolve_interpret(False, "cpu") is False
    # the no-backend form consults jax.default_backend()
    import jax

    expected = jax.default_backend().lower() not in COMPILED_BACKENDS
    assert resolve_interpret(None) is expected


def test_propensity_call_default_interpret_runs(rng):
    """propensity_call with no `interpret` must pick a mode that runs
    on the current backend (interpret on CPU, compiled on TPU) and
    agree with the reference math."""
    from repro.kernels.propensity import propensity_call, reactant_onehots

    sys = _random_system(rng)
    x = rng.integers(0, 25, (8, sys.n_species)).astype(np.float32)
    e = jnp.asarray(reactant_onehots(sys))
    coef = jnp.asarray(sys.reactant_coef.T, jnp.float32)
    a = propensity_call(jnp.asarray(x), e, coef, jnp.asarray(sys.rates))
    ref = propensities_ref(x, sys)
    np.testing.assert_allclose(np.asarray(a), ref, rtol=1e-5, atol=1e-6)
