"""SSA exactness: analytic moments + determinism + horizon semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.gillespie import advance_to, init_lanes, system_tensors
from repro.core.reactions import MAX_COEF, make_system


def _run(system, n, t, seed):
    st = init_lanes(system, n, seed)
    tens = system_tensors(system)
    return jax.jit(lambda s: advance_to(s, tens, t))(st)


def test_pure_death_mean():
    sys = make_system(["A"], [({"A": 1}, {}, 0.5)], {"A": 1000})
    st = _run(sys, 1500, 2.0, seed=1)
    analytic = 1000 * np.exp(-0.5 * 2.0)
    emp = float(st.x.mean())
    # binomial thinning: sd of the lane mean
    sd = np.sqrt(1000 * np.exp(-1.0) * (1 - np.exp(-1.0)) / 1500)
    assert abs(emp - analytic) < 5 * sd


def test_immigration_death_stationary_poisson():
    lam, mu = 50.0, 1.0
    sys = make_system(["A"], [({}, {"A": 1}, lam), ({"A": 1}, {}, mu)],
                      {"A": 0})
    st = _run(sys, 1500, 10.0, seed=2)
    x = np.asarray(st.x[:, 0])
    assert abs(x.mean() - lam) < 1.0
    assert abs(x.var() - lam) < 5.0  # Poisson: var == mean


def test_dimerisation_conservation():
    # 2A -> B conserves A + 2B... (A + 2B invariant)
    sys = make_system(["A", "B"], [({"A": 2}, {"B": 1}, 0.01)],
                      {"A": 100, "B": 0})
    st = _run(sys, 64, 50.0, seed=3)
    inv = np.asarray(st.x[:, 0] + 2 * st.x[:, 1])
    assert (inv == 100).all()


def test_deterministic_same_seed():
    sys = make_system(["A"], [({}, {"A": 1}, 5.0), ({"A": 1}, {}, 0.5)],
                      {"A": 10})
    a = _run(sys, 32, 3.0, seed=7)
    b = _run(sys, 32, 3.0, seed=7)
    assert (a.x == b.x).all() and (a.t == b.t).all()


def test_horizon_freeze_exact():
    """Windowed advance == single long advance distributionally; clocks
    never overshoot the horizon."""
    sys = make_system(["A"], [({}, {"A": 1}, 5.0), ({"A": 1}, {}, 0.5)],
                      {"A": 0})
    tens = system_tensors(sys)
    st = init_lanes(sys, 256, seed=4)
    adv = jax.jit(lambda s, h: advance_to(s, tens, h))
    for h in (0.5, 1.0, 1.5, 2.0):
        st = adv(st, h)
        assert float(st.t.max()) <= h + 1e-6
        assert float(st.t.min()) >= h - 1e-6
    one = _run(sys, 256, 2.0, seed=5)
    m_win, m_one = float(st.x.mean()), float(one.x.mean())
    assert abs(m_win - m_one) < 1.5  # both ~Poisson(10) means over 256 lanes


def test_dead_lanes_stay_dead():
    sys = make_system(["A"], [({"A": 1}, {}, 10.0)], {"A": 3})
    st = _run(sys, 16, 100.0, seed=6)
    assert bool(st.dead.all())
    assert (np.asarray(st.x) == 0).all()


def test_coefficient_beyond_unroll_cap_rejected_by_dense_path():
    """The DENSE path unrolls C(n, c) to c <= MAX_COEF; a larger
    stoichiometric coefficient used to yield silently WRONG
    propensities there. Constructing such a system is legal (the sparse
    encoding unrolls to the system's actual max coefficient), but
    building the dense tensors must reject it, naming the reaction and
    pointing at sparse=True."""
    sys5 = make_system(["A", "P"],
                       [({"A": 1}, {}, 1.0),
                        ({"A": MAX_COEF + 1}, {"P": 1}, 0.1)],
                       {"A": 50}, names=["decay", "pentamer"])
    assert sys5.max_coef == MAX_COEF + 1
    with pytest.raises(ValueError, match="pentamer.*5 > MAX_COEF"):
        system_tensors(sys5)
    with pytest.raises(ValueError, match="sparse=True"):
        system_tensors(sys5)
    # sparse tensors build fine and carry the true unroll bound
    assert system_tensors(sys5, require_dense=False)
    from repro.core.reactions import sparse_tables
    assert sparse_tables(sys5).max_coef == MAX_COEF + 1
    # the cap itself is fine on the dense path
    sys_ok = make_system(["A", "P"], [({"A": MAX_COEF}, {"P": 1}, 0.1)],
                         {"A": 50})
    system_tensors(sys_ok)


def test_rng_stream_is_counter_based_and_key_stable():
    """Draws are a pure function of (lane key, event counter): the key
    never advances, the counter counts consumed draws per lane —
    which is what makes chunked/fused/resumed replay bitwise."""
    sys = make_system(["A"], [({}, {"A": 1}, 5.0), ({"A": 1}, {}, 0.5)],
                      {"A": 10})
    st0 = init_lanes(sys, 8, seed=1)
    st = _run(sys, 8, 3.0, seed=1)
    assert (np.asarray(st.key) == np.asarray(st0.key)).all()
    assert st.ctr.dtype == jnp.uint32
    assert st.ctr_hi.dtype == jnp.uint32
    assert (np.asarray(st.ctr) >= np.asarray(st.steps)).all()
    assert int(st.ctr.max()) > 0
    # far from the 2^32 boundary the high word stays zero — which is
    # also why pre-widening checkpoints restore bitwise with hi=0
    assert (np.asarray(st.ctr_hi) == 0).all()


def _near_wrap_pool(sys, n, seed, back: int = 2):
    """Lanes whose low counter word sits `back` draws below the 2^32
    boundary — the forced-small-boundary harness for wrap tests."""
    st = init_lanes(sys, n, seed)
    return st._replace(
        ctr=jnp.full((n,), np.uint32(2**32 - back), jnp.uint32))


def test_counter_wrap_carries_into_high_word_and_does_not_replay():
    """ROADMAP RNG item, resolved: crossing the uint32 boundary must
    carry into the spare threefry `c1` word instead of replaying the
    stream from draw 0. Regression at a forced boundary: lanes start 2
    draws below the wrap, consume ~tens of draws, and must (a) carry,
    (b) KEEP the wrapped low word counting, and (c) draw different
    uniforms than the draw-0 stream at the same low word."""
    from repro.core.stream import counter_uniforms

    sys = make_system(["A"], [({}, {"A": 1}, 1000.0)], {"A": 0})
    tens = system_tensors(sys)
    st = _near_wrap_pool(sys, 4, seed=2)
    out = jax.jit(lambda s: advance_to(s, tens, 0.05))(st)
    assert int(out.steps.min()) > 4  # every lane crossed the boundary
    assert (np.asarray(out.ctr_hi) == 1).all()
    assert (np.asarray(out.ctr) < 2**31).all()  # wrapped, kept counting
    # wrapped draws differ from the pre-wrap epoch's draws at the same
    # low word — the period is 2^64, not 2^32
    k0, k1 = out.key[:, 0], out.key[:, 1]
    lo = jnp.zeros_like(out.ctr)
    u_hi1 = counter_uniforms(k0, k1, lo, jnp.ones_like(lo))
    u_hi0 = counter_uniforms(k0, k1, lo, jnp.zeros_like(lo))
    assert (np.asarray(u_hi1[0]) != np.asarray(u_hi0[0])).all()


def test_counter_wrap_bitwise_across_kernel_and_unfused():
    """The carry is computed by the shared `stream.ctr_add` in both the
    host-traced step and the Pallas kernel body — a window that crosses
    the boundary stays bitwise identical across paths."""
    from repro.kernels.ops import fused_window

    sys = make_system(["A"], [({}, {"A": 1}, 1000.0), ({"A": 1}, {}, 1.0)],
                      {"A": 5})
    tens = system_tensors(sys)
    a = jax.jit(lambda s: advance_to(s, tens, 0.05))(
        _near_wrap_pool(sys, 8, seed=3))
    out = fused_window(_near_wrap_pool(sys, 8, seed=3), tens, 0.05,
                       chunk_steps=7)
    b = out.state
    assert not bool(out.truncated)
    assert (np.asarray(a.ctr_hi) == 1).all()
    for fa, fb in zip(a, b):
        assert (np.asarray(fa) == np.asarray(fb)).all()
