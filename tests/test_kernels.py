"""Pallas kernels vs jnp oracles: shape/dtype sweeps + bitwise checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cwc.compile import compile_model
from repro.core.cwc.models import (
    ecoli_gene_regulation,
    lotka_volterra,
    membrane_transport,
)
from repro.core.gillespie import advance_to, init_lanes, system_tensors
from repro.kernels.ops import FusedWindowOut, fused_window
from repro.kernels.propensity import propensity_call, reactant_onehots
from repro.kernels.ref import propensity_ref, ssa_window_ref
from repro.kernels.ssa_step import ssa_window_call

SYSTEMS = {
    "lv2": lotka_volterra(2),
    "lv8": lotka_volterra(8),
    "ecoli": ecoli_gene_regulation(),
    "transport": membrane_transport(),
}


@pytest.mark.parametrize("name", list(SYSTEMS))
@pytest.mark.parametrize("batch", [1, 17, 256, 300])
def test_propensity_kernel_shape_sweep(name, batch, rng):
    sys, _ = compile_model(SYSTEMS[name])
    e = jnp.asarray(reactant_onehots(sys))
    coef = jnp.asarray(sys.reactant_coef.T, jnp.float32)
    x = jnp.asarray(rng.integers(0, 50, (batch, sys.n_species))
                    .astype(np.float32))
    a_k = propensity_call(x, e, coef, jnp.asarray(sys.rates), interpret=True)
    a_r = propensity_ref(x, jnp.asarray(sys.reactant_idx),
                         jnp.asarray(sys.reactant_coef),
                         jnp.asarray(sys.rates))
    np.testing.assert_allclose(np.asarray(a_k), np.asarray(a_r),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("name", list(SYSTEMS))
def test_propensity_kernel_per_lane_rates(name, rng):
    sys, _ = compile_model(SYSTEMS[name])
    b = 9
    e = jnp.asarray(reactant_onehots(sys))
    coef = jnp.asarray(sys.reactant_coef.T, jnp.float32)
    rates = jnp.asarray(
        rng.uniform(0.1, 3.0, (b, sys.n_reactions)).astype(np.float32))
    x = jnp.asarray(rng.integers(0, 30, (b, sys.n_species))
                    .astype(np.float32))
    a_k = propensity_call(x, e, coef, rates, interpret=True)
    a_r = propensity_ref(x, jnp.asarray(sys.reactant_idx),
                         jnp.asarray(sys.reactant_coef), rates)
    np.testing.assert_allclose(np.asarray(a_k), np.asarray(a_r), rtol=1e-6)


@pytest.mark.parametrize("name", ["lv2", "ecoli", "transport"])
@pytest.mark.parametrize("batch,n_steps", [(8, 16), (33, 64), (128, 32)])
def test_fused_window_bitwise_vs_ref(name, batch, n_steps, rng):
    """The kernel's in-VREG counter-based draws match the jnp oracle
    consuming the same (key, ctr) stream — bitwise."""
    sys, _ = compile_model(SYSTEMS[name])
    pool = init_lanes(sys, batch, seed=batch + n_steps)
    e = jnp.asarray(reactant_onehots(sys))
    coef = jnp.asarray(sys.reactant_coef.T, jnp.float32)
    delta = jnp.asarray(sys.delta, jnp.float32)
    rates = jnp.asarray(sys.rates)
    horizon = 0.1
    out_k = ssa_window_call(pool.x, pool.t, pool.dead.astype(jnp.int32),
                            pool.key, pool.ctr, pool.ctr_hi, e, coef,
                            delta, rates, horizon, n_steps=n_steps,
                            interpret=True)
    out_r = ssa_window_ref(pool.x, pool.t, pool.dead.astype(jnp.int32),
                           pool.key, pool.ctr, pool.ctr_hi,
                           jnp.asarray(sys.reactant_idx),
                           jnp.asarray(sys.reactant_coef), delta, rates,
                           horizon, n_steps=n_steps)
    assert (out_k[0] == out_r[0]).all(), "state mismatch"
    np.testing.assert_allclose(np.asarray(out_k[1]), np.asarray(out_r[1]),
                               rtol=1e-5, atol=1e-6)
    assert (out_k[3] == out_r[3]).all(), "step counts mismatch"
    assert (out_k[4] == out_r[4]).all(), "draw counters mismatch"
    assert (out_k[5] == out_r[5]).all(), "high counter words mismatch"


@pytest.mark.parametrize("chunk_steps,max_chunks",
                         [(1, 2048), (7, 512), (256, 64)])
def test_fused_window_bitwise_vs_unfused_any_chunk(chunk_steps, max_chunks):
    """Counter-based RNG makes kernel<->unfused parity bitwise for ANY
    chunk size, INCLUDING across a window boundary (previously only the
    first window was bitwise; across windows it was distributional)."""
    sys, _ = compile_model(lotka_volterra(2))
    tens = system_tensors(sys)
    p1 = init_lanes(sys, 64, seed=9)
    p2 = init_lanes(sys, 64, seed=9)
    adv = jax.jit(lambda p, h: advance_to(p, tens, h))
    a = adv(adv(p1, 0.1), 0.2)  # two windows, unfused
    out = fused_window(p2, tens, 0.1, chunk_steps=chunk_steps,
                       max_chunks=max_chunks)
    out = fused_window(out.state, tens, 0.2, chunk_steps=chunk_steps,
                       max_chunks=max_chunks)
    b = out.state
    assert not bool(out.truncated)
    assert (a.x == b.x).all()
    assert (a.t == b.t).all()
    assert (a.ctr == b.ctr).all()
    assert (a.steps == b.steps).all()
    assert (a.dead == b.dead).all()


def test_fused_window_single_launch_telemetry():
    """FusedWindowOut carries single-launch telemetry only: a device
    chunk count and a truncation flag — the host-driven per-chunk
    dispatch/sync counters are gone along with the loop itself."""
    sys, _ = compile_model(lotka_volterra(2))
    tens = system_tensors(sys)
    out = fused_window(init_lanes(sys, 64, seed=9), tens, 0.1,
                       chunk_steps=128)
    assert set(FusedWindowOut._fields) == {"state", "n_chunks",
                                           "truncated"}
    assert int(out.n_chunks) >= 1
    assert not bool(out.truncated)


def test_fused_window_truncation_is_flagged_not_silent():
    """A window that exhausts max_chunks with live lanes below the
    horizon must say so — previously it returned a partial window as if
    complete."""
    sys, _ = compile_model(lotka_volterra(2))
    tens = system_tensors(sys)
    out = fused_window(init_lanes(sys, 16, seed=3), tens, 5.0,
                       chunk_steps=2, max_chunks=1)
    assert bool(out.truncated)
    assert int(out.n_chunks) == 1
    # the partial state is still below the horizon on some live lane
    live = (np.asarray(out.state.t) < 5.0) & ~np.asarray(out.state.dead)
    assert live.any()
    # a generous budget on the same start completes and clears the flag
    out2 = fused_window(init_lanes(sys, 16, seed=3), tens, 5.0,
                        chunk_steps=256, max_chunks=64)
    assert not bool(out2.truncated)


def test_engine_raises_on_truncated_kernel_window():
    import warnings

    from repro.core.engine import SimConfig, SimulationEngine
    from repro.kernels.ops import FusedWindowTruncated

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        eng = SimulationEngine(
            lotka_volterra(2),
            SimConfig(n_instances=16, t_end=2.0, n_windows=2, n_lanes=16,
                      schema="iii", seed=5, use_kernel=True,
                      kernel_chunk_steps=2, kernel_max_chunks=1))
    with pytest.raises(FusedWindowTruncated, match="kernel_max_chunks"):
        eng.run()
