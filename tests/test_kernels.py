"""Pallas kernels vs jnp oracles: shape/dtype sweeps + bitwise checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cwc.compile import compile_model
from repro.core.cwc.models import (
    ecoli_gene_regulation,
    lotka_volterra,
    membrane_transport,
)
from repro.core.gillespie import advance_to, init_lanes, system_tensors
from repro.kernels.ops import _draw_uniform_stream, fused_window
from repro.kernels.propensity import propensity_call, reactant_onehots
from repro.kernels.ref import propensity_ref, ssa_window_ref
from repro.kernels.ssa_step import ssa_window_call

SYSTEMS = {
    "lv2": lotka_volterra(2),
    "lv8": lotka_volterra(8),
    "ecoli": ecoli_gene_regulation(),
    "transport": membrane_transport(),
}


@pytest.mark.parametrize("name", list(SYSTEMS))
@pytest.mark.parametrize("batch", [1, 17, 256, 300])
def test_propensity_kernel_shape_sweep(name, batch, rng):
    sys, _ = compile_model(SYSTEMS[name])
    e = jnp.asarray(reactant_onehots(sys))
    coef = jnp.asarray(sys.reactant_coef.T, jnp.float32)
    x = jnp.asarray(rng.integers(0, 50, (batch, sys.n_species))
                    .astype(np.float32))
    a_k = propensity_call(x, e, coef, jnp.asarray(sys.rates), interpret=True)
    a_r = propensity_ref(x, jnp.asarray(sys.reactant_idx),
                         jnp.asarray(sys.reactant_coef),
                         jnp.asarray(sys.rates))
    np.testing.assert_allclose(np.asarray(a_k), np.asarray(a_r),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("name", list(SYSTEMS))
def test_propensity_kernel_per_lane_rates(name, rng):
    sys, _ = compile_model(SYSTEMS[name])
    b = 9
    e = jnp.asarray(reactant_onehots(sys))
    coef = jnp.asarray(sys.reactant_coef.T, jnp.float32)
    rates = jnp.asarray(
        rng.uniform(0.1, 3.0, (b, sys.n_reactions)).astype(np.float32))
    x = jnp.asarray(rng.integers(0, 30, (b, sys.n_species))
                    .astype(np.float32))
    a_k = propensity_call(x, e, coef, rates, interpret=True)
    a_r = propensity_ref(x, jnp.asarray(sys.reactant_idx),
                         jnp.asarray(sys.reactant_coef), rates)
    np.testing.assert_allclose(np.asarray(a_k), np.asarray(a_r), rtol=1e-6)


@pytest.mark.parametrize("name", ["lv2", "ecoli", "transport"])
@pytest.mark.parametrize("batch,n_steps", [(8, 16), (33, 64), (128, 32)])
def test_fused_window_bitwise_vs_ref(name, batch, n_steps, rng):
    sys, _ = compile_model(SYSTEMS[name])
    pool = init_lanes(sys, batch, seed=batch + n_steps)
    _, uniforms = _draw_uniform_stream(pool.key, n_steps)
    e = jnp.asarray(reactant_onehots(sys))
    coef = jnp.asarray(sys.reactant_coef.T, jnp.float32)
    delta = jnp.asarray(sys.delta, jnp.float32)
    rates = jnp.asarray(sys.rates)
    horizon = 0.1
    out_k = ssa_window_call(pool.x, pool.t, pool.dead.astype(jnp.int32),
                            uniforms, e, coef, delta, rates, horizon,
                            n_steps=n_steps, interpret=True)
    out_r = ssa_window_ref(pool.x, pool.t, pool.dead.astype(jnp.int32),
                           uniforms, jnp.asarray(sys.reactant_idx),
                           jnp.asarray(sys.reactant_coef), delta, rates,
                           horizon, n_steps=n_steps)
    assert (out_k[0] == out_r[0]).all(), "state mismatch"
    np.testing.assert_allclose(np.asarray(out_k[1]), np.asarray(out_r[1]),
                               rtol=1e-5, atol=1e-6)
    assert (out_k[3] == out_r[3]).all(), "step counts mismatch"


def test_fused_window_first_window_bitwise_vs_unfused():
    sys, _ = compile_model(lotka_volterra(2))
    tens = system_tensors(sys)
    p1 = init_lanes(sys, 64, seed=9)
    p2 = init_lanes(sys, 64, seed=9)
    a1 = jax.jit(lambda p: advance_to(p, tens, 0.1))(p1)
    out = fused_window(p2, tens, 0.1, chunk_steps=128)
    a2 = out.state
    # chunk-loop telemetry is threaded back (one bool() sync per chunk
    # check, two dispatches per executed chunk)
    assert out.n_host_syncs >= 2 and out.n_dispatches >= 2
    assert (a1.x == a2.x).all()
    np.testing.assert_allclose(np.asarray(a1.t), np.asarray(a2.t), atol=1e-6)
