"""Multi-device semantics (subprocess: needs forced host devices).

Each test shells out with XLA_FLAGS=--xla_force_host_platform_device_count
so the main pytest process keeps the real 1-device platform (see
conftest.py note).
"""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(snippet: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(snippet)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_moe_ep_matches_oracle():
    _run("""
    import jax, jax.numpy as jnp, dataclasses
    from repro.configs.deepseek_moe_16b import smoke
    from repro.models.moe import moe_defs, apply_moe, moe_dense_oracle
    from repro.models.common import init_params
    from repro.sharding.rules import Topology, make_mesh_from_spec
    from repro.configs.base import MeshSpec, ShardingConfig
    cfg = dataclasses.replace(smoke(), capacity_factor=8.0)
    params = init_params(jax.random.PRNGKey(0), moe_defs(cfg), "float32")
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
    y_ref, aux_ref = moe_dense_oracle(params, x, cfg)
    mesh = make_mesh_from_spec(MeshSpec((2, 4), ("data", "model")))
    topo = Topology(mesh, cfg, ShardingConfig(strategy="dp_tp",
                                              expert_parallel=True))
    assert topo.rules["experts"] == "model"
    y, aux = apply_moe(params, x, cfg, topo)
    err = float(jnp.abs(y - y_ref).max())
    assert err < 1e-4, err
    """)


def test_moe_tp_fallback_matches_oracle():
    _run("""
    import jax, jax.numpy as jnp, dataclasses
    from repro.configs.olmoe_1b_7b import smoke
    from repro.models.moe import moe_defs, apply_moe, moe_dense_oracle
    from repro.models.common import init_params
    from repro.sharding.rules import Topology, make_mesh_from_spec
    from repro.configs.base import MeshSpec, ShardingConfig
    cfg = dataclasses.replace(smoke(), capacity_factor=8.0)
    params = init_params(jax.random.PRNGKey(0), moe_defs(cfg), "float32")
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
    y_ref, _ = moe_dense_oracle(params, x, cfg)
    mesh = make_mesh_from_spec(MeshSpec((2, 4), ("data", "model")))
    topo = Topology(mesh, cfg, ShardingConfig(strategy="dp_tp",
                                              expert_parallel=False))
    assert topo.rules["expert_ffn"] == "model"
    y, _ = apply_moe(params, x, cfg, topo)
    err = float(jnp.abs(y - y_ref).max())
    assert err < 1e-4, err
    """)


def test_moe_ep_small_decode_matches_oracle():
    """Decode-sized token counts: weights stay expert-sharded (no
    gathers), outputs psum — §Perf H2-it2."""
    _run("""
    import jax, jax.numpy as jnp, dataclasses
    from repro.configs.deepseek_moe_16b import smoke
    from repro.models.moe import moe_defs, apply_moe, moe_dense_oracle
    from repro.models.common import init_params
    from repro.sharding.rules import Topology, make_mesh_from_spec
    from repro.configs.base import MeshSpec, ShardingConfig
    cfg = dataclasses.replace(smoke(), capacity_factor=8.0)
    params = init_params(jax.random.PRNGKey(0), moe_defs(cfg), "float32")
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 1, cfg.d_model))
    y_ref, _ = moe_dense_oracle(params, x, cfg)
    mesh = make_mesh_from_spec(MeshSpec((2, 4), ("data", "model")))
    topo = Topology(mesh, cfg, ShardingConfig(strategy="dp_tp",
                                              expert_parallel=True))
    y, _ = apply_moe(params, x, cfg, topo)  # t_local=2 -> EP-small path
    err = float(jnp.abs(y - y_ref).max())
    assert err < 1e-4, err
    """)


def test_seq_sharded_flash_decode_exact():
    _run("""
    import jax, jax.numpy as jnp
    from repro.models.attention import (decode_attention,
                                        decode_attention_seqsharded,
                                        write_kv_slot)
    from repro.sharding.rules import Topology, make_mesh_from_spec
    from repro.configs.base import MeshSpec, ShardingConfig
    from repro.configs.llama3_8b import smoke
    B,S,H,KV,hd = 8, 64, 4, 2, 16
    ks = [jax.random.normal(jax.random.PRNGKey(i), s)
          for i, s in enumerate([(B,1,H,hd), (B,S,KV,hd), (B,S,KV,hd),
                                 (B,1,KV,hd), (B,1,KV,hd)])]
    q, kc, vc, kn, vn = ks
    vl = jnp.asarray([37, 60, 1, 63, 0, 17, 32, 48], jnp.int32)
    slot = vl
    kc2, vc2 = write_kv_slot(kc, vc, kn, vn, slot)
    ref = decode_attention(q, kc2, vc2, slot, valid_len=vl)
    for ax in ("data", "model"):
        mesh = make_mesh_from_spec(MeshSpec((4, 2), ("data", "model")))
        topo = Topology(mesh, smoke(), ShardingConfig(seq_sharded_kv=True,
                                                      kv_seq_axis=ax))
        out, kc3, vc3 = decode_attention_seqsharded(
            q, kc, vc, kn, vn, slot, vl, topo)
        err = float(jnp.abs(ref - out).max())
        assert err < 1e-5, (ax, err)
        assert float(jnp.abs(kc3 - kc2).max()) < 1e-6  # cache written
    """)


def test_welford_merge_over_axis():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core.reduction import init_welford, update_batch, merge_over_axis, finalize
    from repro.compat import make_mesh
    mesh = make_mesh((8,), ("data",))
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 3)) * 5 + 2
    def local(x_loc):
        acc = update_batch(init_welford((3,)), x_loc)
        return merge_over_axis(acc, "data")
    from repro.compat import shard_map
    acc = shard_map(local, mesh=mesh, in_specs=P("data"),
                    out_specs=P(), check_vma=False)(x)
    s = finalize(acc)
    np.testing.assert_allclose(np.asarray(s.mean), np.asarray(x.mean(0)),
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(s.var),
                               np.asarray(x.var(0, ddof=1)), rtol=1e-4)
    """)


def test_compressed_psum_error_feedback():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.train.compression import compressed_psum
    from repro.compat import make_mesh
    mesh = make_mesh((4,), ("pod",))
    g = jax.random.normal(jax.random.PRNGKey(0), (4, 256))
    def body(g_loc, err):
        return compressed_psum(g_loc[0], "pod", err)
    # single round: quantisation error bounded by scale
    from repro.compat import shard_map
    out, err = shard_map(body, mesh=mesh, in_specs=(P("pod"), P()),
                         out_specs=(P(), P()), check_vma=False)(
        g, jnp.zeros((256,)))
    exact = np.asarray(g.sum(0))
    got = np.asarray(out)
    scale = float(jnp.abs(g).max()) / 127.0
    assert np.abs(got - exact).max() < 4 * scale * 0.51 + 1e-6
    # error feedback: accumulated compressed sums converge to exact sums
    T = 50
    gs = jax.random.normal(jax.random.PRNGKey(1), (T, 4, 128))
    def run(compress):
        err = jnp.zeros((128,))
        acc = jnp.zeros((128,))
        for t in range(T):
            out, err = shard_map(body, mesh=mesh,
                                 in_specs=(P("pod"), P()),
                                 out_specs=(P(), P()),
                                 check_vma=False)(gs[t], err)
            acc = acc + out
        return acc
    acc_c = run(True)
    acc_e = np.asarray(gs.sum((0, 1)))
    # residual is bounded by one quantisation step, not O(T)
    resid = np.abs(np.asarray(acc_c) - acc_e).max()
    assert resid < 0.2, resid
    """)


def test_sharded_resume_across_mesh_shapes_bitwise(tmp_path):
    """Checkpoint a sharded farm on N=4 forced host devices, restore it
    on M=2, and compare against an uninterrupted single-device run:
    records AND trajectories must be bit-identical (stat_blocks pinned;
    keyed per-lane RNG makes the mesh shape invisible)."""
    ck = str(tmp_path / "ck")
    common = """
    import numpy as np
    from repro.api import (Ensemble, Experiment, Partitioning, Schedule,
                           simulate)
    from repro.core.cwc.models import lotka_volterra
    def exp(n_shards):
        return Experiment(
            model=lotka_volterra(2),
            ensemble=Ensemble.make(replicas=32),
            schedule=Schedule(t_end=1.0, n_windows=6, schema="ii"),
            n_lanes=8, seed=3,
            partitioning=Partitioning(n_shards=n_shards, stat_blocks=4))
    def digest(res):
        print(repr(np.stack([r.mean for r in res.records]).tolist()))
        print(repr(np.stack([r.var for r in res.records]).tolist()))
        print(repr(res.trajectories().tolist()))
    """
    _run(common + f"""
    simulate(exp(4), max_windows=3, checkpoint_path={ck!r})
    """, devices=4)
    resumed = _run(common + f"""
    digest(simulate(exp(2), checkpoint_path={ck!r}, resume=True))
    """, devices=2)
    clean = _run(common + """
    digest(simulate(exp(1)))
    """, devices=1)
    assert resumed == clean


def test_sim_engine_statistics_invariant_to_devices():
    """The farm gives the same ensemble statistics regardless of how
    many shards execute it (trajectories are keyed per instance)."""
    out1 = _run("""
    import numpy as np
    from repro.core.engine import SimulationEngine, SimConfig
    from repro.core.cwc.models import lotka_volterra
    eng = SimulationEngine(lotka_volterra(2),
                           SimConfig(n_instances=32, t_end=1.0, n_windows=3,
                                     n_lanes=32, schema="iii", seed=5))
    print(repr(np.stack([r.mean for r in eng.run()]).tolist()))
    """, devices=1)
    out8 = _run("""
    import numpy as np
    from repro.core.engine import SimulationEngine, SimConfig
    from repro.core.cwc.models import lotka_volterra
    eng = SimulationEngine(lotka_volterra(2),
                           SimConfig(n_instances=32, t_end=1.0, n_windows=3,
                                     n_lanes=32, schema="iii", seed=5))
    print(repr(np.stack([r.mean for r in eng.run()]).tolist()))
    """, devices=8)
    assert out1 == out8
