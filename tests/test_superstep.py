"""Multi-window supersteps (SimConfig.window_block, DESIGN.md §3e).

The contract under test: fusing W windows into one dispatch (with the
record ring pulled per block by the async collector) changes the
dispatch/sync PROFILE and nothing else — records, grouped per-point
stats, trajectories, and per-window step/leap telemetry are bitwise
identical for any window_block, across the fused/kernel window bodies
and both methods, and checkpoint/resume works at block boundaries
(rejecting mid-block resumes with an error naming the knob).

Sharded × window_block parity lives in tests/test_sharded.py (it needs
forced host devices); telemetry-profile invariants (dispatches and
amortised host syncs per window) live in tests/test_telemetry.py.
"""
import os
import tempfile

import numpy as np
import pytest

from repro.api import (
    Ensemble,
    Experiment,
    ExperimentError,
    Method,
    Reduction,
    Schedule,
    simulate,
)
from repro.core.cwc.models import lotka_volterra
from repro.core.engine import SimConfig

N_WINDOWS = 8


def make_exp(window_block=1, n_windows=N_WINDOWS, schema="iii",
             policy="on_demand", reduction=Reduction.ENSEMBLE, **kw):
    return Experiment(
        model=lotka_volterra(2),
        ensemble=Ensemble.make(replicas=32),
        schedule=Schedule(t_end=1.0, n_windows=n_windows, schema=schema,
                          policy=policy),
        reduction=reduction,
        n_lanes=8, seed=7, window_block=window_block, **kw)


def assert_records_bitwise(a, b, ctx=""):
    assert len(a.records) == len(b.records), ctx
    for ra, rb in zip(a.records, b.records):
        assert ra.t == rb.t and ra.window == rb.window and ra.n == rb.n, ctx
        assert (ra.mean == rb.mean).all(), ctx
        assert (ra.var == rb.var).all(), ctx
        assert (ra.ci90 == rb.ci90).all(), ctx


def assert_bitwise(a, b, ctx=""):
    assert_records_bitwise(a, b, ctx)
    # telemetry covers the runs' own windows, so only full runs compare
    ta, tb = a.telemetry, b.telemetry
    assert ta.steps_per_window == tb.steps_per_window, ctx
    assert ta.leaps_per_window == tb.leaps_per_window, ctx


# ------------------------------------------------------------- parity
@pytest.mark.parametrize("use_kernel", [False, True])
@pytest.mark.parametrize("method", [Method.EXACT, Method.TAU_LEAP])
def test_records_bitwise_invariant_to_window_block(use_kernel, method):
    """The acceptance bar: window_block ∈ {1, 2, 4} × fused/kernel ×
    exact/tau-leap all emit bit-identical records and telemetry —
    window_block=1 IS the unchanged per-window path, so this pins the
    superstep scan to the legacy behaviour."""
    base = simulate(make_exp(1, use_kernel=use_kernel, method=method))
    for wb in (2, 4):
        got = simulate(make_exp(wb, use_kernel=use_kernel, method=method))
        assert_bitwise(base, got, ctx=(wb, use_kernel, method))


def test_non_dividing_window_block_runs_short_final_block():
    """window_block that does not divide n_windows: the final block is
    short; records still bitwise, no window dropped or duplicated."""
    base = simulate(make_exp(1))
    got = simulate(make_exp(3))
    assert_bitwise(base, got)
    assert got.windows_run == N_WINDOWS
    # ceil(8 / 3) = 3 dispatches
    assert got.telemetry.dispatches == 3


def test_window_block_wider_than_grid_is_one_dispatch():
    base = simulate(make_exp(1))
    got = simulate(make_exp(64))
    assert_bitwise(base, got)
    assert got.telemetry.dispatches == 1
    assert got.telemetry.host_syncs == 1


def test_grouped_per_point_stats_invariant_to_window_block():
    """PER_POINT reduction rides the same block pull (one sync per
    block) and stays bitwise."""
    def exp(wb):
        return Experiment(
            model=lotka_volterra(2),
            ensemble=Ensemble.make(replicas=16, sweep={"die": [0.3, 1.2]}),
            schedule=Schedule(t_end=1.0, n_windows=4, schema="iii"),
            reduction=Reduction.PER_POINT,
            n_lanes=8, seed=11, window_block=wb)

    base, got = simulate(exp(1)), simulate(exp(4))
    pb, pg = base.per_point(), got.per_point()
    for k in ("n", "mean", "var", "ci90"):
        assert (pb[k] == pg[k]).all(), k


@pytest.mark.parametrize("schema", ["i", "ii"])
def test_buffering_schemas_keep_trajectories_bitwise(schema):
    base = simulate(make_exp(1, schema=schema))
    got = simulate(make_exp(4, schema=schema))
    assert (base.trajectories() == got.trajectories()).all()
    assert_bitwise(base, got)


def test_record_trajectories_under_schema_iii():
    base = simulate(make_exp(1, record_trajectories=True))
    got = simulate(make_exp(4, record_trajectories=True))
    assert (base.trajectories() == got.trajectories()).all()


def test_predictive_policy_composes_with_supersteps():
    """Predictive EMA costs update per window at collect time, so the
    cost state matches the per-window path at every block boundary;
    regrouping at block (not window) cadence never changes a
    trajectory (lane groups are packaging, not semantics)."""
    base = simulate(make_exp(1, policy="predictive"))
    got = simulate(make_exp(4, policy="predictive"))
    assert_bitwise(base, got)
    assert np.array_equal(base._engine.scheduler._cost,
                          got._engine.scheduler._cost)


# ------------------------------------------------- depth-K pipelining
@pytest.mark.parametrize("depth", [2, 4, "auto"])
@pytest.mark.parametrize("use_kernel", [False, True])
def test_depth_k_records_bitwise(depth, use_kernel):
    """PR9 acceptance bar: pipeline_depth only changes WHEN the oldest
    ring is collected, never what was dispatched — records, telemetry,
    and trajectories are bitwise identical to depth 1 (and to the
    per-window path) for any K, including the auto-probed depth."""
    base = simulate(make_exp(1, use_kernel=use_kernel,
                             record_trajectories=True))
    got = simulate(make_exp(2, use_kernel=use_kernel, pipeline_depth=depth,
                            record_trajectories=True))
    assert_bitwise(base, got, ctx=(depth, use_kernel))
    assert (base.trajectories() == got.trajectories()).all()
    t = got.telemetry
    if depth == "auto":
        probe = got._engine.depth_probe
        assert probe is not None and probe["depth"] == t.pipeline_depth
        assert 2 <= t.pipeline_depth <= 8
    else:
        assert t.pipeline_depth == depth
    # pending holds up to depth+1 rings transiently (dispatch K+1
    # happens before the oldest pull), capped by the 4 total blocks
    assert t.peak_inflight_blocks >= min(t.pipeline_depth, 3)
    assert t.peak_inflight_blocks <= min(t.pipeline_depth + 1, 4)


@pytest.mark.parametrize("depth", [2, 4])
def test_depth_k_predictive_in_scan_cost_sort(depth):
    """The predictive regroup now happens inside the block scan on a
    device cost carry — zero host round trips between windows — and
    must stay bitwise with the per-window host path, INCLUDING the
    host-side float64 EMA state at run end."""
    base = simulate(make_exp(1, policy="predictive"))
    got = simulate(make_exp(2, policy="predictive", pipeline_depth=depth))
    assert_bitwise(base, got, ctx=depth)
    assert np.array_equal(base._engine.scheduler._cost,
                          got._engine.scheduler._cost)


@pytest.mark.parametrize("method", [Method.EXACT, Method.TAU_LEAP])
def test_depth_k_methods_and_sparse_bitwise(method):
    base = simulate(make_exp(1, method=method))
    for kw in ({}, {"sparse": True}):
        got = simulate(make_exp(2, pipeline_depth=4, method=method, **kw))
        assert_bitwise(base, got, ctx=(method, kw))


def test_depth_k_sketches_and_grouped_bitwise():
    from repro.api import SketchSpec

    sk = SketchSpec(n_bins=8, lo=0.0, hi=600.0)
    base = simulate(make_exp(1, sketch=sk))
    got = simulate(make_exp(2, pipeline_depth=4, sketch=sk))
    assert_bitwise(base, got)
    for sa, sb in zip(base.sketches(), got.sketches()):
        assert (sa.hist == sb.hist).all()


def test_pipeline_depth_bounds_inflight_rings():
    """Engine-level: at depth K the collector lets K blocks queue
    before blocking on the oldest — run_block turns dispatch first,
    so pending peaks at K+1 within a turn."""
    from repro.api.run import build_engine

    eng = build_engine(make_exp(2, n_windows=16, pipeline_depth=3))
    for expect_pending, expect_window in [
            (1, 0), (2, 0), (3, 0),  # filling: no collects yet
            (3, 2),  # 4th dispatch tips pending past K: collect oldest
    ]:
        eng.run_block()
        assert len(eng._pending) == expect_pending
        assert eng._window == expect_window
    eng.flush()
    assert not eng._pending and eng._window == 8
    assert eng.peak_inflight_blocks == 4  # K+1 transient inside a turn


def test_pipeline_depth_validation():
    with pytest.raises(ExperimentError, match="pipeline_depth"):
        make_exp(2, pipeline_depth=0).validate()
    with pytest.raises(ExperimentError, match="pipeline_depth"):
        make_exp(2, pipeline_depth="deep").validate()
    with pytest.raises(ValueError, match="pipeline_depth"):
        SimConfig(window_block=2, pipeline_depth=0)
    with pytest.raises(ValueError, match="pipeline_depth"):
        SimConfig(window_block=2, pipeline_depth="never")
    SimConfig(window_block=2, pipeline_depth="auto")  # the probe knob


def test_auto_depth_resolution_rule():
    from repro.core.engine import (AUTO_DEPTH_MAX, AUTO_DEPTH_MIN,
                                   resolve_auto_depth)

    assert resolve_auto_depth(1.0, 0.5) == 2   # collect hides in 1 block
    assert resolve_auto_depth(1.0, 2.5) == 4   # ceil(2.5) + 1
    assert resolve_auto_depth(1.0, 100.0) == AUTO_DEPTH_MAX
    assert resolve_auto_depth(0.0, 1.0) == AUTO_DEPTH_MIN  # degenerate


# -------------------------------------------------- checkpoint/resume
def test_checkpoint_resume_at_block_boundary_is_bitwise():
    ck = os.path.join(tempfile.mkdtemp(), "ck")
    clean = simulate(make_exp(4))
    simulate(make_exp(4), max_windows=4, checkpoint_path=ck)
    z = np.load(ck + ".npz")
    assert int(z["window"]) == 4  # save forced a flush: block boundary
    resumed = simulate(make_exp(4), checkpoint_path=ck, resume=True)
    assert_records_bitwise(clean, resumed)


def test_checkpoint_resumes_across_window_block_values():
    """A block-boundary checkpoint is just a window-boundary
    checkpoint: any window_block dividing its index (including 1)
    resumes it bitwise."""
    ck = os.path.join(tempfile.mkdtemp(), "ck")
    clean = simulate(make_exp(1))
    simulate(make_exp(4), max_windows=4, checkpoint_path=ck)
    for wb in (1, 2, 4):
        resumed = simulate(make_exp(wb), checkpoint_path=ck, resume=True)
        assert_records_bitwise(clean, resumed, ctx=wb)


def test_mid_block_resume_rejected_naming_the_knob():
    """A checkpoint cut mid-block (here by a wb=1 run stopping at
    window 3) cannot seed a wb=4 resume — supersteps advance 4 windows
    per dispatch — and the error must name window_block."""
    ck = os.path.join(tempfile.mkdtemp(), "ck")
    simulate(make_exp(1), max_windows=3, checkpoint_path=ck)
    with pytest.raises(ExperimentError, match="window_block"):
        simulate(make_exp(4), checkpoint_path=ck, resume=True)
    # a dividing window_block is fine
    resumed = simulate(make_exp(3), checkpoint_path=ck, resume=True)
    assert_records_bitwise(simulate(make_exp(1)), resumed)


def test_snapshot_checkpoint_saves_without_flushing_pipeline():
    """With snapshots enabled, checkpoint() while K blocks are in
    flight serves the save from the oldest ring's ENTRY snapshot (the
    pool as of the collected frontier) — the pipeline is untouched,
    and the file seeds a bitwise resume."""
    from repro.api.run import build_engine

    eng = build_engine(make_exp(2, pipeline_depth=2))
    eng.enable_snapshots()
    eng.run_block()          # dispatch b0
    eng.run_block()          # dispatch b1 (pending=2, within depth)
    eng.run_block()          # dispatch b2, collect b0 -> window=2
    assert len(eng._pending) == 2 and eng._window == 2
    ck = os.path.join(tempfile.mkdtemp(), "ck")
    eng.checkpoint(ck)
    # the save neither collected nor dropped the in-flight blocks
    assert len(eng._pending) == 2 and eng._window == 2
    assert eng.n_snapshot_saves == 1 and eng.n_ckpt_flushes == 0
    z = np.load(ck + ".npz")
    assert int(z["window"]) == 2  # the collected frontier, not the
    #                               dispatch cursor (which is at 6)
    assert len(z["rec_t"]) == 2
    eng.flush()  # finish this engine cleanly
    resumed = simulate(make_exp(2), checkpoint_path=ck, resume=True)
    assert_records_bitwise(simulate(make_exp(1)), resumed)


def test_checkpoint_without_snapshots_still_flushes():
    """Snapshots are opt-in: a plain engine.checkpoint() mid-flight
    keeps the old collect-first semantics and counts the flush."""
    from repro.api.run import build_engine

    eng = build_engine(make_exp(4))
    eng.run_block()
    ck = os.path.join(tempfile.mkdtemp(), "ck")
    eng.checkpoint(ck)
    assert eng.n_ckpt_flushes == 1 and eng.n_snapshot_saves == 0
    assert eng._window == 4


def test_save_mid_run_forces_flush_of_inflight_block():
    """Engine-level: checkpoint() while a superstep is in flight
    collects it first, so the saved pool state and records agree."""
    from repro.api.run import build_engine

    eng = build_engine(make_exp(4))
    eng.run_block()  # dispatches block 0, collects nothing (pipelined)
    assert eng._dispatched == 4 and eng._window == 0
    ck = os.path.join(tempfile.mkdtemp(), "ck")
    eng.checkpoint(ck)
    assert eng._window == 4  # the flush
    z = np.load(ck + ".npz")
    assert int(z["window"]) == 4
    assert len(z["rec_t"]) == 4


def test_checkpointing_saves_on_every_block_boundary():
    """A checkpoint_path run saves after every block, ON that block's
    boundary — the dispatch-ahead is disabled so a save never flushes
    the NEXT block's windows into the file (regression: the pipelined
    loop used to checkpoint only every second block)."""
    from repro.api.result import SimulationResult
    from repro.api.run import build_engine

    exp = make_exp(2)
    eng = build_engine(exp)
    ck = os.path.join(tempfile.mkdtemp(), "ck")
    saves = []
    orig = eng.checkpoint
    eng.checkpoint = lambda p: (orig(p), saves.append(eng._window))
    SimulationResult(exp, eng).resume(checkpoint_path=ck)
    assert saves == [2, 4, 6, 8]
    assert_records_bitwise(simulate(make_exp(1)),
                           SimulationResult(exp, eng))


def test_max_windows_can_cut_a_block_short_and_realign():
    """max_windows stops mid-block via a short dispatch; the
    in-process continuation realigns to the absolute block grid and
    stays bitwise."""
    clean = simulate(make_exp(4))
    r = simulate(make_exp(4), max_windows=3)
    assert r.windows_run == 3
    r.resume()
    assert_bitwise(clean, r)


def test_mid_block_cut_checkpoint_rolls_back_to_block_boundary():
    """A checkpoint at a mid-block max_windows cut (frontier 6 under
    wb=4) is served from the cut block's aligned ENTRY snapshot: the
    file lands on window 4 — restorable under the run's own
    window_block — without ever flushing the pipeline, and the resumed
    run replays the tail bitwise."""
    ck = os.path.join(tempfile.mkdtemp(), "ck")
    clean = simulate(make_exp(4))
    cut = simulate(make_exp(4), max_windows=6, checkpoint_path=ck)
    assert cut.windows_run == 6
    assert cut.telemetry.ckpt_flushes == 0
    z = np.load(ck + ".npz")
    assert int(z["window"]) == 4  # rolled back to the block boundary
    resumed = simulate(make_exp(4), checkpoint_path=ck, resume=True)
    assert_records_bitwise(clean, resumed)


# ------------------------------------------------------- error paths
def test_truncation_raises_naming_the_failing_window():
    from repro.kernels.ops import FusedWindowTruncated

    with pytest.raises(FusedWindowTruncated, match="window 0"):
        simulate(make_exp(4, use_kernel=True, kernel_chunk_steps=1,
                          kernel_max_chunks=1))


def test_truncation_drops_the_inflight_pipeline():
    """When block k truncates, block k+1 (already dispatched from the
    partial-window pool) must be dropped — a later accessor's flush
    must neither re-raise from a getter nor turn the invalid state
    into records."""
    from repro.api.run import build_engine
    from repro.kernels.ops import FusedWindowTruncated

    eng = build_engine(make_exp(2, use_kernel=True, kernel_chunk_steps=1,
                                kernel_max_chunks=1))
    eng.run_block()  # dispatch block 0 (pipelined: nothing collected)
    with pytest.raises(FusedWindowTruncated):
        eng.run_block()  # dispatches block 1, then collects block 0
    assert not eng._pending
    assert eng.grouped_stats() == []  # accessors flush without raising
    assert eng.stream.records() == []  # no record from invalid state
    # the dispatch cursor rewound to the collected frontier: a caller
    # driving on re-runs from the failed window, never skipping any
    assert eng._dispatched == eng._window == 0


def test_window_block_validation():
    with pytest.raises(ExperimentError, match="window_block"):
        make_exp(0).validate()
    with pytest.raises(ExperimentError, match="host_loop"):
        make_exp(4, host_loop=True).validate()
    with pytest.raises(ValueError, match="window_block"):
        SimConfig(window_block=0)
    with pytest.raises(ValueError, match="host_loop"):
        SimConfig(window_block=2, host_loop=True)
    # window_block=1 + host_loop stays legal (the baseline)
    SimConfig(window_block=1, host_loop=True)


def test_sinks_receive_records_in_window_order():
    seen = []
    simulate(make_exp(4, sinks=(lambda rec: seen.append(rec.window),)))
    assert seen == list(range(N_WINDOWS))
