"""Dispatch/sync-profile regression tests (promoted from the
bench-smoke job so a profile regression fails `pytest`, not just CI's
benchmark gate).

The profile contract per dispatch path (BENCH_PR5.json records the
same numbers at benchmark scale):

  host_loop   : one dispatch per (group × window), 1 blocking pull per
                window;
  fused       : ONE dispatch per window, exactly 1 blocking pull per
                window (the combined record pull — PR4 folded the
                kernel truncation flag into it);
  supersteps  : window_block=W fuses W windows into one dispatch and
                one block pull, so BOTH amortise to 1/W per window.
"""
import pytest

from repro.api import Ensemble, Experiment, Method, Schedule, simulate
from repro.core.cwc.models import lotka_volterra

N_INSTANCES, N_LANES, N_WINDOWS = 32, 8, 8
N_GROUPS = N_INSTANCES // N_LANES  # host-loop dispatches per window


def run(**kw):
    res = simulate(Experiment(
        model=lotka_volterra(2),
        ensemble=Ensemble.make(replicas=N_INSTANCES),
        schedule=Schedule(t_end=1.0, n_windows=N_WINDOWS, schema="iii"),
        n_lanes=N_LANES, seed=7, **kw))
    t = res.telemetry
    return (t.dispatches / N_WINDOWS, t.host_syncs / N_WINDOWS)


def test_host_loop_profile():
    disp, syncs = run(host_loop=True)
    assert disp == N_GROUPS
    assert syncs == 1.0


@pytest.mark.parametrize("use_kernel", [False, True])
def test_per_window_paths_are_one_dispatch_one_sync(use_kernel):
    disp, syncs = run(use_kernel=use_kernel)
    assert disp == 1.0, f"kernel={use_kernel}"
    assert syncs == 1.0, f"kernel={use_kernel}"


@pytest.mark.parametrize("use_kernel", [False, True])
@pytest.mark.parametrize("method", [Method.EXACT, Method.TAU_LEAP])
def test_superstep_amortises_dispatches_and_syncs(use_kernel, method):
    """The PR5 acceptance numbers: at window_block=4 both
    dispatches/window and amortised host_syncs/window are 0.25 —
    ≤ 0.25 and < 1.0 respectively."""
    disp, syncs = run(window_block=4, use_kernel=use_kernel,
                      method=method)
    assert disp <= 0.25, (use_kernel, method)
    assert syncs < 1.0, (use_kernel, method)
    assert syncs == disp == 0.25, (use_kernel, method)


def test_superstep_amortises_trajectory_and_grouped_pulls():
    """Per-window paths pay extra pulls for buffered samples; the block
    collector folds samples into the one ring pull, so even a
    trajectory-buffering run stays below 1 sync per window."""
    from repro.api import Reduction

    res = simulate(Experiment(
        model=lotka_volterra(2),
        ensemble=Ensemble.make(replicas=16, sweep={"die": [0.3, 1.2]}),
        schedule=Schedule(t_end=1.0, n_windows=N_WINDOWS, schema="iii"),
        reduction=Reduction.PER_POINT, record_trajectories=True,
        n_lanes=N_LANES, seed=7, window_block=4))
    t = res.telemetry
    assert t.dispatches / N_WINDOWS == 0.25
    assert t.host_syncs / N_WINDOWS < 1.0


def test_superstep_pipeline_stays_one_block_deep():
    """The collector double-buffers: after the steady-state turn of
    run_block there is exactly one in-flight block (dispatch k+1
    happened before the blocking pull of k)."""
    from repro.api.run import build_engine

    eng = build_engine(Experiment(
        model=lotka_volterra(2),
        ensemble=Ensemble.make(replicas=N_INSTANCES),
        schedule=Schedule(t_end=1.0, n_windows=N_WINDOWS, schema="iii"),
        n_lanes=N_LANES, seed=7, window_block=2))
    eng.run_block()
    assert len(eng._pending) == 1 and eng._window == 0
    eng.run_block()  # dispatches block 1, THEN collects block 0
    assert len(eng._pending) == 1 and eng._window == 2
    eng.flush()
    assert not eng._pending and eng._window == 4


# --------------------------------------------- straggler watchdog
def test_window_watchdog_flags_outliers():
    """Unit contract: a window whose wall share exceeds factor x the
    rolling median (of PRIOR observations) is flagged with its
    context; steady windows are not."""
    from repro.runtime.straggler import WindowWatchdog

    wd = WindowWatchdog(factor=3.0)
    assert not wd.observe(0, 0.1)  # no history yet: self-median
    for w in range(1, 5):
        assert not wd.observe(w, 0.1)
    assert wd.observe(5, 0.5)  # 5x the median
    assert not wd.observe(6, 0.1)
    assert wd.flagged == [(5, 0.5, 0.1)]
    assert wd.straggler_rate() == 1 / 7


@pytest.mark.parametrize("window_block", [1, 4])
def test_watchdog_observes_every_window_into_telemetry(window_block):
    """Engine wiring (per-window AND superstep collector): every
    window's wall share feeds the watchdog, and the telemetry
    surfaces its verdicts."""
    res = simulate(Experiment(
        model=lotka_volterra(2),
        ensemble=Ensemble.make(replicas=N_INSTANCES),
        schedule=Schedule(t_end=1.0, n_windows=N_WINDOWS, schema="iii"),
        n_lanes=N_LANES, seed=7, window_block=window_block))
    wd = res._engine.watchdog
    assert len(wd.history) == N_WINDOWS
    t = res.telemetry
    assert t.straggler_rate == wd.straggler_rate()
    assert t.straggler_windows == tuple(wd.flagged)
    for w, wall, med in t.straggler_windows:
        assert 0 <= w < N_WINDOWS and wall > 3.0 * med
