"""Dispatch/sync-profile regression tests (promoted from the
bench-smoke job so a profile regression fails `pytest`, not just CI's
benchmark gate).

The profile contract per dispatch path (BENCH_PR5.json records the
same numbers at benchmark scale):

  host_loop   : one dispatch per (group × window), 1 blocking pull per
                window;
  fused       : ONE dispatch per window, exactly 1 blocking pull per
                window (the combined record pull — PR4 folded the
                kernel truncation flag into it);
  supersteps  : window_block=W fuses W windows into one dispatch and
                one block pull, so BOTH amortise to 1/W per window.
"""
import pytest

from repro.api import Ensemble, Experiment, Method, Schedule, simulate
from repro.core.cwc.models import lotka_volterra

N_INSTANCES, N_LANES, N_WINDOWS = 32, 8, 8
N_GROUPS = N_INSTANCES // N_LANES  # host-loop dispatches per window


def run(**kw):
    res = simulate(Experiment(
        model=lotka_volterra(2),
        ensemble=Ensemble.make(replicas=N_INSTANCES),
        schedule=Schedule(t_end=1.0, n_windows=N_WINDOWS, schema="iii"),
        n_lanes=N_LANES, seed=7, **kw))
    t = res.telemetry
    return (t.dispatches / N_WINDOWS, t.host_syncs / N_WINDOWS)


def test_host_loop_profile():
    disp, syncs = run(host_loop=True)
    assert disp == N_GROUPS
    assert syncs == 1.0


@pytest.mark.parametrize("use_kernel", [False, True])
def test_per_window_paths_are_one_dispatch_one_sync(use_kernel):
    disp, syncs = run(use_kernel=use_kernel)
    assert disp == 1.0, f"kernel={use_kernel}"
    assert syncs == 1.0, f"kernel={use_kernel}"


@pytest.mark.parametrize("use_kernel", [False, True])
@pytest.mark.parametrize("method", [Method.EXACT, Method.TAU_LEAP])
def test_superstep_amortises_dispatches_and_syncs(use_kernel, method):
    """The PR5 acceptance numbers: at window_block=4 both
    dispatches/window and amortised host_syncs/window are 0.25 —
    ≤ 0.25 and < 1.0 respectively."""
    disp, syncs = run(window_block=4, use_kernel=use_kernel,
                      method=method)
    assert disp <= 0.25, (use_kernel, method)
    assert syncs < 1.0, (use_kernel, method)
    assert syncs == disp == 0.25, (use_kernel, method)


def test_superstep_amortises_trajectory_and_grouped_pulls():
    """Per-window paths pay extra pulls for buffered samples; the block
    collector folds samples into the one ring pull, so even a
    trajectory-buffering run stays below 1 sync per window."""
    from repro.api import Reduction

    res = simulate(Experiment(
        model=lotka_volterra(2),
        ensemble=Ensemble.make(replicas=16, sweep={"die": [0.3, 1.2]}),
        schedule=Schedule(t_end=1.0, n_windows=N_WINDOWS, schema="iii"),
        reduction=Reduction.PER_POINT, record_trajectories=True,
        n_lanes=N_LANES, seed=7, window_block=4))
    t = res.telemetry
    assert t.dispatches / N_WINDOWS == 0.25
    assert t.host_syncs / N_WINDOWS < 1.0


def test_superstep_pipeline_stays_one_block_deep():
    """The collector double-buffers: after the steady-state turn of
    run_block there is exactly one in-flight block (dispatch k+1
    happened before the blocking pull of k)."""
    from repro.api.run import build_engine

    eng = build_engine(Experiment(
        model=lotka_volterra(2),
        ensemble=Ensemble.make(replicas=N_INSTANCES),
        schedule=Schedule(t_end=1.0, n_windows=N_WINDOWS, schema="iii"),
        n_lanes=N_LANES, seed=7, window_block=2))
    eng.run_block()
    assert len(eng._pending) == 1 and eng._window == 0
    eng.run_block()  # dispatches block 1, THEN collects block 0
    assert len(eng._pending) == 1 and eng._window == 2
    eng.flush()
    assert not eng._pending and eng._window == 4


# --------------------------------------------- straggler watchdog
def test_window_watchdog_flags_outliers():
    """Unit contract: a window whose wall share exceeds factor x the
    rolling median (of PRIOR observations) is flagged with its
    context; steady windows are not."""
    from repro.runtime.straggler import WindowWatchdog

    wd = WindowWatchdog(factor=3.0)
    assert not wd.observe(0, 0.1)  # no history yet: self-median
    for w in range(1, 5):
        assert not wd.observe(w, 0.1)
    assert wd.observe(5, 0.5)  # 5x the median
    assert not wd.observe(6, 0.1)
    assert wd.flagged == [(5, 0.5, 0.1)]
    assert wd.straggler_rate() == 1 / 7


@pytest.mark.parametrize("window_block", [1, 4])
def test_watchdog_observes_every_window_into_telemetry(window_block):
    """Engine wiring (per-window AND superstep collector): every
    window is accounted by the watchdog — per-window walls on the
    per-window path, ONE block-level sample per superstep (per-window
    walls are not measurable under block dispatch; n identical slices
    would poison the median) with `observed` still advancing by the
    real window count — and the telemetry surfaces its verdicts."""
    res = simulate(Experiment(
        model=lotka_volterra(2),
        ensemble=Ensemble.make(replicas=N_INSTANCES),
        schedule=Schedule(t_end=1.0, n_windows=N_WINDOWS, schema="iii"),
        n_lanes=N_LANES, seed=7, window_block=window_block))
    wd = res._engine.watchdog
    assert wd.observed == N_WINDOWS
    assert len(wd.history) == N_WINDOWS // window_block
    t = res.telemetry
    assert t.straggler_rate == wd.straggler_rate()
    assert t.straggler_windows == tuple(wd.flagged)
    for w, wall, med in t.straggler_windows:
        assert 0 <= w < N_WINDOWS and wall > 3.0 * med


def test_supervised_cadence_saves_do_not_flush_pipeline(tmp_path):
    """PR9 acceptance: cadence checkpoints under the supervisor are
    served from in-flight ring snapshots, not by draining the pipeline
    — so a fault-free supervised superstep run keeps the SAME
    dispatch/host-sync profile as an unsupervised one (4 block
    dispatches, 4 block pulls for wb=2 over 8 windows), every cadence
    save is a snapshot save, and no save forced a flush."""
    from repro.api import Recovery

    res = simulate(Experiment(
        model=lotka_volterra(2),
        ensemble=Ensemble.make(replicas=N_INSTANCES),
        schedule=Schedule(t_end=1.0, n_windows=N_WINDOWS, schema="iii"),
        n_lanes=N_LANES, seed=7, window_block=2,
        recovery=Recovery(ckpt_dir=str(tmp_path / "rec"), cadence=2)))
    t = res.telemetry
    assert t.dispatches == N_WINDOWS // 2
    assert t.host_syncs == N_WINDOWS // 2
    assert t.ckpt_flushes == 0
    # saves at windows 2, 4, 6 land while the next block is in flight;
    # the final save at 8 finds an empty pipeline (flush-free either way)
    assert t.snapshot_saves >= 3
    assert t.peak_inflight_blocks >= 2
    assert t.restarts == 0 and t.stall_redispatches == 0


def test_watchdog_rate_denominator_survives_long_runs():
    """>64-window regression: `history` is a bounded median window
    (maxlen=64), so the rate denominator must be the monotone
    `observed` counter — with the old len(history) denominator a run
    flagging >64 windows could report a rate above 1.0."""
    from repro.runtime.straggler import WindowWatchdog

    wd = WindowWatchdog(factor=3.0)
    flagged = 0
    for w in range(200):
        # alternate calm stretches with bursts so flags keep landing
        # long after the deque saturated
        wall = 1.0 if w % 10 else 100.0
        if wd.observe(w, wall):
            flagged += 1
    assert wd.observed == 200
    assert len(wd.history) == 64  # saturated median window
    assert flagged == len(wd.flagged) > 0
    assert wd.straggler_rate() == flagged / 200
    assert 0.0 <= wd.straggler_rate() <= 1.0


def test_watchdog_block_observation_rate_is_per_window():
    """observe_block records one median sample per block but advances
    the denominator by the block's real window count."""
    from repro.runtime.straggler import WindowWatchdog

    wd = WindowWatchdog(factor=3.0)
    for b in range(5):
        assert not wd.observe_block(b * 4, 4, 4.0)  # 1.0 per window
    assert wd.observe_block(20, 4, 20.0)  # 5.0 per window: straggler
    assert wd.observed == 24
    assert len(wd.history) == 6
    assert wd.flagged == [(20, 5.0, 1.0)]
    assert wd.straggler_rate() == 1 / 24
