"""Tau-leaping engine path: parity across every dispatch path, exact
degeneration, invariants, checkpoint/resume, telemetry, validation."""
import numpy as np
import pytest

from repro.api import (
    Ensemble,
    Experiment,
    ExperimentError,
    Method,
    Schedule,
    simulate,
)
from repro.core.cwc.models import ecoli_gene_regulation, lotka_volterra
from repro.core.gillespie import init_lanes
from repro.core.reactions import make_system
from repro.core.tau_leap import advance_to as tau_advance_to
from repro.core.tau_leap import gi_tables, poisson_from_uniform


def _exp(model=None, method=Method.TAU_LEAP, replicas=16, windows=3,
         t_end=0.5, seed=5, **kw):
    kw.setdefault("record_trajectories", True)
    return Experiment(
        model=model if model is not None else lotka_volterra(2),
        ensemble=Ensemble.make(replicas=replicas),
        schedule=Schedule(t_end=t_end, n_windows=windows),
        n_lanes=8, seed=seed, method=method, **kw)


# ------------------------------------------------------------- parity
def test_tau_leap_bitwise_across_all_dispatch_paths():
    """The signature invariant extends to the second algorithm: fused,
    host-loop, Pallas-kernel, and host-loop+kernel tau-leap runs are
    BITWISE identical (same `tau_step_core`, same counter stream)."""
    base = simulate(_exp())
    assert sum(base.telemetry.leaps_per_window) > 0, (
        "config must actually leap for the parity claim to bite")
    for kw in (dict(use_kernel=True), dict(host_loop=True),
               dict(host_loop=True, use_kernel=True)):
        other = simulate(_exp(**kw))
        assert (other.means() == base.means()).all(), kw
        assert (other.trajectories() == base.trajectories()).all(), kw
        for a, b in zip(base.records, other.records):
            assert (a.var == b.var).all() and (a.ci90 == b.ci90).all()


def test_tau_leap_bitwise_invariant_to_lane_grouping():
    a = simulate(_exp().with_(n_lanes=4))
    b = simulate(_exp().with_(n_lanes=16))
    assert (a.trajectories() == b.trajectories()).all()


def test_tau_leap_with_unreachable_threshold_is_exact_ssa_bitwise():
    """tau_fallback=inf forces the per-lane exact fallback on every
    step — the tau-leap path must then REPRODUCE the exact engine
    bitwise (same stream consumption, same propensity/update math), so
    the fallback is provably the exact algorithm, not a lookalike."""
    # pure birth consumes no species, so the Cao drift bound is vacuous
    # (candidate tau = inf) — the leap gate must use the CLAMPED leap
    # tau or this system leaps past any threshold
    pure_birth = make_system(["A"], [({}, {"A": 1}, 100.0)], {"A": 0})
    for model in (lotka_volterra(2), ecoli_gene_regulation(),
                  pure_birth):
        ex = simulate(_exp(model, method=Method.EXACT))
        tl = simulate(_exp(model, method=Method.TAU_LEAP,
                           tau_fallback=float("inf")))
        assert sum(tl.telemetry.leaps_per_window) == 0
        assert (ex.means() == tl.means()).all()
        assert (ex.trajectories() == tl.trajectories()).all()


def test_tau_leap_deterministic_same_seed():
    a, b = simulate(_exp(seed=9)), simulate(_exp(seed=9))
    assert (a.trajectories() == b.trajectories()).all()
    c = simulate(_exp(seed=10))
    assert (c.trajectories() != a.trajectories()).any()


# --------------------------------------------------------- invariants
def test_tau_leap_preserves_stoichiometric_conservation():
    """2A -> B leaps fire K*(-2A, +B) at once: A + 2B is conserved by
    every accepted leap exactly, never just approximately."""
    sys = make_system(["A", "B"], [({"A": 2}, {"B": 1}, 0.001)],
                      {"A": 3000, "B": 0})
    res = simulate(_exp(sys, replicas=32, t_end=0.2, windows=2,
                        record_trajectories=False))
    assert sum(res.telemetry.leaps_per_window) > 0
    x = res.final_state()
    assert (x[:, 0] + 2 * x[:, 1] == 3000).all()
    assert (x >= 0).all()


def test_tau_leap_rejection_keeps_populations_nonnegative():
    """A fast pure-death system drives leap proposals into the
    negative-population regime: rejection/retry (then exact fallback)
    must keep every lane count >= 0 at every window."""
    sys = make_system(["A"], [({"A": 1}, {}, 30.0)], {"A": 400})
    res = simulate(_exp(sys, replicas=64, t_end=0.6, windows=6,
                        tau_eps=0.2))
    traj = res.trajectories()
    assert (traj >= 0).all()
    assert (res.final_state() >= 0).all()


def test_tau_leap_executes_fewer_steps_than_exact():
    """The point of the method: on a large-population model the solver
    advances in leaps — far fewer iterations than exact SSA events."""
    lam, mu = 4000.0, 1.0
    sys = make_system(["A"], [({}, {"A": 1}, lam), ({"A": 1}, {}, mu)],
                      {"A": 0})
    ex = simulate(_exp(sys, method=Method.EXACT, replicas=64, t_end=2.0,
                       windows=4, record_trajectories=False))
    tl = simulate(_exp(sys, method=Method.TAU_LEAP, replicas=64,
                       t_end=2.0, windows=4, record_trajectories=False))
    s_ex = sum(ex.telemetry.steps_per_window)
    s_tl = sum(tl.telemetry.steps_per_window)
    assert s_tl * 5 <= s_ex, (s_ex, s_tl)
    assert sum(tl.telemetry.leaps_per_window) > 0
    # and the ensembles still agree on the mean trajectory
    m = lam / mu * (1 - np.exp(-mu * 2.0))
    assert abs(tl.means()[-1, 0] - m) < 5 * np.sqrt(m / 64)


def test_tau_leap_telemetry_splits_leaps_vs_fallback():
    res = simulate(_exp())
    tele = res.telemetry
    assert len(tele.steps_per_window) == 3
    assert len(tele.leaps_per_window) == 3
    for s, l in zip(tele.steps_per_window, tele.leaps_per_window):
        assert 0 <= l <= s  # fallback share = s - l
    ex = simulate(_exp(method=Method.EXACT))
    assert sum(ex.telemetry.leaps_per_window) == 0
    assert sum(ex.telemetry.steps_per_window) > 0


# ------------------------------------------------------- fault drills
def test_tau_leap_checkpoint_resume_bitwise(tmp_path):
    ck = str(tmp_path / "ck")
    clean = simulate(_exp(windows=4))
    simulate(_exp(windows=4), max_windows=2, checkpoint_path=ck)
    resumed = simulate(_exp(windows=4), checkpoint_path=ck, resume=True)
    assert (resumed.means() == clean.means()).all()
    assert (resumed.trajectories() == clean.trajectories()).all()
    # per-window telemetry restarts from the checkpoint, not from 0
    assert (list(clean.telemetry.steps_per_window[2:])
            == list(resumed.telemetry.steps_per_window))


def test_tau_leap_checkpoint_roundtrips_new_lane_fields(tmp_path):
    ck = str(tmp_path / "ck")
    simulate(_exp(windows=2), max_windows=1, checkpoint_path=ck)
    z = np.load(ck + ".npz")
    assert z["ctr_hi"].dtype == np.uint32
    assert z["leaps"].dtype == np.int32
    assert int(z["leaps"].sum()) >= 0


def test_old_checkpoint_without_new_fields_still_restores(tmp_path):
    """Pre-widening checkpoints (no ctr_hi/leaps) restore with zeros —
    bitwise for any stream below 2^32 draws."""
    ck = str(tmp_path / "ck")
    clean = simulate(_exp(windows=3, method=Method.EXACT))
    simulate(_exp(windows=3, method=Method.EXACT), max_windows=1,
             checkpoint_path=ck)
    z = dict(np.load(ck + ".npz"))
    z.pop("ctr_hi"), z.pop("leaps")
    # old checkpoints predate the embedded magic/checksum too — a plain
    # np.savez rewrite (legacy files restore unchecked)
    z.pop("__ckpt_magic__", None), z.pop("__ckpt_sha256__", None)
    np.savez(ck, **z)
    resumed = simulate(_exp(windows=3, method=Method.EXACT),
                       checkpoint_path=ck, resume=True)
    assert (resumed.means() == clean.means()).all()


# -------------------------------------------------------- unit pieces
def test_poisson_inverse_transform_moments(rng):
    import jax.numpy as jnp

    for lam in (0.3, 2.0, 9.0):
        u = jnp.asarray(rng.uniform(1e-12, 1.0, 20000).astype(np.float32))
        k = np.asarray(poisson_from_uniform(u, jnp.float32(lam)))
        assert abs(k.mean() - lam) < 4 * np.sqrt(lam / 20000)
        assert abs(k.var() - lam) < 0.1 * lam + 4 * lam * np.sqrt(2 / 20000)
    z = np.asarray(poisson_from_uniform(
        jnp.asarray([0.5], jnp.float32), jnp.asarray([0.0], jnp.float32)))
    assert z[0] == 0.0  # lam=0 never fires


def test_gi_tables_standard_cases():
    # first order: g = 1
    sys1 = make_system(["A"], [({"A": 1}, {}, 1.0)], {"A": 5})
    assert gi_tables(sys1)[0, 0] == 1.0
    # second order, two of the same: g = 2 + 1/(x-1)
    sys2 = make_system(["A", "B"], [({"A": 2}, {"B": 1}, 1.0)],
                       {"A": 5})
    tab = gi_tables(sys2)
    assert tab[0, 0] == 2.0 and tab[1, 0] == 1.0
    # HOR wins: the dimerisation bound beats the decay's first order
    sys3 = make_system(["A", "B"],
                       [({"A": 1}, {}, 1.0), ({"A": 2}, {"B": 1}, 1.0)],
                       {"A": 5})
    assert (gi_tables(sys3)[:, 0] == tab[:, 0]).all()


def test_tau_leap_standalone_advance_matches_engine_window():
    """core.tau_leap.advance_to is the same per-lane algorithm the
    engine dispatches — one window must agree bitwise."""
    sys = make_system(["A"], [({}, {"A": 1}, 200.0), ({"A": 1}, {}, 1.0)],
                      {"A": 0})
    st = tau_advance_to(init_lanes(sys, 16, seed=2), sys, 0.5)
    res = simulate(_exp(sys, replicas=16, windows=1, t_end=0.5, seed=2,
                        record_trajectories=False))
    assert (res.final_state() == np.asarray(st.x)).all()
    assert (np.asarray(st.t) == 0.5).all()


# --------------------------------------------------------- validation
def test_method_coercion_and_validation():
    e = _exp(method="tau_leap")  # legacy-string spelling coerces
    assert e.method is Method.TAU_LEAP
    with pytest.raises(ExperimentError, match="unknown method"):
        _exp(method="leapfrog")
    with pytest.raises(ExperimentError, match="tau_eps"):
        _exp(tau_eps=0.0).validate()
    with pytest.raises(ExperimentError, match="tau_fallback"):
        _exp(tau_fallback=-1.0).validate()
    with pytest.raises(ValueError, match="method"):
        from repro.core.engine import SimConfig

        SimConfig(method="nope")
