"""Welford streaming statistics: exactness + merge associativity."""
import numpy as np
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.reduction import (
    finalize,
    init_welford,
    merge,
    update_batch,
)


def test_update_batch_matches_numpy(rng):
    x = rng.standard_normal((64, 3)).astype(np.float32) * 10
    acc = init_welford((3,))
    acc = update_batch(acc, jnp.asarray(x))
    stats = finalize(acc)
    np.testing.assert_allclose(np.asarray(stats.mean), x.mean(0), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(stats.var), x.var(0, ddof=1),
                               rtol=1e-4)


def test_windowed_merge_equals_batch(rng):
    x = rng.standard_normal((100, 2)).astype(np.float32)
    acc = init_welford((2,))
    for i in range(0, 100, 10):
        acc = update_batch(acc, jnp.asarray(x[i:i + 10]))
    s = finalize(acc)
    np.testing.assert_allclose(np.asarray(s.mean), x.mean(0), rtol=1e-4,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(s.var), x.var(0, ddof=1), rtol=1e-3,
                               atol=1e-5)


def test_masked_update(rng):
    x = rng.standard_normal((10, 2)).astype(np.float32)
    mask = np.array([1, 1, 0, 1, 0, 1, 1, 0, 1, 1], bool)
    acc = update_batch(init_welford((2,)), jnp.asarray(x), jnp.asarray(mask))
    s = finalize(acc)
    np.testing.assert_allclose(np.asarray(s.mean), x[mask].mean(0), rtol=1e-5)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(-1e3, 1e3), min_size=2, max_size=40),
       st.integers(1, 10))
def test_merge_associative_split_invariant(xs, split):
    """Any split of the sample stream yields identical (n, mean, m2)."""
    x = np.asarray(xs, np.float32)[:, None]
    split = min(split, len(xs) - 1)
    a = update_batch(init_welford((1,)), jnp.asarray(x))
    b1 = update_batch(init_welford((1,)), jnp.asarray(x[:split]))
    b2 = update_batch(init_welford((1,)), jnp.asarray(x[split:]))
    b = merge(b1, b2)
    scale = max(1.0, np.abs(x).max()) ** 2
    assert abs(float(a.n[0] - b.n[0])) == 0
    assert abs(float(a.mean[0] - b.mean[0])) < 1e-3 * max(1.0, np.abs(x).max())
    assert abs(float(a.m2[0] - b.m2[0])) < 1e-2 * scale * len(xs)


def test_ci90_shrinks_with_n(rng):
    x = rng.standard_normal((1000, 1)).astype(np.float32)
    s_small = finalize(update_batch(init_welford((1,)), jnp.asarray(x[:10])))
    s_big = finalize(update_batch(init_welford((1,)), jnp.asarray(x)))
    assert float(s_big.ci90[0]) < float(s_small.ci90[0])
