"""Config/registry/shape-matrix tests."""
import pytest

from repro.configs.base import ALL_SHAPES, SHAPES, shape_applicable
from repro.configs.registry import (
    ARCH_NAMES,
    default_sharding,
    dryrun_cells,
    get_config,
    get_smoke_config,
    skipped_cells,
)


def test_all_archs_registered():
    # 7 decoder-only archs: the encoder-decoder seamless-m4t family was
    # pruned with models/encdec.py
    assert len(ARCH_NAMES) == 7


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_param_counts_in_band(name):
    """Sanity bands around the published sizes."""
    bands = {
        "olmoe-1b-7b": (6e9, 8e9),
        "deepseek-moe-16b": (15e9, 18e9),
        "internvl2-1b": (0.4e9, 1.2e9),
        "llama3-8b": (7.5e9, 8.6e9),
        "starcoder2-7b": (6.5e9, 8e9),
        "command-r-35b": (28e9, 36e9),
        "gemma-7b": (7.5e9, 9.5e9),
    }
    n = get_config(name).param_count()
    lo, hi = bands[name]
    assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B outside [{lo/1e9},{hi/1e9}]"


def test_cell_matrix():
    cells = dryrun_cells()
    skips = skipped_cells()
    # 3 applicable shapes per remaining arch (seamless-m4t pruned)
    assert len(cells) == 21
    assert len(skips) == 7
    assert all(s[1] == "long_500k" for s in skips)
    # the sub-quadratic archs that ran long_500k were retired (the
    # simulator is the repo's subject; see ROADMAP) — no arch left
    # qualifies for the long-context shape
    long_archs = {a for a, s in cells if s.name == "long_500k"}
    assert long_archs == set()


def test_deepseek_first_dense():
    cfg = get_config("deepseek-moe-16b")
    specs = cfg.layer_specs()
    assert specs[0].ffn == "dense"
    assert all(s.ffn == "moe" for s in specs[1:])


def test_default_sharding_decode_rules():
    # kv heads not divisible by 16 -> flash-decode over `model`
    s = default_sharding("llama3-8b", SHAPES["decode_32k"])
    assert s.seq_sharded_kv and s.kv_seq_axis == "model"
    # divisible kv heads -> plain kv-head sharding
    s = default_sharding("gemma-7b", SHAPES["decode_32k"])
    assert not s.seq_sharded_kv
    # long context -> cache seq over `data` (arch-independent rule)
    s = default_sharding("gemma-7b", SHAPES["long_500k"])
    assert s.seq_sharded_kv and s.kv_seq_axis == "data"


def test_padded_vocab():
    cfg = get_config("internvl2-1b")
    assert cfg.padded_vocab % 256 == 0 and cfg.padded_vocab >= cfg.vocab_size


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_configs_are_small(name):
    cfg = get_smoke_config(name)
    assert cfg.param_count() < 5e7
    assert cfg.family == get_config(name).family
