"""Config/registry/shape-matrix tests."""
import pytest

from repro.configs.base import ALL_SHAPES, SHAPES, shape_applicable
from repro.configs.registry import (
    ARCH_NAMES,
    default_sharding,
    dryrun_cells,
    get_config,
    get_smoke_config,
    skipped_cells,
)


def test_all_archs_registered():
    assert len(ARCH_NAMES) == 10


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_param_counts_in_band(name):
    """Sanity bands around the published sizes."""
    bands = {
        "olmoe-1b-7b": (6e9, 8e9),
        "deepseek-moe-16b": (15e9, 18e9),
        "internvl2-1b": (0.4e9, 1.2e9),
        "xlstm-1.3b": (0.9e9, 2.5e9),
        "jamba-v0.1-52b": (48e9, 56e9),
        "llama3-8b": (7.5e9, 8.6e9),
        "starcoder2-7b": (6.5e9, 8e9),
        "command-r-35b": (28e9, 36e9),
        "gemma-7b": (7.5e9, 9.5e9),
        "seamless-m4t-large-v2": (1.2e9, 2.5e9),
    }
    n = get_config(name).param_count()
    lo, hi = bands[name]
    assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B outside [{lo/1e9},{hi/1e9}]"


def test_cell_matrix():
    cells = dryrun_cells()
    skips = skipped_cells()
    assert len(cells) == 32
    assert len(skips) == 8
    assert all(s[1] == "long_500k" for s in skips)
    # long_500k runs exactly for the sub-quadratic archs
    long_archs = {a for a, s in cells if s.name == "long_500k"}
    assert long_archs == {"xlstm-1.3b", "jamba-v0.1-52b"}


def test_jamba_layer_pattern():
    cfg = get_config("jamba-v0.1-52b")
    specs = cfg.layer_specs()
    assert len(specs) == 32
    attn_layers = [i for i, s in enumerate(specs) if s.mixer == "attn"]
    assert attn_layers == [4, 12, 20, 28]  # 1 in 8
    moe_layers = [i for i, s in enumerate(specs) if s.ffn == "moe"]
    assert moe_layers == list(range(1, 32, 2))  # every other


def test_xlstm_layer_pattern():
    cfg = get_config("xlstm-1.3b")
    specs = cfg.layer_specs()
    slstm = [i for i, s in enumerate(specs) if s.mixer == "slstm"]
    assert slstm == list(range(7, 48, 8))
    assert all(s.ffn == "none" for s in specs)


def test_deepseek_first_dense():
    cfg = get_config("deepseek-moe-16b")
    specs = cfg.layer_specs()
    assert specs[0].ffn == "dense"
    assert all(s.ffn == "moe" for s in specs[1:])


def test_default_sharding_decode_rules():
    # kv heads not divisible by 16 -> flash-decode over `model`
    s = default_sharding("llama3-8b", SHAPES["decode_32k"])
    assert s.seq_sharded_kv and s.kv_seq_axis == "model"
    # divisible kv heads -> plain kv-head sharding
    s = default_sharding("gemma-7b", SHAPES["decode_32k"])
    assert not s.seq_sharded_kv
    # long context -> cache seq over `data`
    s = default_sharding("jamba-v0.1-52b", SHAPES["long_500k"])
    assert s.seq_sharded_kv and s.kv_seq_axis == "data"


def test_padded_vocab():
    cfg = get_config("internvl2-1b")
    assert cfg.padded_vocab % 256 == 0 and cfg.padded_vocab >= cfg.vocab_size


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_configs_are_small(name):
    cfg = get_smoke_config(name)
    assert cfg.param_count() < 5e7
    assert cfg.family == get_config(name).family
