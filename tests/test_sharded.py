"""Sharded ensemble farm (subprocess: needs forced host devices).

Each test shells out with XLA_FLAGS=--xla_force_host_platform_device_count
so the main pytest process keeps the real 1-device platform (see
conftest.py note). Validation-only Partitioning tests that never touch
a device live in test_api.py.
"""
import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_EXP = """
import numpy as np
from repro.api import (Ensemble, Experiment, Partitioning, Reduction,
                       Schedule, simulate)
from repro.core.cwc.models import lotka_volterra

def make_exp(n_shards, stat_blocks=8, policy="on_demand", **kw):
    kw.setdefault("record_trajectories", True)
    return Experiment(
        model=lotka_volterra(2),
        ensemble=Ensemble.make(replicas=16, sweep={"die": [0.3, 1.2]}),
        schedule=Schedule(t_end=1.0, n_windows=4, schema="iii",
                          policy=policy),
        reduction=Reduction.PER_POINT,
        n_lanes=8, seed=11,
        partitioning=Partitioning(n_shards=n_shards,
                                  stat_blocks=stat_blocks), **kw)
"""


def _run(body: str, devices: int = 8) -> str:
    """Run `_EXP` + the dedented test body in a forced-device child.

    The body MUST be dedented BEFORE prepending `_EXP`: `_EXP` sits at
    the margin, so dedenting the concatenation is a no-op — and the
    still-indented body then parses as unreachable code inside
    `make_exp`'s def. That exact bug made every test here vacuously
    pass (subprocesses finishing in ~1s having executed nothing); the
    sentinel asserts the body really ran to its last line.
    """
    snippet = _EXP + textwrap.dedent(body) + '\nprint("SNIPPET-RAN")\n'
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", snippet],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "SNIPPET-RAN" in out.stdout, (
        "test body did not execute — harness regression")
    return out.stdout


def test_sharded_bit_identical_to_fused_single_device():
    """The acceptance bar: on 8 forced host devices the sharded path
    reproduces the single-device fused path bit-identically — records,
    grouped per-point stats, and trajectories — with one device
    dispatch per window (O(1) in shard count)."""
    _run("""
    base = simulate(make_exp(n_shards=1))
    for K in (2, 4, 8):
        shard = simulate(make_exp(n_shards=K))
        for a, b in zip(base.records, shard.records):
            assert a.t == b.t and a.n == b.n
            assert (a.mean == b.mean).all()
            assert (a.var == b.var).all()
            assert (a.ci90 == b.ci90).all()
        pb, ps = base.per_point(), shard.per_point()
        for k in ("n", "mean", "var", "ci90"):
            assert (pb[k] == ps[k]).all(), (K, k)
        assert (base.trajectories() == shard.trajectories()).all()
        assert shard.telemetry.dispatches == 4  # one per window, any K
    """)


def test_sharded_records_invariant_to_shard_count_without_pinning():
    """stat_blocks defaults to n_shards, so two different shard counts
    only compare bitwise when stat_blocks is pinned — which the default
    does NOT do across meshes. Pinning blocks=4 must equalise K=2/K=4."""
    _run("""
    a = simulate(make_exp(n_shards=2, stat_blocks=4))
    b = simulate(make_exp(n_shards=4, stat_blocks=4))
    for ra, rb in zip(a.records, b.records):
        assert (ra.mean == rb.mean).all() and (ra.var == rb.var).all()
    """, devices=4)


def test_predictive_groups_stay_within_shards():
    """The predictive policy must form cost-homogeneous groups WITHIN
    shard blocks (no cross-shard gathers), and still reproduce the
    on_demand results bitwise (keyed per-lane RNG)."""
    _run("""
    from repro.api.run import build_engine
    pred = make_exp(n_shards=4, policy="predictive")
    eng = build_engine(pred)
    res_p = simulate(pred)
    res_o = simulate(make_exp(n_shards=4, policy="on_demand"))
    for a, b in zip(res_p.records, res_o.records):
        assert (a.mean == b.mean).all()
    # drive a couple of windows so EMA costs are non-trivial, then
    # check every group is contained in one shard block
    eng.run_window(); eng.run_window()
    per = eng.cfg.n_instances // 4
    for g in eng.scheduler.groups():
        shards = set(int(i) // per for i in g)
        assert len(shards) == 1, (g, shards)
    """, devices=4)


def test_sharded_checkpoint_is_mesh_shape_agnostic_artifact():
    """checkpoint() gathers to plain global npz arrays — restorable by
    any mesh — and a same-process 8-shard resume is bit-identical."""
    _run("""
    import tempfile, os
    ck = os.path.join(tempfile.mkdtemp(), "ck")
    clean = simulate(make_exp(n_shards=8))
    part = simulate(make_exp(n_shards=8), max_windows=2,
                    checkpoint_path=ck)
    z = np.load(ck + ".npz")
    assert z["x"].shape[0] == 32  # global pool, not a shard
    resumed = simulate(make_exp(n_shards=8), checkpoint_path=ck,
                       resume=True)
    assert (np.stack([r.mean for r in resumed.records])
            == np.stack([r.mean for r in clean.records])).all()
    """)


def test_sharded_step_rebuilds_when_group_count_changes():
    """Re-calling set_groups with a different group count must rebuild
    the cached sharded step (its jit closes over n_groups)."""
    _run("""
    from repro.api.run import build_engine
    eng = build_engine(make_exp(n_shards=4))
    eng.run_window()
    assert eng.grouped_stats()[-1].mean.shape == (2, 2)
    eng.set_groups(np.arange(32, dtype=np.int32) % 4)
    eng.run_window()
    assert eng.grouped_stats()[-1].mean.shape == (4, 2)
    """, devices=4)


def test_sharded_schema_ii_buffers_global_trajectories():
    """Schema ii on the sharded path gathers per-window samples for
    post-hoc use exactly like the fused path."""
    _run("""
    a = simulate(make_exp(n_shards=8).with_(
        schedule=Schedule(t_end=1.0, n_windows=3, schema="ii")))
    b = simulate(make_exp(n_shards=1).with_(
        schedule=Schedule(t_end=1.0, n_windows=3, schema="ii")))
    ta, tb = a.trajectories(), b.trajectories()
    assert ta.shape == (32, 3, 2)
    assert (ta == tb).all()
    """)


def test_kernel_composes_with_sharded_dispatch():
    """The Pallas fused kernel under the sharded strategy (the paper's
    two families composed): on 8 forced host devices, per-shard kernel
    windows reproduce the single-device kernel run — and the UNFUSED
    jnp path — bit-identically for 1/2/4/8 shards (counter-based
    per-lane RNG; stat_blocks pinned), one device dispatch per window."""
    _run("""
    base = simulate(make_exp(n_shards=1, use_kernel=True))
    plain = simulate(make_exp(n_shards=1))
    assert (np.stack([r.mean for r in base.records])
            == np.stack([r.mean for r in plain.records])).all()
    assert (base.trajectories() == plain.trajectories()).all()
    for K in (2, 4, 8):
        shard = simulate(make_exp(n_shards=K, use_kernel=True))
        for a, b in zip(base.records, shard.records):
            assert a.t == b.t and a.n == b.n
            assert (a.mean == b.mean).all()
            assert (a.var == b.var).all()
            assert (a.ci90 == b.ci90).all()
        pb, ps = base.per_point(), shard.per_point()
        for k in ("n", "mean", "var", "ci90"):
            assert (pb[k] == ps[k]).all(), (K, k)
        assert (base.trajectories() == shard.trajectories()).all()
        assert shard.telemetry.dispatches == 4  # one per window, any K
    """)


def test_tau_leap_composes_with_sharded_dispatch():
    """Method.TAU_LEAP under the sharded strategy: records, grouped
    per-point stats, and trajectories bit-identical across 1/2/4/8
    shards AND across the jnp/kernel window bodies (the same
    `tau_step_core` runs per shard under shard_map), one dispatch per
    window — the full exact-SSA invariant matrix, on the second
    algorithm."""
    _run("""
    base = simulate(make_exp(n_shards=1, method="tau_leap"))
    assert sum(base.telemetry.leaps_per_window) > 0  # it actually leaps
    kern1 = simulate(make_exp(n_shards=1, method="tau_leap",
                              use_kernel=True))
    assert (np.stack([r.mean for r in base.records])
            == np.stack([r.mean for r in kern1.records])).all()
    assert (base.trajectories() == kern1.trajectories()).all()
    for K in (2, 4, 8):
        for kernel in (False, True):
            shard = simulate(make_exp(n_shards=K, method="tau_leap",
                                      use_kernel=kernel))
            for a, b in zip(base.records, shard.records):
                assert a.t == b.t and a.n == b.n
                assert (a.mean == b.mean).all()
                assert (a.var == b.var).all()
                assert (a.ci90 == b.ci90).all()
            pb, ps = base.per_point(), shard.per_point()
            for k in ("n", "mean", "var", "ci90"):
                assert (pb[k] == ps[k]).all(), (K, kernel, k)
            assert (base.trajectories() == shard.trajectories()).all()
            assert shard.telemetry.dispatches == 4  # one per window
            assert (shard.telemetry.leaps_per_window
                    == base.telemetry.leaps_per_window)
    """)


def test_supersteps_compose_with_sharded_dispatch():
    """window_block under the sharded strategy: the per-shard window
    body (jnp or Pallas kernel) scans W windows inside ONE shard_map'd
    dispatch, per-window psum-gathered stat stacks ride the record
    ring, and records/grouped stats/trajectories stay bit-identical to
    the per-window single-device baseline for 2/4/8 shards ×
    window_block ∈ {2, 4} × both window bodies — at 4 windows and
    window_block=4 the whole run is ONE dispatch and ONE blocking
    pull."""
    _run("""
    base = simulate(make_exp(n_shards=1))
    for K in (2, 4, 8):
        for wb in (2, 4):
            for kernel in (False, True):
                shard = simulate(make_exp(n_shards=K, window_block=wb,
                                          use_kernel=kernel))
                for a, b in zip(base.records, shard.records):
                    assert a.t == b.t and a.n == b.n
                    assert (a.mean == b.mean).all()
                    assert (a.var == b.var).all()
                    assert (a.ci90 == b.ci90).all()
                pb, ps = base.per_point(), shard.per_point()
                for k in ("n", "mean", "var", "ci90"):
                    assert (pb[k] == ps[k]).all(), (K, wb, k)
                assert (base.trajectories()
                        == shard.trajectories()).all()
                tele = shard.telemetry
                assert tele.dispatches == -(-4 // wb), (K, wb)
                assert tele.host_syncs == -(-4 // wb), (K, wb)
    """)


def test_depth_k_pipeline_composes_with_sharded_dispatch():
    """PR9: pipeline_depth on the sharded path — K ∈ {1, 2, 4} blocks
    in flight, including the predictive policy's in-scan device cost
    carry (shard-local argsort, no host round trips) — stays bitwise
    with the single-device per-window baseline; the depth only moves
    the collect point, never the dispatched work."""
    _run("""
    base = simulate(make_exp(n_shards=1))
    pred_base = simulate(make_exp(n_shards=1, policy="predictive"))
    for a, b in zip(base.records, pred_base.records):
        assert (a.mean == b.mean).all()
    for depth in (1, 2, 4):
        for policy in ("on_demand", "predictive"):
            shard = simulate(make_exp(n_shards=4, window_block=2,
                                      pipeline_depth=depth,
                                      policy=policy))
            for a, b in zip(base.records, shard.records):
                assert a.t == b.t and a.n == b.n
                assert (a.mean == b.mean).all()
                assert (a.var == b.var).all()
                assert (a.ci90 == b.ci90).all()
            pb, ps = base.per_point(), shard.per_point()
            for k in ("n", "mean", "var", "ci90"):
                assert (pb[k] == ps[k]).all(), (depth, policy, k)
            assert (base.trajectories() == shard.trajectories()).all()
            tele = shard.telemetry
            assert tele.pipeline_depth == depth
            assert tele.dispatches == 2 and tele.host_syncs == 2
    """, devices=4)


def test_superstep_checkpoint_resumes_on_sharded_path():
    """A block-boundary checkpoint from a sharded superstep run is the
    same mesh-shape-agnostic artifact: resume on a different shard
    count and window_block, bitwise."""
    _run("""
    import tempfile, os
    ck = os.path.join(tempfile.mkdtemp(), "ck")
    clean = simulate(make_exp(n_shards=1))
    simulate(make_exp(n_shards=8, window_block=2), max_windows=2,
             checkpoint_path=ck)
    z = np.load(ck + ".npz")
    assert int(z["window"]) == 2
    resumed = simulate(make_exp(n_shards=4, window_block=2),
                       checkpoint_path=ck, resume=True)
    assert (np.stack([r.mean for r in resumed.records])
            == np.stack([r.mean for r in clean.records])).all()
    """)


def test_kernel_truncation_raises_under_sharded_dispatch():
    """A chunk-budget overrun on ANY shard surfaces (psum'd flag) —
    never a silent partial window."""
    _run("""
    import warnings
    from repro.core.dispatch import Partitioning
    from repro.core.engine import SimConfig, SimulationEngine
    from repro.kernels.ops import FusedWindowTruncated

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        eng = SimulationEngine(
            lotka_volterra(2),
            SimConfig(n_instances=32, t_end=2.0, n_windows=2, n_lanes=8,
                      schema="iii", seed=5, use_kernel=True,
                      kernel_chunk_steps=1, kernel_max_chunks=1),
            partitioning=Partitioning(n_shards=4, stat_blocks=8))
    try:
        eng.run_window()
        raise AssertionError("expected FusedWindowTruncated")
    except FusedWindowTruncated:
        pass
    """, devices=4)
