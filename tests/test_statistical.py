"""Statistical validation: exact SSA and tau-leap ensemble moments
pinned to ANALYTIC ground truth (birth-death: Poisson transient;
dimerization: the exact chemical master equation integrated on its
finite state ladder), plus an SSA-vs-tau-leap distribution-agreement
check. All runs are seeded — the asserted bounds are deterministic,
sized off calibrated z-scores with >= 1.6x headroom, so they are
CI-safe while still catching real moment drift (a broken Poisson
sampler, mis-scaled tau, or biased fallback shifts z by far more).
"""
import numpy as np
import pytest

from repro.api import Ensemble, Experiment, Method, Schedule, simulate
from repro.core.reactions import make_system

N_LANES_BD = 512
N_LANES_DIM = 256


def _run(system, method, replicas, t_end, windows, seed=11, **kw):
    return simulate(Experiment(
        model=system,
        ensemble=Ensemble.make(replicas=replicas),
        schedule=Schedule(t_end=t_end, n_windows=windows),
        n_lanes=64, seed=seed, method=method, **kw))


# ------------------------------------------------------ birth-death
# X(0)=0, birth rate lam, per-capita death mu: X(t) ~ Poisson(m(t)),
# m(t) = lam/mu (1 - e^{-mu t}) — mean AND variance analytic at every
# grid point.
LAM, MU = 400.0, 1.0


def _birth_death():
    return make_system(
        ["A"], [({}, {"A": 1}, LAM), ({"A": 1}, {}, MU)], {"A": 0})


@pytest.mark.parametrize("method", [Method.EXACT, Method.TAU_LEAP])
def test_birth_death_moments_match_poisson_transient(method):
    res = _run(_birth_death(), method, N_LANES_BD, 2.0, 4)
    n = N_LANES_BD
    for rec in res.records:
        m = LAM / MU * (1 - np.exp(-MU * rec.t))
        z_mean = (rec.mean[0] - m) / np.sqrt(m / n)
        # Poisson: Var = mean; sd of the sample variance ~ m sqrt(2/n)
        z_var = (rec.var[0] - m) / (m * np.sqrt(2.0 / (n - 1)))
        assert abs(z_mean) < 4.0, (method, rec.t, rec.mean[0], m, z_mean)
        assert abs(z_var) < 4.0, (method, rec.t, rec.var[0], m, z_var)
    if method is Method.TAU_LEAP:
        assert sum(res.telemetry.leaps_per_window) > 0, (
            "tau-leap never leaped — the validation would only have "
            "re-tested the exact fallback")


# ------------------------------------------------------ dimerization
# 2A -> B from A(0)=N: the CME lives on the finite ladder
# k = dimerizations fired, a_k = c C(N-2k, 2) — integrate it exactly
# (RK4 well inside its stability bound) for ground-truth moments.
DIM_N, DIM_C = 8000, 3e-5


def _dimerization():
    return make_system(["A", "B"], [({"A": 2}, {"B": 1}, DIM_C)],
                       {"A": DIM_N, "B": 0})


def _cme_moments(t_end: float, steps: int = 3000):
    kmax = DIM_N // 2
    x = DIM_N - 2 * np.arange(kmax + 1)
    ak = np.maximum(DIM_C * x * (x - 1) / 2.0, 0.0)
    p = np.zeros(kmax + 1)
    p[0] = 1.0
    h = t_end / steps  # |ak h| << 2.78: RK4 is stable and ~exact here

    def deriv(p):
        d = -ak * p
        d[1:] += ak[:-1] * p[:-1]
        return d

    for _ in range(steps):
        k1 = deriv(p)
        k2 = deriv(p + h / 2 * k1)
        k3 = deriv(p + h / 2 * k2)
        k4 = deriv(p + h * k3)
        p = p + h / 6 * (k1 + 2 * k2 + 2 * k3 + k4)
    mean = (p * x).sum()
    return mean, (p * x * x).sum() - mean * mean


@pytest.mark.parametrize("method", [Method.EXACT, Method.TAU_LEAP])
def test_dimerization_moments_match_master_equation(method):
    res = _run(_dimerization(), method, N_LANES_DIM, 1.0, 2,
               tau_eps=0.02)
    n = N_LANES_DIM
    for rec in res.records:
        am, av = _cme_moments(rec.t)
        z_mean = (rec.mean[0] - am) / np.sqrt(av / n)
        assert abs(z_mean) < 4.0, (method, rec.t, rec.mean[0], am, z_mean)
        # explicit tau-leaping inflates the variance by O(tau) — the
        # calibrated inflation here is <= 1.21x (exact: 0.98-1.04x);
        # a mis-sized leap or broken Poisson blows far past 1.4x
        assert 0.7 < rec.var[0] / av < 1.4, (method, rec.t, rec.var[0],
                                             av)
    if method is Method.TAU_LEAP:
        tele = res.telemetry
        assert sum(tele.leaps_per_window) > 0
        # the conserved quantity survives every leap exactly
        x = res.final_state()
        assert (x[:, 0] + 2 * x[:, 1] == DIM_N).all()


def test_dimerization_tau_leap_is_much_cheaper_than_exact():
    ex = _run(_dimerization(), Method.EXACT, N_LANES_DIM, 1.0, 2)
    tl = _run(_dimerization(), Method.TAU_LEAP, N_LANES_DIM, 1.0, 2)
    s_ex = sum(ex.telemetry.steps_per_window)
    s_tl = sum(tl.telemetry.steps_per_window)
    assert s_tl * 5 <= s_ex, (s_ex, s_tl)


# --------------------------------------- SSA vs tau-leap agreement
def test_ssa_vs_tau_leap_distribution_agreement():
    """Beyond matched moments: the SSA and tau-leap ensembles at the
    birth-death endpoint must agree as DISTRIBUTIONS — two-sample
    z-test on the mean, variance ratio, and total-variation distance
    between common-binned histograms."""
    ex = _run(_birth_death(), Method.EXACT, N_LANES_BD, 2.0, 2)
    tl = _run(_birth_death(), Method.TAU_LEAP, N_LANES_BD, 2.0, 2,
              seed=12)  # independent streams: a genuine two-sample test
    a = ex.final_state()[:, 0]
    b = tl.final_state()[:, 0]
    n = N_LANES_BD
    z = (a.mean() - b.mean()) / np.sqrt(a.var() / n + b.var() / n)
    assert abs(z) < 4.0, (a.mean(), b.mean(), z)
    assert 0.75 < a.var() / b.var() < 1.33, (a.var(), b.var())
    lo, hi = min(a.min(), b.min()), max(a.max(), b.max())
    bins = np.linspace(lo, hi + 1e-6, 9)
    pa, _ = np.histogram(a, bins=bins)
    pb, _ = np.histogram(b, bins=bins)
    tv = 0.5 * np.abs(pa / n - pb / n).sum()
    assert tv < 0.15, tv
