"""SimulationEngine: schema equivalence, memory bound, fault drills."""
import numpy as np
import pytest

from repro.core.cwc.models import ecoli_gene_regulation, lotka_volterra
from repro.core.engine import SimConfig, SimulationEngine
from repro.core.sweep import SweepSpec, sweep_rates
from repro.runtime.fault import FailurePlan, run_sim_with_failures


def _means(recs):
    return np.stack([r.mean for r in recs])


def test_schema_equivalence_bitwise():
    """Same seeds + same grid => identical reduced trajectories across
    schemas (per-lane keyed RNG makes scheduling invisible)."""
    outs = {}
    for schema in ("i", "ii", "iii"):
        cfg = SimConfig(n_instances=24, t_end=1.0, n_windows=4, n_lanes=8,
                        schema=schema, seed=13)
        eng = SimulationEngine(lotka_volterra(2), cfg)
        outs[schema] = _means(eng.run())
    assert (outs["i"] == outs["iii"]).all()
    assert (outs["ii"] == outs["iii"]).all()


def test_schema_iii_memory_bounded():
    per = {}
    for schema in ("ii", "iii"):
        cfg = SimConfig(n_instances=64, t_end=1.0, n_windows=16, n_lanes=64,
                        schema=schema, seed=3)
        eng = SimulationEngine(lotka_volterra(2), cfg)
        eng.run()
        per[schema] = eng.peak_buffered_bytes
    # schema ii buffers all windows; iii only the running one
    assert per["iii"] * 8 <= per["ii"]


def test_predictive_policy_same_results():
    base = None
    for policy in ("on_demand", "predictive"):
        cfg = SimConfig(n_instances=32, t_end=1.0, n_windows=5, n_lanes=8,
                        schema="iii", policy=policy, seed=5)
        eng = SimulationEngine(lotka_volterra(2), cfg)
        m = _means(eng.run())
        if base is None:
            base = m
        else:
            assert (m == base).all()


def test_parameter_sweep_rates_and_separation():
    model = lotka_volterra(2)
    from repro.core.cwc.compile import compile_model

    system, _ = compile_model(model)
    spec = SweepSpec.make({"die": [0.1, 2.0]}, replicas=8)
    rates = sweep_rates(system, spec)
    assert rates.shape == (16, system.n_reactions)
    from repro.core.sweep import _matching_reactions

    (j,) = _matching_reactions(system, "die")
    assert (rates[:8, j] == 0.1).all() and (rates[8:, j] == 2.0).all()

    cfg = SimConfig(n_instances=16, t_end=2.0, n_windows=4, n_lanes=16,
                    schema="iii", seed=9)
    eng = SimulationEngine(model, cfg, rates=rates)
    eng.run()
    x = np.asarray(eng._pool.x)
    # higher predator death rate -> fewer predators on average
    assert x[8:, 1].mean() < x[:8, 1].mean()


def test_crash_restore_bitwise(tmp_path):
    plan = FailurePlan(schedule={2: "crash", 4: "crash"})
    make = lambda: SimulationEngine(
        ecoli_gene_regulation(),
        SimConfig(n_instances=16, t_end=4.0, n_windows=6, n_lanes=16,
                  schema="iii", seed=21))
    with_fail, events = run_sim_with_failures(
        make, str(tmp_path / "drill.npz"), plan)
    clean = make().run()
    assert len(events) == 2
    assert (_means(with_fail) == _means(clean)).all()


def test_fused_kernel_engine_bitwise():
    """Engine with the Pallas fused window vs the unfused path: the
    counter-based (key, ctr) stream makes EVERY window bitwise equal
    (pre-PR only the first window was; later windows merely agreed in
    distribution), and a window is ONE device dispatch with no
    mid-window host pulls."""
    cfgk = SimConfig(n_instances=256, t_end=1.0, n_windows=2, n_lanes=256,
                     schema="iii", seed=17, use_kernel=True)
    cfgj = SimConfig(n_instances=256, t_end=1.0, n_windows=2, n_lanes=256,
                     schema="iii", seed=17, use_kernel=False)
    mk = SimulationEngine(lotka_volterra(2), cfgk)
    mj = SimulationEngine(lotka_volterra(2), cfgj)
    rk, rj = mk.run(), mj.run()
    for wk, wj in zip(rk, rj):
        assert (wk.mean == wj.mean).all()
        assert (wk.var == wj.var).all()
        assert (wk.ci90 == wj.ci90).all()
    assert mk.n_dispatches == cfgk.n_windows  # one launch per window
