"""Shared fixtures. NOTE: no XLA_FLAGS here on purpose — smoke tests and
benches must see the real (1-device) platform; only launch/dryrun.py
forces 512 placeholder devices. Multi-device tests run in subprocesses
(see tests/test_distributed.py)."""
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
