"""Shared fixtures. NOTE: no XLA_FLAGS here on purpose — smoke tests and
benches must see the real (1-device) platform. Multi-device tests run
in subprocesses that force their own host-device counts (see
tests/test_distributed.py and tests/test_sharded.py)."""
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
