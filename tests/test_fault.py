"""Fault drills for the self-healing supervisor (DESIGN.md §3h).

The recovery contract under test: any injected fault sequence —
crashes, corrupt checkpoints, device loss, stalls, NaN-poisoned pools
— produces records, sketches, and steering decision logs BITWISE
identical to the uninterrupted run. Trajectories are a pure function
of (seed, counter-RNG state), and checkpoints carry the full pool +
RNG counters + emitted records, so recovery replays rather than
approximates.

Sharded drills (device loss → elastic degradation) shell out with
forced host devices, mirroring tests/test_sharded.py's harness.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.api import (
    Ensemble,
    Experiment,
    ExperimentError,
    Method,
    Recovery,
    Reduction,
    Schedule,
    SketchSpec,
    Steering,
    simulate,
)
from repro.ckpt import store
from repro.core.cwc.models import lotka_volterra
from repro.runtime.fault import (
    FAULT_KINDS,
    EngineCrash,
    FailureInjector,
    FailurePlan,
    InvariantViolation,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
N_WINDOWS = 8


def make_exp(**kw):
    kw.setdefault("record_trajectories", True)
    return Experiment(
        model=lotka_volterra(2),
        ensemble=Ensemble.make(replicas=16),
        schedule=Schedule(t_end=1.0, n_windows=N_WINDOWS, schema="iii"),
        reduction=Reduction.ENSEMBLE,
        n_lanes=8, seed=7, **kw)


def recovery(tmp_path, schedule, **kw):
    kw.setdefault("cadence", 2)
    kw.setdefault("keep_last", 2)
    return Recovery(ckpt_dir=str(tmp_path / "rec"),
                    inject=FailurePlan(schedule=schedule), **kw)


def assert_bitwise(a, b, ctx=""):
    assert len(a.records) == len(b.records), ctx
    for ra, rb in zip(a.records, b.records):
        assert ra.t == rb.t and ra.window == rb.window and ra.n == rb.n, ctx
        assert (ra.mean == rb.mean).all(), ctx
        assert (ra.var == rb.var).all(), ctx
        assert (ra.ci90 == rb.ci90).all(), ctx


# ----------------------------------------------------------- the bar
@pytest.mark.parametrize("use_kernel", [False, True])
@pytest.mark.parametrize("method", [Method.EXACT, Method.TAU_LEAP])
@pytest.mark.parametrize("window_block", [1, 4])
def test_drill_matrix_records_bitwise(tmp_path, use_kernel, method,
                                      window_block):
    """Crash + corrupt-newest-checkpoint drills across the execution
    matrix: records identical to the uninterrupted run, bit for bit."""
    base = simulate(make_exp(use_kernel=use_kernel, method=method,
                             window_block=window_block))
    got = simulate(make_exp(
        use_kernel=use_kernel, method=method, window_block=window_block,
        recovery=recovery(tmp_path, {2: "crash", 5: "ckpt_corrupt"})))
    assert_bitwise(base, got, ctx=(use_kernel, method, window_block))
    rep = got.recovery_report()
    assert rep["restarts"] == 2
    assert (base.trajectories() == got.trajectories()).all()


def test_drill_sparse_engine_bitwise(tmp_path):
    base = simulate(make_exp(sparse=True))
    got = simulate(make_exp(
        sparse=True, recovery=recovery(tmp_path, {3: "crash"})))
    assert_bitwise(base, got)


def test_drill_sketches_bitwise(tmp_path):
    sk = SketchSpec(n_bins=8, lo=0.0, hi=600.0)
    base = simulate(make_exp(window_block=4, sketch=sk))
    got = simulate(make_exp(
        window_block=4, sketch=sk,
        recovery=recovery(tmp_path, {2: "crash", 5: "stall"})))
    assert_bitwise(base, got)
    for sa, sb in zip(base.sketches(), got.sketches()):
        assert (sa.hist == sb.hist).all()


def test_drill_steering_decision_log_bitwise(tmp_path):
    st = Steering(ci_rel_tol=0.03, min_windows=4)
    base = simulate(make_exp(steering=st))
    got = simulate(make_exp(
        steering=st, recovery=recovery(tmp_path, {3: "crash", 6: "crash"})))
    assert_bitwise(base, got)
    assert base.steering_report()["decisions"] \
        == got.steering_report()["decisions"]


def test_nan_pool_caught_by_engine_guard_and_recovered(tmp_path):
    """The injector poisons the pool without raising; the engine's own
    invariant guard must turn it into a typed recoverable fault."""
    base = simulate(make_exp())
    got = simulate(make_exp(
        recovery=recovery(tmp_path, {4: "nan_pool"})))
    assert_bitwise(base, got)
    rep = got.recovery_report()
    assert rep["faults_by_kind"].get("nan_pool", 0) >= 1


# ------------------------------------------------- checkpoint hygiene
def test_retention_keeps_last_k(tmp_path):
    res = simulate(make_exp(recovery=recovery(tmp_path, {}, cadence=1,
                                              keep_last=3)))
    cks = store.list_checkpoints(str(tmp_path / "rec"))
    assert len(cks) == 3
    assert [w for w, _ in cks] == [6, 7, 8]
    assert res.recovery_report()["restarts"] == 0


def test_fallback_past_corrupt_checkpoint(tmp_path):
    """ckpt_corrupt garbles the NEWEST snapshot then crashes; recovery
    must fall back to the older one and still replay bitwise."""
    base = simulate(make_exp())
    got = simulate(make_exp(recovery=recovery(tmp_path,
                                              {5: "ckpt_corrupt"})))
    assert_bitwise(base, got)
    skipped = [e for e in got.recovery_report()["events"]
               if e["event"] == "corrupt_checkpoint_skipped"]
    assert skipped, "expected the corrupt newest checkpoint to be skipped"


def test_verify_rejects_truncated_and_garbage(tmp_path):
    p = str(tmp_path / "c.npz")
    store.save_atomic(p, {"x": np.arange(4.0)})
    store.verify(p, required=("x",))  # round-trips clean
    with open(p, "r+b") as f:
        f.truncate(os.path.getsize(p) // 2)
    with pytest.raises(store.CheckpointCorrupt) as ei:
        store.verify(p)
    assert p in str(ei.value)
    g = str(tmp_path / "g.npz")
    with open(g, "wb") as f:
        f.write(b"not a zipfile at all")
    with pytest.raises(store.CheckpointCorrupt, match="unreadable"):
        store.verify(g)


def test_verify_rejects_bitflip_and_missing_key(tmp_path):
    p = str(tmp_path / "c.npz")
    store.save_atomic(p, {"x": np.zeros(64, np.float32)})
    with pytest.raises(store.CheckpointCorrupt, match="missing"):
        store.verify(p, required=("x", "nope"))
    size = os.path.getsize(p)
    with open(p, "r+b") as f:
        f.seek(size // 2)
        f.write(b"\xff\xff")
    with pytest.raises(store.CheckpointCorrupt):
        store.verify(p)


def test_save_atomic_leaves_no_tmp_and_is_loadable(tmp_path):
    p = str(tmp_path / "c.npz")
    store.save_atomic(p, {"a": np.arange(3), "b": np.eye(2)})
    assert os.listdir(tmp_path) == ["c.npz"]
    z = store.verify(p, required=("a", "b"))
    assert (z["a"] == np.arange(3)).all()


# ----------------------------------------------------- typed surface
def test_failure_plan_materialize_deterministic():
    p = FailurePlan(schedule={2: "crash"}, seed=3, random_rate=0.5,
                    random_kind="stall")
    a, b = p.materialize(20), p.materialize(20)
    assert a == b
    assert a[2] == "crash"  # explicit entries win over random draws
    assert any(k == "stall" for w, k in a.items() if w != 2)
    assert p.materialize(20) != FailurePlan(
        schedule={2: "crash"}, seed=4, random_rate=0.5,
        random_kind="stall").materialize(20)


def test_failure_plan_validates_kinds():
    with pytest.raises(ValueError):
        FailurePlan(schedule={1: "meteor"})
    with pytest.raises(ValueError):
        FailurePlan(random_rate=2.0)
    for k in FAULT_KINDS:
        FailurePlan(schedule={0: k})  # all documented kinds accepted


def test_injector_is_one_shot_per_window():
    inj = FailureInjector(FailurePlan(schedule={3: "crash"}))
    assert inj.maybe_fail(3) == "crash"
    assert inj.maybe_fail(3) is None  # replay after restart: no refire


def test_stall_does_not_consume_restart_budget(tmp_path):
    """PR9 satellite: a stalled window is re-dispatched in place — it
    must not count as a restart, trigger backoff, or eat into
    max_restarts. With max_restarts=0 a stall-only plan still
    completes bitwise."""
    base = simulate(make_exp())
    got = simulate(make_exp(
        recovery=recovery(tmp_path, {2: "stall"}, max_restarts=0)))
    assert_bitwise(base, got)
    rep = got.recovery_report()
    assert rep["restarts"] == 0
    assert rep["stall_redispatches"] == 1
    assert got.telemetry.stall_redispatches == 1
    stall_events = [e for e in rep["events"]
                    if e["event"] == "fault" and e["kind"] == "stall"]
    assert len(stall_events) == 1
    assert stall_events[0]["stall_redispatch"] == 1


def test_max_restarts_declares_run_dead(tmp_path):
    plan = {w: "crash" for w in range(N_WINDOWS)}
    with pytest.raises(RuntimeError, match="declared dead"):
        simulate(make_exp(recovery=recovery(tmp_path, plan,
                                            max_restarts=2)))


def test_recovery_rejects_conflicting_simulate_args(tmp_path):
    exp = make_exp(recovery=Recovery(ckpt_dir=str(tmp_path / "rec")))
    with pytest.raises(ExperimentError):
        simulate(exp, max_windows=2)


def test_engine_guard_raises_typed_invariant(tmp_path):
    """Direct guard drill: NaN-poison the pool mid-run and step — the
    engine raises InvariantViolation naming the check; with
    SimConfig.guards off the same poison sails through."""
    import dataclasses

    from repro.api.run import build_engine
    from repro.runtime.supervisor import RunSupervisor

    eng = build_engine(make_exp())
    eng.run_window()
    sup_exp = make_exp(recovery=Recovery(ckpt_dir=str(tmp_path / "x")))
    RunSupervisor(sup_exp, sup_exp.recovery)._poison_pool(eng)
    with pytest.raises(InvariantViolation, match="non_finite_stats"):
        eng.run_window()
    eng2 = build_engine(make_exp())
    eng2.cfg = dataclasses.replace(eng2.cfg, guards=False)
    eng2.run_window()
    RunSupervisor(sup_exp, sup_exp.recovery)._poison_pool(eng2)
    eng2.run_window()  # no guard, no raise


def test_recoverable_errors_are_typed():
    e = EngineCrash("boom", window=5)
    assert e.kind == "crash" and e.window == 5
    assert isinstance(e, Exception)


# --------------------------------------------------- sharded drills
_EXP = """
import numpy as np
from repro.api import (Ensemble, Experiment, FailurePlan, Partitioning,
                       Recovery, Reduction, Schedule, simulate)
from repro.core.cwc.models import lotka_volterra

def make_exp(**kw):
    kw.setdefault("record_trajectories", True)
    return Experiment(
        model=lotka_volterra(2),
        ensemble=Ensemble.make(replicas=16),
        schedule=Schedule(t_end=1.0, n_windows=8, schema="iii"),
        reduction=Reduction.ENSEMBLE,
        n_lanes=8, seed=7, **kw)

def assert_bitwise(a, b):
    assert len(a.records) == len(b.records)
    for ra, rb in zip(a.records, b.records):
        assert (ra.mean == rb.mean).all() and (ra.var == rb.var).all()
        assert (ra.ci90 == rb.ci90).all()
"""


def _run(body: str, devices: int = 4) -> str:
    snippet = _EXP + textwrap.dedent(body) + '\nprint("SNIPPET-RAN")\n'
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", snippet],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "SNIPPET-RAN" in out.stdout, (
        "test body did not execute — harness regression")
    return out.stdout


def test_shard_loss_degrades_and_stays_bitwise(tmp_path):
    """Two device-loss faults on a 4-shard farm: the supervisor
    degrades 4 → 2 → 1 shards (stat_blocks pinned) and the final
    records match the clean 4-shard run bitwise."""
    out = _run(f"""
    part = Partitioning(n_shards=4, stat_blocks=4)
    base = simulate(make_exp(partitioning=part))
    rec = Recovery(ckpt_dir={str(tmp_path / 'rec')!r}, cadence=2,
                   inject=FailurePlan(schedule={{3: "device_lost",
                                                 6: "device_lost"}}))
    got = simulate(make_exp(partitioning=part, recovery=rec))
    assert_bitwise(base, got)
    rep = got.recovery_report()
    assert rep["restarts"] == 2
    assert rep["final_n_shards"] == 1
    shrinks = [(e["from_shards"], e["to_shards"])
               for e in rep["events"] if e["event"] == "degraded"]
    assert shrinks == [(4, 2), (2, 1)]
    print("DEGRADE-OK")
    """)
    assert "DEGRADE-OK" in out


def test_sharded_crash_drill_with_window_block(tmp_path):
    out = _run(f"""
    part = Partitioning(n_shards=4, stat_blocks=4)
    base = simulate(make_exp(partitioning=part, window_block=4))
    rec = Recovery(ckpt_dir={str(tmp_path / 'rec')!r}, cadence=4,
                   inject=FailurePlan(schedule={{5: "crash"}}))
    got = simulate(make_exp(partitioning=part, window_block=4,
                            recovery=rec))
    assert_bitwise(base, got)
    assert got.recovery_report()["restarts"] == 1
    print("WB-CRASH-OK")
    """)
    assert "WB-CRASH-OK" in out
