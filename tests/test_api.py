"""repro.api: spec validation, old-API shim equivalence, grouped
per-sweep-point reduction, checkpoint/resume through SimulationResult,
and the sink close() lifecycle."""
import warnings

import numpy as np
import pytest

from repro.api import (
    CsvSink,
    Ensemble,
    Experiment,
    ExperimentError,
    Partitioning,
    Policy,
    Reduction,
    Schedule,
    Schema,
    simulate,
)
from repro.core.cwc.models import lotka_volterra
from repro.core.engine import SimConfig, SimulationEngine


def _exp(schema="iii", replicas=24, windows=4, seed=13, **kw):
    return Experiment(
        model=lotka_volterra(2),
        ensemble=Ensemble.make(replicas=replicas),
        schedule=Schedule(t_end=1.0, n_windows=windows, schema=schema),
        n_lanes=8, seed=seed, **kw)


def _old_engine(schema, replicas=24, windows=4, seed=13, **kw):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return SimulationEngine(
            lotka_volterra(2),
            SimConfig(n_instances=replicas, t_end=1.0, n_windows=windows,
                      n_lanes=8, schema=schema, seed=seed, **kw))


# ---------------------------------------------------------- validation
def test_validation_errors_name_the_field():
    good = _exp()
    with pytest.raises(ExperimentError, match="t_end"):
        simulate(good.with_(schedule=Schedule(t_end=0.0, n_windows=4)))
    with pytest.raises(ExperimentError, match="n_windows"):
        simulate(good.with_(schedule=Schedule(t_end=1.0, n_windows=0)))
    with pytest.raises(ExperimentError, match="replicas"):
        simulate(good.with_(ensemble=Ensemble.make(replicas=0)))
    with pytest.raises(ExperimentError, match="n_lanes"):
        simulate(good.with_(n_lanes=0))
    with pytest.raises(ExperimentError, match="PREDICTIVE"):
        simulate(good.with_(schedule=Schedule(
            t_end=1.0, n_windows=4, schema=Schema.STATIC_FARM,
            policy=Policy.PREDICTIVE)))
    with pytest.raises(ExperimentError, match="Reduction"):
        simulate(good.with_(reduction="per_point"))
    with pytest.raises(ExperimentError, match="Ensemble"):
        simulate(good.with_(ensemble=None))


def test_schema_policy_coercion_and_unknown_strings():
    assert Schema.coerce("iii") is Schema.ONLINE
    assert Schema.coerce("STATIC_FARM") is Schema.STATIC_FARM
    assert Policy.coerce("on_demand") is Policy.ON_DEMAND
    with pytest.raises(ExperimentError, match="unknown schema"):
        Schema.coerce("iv")
    with pytest.raises(ExperimentError, match="unknown policy"):
        Policy.coerce("greedy")
    # Schedule coerces strings at construction
    assert Schedule(t_end=1.0, n_windows=2, schema="ii",
                    policy="predictive").schema is Schema.TIME_SLICED


def test_sweep_unknown_rate_name_is_an_experiment_error():
    exp = _exp().with_(ensemble=Ensemble.make(
        replicas=4, sweep={"not_a_reaction": [1.0, 2.0]}))
    with pytest.raises(ExperimentError, match="not_a_reaction"):
        simulate(exp)


def test_partitioning_validation():
    """Partitioning is pure data; its constraints surface as
    ExperimentError before any device is touched."""
    good = _exp()  # 24 instances
    with pytest.raises(ExperimentError, match="n_shards"):
        simulate(good.with_(partitioning=Partitioning(n_shards=0)))
    with pytest.raises(ExperimentError, match="divide evenly"):
        simulate(good.with_(partitioning=Partitioning(n_shards=5)))
    with pytest.raises(ExperimentError, match="stat_blocks"):
        simulate(good.with_(
            partitioning=Partitioning(n_shards=2, stat_blocks=3)))
    with pytest.raises(ExperimentError, match="multiple"):
        simulate(good.with_(
            partitioning=Partitioning(n_shards=4, stat_blocks=2)))
    with pytest.raises(ExperimentError, match="Partitioning"):
        simulate(good.with_(partitioning="data"))
    with pytest.raises(ExperimentError, match="host_loop"):
        simulate(good.with_(partitioning=Partitioning(n_shards=2),
                            host_loop=True))
    # more shards than visible devices: named, actionable error
    with pytest.raises(ExperimentError, match="device"):
        simulate(good.with_(partitioning=Partitioning(n_shards=24)))
    # n_shards=1 is a plain fused run and needs no extra devices
    assert Partitioning(n_shards=2).blocks == 2
    assert Partitioning(n_shards=2, stat_blocks=8).blocks == 8
    res = simulate(good.with_(partitioning=Partitioning(n_shards=1)))
    assert res.completed


def test_stat_blocks_changes_merge_tree_but_stays_close():
    """Records are a function of stat_blocks (the pinned merge tree):
    blocks=1 reproduces the historical records bitwise; blocks=8 agrees
    to float tolerance (same Welford algebra, different fold order)."""
    legacy = simulate(_exp())
    blocked = simulate(_exp().with_(
        partitioning=Partitioning(n_shards=1, stat_blocks=8)))
    assert (legacy.means() == simulate(_exp()).means()).all()
    np.testing.assert_allclose(blocked.means(), legacy.means(),
                               rtol=1e-5, atol=1e-5)
    var_l = np.stack([r.var for r in legacy.records])
    var_b = np.stack([r.var for r in blocked.records])
    # variance merges via s2 - n*mean^2, which cancels in float32 when
    # mean >> std — hence the looser bound than the means get
    np.testing.assert_allclose(var_b, var_l, rtol=5e-3, atol=1e-3)


# --------------------------------------------------- shim equivalence
@pytest.mark.parametrize("schema", ["i", "ii", "iii"])
def test_old_api_shim_bit_identical(schema):
    """simulate(Experiment) reproduces SimulationEngine(model, SimConfig)
    records bit-identically for a fixed seed, on every schema."""
    res = simulate(_exp(schema=schema))
    eng = _old_engine(schema)
    old = eng.run()
    assert len(old) == len(res.records)
    for a, b in zip(old, res.records):
        assert a.t == b.t and a.window == b.window and a.n == b.n
        assert (a.mean == b.mean).all()
        assert (a.var == b.var).all()
        assert (a.ci90 == b.ci90).all()


@pytest.mark.parametrize("schema", ["i", "ii", "iii"])
def test_host_loop_and_window_step_bit_identical(schema):
    """The legacy per-group gather/scatter path and the fused scan-based
    window_step produce bit-identical records AND trajectories."""
    new = simulate(_exp(schema=schema, record_trajectories=True))
    old = simulate(_exp(schema=schema, record_trajectories=True,
                        host_loop=True))
    assert (new.means() == old.means()).all()
    assert (new.trajectories() == old.trajectories()).all()
    # and measurably fewer device dispatches (3 groups of 8 lanes)
    assert new.telemetry.dispatches < old.telemetry.dispatches


def test_trajectories_schema_i_and_ii_present_and_equal():
    """Schema i materialises full trajectories (regression: it used to
    return None) and matches schema ii bitwise (keyed per-lane RNG)."""
    t_i = simulate(_exp(schema="i")).trajectories()
    t_ii = simulate(_exp(schema="ii")).trajectories()
    assert t_i is not None and t_i.shape == (24, 4, 2)
    assert (t_i == t_ii).all()
    # schema iii stays memory-bounded unless opted in
    assert simulate(_exp(schema="iii")).trajectories() is None
    t_iii = simulate(_exp(schema="iii",
                          record_trajectories=True)).trajectories()
    assert (t_iii == t_ii).all()


# ----------------------------------------------------- grouped stats
def test_per_point_grouped_reduction_matches_numpy():
    exp = Experiment(
        model=lotka_volterra(2),
        ensemble=Ensemble.make(replicas=8, sweep={"die": [0.1, 2.0]}),
        schedule=Schedule(t_end=2.0, n_windows=3, schema="ii"),
        reduction=Reduction.PER_POINT,
        n_lanes=16, seed=9)
    res = simulate(exp)
    pp = res.per_point()
    assert pp["mean"].shape == (3, 2, 2)
    assert pp["points"] == [{"die": 0.1}, {"die": 2.0}]
    assert (pp["n"] == 8).all()
    # oracle: per-point stats straight from the buffered trajectories
    traj = res.trajectories()  # (16, 3, 2)
    for p, sl in ((0, slice(0, 8)), (1, slice(8, 16))):
        np.testing.assert_allclose(
            pp["mean"][:, p], traj[sl].mean(axis=0), rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(
            pp["var"][:, p], traj[sl].var(axis=0, ddof=1),
            rtol=1e-4, atol=1e-4)
    # higher predator death rate -> fewer predators at the end
    assert pp["mean"][-1, 1, 1] < pp["mean"][-1, 0, 1]


def test_ensemble_reduction_has_no_grouped_stats():
    assert simulate(_exp()).per_point() is None


# ------------------------------------------------- checkpoint / resume
def test_checkpoint_resume_in_process(tmp_path):
    clean = simulate(_exp(windows=6))
    part = simulate(_exp(windows=6), max_windows=2,
                    checkpoint_path=str(tmp_path / "ck"))
    assert not part.completed and part.windows_run == 2
    part.resume()
    assert part.completed
    assert (part.means() == clean.means()).all()


def test_checkpoint_resume_from_file(tmp_path):
    """A fresh simulate(resume=True) continues bit-identically, records
    before the checkpoint included (replayed from the npz)."""
    ck = str(tmp_path / "ck")
    clean = simulate(_exp(windows=6))
    simulate(_exp(windows=6), max_windows=3, checkpoint_path=ck)
    resumed = simulate(_exp(windows=6), checkpoint_path=ck, resume=True)
    assert resumed.completed
    assert len(resumed.records) == 6
    assert (resumed.means() == clean.means()).all()


def test_resume_keeps_csv_and_grouped_stats_complete(tmp_path):
    """File-based resume replays restored records into fresh sinks and
    restores per-point grouped stats, so neither loses the
    pre-checkpoint windows."""
    ck = str(tmp_path / "ck")
    csv_path = str(tmp_path / "out.csv")

    def exp(sink=None):
        return Experiment(
            model=lotka_volterra(2),
            ensemble=Ensemble.make(replicas=8, sweep={"die": [0.1, 2.0]}),
            schedule=Schedule(t_end=1.0, n_windows=5, schema="iii"),
            reduction=Reduction.PER_POINT,
            sinks=(sink,) if sink else (),
            n_lanes=16, seed=2)

    simulate(exp(), max_windows=2, checkpoint_path=ck)
    resumed = simulate(exp(CsvSink(csv_path, ["prey", "pred"])),
                       checkpoint_path=ck, resume=True)
    clean = simulate(exp())
    assert len(open(csv_path).read().strip().splitlines()) == 6  # hdr + 5
    pp, pp_clean = resumed.per_point(), clean.per_point()
    assert pp["mean"].shape == pp_clean["mean"].shape == (5, 2, 2)
    assert (pp["mean"] == pp_clean["mean"]).all()


def test_max_steps_per_window_same_on_both_paths():
    sched = Schedule(t_end=1.0, n_windows=3, schema="iii",
                     max_steps_per_window=5)
    new = simulate(_exp().with_(schedule=sched))
    old = simulate(_exp().with_(schedule=sched, host_loop=True))
    unbounded = simulate(_exp(windows=3))
    assert (new.means() == old.means()).all()
    # the cap actually bit (5 SSA steps rarely reach the horizon)
    assert not (new.means() == unbounded.means()).all()
    with pytest.raises(ExperimentError, match="max_steps_per_window"):
        simulate(_exp().with_(schedule=sched, use_kernel=True))


def test_resume_requires_existing_checkpoint(tmp_path):
    with pytest.raises(ExperimentError, match="checkpoint_path"):
        simulate(_exp(), resume=True)
    with pytest.raises(ExperimentError, match="no checkpoint"):
        simulate(_exp(), resume=True,
                 checkpoint_path=str(tmp_path / "missing"))


# ------------------------------------------------------ sink lifecycle
def test_csv_sink_closed_by_simulate(tmp_path):
    path = str(tmp_path / "out.csv")
    sink = CsvSink(path, ["prey", "pred"])
    res = simulate(_exp(windows=5).with_(sinks=(sink,)))
    assert res.completed and sink.closed
    lines = open(path).read().strip().splitlines()
    assert len(lines) == 6  # header + one row per window
    assert lines[0].startswith("t,n,prey_mean")
    with pytest.raises(ValueError, match="closed"):
        sink(res.records[0])


def test_telemetry_counts_one_dispatch_per_window():
    res = simulate(_exp(windows=4))
    assert res.telemetry.dispatches == 4
    assert res.telemetry.wall_time_s > 0
    assert len(res.telemetry.window_wall_times) == 4


def test_kernel_path_is_one_dispatch_per_window():
    """The Pallas chunk loop now runs device-side: a kernel window is
    ONE dispatch (vs one per group for the host loop), there are no
    per-chunk host syncs (the truncation flag rides the per-window
    record pull — no longer its own sync), and the records are BITWISE
    equal to both the fused jnp path and the host-loop baseline —
    parity the counter-based RNG guarantees for any chunk size."""
    kern = simulate(_exp(windows=2, replicas=16, use_kernel=True))
    fused = simulate(_exp(windows=2, replicas=16))
    host = simulate(_exp(windows=2, replicas=16, host_loop=True))
    assert kern.telemetry.dispatches == 2  # one launch per window
    assert (kern.means() == fused.means()).all()
    assert (kern.means() == host.means()).all()
    # the truncation flag joins the combined end-of-window pull: the
    # kernel path's sync profile now EQUALS the fused jnp path's
    # (BENCH_PR3 recorded 2.0 syncs/window vs 1.0 before the fix)
    assert kern.telemetry.host_syncs == fused.telemetry.host_syncs
    # host_loop+use_kernel stays the per-group baseline: one fused
    # launch per (group x window), still no chunk-loop sync storm
    both = simulate(_exp(windows=2, replicas=16, host_loop=True,
                         use_kernel=True))
    assert both.telemetry.dispatches == 2 * 2  # 16 inst / 8 lanes
    assert (both.means() == kern.means()).all()


def test_kernel_budget_knobs_exposed_on_experiment():
    """The FusedWindowTruncated remedy ("raise kernel_max_chunks /
    kernel_chunk_steps") must be applicable through the declarative
    API, and the chunking must never change a trajectory."""
    from repro.kernels.ops import FusedWindowTruncated

    tight = _exp(windows=2, replicas=16, use_kernel=True,
                 kernel_chunk_steps=2, kernel_max_chunks=1)
    with pytest.raises(FusedWindowTruncated, match="kernel_max_chunks"):
        simulate(tight)
    odd = simulate(_exp(windows=2, replicas=16, use_kernel=True,
                        kernel_chunk_steps=7, kernel_max_chunks=512))
    default = simulate(_exp(windows=2, replicas=16, use_kernel=True))
    assert (odd.means() == default.means()).all()
    with pytest.raises(ExperimentError, match="kernel_chunk_steps"):
        simulate(_exp(use_kernel=True, kernel_chunk_steps=0))
    with pytest.raises(ExperimentError, match="kernel_max_chunks"):
        simulate(_exp(use_kernel=True, kernel_max_chunks=-1))
