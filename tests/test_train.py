"""Training: convergence, grad-accum equivalence, crash/restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import store
from repro.configs.base import OptimizerConfig, ShardingConfig
from repro.configs.registry import get_smoke_config
from repro.data.pipeline import synth_batch
from repro.models import build_model
from repro.runtime.fault import FailurePlan
from repro.train.drill import run_train_with_failures
from repro.sharding.rules import smoke_topology
from repro.train.optim import init_opt_state
from repro.train.step import make_train_step


def _setup(arch="llama3-8b", accum=1, lr=1e-3, steps=100):
    cfg = get_smoke_config(arch)
    topo = smoke_topology(cfg)
    model = build_model(cfg, topo, remat="none")
    ocfg = OptimizerConfig(lr=lr, warmup_steps=5, total_steps=steps)
    scfg = ShardingConfig(strategy="dp_tp", grad_accum=accum)
    step = jax.jit(make_train_step(model, ocfg, scfg), donate_argnums=(0,))
    params = model.init(jax.random.PRNGKey(0))
    state = {"params": params, "opt": init_opt_state(params, ocfg)}
    return cfg, model, step, state, ocfg


def test_loss_decreases():
    cfg, model, step, state, _ = _setup(steps=40)
    losses = []
    for i in range(40):
        b = synth_batch(cfg, 8, 32, i % 4)  # small repeated data
        state, m = step(state, b)
        losses.append(float(np.asarray(m["loss"])))
    assert losses[-1] < losses[0] - 0.5, losses[::8]


def test_grad_accum_equivalent():
    """accum=2 over a batch == accum=1 on the same batch (same grads up
    to fp tolerance) — verified via resulting params."""
    b = synth_batch(get_smoke_config("llama3-8b"), 8, 32, 0)
    outs = []
    for accum in (1, 2):
        cfg, model, step, state, _ = _setup(accum=accum)
        state, _ = step(state, b)
        outs.append(state["params"]["embed"])
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(outs[1]),
                               atol=2e-5)


def test_crash_restore_deterministic(tmp_path):
    cfg, model, step, state0, ocfg = _setup(steps=12)
    batches = [synth_batch(cfg, 4, 32, i) for i in range(8)]

    # clean run
    state = jax.tree.map(jnp.copy, state0)
    clean = []
    for b in batches:
        state, m = step(state, b)
        clean.append(float(np.asarray(m["loss"])))

    # crashy run
    ckpt_dir = str(tmp_path / "ck")
    saved = {}

    def save_fn(st, i):
        store.save(st, ckpt_dir, i)
        saved[i] = True

    def restore_fn():
        s = store.latest_step(ckpt_dir)
        template = jax.tree.map(jnp.copy, state0)
        return store.restore(template, ckpt_dir, s), s

    def make_state():
        return jax.tree.map(jnp.copy, state0)

    plan = FailurePlan(schedule={3: "crash", 6: "crash"})
    _, crashy, events = run_train_with_failures(
        make_state, step, batches, ckpt_dir, plan, save_fn, restore_fn,
        ckpt_every=2)
    assert len(events) == 2
    np.testing.assert_allclose(clean, crashy, rtol=1e-4, atol=1e-5)


def test_lr_schedule_and_clip():
    from repro.train.optim import clip_by_global_norm, lr_schedule

    ocfg = OptimizerConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    assert float(lr_schedule(ocfg, 0)) == 0.0
    assert np.isclose(float(lr_schedule(ocfg, 10)), 1e-3)
    assert float(lr_schedule(ocfg, 100)) < 2e-4
    g = {"a": jnp.full((4,), 100.0)}
    gc, norm = clip_by_global_norm(g, 1.0)
    assert np.isclose(float(norm), 200.0)
    assert np.isclose(
        float(jnp.sqrt(sum(jnp.sum(x * x) for x in jax.tree.leaves(gc)))),
        1.0, rtol=1e-5)
