"""Serve engine: continuous batching correctness + slot reuse."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.models import build_model
from repro.serve.engine import Request, ServeEngine
from repro.sharding.rules import smoke_topology


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("llama3-8b")
    topo = smoke_topology(cfg)
    model = build_model(cfg, topo)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _sequential_greedy(model, params, prompt, n_new, cache_len):
    """Oracle: single-request greedy decode."""
    cache, last = model.prefill(params, {"tokens": prompt[None, :]})

    # pad cache seq to cache_len like the engine does
    def pad(a):
        if a.ndim >= 3 and a.shape[-3] == prompt.shape[0]:
            pass
        return a

    toks = [int(jnp.argmax(last[0, -1]))]
    pos = prompt.shape[0]
    # rebuild full-size cache by re-prefilling into engine-shaped cache
    eng = ServeEngine(model, params, n_slots=1, cache_len=cache_len)
    eng.submit(Request(uid=0, prompt=np.asarray(prompt),
                       max_new_tokens=n_new))
    eng.run()
    return eng


def test_batched_equals_sequential(setup):
    """The same requests decoded (a) one at a time in a 1-slot engine and
    (b) together in a 4-slot engine produce identical greedy tokens."""
    cfg, model, params = setup
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (4, 7, 5, 9)]
    outs = {}
    for slots in (1, 4):
        eng = ServeEngine(model, params, n_slots=slots, cache_len=32)
        reqs = [Request(uid=i, prompt=p, max_new_tokens=6)
                for i, p in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        eng.run()
        outs[slots] = [tuple(r.out_tokens) for r in reqs]
        assert all(len(r.out_tokens) == 6 for r in reqs)
    assert outs[1] == outs[4]


def test_slot_reuse_and_utilisation(setup):
    cfg, model, params = setup
    rng = np.random.default_rng(1)
    eng = ServeEngine(model, params, n_slots=2, cache_len=32)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=3)
                    .astype(np.int32),
                    max_new_tokens=4) for i in range(6)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(len(r.out_tokens) == 4 for r in reqs)
    # 6 requests through 2 slots -> slots must have been reused
    assert eng.ticks >= 3 * 3
    assert eng.utilisation > 0.6


def test_streaming_callback(setup):
    cfg, model, params = setup
    got = []
    req = Request(uid=42, prompt=np.array([1, 2, 3], np.int32),
                  max_new_tokens=5,
                  on_token=lambda uid, tok: got.append((uid, tok)))
    eng = ServeEngine(model, params, n_slots=1, cache_len=16)
    eng.submit(req)
    eng.run()
    assert len(got) == 5 and all(u == 42 for u, _ in got)
    assert [t for _, t in got] == req.out_tokens
