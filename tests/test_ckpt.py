"""Checkpoint store: roundtrip, atomicity, corruption, async."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import store


def _tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.ones((2, 2), jnp.bfloat16),
                       "c": [jnp.zeros(3), jnp.full((2,), 7)]}}


def test_roundtrip(tmp_path):
    t = _tree()
    store.save(t, str(tmp_path), 5)
    assert store.latest_step(str(tmp_path)) == 5
    r = store.restore(jax.tree.map(jnp.zeros_like, t), str(tmp_path))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_latest_step_picks_max(tmp_path):
    t = _tree()
    for s in (1, 3, 2):
        store.save(t, str(tmp_path), s)
    assert store.latest_step(str(tmp_path)) == 3


def test_corruption_detected(tmp_path):
    t = _tree()
    p = store.save(t, str(tmp_path), 1)
    with open(os.path.join(p, "arrays.npz"), "ab") as f:
        f.write(b"junk")
    with pytest.raises(store.CheckpointCorrupt, match="checksum mismatch"):
        store.restore(t, str(tmp_path), 1)


def test_async_writer(tmp_path):
    w = store.AsyncWriter()
    t = _tree()
    w.submit(t, str(tmp_path), 7)
    w.wait()
    assert store.latest_step(str(tmp_path)) == 7
    r = store.restore(t, str(tmp_path))
    np.testing.assert_array_equal(np.asarray(r["a"]), np.asarray(t["a"]))


def test_elastic_restore_with_shardings(tmp_path):
    """Restore places leaves with explicitly provided shardings (the
    elastic-rescale path; trivially a 1-device sharding here)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.compat import make_mesh
    mesh = make_mesh((1,), ("data",), devices=jax.devices()[:1])
    t = _tree()
    store.save(t, str(tmp_path), 2)
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), t)
    r = store.restore(t, str(tmp_path), 2, shardings=sh)
    assert r["a"].sharding == NamedSharding(mesh, P())


# ------------------------------------------- namespaced coexistence
# Farm workers share ONE ckpt_dir; each worker's store is keyed by a
# `<namespace>__` filename prefix. The coexistence contract: listing,
# retention, and corruption reporting each see ONLY their own files.
def _save_ckpt(tmp_path, window, namespace=""):
    path = os.path.join(str(tmp_path),
                        store.checkpoint_name(window, namespace))
    return store.save_atomic(path, {"window": np.int64(window),
                                    "x": np.arange(4.0)})


def test_namespaced_stores_coexist_in_one_dir(tmp_path):
    for w in (0, 2, 4):
        _save_ckpt(tmp_path, w, "shard00")
    for w in (0, 2):
        _save_ckpt(tmp_path, w, "shard01")
    _save_ckpt(tmp_path, 6)  # legacy un-namespaced store
    assert [w for w, _ in store.list_checkpoints(
        str(tmp_path), "shard00")] == [0, 2, 4]
    assert [w for w, _ in store.list_checkpoints(
        str(tmp_path), "shard01")] == [0, 2]
    # the un-namespaced store never sees namespaced files
    assert [w for w, _ in store.list_checkpoints(str(tmp_path))] == [6]


def test_retention_prunes_only_its_own_namespace(tmp_path):
    for w in (0, 2, 4, 6):
        _save_ckpt(tmp_path, w, "shard00")
        _save_ckpt(tmp_path, w, "shard01")
    removed = store.RetentionPolicy(keep_last=2).apply(
        str(tmp_path), "shard00")
    assert len(removed) == 2
    assert all("shard00__" in os.path.basename(p) for p in removed)
    assert [w for w, _ in store.list_checkpoints(
        str(tmp_path), "shard00")] == [4, 6]
    # the sibling namespace is untouched
    assert [w for w, _ in store.list_checkpoints(
        str(tmp_path), "shard01")] == [0, 2, 4, 6]


def test_listing_ignores_foreign_and_partial_files(tmp_path):
    _save_ckpt(tmp_path, 2, "shard00")
    # interrupted atomic save leftover + unrelated farm artifacts
    for name in ("shard00__ckpt_4.npz.tmp.1234", "notackpt_3.npz",
                 "shard00__result.npz", "hb_shard00.json"):
        with open(os.path.join(str(tmp_path), name), "wb") as f:
            f.write(b"partial")
    assert [w for w, _ in store.list_checkpoints(
        str(tmp_path), "shard00")] == [2]
    assert store.list_checkpoints(str(tmp_path)) == []


def test_corrupt_checkpoint_error_names_owner(tmp_path):
    """A truncated worker checkpoint raises CheckpointCorrupt whose
    message carries the namespaced path — operators can tell WHOSE
    file died in a dir shared by the whole farm."""
    path = _save_ckpt(tmp_path, 2, "shard01")
    with open(path, "r+b") as f:
        f.truncate(os.path.getsize(path) // 2)
    with pytest.raises(store.CheckpointCorrupt, match="shard01__"):
        store.verify(path)


def test_namespace_rejects_underscores(tmp_path):
    with pytest.raises(ValueError, match="namespace"):
        store.list_checkpoints(str(tmp_path), "bad_name")
