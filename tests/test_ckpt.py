"""Checkpoint store: roundtrip, atomicity, corruption, async."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import store


def _tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.ones((2, 2), jnp.bfloat16),
                       "c": [jnp.zeros(3), jnp.full((2,), 7)]}}


def test_roundtrip(tmp_path):
    t = _tree()
    store.save(t, str(tmp_path), 5)
    assert store.latest_step(str(tmp_path)) == 5
    r = store.restore(jax.tree.map(jnp.zeros_like, t), str(tmp_path))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_latest_step_picks_max(tmp_path):
    t = _tree()
    for s in (1, 3, 2):
        store.save(t, str(tmp_path), s)
    assert store.latest_step(str(tmp_path)) == 3


def test_corruption_detected(tmp_path):
    t = _tree()
    p = store.save(t, str(tmp_path), 1)
    with open(os.path.join(p, "arrays.npz"), "ab") as f:
        f.write(b"junk")
    with pytest.raises(store.CheckpointCorrupt, match="checksum mismatch"):
        store.restore(t, str(tmp_path), 1)


def test_async_writer(tmp_path):
    w = store.AsyncWriter()
    t = _tree()
    w.submit(t, str(tmp_path), 7)
    w.wait()
    assert store.latest_step(str(tmp_path)) == 7
    r = store.restore(t, str(tmp_path))
    np.testing.assert_array_equal(np.asarray(r["a"]), np.asarray(t["a"]))


def test_elastic_restore_with_shardings(tmp_path):
    """Restore places leaves with explicitly provided shardings (the
    elastic-rescale path; trivially a 1-device sharding here)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.compat import make_mesh
    mesh = make_mesh((1,), ("data",), devices=jax.devices()[:1])
    t = _tree()
    store.save(t, str(tmp_path), 2)
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), t)
    r = store.restore(t, str(tmp_path), 2, shardings=sh)
    assert r["a"].sharding == NamedSharding(mesh, P())
